"""Benchmark entry point: one harness per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run --only table1
  PYTHONPATH=src python -m benchmarks.run --quick      # smaller corpus
  python benchmarks/run.py --list                      # enumerate harnesses

The roofline/dry-run analyses need 512 placeholder devices and live in
separate entry points:
  PYTHONPATH=src python -m repro.launch.dryrun --both --out results/dryrun.json
  PYTHONPATH=src python -m benchmarks.roofline --out results/roofline.json
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

# Script-friendly bootstrap: `python benchmarks/run.py` puts benchmarks/ on
# sys.path but neither the repo root (for `import benchmarks`) nor src (for
# `import repro`); add both so the module works as script and as -m target.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

# name -> (module, description).  perf_iterations / roofline are listed (and
# import-checked by --list) but run through their own __main__ entry points
# because they pin XLA_FLAGS for 512 placeholder devices at import.
HARNESSES = {
    "table1": ("benchmarks.table1_efficiency", "paper Table 1: efficiency"),
    "table2": ("benchmarks.table2_effectiveness",
               "paper Table 2: effectiveness"),
    "fig2": ("benchmarks.fig2_tradeoff", "paper Fig. 2: tradeoff curve"),
    "fig4": ("benchmarks.fig4_exploration", "paper Fig. 4: exploration"),
    "fig5": ("benchmarks.fig5_ann_bounds", "paper Fig. 5: ANN bounds"),
    "generalized": ("benchmarks.generalized_recsys",
                    "generalized bandit on recsys scorers"),
    "serving": ("benchmarks.serving_latency",
                "RetrievalEngine p50/p99 latency + throughput"),
    "serving_load": ("benchmarks.serving_load",
                     "open-loop Poisson load: goodput, sync vs async"),
    "reveal": ("benchmarks.reveal_throughput",
               "pooled frontier vs vmapped lockstep reveal engine"),
    "kernels": ("benchmarks.kernel_bench",
                "kernel-op block autotuning: tuned vs default tiles"),
    "sharded": ("benchmarks.sharded_serving",
                "corpus-sharded pooled-bandit serving, 1/4/16 shards"),
    "chaos": ("benchmarks.chaos_serving",
              "fault-injected serving: supervision, failover, ladder"),
    "compress": ("benchmarks.compression",
                 "compressed corpus: bytes/doc, dequant cells/s, fidelity"),
}
STANDALONE = {
    "perf_iterations": ("benchmarks.perf_iterations",
                        "§Perf hillclimb (own entry point, 512 fake devices)"),
    "roofline": ("benchmarks.roofline",
                 "roofline terms per cell (own entry point)"),
    "lint": ("repro.analysis.lint",
             "trace-safety + lockset lint "
             "(python -m repro.analysis.lint src)"),
}


def _to_jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_to_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        return float(obj)
    return obj


def list_harnesses() -> int:
    """Import-check and print every harness. A broken import (like the
    repro.dist regression this guards against) fails loudly, per-module."""
    failures = 0
    print(f"{'name':16s} {'module':34s} description")
    for name, (module, desc) in {**HARNESSES, **STANDALONE}.items():
        try:
            importlib.import_module(module)
            status = desc
        except Exception as e:
            failures += 1
            status = f"[IMPORT FAILED] {type(e).__name__}: {e}"
        print(f"{name:16s} {module:34s} {status}")
    if failures:
        print(f"\n{failures} harness module(s) failed to import")
    return 1 if failures else 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(HARNESSES))
    ap.add_argument("--list", action="store_true",
                    help="list harnesses (import-checking each) and exit")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/bench.json")
    args = ap.parse_args(argv)

    if args.list:
        return list_harnesses()

    n_docs = 192 if args.quick else 384
    n_q = 6 if args.quick else 12

    from benchmarks import (chaos_serving, compression, fig2_tradeoff,
                            fig4_exploration, fig5_ann_bounds,
                            generalized_recsys, kernel_bench,
                            reveal_throughput, serving_latency, serving_load,
                            sharded_serving, table1_efficiency,
                            table2_effectiveness)
    benches = {
        "table1": lambda: table1_efficiency.run(n_docs, n_q),
        "table2": lambda: table2_effectiveness.run(n_docs, n_q),
        "fig2": lambda: fig2_tradeoff.run(n_docs, n_q),
        "fig4": lambda: fig4_exploration.run(min(n_docs, 256), min(n_q, 8)),
        "fig5": lambda: fig5_ann_bounds.run(min(n_docs, 256), min(n_q, 8)),
        "generalized": lambda: generalized_recsys.run(),
        "serving": lambda: serving_latency.run(
            n_docs=min(n_docs, 96),
            n_requests=24 if args.quick else 48,
            batch_sizes=(2, 4) if args.quick else (2, 4, 8),
            alphas=(0.3,) if args.quick else (0.15, 0.3, 1.0)),
        "serving_load": lambda: serving_load.run(smoke=args.quick),
        "reveal": lambda: reveal_throughput.run(
            Q=16 if args.quick else 64, n_docs=min(n_docs, 96)),
        "kernels": lambda: kernel_bench.run(quick=args.quick),
        # spawns one subprocess per shard count (each pins its own XLA
        # host device count), so it is safe to run from this single-device
        # process.
        "sharded": lambda: sharded_serving.run(
            shard_counts=(1, 4) if args.quick else (1, 4, 16)),
        # the mesh chaos measurement runs in its own subprocess (it pins 4
        # host devices), so it is safe from this single-device process.
        "chaos": lambda: chaos_serving.run(quick=args.quick),
        "compress": lambda: compression.run(quick=args.quick),
    }
    wanted = [args.only] if args.only else list(benches)

    results = {}
    for name in wanted:
        t0 = time.time()
        print(f"\n######## {name} ########")
        results[name] = benches[name]()
        print(f"[{name} done in {time.time()-t0:.1f}s]")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(_to_jsonable(results), f, indent=1, default=str)
    print(f"\nwrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
