"""Benchmark entry point: one harness per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run --only table1
  PYTHONPATH=src python -m benchmarks.run --quick      # smaller corpus

The roofline/dry-run analyses need 512 placeholder devices and live in
separate entry points:
  PYTHONPATH=src python -m repro.launch.dryrun --both --out results/dryrun.json
  PYTHONPATH=src python -m benchmarks.roofline --out results/roofline.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def _to_jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_to_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        return float(obj)
    return obj


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "fig2", "fig4", "fig5",
                             "generalized"])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/bench.json")
    args = ap.parse_args(argv)

    n_docs = 192 if args.quick else 384
    n_q = 6 if args.quick else 12

    from benchmarks import (fig2_tradeoff, fig4_exploration, fig5_ann_bounds,
                            generalized_recsys, table1_efficiency,
                            table2_effectiveness)
    benches = {
        "table1": lambda: table1_efficiency.run(n_docs, n_q),
        "table2": lambda: table2_effectiveness.run(n_docs, n_q),
        "fig2": lambda: fig2_tradeoff.run(n_docs, n_q),
        "fig4": lambda: fig4_exploration.run(min(n_docs, 256), min(n_q, 8)),
        "fig5": lambda: fig5_ann_bounds.run(min(n_docs, 256), min(n_q, 8)),
        "generalized": lambda: generalized_recsys.run(),
    }
    wanted = [args.only] if args.only else list(benches)

    results = {}
    for name in wanted:
        t0 = time.time()
        print(f"\n######## {name} ########")
        results[name] = benches[name]()
        print(f"[{name} done in {time.time()-t0:.1f}s]")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(_to_jsonable(results), f, indent=1, default=str)
    print(f"\nwrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
