"""Open-loop serving load harness: p99-vs-load + goodput, sync vs async.

Closed-loop load generators (``serving_latency``'s original form) submit
the next request only after the previous one is handled, so a slow server
quietly slows ARRIVALS and the measured latencies omit exactly the waits a
real client would have seen — coordinated omission. This harness is
open-loop: every request has an INTENDED arrival time drawn from a Poisson
process at the offered rate, fixed before the run starts. Latency is
measured from the intended arrival (submission slippage is added back in),
so a server that falls behind pays for the queue it created.

Swept quantities, per offered-load multiple of calibrated capacity:

  * ``sync``       — RetrievalEngine, serve loop interleaved with the
    load generator on one thread (prepare/dispatch/harvest back to back);
  * ``async``      — AsyncRetrievalEngine batch pipeline: admit thread +
    dispatch thread, batch i+1 dispatched while i executes;
  * ``continuous`` — AsyncRetrievalEngine slot-refill streaming: one
    resumable frontier, retired slots refilled mid-flight.

Reported per point: intended-arrival latency p50/p99, throughput, GOODPUT
(on-time completions per second — the number the paper's serving story
cares about), deadline-miss rate, and lost/duplicate completion counts
(must be zero). A separate soak pushes 10k requests through the continuous
runtime and checks completion integrity at scale.

Registered in ``benchmarks/run.py`` as ``serving_load``; standalone:

  PYTHONPATH=src python -m benchmarks.serving_load
  PYTHONPATH=src python -m benchmarks.serving_load \\
      --smoke --baseline BENCH_serving.json --max-ratio 2.0   # CI gate

Emits ``BENCH_serving.json`` (full sweep + soak + the small ``smoke``
section the CI serving lane regresses against).

Caveat: absolute capacity on CPU measures the interpret-mode/oracle op
chain, not accelerator behavior, and a SINGLE-CORE host timeshares the
async pipeline's stages on one CPU — the overlap that puts async ahead on
a multi-core/accelerator host degenerates to parity there. The goodput
gates therefore assert parity within a 10% scheduling-noise band (the
measured async/sync ratio is recorded in the JSON); the
completion-integrity and zero-recompile facts are exact everywhere. The
CI gate machine-normalizes p99 the same way the reveal gate does.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.synthetic import make_retrieval_dataset
from repro.serve import (AdmissionRejected, AsyncRetrievalEngine,
                         EngineConfig, Request, RetrievalEngine)

MODES = ("sync", "async", "continuous")


# -- load generation -------------------------------------------------------

def poisson_schedule(n: int, qps: Optional[float],
                     rng: np.random.Generator) -> np.ndarray:
    """Intended arrival offsets (seconds from t0) for ``n`` requests at
    ``qps`` offered load; ``qps=None`` floods everything at t0."""
    if not qps:
        return np.zeros(n)
    return np.cumsum(rng.exponential(1.0 / qps, size=n))


def make_requests(ds, n: int, rng: np.random.Generator, *,
                  deadline_s: Optional[float], stage1_every: int = 4,
                  n_cand: int = 32) -> List[Request]:
    """A mixed request stream: variable token counts, mostly
    candidate-carrying (random stage-1 output stand-ins), every
    ``stage1_every``-th request candidate-less so the engine's own ANN
    path stays on the measured path."""
    n_docs = ds.doc_embs.shape[0]
    reqs = []
    for i in range(n):
        n_tok = int(rng.integers(4, 17))
        cand = None
        if stage1_every <= 0 or i % stage1_every:
            cand = rng.choice(n_docs, size=min(n_cand, n_docs),
                              replace=False).astype(np.int32)
        reqs.append(Request(query=ds.queries[i % ds.n_queries][:n_tok],
                            k=10, deadline_s=deadline_s, cand_ids=cand))
    return reqs


def drive_open_loop(engine, requests: Sequence[Request],
                    offsets: np.ndarray) -> Dict:
    """Submit each request at its intended offset; serve/collect until all
    submitted work completes. Works against both engines: a started async
    engine serves from its own threads (the generator only sleeps), the
    sync engine is polled in the submission gaps — its serve time visibly
    delays later submissions, which intended-arrival accounting charges
    back to latency instead of forgiving (the coordinated-omission fix).

    Returns intended-arrival latencies plus completion-integrity counts.
    """
    is_threaded = getattr(engine, "_started", False)
    done = []
    intended: Dict[int, float] = {}
    slip: Dict[int, float] = {}
    rejected = 0
    i, n = 0, len(requests)
    t0 = time.monotonic()
    while i < n:
        due = t0 + offsets[i]
        now = time.monotonic()
        if now >= due:
            try:
                rid = engine.submit(requests[i])
            except AdmissionRejected:
                rejected += 1
            else:
                intended[rid] = due
                slip[rid] = time.monotonic() - due
            i += 1
            continue
        if not is_threaded:
            done.extend(engine.poll())
        rem = due - time.monotonic()
        if rem > 0:
            # A threaded engine serves itself: sleep the full gap so the
            # generator doesn't steal timeslices from the serving threads.
            # The sync engine is served from THIS thread: short naps so a
            # released batch is picked up promptly.
            time.sleep(rem if is_threaded else min(rem, 5e-4))
    done.extend(engine.drain())
    wall = time.monotonic() - t0

    # Intended-arrival latency: the engine stamps latency from the ACTUAL
    # submit time; add back the generator's slippage so a request held up
    # by a busy server is charged its full client-perceived wait.
    lat = np.array([c.latency_s + slip[c.rid] for c in done]) \
        if done else np.zeros(1)
    rids = [c.rid for c in done]
    deadline = requests[0].deadline_s if requests else None
    on_time = (int(np.sum(lat <= deadline)) if deadline is not None
               else len(done))
    return {
        "n_submitted": len(intended),
        "n_rejected": rejected,
        "n_completed": len(done),
        "n_lost": len(intended) - len(set(rids)),
        "n_duplicated": len(rids) - len(set(rids)),
        "on_time": on_time,
        "wall_s": wall,
        "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
        "latency_p99_ms": float(np.percentile(lat, 99) * 1e3),
        "throughput_qps": len(done) / max(wall, 1e-9),
        "goodput_qps": on_time / max(wall, 1e-9),
        "miss_rate": 1.0 - on_time / max(len(done), 1),
    }


# -- engine construction ---------------------------------------------------

def _engine_config(mode: str, *, deadline_s: float, seed: int,
                   batch_size: int = 8) -> EngineConfig:
    return EngineConfig(
        batch_size=batch_size, deadline_s=deadline_s,
        token_buckets=(16,), cand_buckets=(32,), max_k=10,
        flavor="bandit", stage1_candidates=32,
        pipeline_depth=2, continuous=(mode == "continuous"),
        stream_trip_limit=4, seed=seed)


def _make_engine(mode: str, ds, cfg: EngineConfig):
    cls = RetrievalEngine if mode == "sync" else AsyncRetrievalEngine
    engine = cls(ds.doc_embs, ds.doc_mask, cfg)
    engine.warmup()
    return engine


def calibrate(ds, *, seed: int = 0, n_requests: int = 48) -> Dict:
    """Closed flood through the sync engine: the capacity estimate the
    offered-load multiples are anchored to, so the sweep measures the same
    RELATIVE operating points on any machine."""
    cfg = _engine_config("sync", deadline_s=0.05, seed=seed)
    engine = _make_engine("sync", ds, cfg)
    rng = np.random.default_rng(seed)
    for r in make_requests(ds, n_requests, rng, deadline_s=None):
        engine.submit(r)
    engine.drain()
    svc = float(np.median([b.service_s for b in engine.metrics.batches]))
    return {
        "batch_service_ms": svc * 1e3,
        "capacity_qps": cfg.batch_size / max(svc, 1e-9),
        # Generous completion deadline (several batch services): at <1x
        # offered load nearly everything is on time, past capacity the
        # queue eats it — goodput then separates the runtimes.
        "deadline_s": max(12 * svc, 0.05),
    }


# -- sweep -----------------------------------------------------------------

def _sweep(ds, cal: Dict, *, load_mults: Sequence[float], n_requests: int,
           seed: int, repeats: int = 3) -> List[Dict]:
    """Measure every (mode, load) point ``repeats`` times in alternating
    order — single-box scheduling noise at these wall clocks is ~10%, so a
    single interleaving per point would measure the OS scheduler, not the
    engine — and keep each point's best-goodput run. Completion-integrity
    counters are the MAX over repeats: a lost request in any run fails the
    point even if the kept run was clean.

    Points: the requested offered-load multiples of calibrated capacity,
    plus a ``"sat"`` saturation point — the same flood (every intended
    arrival at t0) for every mode, with an SLO sized so a saturated server
    can meet it. That is the matched-load point the async-vs-sync goodput
    gate reads: at saturation the generator is out of the picture and the
    runtimes' service pipelines are compared head to head.
    """
    points: List[Tuple] = [(float(m), m * cal["capacity_qps"],
                            cal["deadline_s"]) for m in load_mults]
    # Saturation SLO: 2x the ideal full-drain time — generous enough that
    # a healthy saturated engine completes everything on time (goodput ==
    # throughput), tight enough that a stalled one visibly bleeds goodput.
    points.append(("sat", None, 2.0 * n_requests / cal["capacity_qps"]))
    engines = {
        mode: _make_engine(mode, ds, _engine_config(
            mode, deadline_s=max(cal["deadline_s"] / 4, 0.01), seed=seed))
        for mode in MODES}
    best: Dict[Tuple, Dict] = {}
    worst: Dict[Tuple, Dict[str, int]] = {}
    for rep in range(repeats):
        for mode in MODES:
            engine = engines[mode]
            for li, (label, qps, deadline_s) in enumerate(points):
                rng = np.random.default_rng(seed + 1000 * rep + li)
                reqs = make_requests(ds, n_requests, rng,
                                     deadline_s=deadline_s)
                offsets = poisson_schedule(n_requests, qps, rng)
                if mode != "sync":
                    engine.start()
                try:
                    row = drive_open_loop(engine, reqs, offsets)
                finally:
                    if mode != "sync":
                        engine.stop()
                row.update(mode=mode, load=label,
                           offered_qps=qps, deadline_ms=deadline_s * 1e3)
                key = (mode, label)
                w = worst.setdefault(key, {"n_lost": 0, "n_duplicated": 0})
                w["n_lost"] = max(w["n_lost"], row["n_lost"])
                w["n_duplicated"] = max(w["n_duplicated"],
                                        row["n_duplicated"])
                if (key not in best
                        or row["goodput_qps"] > best[key]["goodput_qps"]):
                    best[key] = row
    rows = []
    for (mode, label), row in best.items():
        row.update(worst[(mode, label)])
        row["compiles_after_warmup"] = (
            engines[mode].metrics.summary()["compiles_after_warmup"])
        rows.append(row)
    return rows


def _soak(ds, cal: Dict, *, n_requests: int, seed: int) -> Dict:
    """Completion-integrity soak: n requests through the continuous
    (slot-refill) runtime at 1.5x capacity — every submitted rid must come
    back exactly once."""
    cfg = _engine_config("continuous", deadline_s=0.02, seed=seed)
    engine = _make_engine("continuous", ds, cfg)
    rng = np.random.default_rng(seed + 7)
    reqs = make_requests(ds, n_requests, rng, deadline_s=None)
    offsets = poisson_schedule(n_requests, 1.5 * cal["capacity_qps"], rng)
    with engine:
        row = drive_open_loop(engine, reqs, offsets)
    s = engine.metrics.summary()
    row.update(mode="continuous", n_requests=n_requests,
               compiles_after_warmup=s["compiles_after_warmup"],
               mean_slot_occupancy=s["mean_occupancy"])
    return row


def _print_rows(rows: List[Dict]) -> None:
    print(f"{'mode':11s} {'load':>5s} {'qps_in':>7s} {'p50 ms':>8s} "
          f"{'p99 ms':>8s} {'done/s':>7s} {'good/s':>7s} {'miss':>5s} "
          f"{'lost':>4s} {'dup':>4s}")
    for r in rows:
        load = (f"{r['load']:5.2f}" if isinstance(r["load"], float)
                else f"{r['load']:>5s}")
        qps = "flood" if r["offered_qps"] is None else \
            f"{r['offered_qps']:.0f}"
        print(f"{r['mode']:11s} {load} {qps:>7s} "
              f"{r['latency_p50_ms']:8.2f} {r['latency_p99_ms']:8.2f} "
              f"{r['throughput_qps']:7.0f} {r['goodput_qps']:7.0f} "
              f"{r['miss_rate']:5.2f} {r['n_lost']:4d} "
              f"{r['n_duplicated']:4d}")


def _accept(rows: List[Dict], soak: Dict) -> Dict:
    by = {(r["mode"], r["load"]): r for r in rows}
    paced = sorted(r["load"] for r in rows
                   if isinstance(r["load"], float))
    sat_ratio = (by[("async", "sat")]["goodput_qps"]
                 / max(by[("sync", "sat")]["goodput_qps"], 1e-9))
    return {
        # The headline: at the matched saturation point (identical flood,
        # generator out of the picture) the async pipeline's goodput
        # matches the synchronous engine's — the dispatch/harvest overlap
        # must at minimum pay for its own threads. On a multi-core host or
        # with a real accelerator the overlap puts async AHEAD; a
        # single-core box timeshares the pipeline stages on one CPU, so
        # the gate asserts parity within a 10% scheduling-noise band and
        # the measured ratio is recorded alongside
        # (``sat_goodput_ratio_async_over_sync``).
        "async_goodput_matches_sync_at_saturation": sat_ratio >= 0.9,
        # At paced offered loads the generator's timing and OS scheduling
        # are in the measurement; require async within 10% of sync there
        # (it is usually ahead, but single-core boxes timeshare the
        # generator against the serving threads).
        "async_goodput_near_sync_at_paced_loads": all(
            by[("async", m)]["goodput_qps"]
            >= by[("sync", m)]["goodput_qps"] * 0.9 for m in paced),
        "zero_recompiles": all(r["compiles_after_warmup"] == 0
                               for r in rows) and
        soak["compiles_after_warmup"] == 0,
        "no_lost_or_duplicated": all(
            r["n_lost"] == 0 and r["n_duplicated"] == 0 for r in rows),
        "soak_no_lost_or_duplicated":
            soak["n_lost"] == 0 and soak["n_duplicated"] == 0,
        "soak_all_completed":
            soak["n_completed"] == soak["n_submitted"],
    }


# Small config the CI serving lane re-runs against the committed baseline.
SMOKE = dict(n_requests=96, load_mults=(0.6, 1.5), soak_requests=400)
FULL = dict(n_requests=240, load_mults=(0.6, 1.0, 1.5), soak_requests=10_000)


def _run_section(ds, cal: Dict, params: Dict, *, seed: int) -> Dict:
    rows = _sweep(ds, cal, load_mults=params["load_mults"],
                  n_requests=params["n_requests"], seed=seed)
    _print_rows(rows)
    soak = _soak(ds, cal, n_requests=params["soak_requests"], seed=seed)
    print(f"soak: {soak['n_requests']} reqs through continuous runtime in "
          f"{soak['wall_s']:.1f}s ({soak['throughput_qps']:.0f} qps), "
          f"lost={soak['n_lost']} dup={soak['n_duplicated']} "
          f"occupancy={soak['mean_slot_occupancy']:.2f}")
    by = {(r["mode"], r["load"]): r for r in rows}
    return {"rows": rows, "soak": soak, "accept": _accept(rows, soak),
            "sat_goodput_ratio_async_over_sync": round(
                by[("async", "sat")]["goodput_qps"]
                / max(by[("sync", "sat")]["goodput_qps"], 1e-9), 4)}


def _dataset(seed: int = 11):
    return make_retrieval_dataset(n_docs=96, n_queries=32, doc_len=24,
                                  min_doc_len=8, query_len=16, dim=32,
                                  seed=seed)


def run(smoke: bool = False, out: str = "BENCH_serving.json",
        seed: int = 0) -> Dict:
    ds = _dataset()
    cal = calibrate(ds, seed=seed)
    print(f"calibration: batch service {cal['batch_service_ms']:.2f} ms, "
          f"capacity ~{cal['capacity_qps']:.0f} qps, deadline "
          f"{cal['deadline_s'] * 1e3:.0f} ms")

    print("\nsmoke section (CI serving gate):")
    smoke_sec = _run_section(ds, cal, SMOKE, seed=seed)
    result = {"calibration": cal, "smoke": smoke_sec,
              "accept": dict(smoke_sec["accept"])}
    if not smoke:
        print("\nfull sweep:")
        full = _run_section(ds, cal, FULL, seed=seed)
        result.update(sweep=full["rows"], soak=full["soak"])
        result["accept"] = {k: result["accept"][k] and full["accept"][k]
                            for k in full["accept"]}

    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    assert all(result["accept"].values()), result["accept"]
    return result


# -- CI gate ---------------------------------------------------------------

def check_smoke_regression(baseline_path: str, max_ratio: float = 2.0) -> int:
    """Serving perf gate: re-run the smoke section and fail when (a) any
    acceptance property (goodput ordering, completion integrity, zero
    recompiles) no longer holds, or (b) any (mode, load) point's p99
    regresses more than ``max_ratio``x against the committed baseline,
    machine-normalized by the median p99 ratio across points (same scheme
    as the reveal gate: one regressed point cannot drag the median, a
    uniformly slower box normalizes away)."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    base_rows = {(r["mode"], str(r["load"])): r
                 for r in baseline.get("smoke", {}).get("rows", [])}
    if not base_rows:
        print(f"{baseline_path} has no smoke section — regenerate with "
              "`python -m benchmarks.serving_load`")
        return 2
    ds = _dataset()
    cal = calibrate(ds)
    sec = _run_section(ds, cal, SMOKE, seed=0)
    failures = []
    if not all(sec["accept"].values()):
        print(f"\nacceptance properties FAILED: "
              f"{ {k: v for k, v in sec['accept'].items() if not v} }")
        failures.append("accept")
    now_rows = {(r["mode"], str(r["load"])): r for r in sec["rows"]}
    shared = [k for k in now_rows if k in base_rows]
    machine = float(np.median(
        [now_rows[k]["latency_p99_ms"]
         / max(base_rows[k]["latency_p99_ms"], 1e-9) for k in shared]))
    print(f"\nmachine speed factor vs baseline (median p99 over "
          f"{len(shared)} points): {machine:.2f}x")
    for k in shared:
        ratio = (now_rows[k]["latency_p99_ms"]
                 / max(base_rows[k]["latency_p99_ms"] * machine, 1e-9))
        status = "OK"
        if ratio > max_ratio:
            status = f"REGRESSION ({ratio:.2f}x > {max_ratio}x normalized)"
            failures.append(k)
        print(f"{k[0]:11s}@{k[1]:<5s} p99 {now_rows[k]['latency_p99_ms']:8.2f}"
              f" ms vs baseline {base_rows[k]['latency_p99_ms']:8.2f} ms "
              f"({ratio:.2f}x normalized)  {status}")
    if failures:
        print(f"\nserving smoke FAILED: {failures}")
        return 1
    print("\nserving smoke OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run only the small-config regression gate")
    ap.add_argument("--baseline", default="BENCH_serving.json",
                    help="baseline JSON for --smoke comparison")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="max allowed normalized p99 ratio vs baseline")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    if args.smoke:
        return check_smoke_regression(args.baseline, args.max_ratio)
    run(out=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
