"""Shared harness for the paper-table benchmarks.

All benchmarks run the two-stage pipeline on the synthetic topic-model
corpus (data/synthetic.py) and report the same quantities as the paper:
coverage (Eq. 6), Overlap@K (Eq. 16), Recall/MRR/nDCG@K, and FLOP savings
vs. full reranking. Col-Bandit operating points come from sweeping the
relaxation parameter alpha_ef (paper Sec. 5.1); baseline points from fixed
coverage budgets.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import BanditConfig
from repro.data.synthetic import RetrievalDataset, make_retrieval_dataset
from repro.retrieval.pipeline import evaluate_dataset

DEFAULT_ALPHAS = (0.05, 0.15, 0.3, 0.6, 1.0, 2.0)
DEFAULT_BUDGETS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.65, 0.8, 1.0)


@functools.lru_cache(maxsize=4)
def bench_dataset(n_docs: int = 384, n_queries: int = 12,
                  seed: int = 7) -> RetrievalDataset:
    return make_retrieval_dataset(n_docs=n_docs, n_queries=n_queries,
                                  distractors_per_query=32, seed=seed)


def frontier_bandit(ds: RetrievalDataset, *, k: int, method: str = "bandit",
                    alphas: Sequence[float] = DEFAULT_ALPHAS,
                    use_ann_bounds: bool = True, epsilon: float = 0.1,
                    warmup_fraction: float = 0.0,
                    init_one_per_doc: bool = True,
                    bias_kappa: float = 0.25,
                    prereveal_ann: bool = False) -> List[Dict]:
    """One operating point per alpha_ef (paper Fig. 2 star markers).
    bias_kappa=0 reproduces the paper's exact Eq. 12 radius."""
    pts = []
    for alpha in alphas:
        cfg = BanditConfig(k=k, alpha_ef=alpha, epsilon=epsilon,
                           warmup_fraction=warmup_fraction,
                           bias_kappa=bias_kappa)
        out = evaluate_dataset(ds, method=method, k=k, bandit=cfg,
                               use_ann_bounds=use_ann_bounds,
                               prereveal_ann=prereveal_ann)
        out["alpha_ef"] = alpha
        pts.append(out)
    return pts


def frontier_budget(ds: RetrievalDataset, *, k: int, method: str,
                    budgets: Sequence[float] = DEFAULT_BUDGETS,
                    use_ann_bounds: bool = True) -> List[Dict]:
    pts = []
    for frac in budgets:
        out = evaluate_dataset(ds, method=method, k=k,
                               budget_fraction=frac,
                               use_ann_bounds=use_ann_bounds)
        out["budget"] = frac
        pts.append(out)
    return pts


def coverage_for_target(points: List[Dict], target_overlap: float
                        ) -> Optional[float]:
    """Min mean coverage among operating points reaching the target
    (paper Table 1: 'coverage budget required to achieve X% Overlap@K')."""
    ok = [p["coverage"] for p in points if p["overlap"] >= target_overlap]
    return min(ok) if ok else None


def fmt_cov(c: Optional[float]) -> str:
    return f"{100 * c:5.1f}%" if c is not None else "  >100%"


def savings(c: Optional[float]) -> str:
    return f"{1.0 / c:4.1f}x" if c else "  - "
