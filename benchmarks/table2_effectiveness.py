"""Paper Table 2 — retrieval effectiveness (Recall/nDCG/MRR@5) at matched
coverage levels, vs. the full-reranking reference."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_dataset, frontier_bandit, frontier_budget
from repro.retrieval.pipeline import evaluate_dataset


def _closest(points, cov):
    return min(points, key=lambda p: abs(p["coverage"] - cov))


def run(n_docs: int = 384, n_queries: int = 12) -> dict:
    ds = bench_dataset(n_docs, n_queries)
    k = 5
    full = evaluate_dataset(ds, method="exact", k=k)
    bandit = frontier_bandit(ds, k=k)
    uni = frontier_budget(ds, k=k, method="uniform")
    top = frontier_budget(ds, k=k, method="topmargin")

    print("\n=== Table 2: retrieval effectiveness at matched coverage ===")
    print(f"{'method':22s} {'coverage':>9s} {'Recall@5':>9s} "
          f"{'nDCG@5':>8s} {'MRR@5':>8s}")
    print(f"{'Full ColBERT':22s} {'100.0%':>9s} {full['recall']:9.3f} "
          f"{full['ndcg']:8.3f} {full['mrr']:8.3f}")
    rows = {"full": full}
    for cov in (0.2, 0.4):
        p = _closest(bandit, cov)
        print(f"{'Col-Bandit':22s} {100*p['coverage']:8.1f}% "
              f"{p['recall']:9.3f} {p['ndcg']:8.3f} {p['mrr']:8.3f}")
        rows[f"bandit@{cov}"] = p
    for name, pts in (("Doc-TopMargin", top), ("Doc-Uniform", uni)):
        p = _closest(pts, 0.4)
        print(f"{name:22s} {100*p['coverage']:8.1f}% "
              f"{p['recall']:9.3f} {p['ndcg']:8.3f} {p['mrr']:8.3f}")
        rows[f"{name}@0.4"] = p

    b40 = _closest(bandit, 0.4)
    print("\nRelative retention at ~40% coverage (vs Full):")
    for m in ("recall", "ndcg", "mrr"):
        print(f"  {m}: {100 * b40[m] / max(full[m], 1e-9):5.1f}%")
    return rows


if __name__ == "__main__":
    run()
