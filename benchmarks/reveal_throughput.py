"""Reveal-engine throughput: pooled cross-query frontier vs vmapped lockstep.

The serving question this answers: on a mixed-difficulty batch, how many
reveal rounds does the batch actually PAY, and how fast do revealed cells
come out of the engine?

  * ``vmapped`` — jax.vmap(solo bandit): every query rides the global
    while_loop to the SLOWEST query's round count (lockstep), so the batch
    pays Q * max(rounds) round-slots.
  * ``pooled`` — repro.core.frontier with the CHAIN round body (the
    ``REPRO_KERNEL_IMPL=ref`` oracle): one global loop, per-query
    retirement, but each round still pays the gather -> score ->
    five-scatter state-update op chain.
  * ``pooled_fused`` — the fused round body: one reveal launch per round
    returning values AND sufficient-statistic deltas, state update
    collapsed to one scatter-min + one scatter-add, compaction skipped at
    fixed capacity. Identical reveal trajectory to ``pooled`` (pinned in
    ``accept``), strictly fewer ops per trip — this row must be the
    fastest engine (>= vmapped cells/s, the PR-5 acceptance bar).
  * ``pooled_grow`` / ``pooled_grow2d`` — retired queries' capacity is
    reallocated to the stragglers (doc slots; doc slots + token widths),
    shrinking the global trip count itself.

Also verifies the serving-side acceptance properties:
  * full-budget parity — in hard-bound mode (alpha_ef -> inf) both pooled
    round bodies and vmapped return the IDENTICAL top-K set per query;
  * the compiled dense serving step materializes no (B, N, L, T)
    similarity intermediate (``launch.hlo_analysis.peak_buffer_bytes``
    against the einsum formulation it replaced).

Registered in ``benchmarks/run.py`` as ``reveal``; standalone:

  PYTHONPATH=src python -m benchmarks.reveal_throughput
  PYTHONPATH=src python -m benchmarks.reveal_throughput \\
      --smoke --baseline BENCH_reveal.json --max-ratio 1.5   # CI perf gate

Emits ``BENCH_reveal.json`` (cells/s, total rounds, lockstep waste, the
small-config ``smoke`` section the CI perf lane regresses against, and the
autotuned kernel block table for the benchmark's serving-analog shapes).

Caveat on cells/s: oracle mode on CPU measures control-loop op dispatch;
the launch-consolidation win (one fused reveal kernel per round for the
whole batch instead of Q per-query reveals) is a TPU property. The rounds /
waste / trips / occupancy columns are engine-invariant scheduling facts.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import run_batched_oracle, run_pooled_oracle
from repro.data.synthetic import make_mixed_difficulty_h
from repro.launch.hlo_analysis import peak_buffer_bytes


def _run_engines(H, keys, *, k: int, alpha_ef: float, block_docs: int,
                 block_tokens: int, grow: int, repeats: int = 3
                 ) -> Dict[str, Dict]:
    Q, N, T = H.shape
    a = jnp.zeros(H.shape, jnp.float32)
    b = jnp.ones(H.shape, jnp.float32)
    kw = dict(k=k, alpha_ef=alpha_ef, block_docs=block_docs,
              block_tokens=block_tokens)

    solo = functools.partial(run_batched_oracle, **kw)
    runners = {
        "vmapped": lambda: jax.vmap(solo)(H, a, b, keys),
        "pooled": lambda: run_pooled_oracle(H, a, b, keys, fused=False,
                                            **kw),
        "pooled_fused": lambda: run_pooled_oracle(H, a, b, keys, fused=True,
                                                  **kw),
        "pooled_grow": lambda: run_pooled_oracle(H, a, b, keys, fused=True,
                                                 max_block_docs=grow, **kw),
        "pooled_grow2d": lambda: run_pooled_oracle(
            H, a, b, keys, fused=True, max_block_docs=grow,
            max_block_tokens=2 * block_tokens, **kw),
    }
    out: Dict[str, Dict] = {}
    for name, fn in runners.items():
        res = jax.block_until_ready(fn())        # compile + warm
        wall = float("inf")                      # best-of-N: dispatch noise
        for _ in range(max(repeats, 1)):         # must not decide the race
            t0 = time.perf_counter()
            res = jax.block_until_ready(fn())
            wall = min(wall, time.perf_counter() - t0)
        rounds = np.asarray(res.rounds)
        reveals = int(np.asarray(res.reveals).sum())
        row = {
            "wall_s": wall,
            "cells_per_s": reveals / max(wall, 1e-9),
            "total_reveals": reveals,
            "rounds_mean": float(rounds.mean()),
            "rounds_max": int(rounds.max()),
            "total_rounds": int(rounds.sum()),
            "lockstep_rounds": int(Q * rounds.max()),
            "lockstep_waste": int(Q * rounds.max() - rounds.sum()),
        }
        if hasattr(res, "occupancy"):
            row["trips"] = int(res.trips)
            row["frontier_occupancy"] = float(res.occupancy)
        out[name] = row
    return out


def _topk_parity(H, keys, *, k: int, block_docs: int,
                 block_tokens: int) -> bool:
    """Hard-bound full-budget mode: both pooled round bodies and vmapped
    must return the identical top-K SET for every query."""
    a = jnp.zeros(H.shape, jnp.float32)
    b = jnp.ones(H.shape, jnp.float32)
    kw = dict(k=k, alpha_ef=1e9, block_docs=block_docs,
              block_tokens=block_tokens)
    vm = jax.vmap(functools.partial(run_batched_oracle, **kw))(H, a, b, keys)
    vm_tk = np.asarray(vm.topk)
    for fused in (False, True):
        pl = run_pooled_oracle(H, a, b, keys, fused=fused, **kw)
        pl_tk = np.asarray(pl.topk)
        if not all(set(vm_tk[q]) == set(pl_tk[q]) for q in range(H.shape[0])):
            return False
    return True


def _dense_peak_buffer(*, B=8, C=64, N=32, L=512, M=16, T=64) -> Dict:
    """Compile the engine-facing dense step under REPRO_KERNEL_IMPL=ref
    (the L-chunked scorer every non-TPU CI lane runs; the Pallas path tiles
    through VMEM by construction) and check its peak temp buffer stays
    below one (B, N, L, T) f32 tensor — the intermediate the einsum
    formulation it replaced always materialized."""
    from repro.retrieval.service import gather_candidates, rerank_dense_step

    SDS = jax.ShapeDtypeStruct
    args = (SDS((C, L, M), jnp.float32), SDS((C, L), jnp.bool_),
            SDS((B, T, M), jnp.float32), SDS((B, N), jnp.int32),
            SDS((B, N, T), jnp.float32), SDS((B, N, T), jnp.float32),
            SDS((), jnp.int32))

    def step(ce, cm, q, cand, a, b, seed):
        return rerank_dense_step(ce, cm, q, cand, a, b,
                                 jax.random.key(seed), topk=10)

    def einsum_step(ce, cm, q, cand, a, b, seed):   # the replaced path
        del a, b, seed
        docs, dmask = gather_candidates(ce, cm, cand)
        sims = jnp.einsum("bnlm,btm->bnlt", docs, q)
        sims = jnp.where(dmask[:, :, :, None], sims, -3e38)
        h = jnp.max(sims, axis=2)
        return jnp.sum(jnp.where(jnp.any(dmask, 2)[:, :, None], h, 0.0), -1)

    prev = os.environ.get("REPRO_KERNEL_IMPL")
    os.environ["REPRO_KERNEL_IMPL"] = "ref"
    try:
        peak = peak_buffer_bytes(jax.jit(step).lower(*args).compile())
        peak_einsum = peak_buffer_bytes(
            jax.jit(einsum_step).lower(*args).compile())
    finally:
        if prev is None:
            os.environ.pop("REPRO_KERNEL_IMPL", None)
        else:
            os.environ["REPRO_KERNEL_IMPL"] = prev
    bnlt = B * N * L * T * 4
    return {
        "shape": {"B": B, "N": N, "L": L, "M": M, "T": T},
        "bnlt_bytes": bnlt,
        "peak_temp_bytes": peak,
        "peak_temp_bytes_einsum": peak_einsum,
        "no_bnlt_intermediate": peak < bnlt,
    }


def _tuned_block_table(*, Q: int, block_docs: int, block_tokens: int,
                       L: int = 128, M: int = 128) -> Dict:
    """Autotune the reveal-path kernels at the benchmark's serving-analog
    shapes (the oracle rows above have no embeddings; this is the shape the
    SERVING frontier would launch for the same batch geometry) and return
    the tuned table rows for BENCH_reveal.json."""
    from repro.kernels import tuning
    from repro.kernels.ops import autotune_op

    half = max(block_docs // 2, 1)
    rows = Q * 2 * half
    dims = dict(B=rows, G=block_tokens, L=L, M=M, D=Q * 64, TQ=Q * 32)
    t0 = time.perf_counter()
    table: Dict[str, Dict] = {}
    for op in ("fused_reveal", "gather_maxsim"):
        best, timings = autotune_op(op, dims)
        table[op] = {"dims": dims, "best": best, "timings_s": timings}
    return {"autotune_s": time.perf_counter() - t0, "ops": table,
            "table": tuning.table_json()}


def _bench_section(Q, n_docs, n_tokens, *, k, alpha_ef, block_docs,
                   block_tokens, grow, seed, repeats=3) -> Dict:
    H = jnp.asarray(make_mixed_difficulty_h(Q, n_docs, n_tokens, k=k,
                                            seed=seed))
    keys = jax.random.split(jax.random.key(seed), Q)
    engines = _run_engines(H, keys, k=k, alpha_ef=alpha_ef,
                           block_docs=block_docs, block_tokens=block_tokens,
                           grow=grow, repeats=repeats)
    hdr = (f"{'engine':14s} {'cells/s':>12s} {'rounds':>7s} {'lockstep':>9s} "
           f"{'waste':>6s} {'trips':>6s} {'occ':>5s}")
    print(f"mixed-difficulty batch: Q={Q}, N={n_docs}, T={n_tokens}, "
          f"block={block_docs}x{block_tokens}, alpha_ef={alpha_ef}")
    print(hdr)
    for name, r in engines.items():
        print(f"{name:14s} {r['cells_per_s']:12.0f} {r['total_rounds']:7d} "
              f"{r['lockstep_rounds']:9d} {r['lockstep_waste']:6d} "
              f"{r.get('trips', r['rounds_max']):6d} "
              f"{r.get('frontier_occupancy', float('nan')):5.2f}")
    parity = _topk_parity(H, keys, k=k, block_docs=block_docs,
                          block_tokens=block_tokens)
    return {
        "config": {"Q": Q, "N": n_docs, "T": n_tokens, "k": k,
                   "alpha_ef": alpha_ef, "block_docs": block_docs,
                   "block_tokens": block_tokens, "grow": grow, "seed": seed},
        "engines": engines,
        "full_budget_topk_parity": parity,
    }


# Small config the CI perf-smoke lane re-runs and regresses against the
# committed baseline (see ``check_smoke_regression``). Sized so every
# engine's wall stays in the tens of milliseconds: single-digit-ms walls
# put dispatch jitter inside the 1.5x gate.
SMOKE = dict(Q=32, n_docs=64, n_tokens=32, k=5, alpha_ef=0.3, block_docs=8,
             block_tokens=4, grow=24, seed=0, repeats=7)


def _run_smoke() -> Dict:
    return _bench_section(SMOKE["Q"], SMOKE["n_docs"], SMOKE["n_tokens"],
                          k=SMOKE["k"], alpha_ef=SMOKE["alpha_ef"],
                          block_docs=SMOKE["block_docs"],
                          block_tokens=SMOKE["block_tokens"],
                          grow=SMOKE["grow"], seed=SMOKE["seed"],
                          repeats=SMOKE["repeats"])


def run(Q: int = 64, n_docs: int = 64, n_tokens: int = 32, k: int = 10,
        alpha_ef: float = 0.3, block_docs: int = 16, block_tokens: int = 4,
        grow: int = 48, seed: int = 0,
        out: str = "BENCH_reveal.json") -> Dict:
    main = _bench_section(Q, n_docs, n_tokens, k=k, alpha_ef=alpha_ef,
                          block_docs=block_docs, block_tokens=block_tokens,
                          grow=grow, seed=seed)
    engines = main["engines"]
    print("\nsmoke config (CI perf gate):")
    smoke = _run_smoke()

    dense = _dense_peak_buffer()
    tuned = _tuned_block_table(Q=Q, block_docs=block_docs,
                               block_tokens=block_tokens)
    pooled, fused = engines["pooled"], engines["pooled_fused"]
    accept = {
        # Q * max(per-query rounds) is what lockstep pays; the pooled
        # engine's attributable rounds must come in strictly below it.
        "total_rounds_below_lockstep":
            pooled["total_rounds"] < pooled["lockstep_rounds"],
        "full_budget_topk_parity": main["full_budget_topk_parity"],
        "dense_no_bnlt_intermediate": dense["no_bnlt_intermediate"],
        # PR-5 acceptance: the fused round reveals the EXACT same cells as
        # the unfused pooled engine and flips the throughput ordering.
        "fused_reveal_count_parity":
            fused["total_reveals"] == pooled["total_reveals"]
            and fused["total_rounds"] == pooled["total_rounds"],
        "fused_at_least_vmapped_cells_per_s":
            fused["cells_per_s"] >= engines["vmapped"]["cells_per_s"],
    }
    print(f"\nparity(full budget): {main['full_budget_topk_parity']}   "
          f"dense peak {dense['peak_temp_bytes']/2**20:.1f} MiB vs BNLT "
          f"{dense['bnlt_bytes']/2**20:.1f} MiB (einsum path was "
          f"{dense['peak_temp_bytes_einsum']/2**20:.1f} MiB)")
    print(f"fused vs vmapped cells/s: {fused['cells_per_s']:.0f} vs "
          f"{engines['vmapped']['cells_per_s']:.0f} "
          f"({fused['cells_per_s']/engines['vmapped']['cells_per_s']:.2f}x)")

    result = {
        "config": main["config"],
        "engines": engines,
        "smoke": smoke,
        "tuning": tuned,
        "dense_peak_buffer": dense,
        "accept": accept,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    assert all(accept.values()), accept
    return result


def check_smoke_regression(baseline_path: str, max_ratio: float = 1.5) -> int:
    """CI perf-smoke gate: re-run the small config and fail (non-zero) when
    any engine's wall clock regresses more than ``max_ratio``x against the
    committed baseline's ``smoke`` section, MACHINE-NORMALIZED: the
    baseline was timed on whatever box regenerated it, so raw walls are
    not comparable across hardware (or across load on a shared box). The
    speed factor is the MEDIAN of (wall_now / wall_baseline) over all
    engines — one genuinely regressed engine cannot drag the median, while
    a uniformly slower/faster machine normalizes away. (The flip side is
    inherent: a slowdown hitting every engine equally is indistinguishable
    from slower hardware — that is what the absolute BENCH numbers on the
    regenerating box are for.)

    Reveal-trajectory facts (total reveals / rounds) must match the
    baseline exactly on every engine — a drift there is a silent policy
    change, not noise, and no amount of hardware variance excuses it."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    base = baseline.get("smoke", {}).get("engines")
    if not base:
        print(f"{baseline_path} has no smoke section — regenerate the "
              "baseline with `python -m benchmarks.reveal_throughput`")
        return 2
    smoke = _run_smoke()
    shared = [n for n in smoke["engines"] if n in base]
    machine = float(np.median([
        smoke["engines"][n]["wall_s"] / max(base[n]["wall_s"], 1e-9)
        for n in shared]))
    print(f"machine speed factor vs baseline (median over "
          f"{len(shared)} engines): {machine:.2f}x")
    failures = []
    for name, row in smoke["engines"].items():
        b = base.get(name)
        if b is None:
            continue                      # new engine: no baseline yet
        ratio = row["wall_s"] / max(b["wall_s"] * machine, 1e-9)
        drift = (row["total_reveals"] != b["total_reveals"]
                 or row["total_rounds"] != b["total_rounds"])
        status = "OK"
        if ratio > max_ratio:
            status = f"REGRESSION ({ratio:.2f}x > {max_ratio}x normalized)"
            failures.append(name)
        if drift:
            status = (f"TRAJECTORY DRIFT (reveals {row['total_reveals']} vs "
                      f"{b['total_reveals']})")
            failures.append(name)
        print(f"{name:14s} wall {row['wall_s']*1e3:8.1f} ms vs baseline "
              f"{b['wall_s']*1e3:8.1f} ms ({ratio:.2f}x normalized)  "
              f"{status}")
    if failures:
        print(f"\nperf smoke FAILED: {sorted(set(failures))}")
        return 1
    print("\nperf smoke OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run only the small-config regression gate")
    ap.add_argument("--baseline", default="BENCH_reveal.json",
                    help="baseline JSON for --smoke comparison")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="max allowed wall-clock ratio vs baseline")
    ap.add_argument("--out", default="BENCH_reveal.json")
    args = ap.parse_args(argv)
    if args.smoke:
        return check_smoke_regression(args.baseline, args.max_ratio)
    run(out=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
