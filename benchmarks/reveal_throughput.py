"""Reveal-engine throughput: pooled cross-query frontier vs vmapped lockstep.

The serving question this answers: on a mixed-difficulty batch, how many
reveal rounds does the batch actually PAY, and how fast do revealed cells
come out of the engine?

  * ``vmapped`` — jax.vmap(solo bandit): every query rides the global
    while_loop to the SLOWEST query's round count (lockstep), so the batch
    pays Q * max(rounds) round-slots.
  * ``pooled`` — repro.core.frontier: one global loop, per-query retirement;
    the batch pays sum(rounds) round-slots and the frontier occupancy
    reports how full the shared reveal kernel runs.
  * ``pooled+grow`` — retired queries' slots are reallocated to the
    stragglers (max_block_docs), shrinking the global trip count itself.

Also verifies the two serving-side acceptance properties:
  * full-budget parity — in hard-bound mode (alpha_ef -> inf) pooled and
    vmapped return the IDENTICAL top-K set per query;
  * the compiled dense serving step materializes no (B, N, L, T)
    similarity intermediate (``launch.hlo_analysis.peak_buffer_bytes``
    against the einsum formulation it replaced).

Registered in ``benchmarks/run.py`` as ``reveal``; standalone:

  PYTHONPATH=src python -m benchmarks.reveal_throughput

Emits ``BENCH_reveal.json`` (cells/s, total rounds, lockstep waste).

Caveat on cells/s: oracle mode on CPU measures control-loop op dispatch,
where the pooled body pays extra compaction/scatter ops per trip; the
launch-consolidation win (one gather_maxsim kernel per round for the whole
batch instead of Q per-query reveals) is a TPU property. The rounds /
waste / trips / occupancy columns are engine-invariant scheduling facts.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import run_batched_oracle, run_pooled_oracle
from repro.data.synthetic import make_mixed_difficulty_h
from repro.launch.hlo_analysis import peak_buffer_bytes


def _run_engines(H, keys, *, k: int, alpha_ef: float, block_docs: int,
                 block_tokens: int, grow: int) -> Dict[str, Dict]:
    Q, N, T = H.shape
    a = jnp.zeros(H.shape, jnp.float32)
    b = jnp.ones(H.shape, jnp.float32)
    kw = dict(k=k, alpha_ef=alpha_ef, block_docs=block_docs,
              block_tokens=block_tokens)

    solo = functools.partial(run_batched_oracle, **kw)
    runners = {
        "vmapped": lambda: jax.vmap(solo)(H, a, b, keys),
        "pooled": lambda: run_pooled_oracle(H, a, b, keys, **kw),
        "pooled_grow": lambda: run_pooled_oracle(H, a, b, keys,
                                                 max_block_docs=grow, **kw),
    }
    out: Dict[str, Dict] = {}
    for name, fn in runners.items():
        jax.block_until_ready(fn())              # compile + warm
        t0 = time.perf_counter()
        res = jax.block_until_ready(fn())
        wall = time.perf_counter() - t0
        rounds = np.asarray(res.rounds)
        reveals = int(np.asarray(res.reveals).sum())
        row = {
            "wall_s": wall,
            "cells_per_s": reveals / max(wall, 1e-9),
            "total_reveals": reveals,
            "rounds_mean": float(rounds.mean()),
            "rounds_max": int(rounds.max()),
            "total_rounds": int(rounds.sum()),
            "lockstep_rounds": int(Q * rounds.max()),
            "lockstep_waste": int(Q * rounds.max() - rounds.sum()),
        }
        if hasattr(res, "occupancy"):
            row["trips"] = int(res.trips)
            row["frontier_occupancy"] = float(res.occupancy)
        out[name] = row
    return out


def _topk_parity(H, keys, *, k: int, block_docs: int,
                 block_tokens: int) -> bool:
    """Hard-bound full-budget mode: pooled and vmapped must return the
    identical top-K SET for every query."""
    a = jnp.zeros(H.shape, jnp.float32)
    b = jnp.ones(H.shape, jnp.float32)
    kw = dict(k=k, alpha_ef=1e9, block_docs=block_docs,
              block_tokens=block_tokens)
    vm = jax.vmap(functools.partial(run_batched_oracle, **kw))(H, a, b, keys)
    pl = run_pooled_oracle(H, a, b, keys, **kw)
    vm_tk, pl_tk = np.asarray(vm.topk), np.asarray(pl.topk)
    return all(set(vm_tk[q]) == set(pl_tk[q]) for q in range(H.shape[0]))


def _dense_peak_buffer(*, B=8, C=64, N=32, L=512, M=16, T=64) -> Dict:
    """Compile the engine-facing dense step under REPRO_KERNEL_IMPL=ref
    (the L-chunked scorer every non-TPU CI lane runs; the Pallas path tiles
    through VMEM by construction) and check its peak temp buffer stays
    below one (B, N, L, T) f32 tensor — the intermediate the einsum
    formulation it replaced always materialized."""
    from repro.retrieval.service import gather_candidates, rerank_dense_step

    SDS = jax.ShapeDtypeStruct
    args = (SDS((C, L, M), jnp.float32), SDS((C, L), jnp.bool_),
            SDS((B, T, M), jnp.float32), SDS((B, N), jnp.int32),
            SDS((B, N, T), jnp.float32), SDS((B, N, T), jnp.float32),
            SDS((), jnp.int32))

    def step(ce, cm, q, cand, a, b, seed):
        return rerank_dense_step(ce, cm, q, cand, a, b,
                                 jax.random.key(seed), topk=10)

    def einsum_step(ce, cm, q, cand, a, b, seed):   # the replaced path
        del a, b, seed
        docs, dmask = gather_candidates(ce, cm, cand)
        sims = jnp.einsum("bnlm,btm->bnlt", docs, q)
        sims = jnp.where(dmask[:, :, :, None], sims, -3e38)
        h = jnp.max(sims, axis=2)
        return jnp.sum(jnp.where(jnp.any(dmask, 2)[:, :, None], h, 0.0), -1)

    prev = os.environ.get("REPRO_KERNEL_IMPL")
    os.environ["REPRO_KERNEL_IMPL"] = "ref"
    try:
        peak = peak_buffer_bytes(jax.jit(step).lower(*args).compile())
        peak_einsum = peak_buffer_bytes(
            jax.jit(einsum_step).lower(*args).compile())
    finally:
        if prev is None:
            os.environ.pop("REPRO_KERNEL_IMPL", None)
        else:
            os.environ["REPRO_KERNEL_IMPL"] = prev
    bnlt = B * N * L * T * 4
    return {
        "shape": {"B": B, "N": N, "L": L, "M": M, "T": T},
        "bnlt_bytes": bnlt,
        "peak_temp_bytes": peak,
        "peak_temp_bytes_einsum": peak_einsum,
        "no_bnlt_intermediate": peak < bnlt,
    }


def run(Q: int = 64, n_docs: int = 64, n_tokens: int = 32, k: int = 10,
        alpha_ef: float = 0.3, block_docs: int = 16, block_tokens: int = 4,
        grow: int = 48, seed: int = 0,
        out: str = "BENCH_reveal.json") -> Dict:
    H = jnp.asarray(make_mixed_difficulty_h(Q, n_docs, n_tokens, k=k,
                                            seed=seed))
    keys = jax.random.split(jax.random.key(seed), Q)

    print(f"mixed-difficulty batch: Q={Q}, N={n_docs}, T={n_tokens}, "
          f"block={block_docs}x{block_tokens}, alpha_ef={alpha_ef}")
    engines = _run_engines(H, keys, k=k, alpha_ef=alpha_ef,
                           block_docs=block_docs,
                           block_tokens=block_tokens, grow=grow)
    hdr = (f"{'engine':12s} {'cells/s':>12s} {'rounds':>7s} {'lockstep':>9s} "
           f"{'waste':>6s} {'trips':>6s} {'occ':>5s}")
    print(hdr)
    for name, r in engines.items():
        print(f"{name:12s} {r['cells_per_s']:12.0f} {r['total_rounds']:7d} "
              f"{r['lockstep_rounds']:9d} {r['lockstep_waste']:6d} "
              f"{r.get('trips', r['rounds_max']):6d} "
              f"{r.get('frontier_occupancy', float('nan')):5.2f}")

    parity = _topk_parity(H, keys, k=k, block_docs=block_docs,
                          block_tokens=block_tokens)
    dense = _dense_peak_buffer()
    pooled = engines["pooled"]
    accept = {
        # Q * max(per-query rounds) is what lockstep pays; the pooled
        # engine's attributable rounds must come in strictly below it.
        "total_rounds_below_lockstep":
            pooled["total_rounds"] < pooled["lockstep_rounds"],
        "full_budget_topk_parity": parity,
        "dense_no_bnlt_intermediate": dense["no_bnlt_intermediate"],
    }
    print(f"parity(full budget): {parity}   dense peak "
          f"{dense['peak_temp_bytes']/2**20:.1f} MiB vs BNLT "
          f"{dense['bnlt_bytes']/2**20:.1f} MiB (einsum path was "
          f"{dense['peak_temp_bytes_einsum']/2**20:.1f} MiB)")

    result = {
        "config": {"Q": Q, "N": n_docs, "T": n_tokens, "k": k,
                   "alpha_ef": alpha_ef, "block_docs": block_docs,
                   "block_tokens": block_tokens, "grow": grow,
                   "seed": seed},
        "engines": engines,
        "dense_peak_buffer": dense,
        "accept": accept,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    assert all(accept.values()), accept
    return result


if __name__ == "__main__":
    run()
