"""Paper Fig. 5/8 — effect of ANN-derived bounds (Eq. 15) vs generic
similarity-range bounds, for Col-Bandit AND Doc-TopMargin (whose widths are
uniform — hence uninformative — without ANN bounds). Also reports the
beyond-paper `prereveal_ann` variant (stage-1 exact cells revealed free)."""
from __future__ import annotations

from benchmarks.common import (bench_dataset, frontier_bandit,
                               frontier_budget)


def run(n_docs: int = 256, n_queries: int = 8, k: int = 5) -> dict:
    ds = bench_dataset(n_docs, n_queries)
    curves = {
        "bandit+ann": frontier_bandit(ds, k=k, use_ann_bounds=True),
        "bandit-generic": frontier_bandit(ds, k=k, use_ann_bounds=False),
        "bandit+ann+prereveal": frontier_bandit(ds, k=k, use_ann_bounds=True,
                                                prereveal_ann=True),
        "topmargin+ann": frontier_budget(ds, k=k, method="topmargin",
                                         use_ann_bounds=True),
        "topmargin-generic": frontier_budget(ds, k=k, method="topmargin",
                                             use_ann_bounds=False),
    }
    print("\n=== Fig 5: ANN-derived bounds ablation ===")
    for name, pts in curves.items():
        frontier = ", ".join(
            f"({100*p['coverage']:.0f}%,{p['overlap']:.2f})" for p in pts)
        print(f"  {name:22s}: {frontier}")
    return curves


if __name__ == "__main__":
    run()
