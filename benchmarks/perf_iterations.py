import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimbing — hypothesis -> change -> measure -> validate, applied
to the THREE selected cells (see EXPERIMENTS.md §Perf for the napkin math):

  H1  qwen2.5-3b x train_4k      (collective-bound: ZeRO-3 re-gathers all
      weights EVERY microbatch)   -> ZeRO-1 params (replicated over FSDP,
      opt state stays sharded); also sweep microbatch count.
  H2  internlm2-20b x long_500k  (collective-bound: GSPMD all-gathers the
      seq-sharded KV cache per layer) -> pin decode logits to the cache
      sharding = distributed split-K softmax (flash-decoding).
  H3  colbert-text x rerank_bulk (the paper's own cell) -> budgeted step:
      score only G' of T query tokens per candidate (the bandit/top-margin
      reveal set) — coverage savings become compiled-FLOP savings.

  PYTHONPATH=src python -m benchmarks.perf_iterations --out results/perf.json
"""

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.launch import hlo_analysis as H
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import _dp_total, build_cell
from benchmarks.roofline import _fit, _measure


def _terms(est):
    return {"compute_s": est["flops"] / PEAK_FLOPS,
            "memory_s": est["bytes"] / HBM_BW,
            "collective_s": est["coll"] / ICI_BW}


def _fmt(name, est):
    t = _terms(est)
    dom = max(t, key=t.get)
    print(f"  {name:34s} T_c={1e3*t['compute_s']:9.2f}ms "
          f"T_m={1e3*t['memory_s']:9.2f}ms "
          f"T_coll={1e3*t['collective_s']:9.2f}ms  dominant={dom}")
    return {**est, **t, "dominant": dom}


def h1_train_zero1(mesh):
    """qwen train: per-micro ZeRO-3 weight gathers dominate T_coll."""
    arch, shape = "qwen2.5-3b", "train_4k"
    cfg = get_config(arch)
    m_full = max(1, 256 // _dp_total(mesh))
    b_red = 256 // m_full
    out = {"cell": f"{arch} x {shape}", "iterations": []}
    print(f"\n== H1: {arch} x {shape} (x{m_full} microbatches) ==")

    def fitted(param_mode):
        lo, _ = _measure(arch, shape, mesh, depth=2, batch=b_red, micro=1,
                         param_mode=param_mode)
        hi, _ = _measure(arch, shape, mesh, depth=4, batch=b_red, micro=1,
                         param_mode=param_mode)
        per_micro = _fit(lo, hi, 2, 4, cfg.n_layers)
        return {k: m_full * v for k, v in per_micro.items()}

    base = fitted("zero3")
    out["iterations"].append({"name": "baseline zero3",
                              **_fmt("baseline (ZeRO-3)", base)})
    opt = fitted("zero1")
    out["iterations"].append({"name": "zero1 params",
                              **_fmt("ZeRO-1 params (opt sharded)", opt)})
    # iteration 3: refuted hypothesis -> new one: T_coll is dominated by
    # per-layer TP activation all-reduces, so drop TP entirely: batch and
    # ZeRO-3 params shard over all 256 chips, 1 row/chip, no microbatching.
    lo, _ = _measure(arch, shape, mesh, depth=2, batch=256, micro=1,
                     param_mode="dp_all")
    hi, _ = _measure(arch, shape, mesh, depth=4, batch=256, micro=1,
                     param_mode="dp_all")
    opt2 = _fit(lo, hi, 2, 4, cfg.n_layers)
    out["iterations"].append({"name": "dp_all (no TP, 1 row/chip)",
                              **_fmt("dp_all: no TP, no micro", opt2)})

    # iteration 4: dp_all's T_coll is the fp32 grad all-reduce -> replace it
    # with the int8 reduce-scatter/all-gather collective (error feedback).
    # Params/opt replicated here (ZeRO-0): fits 3B-scale models; compose
    # with zero1 opt sharding for larger ones.
    import dataclasses as _dc
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import scan_util
    from repro.models.transformer import init_lm
    from repro.train.compressed_step import (CompressedTrainState,
                                             make_compressed_lm_train_step)
    from repro.train.optimizer import AdamWState, adamw, cosine_schedule
    SDS = jax.ShapeDtypeStruct
    every = tuple(mesh.axis_names)

    def compressed_cost(depth):
        # everything is manual inside shard_map: GSPMD activation
        # constraints from earlier build_cell calls must be off
        from repro.dist import act_sharding
        act_sharding.clear()
        cfg_d = _dc.replace(cfg, n_layers=depth,
                            attn_q_chunk=2048)
        opt_o = adamw(cosine_schedule(3e-4, 100, 10_000))
        params_abs = jax.eval_shape(
            lambda: init_lm(jax.random.key(0), cfg_d, dtype=jnp.bfloat16))
        f32 = lambda t: jax.tree.map(lambda p: SDS(p.shape, jnp.float32), t)
        state_abs = CompressedTrainState(
            params=params_abs,
            opt=AdamWState(step=SDS((), jnp.int32), m=f32(params_abs),
                           v=f32(params_abs)),
            error=f32(params_abs))
        rep = jax.tree.map(lambda _: NamedSharding(mesh, P()), state_abs)
        batch_abs = {"tokens": SDS((256, 4096), jnp.int32),
                     "targets": SDS((256, 4096), jnp.int32)}
        b_sh = {k: NamedSharding(mesh, P(every, None)) for k in batch_abs}
        step = make_compressed_lm_train_step(cfg_d, opt_o, mesh)
        scan_util.set_unroll(True)
        try:
            with mesh:
                compiled = jax.jit(step, in_shardings=(rep, b_sh),
                                   donate_argnums=(0,)
                                   ).lower(state_abs, batch_abs).compile()
        finally:
            scan_util.set_unroll(False)
        cost = H.flops_and_bytes(compiled)
        coll = H.collective_bytes(compiled.as_text())
        return {"flops": cost["hlo_flops"], "bytes": cost["hlo_bytes"],
                "coll": float(coll.get("total", 0))}

    opt3 = _fit(compressed_cost(2), compressed_cost(4), 2, 4, cfg.n_layers)
    out["iterations"].append({"name": "DP + int8 RS/AG grads",
                              **_fmt("pure DP + int8-compressed grads", opt3)})
    dom = "coll"
    out["speedup_dominant"] = (base[dom] / opt3[dom]) if opt3[dom] else float("inf")
    print(f"  -> collective-term improvement (final): {out['speedup_dominant']:.2f}x")
    return out


def h2_flash_decode(mesh):
    arch, shape = "internlm2-20b", "long_500k"
    cfg = get_config(arch)
    out = {"cell": f"{arch} x {shape}", "iterations": []}
    print(f"\n== H2: {arch} x {shape} ==")

    def fitted(flash):
        # build_cell handles flash via kwargs threaded through _measure? No:
        # measure manually with build_cell(flash_decode=...)
        from repro.models import scan_util
        ests = []
        for d in (2, 4):
            scan_util.set_unroll(True)
            try:
                cell = build_cell(arch, shape, mesh, depth=d,
                                  flash_decode=flash)
                with mesh:
                    compiled = jax.jit(
                        cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings,
                        donate_argnums=cell.donate_argnums,
                    ).lower(*cell.args).compile()
                cost = H.flops_and_bytes(compiled)
                coll = H.collective_bytes(compiled.as_text())
                ests.append({"flops": cost["hlo_flops"],
                             "bytes": cost["hlo_bytes"],
                             "coll": float(coll.get("total", 0))})
            finally:
                scan_util.set_unroll(False)
        return _fit(ests[0], ests[1], 2, 4, cfg.n_layers)

    base = fitted(False)
    out["iterations"].append({"name": "baseline",
                              **_fmt("baseline (GSPMD KV gather)", base)})
    opt = fitted(True)
    out["iterations"].append({"name": "flash-decode split-K",
                              **_fmt("split-K distributed softmax", opt)})
    out["speedup_dominant"] = (base["coll"] / opt["coll"]) if opt["coll"] else float("inf")
    print(f"  -> collective-term improvement: {out['speedup_dominant']:.2f}x")
    return out


def h3_budgeted_rerank(mesh):
    from repro.retrieval.service import (make_rerank_budgeted_step,
                                         make_rerank_dense_step)
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = get_config("colbert-text")
    B, N = 512, 512   # one lax.map chunk: loop-free HLO accounting
    L, M, T = cfg.doc_tokens, cfg.dim, cfg.query_tokens
    n_dev = int(np.prod(list(mesh.shape.values())))
    C = -(-cfg.corpus_docs // n_dev) * n_dev
    n_loc = max(1, -(-N * 4 // n_dev))
    every = tuple(mesh.axis_names)
    SDS = jax.ShapeDtypeStruct
    out = {"cell": "colbert-text x rerank_bulk", "iterations": []}
    print("\n== H3: colbert-text x rerank_bulk ==")

    def measure(step, args, in_specs):
        shard = tuple(NamedSharding(mesh, s) for s in in_specs)
        with mesh:
            compiled = jax.jit(step, in_shardings=shard).lower(*args).compile()
        cost = H.flops_and_bytes(compiled)
        coll = H.collective_bytes(compiled.as_text())
        return {"flops": cost["hlo_flops"], "bytes": cost["hlo_bytes"],
                "coll": float(coll.get("total", 0))}

    base_args = (SDS((C, L, M), jax.numpy.bfloat16), SDS((C, L), bool),
                 SDS((B, T, M), jax.numpy.bfloat16),
                 SDS((B, n_dev, n_loc), jax.numpy.int32))
    base_specs = (P(every, None, None), P(every, None), P(None, None, None),
                  P(None, every, None))
    base = measure(make_rerank_dense_step(mesh), base_args, base_specs)
    out["iterations"].append({"name": "baseline exact (T=32)",
                              **_fmt("baseline exact rerank", base)})
    for gp in (10, 6):
        args = base_args + (SDS((B, n_dev, n_loc, gp), jax.numpy.int32),)
        specs = base_specs + (P(None, every, None, None),)
        opt = measure(make_rerank_budgeted_step(mesh, tokens_per_doc=gp),
                      args, specs)
        out["iterations"].append({
            "name": f"budgeted G'={gp} ({100*gp/T:.0f}% coverage)",
            **_fmt(f"budgeted G'={gp}/{T}", opt)})
    # iteration 3: token pruning cut FLOPs but NOT the dominant memory
    # term (candidate L x M reads). Two-phase: pooled screening (M bytes
    # per doc), exact MaxSim only for top-2 of 8 local survivors.
    from repro.retrieval.service import make_rerank_two_phase_step
    args2 = (base_args[0], base_args[1],
             SDS((C, M), jax.numpy.bfloat16)) + base_args[2:]
    specs2 = (base_specs[0], base_specs[1],
              P(every, None)) + base_specs[2:]
    two = _fmt("two-phase pooled (2/8 survive)",
               measure(make_rerank_two_phase_step(mesh, survivors=2), args2,
                       specs2))
    out["iterations"].append({"name": "two-phase pooled screening (2/8)",
                              **two})
    b = out["iterations"][0]
    out["speedup_dominant"] = (b["memory_s"] / two["memory_s"]
                               if two["memory_s"] else float("inf"))
    print(f"  -> memory-term improvement (final): {out['speedup_dominant']:.2f}x")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--only", default=None, choices=["h1", "h2", "h3"])
    args = ap.parse_args(argv)
    mesh = make_production_mesh(multi_pod=False)
    runs = {"h1": h1_train_zero1, "h2": h2_flash_decode,
            "h3": h3_budgeted_rerank}
    wanted = [args.only] if args.only else list(runs)
    results = {}
    for name in wanted:
        results[name] = runs[name](mesh)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
