"""Sharded-corpus serving throughput: 1 / 4 / 16 shards on the CPU mesh.

The serving question this answers: when the (C, L, M) token index is
sharded over a real mesh and every shard runs the pooled frontier engine
over its OWN resident candidates (cross-shard traffic = K-sized scorecards
only), what does the corpus-resident pooled-bandit step sustain, and how is
frontier work distributed over the shards?

Each shard count runs in its own subprocess with that many XLA host
placeholder devices (the parent process must stay single-device, same
discipline as tests/_subproc.py), building the mesh via
``repro.launch.mesh.make_host_mesh``, a RAGGED ShardedCorpus (C chosen so
the tail shard is short — the valid_docs clamp is on the measured path),
and the ``make_sharded_serving_step`` bandit flavor.

Reported per shard count: queries/s, reveal fraction, per-shard bandit
round counts and frontier occupancy, plus a hard-bound (alpha_ef -> inf)
parity check against exact dense top-K — the acceptance gate.

Each worker additionally measures the full stage-1-inclusive pipeline both
ways (ISSUE 6): the GATHERED path (host full-corpus stage-1 kNN + numpy
``route_batch`` + the pre-routed shard_map step) against the ROUTED path
(``make_routed_serving_step``: centroid routing + shard-local stage-1 +
rerank in ONE shard_map dispatch), under a uniform query mix and a
Zipf-skewed one (queries drawn from Zipf(1.5)-popular documents, piling
routed mass onto the low shards). The second acceptance gate asserts the
4-shard routed pipeline sustains at least the gathered pipeline's cells/s
on the skewed mix — the host routing round-trip it deletes is genuinely
sequential, so this holds even though CPU shards timeshare one machine.

Caveat: on the CPU host platform the per-shard programs timeshare one
machine, so walltime does NOT improve with shard count here; the numbers
pin scheduling facts (rounds, occupancy, scorecard-only traffic) and give
the shape of the throughput curve a real mesh would see.

Registered in ``benchmarks/run.py`` as ``sharded``; standalone:

  PYTHONPATH=src python -m benchmarks.sharded_serving

Emits ``BENCH_sharded.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _worker(n_shards: int, n_docs: int, B: int, N: int, T: int, L: int,
            M: int, k: int, alpha_ef: float, n_batches: int,
            seed: int) -> Dict:
    """Runs inside the subprocess that owns ``n_shards`` host devices."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.mesh import make_host_mesh
    from repro.retrieval.ann import generate_candidates
    from repro.retrieval.service import (make_rerank_dense_step,
                                         make_routed_serving_step,
                                         make_sharded_serving_step)
    from repro.retrieval.sharded import (route_aligned, route_batch,
                                         route_candidates, shard_corpus)

    assert len(jax.devices()) == n_shards, (len(jax.devices()), n_shards)
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n_docs, L, M)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=-1, keepdims=True)
    msk = np.arange(L)[None] < rng.integers(L // 2, L + 1, n_docs)[:, None]
    mesh = make_host_mesh(n_shards)
    sc = shard_corpus(emb, msk, mesh, n_centroids=8, router_seed=seed)

    def batch(i):
        r = np.random.default_rng(1000 + i)
        q = r.standard_normal((B, T, M)).astype(np.float32)
        q /= np.linalg.norm(q, axis=-1, keepdims=True)
        cand = np.stack([r.choice(n_docs, N, replace=False)
                         for _ in range(B)]).astype(np.int32)
        cand_l = route_candidates(cand, sc.docs_per_shard, sc.n_shards)
        # valid per-cell support: normalized docs x normalized query tokens
        a = np.full((B, N, T), -1.0, np.float32)
        b = np.ones((B, N, T), np.float32)
        a_l = route_aligned(a, cand, cand_l, sc.docs_per_shard)
        b_l = route_aligned(b, cand, cand_l, sc.docs_per_shard)
        return (q, cand, jnp.asarray(cand_l), jnp.asarray(a_l),
                jnp.asarray(b_l))

    step = jax.jit(make_sharded_serving_step(
        mesh, "bandit", topk=k, alpha_ef=alpha_ef, block_docs=8,
        block_tokens=4))
    vd = sc.valid_docs_device()

    batches = [batch(i) for i in range(n_batches)]
    q0, _, cl0, al0, bl0 = batches[0]
    jax.block_until_ready(step(sc.embs, sc.mask, jnp.asarray(q0), cl0, al0,
                               bl0, vd, jnp.int32(0)))        # compile+warm
    t0 = time.perf_counter()
    frac_sum, stats_last = 0.0, None
    for i, (q, _, cl, al, bl) in enumerate(batches):
        _, _, frac, stats = jax.block_until_ready(
            step(sc.embs, sc.mask, jnp.asarray(q), cl, al, bl, vd,
                 jnp.int32(i)))
        frac_sum += float(np.mean(np.asarray(frac)))
        stats_last = np.asarray(stats)
    wall = time.perf_counter() - t0

    # hard-bound parity vs exact dense, on the last batch
    hb = jax.jit(make_sharded_serving_step(
        mesh, "bandit", topk=k, alpha_ef=1e9, block_docs=8, block_tokens=4))
    q, cand, cl, al, bl = batches[-1]
    _, ids, _, _ = hb(sc.embs, sc.mask, jnp.asarray(q), cl, al, bl, vd,
                      jnp.int32(0))
    dense1 = make_rerank_dense_step(jax.make_mesh((1,), ("data",)), topk=k)
    _, want = dense1(jnp.asarray(emb), jnp.asarray(msk), jnp.asarray(q),
                     jnp.asarray(cand[:, None, :]))
    parity = all(set(np.asarray(ids)[b]) == set(np.asarray(want)[b])
                 for b in range(B))

    # --- routed vs gathered stage-1-inclusive pipelines (ISSUE 6) --------
    # Both serve the SAME budget of N candidates x T tokens per query, so
    # cells/s reduces to the walltime ratio; the gathered clock includes
    # the host stage-1 dispatch and the numpy routing round-trip the
    # routed step deletes.
    kprime = 8
    cells_per_batch = B * N * T
    routed_step = jax.jit(make_routed_serving_step(
        mesh, "bandit", topk=k, n_local=N, n_total=N, kprime=kprime,
        alpha_ef=alpha_ef, block_docs=8, block_tokens=4))
    cents, mass = sc.router.centroids, sc.router.shard_mass
    gen = jax.jit(jax.vmap(lambda qq: generate_candidates(
        jnp.asarray(emb), jnp.asarray(msk), qq, kprime=kprime,
        max_candidates=N)))

    def queries_uniform(i):
        r = np.random.default_rng(2000 + i)
        q = r.standard_normal((B, T, M)).astype(np.float32)
        return q / np.linalg.norm(q, axis=-1, keepdims=True)

    def queries_zipf(i):
        # Popularity-skewed traffic: query tokens sampled (with noise) from
        # Zipf(1.5)-favored documents, which live on the low shards under
        # the contiguous-block placement.
        r = np.random.default_rng(3000 + i)
        docs = np.minimum(r.zipf(1.5, size=B) - 1, n_docs - 1)
        tok = emb[docs[:, None], r.integers(0, L, (B, T))]     # (B, T, M)
        q = (tok + 0.2 * r.standard_normal((B, T, M))).astype(np.float32)
        return q / np.linalg.norm(q, axis=-1, keepdims=True)

    def time_routed(make_q):
        qs = [make_q(i) for i in range(n_batches)]
        jax.block_until_ready(routed_step(
            sc.embs, sc.mask, cents, mass, jnp.asarray(qs[0]), vd,
            jnp.int32(0)))
        t0 = time.perf_counter()
        stats_r = None
        for i, qq in enumerate(qs):
            _, _, _, stats = jax.block_until_ready(routed_step(
                sc.embs, sc.mask, cents, mass, jnp.asarray(qq), vd,
                jnp.int32(i)))
            stats_r = np.asarray(stats)
        wall_r = time.perf_counter() - t0
        qshare = stats_r[:, 3]
        return {
            "queries_per_s": B * n_batches / max(wall_r, 1e-9),
            "cells_per_s": cells_per_batch * n_batches / max(wall_r, 1e-9),
            "quota_share_mean": [float(x) for x in qshare],
            "routed_skew": float(np.max(qshare) * len(qshare)),
        }

    def time_gathered(make_q):
        qs = [make_q(i) for i in range(n_batches)]

        def one(qq, i):
            cand = jax.block_until_ready(gen(jnp.asarray(qq)))
            cand_l, (a_r, b_r) = route_batch(
                np.asarray(cand.doc_ids),
                [np.asarray(cand.a), np.asarray(cand.b)],
                sc.docs_per_shard, sc.n_shards, n_local=N)
            return jax.block_until_ready(step(
                sc.embs, sc.mask, jnp.asarray(qq), jnp.asarray(cand_l),
                jnp.asarray(a_r), jnp.asarray(b_r), vd, jnp.int32(i)))

        one(qs[0], 0)                                  # compile + warm
        t0 = time.perf_counter()
        for i, qq in enumerate(qs):
            one(qq, i)
        wall_g = time.perf_counter() - t0
        return {
            "queries_per_s": B * n_batches / max(wall_g, 1e-9),
            "cells_per_s": cells_per_batch * n_batches / max(wall_g, 1e-9),
        }

    routed, gathered = {}, {}
    for mix, make_q in (("uniform", queries_uniform),
                        ("zipf", queries_zipf)):
        gathered[mix] = time_gathered(make_q)
        routed[mix] = time_routed(make_q)
        routed[mix]["speedup_vs_gathered"] = (
            routed[mix]["cells_per_s"]
            / max(gathered[mix]["cells_per_s"], 1e-9))

    return {
        "n_shards": n_shards,
        "mesh": {a: int(n) for a, n in mesh.shape.items()},
        "docs_per_shard": sc.docs_per_shard,
        "valid_docs": [int(v) for v in sc.valid_docs],
        "queries_per_s": B * n_batches / max(wall, 1e-9),
        "wall_s": wall,
        "mean_reveal_fraction": frac_sum / n_batches,
        "shard_rounds": [float(x) for x in stats_last[:, 1]],
        "shard_occupancy": [float(x) for x in stats_last[:, 0]],
        "hard_bound_topk_parity": bool(parity),
        "gathered": gathered,
        "routed": routed,
    }


def run(shard_counts=(1, 4, 16), n_docs: int = 93, B: int = 8, N: int = 16,
        T: int = 8, L: int = 16, M: int = 16, k: int = 5,
        alpha_ef: float = 0.3, n_batches: int = 4, seed: int = 0,
        out: str = "BENCH_sharded.json") -> Dict:
    """Spawn one subprocess per shard count (each pins its own XLA host
    device count BEFORE importing jax) and collect the rows."""
    rows = {}
    for s in shard_counts:
        cmd = [sys.executable, "-m", "benchmarks.sharded_serving",
               "--worker", str(s), "--n-docs", str(n_docs), "--batch",
               str(B), "--cands", str(N), "--tokens", str(T),
               "--doc-len", str(L), "--dim", str(M), "--topk", str(k),
               "--alpha-ef", str(alpha_ef), "--batches", str(n_batches),
               "--seed", str(seed)]
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={s}",
                   JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
                   PYTHONPATH=os.pathsep.join(
                       [os.path.join(_ROOT, "src"), _ROOT,
                        os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=900, cwd=_ROOT, env=env)
        if proc.returncode != 0:
            raise RuntimeError(f"{s}-shard worker failed:\n"
                               f"{proc.stderr[-3000:]}")
        row = json.loads(proc.stdout.strip().splitlines()[-1])
        rows[str(s)] = row
        print(f"{s:3d} shards: {row['queries_per_s']:8.1f} q/s  "
              f"reveal {row['mean_reveal_fraction']:.3f}  "
              f"rounds/shard {row['shard_rounds']}  "
              f"parity {row['hard_bound_topk_parity']}")
        for mix in ("uniform", "zipf"):
            g, r = row["gathered"][mix], row["routed"][mix]
            print(f"            {mix:7s}: gathered {g['cells_per_s']:10.0f} "
                  f"cells/s | routed {r['cells_per_s']:10.0f} cells/s "
                  f"({r['speedup_vs_gathered']:.2f}x, "
                  f"skew {r['routed_skew']:.2f})")

    accept = {"hard_bound_topk_parity_all":
              all(r["hard_bound_topk_parity"] for r in rows.values()),
              "every_shard_count_served":
              len(rows) == len(tuple(shard_counts))}
    if "4" in rows:
        # ISSUE 6 gate: deleting the host stage-1 + routing round-trip must
        # pay for itself on the 4-shard mesh under skewed traffic.
        accept["routed_beats_gathered_zipf_4shard"] = (
            rows["4"]["routed"]["zipf"]["cells_per_s"]
            >= rows["4"]["gathered"]["zipf"]["cells_per_s"])
    result = {
        "config": {"n_docs": n_docs, "B": B, "N": N, "T": T, "L": L, "M": M,
                   "k": k, "alpha_ef": alpha_ef, "n_batches": n_batches,
                   "shard_counts": list(shard_counts), "seed": seed},
        "shards": rows,
        "accept": accept,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    assert all(accept.values()), accept
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=0,
                    help="internal: run the measurement for N shards "
                         "in-process (device count set by the parent)")
    ap.add_argument("--n-docs", type=int, default=93)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--cands", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--doc-len", type=int, default=16)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--alpha-ef", type=float, default=0.3)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.worker:
        row = _worker(args.worker, args.n_docs, args.batch, args.cands,
                      args.tokens, args.doc_len, args.dim, args.topk,
                      args.alpha_ef, args.batches, args.seed)
        print(json.dumps(row))
        return 0
    run(shard_counts=(1, 4) if args.quick else (1, 4, 16),
        n_docs=args.n_docs, B=args.batch, N=args.cands, T=args.tokens,
        L=args.doc_len, M=args.dim, k=args.topk, alpha_ef=args.alpha_ef,
        n_batches=args.batches, seed=args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
