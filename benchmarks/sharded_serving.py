"""Sharded-corpus serving throughput: 1 / 4 / 16 shards on the CPU mesh.

The serving question this answers: when the (C, L, M) token index is
sharded over a real mesh and every shard runs the pooled frontier engine
over its OWN resident candidates (cross-shard traffic = K-sized scorecards
only), what does the corpus-resident pooled-bandit step sustain, and how is
frontier work distributed over the shards?

Each shard count runs in its own subprocess with that many XLA host
placeholder devices (the parent process must stay single-device, same
discipline as tests/_subproc.py), building the mesh via
``repro.launch.mesh.make_host_mesh``, a RAGGED ShardedCorpus (C chosen so
the tail shard is short — the valid_docs clamp is on the measured path),
and the ``make_sharded_serving_step`` bandit flavor.

Reported per shard count: queries/s, reveal fraction, per-shard bandit
round counts and frontier occupancy, plus a hard-bound (alpha_ef -> inf)
parity check against exact dense top-K — the acceptance gate.

Caveat: on the CPU host platform the per-shard programs timeshare one
machine, so walltime does NOT improve with shard count here; the numbers
pin scheduling facts (rounds, occupancy, scorecard-only traffic) and give
the shape of the throughput curve a real mesh would see.

Registered in ``benchmarks/run.py`` as ``sharded``; standalone:

  PYTHONPATH=src python -m benchmarks.sharded_serving

Emits ``BENCH_sharded.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _worker(n_shards: int, n_docs: int, B: int, N: int, T: int, L: int,
            M: int, k: int, alpha_ef: float, n_batches: int,
            seed: int) -> Dict:
    """Runs inside the subprocess that owns ``n_shards`` host devices."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.mesh import make_host_mesh
    from repro.retrieval.service import (make_rerank_dense_step,
                                         make_sharded_serving_step)
    from repro.retrieval.sharded import (route_aligned, route_candidates,
                                         shard_corpus)

    assert len(jax.devices()) == n_shards, (len(jax.devices()), n_shards)
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n_docs, L, M)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=-1, keepdims=True)
    msk = np.arange(L)[None] < rng.integers(L // 2, L + 1, n_docs)[:, None]
    mesh = make_host_mesh(n_shards)
    sc = shard_corpus(emb, msk, mesh)

    def batch(i):
        r = np.random.default_rng(1000 + i)
        q = r.standard_normal((B, T, M)).astype(np.float32)
        q /= np.linalg.norm(q, axis=-1, keepdims=True)
        cand = np.stack([r.choice(n_docs, N, replace=False)
                         for _ in range(B)]).astype(np.int32)
        cand_l = route_candidates(cand, sc.docs_per_shard, sc.n_shards)
        # valid per-cell support: normalized docs x normalized query tokens
        a = np.full((B, N, T), -1.0, np.float32)
        b = np.ones((B, N, T), np.float32)
        a_l = route_aligned(a, cand, cand_l, sc.docs_per_shard)
        b_l = route_aligned(b, cand, cand_l, sc.docs_per_shard)
        return (q, cand, jnp.asarray(cand_l), jnp.asarray(a_l),
                jnp.asarray(b_l))

    step = jax.jit(make_sharded_serving_step(
        mesh, "bandit", topk=k, alpha_ef=alpha_ef, block_docs=8,
        block_tokens=4))
    vd = sc.valid_docs_device()

    batches = [batch(i) for i in range(n_batches)]
    q0, _, cl0, al0, bl0 = batches[0]
    jax.block_until_ready(step(sc.embs, sc.mask, jnp.asarray(q0), cl0, al0,
                               bl0, vd, jnp.int32(0)))        # compile+warm
    t0 = time.perf_counter()
    frac_sum, stats_last = 0.0, None
    for i, (q, _, cl, al, bl) in enumerate(batches):
        _, _, frac, stats = jax.block_until_ready(
            step(sc.embs, sc.mask, jnp.asarray(q), cl, al, bl, vd,
                 jnp.int32(i)))
        frac_sum += float(np.mean(np.asarray(frac)))
        stats_last = np.asarray(stats)
    wall = time.perf_counter() - t0

    # hard-bound parity vs exact dense, on the last batch
    hb = jax.jit(make_sharded_serving_step(
        mesh, "bandit", topk=k, alpha_ef=1e9, block_docs=8, block_tokens=4))
    q, cand, cl, al, bl = batches[-1]
    _, ids, _, _ = hb(sc.embs, sc.mask, jnp.asarray(q), cl, al, bl, vd,
                      jnp.int32(0))
    dense1 = make_rerank_dense_step(jax.make_mesh((1,), ("data",)), topk=k)
    _, want = dense1(jnp.asarray(emb), jnp.asarray(msk), jnp.asarray(q),
                     jnp.asarray(cand[:, None, :]))
    parity = all(set(np.asarray(ids)[b]) == set(np.asarray(want)[b])
                 for b in range(B))

    return {
        "n_shards": n_shards,
        "mesh": {a: int(n) for a, n in mesh.shape.items()},
        "docs_per_shard": sc.docs_per_shard,
        "valid_docs": [int(v) for v in sc.valid_docs],
        "queries_per_s": B * n_batches / max(wall, 1e-9),
        "wall_s": wall,
        "mean_reveal_fraction": frac_sum / n_batches,
        "shard_rounds": [float(x) for x in stats_last[:, 1]],
        "shard_occupancy": [float(x) for x in stats_last[:, 0]],
        "hard_bound_topk_parity": bool(parity),
    }


def run(shard_counts=(1, 4, 16), n_docs: int = 93, B: int = 8, N: int = 16,
        T: int = 8, L: int = 16, M: int = 16, k: int = 5,
        alpha_ef: float = 0.3, n_batches: int = 4, seed: int = 0,
        out: str = "BENCH_sharded.json") -> Dict:
    """Spawn one subprocess per shard count (each pins its own XLA host
    device count BEFORE importing jax) and collect the rows."""
    rows = {}
    for s in shard_counts:
        cmd = [sys.executable, "-m", "benchmarks.sharded_serving",
               "--worker", str(s), "--n-docs", str(n_docs), "--batch",
               str(B), "--cands", str(N), "--tokens", str(T),
               "--doc-len", str(L), "--dim", str(M), "--topk", str(k),
               "--alpha-ef", str(alpha_ef), "--batches", str(n_batches),
               "--seed", str(seed)]
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={s}",
                   JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
                   PYTHONPATH=os.pathsep.join(
                       [os.path.join(_ROOT, "src"), _ROOT,
                        os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=900, cwd=_ROOT, env=env)
        if proc.returncode != 0:
            raise RuntimeError(f"{s}-shard worker failed:\n"
                               f"{proc.stderr[-3000:]}")
        row = json.loads(proc.stdout.strip().splitlines()[-1])
        rows[str(s)] = row
        print(f"{s:3d} shards: {row['queries_per_s']:8.1f} q/s  "
              f"reveal {row['mean_reveal_fraction']:.3f}  "
              f"rounds/shard {row['shard_rounds']}  "
              f"parity {row['hard_bound_topk_parity']}")

    accept = {"hard_bound_topk_parity_all":
              all(r["hard_bound_topk_parity"] for r in rows.values()),
              "every_shard_count_served":
              len(rows) == len(tuple(shard_counts))}
    result = {
        "config": {"n_docs": n_docs, "B": B, "N": N, "T": T, "L": L, "M": M,
                   "k": k, "alpha_ef": alpha_ef, "n_batches": n_batches,
                   "shard_counts": list(shard_counts), "seed": seed},
        "shards": rows,
        "accept": accept,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    assert all(accept.values()), accept
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=0,
                    help="internal: run the measurement for N shards "
                         "in-process (device count set by the parent)")
    ap.add_argument("--n-docs", type=int, default=93)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--cands", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--doc-len", type=int, default=16)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--alpha-ef", type=float, default=0.3)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.worker:
        row = _worker(args.worker, args.n_docs, args.batch, args.cands,
                      args.tokens, args.doc_len, args.dim, args.topk,
                      args.alpha_ef, args.batches, args.seed)
        print(json.dumps(row))
        return 0
    run(shard_counts=(1, 4) if args.quick else (1, 4, 16),
        n_docs=args.n_docs, B=args.batch, N=args.cands, T=args.tokens,
        L=args.doc_len, M=args.dim, k=args.topk, alpha_ef=args.alpha_ef,
        n_batches=args.batches, seed=args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
