import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Roofline analysis (EXPERIMENTS.md §Roofline).

XLA's HloCostAnalysis counts while-loop bodies ONCE, so the full-depth
dry-run lowering (rolled scans) undercounts FLOPs/bytes/collectives by
~n_layers x n_microbatches. This pass therefore:

  1. lowers each LM cell TWICE at reduced depth with every scan UNROLLED
     (repro/models/scan_util.py),
  2. linear-fits cost(L) = base + slope*L per metric and extrapolates to
     full depth (train cells are lowered at one-microbatch batch size and
     scaled by the microbatch count, plus an analytic optimizer term),
  3. GNN cells are lowered fully unrolled (4 layers — cheap), recsys /
     retrieval cells have no loops and are measured directly.

Terms (per chip, TPU v5e): compute = FLOPs / 197e12; memory = bytes / 819e9;
collective = collective-bytes / 50e9. The dominant term is the bottleneck;
MODEL_FLOPS / HLO_FLOPS is the useful-compute fraction.

  PYTHONPATH=src python -m benchmarks.roofline --out results/roofline.json
"""

import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch import hlo_analysis as H
from repro.launch.dryrun import HBM_BW, ICI_BW, PAPER_ARCHS, PEAK_FLOPS
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, _dp_total
from repro.models import scan_util


def _measure(arch, shape_name, mesh, *, depth=0, batch=0, micro=0,
             unroll=True, param_mode="zero3"):
    """Lower one (possibly reduced) cell and pull cost numbers."""
    scan_util.set_unroll(unroll)
    try:
        cell = build_cell(arch, shape_name, mesh, depth=depth, batch=batch,
                          micro=micro, param_mode=param_mode)
        with mesh:
            compiled = jax.jit(
                cell.fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            ).lower(*cell.args).compile()
        cost = H.flops_and_bytes(compiled)
        coll = H.collective_bytes(compiled.as_text())
        return {"flops": cost["hlo_flops"], "bytes": cost["hlo_bytes"],
                "coll": float(coll.get("total", 0))}, cell
    finally:
        scan_util.set_unroll(False)


def _fit(c_lo, c_hi, d_lo, d_hi, d_full):
    out = {}
    for k in ("flops", "bytes", "coll"):
        slope = (c_hi[k] - c_lo[k]) / max(d_hi - d_lo, 1)
        out[k] = max(c_lo[k] + slope * (d_full - d_lo), 0.0)
    return out


def roofline_cell(arch, shape_name, mesh):
    cfg = get_config(arch)
    shape = next(s for s in cfg.shapes if s.name == shape_name)

    if cfg.family == "lm":
        d_lo, d_hi = 2, 4
        L = cfg.n_layers
        if shape.kind == "train":
            dp = _dp_total(mesh)
            m_full = max(1, shape.global_batch // dp)
            b_red = shape.global_batch // m_full
            c_lo, cell = _measure(arch, shape_name, mesh, depth=d_lo,
                                  batch=b_red, micro=1)
            c_hi, _ = _measure(arch, shape_name, mesh, depth=d_hi,
                               batch=b_red, micro=1)
            per_micro = _fit(c_lo, c_hi, d_lo, d_hi, L)
            n_chips = int(np.prod(list(mesh.shape.values())))
            n_params = cfg.param_count()
            # analytic optimizer term (elementwise; once per step, all params)
            opt_flops = 12.0 * n_params / n_chips
            opt_bytes = 28.0 * n_params / n_chips
            est = {k: m_full * per_micro[k] for k in per_micro}
            est["flops"] += opt_flops
            est["bytes"] += opt_bytes
            note = f"fit L∈({d_lo},{d_hi})→{L}, x{m_full} micro + opt"
        else:
            c_lo, cell = _measure(arch, shape_name, mesh, depth=d_lo)
            c_hi, _ = _measure(arch, shape_name, mesh, depth=d_hi)
            est = _fit(c_lo, c_hi, d_lo, d_hi, L)
            note = f"fit L∈({d_lo},{d_hi})→{L}"
        cell_full = build_cell(arch, shape_name, mesh)   # for model_flops
        model_flops = cell_full.model_flops
    elif cfg.family == "gnn":
        est, cell = _measure(arch, shape_name, mesh, unroll=True)
        model_flops = cell.model_flops
        note = "fully unrolled (4 layers)"
    elif cfg.family == "retrieval":
        # the serving steps chunk queries with lax.map (counted once by
        # HloCostAnalysis): measure ONE chunk (B<=512, loop-free) and scale
        # linearly — compute/bytes/scorecard-collectives are all ~B.
        B = shape.batch
        b_meas = min(B, 512)
        est, cell = _measure(arch, shape_name, mesh, unroll=True,
                             batch=b_meas)
        scale = B / b_meas
        est = {k: v * scale for k, v in est.items()}
        cell_full = build_cell(arch, shape_name, mesh)
        model_flops = cell_full.model_flops
        note = f"measured at B={b_meas}, scaled x{scale:.0f}"
    else:
        est, cell = _measure(arch, shape_name, mesh, unroll=True)
        model_flops = cell.model_flops
        note = "loop-free; measured directly"

    n_chips = int(np.prod(list(mesh.shape.values())))
    compute_s = est["flops"] / PEAK_FLOPS
    memory_s = est["bytes"] / HBM_BW
    collective_s = est["coll"] / ICI_BW
    bound = max((("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)), key=lambda kv: kv[1])[0]
    step_s = max(compute_s, memory_s, collective_s)
    mf_chip = model_flops / n_chips
    return {
        "arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
        "hlo_flops": est["flops"], "hlo_bytes": est["bytes"],
        "collective_bytes": est["coll"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "bottleneck": bound,
        "model_flops_per_chip": mf_chip,
        "useful_flops_frac": mf_chip / est["flops"] if est["flops"] else 0.0,
        "mfu_bound": (mf_chip / PEAK_FLOPS) / step_s if step_s else 0.0,
        "note": note,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=False)   # roofline is single-pod
    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS) + PAPER_ARCHS

    records = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else [s.name for s in cfg.shapes]
        for shape_name in shapes:
            try:
                rec = roofline_cell(arch, shape_name, mesh)
                records.append(rec)
                print(f"[{arch:22s} {shape_name:15s}] "
                      f"T_c={rec['compute_s']*1e3:9.2f}ms "
                      f"T_m={rec['memory_s']*1e3:9.2f}ms "
                      f"T_coll={rec['collective_s']*1e3:9.2f}ms "
                      f"-> {rec['bottleneck']:10s} "
                      f"useful={rec['useful_flops_frac']*100:5.1f}% "
                      f"mfu_bound={rec['mfu_bound']*100:5.1f}%")
            except Exception as e:
                import traceback
                traceback.print_exc(limit=3)
                print(f"[FAIL {arch} {shape_name}] {e}")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
