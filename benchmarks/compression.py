"""Compressed resident corpus: bytes/doc, in-kernel dequant throughput,
and top-K fidelity per ``CorpusFormat``.

The compression question this answers: when the (C, L, M) token index is
re-encoded as int8 rows (per-(doc,token) symmetric scale) or as centroid
ids + int8 residuals against the router codebook, (1) how many resident
bytes does a document cost, (2) what does the fused reveal path sustain
when dequantization happens INSIDE the kernel, and (3) how much top-K
fidelity survives against the exhaustive f32 oracle?

Three format rows share one synthetic corpus and one workload:

* ``bf16``     — dense corpus cast to bf16: the uncompressed resident
  baseline the throughput gate is measured against.
* ``int8``     — ``kernels.quant.quantize_int8``: ~3.9x fewer resident
  bytes than the f32-resident seed path (~1.9x vs true bf16 residency).
* ``residual`` — centroid id + int8 residual, codebook = the spherical
  k-means router centroids (``retrieval.corpus.build_router``).

Acceptance gates (the ISSUE 10 contract):

* int8 bytes/doc at least 3.5x below the f32-resident baseline;
* int8 fused-reveal cells/s at least 0.9x the bf16 fused path;
* int8 AND residual top-5 overlap vs the exhaustive f32 oracle >= 0.9.

Registered in ``benchmarks/run.py`` as ``compress``; standalone:

  PYTHONPATH=src python -m benchmarks.compression
  PYTHONPATH=src python -m benchmarks.compression \
      --smoke --baseline BENCH_compress.json --max-ratio 2.0   # CI gate

Emits ``BENCH_compress.json``. The CI perf-smoke lane re-runs the small
``smoke`` section and fails on wall-clock regression past ``--max-ratio``
(machine-normalized by the median wall ratio over formats), on any
bytes/doc drift (encoding sizes are deterministic — a drift is a format
change, not noise), or on a broken acceptance gate.

Caveat: on CPU the kernels execute in interpret mode, so cells/s measures
the interpreted dequant+score loop, not MXU/VMEM behavior; the bandwidth
win of moving 1-byte rows through HBM only shows on a real TPU. The
throughput gate still binds — in-kernel dequant must not cost more than
the tolerated compute overhead even without the bandwidth payoff.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.ops import fused_reveal_op, maxsim_scores_op
from repro.kernels.quant import corpus_asarray, corpus_nbytes, quantize
from repro.retrieval.corpus import build_router

FORMATS = ("bf16", "int8", "residual")


def _make_corpus(C: int, L: int, M: int, seed: int):
    """Unit-normalized token corpus with ragged masks (every doc keeps at
    least half its tokens, so no all-masked sentinel rows confound the
    fidelity measurement)."""
    rng = np.random.default_rng(seed)
    embs = rng.standard_normal((C, L, M)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=-1, keepdims=True)
    mask = np.arange(L)[None] < rng.integers(L // 2, L + 1, C)[:, None]
    return embs, mask


def _resident(embs: np.ndarray, fmt: str, codebook):
    """The corpus as it would sit in device memory under ``fmt``. The
    bf16 row is CAST (not just relabeled): it is the uncompressed resident
    baseline the throughput gate compares against."""
    if fmt == "bf16":
        return jnp.asarray(embs, jnp.bfloat16)
    return corpus_asarray(quantize(
        embs, fmt, codebook=codebook if fmt == "residual" else None))


def _bytes_row(embs: np.ndarray, fmt: str, codebook) -> Dict:
    C = embs.shape[0]
    resident = _resident(embs, fmt, codebook)
    nbytes = corpus_nbytes(resident)
    f32_bytes = embs.size * 4
    bf16_bytes = embs.size * 2
    return {
        "resident_bytes": int(nbytes),
        "bytes_per_doc": nbytes / C,
        "reduction_vs_f32": f32_bytes / nbytes,
        "reduction_vs_bf16": bf16_bytes / nbytes,
    }


def _time_fused(resident, mask, B: int, G: int, TQ: int, seed: int,
                iters: int, repeats: int) -> Dict:
    """Best-of-``repeats`` fused-reveal wall over ``iters`` launches of a
    fixed (B, G) selection against the resident corpus."""
    D, L, M = resident.shape
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((TQ, M)).astype(np.float32)
    q /= np.linalg.norm(q, axis=-1, keepdims=True)
    di = jnp.asarray(rng.integers(0, D, B, dtype=np.int32))
    ti = jnp.asarray(rng.integers(0, TQ, (B, G), dtype=np.int32))
    nm = jnp.ones((B, G), jnp.bool_)
    m, qd = jnp.asarray(mask), jnp.asarray(q)

    def launch():
        return jax.block_until_ready(
            fused_reveal_op(resident, m, qd, di, ti, nm))

    vals, stats = launch()                       # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            launch()
        best = min(best, time.perf_counter() - t0)
    cells = B * G * iters
    return {
        "wall_s": best,
        "cells_per_s": cells / max(best, 1e-9),
        # trajectory facts for the drift gate: the revealed-cell statistics
        # are a pure function of (inputs, format) — any change is a kernel
        # semantics change, not noise.
        "stat_count": float(np.asarray(stats)[:, 0].sum()),
    }


def _fidelity(embs, mask, resident, Q: int, T: int, k: int,
              seed: int) -> Dict:
    """Mean top-``k`` overlap of the format corpus's exhaustive MaxSim
    ranking against the f32 numpy oracle, over ``Q`` queries."""
    rng = np.random.default_rng(seed)
    overlaps = []
    m = jnp.asarray(mask)
    for _ in range(Q):
        q = rng.standard_normal((T, embs.shape[2])).astype(np.float32)
        q /= np.linalg.norm(q, axis=-1, keepdims=True)
        sims = np.einsum("nlm,tm->nlt", embs, q, dtype=np.float32)
        sims = np.where(mask[:, :, None], sims, -np.inf)
        oracle = np.argsort(-sims.max(axis=1).sum(axis=-1))[:k]
        got = np.asarray(maxsim_scores_op(resident, m, jnp.asarray(q)))
        topk = np.argsort(-got)[:k]
        overlaps.append(len(set(oracle) & set(topk)) / k)
    return {"topk_overlap": float(np.mean(overlaps)), "k": k, "queries": Q}


def _section(C: int, L: int, M: int, *, B: int, G: int, TQ: int, Q: int,
             T: int, k: int, seed: int, iters: int, repeats: int) -> Dict:
    embs, mask = _make_corpus(C, L, M, seed)
    codebook = np.asarray(build_router(
        embs, mask, n_shards=1, docs_per_shard=C, n_centroids=8,
        seed=seed).centroids, np.float32)
    rows = {}
    print(f"corpus C={C} L={L} M={M} | reveal B={B} G={G} x{iters}")
    print(f"{'format':9s} {'bytes/doc':>10s} {'vs f32':>7s} {'vs bf16':>8s} "
          f"{'cells/s':>12s} {'top-5 ovl':>10s}")
    for fmt in FORMATS:
        resident = _resident(embs, fmt, codebook)
        row = _bytes_row(embs, fmt, codebook)
        row.update(_time_fused(resident, mask, B, G, TQ, seed, iters,
                               repeats))
        row.update(_fidelity(embs, mask, resident, Q, T, k, seed + 1))
        rows[fmt] = row
        print(f"{fmt:9s} {row['bytes_per_doc']:10.1f} "
              f"{row['reduction_vs_f32']:6.2f}x {row['reduction_vs_bf16']:7.2f}x "
              f"{row['cells_per_s']:12.0f} {row['topk_overlap']:10.3f}")
    return {
        "config": {"C": C, "L": L, "M": M, "B": B, "G": G, "TQ": TQ,
                   "Q": Q, "T": T, "k": k, "seed": seed, "iters": iters,
                   "repeats": repeats},
        "formats": rows,
    }


def _gates(rows: Dict) -> Dict:
    """The ISSUE 10 acceptance gates over one section's format rows."""
    return {
        "int8_bytes_reduction_3p5x_vs_f32":
            rows["int8"]["reduction_vs_f32"] >= 3.5,
        "int8_fused_at_least_0p9x_bf16":
            rows["int8"]["cells_per_s"]
            >= 0.9 * rows["bf16"]["cells_per_s"],
        "int8_top5_overlap_0p9":
            rows["int8"]["topk_overlap"] >= 0.9,
        "residual_top5_overlap_0p9":
            rows["residual"]["topk_overlap"] >= 0.9,
    }


# Small config the CI perf-smoke lane re-runs against the committed
# baseline. Sized so each format's fused wall stays in the tens of
# milliseconds on the interpret path (single-digit-ms walls put dispatch
# jitter inside the gate) while the fidelity loop stays cheap.
SMOKE = dict(C=128, L=12, M=64, B=128, G=8, TQ=64, Q=8, T=8, k=5, seed=0,
             iters=4, repeats=3)
FULL = dict(C=256, L=12, M=64, B=256, G=8, TQ=128, Q=16, T=8, k=5, seed=0,
            iters=4, repeats=5)


def _run_smoke() -> Dict:
    return _section(SMOKE["C"], SMOKE["L"], SMOKE["M"], B=SMOKE["B"],
                    G=SMOKE["G"], TQ=SMOKE["TQ"], Q=SMOKE["Q"], T=SMOKE["T"],
                    k=SMOKE["k"], seed=SMOKE["seed"], iters=SMOKE["iters"],
                    repeats=SMOKE["repeats"])


def run(quick: bool = False, out: str = "BENCH_compress.json") -> Dict:
    cfg = dict(SMOKE if quick else FULL)
    main = _section(cfg["C"], cfg["L"], cfg["M"], B=cfg["B"], G=cfg["G"],
                    TQ=cfg["TQ"], Q=cfg["Q"], T=cfg["T"], k=cfg["k"],
                    seed=cfg["seed"], iters=cfg["iters"],
                    repeats=cfg["repeats"])
    print("\nsmoke config (CI gate):")
    smoke = main if quick else _run_smoke()
    accept = _gates(main["formats"])
    result = {
        "config": main["config"],
        "formats": main["formats"],
        "smoke": smoke,
        "accept": accept,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    assert all(accept.values()), accept
    return result


def check_smoke_regression(baseline_path: str,
                           max_ratio: float = 2.0) -> int:
    """CI gate: re-run the smoke section and fail (non-zero) when

    * any format's bytes/doc differs from the committed baseline (the
      encoders are deterministic — a byte drift is a format change);
    * any ISSUE 10 acceptance gate no longer holds on the fresh run;
    * any format's fused wall regresses more than ``max_ratio``x,
      machine-normalized by the MEDIAN (wall_now / wall_baseline) over
      formats, so a uniformly slower box normalizes away while one
      genuinely regressed format cannot drag the median.
    """
    with open(baseline_path) as f:
        baseline = json.load(f)
    base = baseline.get("smoke", {}).get("formats")
    if not base:
        print(f"{baseline_path} has no smoke section — regenerate with "
              "`python -m benchmarks.compression`")
        return 2
    smoke = _run_smoke()
    rows = smoke["formats"]
    shared = [f for f in rows if f in base]
    machine = float(np.median([
        rows[f]["wall_s"] / max(base[f]["wall_s"], 1e-9) for f in shared]))
    print(f"\nmachine speed factor vs baseline (median over "
          f"{len(shared)} formats): {machine:.2f}x")
    failures = []
    for fmt in shared:
        row, b = rows[fmt], base[fmt]
        ratio = row["wall_s"] / max(b["wall_s"] * machine, 1e-9)
        status = "OK"
        if ratio > max_ratio:
            status = f"REGRESSION ({ratio:.2f}x > {max_ratio}x normalized)"
            failures.append(fmt)
        if row["resident_bytes"] != b["resident_bytes"]:
            status = (f"BYTES DRIFT ({row['resident_bytes']} vs "
                      f"{b['resident_bytes']})")
            failures.append(fmt)
        print(f"{fmt:9s} wall {row['wall_s']*1e3:8.1f} ms vs baseline "
              f"{b['wall_s']*1e3:8.1f} ms ({ratio:.2f}x normalized)  "
              f"{status}")
    gates = _gates(rows)
    for name, ok in gates.items():
        print(f"gate {name}: {'OK' if ok else 'FAILED'}")
        if not ok:
            failures.append(name)
    if failures:
        print(f"\ncompression smoke FAILED: {sorted(set(failures))}")
        return 1
    print("\ncompression smoke OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run only the small-config regression gate")
    ap.add_argument("--baseline", default="BENCH_compress.json",
                    help="baseline JSON for --smoke comparison")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="max allowed wall-clock ratio vs baseline")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_compress.json")
    args = ap.parse_args(argv)
    if args.smoke:
        return check_smoke_regression(args.baseline, args.max_ratio)
    run(quick=args.quick, out=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
