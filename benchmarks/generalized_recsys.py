"""Beyond-paper: generalized Col-Bandit on the recsys retrieval_cand shape.

The paper's machinery needs only a sum-decomposable score with bounded
components; FM candidate scoring decomposes over context fields
(core/generalized.py). We run finite-population Top-K identification over
1 query x N candidates and report coverage/overlap vs exact scoring —
the direct analogue of Table 1 for the recsys family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.core.baselines import exact_topk
from repro.core.generalized import (component_support,
                                    fm_pair_components,
                                    topk_bandit_generalized)
from repro.core.metrics import overlap_at_k
from repro.models import recsys as R


def run(n_candidates: int = 4096, n_fields: int = 16, dim: int = 10,
        k: int = 10, seeds=(0, 1, 2, 3)) -> dict:
    out = {"points": []}
    print("\n=== Generalized bandit: FM retrieval_cand "
          f"({n_candidates} candidates, {n_fields} context fields) ===")
    for alpha in (0.1, 0.3, 1.0):
        covs, ovs = [], []
        for seed in seeds:
            rng = np.random.default_rng(seed)
            ctx = jnp.asarray(rng.standard_normal((n_fields, dim)) * 0.3,
                              jnp.float32)
            cands = jnp.asarray(rng.standard_normal((n_candidates, dim)) * 0.3,
                                jnp.float32)
            comps = fm_pair_components(ctx, cands)     # (N, F)
            exact, _ = exact_topk(comps, k=k)
            res = topk_bandit_generalized(
                comps, jax.random.key(seed), k=k, alpha_ef=alpha,
                block_docs=64, block_tokens=2)
            covs.append(float(res.coverage))
            ovs.append(float(overlap_at_k(res.topk, exact)))
        pt = {"alpha_ef": alpha, "coverage": float(np.mean(covs)),
              "overlap": float(np.mean(ovs))}
        out["points"].append(pt)
        print(f"  alpha={alpha:4.1f}: coverage={100*pt['coverage']:5.1f}% "
              f"overlap@{k}={pt['overlap']:.3f} "
              f"(compute saving {1/max(pt['coverage'],1e-9):.1f}x)")
    return out


if __name__ == "__main__":
    run()
