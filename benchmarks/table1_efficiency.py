"""Paper Table 1 — Universal Efficiency Analysis.

Mean coverage required to achieve 90% / 95% Overlap@1 and Overlap@5 for
Doc-Uniform (Alg. 2), Doc-TopMargin (Alg. 3), Col-Bandit (Alg. 1, sequential
= paper-faithful) and the TPU block-synchronous variant; plus savings vs
full reranking (100% / mean coverage).
"""
from __future__ import annotations

from benchmarks.common import (bench_dataset, coverage_for_target, fmt_cov,
                               frontier_bandit, frontier_budget, savings)


def run(n_docs: int = 384, n_queries: int = 12) -> dict:
    ds = bench_dataset(n_docs, n_queries)
    results = {}
    for k in (1, 5):
        rows = {}
        rows["Doc-Uniform"] = frontier_budget(ds, k=k, method="uniform")
        rows["Doc-TopMargin"] = frontier_budget(ds, k=k, method="topmargin")
        rows["Col-Bandit (faithful)"] = frontier_bandit(
            ds, k=k, method="bandit", bias_kappa=0.0)   # paper's exact Eq.12
        rows["Col-Bandit (seq)"] = frontier_bandit(ds, k=k, method="bandit")
        rows["Col-Bandit (TPU)"] = frontier_bandit(ds, k=k, method="batched")
        results[k] = rows

    print("\n=== Table 1: coverage needed for target Overlap@K "
          "(synthetic corpus) ===")
    print(f"{'method':20s} | {'Ov@1>=90%':>9s} {'Ov@1>=95%':>9s} "
          f"{'sav90':>6s} {'sav95':>6s} | {'Ov@5>=90%':>9s} "
          f"{'Ov@5>=95%':>9s} {'sav90':>6s} {'sav95':>6s}")
    for method in ["Doc-Uniform", "Doc-TopMargin", "Col-Bandit (faithful)",
                   "Col-Bandit (seq)", "Col-Bandit (TPU)"]:
        cells = []
        for k in (1, 5):
            c90 = coverage_for_target(results[k][method], 0.90)
            c95 = coverage_for_target(results[k][method], 0.95)
            cells.append((c90, c95))
        (a90, a95), (b90, b95) = cells
        print(f"{method:20s} | {fmt_cov(a90):>9s} {fmt_cov(a95):>9s} "
              f"{savings(a90):>6s} {savings(a95):>6s} | {fmt_cov(b90):>9s} "
              f"{fmt_cov(b95):>9s} {savings(b90):>6s} {savings(b95):>6s}")
    return results


if __name__ == "__main__":
    run()
