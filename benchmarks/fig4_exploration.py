"""Paper Fig. 4 — exploration ablation: dynamic epsilon-greedy vs static
warm-up schedules."""
from __future__ import annotations

from benchmarks.common import bench_dataset, frontier_bandit


def run(n_docs: int = 256, n_queries: int = 8, k: int = 5) -> dict:
    ds = bench_dataset(n_docs, n_queries)
    curves = {
        "eps-greedy(0.1)": frontier_bandit(ds, k=k, epsilon=0.1),
        "eps-greedy(0.3)": frontier_bandit(ds, k=k, epsilon=0.3),
        "warmup(10%)": frontier_bandit(ds, k=k, epsilon=0.0,
                                       warmup_fraction=0.10),
        "warmup(25%)": frontier_bandit(ds, k=k, epsilon=0.0,
                                       warmup_fraction=0.25),
    }
    print("\n=== Fig 4: exploration strategy ablation ===")
    for name, pts in curves.items():
        frontier = ", ".join(
            f"({100*p['coverage']:.0f}%,{p['overlap']:.2f})" for p in pts)
        print(f"  {name:16s}: {frontier}")
    return curves


if __name__ == "__main__":
    run()
