"""Paper Fig. 2 — cost-accuracy frontier: Overlap@5 vs coverage for every
method (Col-Bandit operating points = alpha_ef sweep)."""
from __future__ import annotations

from benchmarks.common import (bench_dataset, frontier_bandit,
                               frontier_budget)


def run(n_docs: int = 384, n_queries: int = 12, k: int = 5) -> dict:
    ds = bench_dataset(n_docs, n_queries)
    curves = {
        "col-bandit": frontier_bandit(ds, k=k),
        "col-bandit-tpu": frontier_bandit(ds, k=k, method="batched"),
        "doc-uniform": frontier_budget(ds, k=k, method="uniform"),
        "doc-topmargin": frontier_budget(ds, k=k, method="topmargin"),
    }
    print(f"\n=== Fig 2: cost-accuracy trade-off (Overlap@{k} vs coverage) ===")
    for name, pts in curves.items():
        print(f"  {name}:")
        for p in pts:
            knob = p.get("alpha_ef", p.get("budget"))
            print(f"    knob={knob:6.2f} coverage={100*p['coverage']:5.1f}% "
                  f"overlap={p['overlap']:.3f} "
                  f"flops_saving={p['flops_saving']:.2f}x")
    return curves


if __name__ == "__main__":
    run()
