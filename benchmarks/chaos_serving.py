"""Fault-injected serving harness: supervision overhead + recovery + ladder.

Three measurements for the ISSUE 8 resilience layer:

  * ``overhead``  — the supervised async pipeline vs the bare one on an
    identical no-fault stream: the watchdog + engine-owned in-flight
    bookkeeping must be noise, not a tax (ratio recorded, not gated —
    single-core CI hosts timeshare the threads).
  * ``chaos``     — a 4-shard mesh run (subprocess, own device count)
    with a replayable FaultPlan: dispatch-thread kill plus a temporary
    shard outage mid-stream. Gates the RECOVERY facts, which are exact
    on any host: zero lost / zero duplicated completions, no error
    completions, restarts and failovers actually happened, coverage
    stayed in [0, 1], zero post-warmup recompiles (health mask and
    fidelity knobs are traced operands).
  * ``ladder``    — the deadline-aware degradation ladder on a bandit
    engine: squeezed deadlines must engage rungs > 0 (recorded per-rung
    batch counts) without a single recompile, and the degraded stream's
    mean reveal work must not exceed the comfortable stream's.

Registered in ``benchmarks/run.py`` as ``chaos``; standalone:

  PYTHONPATH=src python -m benchmarks.chaos_serving [--quick]

Emits ``BENCH_chaos.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np


def _dataset(C, L, M, T, n_queries, seed):
    rng = np.random.default_rng(seed)
    embs = rng.standard_normal((C, L, M)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=-1, keepdims=True)
    mask = np.arange(L)[None] < rng.integers(max(3, L // 2), L + 1,
                                             C)[:, None]
    qs = rng.standard_normal((n_queries, T, M)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=-1, keepdims=True)
    return embs, mask, qs, rng


def supervision_overhead(n_requests: int = 256) -> Dict:
    """Same no-fault stream through supervise=False and supervise=True."""
    from repro.serve import AsyncRetrievalEngine, EngineConfig, Request

    embs, mask, qs, rng = _dataset(64, 8, 16, 8, 16, seed=0)
    cands = [rng.choice(64, 16, replace=False).astype(np.int32)
             for _ in range(n_requests)]
    out = {}
    # the first pass is a throwaway: it absorbs process-wide lazy init
    # (dispatch caches etc.) that would otherwise tax whichever variant
    # happens to run first and poison the ratio.
    for name, supervise in (("_warm", False), ("bare", False),
                            ("supervised", True)):
        eng = AsyncRetrievalEngine(embs, mask, EngineConfig(
            batch_size=8, deadline_s=0.02, token_buckets=(8,),
            cand_buckets=(16,), max_k=5, flavor="dense", pipeline_depth=2,
            supervise=supervise))
        eng.warmup()
        t0 = time.perf_counter()
        with eng:
            for i, c in enumerate(cands):
                eng.submit(Request(query=qs[i % 16], k=5, cand_ids=c))
            done = eng.drain()
        wall = time.perf_counter() - t0
        assert sorted(c.rid for c in done) == list(range(n_requests))
        assert eng.metrics.compiles_after_warmup == 0
        if name != "_warm":
            out[name] = {"wall_s": wall,
                         "qps": n_requests / max(wall, 1e-9)}
    out["overhead_ratio"] = out["bare"]["qps"] / max(
        out["supervised"]["qps"], 1e-9)
    return out


def ladder(n_requests: int = 64) -> Dict:
    """Squeezed vs comfortable deadlines through backpressure="degrade"."""
    from repro.serve import EngineConfig, Request, RetrievalEngine

    embs, mask, qs, rng = _dataset(96, 8, 16, 8, 16, seed=1)
    cands = [rng.choice(96, 32, replace=False).astype(np.int32)
             for _ in range(n_requests)]
    out = {}
    for name, deadline in (("comfortable", 1e6), ("squeezed", 1e-3)):
        eng = RetrievalEngine(embs, mask, EngineConfig(
            batch_size=8, token_buckets=(8,), cand_buckets=(32,), max_k=5,
            flavor="bandit", alpha_ef=0.3, block_docs=8, block_tokens=4,
            backpressure="degrade", deadline_headroom_s=0.05))
        eng.warmup()
        t0 = time.perf_counter()
        for i, c in enumerate(cands):
            eng.submit(Request(query=qs[i % 16], k=5, deadline_s=deadline,
                               cand_ids=c))
        done = eng.drain()
        wall = time.perf_counter() - t0
        levels = [b.degrade_level for b in eng.metrics.batches]
        out[name] = {
            "wall_s": wall,
            "qps": n_requests / max(wall, 1e-9),
            "mean_reveal_fraction": float(np.mean(
                [b.reveal_fraction for b in eng.metrics.batches])),
            "batches_per_rung": {str(l): levels.count(l)
                                 for l in sorted(set(levels))},
            "mean_degrade_level": float(np.mean(levels)),
            "compiles_after_warmup": eng.metrics.compiles_after_warmup,
        }
        assert len(done) == n_requests
    return out


def _chaos_worker(n_requests: int) -> Dict:
    """Mesh chaos run; the parent pinned 4 host devices before jax loaded."""
    from repro.dist.fault import FaultPlan, InjectedFault, poison_corpus
    from repro.serve import AsyncRetrievalEngine, EngineConfig, Request

    embs, mask, qs, rng = _dataset(47, 6, 8, 8, 32, seed=2)
    poisoned, rows = poison_corpus(embs, 0.01, seed=7, mode="nan")
    bad = int(np.flatnonzero(rows)[0])
    n_batches = n_requests // 8
    plan = FaultPlan([
        InjectedFault(point="dispatch", at=max(2, n_batches // 8),
                      action="kill"),
        InjectedFault(point="dispatch", at=max(4, n_batches // 4),
                      action="shard_down", arg=1),
        InjectedFault(point="dispatch", at=max(6, n_batches // 2),
                      action="shard_up", arg=1),
    ])
    eng = AsyncRetrievalEngine(poisoned, mask, EngineConfig(
        batch_size=8, deadline_s=0.02, token_buckets=(8,),
        cand_buckets=(16,), max_k=5, flavor="dense", pipeline_depth=2,
        supervise=True, max_thread_restarts=2,
        mesh_axes=(("data", 2), ("model", 2))), fault_plan=plan)
    eng.warmup()
    t0 = time.perf_counter()
    with eng:
        for i in range(n_requests):
            cand = rng.choice(47, 16, replace=False).astype(np.int32)
            if i % 10 == 0 and bad not in cand:
                cand[0] = bad
            eng.submit(Request(query=qs[i % 32], k=5, cand_ids=cand))
        done = eng.drain()
    wall = time.perf_counter() - t0
    rids = [c.rid for c in done]
    covs = [c.coverage for c in done]
    s = eng.metrics.summary()
    return {
        "n_requests": n_requests,
        "wall_s": wall,
        "qps": n_requests / max(wall, 1e-9),
        "lost": n_requests - len(set(rids)),
        "dup": len(rids) - len(set(rids)),
        "errors": s["errors"],
        "thread_restarts": s["thread_restarts"],
        "failovers": s["failovers"],
        "quarantined_total": s["quarantined_total"],
        "coverage_min": float(min(covs)),
        "coverage_mean": float(np.mean(covs)),
        "coverage_in_unit_interval": bool(
            all(0.0 <= c <= 1.0 for c in covs)),
        "fired": [f.action for f in plan.fired],
        "compiles_after_warmup": eng.metrics.compiles_after_warmup,
    }


def run(quick: bool = False, out: str = "BENCH_chaos.json") -> Dict:
    n = 128 if quick else 512
    print("## supervision overhead (no faults)")
    overhead = supervision_overhead(n_requests=min(n, 256))
    print(f"bare {overhead['bare']['qps']:.1f} q/s | supervised "
          f"{overhead['supervised']['qps']:.1f} q/s "
          f"(ratio {overhead['overhead_ratio']:.2f})")

    print("## chaos recovery (4-shard mesh, kill + shard outage)")
    cmd = [sys.executable, "-m", "benchmarks.chaos_serving",
           "--worker", str(n)]
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(_ROOT, "src"), _ROOT,
                    os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                          cwd=_ROOT, env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"chaos worker failed:\n{proc.stderr[-3000:]}")
    chaos = json.loads(proc.stdout.strip().splitlines()[-1])
    print(f"{chaos['qps']:.1f} q/s under chaos | restarts "
          f"{chaos['thread_restarts']} | failovers {chaos['failovers']} | "
          f"coverage min {chaos['coverage_min']:.2f} | "
          f"quarantined {chaos['quarantined_total']:.0f}")

    print("## degradation ladder (deadline squeeze)")
    lad = ladder(n_requests=32 if quick else 64)
    print(f"comfortable reveal {lad['comfortable']['mean_reveal_fraction']:.3f}"
          f" | squeezed reveal {lad['squeezed']['mean_reveal_fraction']:.3f} "
          f"rungs {lad['squeezed']['batches_per_rung']}")

    accept = {
        "chaos_zero_lost": chaos["lost"] == 0,
        "chaos_zero_dup": chaos["dup"] == 0,
        "chaos_zero_errors": chaos["errors"] == 0,
        "chaos_restart_happened": sum(
            chaos["thread_restarts"].values()) >= 1,
        "chaos_failover_happened": chaos["failovers"] >= 1,
        "chaos_coverage_in_unit_interval":
            chaos["coverage_in_unit_interval"],
        "chaos_quarantine_engaged": chaos["quarantined_total"] > 0,
        "chaos_zero_recompiles": chaos["compiles_after_warmup"] == 0,
        "ladder_engaged": any(int(r) > 0 for r in
                              lad["squeezed"]["batches_per_rung"]),
        "ladder_zero_recompiles":
            lad["squeezed"]["compiles_after_warmup"] == 0,
        "ladder_no_extra_reveal_work": (
            lad["squeezed"]["mean_reveal_fraction"]
            <= lad["comfortable"]["mean_reveal_fraction"] + 1e-6),
    }
    result = {"overhead": overhead, "chaos": chaos, "ladder": lad,
              "accept": accept}
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    assert all(accept.values()), accept
    return result


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=0,
                    help="internal: run the mesh chaos measurement "
                         "in-process (device count set by the parent)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.worker:
        print(json.dumps(_chaos_worker(args.worker)))
        return 0
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
