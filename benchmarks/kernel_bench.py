"""Kernel-op microbenchmark: tuned vs default block sizes per shape bucket.

Times every kernel op's candidate block configurations (the same grid the
serving engine's warmup autotune walks) at a handful of representative
shape buckets, and reports the tuned-vs-default speedup. This is the
evidence behind ``EngineConfig.autotune``: if the default tiles were
already optimal everywhere, the tuner would be dead weight.

Each op's first candidate IS its default configuration
(``repro.kernels.tuning.DEFAULTS``), so the speedup column is
default-time / best-time measured in the same session.

Registered in ``benchmarks/run.py`` as ``kernels``; standalone:

  PYTHONPATH=src python -m benchmarks.kernel_bench

Emits ``BENCH_kernels.json`` (per-bucket timings + the resulting tuned
table). Caveat: on CPU the kernels execute in interpret mode, so absolute
times measure the interpreted tiling loop, not MXU/VMEM behavior — the
harness exists to exercise the tuner end-to-end and to pin that tuned
configs are never slower than defaults (they minimize over a set that
contains the default).
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List

from repro.kernels import tuning
from repro.kernels.ops import autotune_op

# (op, dims) buckets: serving-analog shapes kept small enough for the
# interpret-mode CI lane (grid size drives trace time on CPU). Quantized
# buckets carry the FMT ordinal (ops._fmt_dims: int8=2, residual=4) —
# autotune_op encodes the synthetic corpus into that format itself, so the
# dequant kernels learn their own block sizes without touching the dense
# buckets' keys.
BUCKETS: List = [
    ("maxsim", dict(N=32, T=48, L=256, M=128)),
    ("maxsim_batch", dict(B=4, N=16, T=16, L=128, M=128)),
    ("gather_maxsim", dict(B=64, G=4, L=128, M=128, D=256, TQ=256)),
    ("fused_reveal", dict(B=64, G=4, L=128, M=128, D=256, TQ=256)),
    ("fused_reveal", dict(B=64, G=4, L=128, M=128, D=256, TQ=256, FMT=2)),
    ("fused_reveal", dict(B=64, G=4, L=128, M=128, D=256, TQ=256, FMT=4)),
    ("maxsim_batch", dict(B=4, N=16, T=16, L=128, M=128, FMT=2)),
]


def run(quick: bool = False, out: str = "BENCH_kernels.json") -> Dict:
    buckets = BUCKETS[2:] if quick else BUCKETS
    rows = []
    t_all = time.perf_counter()
    print(f"{'op':14s} {'default_ms':>11s} {'best_ms':>9s} {'speedup':>8s} "
          f"best_config")
    for op, dims in buckets:
        best, timings = autotune_op(op, dims)
        if not timings:            # REPRO_KERNEL_IMPL=ref: nothing to tune
            continue
        default_key = json.dumps(
            {k: min(v, dims.get({"block_n": "N", "block_t": "T",
                                 "block_l": "L", "block_b": "B"}[k], v))
             for k, v in tuning.DEFAULTS[op].items()}, sort_keys=True)
        t_default = timings.get(default_key, max(timings.values()))
        t_best = min(timings.values())
        speedup = t_default / max(t_best, 1e-12)
        print(f"{op:14s} {t_default*1e3:11.2f} {t_best*1e3:9.2f} "
              f"{speedup:7.2f}x {best}")
        rows.append({"op": op, "dims": dims, "best": best,
                     "default_s": t_default, "best_s": t_best,
                     "speedup": speedup, "timings_s": timings})
    result = {
        "buckets": rows,
        "table": tuning.table_json(),
        "wall_s": time.perf_counter() - t_all,
        # Tuned can never lose to default: the default is in the candidate
        # set, so min() over candidates is <= the default's own time.
        "accept": {"tuned_never_slower": all(r["speedup"] >= 1.0 - 1e-9
                                             for r in rows)},
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    assert all(result["accept"].values()), result["accept"]
    return result


if __name__ == "__main__":
    sys.exit(0 if run() else 1)
