"""Serving-latency harness: p50/p99 latency + throughput of RetrievalEngine.

Flood load (every request's INTENDED arrival is t0; the whole stream is
admitted as fast as the generator can go, so batches run full) swept over

  * batch size     (dense flavor)   — batching amortization curve, and
  * alpha_ef       (bandit flavor)  — adaptive-rerank cost knob: smaller
    alpha_ef widens decision intervals -> more reveals -> higher latency,
    the serving-side view of the paper's Fig. 2 tradeoff.

Latencies are measured from the intended arrival timestamp, not the submit
stamp (``benchmarks.serving_load.drive_open_loop``): the generator's own
submission slippage — which grows exactly when the server is slow — is
charged back to the request instead of silently forgiven, the same
coordinated-omission fix the open-loop ``serving_load`` harness applies at
finite offered rates. Every engine is warmed first, so measured latencies
are steady-state (compiles_after_warmup is asserted 0 and reported).
Registered in ``benchmarks/run.py`` as ``serving``; also runnable
standalone:

  PYTHONPATH=src python -m benchmarks.serving_latency
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from benchmarks.serving_load import drive_open_loop
from repro.data.synthetic import make_retrieval_dataset
from repro.serve import EngineConfig, Request, RetrievalEngine


def _serve_flood(ds, *, n_requests: int, batch_size: int, flavor: str,
                 alpha_ef: float, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    cfg = EngineConfig(batch_size=batch_size, deadline_s=0.05,
                       token_buckets=(16,), cand_buckets=(32,), max_k=10,
                       flavor=flavor, alpha_ef=alpha_ef,
                       stage1_candidates=32, seed=seed)
    engine = RetrievalEngine(ds.doc_embs, ds.doc_mask, cfg)
    t0 = time.monotonic()
    engine.warmup()
    warmup_s = time.monotonic() - t0

    # Flood: every intended arrival is t0 (no deadlines), so batches run
    # full and the sweep isolates batch-size and alpha_ef effects from
    # admission-timeout effects.
    reqs = [Request(query=ds.queries[i % ds.n_queries]
                    [:int(rng.integers(4, 17))], k=10)
            for i in range(n_requests)]
    row = drive_open_loop(engine, reqs, np.zeros(n_requests))

    s = engine.metrics.summary()
    assert s["compiles_after_warmup"] == 0, s
    assert row["n_lost"] == 0 and row["n_duplicated"] == 0, row
    return {
        "flavor": flavor, "batch_size": batch_size, "alpha_ef": alpha_ef,
        "n_requests": row["n_completed"], "warmup_s": round(warmup_s, 2),
        "latency_p50_ms": row["latency_p50_ms"],
        "latency_p99_ms": row["latency_p99_ms"],
        "throughput_qps": row["throughput_qps"],
        "mean_occupancy": s["mean_occupancy"],
        "mean_reveal_fraction": s["mean_reveal_fraction"],
        "compiles_after_warmup": s["compiles_after_warmup"],
    }


def _print_rows(rows: List[Dict]) -> None:
    hdr = (f"{'flavor':8s} {'B':>3s} {'alpha':>6s} {'p50 ms':>8s} "
           f"{'p99 ms':>8s} {'qps':>8s} {'occ':>5s} {'reveal':>7s}")
    print(hdr)
    for r in rows:
        print(f"{r['flavor']:8s} {r['batch_size']:3d} {r['alpha_ef']:6.2f} "
              f"{r['latency_p50_ms']:8.2f} {r['latency_p99_ms']:8.2f} "
              f"{r['throughput_qps']:8.1f} {r['mean_occupancy']:5.2f} "
              f"{r['mean_reveal_fraction']:7.2f}")


def run(n_docs: int = 96, n_requests: int = 48,
        batch_sizes: Sequence[int] = (2, 4, 8),
        alphas: Sequence[float] = (0.15, 0.3, 1.0)) -> Dict:
    """Sweep latency/throughput vs batch size (dense) and alpha_ef (bandit)."""
    ds = make_retrieval_dataset(n_docs=n_docs, n_queries=min(n_requests, 32),
                                doc_len=32, min_doc_len=8, query_len=16,
                                dim=32, seed=11)
    rows: List[Dict] = []
    print(f"corpus: {n_docs} docs; {n_requests} requests per point")
    for bs in batch_sizes:
        rows.append(_serve_flood(ds, n_requests=n_requests,
                                 batch_size=bs, flavor="dense",
                                 alpha_ef=0.3))
    for alpha in alphas:
        rows.append(_serve_flood(ds, n_requests=n_requests,
                                 batch_size=batch_sizes[-1],
                                 flavor="bandit", alpha_ef=alpha))
    _print_rows(rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
