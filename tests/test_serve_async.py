"""ISSUE 7: async continuous-batching serving runtime.

Four contracts:
  * parity oracle — an un-``start()``-ed AsyncRetrievalEngine serves
    exactly like RetrievalEngine, and a STARTED one (full batches, no
    deadlines) returns bit-identical completions to the sync engine;
  * completion integrity — every admitted rid surfaces exactly once,
    under the batch pipeline, the continuous (slot-refill) stream, and
    randomized interleavings of add/poll/flush on the shared batcher;
  * admission backpressure — "reject" raises AdmissionRejected (and
    counts it), "degrade" truncates the candidate list to the smallest
    compiled bucket (and counts it), neither mutates the caller's
    Request;
  * zero recompiles — the threaded runtime serves a warmed bucket set
    without a single post-warmup compile, same as the sync engine.

Threaded tests carry ``pytest.mark.timeout`` so a wedged serving thread
fails the run instead of hanging it (inert when pytest-timeout is not
installed; the marker is registered in pyproject.toml).
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import make_retrieval_dataset
from repro.dist.fault import DeadlineBatcher
from repro.serve import (AdmissionRejected, AsyncRetrievalEngine,
                         EngineConfig, Request, RetrievalEngine)


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def corpus():
    return make_retrieval_dataset(n_docs=32, n_queries=8, doc_len=12,
                                  min_doc_len=6, query_len=8, dim=16,
                                  seed=5)


def _cfg(**kw):
    # deadline_s is the ADMISSION window: 30 s means only full batches
    # release during a test, so sync and async batch composition match.
    base = dict(batch_size=2, deadline_s=30.0, token_buckets=(8,),
                cand_buckets=(8,), max_k=5, flavor="dense",
                stage1_candidates=8, stage1_kprime=4, pipeline_depth=2)
    base.update(kw)
    return EngineConfig(**base)


def _bandit_cfg(**kw):
    base = dict(flavor="bandit", max_rounds=2, block_docs=4, block_tokens=2)
    base.update(kw)
    return _cfg(**base)


def _stream(corpus, rng, n, *, deadline_s=None):
    """A mixed request stream: variable token counts, alternating
    candidate-carrying / stage-1 requests."""
    reqs = []
    for i in range(n):
        n_tok = int(rng.integers(2, 9))
        cand = (rng.choice(32, 8, replace=False).astype(np.int32)
                if i % 2 else None)
        reqs.append(Request(query=corpus.queries[i % 8][:n_tok], k=5,
                            deadline_s=deadline_s, cand_ids=cand))
    return reqs


def _by_rid(comps):
    out = {c.rid: c for c in comps}
    assert len(out) == len(comps)        # no duplicated rid
    return out


def _assert_bitwise_equal(got, want):
    assert set(got) == set(want)
    for rid, c in got.items():
        np.testing.assert_array_equal(c.topk_ids, want[rid].topk_ids)
        np.testing.assert_array_equal(c.topk_scores, want[rid].topk_scores)


# ---------------------------------------------------------------------------
# DeadlineBatcher: full-batch wakeup + randomized-interleaving integrity
# ---------------------------------------------------------------------------

def test_next_expiry_full_batch_expires_now():
    """Regression (ISSUE 7 bugfix): a ready FULL batch must expire at the
    CURRENT clock even when every pending deadline lies far in the future
    — a poll loop sleeping to the old per-entry expiry would hold a
    releasable batch for the whole admission window."""
    clock = ManualClock()
    b = DeadlineBatcher(batch_size=2, deadline_s=10.0, clock=clock)
    b.add("a")
    assert b.next_expiry() == pytest.approx(10.0)   # partial: window
    b.add("b")
    assert b.next_expiry() == pytest.approx(0.0)    # full: NOW
    clock.advance(3.0)
    assert b.next_expiry() == pytest.approx(3.0)    # still "now", not 0
    assert b.poll() == (["a", "b"], 2)


def test_headroom_is_live_not_frozen_at_add():
    """Regression (ISSUE 7 satellite): the admission deadline of a
    deadline_abs entry is derived at POLL time from the live headroom
    callable — a service-time estimate that rises while the request
    queues must pull the release point earlier."""
    clock = ManualClock()
    headroom = [0.0]
    b = DeadlineBatcher(batch_size=4, deadline_s=10.0, clock=clock,
                        headroom=lambda: headroom[0])
    b.add("a", deadline_abs=1.0)
    assert b.next_expiry() == pytest.approx(1.0)
    headroom[0] = 0.4                     # EMA rose while "a" waited
    assert b.next_expiry() == pytest.approx(0.6)
    clock.advance(0.7)
    assert b.poll() is not None           # released early enough to serve


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 5))
def test_batcher_interleaved_ops_lose_and_duplicate_nothing(seed, batch_size):
    """Property: under a randomized interleaving of add / poll / flush /
    clock advances (random per-request deadlines, both relative and
    absolute, and a drifting headroom), every added item comes back
    exactly once, in FIFO order, and padding never leaks as a real
    item."""
    rng = np.random.default_rng(seed)
    clock = ManualClock()
    headroom = [0.0]
    b = DeadlineBatcher(batch_size=batch_size,
                        deadline_s=float(rng.uniform(0.1, 2.0)),
                        clock=clock, headroom=lambda: headroom[0])
    n_total = int(rng.integers(1, 30))
    added, released = [], []
    i = 0
    while i < n_total or len(b):
        op = rng.integers(0, 4)
        if op == 0 and i < n_total:
            kind = rng.integers(0, 3)
            if kind == 1:
                b.add(i, deadline_s=float(rng.uniform(0, 1.0)))
            elif kind == 2:
                b.add(i, deadline_abs=clock() + float(rng.uniform(0, 1.0)))
            else:
                b.add(i)
            added.append(i)
            i += 1
        elif op == 1:
            out = b.poll()
            if out is not None:
                reqs, n_real = out
                assert len(reqs) == batch_size
                assert reqs[n_real:] == [reqs[n_real - 1]] * (
                    batch_size - n_real)
                released.extend(reqs[:n_real])
        elif op == 2 and rng.random() < 0.3:
            out = b.flush()
            if out is not None:
                released.extend(out[0][:out[1]])
        else:
            clock.advance(float(rng.uniform(0, 0.5)))
            headroom[0] = float(rng.uniform(0, 0.3))
    while (out := b.flush()) is not None:
        released.extend(out[0][:out[1]])
    assert released == added              # exactly once, FIFO


# ---------------------------------------------------------------------------
# parity: un-started == sync; started batch pipeline == sync, bit for bit
# ---------------------------------------------------------------------------

def test_unstarted_async_engine_is_sync_parity(corpus):
    """The parity-oracle mode: without start(), the async engine's
    submit/poll/drain serve synchronously and bit-identically to
    RetrievalEngine (batch ordinals, PRNG stream and all)."""
    rng = np.random.default_rng(0)
    reqs = _stream(corpus, rng, 6)
    results = []
    for cls in (RetrievalEngine, AsyncRetrievalEngine):
        eng = cls(corpus.doc_embs, corpus.doc_mask, _bandit_cfg())
        eng.warmup()
        for r in reqs:
            eng.submit(r)
        results.append(_by_rid(eng.drain()))
        assert eng.metrics.compiles_after_warmup == 0
    _assert_bitwise_equal(results[1], results[0])


@pytest.mark.timeout(120)
def test_async_pipeline_matches_sync_bitwise(corpus):
    """Started batch pipeline, full batches only: the async engine's
    completions must be bit-identical to the sync engine's for the same
    stream — the dispatch/harvest overlap may not change a single score
    (the per-batch PRNG ordinal contract survives the thread split)."""
    rng = np.random.default_rng(1)
    reqs = _stream(corpus, rng, 8)       # 4 full batches at B=2
    sync = RetrievalEngine(corpus.doc_embs, corpus.doc_mask, _bandit_cfg())
    sync.warmup()
    for r in reqs:
        sync.submit(r)
    want = _by_rid(sync.drain())

    eng = AsyncRetrievalEngine(corpus.doc_embs, corpus.doc_mask,
                               _bandit_cfg())
    eng.warmup()
    with eng:
        for r in reqs:
            eng.submit(r)
        got = _by_rid(eng.drain())
    assert eng.metrics.compiles_after_warmup == 0
    _assert_bitwise_equal(got, want)


_PARITY = {}


def _parity_engines(corpus):
    """Warm one sync + one async engine, reused across hypothesis examples
    (rebuilding per example would re-AOT-compile every bucket). Reuse is
    sound: both engines see identical streams, so their rid counters and
    batch ordinals advance in lockstep and per-example parity holds."""
    if not _PARITY:
        for name, cls in (("sync", RetrievalEngine),
                          ("async", AsyncRetrievalEngine)):
            _PARITY[name] = cls(corpus.doc_embs, corpus.doc_mask, _cfg())
            _PARITY[name].warmup()
    return _PARITY["sync"], _PARITY["async"]


@pytest.mark.timeout(300)
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_async_drain_equivalence_property(corpus, seed):
    """Property: for a random dense request stream, drain() through the
    started async pipeline returns the same completions (same rids, same
    scores) as the synchronous engine — no request lost, duplicated, or
    rescored."""
    sync, eng = _parity_engines(corpus)
    rng = np.random.default_rng(seed)
    reqs = _stream(corpus, rng, int(rng.integers(1, 10)))
    for r in reqs:
        sync.submit(r)
    want = _by_rid(sync.drain())
    with eng:
        for r in reqs:
            eng.submit(r)
        got = _by_rid(eng.drain())
    _assert_bitwise_equal(got, want)


# ---------------------------------------------------------------------------
# admission backpressure
# ---------------------------------------------------------------------------

def test_backpressure_reject_counts_and_raises(corpus):
    """With a projected wait beyond the request deadline, "reject" raises
    at submit and counts it; a relaxed request still admits."""
    eng = AsyncRetrievalEngine(
        corpus.doc_embs, corpus.doc_mask,
        _cfg(backpressure="reject", deadline_headroom_s=0.2))
    with pytest.raises(AdmissionRejected):
        eng.submit(Request(query=corpus.queries[0][:4], k=5,
                           deadline_s=0.05))
    assert eng.metrics.summary()["rejected"] == 1
    eng.submit(Request(query=corpus.queries[0][:4], k=5, deadline_s=10.0))
    assert len(eng.drain()) == 1


def test_backpressure_degrade_truncates_candidates(corpus):
    """"degrade" admits an over-deadline candidate-carrying request with
    its list truncated to the smallest compiled bucket — and never
    mutates the caller's Request."""
    eng = AsyncRetrievalEngine(
        corpus.doc_embs, corpus.doc_mask,
        _cfg(cand_buckets=(4, 8), max_k=4, backpressure="degrade",
             deadline_headroom_s=0.2, batch_size=1))
    req = Request(query=corpus.queries[0][:4], k=4,
                  deadline_s=0.05,
                  cand_ids=np.arange(8, dtype=np.int32))
    rid = eng.submit(req)
    assert len(req.cand_ids) == 8                 # caller copy untouched
    done = _by_rid(eng.drain())
    assert done[rid].bucket == (8, 4)             # served the cheap bucket
    assert eng.metrics.summary()["degraded"] == 1
    # stage-1 (candidate-less) requests cannot degrade: plain admission.
    rid2 = eng.submit(Request(query=corpus.queries[1][:4], k=4,
                              deadline_s=0.05))
    done = _by_rid(eng.drain())
    assert done[rid2].bucket == (8, 8)
    assert eng.metrics.summary()["degraded"] == 1


# ---------------------------------------------------------------------------
# continuous (slot-refill) runtime
# ---------------------------------------------------------------------------

def test_continuous_submit_requires_start(corpus):
    eng = AsyncRetrievalEngine(corpus.doc_embs, corpus.doc_mask,
                               _bandit_cfg(continuous=True,
                                           stream_trip_limit=2))
    with pytest.raises(RuntimeError):
        eng.submit(Request(query=corpus.queries[0][:4], k=5))


@pytest.mark.timeout(120)
def test_continuous_integrity_determinism_and_futures(corpus):
    """Slot-refill streaming: every submitted rid completes exactly once
    (more requests than slots, so refill is exercised), per-request
    futures resolve, replaying the stream reproduces every score
    bit-for-bit (per-slot keys are fold_in(rid), not slot-index), and
    the warmed stream executable never recompiles."""
    def serve_once():
        eng = AsyncRetrievalEngine(
            corpus.doc_embs, corpus.doc_mask,
            _bandit_cfg(continuous=True, stream_trip_limit=2, max_rounds=4))
        eng.warmup()
        rng = np.random.default_rng(2)
        with eng:
            rids = [eng.submit(r) for r in _stream(corpus, rng, 7)]
            futs = [eng.future(rid) for rid in rids]
            done = _by_rid(eng.drain())
        assert eng.metrics.compiles_after_warmup == 0
        assert sorted(done) == sorted(rids)
        assert all(f.result(timeout=1).rid == rid
                   for f, rid in zip(futs, rids))
        assert eng.metrics.summary()["mean_occupancy"] > 0
        return done

    _assert_bitwise_equal(serve_once(), serve_once())


@pytest.mark.timeout(120)
def test_async_engine_restartable(corpus):
    """stop() then start() must serve again (the stop event is cleared on
    restart) — the pattern the load harness uses between sweep points."""
    eng = AsyncRetrievalEngine(corpus.doc_embs, corpus.doc_mask, _cfg())
    eng.warmup()
    rng = np.random.default_rng(3)
    for _ in range(2):
        with eng:
            for r in _stream(corpus, rng, 4):
                eng.submit(r)
            assert len(eng.drain()) == 4
    assert eng.metrics.summary()["n_requests"] == 8
    assert eng.metrics.compiles_after_warmup == 0
