import os
import sys

# Tests must see the real single CPU device (the dry-run sets its own flags
# in-process); keep any global XLA device-count override out of here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Make `pytest` work from the repo root even without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
if os.path.isdir(_SRC) and os.path.abspath(_SRC) not in (
        os.path.abspath(p) for p in sys.path):
    sys.path.insert(0, os.path.abspath(_SRC))

try:                                     # real hypothesis when installed
    import hypothesis                    # noqa: F401
    # Scheduled CI runs the property suite deterministically and harder:
    # HYPOTHESIS_PROFILE=ci fixes the seed (derandomize) — the example
    # COUNT is scaled by the tests themselves via REPRO_HYP_EXAMPLES_MULT,
    # since test-level @settings(max_examples=...) overrides any profile.
    hypothesis.settings.register_profile(
        "ci", derandomize=True, deadline=None, print_blob=True)
    if os.environ.get("HYPOTHESIS_PROFILE"):
        hypothesis.settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])
except ModuleNotFoundError:              # hermetic fallback (same API subset)
    from repro.testing import hypothesis_fallback
    hypothesis_fallback.install()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
