import os

# Tests must see the real single CPU device (the dry-run sets its own flags
# in-process); keep any global XLA device-count override out of here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
