"""Streaming RetrievalEngine: deadline accounting, shape buckets, and the
zero-recompile serving contract (ISSUE 2 acceptance)."""
import numpy as np
import pytest

from repro.data.synthetic import make_retrieval_dataset
from repro.kernels import ref as kref
from repro.serve import EngineConfig, Request, RetrievalEngine, ShapeBuckets


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def corpus():
    return make_retrieval_dataset(n_docs=48, n_queries=16, doc_len=16,
                                  min_doc_len=6, query_len=16, dim=16,
                                  seed=3)


def _dense_cfg(**kw):
    base = dict(batch_size=4, deadline_s=0.5, token_buckets=(8, 16),
                cand_buckets=(16,), max_k=5, flavor="dense",
                stage1_candidates=16, stage1_kprime=4)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# shape buckets
# ---------------------------------------------------------------------------

def test_shape_buckets_fit_and_validate():
    b = ShapeBuckets((16, 8), (32,))
    assert b.token_buckets == (8, 16)            # sorted + deduped
    assert b.token_bucket(1) == 8
    assert b.token_bucket(9) == 16
    assert b.cand_bucket(32) == 32
    with pytest.raises(ValueError):
        b.token_bucket(17)
    with pytest.raises(ValueError):
        ShapeBuckets((), (8,))


def test_submit_validation(corpus):
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask, _dense_cfg())
    with pytest.raises(ValueError):              # too many query tokens
        eng.submit(Request(query=np.zeros((17, 16), np.float32)))
    with pytest.raises(ValueError):              # k beyond compiled width
        eng.submit(Request(query=np.zeros((4, 16), np.float32), k=9))
    with pytest.raises(ValueError):              # wrong embedding dim
        eng.submit(Request(query=np.zeros((4, 8), np.float32)))
    with pytest.raises(ValueError):              # candidate id off the corpus
        eng.submit(Request(query=np.zeros((4, 16), np.float32),
                           cand_ids=np.array([0, 99], np.int32)))


# ---------------------------------------------------------------------------
# deadline-aware admission + deadline-miss accounting
# ---------------------------------------------------------------------------

def test_deadline_miss_accounting(corpus):
    clock = ManualClock()
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask,
                          _dense_cfg(deadline_s=1.0), clock=clock)
    eng.warmup()
    q = corpus.queries[0][:8]

    # two requests with a 50 ms deadline; the engine only gets to poll
    # 200 ms later -> both are released late and accounted as misses.
    eng.submit(Request(query=q, k=5, deadline_s=0.05))
    eng.submit(Request(query=q, k=5, deadline_s=0.05))
    assert eng.poll() == []                      # not full, not expired yet
    clock.advance(0.2)
    done = eng.poll()
    assert len(done) == 2
    assert all(c.deadline_miss for c in done)
    assert all(abs(c.queue_wait_s - 0.2) < 1e-9 for c in done)

    # a relaxed request released exactly at its per-request deadline is NOT
    # a miss (and is NOT held for the engine-wide 1 s admission window).
    eng.submit(Request(query=q, k=5, deadline_s=0.3))
    assert eng.next_expiry() == pytest.approx(clock.t + 0.3)
    clock.advance(0.3)
    done = eng.poll()
    assert len(done) == 1 and not done[0].deadline_miss

    # a full batch releases immediately -> no waiting, no misses.
    for _ in range(4):
        eng.submit(Request(query=q, k=5, deadline_s=0.05))
    done = eng.poll()
    assert len(done) == 4
    assert not any(c.deadline_miss for c in done)
    assert all(c.queue_wait_s == 0.0 for c in done)

    s = eng.metrics.summary()
    assert s["n_requests"] == 7
    assert s["deadline_miss_rate"] == pytest.approx(2 / 7)


def test_batcher_flush_respects_batch_size():
    """flush never exceeds the padded static batch shape, however much is
    pending — drain it with repeated calls."""
    from repro.dist.fault import DeadlineBatcher
    t = [0.0]
    b = DeadlineBatcher(batch_size=4, deadline_s=1.0, clock=lambda: t[0])
    for x in "abcdef":
        b.add(x)
    reqs, n_real = b.flush()
    assert (reqs, n_real) == (["a", "b", "c", "d"], 4)
    reqs, n_real = b.flush()
    assert (reqs, n_real) == (["e", "f", "f", "f"], 2)
    assert b.flush() is None


def test_submit_does_not_mutate_caller_request(corpus):
    """One Request object may be submitted repeatedly: the engine queues
    its own copies, each with a fresh rid and arrival stamp."""
    clock = ManualClock()
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask,
                          _dense_cfg(batch_size=2), clock=clock)
    req = Request(query=corpus.queries[0][:8], k=5,
                  cand_ids=np.arange(8, dtype=np.int32))
    r0 = eng.submit(req)
    clock.advance(0.1)
    r1 = eng.submit(req)
    assert req.rid == -1 and req.arrival == 0.0      # caller copy untouched
    done = {c.rid: c for c in eng.poll()}
    assert set(done) == {r0, r1} and r0 != r1
    assert done[r0].queue_wait_s == pytest.approx(0.1)
    assert done[r1].queue_wait_s == pytest.approx(0.0)


def test_miss_counted_for_admission_after_stale_next_expiry(corpus):
    """Pin the serve-time miss contract: a request admitted AFTER the
    caller captured next_expiry() — so the poll loop oversleeps its
    (tighter) deadline — must be accounted as a miss when the late poll
    finally serves it. Miss stamping happens at SERVE time against the
    absolute completion deadline captured at admission (Request
    .deadline_abs), never at admission time."""
    clock = ManualClock()
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask,
                          _dense_cfg(deadline_s=1.0), clock=clock)
    eng.warmup()
    q = corpus.queries[0][:8]
    eng.submit(Request(query=q, k=5))              # no deadline
    stale_expiry = eng.next_expiry()               # driven by the 1 s window
    assert stale_expiry == pytest.approx(1.0)
    clock.advance(0.5)
    rid_late = eng.submit(Request(query=q, k=5, deadline_s=0.05))
    # poll loop slept to the STALE expiry; the tight request is now 0.45 s
    # past its completion deadline (0.55 absolute).
    clock.advance(0.5)
    done = {c.rid: c for c in eng.poll()}
    assert done[rid_late].deadline_miss
    assert sum(c.deadline_miss for c in done.values()) == 1  # only the late one
    s = eng.metrics.summary()
    assert s["deadline_miss_rate"] == pytest.approx(1 / 2)


def test_per_batch_prng_folds_ordinal_and_replays_deterministically(corpus):
    """Two batches of the SAME request must reveal distinct cell
    trajectories (the batch ordinal is folded into the bandit key — a
    reused seed would make concurrent buckets reveal identical cells),
    while replaying the identical stream on a fresh engine reproduces
    every score bit-for-bit."""
    def serve_stream():
        cfg = _dense_cfg(batch_size=1, flavor="bandit", alpha_ef=0.3,
                         max_rounds=2, block_docs=4, block_tokens=2,
                         token_buckets=(8,))
        eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask, cfg)
        cand = np.arange(16, dtype=np.int32)
        out = []
        for _ in range(2):                         # two batches, ordinals 0, 1
            eng.submit(Request(query=corpus.queries[0][:8], k=5,
                               cand_ids=cand))
            out += eng.poll()
        return out

    first = serve_stream()
    # distinct per-batch trajectories => distinct partial-coverage estimates
    assert not np.allclose(first[0].topk_scores, first[1].topk_scores)
    replay = serve_stream()
    for c0, c1 in zip(first, replay):
        np.testing.assert_array_equal(c0.topk_scores, c1.topk_scores)
        np.testing.assert_array_equal(c0.topk_ids, c1.topk_ids)
        assert c0.reveal_fraction == c1.reveal_fraction


def test_admission_leaves_service_headroom(corpus):
    """The batcher must release EARLY enough for the batch to execute
    before the completion deadline: admission = deadline - headroom."""
    clock = ManualClock()
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask,
                          _dense_cfg(deadline_headroom_s=0.02), clock=clock)
    eng.submit(Request(query=corpus.queries[0][:8], k=5, deadline_s=0.05))
    assert eng.next_expiry() == pytest.approx(0.03)


# ---------------------------------------------------------------------------
# compile accounting: one compile per bucket, zero after warmup
# ---------------------------------------------------------------------------

def test_cold_engine_compiles_each_bucket_exactly_once(corpus):
    """Without warmup, the first batch per bucket compiles; every later hit
    of the same bucket reuses the cached executable."""
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask, _dense_cfg())
    q_small, q_large = corpus.queries[0][:6], corpus.queries[1][:12]
    for _ in range(3):                           # 3 batches per bucket
        for q in (q_small, q_small, q_small, q_small):
            eng.submit(Request(query=q, k=5))
        eng.poll()
        for q in (q_large, q_large, q_large, q_large):
            eng.submit(Request(query=q, k=5))
        eng.poll()
    assert len(eng.metrics.completions) == 24
    assert all(count == 1 for count in eng.metrics.compiles.values())
    used = {c.bucket for c in eng.metrics.completions}
    assert used == {(8, 16), (16, 16)}


def test_warm_engine_serves_64_request_mixed_stream_with_zero_recompiles(
        corpus):
    """ISSUE 2 acceptance: warmup() pre-compiles every bucket; a 64-request
    stream of mixed query lengths and mixed candidate provenance then
    serves without a single extra compile."""
    clock = ManualClock()
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask,
                          _dense_cfg(deadline_s=0.01), clock=clock)
    eng.warmup()
    compiled = dict(eng.metrics.compiles)
    assert compiled and all(n == 1 for n in compiled.values())

    rng = np.random.default_rng(0)
    done = []
    for i in range(64):
        n_tok = int(rng.integers(2, 17))
        cand = (rng.choice(48, int(rng.integers(4, 17)), replace=False)
                if i % 2 else None)
        eng.submit(Request(query=corpus.queries[i % 16][:n_tok], k=5,
                           deadline_s=0.05, cand_ids=cand))
        clock.advance(float(rng.uniform(0, 0.01)))
        done += eng.poll()
    done += eng.drain()

    assert len(done) == 64
    assert eng.metrics.compiles_after_warmup == 0
    assert dict(eng.metrics.compiles) == compiled   # cache untouched
    assert {c.bucket[0] for c in done} == {8, 16}   # both buckets exercised
    s = eng.metrics.summary()
    assert s["n_requests"] == 64 and s["compiles_after_warmup"] == 0


# ---------------------------------------------------------------------------
# correctness of served results
# ---------------------------------------------------------------------------

def test_dense_results_match_reference(corpus):
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask, _dense_cfg())
    cand = np.arange(16, dtype=np.int32)
    q = corpus.queries[2][:8]
    eng.submit(Request(query=q, k=5, cand_ids=cand))
    done = eng.drain()
    assert len(done) == 1
    h = kref.maxsim_ref(corpus.doc_embs[cand], corpus.doc_mask[cand],
                        np.asarray(q, np.float32))
    s_ref = np.asarray(h.sum(-1))
    order = cand[np.argsort(-s_ref)]
    assert int(done[0].topk_ids[0]) == int(order[0])
    np.testing.assert_allclose(done[0].topk_scores[0], s_ref.max(),
                               atol=1e-4)
    assert done[0].reveal_fraction == pytest.approx(1.0)


@pytest.mark.slow
def test_bandit_flavor_conservative_matches_dense_top1(corpus):
    """alpha_ef -> inf puts the bandit in hard-bound mode: its top-1 must
    agree with dense scoring, at a reveal fraction <= 1."""
    cfg = _dense_cfg(flavor="bandit", alpha_ef=1e9, batch_size=2,
                     token_buckets=(8,), block_docs=4, block_tokens=4)
    eng = RetrievalEngine(corpus.doc_embs, corpus.doc_mask, cfg)
    dense = RetrievalEngine(corpus.doc_embs, corpus.doc_mask, _dense_cfg())
    cand = np.arange(16, dtype=np.int32)
    for qi in (0, 1):
        q = corpus.queries[qi][:8]
        eng.submit(Request(query=q, k=5, cand_ids=cand))
        dense.submit(Request(query=q, k=5, cand_ids=cand))
    got = {c.rid: c for c in eng.drain()}
    want = {c.rid: c for c in dense.drain()}
    for rid, c in got.items():
        assert int(c.topk_ids[0]) == int(want[rid].topk_ids[0])
        assert 0.0 < c.reveal_fraction <= 1.0
        assert c.flavor == "bandit" and want[rid].flavor == "dense"


# ---------------------------------------------------------------------------
# ISSUE 5 satellite: bf16 corpora serve end-to-end (kernels accumulate f32)
# ---------------------------------------------------------------------------

def test_engine_serves_bf16_corpus_matching_f32_topk(corpus):
    """A bfloat16 corpus must stay bf16 on device and serve the same top-K
    as the f32 corpus (scores at bf16-quantization distance): the kernel
    ops cast to f32 at the contraction, never the engine."""
    import jax.numpy as jnp

    cfg = _dense_cfg(batch_size=2, token_buckets=(8,), flavor="bandit",
                     block_docs=4, block_tokens=4, max_rounds=8)
    results = {}
    for dtype in (np.float32, jnp.bfloat16):
        embs = jnp.asarray(corpus.doc_embs).astype(dtype)
        eng = RetrievalEngine(embs, corpus.doc_mask, cfg)
        assert eng.corpus_embs.dtype == dtype
        eng.warmup()
        for i in range(2):
            eng.submit(Request(query=np.asarray(corpus.queries[i, :8],
                                                np.float32),
                               k=5, cand_ids=np.arange(16)))
        done = sorted(eng.drain(), key=lambda c: c.rid)
        assert len(done) == 2 and eng.metrics.compiles_after_warmup == 0
        results[np.dtype(dtype).name if dtype is np.float32 else "bfloat16"] \
            = done
    for c32, c16 in zip(results["float32"], results["bfloat16"]):
        assert set(c32.topk_ids) == set(c16.topk_ids)
