"""Metrics (Eq. 16 + IR metrics) and static baselines (Alg. 2/3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (doc_top_margin, doc_uniform, exact_topk, mrr_at_k,
                        ndcg_at_k, overlap_at_k, recall_at_k)


def test_overlap():
    a = jnp.asarray([1, 2, 3, 4, 5])
    assert float(overlap_at_k(a, a)) == 1.0
    assert float(overlap_at_k(a, jnp.asarray([1, 2, 3, 9, 8]))) == pytest.approx(0.6)
    assert float(overlap_at_k(a, jnp.asarray([9, 8, 7, 6, 0]))) == 0.0
    # order-insensitive
    assert float(overlap_at_k(a, jnp.asarray([5, 4, 3, 2, 1]))) == 1.0


def test_recall_mrr_ndcg():
    rel = jnp.zeros(20, bool).at[jnp.asarray([3, 7])].set(True)
    topk = jnp.asarray([0, 3, 5, 7, 9])
    assert float(recall_at_k(topk, rel)) == pytest.approx(1.0)
    assert float(mrr_at_k(topk, rel)) == pytest.approx(1 / 2)
    topk2 = jnp.asarray([0, 1, 2, 4, 5])
    assert float(recall_at_k(topk2, rel)) == 0.0
    assert float(mrr_at_k(topk2, rel)) == 0.0
    assert float(ndcg_at_k(topk2, rel)) == 0.0
    # perfect ranking => ndcg 1
    topk3 = jnp.asarray([3, 7, 0, 1, 2])
    assert float(ndcg_at_k(topk3, rel)) == pytest.approx(1.0, abs=1e-6)


def test_doc_uniform_full_budget_exact():
    rng = np.random.default_rng(0)
    H = jnp.asarray(rng.uniform(0, 1, (32, 16)).astype(np.float32))
    exact, _ = exact_topk(H, k=4)
    res = doc_uniform(H, jax.random.key(0), k=4, budget=16)
    assert float(overlap_at_k(res.topk, exact)) == 1.0
    assert float(res.coverage) == 1.0


def test_doc_uniform_budget_coverage():
    rng = np.random.default_rng(1)
    H = jnp.asarray(rng.uniform(0, 1, (32, 16)).astype(np.float32))
    res = doc_uniform(H, jax.random.key(0), k=4, budget=4)
    assert float(res.coverage) == pytest.approx(4 / 16)
    # exactly budget cells per row
    assert (np.asarray(res.revealed).sum(-1) == 4).all()


def test_doc_top_margin_picks_widest():
    rng = np.random.default_rng(2)
    H = jnp.asarray(rng.uniform(0, 1, (8, 16)).astype(np.float32))
    a = jnp.zeros(H.shape)
    b = jnp.asarray(np.tile(np.linspace(0.1, 1.0, 16), (8, 1)).astype(np.float32))
    res = doc_top_margin(H, a, b, k=2, budget=4)
    # widest-support cells are the last 4 columns
    assert np.asarray(res.revealed)[:, -4:].all()
    assert not np.asarray(res.revealed)[:, :-4].any()
