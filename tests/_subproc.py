"""Shared helper: run a snippet in a subprocess with 8 host placeholder
devices (multi-device shard_map tests must not disturb the main pytest
process's single-device world — see conftest)."""
import os
import subprocess
import sys
import textwrap

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(code: str, n_devices: int = 8) -> str:
    prog = ("import os\n"
            f"os.environ['XLA_FLAGS']="
            f"'--xla_force_host_platform_device_count={n_devices}'\n"
            + textwrap.dedent(code))
    env = {"PYTHONPATH": "src", "PATH": os.environ.get(
               "PATH", "/usr/bin:/bin"),
           "HOME": os.environ.get("HOME", "/root"),
           # same platform pin as conftest: without it, a container with
           # libtpu installed stalls for minutes probing for TPU hardware
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    # The kernel-dispatch lane (CI matrixes ref/interpret) must reach the
    # shard_map paths exercised in subprocesses too.
    if "REPRO_KERNEL_IMPL" in os.environ:
        env["REPRO_KERNEL_IMPL"] = os.environ["REPRO_KERNEL_IMPL"]
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=300, cwd=REPO_ROOT, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout
