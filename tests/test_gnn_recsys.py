"""PNA (incl. sharded parity + sampler) and recsys model tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig, RecsysConfig
from repro.models import gnn as G
from repro.models import recsys as R

CFG = GNNConfig(name="pna", n_layers=3, d_hidden=16, n_classes=5)


def test_pna_forward_and_grad():
    params = G.init_pna(jax.random.key(0), CFG, 8)
    g = G.random_graph(64, 256, 8, 5, seed=1)
    logits = G.pna_forward(params, CFG, g)
    assert logits.shape == (64, 5)
    assert not bool(jnp.any(jnp.isnan(logits)))
    grads = jax.grad(G.pna_loss)(params, CFG, g)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(grads))


def test_pna_isolated_nodes_zero_aggregate():
    params = G.init_pna(jax.random.key(0), CFG, 8)
    g = G.random_graph(16, 8, 8, 5, seed=2)
    # all edges point at node 0; other nodes have degree 0
    g = g._replace(receivers=jnp.zeros_like(g.receivers))
    logits = G.pna_forward(params, CFG, g)
    assert np.isfinite(np.asarray(logits)).all()


def test_sharded_loss_matches_local():
    """The dst-partitioned shard_map step must agree exactly with the local
    reference on a 1-device mesh (the partition contract is exercised by
    partition_edges_by_dst with multiple parts in the next test)."""
    params = G.init_pna(jax.random.key(0), CFG, 8)
    g = G.random_graph(64, 256, 8, 5, seed=3)
    ref = float(G.pna_loss(params, CFG, g))
    mesh = jax.make_mesh((1,), ("data",))
    S, Rv, M = G.partition_edges_by_dst(
        np.asarray(g.senders), np.asarray(g.receivers), 64, 1)
    g1 = g._replace(senders=jnp.asarray(S), receivers=jnp.asarray(Rv),
                    edge_mask=jnp.asarray(M))
    out = float(G.pna_loss_sharded(params, CFG, g1, mesh))
    assert out == pytest.approx(ref, rel=1e-5)


def test_partition_edges_by_dst_contract():
    rng = np.random.default_rng(0)
    senders = rng.integers(0, 64, 500).astype(np.int32)
    receivers = rng.integers(0, 64, 500).astype(np.int32)
    S, Rv, M = G.partition_edges_by_dst(senders, receivers, 64, 4)
    per = len(S) // 4
    for d in range(4):
        r = Rv[d * per:(d + 1) * per]
        m = M[d * per:(d + 1) * per]
        # every real edge's dst is in the part's node range
        assert ((r[m] // 16) == d).all()
    # no edges lost
    assert M.sum() == 500


def test_neighbor_sampler_shapes_and_validity():
    rng = np.random.default_rng(1)
    send = rng.integers(0, 200, 2000).astype(np.int32)
    recv = rng.integers(0, 200, 2000).astype(np.int32)
    csr = G.build_csr(200, send, recv)
    feats = rng.standard_normal((200, 8)).astype(np.float32)
    labels = rng.integers(0, 5, 200)
    sub = G.sample_subgraph(csr, feats, labels, np.arange(32), (5, 3))
    n_expected = 32 * (1 + 5 + 15)
    assert sub.feats.shape == (n_expected, 8)
    assert sub.senders.shape == (32 * (5 + 15),)
    # edges reference valid local node ids
    assert int(jnp.max(sub.senders)) < n_expected
    assert int(jnp.max(sub.receivers)) < n_expected
    # runs through the model
    out = G.pna_forward(G.init_pna(jax.random.key(0), CFG, 8), CFG, sub)
    assert np.isfinite(np.asarray(out)).all()


def test_molecule_batching_block_diagonal():
    mb = G.batch_molecules(4, 10, 20, 8, 5, seed=0)
    # edges never cross molecule boundaries
    s = np.asarray(mb.senders) // 10
    r = np.asarray(mb.receivers) // 10
    assert (s == r).all()


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------

VOCAB = tuple(50 + 3 * i for i in range(8))


def test_fm_sum_square_trick_vs_naive():
    cfg = RecsysConfig(name="fm", interaction="fm-2way", n_sparse=8,
                       embed_dim=6, vocab_sizes=VOCAB)
    p = R.init_fm(jax.random.key(0), cfg)
    ids = jax.random.randint(jax.random.key(1), (16, 8), 0, 50)
    out = R.fm_forward(p, cfg, ids)
    offs = R.field_offsets(cfg.vocab_sizes)
    v = R.embedding_lookup(p["table"], ids, offs)
    naive = sum(jnp.sum(v[:, i] * v[:, j], -1)
                for i in range(8) for j in range(i + 1, 8))
    lin = R.embedding_lookup(p["linear"], ids, offs)[..., 0].sum(-1)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(p["bias"] + lin + naive), atol=1e-5)


def test_fm_candidate_components_sum_to_score():
    cfg = RecsysConfig(name="fm", interaction="fm-2way", n_sparse=8,
                       embed_dim=6, vocab_sizes=VOCAB)
    p = R.init_fm(jax.random.key(0), cfg)
    ctx = jax.random.randint(jax.random.key(2), (7,), 0, 50)
    cands = jnp.arange(20)
    scores = R.fm_score_candidates(p, cfg, ctx, cands)
    comps = R.fm_candidate_components(p, cfg, ctx, cands)
    np.testing.assert_allclose(np.asarray(comps.sum(-1)), np.asarray(scores),
                               atol=1e-5)


def test_embedding_bag_modes():
    tbl = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    ids = jnp.asarray([0, 1, 2, 5])
    bags = jnp.asarray([0, 0, 1, 1])
    s = R.embedding_bag(tbl, ids, bags, 2, mode="sum")
    np.testing.assert_allclose(np.asarray(s),
                               [[2, 4], [14, 16]])
    m = R.embedding_bag(tbl, ids, bags, 2, mode="mean")
    np.testing.assert_allclose(np.asarray(m), [[1, 2], [7, 8]])
    mx = R.embedding_bag(tbl, ids, bags, 2, mode="max")
    np.testing.assert_allclose(np.asarray(mx), [[2, 3], [10, 11]])


def test_sasrec_candidate_scores_match_forward():
    cfg = RecsysConfig(name="sasrec", interaction="self-attn-seq",
                       embed_dim=16, n_blocks=2, n_heads=1, seq_len=12,
                       item_vocab=100)
    p = R.init_sasrec(jax.random.key(0), cfg)
    hist = jax.random.randint(jax.random.key(1), (12,), 0, 100)
    mask = jnp.ones((12,), bool)
    cands = jnp.arange(30)
    sc = R.sasrec_score_candidates(p, cfg, hist, mask, cands)
    fwd = R.sasrec_forward(p, cfg, jnp.tile(hist[None], (30, 1)),
                           jnp.tile(mask[None], (30, 1)), cands)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(fwd), atol=1e-4)


def test_din_candidate_scores_match_forward():
    cfg = RecsysConfig(name="din", interaction="target-attn", embed_dim=8,
                       seq_len=10, item_vocab=100, attn_mlp=(16, 8),
                       mlp=(32, 16))
    p = R.init_din(jax.random.key(0), cfg)
    hist = jax.random.randint(jax.random.key(1), (10,), 0, 100)
    mask = jnp.ones((10,), bool)
    cands = jnp.arange(25)
    sc = R.din_score_candidates(p, cfg, hist, mask, cands, chunk=8)
    fwd = R.din_forward(p, cfg, jnp.tile(hist[None], (25, 1)),
                        jnp.tile(mask[None], (25, 1)), cands)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(fwd), atol=1e-5)


def test_autoint_forward_shapes():
    cfg = RecsysConfig(name="autoint", interaction="self-attn", n_sparse=8,
                       embed_dim=16, vocab_sizes=VOCAB, n_attn_layers=2,
                       n_heads=2, d_attn=8)
    p = R.init_autoint(jax.random.key(0), cfg)
    ids = jax.random.randint(jax.random.key(1), (16, 8), 0, 50)
    out = R.autoint_forward(p, cfg, ids)
    assert out.shape == (16,)
    assert np.isfinite(np.asarray(out)).all()
