"""ISSUE 2 satellite: sequential (paper Alg. 1) vs block-synchronous bandit.

With a full reveal budget and conservative radii (alpha_ef -> inf puts both
variants in pure hard-bound mode, where stopping implies provable
separation), both must return the IDENTICAL top-K set — and it must be the
exact one. Also checks the observation-set accounting invariants shared by
both control loops: every revealed cell is counted exactly once, and docs
dropped by the candidate mask are never revealed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact_topk, run_bandit, run_batched_oracle


def _make_h(seed, N=48, T=24):
    rng = np.random.default_rng(seed)
    H = rng.uniform(0.1, 0.6, (N, T)).astype(np.float32)
    winners = rng.choice(N, 6, replace=False)
    H[winners] += 0.3
    return jnp.asarray(np.clip(H, 0, 1))


def _run_both(H, *, k, seed=0, doc_mask=None):
    a = jnp.zeros(H.shape)
    b = jnp.ones(H.shape)
    seq = run_bandit(H, a, b, jax.random.key(seed), k=k, alpha_ef=1e9,
                     doc_mask=doc_mask)
    blk = run_batched_oracle(H, a, b, jax.random.key(seed), k=k,
                             alpha_ef=1e9, block_docs=8, block_tokens=4,
                             doc_mask=doc_mask)
    return seq, blk


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_full_budget_topk_sets_identical(seed):
    H = _make_h(seed)
    k = 5
    seq, blk = _run_both(H, k=k, seed=seed)
    exact, _ = exact_topk(H, k=k)
    want = set(int(i) for i in np.asarray(exact))
    assert set(int(i) for i in np.asarray(seq.topk)) == want
    assert set(int(i) for i in np.asarray(blk.topk)) == want
    # hard-bound mode: both must have stopped via provable separation
    assert bool(seq.separated) and bool(blk.separated)


@pytest.mark.parametrize("seed", [3, 4])
def test_reveal_accounting_no_double_count(seed):
    """reveals == |Omega| exactly: re-reveals are no-ops in both variants,
    so the scalar counter and the boolean observation set always agree."""
    H = _make_h(seed)
    for res in _run_both(H, k=5, seed=seed):
        rev = np.asarray(res.revealed)
        assert int(res.reveals) == int(rev.sum())
        n_cells = rev.size
        np.testing.assert_allclose(float(res.coverage),
                                   rev.sum() / n_cells, atol=1e-6)


@pytest.mark.parametrize("seed", [5, 6])
def test_dropped_docs_never_revealed(seed):
    """Docs outside the candidate mask are dropped before the loop starts;
    neither variant may spend a single reveal on them, and neither may
    return one in the top-K."""
    N = 48
    H = _make_h(seed, N=N)
    doc_mask = jnp.asarray(np.arange(N) < 36)
    seq, blk = _run_both(H, k=5, seed=seed, doc_mask=doc_mask)
    exact, _ = exact_topk(jnp.where(doc_mask[:, None], H, -1.0), k=5)
    want = set(int(i) for i in np.asarray(exact))
    for res in (seq, blk):
        rev = np.asarray(res.revealed)
        assert not rev[36:].any()
        assert set(int(i) for i in np.asarray(res.topk)) == want
        assert all(int(i) < 36 for i in np.asarray(res.topk))
