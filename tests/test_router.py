"""Property + regression tests for the centroid router and the prereveal
seam it feeds (ISSUE 6).

The router's contract is arithmetic, so it property-tests cleanly:
  * quota conservation — per-query quotas ALWAYS sum to the global budget,
    whatever the routed mass looks like (including all-zero rows);
  * determinism — same seed, same corpus => bit-identical router state;
  * loud failure — a quota exceeding a shard's ``valid_docs`` (or the
    compiled ``n_local``) raises ``ValueError``, never clamps.

Plus chain-vs-fused parity of ``run_pooled_bandit``'s prereveal seeding:
both round bodies must make identical reveal decisions when the bandit is
seeded with exactly-known cells (the Eq. 15 stage-1 hit values).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.batched import BatchedConfig
from repro.core.frontier import run_pooled_bandit
from repro.retrieval.corpus import (CentroidRouter, build_router,
                                    route_mass, route_quotas,
                                    validate_quotas)

_MULT = max(1, int(os.environ.get("REPRO_HYP_EXAMPLES_MULT", "1")))


# ---------------------------------------------------------------------------
# Quota conservation + bounds
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 7), st.integers(1, 64))
@settings(max_examples=40 * _MULT, deadline=None)
def test_quota_conservation(seed, n_shards, n_total):
    """sum(quotas[b]) == n_total for EVERY query, over random masses —
    including all-zero rows (uniform fallback) and heavily skewed ones."""
    rng = np.random.default_rng(seed)
    B = 5
    mass = rng.uniform(0.0, 1.0, (B, n_shards)).astype(np.float32)
    mass[rng.random(B) < 0.3] = 0.0          # router missed every centroid
    mass[rng.random((B, n_shards)) < 0.4] = 0.0   # sparse shard coverage
    q = np.asarray(route_quotas(jnp.asarray(mass), n_total))
    assert q.shape == (B, n_shards)
    np.testing.assert_array_equal(q.sum(axis=1), n_total)
    assert (q >= 0).all() and (q <= n_total).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15 * _MULT, deadline=None)
def test_quota_proportionality(seed):
    """Quotas track the routed mass: largest-remainder rounding keeps each
    quota within one unit of its proportional ideal."""
    rng = np.random.default_rng(seed)
    S, n_total = 4, 32
    mass = rng.uniform(0.1, 1.0, (3, S)).astype(np.float32)
    q = np.asarray(route_quotas(jnp.asarray(mass), n_total))
    ideal = mass / mass.sum(axis=1, keepdims=True) * n_total
    assert (np.abs(q - ideal) < 1.0 + 1e-5).all()


def test_zero_mass_uniform_fallback():
    q = np.asarray(route_quotas(jnp.zeros((2, 4), jnp.float32), 8))
    np.testing.assert_array_equal(q, np.full((2, 4), 2))


def test_zero_centroid_router_routes_zero_mass():
    m = route_mass(jnp.ones((2, 3, 8), jnp.float32),
                   jnp.zeros((0, 8), jnp.float32),
                   jnp.zeros((0, 4), jnp.float32))
    np.testing.assert_array_equal(np.asarray(m), np.zeros((2, 4)))


# ---------------------------------------------------------------------------
# Router construction: determinism + mass accounting
# ---------------------------------------------------------------------------

def _toy_corpus(seed=0, C=37, L=4, M=8):
    rng = np.random.default_rng(seed)
    embs = rng.normal(size=(C, L, M)).astype(np.float32)
    mask = rng.random((C, L)) < 0.85
    mask[0] = False                          # a doc with no valid token
    return embs, mask


def test_build_router_deterministic_under_seed():
    embs, mask = _toy_corpus()
    r1 = build_router(embs, mask, n_shards=4, docs_per_shard=10, seed=3)
    r2 = build_router(embs, mask, n_shards=4, docs_per_shard=10, seed=3)
    np.testing.assert_array_equal(np.asarray(r1.centroids),
                                  np.asarray(r2.centroids))
    np.testing.assert_array_equal(np.asarray(r1.shard_mass),
                                  np.asarray(r2.shard_mass))


def test_build_router_mass_accounting():
    """shard_mass totals the docs with >= 1 valid token, split by the
    contiguous-block shard placement; tokenless docs carry no mass."""
    embs, mask = _toy_corpus()
    r = build_router(embs, mask, n_shards=4, docs_per_shard=10)
    sm = np.asarray(r.shard_mass)
    n_live = int(mask.any(1).sum())
    assert sm.sum() == n_live                # doc 0 (no tokens) excluded
    per_shard = sm.sum(axis=0)
    expect = np.array([mask.any(1)[s * 10:(s + 1) * 10].sum()
                       for s in range(4)])
    np.testing.assert_array_equal(per_shard, expect)
    # centroids are unit rows (spherical k-means)
    norms = np.linalg.norm(np.asarray(r.centroids), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)


def test_router_route_deterministic():
    embs, mask = _toy_corpus()
    r = build_router(embs, mask, n_shards=4, docs_per_shard=10, seed=1)
    q = np.random.default_rng(5).normal(size=(3, 6, 8)).astype(np.float32)
    q1 = r.route(q, n_total=8)
    q2 = r.route(q, n_total=8)
    np.testing.assert_array_equal(q1, q2)
    np.testing.assert_array_equal(q1.sum(axis=1), 8)


# ---------------------------------------------------------------------------
# Loud failure: quotas never silently clamp
# ---------------------------------------------------------------------------

def test_validate_quotas_valid_docs_message():
    with pytest.raises(ValueError, match=r"exceeds its valid_docs=3"):
        validate_quotas(np.array([[5, 0]]), np.array([3, 3]))


def test_validate_quotas_n_local_message():
    with pytest.raises(ValueError, match=r"per-shard capacity n_local=2"):
        validate_quotas(np.array([[3, 3]]), np.array([8, 8]), n_local=2)


def test_router_route_raises_on_overfull_shard():
    """End-to-end host API: all routed mass on a shard with too few docs
    must raise, not serve a silently shortened candidate list."""
    router = CentroidRouter(
        centroids=jnp.ones((1, 8), jnp.float32) / np.sqrt(8.0),
        shard_mass=jnp.asarray([[10.0, 0.0]], jnp.float32),
        valid_docs=np.array([2, 2], np.int32))
    q = np.ones((1, 3, 8), np.float32)
    with pytest.raises(ValueError, match="exceeds its valid_docs"):
        router.route(q, n_total=8)
    router.route(q, n_total=2)               # within capacity: fine


# ---------------------------------------------------------------------------
# Prereveal seeding: chain-vs-fused parity + stat correctness
# ---------------------------------------------------------------------------

def _oracle_cells(h):
    Q, N, T = h.shape
    h_flat = jnp.asarray(h).reshape(Q * N, T)

    def cells(flat_doc, flat_tok):
        t_local = flat_tok - (flat_doc // N * T)[:, None]
        return h_flat[flat_doc[:, None], jnp.clip(t_local, 0, T - 1)]

    return cells


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8 * _MULT, deadline=None)
def test_prereveal_chain_fused_parity(seed):
    """Seeding the bandit with exactly-known cells must leave both round
    bodies bit-identical: same top-K, same estimates, same reveal sets."""
    rng = np.random.default_rng(seed)
    Q, N, T = 3, 6, 5
    h = rng.uniform(0.0, 1.0, (Q, N, T)).astype(np.float32)
    doc_mask = rng.random((Q, N)) < 0.8
    doc_mask[:, 0] = True
    pr = (rng.random((Q, N, T)) < 0.4) & doc_mask[:, :, None]
    a = np.zeros((Q, N, T), np.float32)
    b = np.ones((Q, N, T), np.float32)
    keys = jax.random.split(jax.random.fold_in(jax.random.key(997), seed), Q)
    cfg = BatchedConfig(k=2, block_docs=2, block_tokens=2, max_rounds=64)

    res = {}
    for fused in (False, True):
        res[fused] = run_pooled_bandit(
            _oracle_cells(h), jnp.asarray(a), jnp.asarray(b), keys, cfg,
            doc_mask=jnp.asarray(doc_mask), fused=fused,
            prereveal=jnp.asarray(pr), prereveal_vals=jnp.asarray(h))
    c, f = res[False], res[True]
    np.testing.assert_array_equal(np.asarray(c.topk), np.asarray(f.topk))
    np.testing.assert_allclose(np.asarray(c.s_hat), np.asarray(f.s_hat),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c.reveals),
                                  np.asarray(f.reveals))
    np.testing.assert_array_equal(np.asarray(c.revealed),
                                  np.asarray(f.revealed))
    np.testing.assert_array_equal(np.asarray(c.rounds), np.asarray(f.rounds))
    # prereveal cells count as revealed from round 0 in both bodies
    assert (np.asarray(c.revealed) | ~pr).all()


def test_full_prereveal_is_exact_and_immediate():
    """Prerevealing EVERY valid cell gives exact scores with zero extra
    reveal work beyond round bookkeeping: s_hat == sum_t h and the reveal
    set never grows past the seeded cells."""
    rng = np.random.default_rng(0)
    Q, N, T = 2, 5, 4
    h = rng.uniform(0.0, 1.0, (Q, N, T)).astype(np.float32)
    doc_mask = np.ones((Q, N), bool)
    pr = np.ones((Q, N, T), bool)
    keys = jax.random.split(jax.random.key(7), Q)
    cfg = BatchedConfig(k=2, block_docs=2, block_tokens=2, max_rounds=32)
    for fused in (False, True):
        res = run_pooled_bandit(
            _oracle_cells(h), jnp.zeros((Q, N, T)), jnp.ones((Q, N, T)),
            keys, cfg, doc_mask=jnp.asarray(doc_mask), fused=fused,
            prereveal=jnp.asarray(pr), prereveal_vals=jnp.asarray(h))
        np.testing.assert_allclose(np.asarray(res.s_hat), h.sum(-1),
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(res.reveals), N * T)
        exact_top = np.argsort(-h.sum(-1), axis=1)[:, :2]
        np.testing.assert_array_equal(
            np.sort(np.asarray(res.topk), axis=1),
            np.sort(exact_top, axis=1))
