"""Sequential Col-Bandit (Algorithm 1) behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact_topk, overlap_at_k, run_bandit


def _make_h(seed=0, N=48, T=32, gap=0.25):
    rng = np.random.default_rng(seed)
    H = rng.uniform(0.2, 0.5, (N, T)).astype(np.float32)
    winners = rng.choice(N, 6, replace=False)
    H[winners] += gap
    return jnp.asarray(np.clip(H, 0, 1)), winners


def test_separated_with_hard_bounds_is_exact():
    """With alpha_ef -> conservative (radius never used: alpha huge makes
    hybrid fall back to hard bounds), separation is a deterministic
    certificate: the returned set MUST equal the exact top-K."""
    H, _ = _make_h(0)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    exact, _ = exact_topk(H, k=5)
    res = run_bandit(H, a, b, jax.random.key(0), k=5, alpha_ef=1e9)
    assert bool(res.separated)
    assert float(overlap_at_k(res.topk, exact)) == 1.0


def test_coverage_below_one_on_separable_instance():
    H, _ = _make_h(1)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    res = run_bandit(H, a, b, jax.random.key(0), k=5, alpha_ef=0.5)
    assert float(res.coverage) < 1.0
    assert bool(res.separated)


def test_full_budget_recovers_exact():
    """Even on an inseparable instance (tiny gaps), exhausting the budget
    must end with the exact ranking (all cells revealed)."""
    rng = np.random.default_rng(2)
    H = jnp.asarray(rng.uniform(0.4, 0.6, (16, 8)).astype(np.float32))
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    exact, _ = exact_topk(H, k=3)
    res = run_bandit(H, a, b, jax.random.key(0), k=3, alpha_ef=1e9,
                     epsilon=0.0)
    assert float(overlap_at_k(res.topk, exact)) == 1.0


def test_alpha_monotone_coverage():
    """Smaller alpha_ef => tighter radius => less coverage (Sec. 4.4)."""
    H, _ = _make_h(3)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    covs = []
    for alpha in (0.1, 1.0, 3.0):
        res = run_bandit(H, a, b, jax.random.key(0), k=5, alpha_ef=alpha)
        covs.append(float(res.coverage))
    assert covs[0] <= covs[1] + 0.05
    assert covs[1] <= covs[2] + 0.05


def test_doc_mask_excludes_padding():
    H, _ = _make_h(4, N=32)
    pad = jnp.arange(32) < 24
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    res = run_bandit(H, a, b, jax.random.key(0), k=5, alpha_ef=0.5,
                     doc_mask=pad)
    assert all(int(i) < 24 for i in np.asarray(res.topk))
    # padded docs never revealed
    assert not np.asarray(res.revealed)[24:].any()


def test_warmup_fraction_reveals_upfront():
    H, _ = _make_h(5)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    res = run_bandit(H, a, b, jax.random.key(0), k=5, alpha_ef=1e9,
                     warmup_fraction=0.5, init_one_per_doc=False)
    assert float(res.coverage) >= 0.5


def test_prereveal_counts_as_observed():
    H, _ = _make_h(6)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    pre = jnp.zeros(H.shape, bool).at[:, :4].set(True)
    res = run_bandit(H, a, b, jax.random.key(0), k=5, alpha_ef=0.5,
                     init_one_per_doc=False, prereveal=pre)
    assert np.asarray(res.revealed)[:, :4].all()


def test_deterministic_given_key():
    H, _ = _make_h(7)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    r1 = run_bandit(H, a, b, jax.random.key(42), k=5, alpha_ef=0.5)
    r2 = run_bandit(H, a, b, jax.random.key(42), k=5, alpha_ef=0.5)
    assert int(r1.reveals) == int(r2.reveals)
    np.testing.assert_array_equal(np.asarray(r1.topk), np.asarray(r2.topk))
