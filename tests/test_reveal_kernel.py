"""ISSUE 5 tentpole: the fused reveal-round kernel (repro.kernels.reveal).

Contracts:
  * value parity with the gather_maxsim oracle (the fused kernel computes
    the same MaxSim cells, it just keeps them in VMEM);
  * statistic parity with ``_apply_block_reveal``'s arithmetic: the
    in-kernel [dn, dtotal, dtotal_sq] rows equal the scatter chain's
    per-row increments, with already-revealed/padded cells contributing 0;
  * both kernel layouts (scalar-prefetch in-kernel gather, block_b == 1,
    and the pre-gathered wide-row layout) match the ref oracle;
  * odd shapes exercise the ops-level padding, stacked query-offset
    indices exercise the pooled frontier's cell contract, and bf16 inputs
    accumulate in f32.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import fused_reveal_op, gather_maxsim_op
from repro.kernels.reveal import STATS_USED, fused_reveal


def _inputs(N, L, M, T, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    E = rng.standard_normal((N, L, M)).astype(np.float32)
    lens = rng.integers(1, L + 1, N)
    mask = np.arange(L)[None] < lens[:, None]
    E = np.where(mask[..., None], E, 0.0)
    Q = rng.standard_normal((T, M)).astype(np.float32)
    return jnp.asarray(E, dtype), jnp.asarray(mask), jnp.asarray(Q, dtype)


def _sel(rng, N, T, F, G):
    di = jnp.asarray(rng.integers(0, N, F), jnp.int32)
    ti = jnp.asarray(rng.integers(0, T, (F, G)), jnp.int32)
    nm = jnp.asarray(rng.random((F, G)) > 0.35)
    return di, ti, nm


SHAPES = [
    (8, 64, 128, 32, 8, 4),      # aligned
    (13, 37, 128, 11, 5, 3),     # odd everything (pad path active)
    (7, 129, 128, 5, 9, 2),      # L just past one block
]


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("shape", SHAPES)
def test_fused_reveal_matches_ref(impl, shape, monkeypatch):
    N, L, M, T, F, G = shape
    E, mask, Q = _inputs(N, L, M, T, seed=1)
    di, ti, nm = _sel(np.random.default_rng(2), N, T, F, G)
    want_v, want_s = ref.fused_reveal_ref(E, mask, Q, di, ti, nm)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    v, s = fused_reveal_op(E, mask, Q, di, ti, nm, block_b=4, block_l=32)
    np.testing.assert_allclose(np.asarray(v), np.asarray(want_v), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s), atol=1e-4)


def test_fused_stats_match_apply_block_reveal_arithmetic():
    """The kernel's stat rows must be exactly what the scatter chain adds:
    sum(new), sum(new * v), sum(new * v * v) per selection row."""
    N, L, M, T, F, G = 10, 48, 128, 9, 7, 4
    E, mask, Q = _inputs(N, L, M, T, seed=3)
    di, ti, nm = _sel(np.random.default_rng(4), N, T, F, G)
    v, s = fused_reveal_op(E, mask, Q, di, ti, nm)
    vv, nn, ss = np.asarray(v), np.asarray(nm), np.asarray(s)
    np.testing.assert_allclose(ss[:, 0], nn.sum(-1))
    np.testing.assert_allclose(ss[:, 1], (vv * nn).sum(-1), atol=1e-5)
    np.testing.assert_allclose(ss[:, 2], (vv * vv * nn).sum(-1), rtol=1e-5)
    assert s.shape == (F, STATS_USED)


@pytest.mark.parametrize("gather", [True, False])
def test_fused_kernel_layouts_agree(gather):
    """Scalar-prefetch in-kernel gather (block_b=1, the TPU layout) and the
    pre-gathered wide-row layout compute identical outputs."""
    N, L, M, T, F, G = 6, 32, 16, 8, 8, 3
    E, mask, Q = _inputs(N, L, M, T, seed=5)
    di, ti, nm = _sel(np.random.default_rng(6), N, T, F, G)
    q_sel = jnp.take(Q, ti, axis=0)
    if gather:
        v, s = fused_reveal(E, mask, q_sel, nm, di, block_l=16,
                            gather=True, interpret=True)
    else:
        v, s = fused_reveal(jnp.take(E, di, axis=0), jnp.take(mask, di, 0),
                            q_sel, nm, di, block_b=4, block_l=16,
                            gather=False, interpret=True)
    want_v, want_s = ref.fused_reveal_ref(E, mask, Q, di, ti, nm)
    np.testing.assert_allclose(np.asarray(v), np.asarray(want_v), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s)[:, :STATS_USED],
                               np.asarray(want_s), atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_fused_stacked_offset_parity(impl, monkeypatch):
    """Query-offset indices into stacked (Q*N, L, M)/(Q*T, M) tensors —
    the exact indexing the pooled frontier's fused round emits."""
    rng = np.random.default_rng(7)
    Bq, N, L, M, T = 3, 8, 48, 128, 6
    parts = [_inputs(N, L, M, T, seed=10 + i) for i in range(Bq)]
    E = jnp.concatenate([p[0] for p in parts])
    mask = jnp.concatenate([p[1] for p in parts])
    Q = jnp.concatenate([p[2] for p in parts])
    S, G = 7, 3
    qid = rng.integers(0, Bq, S)
    di = jnp.asarray(qid * N + rng.integers(0, N, S), jnp.int32)
    ti = jnp.asarray(qid[:, None] * T + rng.integers(0, T, (S, G)),
                     jnp.int32)
    nm = jnp.asarray(rng.random((S, G)) > 0.3)
    want_v, want_s = ref.fused_reveal_ref(E, mask, Q, di, ti, nm)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    v, s = fused_reveal_op(E, mask, Q, di, ti, nm, block_b=4, block_l=16)
    np.testing.assert_allclose(np.asarray(v), np.asarray(want_v), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s), atol=1e-4)


def test_fused_all_masked_documents_no_nan():
    """All-masked docs yield the _NEG sentinel value; with new_mask False
    on those rows the stats must stay exactly 0 — never NaN from squaring
    the sentinel out of f32 range."""
    N, L, M, T = 8, 40, 128, 7
    E, mask, Q = _inputs(N, L, M, T, seed=8)
    mask = jnp.asarray(np.asarray(mask).copy()).at[jnp.asarray([1, 5])].set(
        False)
    di = jnp.asarray([1, 5, 0, 3], jnp.int32)
    ti = jnp.asarray(np.random.default_rng(9).integers(0, T, (4, 2)),
                     jnp.int32)
    nm = jnp.asarray([[False, False], [False, False], [True, True],
                      [True, False]])
    v, s = fused_reveal_op(E, mask, Q, di, ti, nm, block_b=2, block_l=16)
    v, s = np.asarray(v), np.asarray(s)
    assert (v[:2] < -1e37).all()                   # dead rows hit _NEG
    assert np.isfinite(s).all()
    np.testing.assert_array_equal(s[:2], 0.0)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_fused_bf16_inputs_f32_accumulation(impl, monkeypatch):
    """bf16 embeddings/queries: outputs are f32 and match the f32 ref on
    the f32-cast inputs (both paths cast before the contraction)."""
    N, L, M, T, F, G = 9, 63, 128, 17, 6, 4     # L one short of a block
    E, mask, Q = _inputs(N, L, M, T, dtype=jnp.bfloat16, seed=11)
    di, ti, nm = _sel(np.random.default_rng(12), N, T, F, G)
    want_v, want_s = ref.fused_reveal_ref(
        E.astype(jnp.float32), mask, Q.astype(jnp.float32), di, ti, nm)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    v, s = fused_reveal_op(E, mask, Q, di, ti, nm, block_b=4, block_l=32)
    assert v.dtype == jnp.float32 and s.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(v), np.asarray(want_v), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s), atol=1e-4)


def test_fused_values_match_gather_maxsim_op(monkeypatch):
    """The fused op's value plane is the gather_maxsim op, bit-for-bit in
    the same dispatch mode — fusion adds the stats, never changes cells."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    N, L, M, T, F, G = 12, 80, 128, 10, 9, 3
    E, mask, Q = _inputs(N, L, M, T, seed=13)
    di, ti, nm = _sel(np.random.default_rng(14), N, T, F, G)
    v, _ = fused_reveal_op(E, mask, Q, di, ti, nm, block_b=4, block_l=32)
    want = gather_maxsim_op(E, mask, Q, di, ti, block_b=4, block_l=32)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(want))


def test_fused_row_mismatch_raises():
    E, mask, Q = _inputs(4, 16, 8, 4, seed=15)
    with pytest.raises(ValueError, match="fused_reveal_op"):
        fused_reveal_op(E, mask, Q, jnp.zeros((3,), jnp.int32),
                        jnp.zeros((4, 2), jnp.int32),
                        jnp.ones((4, 2), jnp.bool_))
