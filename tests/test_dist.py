"""Distribution primitives: multi-device tests run in a subprocess with 8
host placeholder devices (tests themselves must keep the default 1-device
world — see conftest)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_in_subprocess as _run_subprocess
from repro.dist import sharding as SH


def test_ring_matmul_matches_direct():
    out = _run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import ring_matmul
    mesh = jax.make_mesh((8,), ("x",))
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    f = jax.shard_map(lambda xs, w: ring_matmul(xs, w, "x"), mesh=mesh,
                      in_specs=(P("x", None), P(None, None)),
                      out_specs=P(None, None), check_vma=False)
    got = f(X, W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(X @ W),
                               rtol=1e-5, atol=1e-5)
    print("RING_OK")
    """)
    assert "RING_OK" in out


def test_int8_psum_compression():
    out = _run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.train.compression import init_error_buffer, int8_psum
    mesh = jax.make_mesh((8,), ("x",))
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)

    def f(g_local):
        grads = {"w": g_local}              # (1, 64) local shard
        err = init_error_buffer(grads)
        out, err2 = int8_psum(grads, err, "x")
        return out["w"], err2["w"]

    got, err = jax.shard_map(f, mesh=mesh, in_specs=P("x", None),
                             out_specs=(P(None, None), P("x", None)),
                             check_vma=False)(g)
    got = got[0]
    want = np.asarray(g).mean(0)
    # int8 quantization: ~1% of the max-scale absolute error
    scale = np.abs(np.asarray(g)).max() / 127
    np.testing.assert_allclose(np.asarray(got), want, atol=2 * scale)
    # error feedback buffer holds the residual
    assert np.abs(np.asarray(err)).max() <= scale + 1e-6
    print("INT8_OK")
    """)
    assert "INT8_OK" in out


def test_topk_sparsify_error_feedback():
    from repro.train.compression import init_error_buffer, topk_sparsify
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(100),
                          jnp.float32)}
    err = init_error_buffer(g)
    kept, err2 = topk_sparsify(g, err, frac=0.1)
    nz = int(jnp.sum(kept["w"] != 0))
    assert nz == 10
    # kept + residual reconstructs the input
    np.testing.assert_allclose(np.asarray(kept["w"] + err2["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_param_spec_rules_cover_lm_tree():
    """Every leaf of every assigned LM arch gets a divisible PartitionSpec
    on BOTH production meshes (pure-python divisibility check)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.models.transformer import init_lm

    mesh_shapes = [
        {"data": 16, "model": 16},
        {"pod": 2, "data": 16, "model": 16},
    ]

    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape
            self.axis_names = tuple(shape)

    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if cfg.family != "lm":
            continue
        abs_params = jax.eval_shape(
            lambda: init_lm(jax.random.key(0), cfg, dtype=jnp.bfloat16))
        for ms in mesh_shapes:
            mesh = FakeMesh(ms)
            specs = SH.specs_from_rules(abs_params, SH.lm_param_rules(mesh))
            flat, _ = jax.tree_util.tree_flatten_with_path(abs_params)
            sflat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
            for (path, leaf), spec in zip(flat, sflat):
                for dim, part in zip(leaf.shape, tuple(spec)):
                    if part is None:
                        continue
                    axes = part if isinstance(part, tuple) else (part,)
                    total = int(np.prod([ms[a] for a in axes]))
                    assert dim % total == 0, (
                        f"{arch} {jax.tree_util.keystr(path)} dim {dim} "
                        f"not divisible by {total} ({spec})")


def test_int8_rs_ag_wire_efficient_allreduce():
    """The production int8 collective: int8 on the wire both directions."""
    out = _run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.train.compression import init_error_buffer, int8_rs_ag
    mesh = jax.make_mesh((8,), ("x",))
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)

    def f(g_local):
        grads = {"w": g_local}
        err = init_error_buffer(grads)
        out, err2 = int8_rs_ag(grads, err, "x")
        return out["w"], err2["w"]

    got, err = jax.shard_map(f, mesh=mesh, in_specs=P("x", None),
                             out_specs=(P(None, None), P("x", None)),
                             check_vma=False)(g)
    want = np.asarray(g).mean(0)
    scale = np.abs(np.asarray(g)).max() / 127
    # two quantizations => up to ~3 quantization steps of error
    np.testing.assert_allclose(np.asarray(got[0]), want, atol=3 * scale)
    print("RSAG_OK")
    """)
    assert "RSAG_OK" in out


def test_compressed_train_step_converges():
    """int8-gradient training must still optimize (error feedback works)."""
    import jax.numpy as jnp
    from repro.configs.base import LMConfig
    from repro.models.transformer import init_lm
    from repro.train.optimizer import adamw
    from repro.train.compressed_step import (init_compressed_state,
                                             make_compressed_lm_train_step)
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   d_head=16, d_ff=64, vocab=128)
    mesh = jax.make_mesh((1,), ("data",))
    opt = adamw(1e-3)
    state = init_compressed_state(init_lm(jax.random.key(0), cfg), opt)
    step = jax.jit(make_compressed_lm_train_step(cfg, opt, mesh))
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, 128)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    losses = []
    for _ in range(25):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
    assert np.isfinite(losses).all()
