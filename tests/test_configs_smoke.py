"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family (small width/depth, few experts, tiny tables, small graphs) runs one
forward/train step on CPU, asserting output shapes and no NaNs. The FULL
configs are exercised only via the dry-run (launch/dryrun.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig
from repro.models import gnn as G
from repro.models import recsys as R
from repro.models.transformer import forward_train, init_lm
from repro.train.optimizer import adamw
from repro.train.train_step import (TrainState, make_gnn_train_step,
                                    make_lm_train_step,
                                    make_recsys_train_step)

KEY = jax.random.key(0)


def _reduced_lm(cfg: LMConfig) -> LMConfig:
    """Shrink while keeping the arch's structural features (MoE/SWA/
    local-global/softcaps/QKV-bias) intact."""
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 4) // (1 if cfg.n_kv_heads < 4 else 1)),
        d_head=16, d_ff=0 if cfg.moe else 128, vocab=512,
        moe_d_ff=96 if cfg.moe else 0,
        n_experts=4 if cfg.moe else 0,
        experts_top_k=min(2, cfg.experts_top_k) if cfg.moe else 0,
        sliding_window=8 if cfg.sliding_window else None)


def _no_nans(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(tree)
               if np.issubdtype(np.asarray(x).dtype, np.floating))


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).family == "lm"])
def test_lm_arch_smoke(arch):
    full = get_config(arch)
    cfg = _reduced_lm(full)
    # structural features preserved
    assert cfg.moe == full.moe
    assert cfg.local_global_alternating == full.local_global_alternating
    assert cfg.qkv_bias == full.qkv_bias
    assert (cfg.sliding_window is None) == (full.sliding_window is None)

    params = init_lm(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    logits = forward_train(params, cfg, tokens, remat=False)
    assert logits.shape == (2, 16, cfg.vocab)
    assert _no_nans(logits)

    opt = adamw(1e-3)
    state = TrainState(params=params, opt=opt.init(params))
    step = jax.jit(make_lm_train_step(cfg, opt))
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _no_nans(state.params)


def test_pna_arch_smoke():
    full = get_config("pna")
    cfg = dataclasses.replace(full, n_layers=2, d_hidden=16, n_classes=5)
    assert cfg.aggregators == full.aggregators     # all 4 aggregators
    assert cfg.scalers == full.scalers             # all 3 scalers
    params = G.init_pna(KEY, cfg, d_feat=12)
    batch = G.random_graph(48, 128, 12, 5, seed=0)
    logits = G.pna_forward(params, cfg, batch)
    assert logits.shape == (48, 5)
    assert _no_nans(logits)

    opt = adamw(1e-3)
    state = TrainState(params=params, opt=opt.init(params))
    step = jax.jit(make_gnn_train_step(cfg, opt))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _no_nans(state.params)


def _reduced_recsys(cfg: RecsysConfig) -> RecsysConfig:
    kw = dict(vocab_sizes=tuple(64 + 5 * i for i in range(cfg.n_sparse))
              if cfg.n_sparse else (), item_vocab=256 if cfg.item_vocab else 0,
              seq_len=min(cfg.seq_len, 12) if cfg.seq_len else 0)
    return dataclasses.replace(cfg, **kw)


@pytest.mark.parametrize("arch", ["autoint", "sasrec", "din", "fm"])
def test_recsys_arch_smoke(arch):
    full = get_config(arch)
    cfg = _reduced_recsys(full)
    assert cfg.interaction == full.interaction

    key = KEY
    if cfg.interaction == "fm-2way":
        params = R.init_fm(key, cfg)
        batch = {"ids": jax.random.randint(key, (8, cfg.n_sparse), 0, 64)}
    elif cfg.interaction == "self-attn":
        params = R.init_autoint(key, cfg)
        batch = {"ids": jax.random.randint(key, (8, cfg.n_sparse), 0, 64)}
    else:
        params = (R.init_din(key, cfg) if cfg.interaction == "target-attn"
                  else R.init_sasrec(key, cfg))
        batch = {"hist_ids": jax.random.randint(key, (8, cfg.seq_len), 0, 256),
                 "hist_mask": jnp.ones((8, cfg.seq_len), bool),
                 "target_ids": jax.random.randint(key, (8,), 0, 256)}
    from repro.train.train_step import recsys_forward
    logits = recsys_forward(params, cfg, batch)
    assert logits.shape == (8,)
    assert _no_nans(logits)

    opt = adamw(1e-3)
    state = TrainState(params=params, opt=opt.init(params))
    step = jax.jit(make_recsys_train_step(cfg, opt))
    batch["labels"] = jnp.asarray(np.random.default_rng(0).integers(0, 2, 8),
                                  jnp.float32)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _no_nans(state.params)


def test_all_assigned_archs_registered():
    assert len(ASSIGNED_ARCHS) == 10
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        assert len(cfg.shapes) == 4        # 4 cells per arch = 40 total
