"""Unit + property tests for the decision bounds (paper Sec. 4.2, App. A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bounds as B


def test_rho_n_collapses_at_full_observation():
    T = 32
    n = jnp.arange(0, T + 1)
    rho = B.rho_n(n, T)
    assert float(rho[T]) == pytest.approx(0.0, abs=1e-7)   # Eq.18: n=T -> 0
    assert float(rho[1]) == pytest.approx(1.0, abs=1e-6)   # n=1 -> 1


def test_rho_n_piecewise_continuity():
    # the two branches of Eq. 18 should roughly agree at n = T/2
    T = 64
    lo = float(B.rho_n(jnp.asarray(T // 2), T))
    hi = float(B.rho_n(jnp.asarray(T // 2 + 1), T))
    assert abs(lo - hi) < 0.1


def test_radius_infinite_below_two_samples():
    r = B.serfling_radius(jnp.ones(3), jnp.asarray([0, 1, 2]), T=16, N=3,
                          delta=0.01, alpha_ef=1.0)
    assert np.isinf(np.asarray(r)[:2]).all()
    assert np.isfinite(np.asarray(r)[2])


def test_radius_zero_at_full_row():
    r = B.serfling_radius(jnp.ones(1), jnp.asarray([16]), T=16, N=1,
                          delta=0.01, alpha_ef=1.0)
    assert float(r[0]) == pytest.approx(0.0, abs=1e-6)


def test_radius_scales_with_alpha():
    n = jnp.asarray([8])
    r1 = B.serfling_radius(jnp.ones(1), n, T=16, N=4, delta=0.01, alpha_ef=1.0)
    r2 = B.serfling_radius(jnp.ones(1), n, T=16, N=4, delta=0.01, alpha_ef=0.25)
    assert float(r2[0]) == pytest.approx(0.25 * float(r1[0]), rel=1e-6)


@given(st.integers(2, 31), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_hard_bounds_always_contain_truth(n_obs, seed):
    """Eq. 10/11: LB <= S <= UB for any observation subset (deterministic)."""
    rng = np.random.default_rng(seed)
    N, T = 8, 32
    H = rng.uniform(0, 1, (N, T)).astype(np.float32)
    revealed = np.zeros((N, T), bool)
    for i in range(N):
        idx = rng.choice(T, n_obs, replace=False)
        revealed[i, idx] = True
    total = (H * revealed).sum(-1)
    a = np.zeros((N, T), np.float32)
    b = np.ones((N, T), np.float32)
    lb, ub = B.hard_bounds(jnp.asarray(total), jnp.asarray(revealed),
                           jnp.asarray(a), jnp.asarray(b))
    S = H.sum(-1)
    assert (np.asarray(lb) <= S + 1e-5).all()
    assert (np.asarray(ub) >= S - 1e-5).all()


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_intervals_tighten_with_more_observations(seed):
    rng = np.random.default_rng(seed)
    N, T = 4, 32
    H = rng.uniform(0, 1, (N, T)).astype(np.float32)
    a = jnp.zeros((N, T)); b = jnp.ones((N, T))
    widths = []
    for n_obs in (4, 16, 32):
        revealed = np.zeros((N, T), bool)
        revealed[:, :n_obs] = True
        total = (H * revealed).sum(-1)
        total_sq = ((H ** 2) * revealed).sum(-1)
        iv = B.intervals(jnp.full((N,), n_obs), jnp.asarray(total),
                         jnp.asarray(total_sq), jnp.asarray(revealed), a, b,
                         T=T, N=N, delta=0.01, alpha_ef=1.0)
        widths.append(float(jnp.mean(iv.ucb - iv.lcb)))
    assert widths[0] >= widths[1] >= widths[2]
    assert widths[2] == pytest.approx(0.0, abs=1e-5)   # fully observed


def test_interval_at_full_observation_equals_exact_score():
    rng = np.random.default_rng(1)
    N, T = 4, 16
    H = rng.uniform(0, 1, (N, T)).astype(np.float32)
    revealed = np.ones((N, T), bool)
    iv = B.intervals(jnp.full((N,), T), jnp.asarray(H.sum(-1)),
                     jnp.asarray((H ** 2).sum(-1)), jnp.asarray(revealed),
                     jnp.zeros((N, T)), jnp.ones((N, T)),
                     T=T, N=N, delta=0.01, alpha_ef=1.0)
    np.testing.assert_allclose(np.asarray(iv.s_hat), H.sum(-1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(iv.lcb), H.sum(-1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(iv.ucb), H.sum(-1), rtol=1e-5)
