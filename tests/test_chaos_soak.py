"""ISSUE 8 chaos soak: 1k requests through a supervised mesh engine while
a replayable FaultPlan kills the dispatch thread, takes a shard down (and
back up) mid-run, and 1% of the corpus is NaN-poisoned.

Invariants under chaos — the whole point of the resilience layer:
zero lost and zero duplicated completions, no error completions, every
coverage in [0, 1], poisoned docs quarantined out of every top-K, all
served scores finite, and the watchdog/failover counters prove the
faults actually fired. A second case pins the determinism contract: an
EMPTY FaultPlan is byte-identical to no plan at all.

Mesh cases run in device subprocesses (tests/_subproc.py). The soak is
sized for CI (dense flavor, small corpus): ~125 batches end to end.
"""
import pytest

from _subproc import run_in_subprocess

# Enforced by pytest-timeout in the CI chaos lane; inert without the plugin.
pytestmark = pytest.mark.timeout(420)

_SOAK = """
import numpy as np
from repro.dist.fault import FaultPlan, InjectedFault, poison_corpus
from repro.serve import AsyncRetrievalEngine, EngineConfig, Request

rng = np.random.default_rng(0)
C, L, M, T, N = 47, 6, 8, 8, 1000
embs = rng.standard_normal((C, L, M)).astype(np.float32)
embs /= np.linalg.norm(embs, axis=-1, keepdims=True)
mask = np.arange(L)[None] < rng.integers(3, L + 1, C)[:, None]
qs = rng.standard_normal((32, T, M)).astype(np.float32)
qs /= np.linalg.norm(qs, axis=-1, keepdims=True)

poisoned, rows = poison_corpus(embs, 0.01, seed=11, mode="nan")
bad = int(np.flatnonzero(rows)[0])

# One thread kill and one temporary shard outage, all mid-stream; the
# plan is a pure value -- rerunning this file replays it exactly. The
# dispatch loop ticks at least once per batch (N/batch_size = 125), so
# every fault is guaranteed to fire before the stream drains.
plan = FaultPlan([
    InjectedFault(point="dispatch", at=15, action="kill"),
    InjectedFault(point="dispatch", at=40, action="shard_down", arg=1),
    InjectedFault(point="dispatch", at=80, action="shard_up", arg=1),
])
eng = AsyncRetrievalEngine(poisoned, mask, EngineConfig(
    batch_size=8, deadline_s=0.05, token_buckets=(8,), cand_buckets=(16,),
    max_k=5, flavor="dense", pipeline_depth=2, supervise=True,
    max_thread_restarts=2, mesh_axes=(("data", 2), ("model", 2))),
    fault_plan=plan)
eng.warmup()
with eng:
    for i in range(N):
        cand = rng.choice(C, 16, replace=False).astype(np.int32)
        if i % 10 == 0 and bad not in cand:
            cand[0] = bad               # keep the poisoned doc in play
        eng.submit(Request(query=qs[i % 32], k=5, cand_ids=cand))
    done = eng.drain()

rids = [c.rid for c in done]
assert sorted(rids) == list(range(N)), "lost completions"
assert len(set(rids)) == N, "duplicated completions"
assert all(c.error is None for c in done)
for c in done:
    assert 0.0 <= c.coverage <= 1.0, c.coverage
    assert bad not in c.topk_ids.tolist(), (c.rid, c.topk_ids)
    real = c.topk_scores[c.topk_ids >= 0]
    assert np.isfinite(real).all(), (c.rid, c.topk_scores)

s = eng.metrics.summary()
assert s["errors"] == 0
assert s["thread_restarts"].get("repro-dispatch", 0) >= 1, s
assert s["failovers"] >= 1, s
assert s["quarantined_total"] > 0, s
assert s["shard_healthy"] == [True] * 4          # outage was restored
fired = [f.action for f in plan.fired]
assert fired == ["kill", "shard_down", "shard_up"], fired
print("SOAK_OK", len(done))
"""

_EMPTY_PLAN_PARITY = """
import numpy as np
from repro.dist.fault import FaultPlan
from repro.serve import AsyncRetrievalEngine, EngineConfig, Request

rng = np.random.default_rng(1)
C, L, M, T, N = 47, 6, 8, 8, 64
embs = rng.standard_normal((C, L, M)).astype(np.float32)
embs /= np.linalg.norm(embs, axis=-1, keepdims=True)
mask = np.arange(L)[None] < rng.integers(3, L + 1, C)[:, None]
qs = rng.standard_normal((16, T, M)).astype(np.float32)
qs /= np.linalg.norm(qs, axis=-1, keepdims=True)
cands = [rng.choice(C, 16, replace=False).astype(np.int32)
         for _ in range(N)]

def run(plan):
    eng = AsyncRetrievalEngine(embs, mask, EngineConfig(
        batch_size=8, deadline_s=0.05, token_buckets=(8,),
        cand_buckets=(16,), max_k=5, flavor="bandit", alpha_ef=0.3,
        block_docs=4, block_tokens=2, supervise=True,
        mesh_axes=(("data", 2), ("model", 2))), fault_plan=plan)
    eng.warmup()
    with eng:
        for i in range(N):
            eng.submit(Request(query=qs[i % 16], k=5, cand_ids=cands[i]))
        return {c.rid: c for c in eng.drain()}

a = run(FaultPlan())                    # empty plan: must be inert
b = run(None)
assert sorted(a) == sorted(b) == list(range(N))
for rid in a:
    np.testing.assert_array_equal(a[rid].topk_ids, b[rid].topk_ids)
    np.testing.assert_array_equal(a[rid].topk_scores, b[rid].topk_scores)
    assert a[rid].coverage == b[rid].coverage == 1.0
print("EMPTY_PLAN_OK")
"""


def test_chaos_soak_1k_requests_zero_lost_zero_dup():
    out = run_in_subprocess(_SOAK, n_devices=4)
    assert "SOAK_OK 1000" in out


def test_empty_fault_plan_is_bit_identical_to_no_plan():
    out = run_in_subprocess(_EMPTY_PLAN_PARITY, n_devices=4)
    assert "EMPTY_PLAN_OK" in out


def test_recorder_sanitized_soak_no_undeclared_shared_state():
    """Mini soak under the runtime thread-access sanitizer
    (repro.analysis.recorder): a supervised engine serving through a
    thread kill must touch NO cross-thread attribute outside the
    GUARDED_BY discipline the static lockset pass verifies — the dynamic
    half of the ISSUE 9 race lint."""
    import numpy as np
    from repro.analysis.recorder import ThreadAccessRecorder
    from repro.dist.fault import FaultPlan, InjectedFault
    from repro.serve import AsyncRetrievalEngine, EngineConfig, Request
    from repro.serve import engine as engine_mod

    rng = np.random.default_rng(3)
    C, L, M, T, N = 47, 6, 8, 8, 48
    embs = rng.standard_normal((C, L, M)).astype(np.float32)
    mask = np.ones((C, L), bool)
    qs = rng.standard_normal((8, T, M)).astype(np.float32)
    plan = FaultPlan([InjectedFault(point="dispatch", at=3, action="kill")])
    eng = AsyncRetrievalEngine(embs, mask, EngineConfig(
        batch_size=8, deadline_s=0.02, token_buckets=(8,),
        cand_buckets=(16,), max_k=4, flavor="dense", supervise=True,
        max_thread_restarts=2), fault_plan=plan)
    eng.warmup()
    rec = ThreadAccessRecorder(eng, declared=set(engine_mod.GUARDED_BY))
    with rec:
        with eng:
            for i in range(N):
                cand = rng.choice(C, 16, replace=False).astype(np.int32)
                eng.submit(Request(query=qs[i % 8], k=4, cand_ids=cand))
            done = eng.drain()
    assert sorted(c.rid for c in done) == list(range(N))
    assert [f.action for f in plan.fired] == ["kill"]
    assert rec.violations() == [], rec.violations()
    # The soak genuinely crossed threads on guarded state (not vacuous).
    assert "_completed" in rec.shared()
