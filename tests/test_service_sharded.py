"""ISSUE 4: mesh-sharded corpus serving.

Every rerank flavor served from a corpus sharded over a real (virtual CPU)
mesh must return the identical top-K set as its single-device counterpart —
including on a RAGGED corpus whose tail shard owns fewer (or zero) docs.
Multi-device programs run in a subprocess with 4 host placeholder devices
(tests/_subproc.py), keeping the main pytest process single-device;
REPRO_KERNEL_IMPL is forwarded so CI's ref/interpret lanes reach the
shard_map paths.
"""
import numpy as np

from _subproc import run_in_subprocess

# Shared preamble: a ragged toy corpus (C=41 over 4 shards -> c_loc=11,
# valid=[11, 11, 11, 8]) + per-query candidate lists and their routed
# per-shard layouts on both a 4-shard mesh and the 1-device reference mesh.
_SETUP = """
import jax, jax.numpy as jnp, numpy as np
from repro.retrieval.service import (make_rerank_budgeted_step,
                                     make_rerank_dense_step,
                                     make_rerank_two_phase_step)
from repro.retrieval.sharded import (route_aligned, route_candidates,
                                     shard_corpus)

rng = np.random.default_rng(0)
C, L, M, B, T, N = 41, 12, 16, 4, 8, 16
emb = rng.standard_normal((C, L, M)).astype(np.float32)
emb /= np.linalg.norm(emb, axis=-1, keepdims=True)
msk = np.arange(L)[None] < rng.integers(4, L + 1, C)[:, None]
q_np = rng.standard_normal((B, T, M)).astype(np.float32)
q_np /= np.linalg.norm(q_np, axis=-1, keepdims=True)   # cells land in [-1, 1]
q = jnp.asarray(q_np)
cand = np.stack([rng.choice(C, N, replace=False)
                 for _ in range(B)]).astype(np.int32)

mesh4 = jax.make_mesh((2, 2), ("data", "model"))
mesh1 = jax.make_mesh((1,), ("data",))
sc = shard_corpus(emb, msk, mesh4)
assert (sc.n_shards, sc.docs_per_shard) == (4, 11)
assert list(sc.valid_docs) == [11, 11, 11, 8]
cand_l4 = route_candidates(cand, sc.docs_per_shard, sc.n_shards)
cand_l1 = cand[:, None, :]                    # 1 shard: slots == global ids
vd4 = sc.valid_docs


def check_topk(got_s, got_i, want_s, want_i, label):
    got_s, got_i = np.asarray(got_s), np.asarray(got_i)
    want_s, want_i = np.asarray(want_s), np.asarray(want_i)
    for b in range(got_i.shape[0]):
        assert set(got_i[b]) == set(want_i[b]), (label, b, got_i[b], want_i[b])
        np.testing.assert_allclose(np.sort(got_s[b]), np.sort(want_s[b]),
                                   atol=1e-4, err_msg=f"{label} q{b}")
"""


def test_dense_budgeted_two_phase_sharded_match_single_device():
    """Dense, full-budget budgeted, and exact-survivor two-phase steps on a
    4-shard ragged corpus reproduce the 1-device top-K exactly."""
    out = run_in_subprocess(_SETUP + """
# --- dense ---
d4 = make_rerank_dense_step(mesh4, topk=5, valid_docs=vd4)
d1 = make_rerank_dense_step(mesh1, topk=5)
s4, i4 = d4(sc.embs, sc.mask, q, jnp.asarray(cand_l4))
s1, i1 = d1(jnp.asarray(emb), jnp.asarray(msk), q, jnp.asarray(cand_l1))
check_topk(s4, i4, s1, i1, "dense")

# --- budgeted at full budget == dense ---
tok = np.broadcast_to(np.arange(T, dtype=np.int32)[None, None], (B, N, T))
tok_l4 = route_aligned(tok, cand, cand_l4, sc.docs_per_shard)
b4 = make_rerank_budgeted_step(mesh4, topk=5, tokens_per_doc=T,
                               valid_docs=vd4)
sb, ib = b4(sc.embs, sc.mask, q, jnp.asarray(cand_l4), jnp.asarray(tok_l4))
check_topk(sb, ib, s1, i1, "budgeted")

# --- two-phase with survivors == N_loc (phase 2 exact everywhere) ---
pooled = np.where(msk[:, :, None], emb, 0.0).mean(axis=1).astype(np.float32)
sc_p = shard_corpus(emb, msk, mesh4, pooled=pooled)
t4 = make_rerank_two_phase_step(mesh4, topk=5, survivors=N, valid_docs=vd4)
t1 = make_rerank_two_phase_step(mesh1, topk=5, survivors=N)
st4, it4 = t4(sc_p.embs, sc_p.mask, sc_p.pooled, q, jnp.asarray(cand_l4))
st1, it1 = t1(jnp.asarray(emb), jnp.asarray(msk), jnp.asarray(pooled), q,
              jnp.asarray(cand_l1))
check_topk(st4, it4, st1, it1, "two_phase")
print("FLAVORS_OK")
    """, n_devices=4)
    assert "FLAVORS_OK" in out


def test_sharded_pooled_bandit_matches_single_device():
    """Hard-bound mode (alpha_ef -> inf): the corpus-resident pooled-bandit
    shard_map flavor returns the identical top-K set as the single-device
    pooled engine AND the exact dense scores."""
    out = run_in_subprocess(_SETUP + """
from repro.retrieval.service import (make_rerank_bandit_step,
                                     rerank_bandit_step)

# valid per-cell support: normalized embeddings x normalized query tokens
a = jnp.full((B, N, T), -1.0, jnp.float32)
b = jnp.ones((B, N, T), jnp.float32)
a_l4 = route_aligned(np.asarray(a), cand, cand_l4, sc.docs_per_shard)
b_l4 = route_aligned(np.asarray(b), cand, cand_l4, sc.docs_per_shard)

step = make_rerank_bandit_step(mesh4, topk=5, alpha_ef=1e9, block_docs=4,
                               block_tokens=4, max_rounds=-1,
                               placement="corpus")
s4, i4, frac, stats = step(sc.embs, sc.mask, q, jnp.asarray(cand_l4),
                           jnp.asarray(a_l4), jnp.asarray(b_l4),
                           sc.valid_docs_device(), jnp.int32(0))
assert np.asarray(stats).shape == (4, 4)
assert (np.asarray(stats)[:, 3] == 0).all()   # clean corpus: no quarantine
assert ((np.asarray(frac) > 0) & (np.asarray(frac) <= 1)).all()

s1, i1, _, _ = rerank_bandit_step(
    jnp.asarray(emb), jnp.asarray(msk), q, jnp.asarray(cand), a, b,
    jax.random.key(0), topk=5, alpha_ef=1e9, block_docs=4, block_tokens=4)
check_topk(s4, i4, s1, i1, "bandit")

# dense exact reference, per query
d1 = make_rerank_dense_step(mesh1, topk=5)
sd, idd = d1(jnp.asarray(emb), jnp.asarray(msk), q, jnp.asarray(cand_l1))
check_topk(s4, i4, sd, idd, "bandit_vs_dense")
print("BANDIT_OK")
    """, n_devices=4)
    assert "BANDIT_OK" in out


def test_merge_scorecards_masks_pad_ids():
    """Regression (pad-id leakage): a shard with fewer than topk valid
    candidates ships -1-gid pad slots whose RAW scores (0.0 here) used to
    be gathered unmasked into the global top-K, beating genuinely negative
    real scores. One shard owns 0 candidates; all real scores are negative;
    the merge must still return only real ids, and -1 only for the
    shortfall beyond the number of real candidates."""
    out = run_in_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.retrieval.service import _merge_scorecards

mesh = jax.make_mesh((4,), ("x",))
B, NL, topk = 2, 3, 8
# shard s owns candidate gids {s*10 + j}; shard 3 owns NOTHING (all pads).
gids = np.full((B, 4, NL), -1, np.int32)
scores = np.zeros((B, 4, NL), np.float32)      # pads carry raw 0.0 scores
rng = np.random.default_rng(1)
for s in range(3):
    n_valid = [2, 3, 1][s]
    for j in range(n_valid):
        gids[:, s, j] = s * 10 + j
        scores[:, s, j] = -1.0 - rng.random((B,))   # all real scores < 0


def merged(sc, gd):
    return _merge_scorecards(sc[:, 0], gd[:, 0], ("x",), topk)


best, ids = jax.shard_map(
    merged, mesh=mesh, check_vma=False,
    in_specs=(P(None, "x", None), P(None, "x", None)),
    out_specs=(P(None, None), P(None, None)))(
        jnp.asarray(scores), jnp.asarray(gids))
best, ids = np.asarray(best), np.asarray(ids)
real = {0, 1, 10, 11, 12, 20}
for b in range(B):
    assert set(ids[b, :6]) == real, ids[b]          # no -1 pad beat a real
    assert (ids[b, 6:] == -1).all(), ids[b]         # genuine shortfall: -1
    assert (best[b, :6] < 0).all()                  # real (negative) scores
print("MERGE_OK")
    """, n_devices=4)
    assert "MERGE_OK" in out


def test_shard_global_ids_ragged_clamp_property():
    """Property over odd corpus sizes: with the ShardedCorpus valid_docs
    table, every genuine (shard, slot) maps to its unique global id —
    exactly a permutation of range(C) — and every padded-tail slot maps to
    -1 instead of aliasing a real doc."""
    out = run_in_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.retrieval.service import _shard_global_ids

mesh = jax.make_mesh((4,), ("x",))
for C in (5, 7, 13, 41, 42, 64):
    c_loc = -(-C // 4)
    valid = np.clip(C - c_loc * np.arange(4), 0, c_loc).astype(np.int32)
    slots = np.broadcast_to(np.arange(c_loc, dtype=np.int32),
                            (4, 1, c_loc)).copy()

    def gid_fn(cand, vd):
        return _shard_global_ids(cand, c_loc, ("x",), vd)

    gids = jax.shard_map(
        gid_fn, mesh=mesh, check_vma=False,
        in_specs=(P("x", None, None), P(None)),
        out_specs=P("x", None, None))(
            jnp.asarray(slots), jnp.asarray(valid))
    gids = np.asarray(gids).reshape(-1)
    kept = np.sort(gids[gids >= 0])
    assert kept.shape[0] == C, (C, kept)
    np.testing.assert_array_equal(kept, np.arange(C))   # no aliasing
    # unclamped legacy math WOULD alias: check the property is non-trivial
    if C % 4:
        n_pad = 4 * c_loc - C
        assert (gids == -1).sum() == n_pad
print("RAGGED_OK")
    """, n_devices=4)
    assert "RAGGED_OK" in out


def test_sharded_engine_zero_recompile_and_parity():
    """RetrievalEngine on a (2, 2) mesh: warmup pre-compiles every bucket,
    a mixed stream (provided + stage-1 candidates, both token buckets)
    serves with ZERO recompiles, per-shard metrics surface, and every
    completion's top-K matches the single-device engine bit-for-bit."""
    out = run_in_subprocess("""
import numpy as np
from repro.data.synthetic import make_retrieval_dataset
from repro.serve import EngineConfig, Request, RetrievalEngine

ds = make_retrieval_dataset(n_docs=47, n_queries=8, doc_len=16,
                            min_doc_len=6, query_len=16, dim=16, seed=3)
kw = dict(batch_size=4, deadline_s=0.5, token_buckets=(8, 16),
          cand_buckets=(16,), max_k=5, flavor="dense",
          stage1_candidates=16, stage1_kprime=4)
eng = RetrievalEngine(ds.doc_embs, ds.doc_mask,
                      EngineConfig(mesh_axes=(("data", 2), ("model", 2)),
                                   **kw))
solo = RetrievalEngine(ds.doc_embs, ds.doc_mask, EngineConfig(**kw))
assert eng.warmup() == solo.warmup()
rng = np.random.default_rng(0)
for i in range(8):
    n_tok = int(rng.integers(2, 17))
    cand = (rng.choice(47, int(rng.integers(5, 17)), replace=False)
            if i % 2 else None)
    for e in (eng, solo):
        e.submit(Request(query=ds.queries[i][:n_tok], k=5, cand_ids=cand))
got = {c.rid: c for c in eng.drain()}
want = {c.rid: c for c in solo.drain()}
assert len(got) == 8
for rid, c in got.items():
    assert set(c.topk_ids) == set(want[rid].topk_ids), rid
    np.testing.assert_allclose(np.sort(c.topk_scores),
                               np.sort(want[rid].topk_scores), atol=1e-4)
assert eng.metrics.compiles_after_warmup == 0
s = eng.metrics.summary()
assert s["n_shards"] == 4
assert len(s["shard_rounds_total"]) == 4
assert len(s["shard_occupancy_mean"]) == 4
print("ENGINE_OK")
    """, n_devices=4)
    assert "ENGINE_OK" in out


def test_sharded_engine_bandit_flavor_hard_bound_matches_dense():
    """Bandit flavor on the sharded engine (hard-bound mode): top-1 agrees
    with the sharded dense engine, reveal fraction lands in (0, 1], and the
    per-shard round counts show the frontier actually ran somewhere."""
    out = run_in_subprocess("""
import numpy as np
from repro.data.synthetic import make_retrieval_dataset
from repro.serve import EngineConfig, Request, RetrievalEngine

ds = make_retrieval_dataset(n_docs=47, n_queries=4, doc_len=16,
                            min_doc_len=6, query_len=8, dim=16, seed=3)
mesh = (("data", 2), ("model", 2))
kw = dict(batch_size=2, deadline_s=0.5, token_buckets=(8,),
          cand_buckets=(16,), max_k=5, stage1_candidates=16,
          stage1_kprime=4, mesh_axes=mesh)
bandit = RetrievalEngine(ds.doc_embs, ds.doc_mask,
                         EngineConfig(flavor="bandit", alpha_ef=1e9,
                                      block_docs=4, block_tokens=4, **kw))
dense = RetrievalEngine(ds.doc_embs, ds.doc_mask,
                        EngineConfig(flavor="dense", **kw))
cand = np.arange(16, dtype=np.int32)
for qi in (0, 1):
    q = ds.queries[qi][:8]
    bandit.submit(Request(query=q, k=5, cand_ids=cand))
    dense.submit(Request(query=q, k=5, cand_ids=cand))
got = {c.rid: c for c in bandit.drain()}
want = {c.rid: c for c in dense.drain()}
for rid, c in got.items():
    assert set(c.topk_ids) == set(want[rid].topk_ids), rid
    assert 0.0 < c.reveal_fraction <= 1.0
rec = bandit.metrics.batches[-1]
assert rec.shard_rounds is not None and sum(rec.shard_rounds) > 0
print("ENGINE_BANDIT_OK")
    """, n_devices=4)
    assert "ENGINE_BANDIT_OK" in out


def test_sharded_corpus_format_parity():
    """ISSUE 10: a quantized (int8) corpus sharded 4 ways returns the
    IDENTICAL top-K as the same-format 1-shard layout, for both serving
    flavors — the compressed payload decodes to the same f32 rows on
    every mesh, so sharding and quantization commute. Resident dtype and
    bytes are pinned too: the int8 corpus must ship as an s8 payload at
    >=3.5x less than the f32 dense bytes."""
    out = run_in_subprocess(_SETUP + """
from repro.kernels.quant import corpus_nbytes
from repro.retrieval.service import make_sharded_serving_step

a = np.full((B, N, T), -1.0, np.float32)      # valid unit-cosine support
bsup = np.ones((B, N, T), np.float32)
a_l4 = route_aligned(a, cand, cand_l4, sc.docs_per_shard)
b_l4 = route_aligned(bsup, cand, cand_l4, sc.docs_per_shard)
a_l1, b_l1 = a[:, None], bsup[:, None]
bf16_bytes = {}

for fmt in ("bf16", "int8"):
    sc4 = shard_corpus(emb, msk, mesh4, corpus_format=fmt)
    sc1 = shard_corpus(emb, msk, mesh1, corpus_format=fmt)
    bf16_bytes.setdefault(fmt, corpus_nbytes(sc4.embs))
    if fmt == "int8":
        assert str(sc4.embs.dtype) == str(sc1.embs.dtype) == "int8"
        # same padded doc count on both sides: 2x bf16 resident bytes is
        # the f32-dense equivalent the >=3.5x compression gate is against
        assert 2 * bf16_bytes["bf16"] / bf16_bytes["int8"] >= 3.5
    for flavor, kw in (("dense", {}),
                       ("bandit", dict(alpha_ef=1e9, block_docs=4,
                                       block_tokens=4, max_rounds=-1))):
        s4 = make_sharded_serving_step(mesh4, flavor, topk=5,
                                       corpus_format=fmt, **kw)
        s1 = make_sharded_serving_step(mesh1, flavor, topk=5,
                                       corpus_format=fmt, **kw)
        g4 = s4(sc4.embs, sc4.mask, q, jnp.asarray(cand_l4),
                jnp.asarray(a_l4), jnp.asarray(b_l4),
                sc4.valid_docs_device(), jnp.int32(0))
        g1 = s1(sc1.embs, sc1.mask, q, jnp.asarray(cand_l1),
                jnp.asarray(a_l1), jnp.asarray(b_l1),
                sc1.valid_docs_device(), jnp.int32(0))
        check_topk(g4[0], g4[1], g1[0], g1[1], f"{fmt}/{flavor}")

# cross-format fidelity: int8 dense scores track bf16 dense closely
d_bf = make_sharded_serving_step(mesh4, "dense", topk=5,
                                 corpus_format="bf16")
d_i8 = make_sharded_serving_step(mesh4, "dense", topk=5,
                                 corpus_format="int8")
sb = d_bf(shard_corpus(emb, msk, mesh4).embs, sc.mask, q,
          jnp.asarray(cand_l4), jnp.asarray(a_l4), jnp.asarray(b_l4),
          sc.valid_docs_device(), jnp.int32(0))
si = d_i8(shard_corpus(emb, msk, mesh4, corpus_format="int8").embs,
          sc.mask, q, jnp.asarray(cand_l4), jnp.asarray(a_l4),
          jnp.asarray(b_l4), sc.valid_docs_device(), jnp.int32(0))
np.testing.assert_allclose(np.sort(np.asarray(sb[0])),
                           np.sort(np.asarray(si[0])), atol=0.2)
print("FMT_PARITY_OK")
    """, n_devices=4)
    assert "FMT_PARITY_OK" in out
