"""ISSUE 10: compressed resident-corpus formats (repro.kernels.quant).

Four layers, mirroring the compression data path end to end:

  1. encoder round-trip — the symmetric int8 / centroid-residual encoders
     reconstruct within the per-row quantization step, including all-zero
     rows (scale 0 -> exact zeros) and near-f32-overflow rows (property
     sweep via hypothesis);
  2. structural helpers — gather / reshape / pad over a ``QuantTokens``
     corpus commute with full dequantization, and pad rows decode to
     values the token mask neutralizes;
  3. kernel parity — every scoring op fed a quantized corpus matches the
     same op on the dequantized f32 twin under BOTH dispatch impls,
     including ragged (non-multiple-of-block) shapes and all-masked-doc
     sentinels;
  4. engine + audit — a quantized ``RetrievalEngine`` warms with zero
     post-warmup recompiles per format, reproduces the bf16 engine's
     top-k, and its executables pass the ``hlo-int8-residency`` audit
     rule (which demonstrably fires on a dense corpus handed a lying
     spec, and on synthetic HLO).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import (fused_reveal_op, gather_maxsim_op,
                               maxsim_batch_op, maxsim_scores_op)
from repro.kernels.quant import (CORPUS_FORMATS, QuantTokens, corpus_format,
                                 corpus_nbytes, corpus_pad_to, corpus_take,
                                 dequantize, format_ordinal, quantize,
                                 quantize_int8, quantize_residual)


def _rows(N, L, M, seed=0, unit=True):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((N, L, M)).astype(np.float32)
    if unit:
        x /= np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)
    return x


def _codebook(M, Kc=4, seed=1):
    cb = np.random.default_rng(seed).standard_normal((Kc, M))
    cb /= np.linalg.norm(cb, axis=-1, keepdims=True)
    return cb.astype(np.float32)


def _roundtrip_bound(x, qt):
    """|x - decode| <= step/2 per element, where step is the (bf16-stored)
    per-row scale; 0.501 absorbs f32 division rounding in the encoder and
    the <=quarter-step clip slack when bf16 rounds the scale down."""
    err = np.abs(x - np.asarray(dequantize(qt), np.float32))
    s32 = np.asarray(qt.scales, np.float32)[..., None]
    assert (err <= 0.501 * s32 + 1e-6).all(), float(err.max())


# ---------------------------------------------------------------------------
# 1. encoder round-trip
# ---------------------------------------------------------------------------

@given(st.integers(1, 12), st.integers(1, 9), st.integers(1, 32),
       st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_property(N, L, M, seed):
    """Rows at wildly mixed magnitudes (1e-3 .. 1e3 per row) plus an
    explicit all-zero row and a near-f32-overflow row all round-trip
    within half a quantization step."""
    rng = np.random.default_rng(seed)
    x = _rows(N, L, M, seed, unit=False)
    x *= 10.0 ** rng.integers(-3, 4, (N, L, 1)).astype(np.float32)
    x[0, 0] = 0.0                              # all-zero row
    if N > 1 or L > 1:                         # distinct near-overflow row
        x[-1, -1] = rng.standard_normal(M).astype(np.float32) * 1e36
    qt = quantize_int8(x)
    _roundtrip_bound(x, qt)
    assert (np.asarray(dequantize(qt))[0, 0] == 0.0).all()
    assert np.isfinite(np.asarray(qt.scales, np.float32)).all()


@given(st.integers(1, 10), st.integers(1, 8), st.integers(2, 24),
       st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_residual_roundtrip_property(N, L, M, seed):
    """Residual decode = codebook[code] + data*scale reconstructs within
    half a RESIDUAL step — tighter than int8 on clustered rows, and exact
    (the centroid itself) for all-zero residuals."""
    x = _rows(N, L, M, seed)
    cb = _codebook(M, seed=seed + 1)
    x[0, 0] = cb[2]                            # residual exactly zero
    qt = quantize_residual(x, cb)
    _roundtrip_bound(x, qt)
    assert qt.codes is not None and int(qt.codes[0, 0]) == 2
    np.testing.assert_array_equal(np.asarray(dequantize(qt))[0, 0], cb[2])


def test_residual_beats_int8_on_clustered_rows():
    """The format's reason to exist: rows near a centroid carry a smaller
    residual absmax, hence a finer quantization step."""
    cb = _codebook(32, Kc=4, seed=2)
    x = cb[np.random.default_rng(3).integers(0, 4, (16, 8))]
    x += 0.05 * _rows(16, 8, 32, seed=4, unit=False)
    e_int8 = np.abs(x - np.asarray(dequantize(quantize_int8(x)))).max()
    e_res = np.abs(x - np.asarray(dequantize(quantize_residual(x, cb)))).max()
    assert e_res < e_int8


def test_quantize_dispatch_and_guards():
    x = _rows(4, 3, 8)
    assert quantize(x, "bf16") is x            # passthrough, not a copy
    assert corpus_format(quantize(x, "int8")) == "int8"
    qt = quantize(x, "residual", codebook=_codebook(8))
    assert corpus_format(qt) == "residual" and corpus_format(x) == "bf16"
    with pytest.raises(ValueError, match="needs a .* codebook"):
        quantize(x, "residual")
    with pytest.raises(ValueError, match="unknown corpus format"):
        quantize(x, "int4")
    with pytest.raises(ValueError, match="codebook must be"):
        quantize_residual(x, _codebook(16))    # M mismatch
    assert [format_ordinal(f) for f in CORPUS_FORMATS] == [1, 2, 4]
    with pytest.raises(ValueError, match="unknown corpus format"):
        format_ordinal("fp4")


def test_corpus_nbytes_counts_sidecars_and_hits_3p5x():
    N, L, M = 32, 8, 64
    x = _rows(N, L, M)
    dense_f32 = N * L * M * 4
    q8 = quantize_int8(x)
    assert corpus_nbytes(q8) == N * L * M + N * L * 2     # payload + scales
    assert dense_f32 / corpus_nbytes(q8) >= 3.5           # the bench gate
    qr = quantize_residual(x, _codebook(M))
    assert corpus_nbytes(qr) == (N * L * M + N * L * 2 + N * L * 4
                                 + 4 * M * 4)             # + codes + codebook
    assert corpus_nbytes(jnp.asarray(x)) == dense_f32


# ---------------------------------------------------------------------------
# 2. structural helpers commute with dequantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["int8", "residual"])
def test_take_commutes_with_dequantize(fmt):
    qt = quantize(_rows(11, 5, 16, seed=5), fmt,
                  codebook=_codebook(16))
    idx = jnp.asarray([3, 0, 10, 3], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(dequantize(corpus_take(qt, idx))),
        np.asarray(dequantize(qt))[np.asarray(idx)])


@pytest.mark.parametrize("fmt", ["int8", "residual"])
def test_pad_rows_decode_to_mask_neutral_values(fmt):
    """Pad tokens get scale 0 / code 0: int8 decodes them to exact zeros,
    residual to centroid 0 — either way the all-False pad token mask is
    what neutralizes them, same as zero rows on the dense path."""
    cb = _codebook(16, seed=6)
    qt = quantize(_rows(3, 5, 16, seed=7), fmt, codebook=cb)
    padded = corpus_pad_to(qt, 1, 8)           # L: 5 -> 8
    assert padded.shape == (3, 8, 16)
    tail = np.asarray(dequantize(padded))[:, 5:]
    want = np.zeros((3, 3, 16)) if fmt == "int8" else np.broadcast_to(
        cb[0], (3, 3, 16))
    np.testing.assert_array_equal(tail, want)
    # delegated array protocol: shape-derived call sites keep working
    assert padded.ndim == 3 and str(padded.dtype) == "int8"


# ---------------------------------------------------------------------------
# 3. kernel parity: quantized corpus vs its dequantized f32 twin
# ---------------------------------------------------------------------------

def _quant_corpus(N, L, M, T, fmt, seed=0):
    rng = np.random.default_rng(seed)
    E = _rows(N, L, M, seed)
    lens = rng.integers(1, L + 1, N)
    mask = np.arange(L)[None] < lens[:, None]
    E = np.where(mask[..., None], E, 0.0).astype(np.float32)
    Q = rng.standard_normal((T, M)).astype(np.float32)
    Q /= np.maximum(np.linalg.norm(Q, axis=-1, keepdims=True), 1e-9)
    qt = quantize(E, fmt, codebook=_codebook(M, seed=seed + 1))
    dense = jnp.asarray(np.asarray(dequantize(qt)))
    return qt, dense, jnp.asarray(mask), jnp.asarray(Q)


QSHAPES = [
    (8, 16, 32, 8),       # block-aligned
    (13, 37, 32, 11),     # ragged everything (padding path)
]


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("fmt", ["int8", "residual"])
@pytest.mark.parametrize("shape", QSHAPES)
def test_quantized_scores_match_dequantized_twin(impl, fmt, shape,
                                                 monkeypatch):
    N, L, M, T = shape
    qt, dense, mask, Q = _quant_corpus(N, L, M, T, fmt, seed=40)
    want = np.asarray(ref.maxsim_scores_ref(dense, mask, Q))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    got = np.asarray(maxsim_scores_op(qt, mask, Q))
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("fmt", ["int8", "residual"])
@pytest.mark.parametrize("shape", QSHAPES)
def test_quantized_gather_maxsim_matches_dequantized_twin(impl, fmt, shape,
                                                          monkeypatch):
    N, L, M, T = shape
    qt, dense, mask, Q = _quant_corpus(N, L, M, T, fmt, seed=41)
    rng = np.random.default_rng(42)
    B, G = 5, 3                                # odd B: pad path active
    di = jnp.asarray(rng.integers(0, N, B), jnp.int32)
    ti = jnp.asarray(rng.integers(0, T, (B, G)), jnp.int32)
    want = np.asarray(ref.gather_maxsim_ref(dense, mask, Q, di, ti))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    got = np.asarray(gather_maxsim_op(qt, mask, Q, di, ti,
                                      block_b=4, block_l=16))
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("fmt", ["int8", "residual"])
def test_quantized_fused_reveal_matches_dequantized_twin(impl, fmt,
                                                         monkeypatch):
    N, L, M, T = 13, 37, 32, 11
    qt, dense, mask, Q = _quant_corpus(N, L, M, T, fmt, seed=43)
    rng = np.random.default_rng(44)
    B, G = 7, 3
    di = jnp.asarray(rng.integers(0, N, B), jnp.int32)
    ti = jnp.asarray(rng.integers(0, T, (B, G)), jnp.int32)
    nm = jnp.asarray(rng.random((B, G)) > 0.4)
    want_v, want_s = ref.fused_reveal_ref(dense, mask, Q, di, ti, nm)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    got_v, got_s = fused_reveal_op(qt, mask, Q, di, ti, nm,
                                   block_b=4, block_l=16)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("fmt", ["int8", "residual"])
def test_quantized_batch_all_masked_doc_sentinel(impl, fmt, monkeypatch):
    """All-masked docs on a quantized batched corpus still score the -inf
    sentinel (never the decoded pad value of 0 or centroid 0)."""
    Bq, N, L, M, T = 2, 6, 9, 16, 5
    rng = np.random.default_rng(45)
    E = _rows(Bq * N, L, M, seed=46).reshape(Bq, N, L, M)
    mask = rng.random((Bq, N, L)) > 0.3
    mask[:, :, 0] = True                       # every doc has a live token...
    mask[0, 1] = False                         # ...except this one: all masked
    Q = rng.standard_normal((Bq, T, M)).astype(np.float32)
    qt = quantize(E, fmt, codebook=_codebook(M, seed=47))
    dense = jnp.asarray(np.asarray(dequantize(qt)))
    want = np.asarray(jax.vmap(ref.maxsim_ref)(dense, jnp.asarray(mask),
                                               jnp.asarray(Q)))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    got = np.asarray(maxsim_batch_op(qt, jnp.asarray(mask), jnp.asarray(Q),
                                     block_l=4))
    np.testing.assert_allclose(got, want, atol=1e-4)
    assert (got[0, 1] < -1e37).all()


# ---------------------------------------------------------------------------
# 4. engine + audit
# ---------------------------------------------------------------------------

_C, _L, _M = 96, 8, 32


def _engine(fmt, **over):
    from repro.serve.engine import EngineConfig, RetrievalEngine
    rng = np.random.default_rng(9)
    embs = _rows(_C, _L, _M, seed=10)
    mask = np.ones((_C, _L), bool)
    mask[:, 6:] = rng.random((_C, 2)) > 0.3
    cfg = dict(batch_size=4, token_buckets=(8,), cand_buckets=(32,),
               max_k=5, flavor="bandit", corpus_format=fmt, audit=True,
               seed=3)
    cfg.update(over)
    return RetrievalEngine(embs, mask, EngineConfig(**cfg))


def _serve(eng, n=8, seed=11):
    from repro.serve.engine import Request
    rng = np.random.default_rng(seed)
    comps = {}
    for i in range(n):
        q = rng.standard_normal((5 + (i % 3), _M)).astype(np.float32)
        q /= np.linalg.norm(q, axis=-1, keepdims=True)
        cand = rng.choice(_C, size=20, replace=False).astype(np.int32)
        comps[eng.submit(Request(query=q, k=5, cand_ids=cand))] = None
    for c in eng.drain():
        comps[c.rid] = c
    return comps


@pytest.mark.slow
def test_engine_quantized_zero_recompile_and_fidelity():
    """Per format: warmup compiles every bucket once, serving recompiles
    nothing, the post-serve audit passes (int8-residency rule armed for
    the quantized engines), and top-5 matches the bf16 engine on this
    well-separated toy corpus."""
    results = {}
    for fmt in CORPUS_FORMATS:
        eng = _engine(fmt)
        eng.warmup()
        results[fmt] = _serve(eng)
        assert eng.metrics.compiles_after_warmup == 0, fmt
        eng.audit()                            # re-audit post-serve
    for fmt in ("int8", "residual"):
        overlap = []
        for rid, c in results[fmt].items():
            b = results["bf16"][rid]
            overlap.append(len(set(c.topk_ids[c.topk_ids >= 0])
                               & set(b.topk_ids[b.topk_ids >= 0])) / 5.0)
        # quantization may swap the tail rank of an individual request;
        # the BENCH_compress gate pins >=0.9 overlap vs the exhaustive
        # oracle, and this toy corpus should do at least as well on mean.
        assert np.mean(overlap) >= 0.9 and min(overlap) >= 0.6, (fmt, overlap)


def test_engine_quantized_guards():
    from repro.serve.engine import EngineConfig, Request, RetrievalEngine
    with pytest.raises(ValueError, match="unknown corpus_format"):
        _engine("int4")
    # quantized + shard-local stage-1 rejected BEFORE any mesh is built
    with pytest.raises(ValueError, match="stage1='local'"):
        _engine("int8", stage1="local", mesh_axes=(("data", 4),))
    eng = _engine("int8")
    with pytest.raises(ValueError, match="cand_ids"):
        eng.submit(Request(query=np.zeros((5, _M), np.float32), k=5))


_S8_HLO = """\
HloModule m

ENTRY %main (p0: s8[96,8,32], p1: bf16[96,8], p2: f32[4,8,32]) -> f32[4] {
  %p0 = s8[96,8,32]{2,1,0} parameter(0)
  %p1 = bf16[96,8]{1,0} parameter(1)
  %p2 = f32[4,8,32]{2,1,0} parameter(2)
  ROOT %r = f32[4]{0} constant({0, 0, 0, 0})
}
"""


def test_int8_residency_rule_on_synthetic_hlo():
    from repro.analysis.hlo_audit import AuditError, AuditSpec, audit_hlo_text
    spec = AuditSpec(corpus_dtype="s8", corpus_elems=96 * 8 * 32)
    audit_hlo_text(_S8_HLO, spec)              # s8 payload present: clean
    # (a) a corpus-sized f32 entry parameter = dequantized before lowering
    widened = _S8_HLO.replace("f32[4,8,32]", "f32[96,8,32]")
    with pytest.raises(AuditError) as ei:
        audit_hlo_text(widened, spec)
    assert ei.value.rule == "hlo-int8-residency"
    assert "dequantized before lowering" in str(ei.value)
    # (b) no corpus-sized s8 parameter at all = payload never crossed
    missing = _S8_HLO.replace("s8[96,8,32]", "s8[4,8,32]")
    with pytest.raises(AuditError) as ei:
        audit_hlo_text(missing, spec)
    assert ei.value.rule == "hlo-int8-residency"
    # (c) rule disarmed for dense corpora (promotion rule owns that case)
    audit_hlo_text(missing, AuditSpec(corpus_dtype="bf16",
                                      corpus_elems=96 * 8 * 32))


@pytest.mark.slow
def test_int8_residency_rule_fires_on_dense_executable():
    """Negative control against the REAL compiler output: a dense-corpus
    executable handed a lying 's8' spec must fail the residency rule —
    proving the rule reads actual entry-parameter dtypes, not config."""
    from repro.analysis.hlo_audit import (AuditError, AuditSpec,
                                          audit_executable)
    eng = _engine("bf16")
    eng.warmup()
    exe = eng._exec[("step", "bandit", 8, 32)]
    with pytest.raises(AuditError) as ei:
        audit_executable(exe, AuditSpec(collective_budget=None,
                                        corpus_dtype="s8",
                                        corpus_elems=_C * _L * _M))
    assert ei.value.rule == "hlo-int8-residency"
