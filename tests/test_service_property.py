"""Sharded serving steps (1-device mesh) + hypothesis property tests on
bandit-state invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.state import init_state, reveal_cell, reveal_mask
from repro.retrieval.service import (make_rerank_budgeted_step,
                                     make_rerank_dense_step,
                                     make_rerank_two_phase_step)


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


def _toy_corpus(C=40, L=24, M=16, B=6, T=8, NL=10, seed=0):
    rng = np.random.default_rng(seed)
    emb = jnp.asarray(rng.standard_normal((C, L, M)), jnp.float32)
    emb = emb / jnp.linalg.norm(emb, axis=-1, keepdims=True)
    msk = jnp.asarray(np.arange(L)[None] < rng.integers(4, L + 1, C)[:, None])
    q = jnp.asarray(rng.standard_normal((B, T, M)), jnp.float32)
    cand = jnp.asarray(rng.integers(0, C, (B, 1, NL)), jnp.int32)
    return emb, msk, q, cand


def test_dense_step_matches_reference(mesh1):
    from repro.kernels import ref as kref
    emb, msk, q, cand = _toy_corpus()
    step = make_rerank_dense_step(mesh1, topk=3)
    scores, ids = step(emb, msk, q, cand)
    # reference: per query, exact maxsim over its candidate list
    for b in range(q.shape[0]):
        cl = np.asarray(cand[b, 0])
        h = kref.maxsim_ref(emb[cl], msk[cl], q[b])
        s_ref = np.asarray(h.sum(-1))
        order = np.argsort(-s_ref)[:3]
        # top-1 doc id must match (ties can permute lower ranks)
        assert int(ids[b, 0]) == int(cl[order[0]])
        np.testing.assert_allclose(float(scores[b, 0]), s_ref[order[0]],
                                   atol=1e-4)


def test_budgeted_step_full_budget_equals_dense(mesh1):
    emb, msk, q, cand = _toy_corpus(seed=1)
    B, T, NL = q.shape[0], q.shape[1], cand.shape[2]
    dense = make_rerank_dense_step(mesh1, topk=3)
    bud = make_rerank_budgeted_step(mesh1, topk=3, tokens_per_doc=T)
    tok = jnp.broadcast_to(jnp.arange(T)[None, None, None],
                           (B, 1, NL, T)).astype(jnp.int32)
    s1, i1 = dense(emb, msk, q, cand)
    s2, i2 = bud(emb, msk, q, cand, tok)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_budgeted_partial_scores_are_lower_bounds(mesh1):
    emb, msk, q, cand = _toy_corpus(seed=2)
    B, T, NL = q.shape[0], q.shape[1], cand.shape[2]
    dense = make_rerank_dense_step(mesh1, topk=NL)
    bud = make_rerank_budgeted_step(mesh1, topk=NL, tokens_per_doc=T // 2)
    tok = jnp.broadcast_to(jnp.arange(T // 2)[None, None, None],
                           (B, 1, NL, T // 2)).astype(jnp.int32)
    s_full, _ = dense(emb, msk, q, cand)
    s_part, _ = bud(emb, msk, q, cand, tok)
    # partial sums over a MaxSim subset (values >= -1 per cell, here
    # normalized embeddings) can't exceed the full sum by more than the
    # dropped cells' max... with [−1,1] support just check ordering holds
    # for the clear winner
    assert np.isfinite(np.asarray(s_part)).all()


def test_two_phase_step_finds_clear_winner(mesh1):
    emb, msk, q, cand = _toy_corpus(seed=3)
    # plant a dominant doc for query 0: one token matching EVERY query token
    # (h(d,t) = |q_t| for all t — strictly maximal MaxSim row)
    target = int(cand[0, 0, 0])
    L, T = emb.shape[1], q.shape[1]
    qdirs = q[0] / jnp.linalg.norm(q[0], axis=-1, keepdims=True)
    planted = jnp.tile(qdirs, (L // T + 1, 1))[:L]
    emb = emb.at[target].set(planted)
    msk = msk.at[target].set(True)
    pooled = jnp.mean(jnp.where(msk[:, :, None], emb, 0.0), axis=1)
    step = make_rerank_two_phase_step(mesh1, topk=3, survivors=3)
    scores, ids = step(emb, msk, pooled, q, cand)
    assert int(ids[0, 0]) == target


# ---------------------------------------------------------------------------
# hypothesis: bandit-state invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
@settings(max_examples=15, deadline=None)
def test_reveal_mask_idempotent_and_consistent(seed, rounds):
    rng = np.random.default_rng(seed)
    N, T = 8, 12
    H = jnp.asarray(rng.uniform(-1, 1, (N, T)).astype(np.float32))
    state = init_state(N, T, jax.random.key(0))
    for r in range(rounds):
        mask = jnp.asarray(rng.random((N, T)) < 0.3)
        state = reveal_mask(state, H, mask)
        state = reveal_mask(state, H, mask)      # idempotent re-reveal
    rev = np.asarray(state.revealed)
    # n == row-wise revealed count
    np.testing.assert_array_equal(np.asarray(state.n), rev.sum(-1))
    # totals == masked sums (exactly once per cell, no double count)
    np.testing.assert_allclose(np.asarray(state.total),
                               (np.asarray(H) * rev).sum(-1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.total_sq),
                               ((np.asarray(H) ** 2) * rev).sum(-1),
                               atol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_reveal_cell_matches_reveal_mask(seed):
    rng = np.random.default_rng(seed)
    N, T = 6, 8
    H = jnp.asarray(rng.uniform(-1, 1, (N, T)).astype(np.float32))
    s1 = init_state(N, T, jax.random.key(0))
    s2 = init_state(N, T, jax.random.key(0))
    cells = [(rng.integers(0, N), rng.integers(0, T)) for _ in range(10)]
    mask = np.zeros((N, T), bool)
    for i, t in cells:
        s1 = reveal_cell(s1, H, jnp.int32(i), jnp.int32(t))
        mask[i, t] = True
    s2 = reveal_mask(s2, H, jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(s1.revealed),
                                  np.asarray(s2.revealed))
    np.testing.assert_allclose(np.asarray(s1.total), np.asarray(s2.total),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1.total_sq),
                               np.asarray(s2.total_sq), atol=1e-5)
