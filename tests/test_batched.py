"""Block-synchronous (TPU) Col-Bandit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact_topk, overlap_at_k, run_batched_oracle, run_bandit


def _make_h(seed=0, N=64, T=32, gap=0.25):
    rng = np.random.default_rng(seed)
    H = rng.uniform(0.2, 0.5, (N, T)).astype(np.float32)
    winners = rng.choice(N, 8, replace=False)
    H[winners] += gap
    return jnp.asarray(np.clip(H, 0, 1))


def test_separated_is_exact_conservative():
    H = _make_h(0)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    exact, _ = exact_topk(H, k=5)
    res = run_batched_oracle(H, a, b, jax.random.key(0), k=5, alpha_ef=1e9)
    assert bool(res.separated)
    assert float(overlap_at_k(res.topk, exact)) == 1.0


def test_fewer_rounds_than_sequential():
    """The point of the TPU adaptation: reveals move in B*G blocks, so the
    control-loop iteration count collapses by orders of magnitude."""
    H = _make_h(1)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    seq = run_bandit(H, a, b, jax.random.key(0), k=5, alpha_ef=0.5)
    blk = run_batched_oracle(H, a, b, jax.random.key(0), k=5, alpha_ef=0.5,
                             block_docs=8, block_tokens=8)
    assert int(blk.rounds) * 8 < int(seq.rounds)


def test_block_size_one_matches_sequential_regime():
    """B=2, G=1 approximates LUCB's {i+, i-} pair — coverage should be in
    the same ballpark as the sequential algorithm (within 2x)."""
    H = _make_h(2)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    seq = run_bandit(H, a, b, jax.random.key(0), k=5, alpha_ef=0.5)
    blk = run_batched_oracle(H, a, b, jax.random.key(0), k=5, alpha_ef=0.5,
                             block_docs=2, block_tokens=1)
    assert float(blk.coverage) < 2.5 * float(seq.coverage) + 0.05


def test_doc_mask_respected():
    H = _make_h(3, N=48)
    mask = jnp.arange(48) < 40
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    res = run_batched_oracle(H, a, b, jax.random.key(0), k=5, alpha_ef=0.5,
                             doc_mask=mask)
    assert all(int(i) < 40 for i in np.asarray(res.topk))
    assert not np.asarray(res.revealed)[40:].any()


def test_max_rounds_budget_respected():
    H = _make_h(4)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    res = run_batched_oracle(H, a, b, jax.random.key(0), k=5, alpha_ef=1e9,
                             max_rounds=3)
    assert int(res.rounds) <= 3


def test_stats_consistency_after_run():
    """Revealed mask and coverage must agree."""
    H = _make_h(5)
    a = jnp.zeros(H.shape); b = jnp.ones(H.shape)
    res = run_batched_oracle(H, a, b, jax.random.key(1), k=5, alpha_ef=0.5)
    frac = np.asarray(res.revealed).mean()
    assert float(res.coverage) == pytest.approx(frac, abs=1e-6)
