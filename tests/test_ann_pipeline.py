"""Stage-1 kNN candidate generation (Eq. 15 bounds) + two-stage pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import BanditConfig
from repro.data.synthetic import make_retrieval_dataset
from repro.kernels import ref as kref
from repro.retrieval.ann import generate_candidates
from repro.retrieval.index import build_index, build_index_from_ragged
from repro.retrieval.pipeline import evaluate_dataset, rerank_query


@pytest.fixture(scope="module")
def ds():
    return make_retrieval_dataset(n_docs=128, n_queries=4, seed=0)


@pytest.fixture(scope="module")
def index(ds):
    return build_index(ds.doc_embs, ds.doc_mask, ds.doc_lens)


def test_ann_bounds_are_valid_upper_bounds(ds, index):
    """THE paper-critical property (Eq. 15): b_it >= H_it for every
    candidate cell — otherwise the hard bounds (and hence Col-Bandit's
    stopping certificate) would be wrong."""
    for qi in range(ds.n_queries):
        q = jnp.asarray(ds.queries[qi])
        cand = generate_candidates(index.doc_embs, index.doc_mask, q,
                                   kprime=10, max_candidates=64)
        embs, mask = index.gather_docs(cand.doc_ids)
        h = kref.maxsim_ref(embs, mask, q)
        h = jnp.where(cand.doc_mask[:, None], h, 0.0)
        viol = np.asarray(h - cand.b)
        assert viol.max() <= 1e-5, f"bound violated by {viol.max()}"


def test_ann_known_cells_match_truth(ds, index):
    q = jnp.asarray(ds.queries[0])
    cand = generate_candidates(index.doc_embs, index.doc_mask, q,
                               kprime=10, max_candidates=64)
    embs, mask = index.gather_docs(cand.doc_ids)
    h = np.asarray(kref.maxsim_ref(embs, mask, q))
    km = np.asarray(cand.known_mask)
    kv = np.asarray(cand.known_vals)
    assert km.any()
    np.testing.assert_allclose(kv[km], h[km], atol=1e-5)


def test_candidates_cover_per_token_winners(ds, index):
    """Guaranteed stage-1 property: the doc owning the single best token for
    EACH query token is in the candidate set (it is that token's top-1
    neighbor). The global sum-winner is NOT guaranteed — two-stage retrieval
    accepts stage-1 recall loss, exactly as in the paper's pipeline."""
    for qi in range(ds.n_queries):
        q = jnp.asarray(ds.queries[qi])
        h_all = kref.maxsim_ref(index.doc_embs, index.doc_mask, q)
        cand = generate_candidates(index.doc_embs, index.doc_mask, q,
                                   kprime=10, max_candidates=64)
        ids = set(np.asarray(cand.doc_ids).tolist())
        for t in range(0, q.shape[0], 7):        # spot-check tokens
            owner = int(jnp.argmax(h_all[:, t]))
            assert owner in ids


def test_pipeline_exact_is_reference(index, ds):
    r = rerank_query(index, jnp.asarray(ds.queries[0]), method="exact", k=5)
    assert r.overlap == 1.0 and r.coverage == 1.0


@pytest.mark.parametrize("method", ["bandit", "batched", "uniform",
                                    "topmargin"])
def test_pipeline_methods_run(index, ds, method):
    r = rerank_query(index, jnp.asarray(ds.queries[1]), method=method, k=5,
                     bandit=BanditConfig(k=5, alpha_ef=0.5),
                     qrels_row=ds.qrels[1])
    assert 0.0 < r.coverage <= 1.0
    assert 0.0 <= r.overlap <= 1.0
    assert r.flops <= r.flops_exact + 1e-6
    assert set(r.metrics) == {"recall", "mrr", "ndcg"}


def test_bandit_beats_uniform_at_matched_coverage(ds):
    """Qualitative claim of the paper (Fig. 2): at matched coverage the
    adaptive method achieves higher overlap than Doc-Uniform."""
    out_b = evaluate_dataset(ds, method="bandit", k=5,
                             bandit=BanditConfig(k=5, alpha_ef=1.0))
    out_u = evaluate_dataset(ds, method="uniform", k=5,
                             budget_fraction=max(0.05, out_b["coverage"]))
    assert out_b["overlap"] >= out_u["overlap"] - 0.05


def test_prereveal_ann_reduces_paid_coverage(index, ds):
    base = rerank_query(index, jnp.asarray(ds.queries[2]), method="bandit",
                        k=5, bandit=BanditConfig(k=5, alpha_ef=0.5))
    pre = rerank_query(index, jnp.asarray(ds.queries[2]), method="bandit",
                       k=5, bandit=BanditConfig(k=5, alpha_ef=0.5),
                       prereveal_ann=True)
    assert pre.flops <= base.flops * 1.05


def test_ragged_index_building():
    rng = np.random.default_rng(0)
    docs = [rng.standard_normal((l, 8)).astype(np.float32)
            for l in (3, 7, 5)]
    idx = build_index_from_ragged(docs)
    assert idx.doc_embs.shape == (3, 7, 8)
    assert np.asarray(idx.doc_lens).tolist() == [3, 7, 5]
    assert np.asarray(idx.doc_mask).sum() == 15
