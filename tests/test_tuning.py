"""ISSUE 5: shape-bucket kernel autotuning (repro.kernels.tuning).

Contracts:
  * resolution order — explicit block argument > tuned bucket entry >
    default, resolved at trace time;
  * pow2 bucketing — one tuned entry covers the whole shape family;
  * JSON persistence round-trips the table exactly;
  * ``autotune_op`` records a winner drawn from the candidate grid and the
    op produces identical RESULTS under every candidate (tuning is a pure
    performance knob);
  * engine integration — ``EngineConfig(autotune=True)`` tunes at warmup
    before the AOT compiles (zero-recompile contract intact), persists to
    ``tuning_table``, and a second engine reuses the table instead of
    re-timing.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, tuning
from repro.kernels.ops import autotune_op, gather_maxsim_op, maxsim_op


@pytest.fixture(autouse=True)
def _clean_table():
    tuning.clear()
    yield
    tuning.clear()


def test_bucketing_covers_shape_family():
    k1 = tuning.bucket_key("gather_maxsim", dict(B=65, L=200, M=128))
    k2 = tuning.bucket_key("gather_maxsim", dict(B=128, L=256, M=128))
    k3 = tuning.bucket_key("gather_maxsim", dict(B=129, L=256, M=128))
    assert k1 == k2 and k2 != k3


def test_lookup_merges_tuned_over_defaults():
    dims = dict(N=32, T=16, L=128, M=128)
    base = tuning.lookup("maxsim", dims)
    assert base == tuning.DEFAULTS["maxsim"]
    tuning.record("maxsim", dims, {"block_l": 64})
    got = tuning.lookup("maxsim", dims)
    assert got["block_l"] == 64
    assert got["block_n"] == tuning.DEFAULTS["maxsim"]["block_n"]


def test_maxsim_default_block_t_capped_not_full_axis(monkeypatch):
    """Satellite: the old ``block_t=0 -> bt = T`` default is retired — an
    unbucketed large-T call must tile T at the documented 128 cap (and
    pad), not grow the VMEM tile linearly in T. Pinned by parity at
    T > 128 with an odd T (the pad path is the fix's risk surface)."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    assert tuning.DEFAULTS["maxsim"]["block_t"] == 128
    rng = np.random.default_rng(0)
    N, L, M, T = 4, 32, 128, 200                   # T > 128, unaligned
    E = jnp.asarray(rng.standard_normal((N, L, M)), jnp.float32)
    mask = jnp.asarray(rng.random((N, L)) > 0.2)
    Q = jnp.asarray(rng.standard_normal((T, M)), jnp.float32)
    h = maxsim_op(E, mask, Q, block_l=32)          # default block_t
    np.testing.assert_allclose(np.asarray(h),
                               np.asarray(ref.maxsim_ref(E, mask, Q)),
                               atol=1e-5)


def test_explicit_block_argument_beats_tuned_entry(monkeypatch):
    """An explicit block argument must win over a (deliberately broken)
    tuned entry — pinned via the kernel's divisibility error: block_b=3
    with B=6 pads to 6 rows, while a tuned block_b would differ."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    rng = np.random.default_rng(1)
    N, L, M, T = 8, 32, 16, 8
    E = jnp.asarray(rng.standard_normal((N, L, M)), jnp.float32)
    mask = jnp.ones((N, L), jnp.bool_)
    Q = jnp.asarray(rng.standard_normal((T, M)), jnp.float32)
    di = jnp.asarray(rng.integers(0, N, 6), jnp.int32)
    ti = jnp.asarray(rng.integers(0, T, (6, 2)), jnp.int32)
    dims = dict(B=6, G=2, L=L, M=M, D=N, TQ=T)
    tuning.record("gather_maxsim", dims, {"block_b": 4, "block_l": 16})
    want = np.asarray(ref.gather_maxsim_ref(E, mask, Q, di, ti))
    for explicit in (None, 2):                     # tuned path, then override
        out = gather_maxsim_op(E, mask, Q, di, ti, block_b=explicit)
        np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)


def test_save_load_roundtrip(tmp_path):
    dims = dict(B=64, G=4, L=128, M=128, D=256, TQ=256)
    tuning.record("fused_reveal", dims, {"block_b": 16, "block_l": 64})
    tuning.record("maxsim", dict(N=8, T=8, L=64, M=128), {"block_l": 64})
    path = str(tmp_path / "table.json")
    tuning.save_table(path)
    before = tuning.table()
    tuning.clear()
    assert tuning.table() == {}
    assert tuning.load_table(path) == 2
    assert tuning.table() == before
    # file is plain rows
    rows = json.load(open(path))
    assert all(set(r) == {"op", "bucket", "config"} for r in rows)


def test_autotune_op_records_winner_and_results_invariant(monkeypatch):
    """autotune_op must record a candidate-grid winner, and every candidate
    configuration must produce identical op RESULTS — block sizes are a
    pure performance knob."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    dims = dict(B=8, G=2, L=32, M=16, D=16, TQ=16)
    best, timings = autotune_op("gather_maxsim", dims, repeats=1)
    assert timings and best in tuning.candidates("gather_maxsim", dims)
    assert tuning.bucket_key("gather_maxsim", dims) in tuning.table()
    rng = np.random.default_rng(2)
    E = jnp.asarray(rng.standard_normal((16, 32, 16)), jnp.float32)
    mask = jnp.ones((16, 32), jnp.bool_)
    Q = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    di = jnp.asarray(rng.integers(0, 16, 8), jnp.int32)
    ti = jnp.asarray(rng.integers(0, 16, (8, 2)), jnp.int32)
    outs = [np.asarray(gather_maxsim_op(E, mask, Q, di, ti, **cand))
            for cand in tuning.candidates("gather_maxsim", dims)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5)


def test_autotune_op_ref_lane_is_a_noop(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
    best, timings = autotune_op("fused_reveal",
                                dict(B=4, G=2, L=16, M=8, D=8, TQ=8))
    assert timings == {} and tuning.table() == {}
    assert best == tuning.DEFAULTS["fused_reveal"]


@pytest.mark.slow
@pytest.mark.skipif(os.environ.get("REPRO_KERNEL_IMPL") == "ref",
                    reason="block sizes are ignored by the pure-jnp "
                           "oracles; autotune is a documented no-op")
def test_engine_warmup_autotunes_and_persists(tmp_path):
    """EngineConfig(autotune=True, tuning_table=...): warmup times the
    serving buckets' kernel shapes, persists the table, keeps the
    zero-recompile contract, and a second engine reuses the table (zero
    buckets re-measured)."""
    from repro.serve.engine import EngineConfig, Request, RetrievalEngine

    rng = np.random.default_rng(3)
    C, L, M = 40, 16, 16
    embs = rng.standard_normal((C, L, M)).astype(np.float32)
    mask = np.ones((C, L), bool)
    path = str(tmp_path / "tuned.json")
    cfg = EngineConfig(batch_size=2, token_buckets=(8,), cand_buckets=(16,),
                       flavor="bandit", block_docs=4, block_tokens=4,
                       max_rounds=6, autotune=True, tuning_table=path)
    eng = RetrievalEngine(embs, mask, cfg)
    eng.warmup()
    assert eng.metrics.autotune_buckets > 0
    assert eng.metrics.autotune_s > 0
    rows = json.load(open(path))
    assert len(rows) == eng.metrics.autotune_buckets
    # serving still zero-recompile after warmup
    for _ in range(3):
        eng.submit(Request(query=rng.standard_normal((8, M)).astype(
            np.float32), k=4, cand_ids=np.arange(16)))
    done = eng.drain()
    assert len(done) == 3
    assert eng.metrics.compiles_after_warmup == 0
    summary = eng.metrics.summary()
    assert summary["autotune_buckets"] == eng.metrics.autotune_buckets

    # second engine: loads the table, re-times nothing
    eng2 = RetrievalEngine(embs, mask, cfg)
    eng2.warmup()
    assert eng2.metrics.tuning_entries_loaded == len(rows)
    assert eng2.metrics.autotune_buckets == 0
