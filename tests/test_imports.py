"""Import sweep: every module under src/repro, benchmarks/ and examples/
must import cleanly.  A missing package (like the repro.dist regression
this PR fixed) then fails HERE, in one obvious place, instead of as six
scattered collection errors.

Imports run in a subprocess per tree because some modules (launch/dryrun,
benchmarks/roofline, benchmarks/perf_iterations) pin XLA_FLAGS for 512
placeholder devices at import time — that must never leak into this test
process's jax.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _modules_under(base_dir: str, pkg_prefix: str):
    mods = []
    for dirpath, _, filenames in os.walk(os.path.join(ROOT, base_dir)):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            rel = os.path.relpath(os.path.join(dirpath, fn), ROOT)
            parts = rel[:-3].replace(os.sep, ".")
            if pkg_prefix:
                parts = parts[len(base_dir) + 1:]
                parts = f"{pkg_prefix}.{parts}" if parts else pkg_prefix
            if parts.endswith(".__init__"):
                parts = parts[: -len(".__init__")]
            mods.append(parts)
    return sorted(set(mods))


def _import_all(modules):
    prog = (
        "import importlib, sys, traceback\n"
        "failed = []\n"
        f"for m in {modules!r}:\n"
        "    try:\n"
        "        importlib.import_module(m)\n"
        "    except Exception:\n"
        "        failed.append(m)\n"
        "        traceback.print_exc()\n"
        "print('FAILED:' + ','.join(failed) if failed else 'ALL_OK')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                        text=True, timeout=600, cwd=ROOT, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALL_OK" in out.stdout, (
        f"import failures: {out.stdout.strip().splitlines()[-1]}\n"
        f"{out.stderr[-3000:]}")


def test_repro_package_imports():
    mods = _modules_under("src/repro", "repro")
    assert "repro.dist.sharding" in mods      # the restored subsystem
    assert "repro.dist.fault" in mods
    assert "repro.analysis.lint" in mods      # static-analysis subsystem
    assert "repro.analysis.hlo_audit" in mods
    assert "repro.analysis.fixtures.trace_unsafe" in mods
    _import_all(mods)


def test_benchmarks_import():
    mods = _modules_under("benchmarks", "benchmarks")
    assert "benchmarks.perf_iterations" in mods
    _import_all(mods)


def test_examples_import():
    mods = _modules_under("examples", "examples")
    assert len(mods) >= 4
    _import_all(mods)
