"""ISSUE 9 trace-safety + thread-lockset lint (repro.analysis.lint/locks)
and the runtime access recorder (repro.analysis.recorder).

Pins: every committed fixture fires exactly its rule, ``# repro:
noqa-<rule>`` suppresses without hiding (the gate still counts it), the
committed baseline is EMPTY and the real src/ tree passes the merge gate
(``--max-suppressions 0``), the engine's declared threading discipline
verifies, and tampering with the engine's tables is caught."""
import os
import threading

import pytest

from repro.analysis import lint, locks
from repro.analysis.recorder import ThreadAccessRecorder

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIX = os.path.join(ROOT, "src", "repro", "analysis", "fixtures")
ENGINE = os.path.join(ROOT, "src", "repro", "serve", "engine.py")


def _pairs(viols):
    return sorted((v.rule, v.line) for v in viols)


# ---------------------------------------------------------------------------
# Fixtures fire their rules
# ---------------------------------------------------------------------------

def test_trace_unsafe_fixture_fires_every_trace_rule():
    v = lint.lint_file(os.path.join(FIX, "trace_unsafe.py"))
    assert not any(x.suppressed for x in v)
    assert _pairs(v) == sorted([
        ("prng-aliasing", 13),
        ("mutable-default", 16),
        ("traced-truthiness", 22),
        ("traced-cast", 27),
        ("traced-cast", 28),
        ("host-sync-in-trace", 29),
        ("time-in-trace", 30),
    ])


def test_kernel_assert_fixture():
    v = lint.lint_file(os.path.join(FIX, "kernels", "bad_assert.py"))
    assert _pairs(v) == [("kernel-assert", 7)]


def test_locks_bad_fixture_flags_shared_attr_and_guard_escape():
    v = locks.check_file(os.path.join(FIX, "locks_bad.py"))
    assert all(x.rule == "lockset" for x in v)
    shared = [x for x in v if "no GUARDED_BY entry" in x.msg]
    assert shared and all("_count" in x.msg for x in shared)
    escape = [x for x in v if "outside its declared guard" in x.msg]
    assert [x.line for x in escape] == [28]
    assert "self._lock" in escape[0].msg
    # lint_file folds the lockset pass in for table-declaring files.
    assert _pairs(lint.lint_file(os.path.join(FIX, "locks_bad.py"))) \
        == _pairs(v)


def test_noqa_suppression_counts_but_is_not_active():
    v = lint.lint_file(os.path.join(FIX, "noqa_ok.py"))
    assert [x.rule for x in v if x.suppressed] == ["prng-aliasing"]
    assert not [x for x in v if not x.suppressed]


# ---------------------------------------------------------------------------
# CLI gate semantics
# ---------------------------------------------------------------------------

def test_cli_fails_on_fixture_violations(capsys):
    rc = lint.main([os.path.join(FIX, "trace_unsafe.py")])
    assert rc == 1
    out = capsys.readouterr().out
    assert "[prng-aliasing]" in out and "7 violation(s)" in out


def test_cli_report_only_exits_zero(capsys):
    assert lint.main([os.path.join(FIX, "trace_unsafe.py"),
                      "--report-only"]) == 0
    assert "[prng-aliasing]" in capsys.readouterr().out


def test_cli_suppression_budget(capsys):
    noqa = os.path.join(FIX, "noqa_ok.py")
    assert lint.main([noqa]) == 0                      # suppressed: passes
    assert lint.main([noqa, "--max-suppressions", "0"]) == 1
    assert "suppression budget exceeded" in capsys.readouterr().out


def test_src_tree_passes_merge_gate(capsys):
    """THE satellite-1 pin: the real source tree is clean under the CI
    gate — zero active violations, zero suppressions in effect."""
    assert lint.main([os.path.join(ROOT, "src"),
                      "--max-suppressions", "0"]) == 0
    assert " 0 violation(s), 0 suppressed" in capsys.readouterr().out


def test_committed_baseline_is_empty():
    assert lint.load_baseline(lint.DEFAULT_BASELINE) == set()


def test_fixture_tree_excluded_unless_opted_in():
    files = lint.iter_py_files([os.path.join(ROOT, "src")])
    assert not any(os.sep + "fixtures" + os.sep in f for f in files)
    with_fix = lint.iter_py_files([os.path.join(ROOT, "src")],
                                  include_fixtures=True)
    assert any(f.endswith("trace_unsafe.py") for f in with_fix)


# ---------------------------------------------------------------------------
# The engine's declared threading discipline
# ---------------------------------------------------------------------------

def test_engine_lockset_clean():
    assert locks.check_file(ENGINE) == []


def test_engine_lockset_catches_removed_declaration():
    src = open(ENGINE).read()
    entry = '"_thread_exc": "_done_cv",'
    assert entry in src
    v = locks.check_source(src.replace(entry, ""), ENGINE)
    assert any("_thread_exc" in x.msg and "no GUARDED_BY entry" in x.msg
               for x in v), v


def test_engine_lockset_catches_write_outside_declared_guard():
    src = open(ENGINE).read()
    entry = '"_inflight": "_inflight_lock",'
    assert entry in src
    v = locks.check_source(
        src.replace(entry, '"_inflight": "_completed_lock",'), ENGINE)
    assert any("self._inflight written in" in x.msg
               and "self._completed_lock" in x.msg for x in v), v


# ---------------------------------------------------------------------------
# Runtime access recorder (the lockset pass's dynamic twin)
# ---------------------------------------------------------------------------

class _Plain:
    def __init__(self):
        self.shared_undeclared = 0
        self.shared_declared = 0
        self.private = 0


def _hammer(obj, n_threads=4, n_iter=50):
    def work():
        for _ in range(n_iter):
            obj.shared_undeclared += 1
            obj.shared_declared += 1
    ts = [threading.Thread(target=work, name=f"w{i}")
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()


def test_recorder_flags_undeclared_shared_writes_only():
    obj = _Plain()
    with ThreadAccessRecorder(obj,
                              declared={"shared_declared"}) as rec:
        _hammer(obj)
        obj.private += 1                       # main thread only
    v = rec.violations()
    assert len(v) == 1 and v[0].startswith("shared_undeclared:")
    assert "no declared guard" in v[0]
    shared = rec.shared()
    assert "shared_declared" in shared         # observed, just declared
    assert "private" not in shared             # one thread: not shared


def test_recorder_uninstall_restores_class():
    obj = _Plain()
    cls = type(obj)
    rec = ThreadAccessRecorder(obj).install()
    assert type(obj) is not cls
    obj.private = 5
    rec.uninstall()
    assert type(obj) is cls and obj.private == 5
    before = dict(rec.writes)
    obj.private = 6                            # uninstrumented: unrecorded
    assert rec.writes == before
