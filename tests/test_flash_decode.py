"""Split-K flash-decode attention (dist/flash_decode.py): the sharded path
must match the unsharded reference bit-for-practical-purposes.  Runs in a
subprocess with 8 host placeholder devices (same contract as test_dist)."""
from _subproc import run_in_subprocess as _run_subprocess


def test_split_k_kernel_matches_local():
    out = _run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist import flash_decode as FD
    B, S, Hkv, G, Dh = 2, 64, 2, 3, 8
    rng = np.random.default_rng(0)
    qg = jnp.asarray(rng.standard_normal((B, 1, Hkv, G, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    kv_pos = jnp.where(kv_pos < 50, kv_pos, -1)      # some empty slots
    kv_valid = kv_pos >= 0
    q_pos = jnp.full((B, 1), 49, jnp.int32)
    scale = 1.0 / Dh ** 0.5
    mesh = jax.make_mesh((8,), ("model",))
    for window, cap in ((0, 50.0), (16, None)):
        ref = FD._local_attention(qg, k, v, kv_pos, kv_valid, q_pos,
                                  jnp.int32(window), scale=scale,
                                  softcap=cap, seq_axes=())
        FD.configure(mesh, None, "model")
        got = jax.jit(lambda *a: FD.flash_decode_attention(*a, scale, cap))(
            qg, k, v, kv_pos, kv_valid, q_pos, jnp.int32(window))
        FD.configure(None, None, None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)
    print("FLASH_DECODE_OK")
    """)
    assert "FLASH_DECODE_OK" in out


def test_forward_decode_parity_with_flash_decode():
    """The full decode layer (models/transformer.py FD branch) must emit the
    same logits with split-K enabled as the GSPMD reference path."""
    out = _run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import LMConfig
    from repro.models.transformer import (forward_decode, forward_prefill,
                                          init_lm)
    from repro.dist import flash_decode as FD
    cfg = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_head=16, d_ff=128, vocab=256)
    params = init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    last, cache = forward_prefill(params, cfg, tokens, max_seq=32,
                                  cache_dtype=jnp.float32)
    cur = jnp.argmax(last, -1)
    FD.configure(None, None, None)
    ref, _ = forward_decode(params, cfg, cur, jnp.int32(16), cache)
    mesh = jax.make_mesh((8,), ("model",))
    FD.configure(mesh, None, "model")    # cache seq (32) shards 8-way
    got, _ = jax.jit(
        lambda p, c, pos, ca: forward_decode(p, cfg, c, pos, ca))(
        params, cur, jnp.int32(16), cache)
    FD.configure(None, None, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)
    print("DECODE_PARITY_OK")
    """)
    assert "DECODE_PARITY_OK" in out
