"""Training loop, optimizer, checkpointing, fault-tolerance tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (AsyncCheckpointer, latest_checkpoint,
                                   restore_checkpoint, save_checkpoint)
from repro.configs.base import LMConfig
from repro.dist.fault import DeadlineBatcher, simulate_failure
from repro.models.transformer import init_lm
from repro.train.optimizer import adamw, cosine_schedule, global_norm
from repro.train.train_step import TrainState, make_lm_train_step
from repro.train.trainer import Trainer, TrainerConfig

CFG = LMConfig(name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
               d_head=16, d_ff=64, vocab=128)


def _batch_fn(step: int):
    key = jax.random.fold_in(jax.random.key(123), step)
    toks = jax.random.randint(key, (4, 16), 0, CFG.vocab)
    return {"tokens": toks[:, :], "targets": jnp.roll(toks, -1, axis=1)}


def _init_state():
    params = init_lm(jax.random.key(0), CFG)
    opt = adamw(1e-3)
    return TrainState(params=params, opt=opt.init(params)), opt


def test_adamw_minimizes_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_lm_loss_decreases():
    state, opt = _init_state()
    step = jax.jit(make_lm_train_step(CFG, opt))
    batch = _batch_fn(0)    # overfit one batch
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
    assert np.isfinite(losses).all()


def test_microbatching_matches_full_batch_grads():
    """Gradient accumulation must match the single-batch step numerically."""
    state, opt = _init_state()
    batch = _batch_fn(1)
    s1 = jax.jit(make_lm_train_step(CFG, opt, num_microbatches=1))
    s2 = jax.jit(make_lm_train_step(CFG, opt, num_microbatches=4))
    out1, m1 = s1(state, batch)
    out2, m2 = s2(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(out1.params), jax.tree.leaves(out2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_checkpoint_roundtrip(tmp_path):
    state, _ = _init_state()
    d = save_checkpoint(str(tmp_path), 7, state)
    restored, meta = restore_checkpoint(d, state)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_checkpoint_and_gc(tmp_path):
    state, _ = _init_state()
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.save(s, state)
        ck.wait()
    assert latest_checkpoint(str(tmp_path))[0] == 30
    steps = sorted(os.listdir(tmp_path))
    assert len(steps) == 2           # GC kept the last two


def test_restart_is_bitwise_identical(tmp_path):
    """The flagship fault-tolerance property: crash at step 7, restart from
    the step-5 checkpoint, and land on EXACTLY the same params as an
    uninterrupted run (step-keyed data pipeline + full-state checkpoints)."""
    def build(ckpt_dir):
        state, opt = _init_state()
        step = jax.jit(make_lm_train_step(CFG, opt))
        tr = Trainer(step, _batch_fn, state,
                     TrainerConfig(total_steps=12, ckpt_every=5,
                                   ckpt_dir=ckpt_dir, log_every=100,
                                   async_ckpt=False))
        return tr

    # uninterrupted reference
    ref = build(None).run()

    # crash at step 7, then resume
    d = str(tmp_path / "ck")
    tr = build(d)
    killed = simulate_failure(lambda guard: tr.run(guard), fail_at_step=7)
    assert killed
    tr2 = build(d)
    tr2.maybe_restore()
    assert tr2.start_step == 5
    out = tr2.run()

    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(out.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_roundtrip(tmp_path):
    """Save under one layout, restore into a fresh device placement."""
    from repro.dist.fault import reshard
    from jax.sharding import PartitionSpec as P
    state, _ = _init_state()
    d = save_checkpoint(str(tmp_path), 1, state)
    restored, _ = restore_checkpoint(d, state)
    mesh = jax.make_mesh((1,), ("data",))
    spec = jax.tree.map(lambda _: P(), restored)
    placed = reshard(restored, spec, mesh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_deadline_batcher():
    t = [0.0]
    b = DeadlineBatcher(batch_size=4, deadline_s=1.0, clock=lambda: t[0])
    b.add("a"); b.add("b")
    assert b.poll() is None            # not full, not expired
    t[0] = 1.5
    reqs, n_real = b.poll()            # expired -> partial batch, padded
    assert n_real == 2 and len(reqs) == 4
    for x in "cdef":
        b.add(x)
    reqs, n_real = b.poll()            # full batch immediately
    assert n_real == 4


def test_global_norm():
    assert float(global_norm({"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])})) == pytest.approx(5.0)
