"""ISSUE 8: fault-injected, self-healing serving.

Units for the resilience layer (FaultPlan determinism, ChaosClock,
poison_corpus, DegradeLadder, Supervisor) plus engine-level regressions:
the finite-score quarantine end to end over a poisoned corpus, supervised
thread-kill recovery with the zero-lost / zero-dup delivery guarantee,
stop()'s flush-and-complete contract (no dangling futures, no silently
dropped queued work), the deadline-aware fidelity ladder (traced knobs =
zero recompiles), and shard failover's partial-coverage accounting (the
mesh cases run in device subprocesses via tests/_subproc.py).
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from _subproc import run_in_subprocess

from repro.dist.fault import (ChaosClock, ChaosKill, FaultPlan,
                              InjectedFault, poison_corpus)
from repro.serve import (AsyncRetrievalEngine, EngineConfig, Request,
                         RetrievalEngine)
from repro.serve.resilience import DegradeLadder, Supervisor

# Threaded chaos tests must never hang CI: enforced by pytest-timeout in
# the chaos lane, inert where the plugin is not installed.
pytestmark = pytest.mark.timeout(300)


def _dataset(C=32, L=6, T=8, M=16, seed=0):
    rng = np.random.default_rng(seed)
    embs = rng.standard_normal((C, L, M)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=-1, keepdims=True)
    mask = np.arange(L)[None] < rng.integers(3, L + 1, C)[:, None]
    q = rng.standard_normal((T, M)).astype(np.float32)
    q /= np.linalg.norm(q, axis=-1, keepdims=True)
    return embs, mask, q


# -- fault-injection primitives ------------------------------------------


def test_fault_plan_counter_determinism():
    """Ticking is counter-based: the same plan replayed over the same tick
    stream fires the identical faults at the identical ticks, and foreign
    points never fire."""
    mk = lambda: FaultPlan([
        InjectedFault(point="dispatch", at=3, action="kill"),
        InjectedFault(point="dispatch", at=5, action="shard_down", arg=1),
        InjectedFault(point="admit", at=2, action="delay", arg=0.5),
    ])
    logs = []
    for _ in range(2):
        plan, log = mk(), []
        for t in range(1, 7):
            log.append((t, "admit", [f.action for f in plan.tick("admit")]))
            log.append((t, "dispatch",
                        [f.action for f in plan.tick("dispatch")]))
        logs.append(log)
    assert logs[0] == logs[1]
    fired = {(t, p): a for t, p, a in logs[0] if a}
    assert fired == {(2, "admit"): ["delay"], (3, "dispatch"): ["kill"],
                     (5, "dispatch"): ["shard_down"]}


def test_fault_plan_seeded_replay_and_kill_ordering():
    """seeded() is a pure function of the seed, and a tick carrying both a
    state flip and a kill applies the flip first (kills sort last)."""
    a = FaultPlan.seeded(7, points=("admit", "dispatch"), n_faults=4,
                         actions=("kill", "shard_down"), shards=(0, 1))
    b = FaultPlan.seeded(7, points=("admit", "dispatch"), n_faults=4,
                         actions=("kill", "shard_down"), shards=(0, 1))
    assert a.faults == b.faults
    assert FaultPlan.seeded(8).faults != a.faults or True  # just replayable
    plan = FaultPlan([
        InjectedFault(point="dispatch", at=1, action="kill"),
        InjectedFault(point="dispatch", at=1, action="shard_down", arg=0)])
    due = plan.tick("dispatch")
    assert [f.action for f in due] == ["shard_down", "kill"]
    assert not FaultPlan().tick("dispatch") and FaultPlan().empty


def test_chaos_clock_virtual_delay():
    clk = ChaosClock(10.0)
    assert clk() == 10.0
    clk.sleep(2.5)
    assert clk() == 12.5
    from repro.dist.fault import apply_delay
    t0 = time.monotonic()
    apply_delay(clk, 100.0)                 # virtual: must not wall-sleep
    assert time.monotonic() - t0 < 5.0
    assert clk() == 112.5


def test_poison_corpus_modes_and_copy():
    embs, _, _ = _dataset()
    for mode in ("nan", "inf", "neginf"):
        poisoned, rows = poison_corpus(embs, 0.01, seed=3, mode=mode)
        assert rows.shape == (embs.shape[0],) and rows.any()
        assert np.isfinite(embs).all()              # input untouched
        assert not np.isfinite(poisoned[rows]).all()
        assert np.array_equal(poisoned[~rows], embs[~rows])


# -- degrade ladder -------------------------------------------------------


def test_degrade_ladder_levels_and_knobs():
    lad = DegradeLadder()
    assert [lad.level_for(r) for r in (2.0, 1.0, 0.7, 0.4, 0.1, -1.0)] == \
        [0, 0, 1, 2, 3, 3]
    assert lad.knobs(0) == (1.0, 0)                  # bit-identity rung
    assert lad.knobs(1) == (2.0, 0)
    assert lad.knobs(2) == (4.0, 8)
    assert lad.knobs(3) == (8.0, 4)
    assert lad.knobs(99) == (8.0, 4)                 # clamps
    with pytest.raises(ValueError, match="equal length"):
        DegradeLadder(headrooms=(1.0,), alpha_scales=(2.0, 3.0),
                      round_caps=(0,))
    with pytest.raises(ValueError, match="strictly decrease"):
        DegradeLadder(headrooms=(0.5, 0.5), alpha_scales=(2.0, 3.0),
                      round_caps=(0, 0))
    with pytest.raises(ValueError, match=">= 1"):
        DegradeLadder(headrooms=(1.0,), alpha_scales=(0.5,), round_caps=(0,))


def test_engine_deadline_ladder_degrades_without_recompiles():
    """A bandit engine under backpressure="degrade" with squeezed deadlines
    runs the ladder: batches record a rung > 0, completions carry it, and
    — the traced-knob contract — not a single recompile. A frozen
    ChaosClock makes the headroom ratio (and so the rung) exact."""
    embs, mask, q = _dataset(C=48)
    eng = RetrievalEngine(embs, mask, EngineConfig(
        batch_size=2, token_buckets=(8,), cand_buckets=(16,), max_k=5,
        flavor="bandit", alpha_ef=0.3, block_docs=4, block_tokens=2,
        backpressure="degrade", deadline_headroom_s=1.0),
        clock=ChaosClock())
    eng.warmup()
    rng = np.random.default_rng(0)
    for i in range(4):
        cand = rng.choice(48, 16, replace=False).astype(np.int32)
        # deadline 0.3 s vs expected service 1.0 s -> headroom ratio 0.3
        # -> rung 2 (alpha x4, rounds capped at 8)
        eng.submit(Request(query=q, k=5, deadline_s=0.3, cand_ids=cand))
    done = eng.drain()
    assert len(done) == 4
    assert all(c.degrade_level == 2 for c in done)
    assert all(np.isfinite(c.topk_scores).all() for c in done)
    s = eng.metrics.summary()
    assert s["ladder_degraded_batches"] == 2
    assert s["compiles_after_warmup"] == 0


def test_engine_ladder_level0_is_bit_identical():
    """Same stream with comfortable deadlines vs no deadlines: rung 0's
    (alpha_scale=1, round_cap=0) knobs are bitwise inert."""
    embs, mask, q = _dataset(C=48)
    cfg = EngineConfig(batch_size=2, token_buckets=(8,), cand_buckets=(16,),
                       max_k=5, flavor="bandit", alpha_ef=0.3, block_docs=4,
                       block_tokens=2)
    rng = np.random.default_rng(1)
    cands = [rng.choice(48, 16, replace=False).astype(np.int32)
             for _ in range(4)]
    outs = []
    for deadline in (None, 1e6):
        bp = "none" if deadline is None else "degrade"
        eng = RetrievalEngine(embs, mask,
                              dataclasses.replace(cfg, backpressure=bp))
        eng.warmup()
        for c in cands:
            eng.submit(Request(query=q, k=5, deadline_s=deadline,
                               cand_ids=c))
        outs.append({c.rid: c for c in eng.drain()})
    for rid, c in outs[0].items():
        np.testing.assert_array_equal(c.topk_ids, outs[1][rid].topk_ids)
        np.testing.assert_array_equal(c.topk_scores,
                                      outs[1][rid].topk_scores)
        assert c.coverage == 1.0 and c.degrade_level == 0


# -- finite-score quarantine end to end ----------------------------------


def test_engine_quarantines_poisoned_corpus_rows():
    """A NaN-poisoned corpus row reaching the candidate list is
    quarantined, never served: top-K excludes it, every returned score is
    finite, and the quarantine count surfaces in the summary."""
    embs, mask, q = _dataset(C=32)
    poisoned, rows = poison_corpus(embs, 1.0 / 32, seed=5)
    bad = int(np.flatnonzero(rows)[0])
    for flavor in ("dense", "bandit"):
        eng = RetrievalEngine(poisoned, mask, EngineConfig(
            batch_size=2, token_buckets=(8,), cand_buckets=(16,), max_k=5,
            flavor=flavor, alpha_ef=0.3, block_docs=4, block_tokens=2))
        eng.warmup()
        rng = np.random.default_rng(2)
        for _ in range(4):
            cand = rng.choice(32, 16, replace=False).astype(np.int32)
            cand[0] = bad                       # force the poisoned doc in
            eng.submit(Request(query=q, k=5, cand_ids=cand))
        done = eng.drain()
        assert len(done) == 4
        for c in done:
            assert bad not in c.topk_ids.tolist(), flavor
            assert np.isfinite(c.topk_scores).all(), flavor
            assert c.coverage == 1.0
        s = eng.metrics.summary()
        assert s["quarantined_total"] >= 4, flavor
        assert s["compiles_after_warmup"] == 0, flavor


# -- supervision ----------------------------------------------------------


def test_supervisor_restarts_within_budget_then_escalates():
    """Unit: a thread that keeps dying is restarted max_restarts times,
    then on_exhausted fires exactly once with the recorded exception."""
    deaths = []
    exhausted = []
    sup = Supervisor(max_restarts=2, interval_s=0.005,
                     on_exhausted=lambda n, e: exhausted.append((n, e)))

    def loop():
        deaths.append(1)
        exc = ChaosKill("boom")
        sup.note_failure("worker", exc)
        raise exc

    def guard():
        try:
            loop()
        except ChaosKill:
            pass

    def spawn():
        t = threading.Thread(target=guard, daemon=True)
        t.start()
        return t

    sup.watch("worker", spawn(), factory=spawn)
    sup.start()
    deadline = time.monotonic() + 10.0
    while not exhausted and time.monotonic() < deadline:
        time.sleep(0.01)
    sup.stop()
    assert len(exhausted) == 1
    assert exhausted[0][0] == "worker"
    assert isinstance(exhausted[0][1], ChaosKill)
    assert sup.restarts["worker"] == 2
    assert len(deaths) == 3                     # initial + two restarts


def test_supervised_dispatch_kill_zero_lost_zero_dup():
    """A FaultPlan kills the dispatch thread mid-stream; the watchdog
    restarts it and every request completes exactly once, served (no
    error completions) and bit-identical to an unfaulted run."""
    embs, mask, q = _dataset(C=32)
    cfg = EngineConfig(batch_size=2, token_buckets=(8,), cand_buckets=(16,),
                       max_k=5, flavor="bandit", alpha_ef=0.3, block_docs=4,
                       block_tokens=2, pipeline_depth=2, supervise=True,
                       max_thread_restarts=2)
    rng = np.random.default_rng(3)
    cands = [rng.choice(32, 16, replace=False).astype(np.int32)
             for _ in range(12)]

    def run(plan):
        eng = AsyncRetrievalEngine(embs, mask, cfg, fault_plan=plan)
        eng.warmup()
        with eng:
            for c in cands:
                eng.submit(Request(query=q, k=5, cand_ids=c))
            done = eng.drain()
        return eng, done

    plan = FaultPlan([InjectedFault(point="dispatch", at=4, action="kill")])
    eng_f, done_f = run(plan)
    eng_c, done_c = run(None)
    assert [f.action for f in plan.fired] == ["kill"]
    assert eng_f.metrics.summary()["thread_restarts"] == {
        "repro-dispatch": 1}
    for eng, done in ((eng_f, done_f), (eng_c, done_c)):
        rids = [c.rid for c in done]
        assert sorted(rids) == list(range(12))          # zero lost
        assert len(set(rids)) == len(rids)              # zero dup
        assert all(c.error is None for c in done)
        assert eng.metrics.summary()["errors"] == 0
    by_f = {c.rid: c for c in done_f}
    by_c = {c.rid: c for c in done_c}
    for rid in by_f:                                     # served identically
        np.testing.assert_array_equal(by_f[rid].topk_ids,
                                      by_c[rid].topk_ids)
        np.testing.assert_array_equal(by_f[rid].topk_scores,
                                      by_c[rid].topk_scores)


def test_supervised_admit_kill_recovers():
    """Same guarantee when the ADMIT thread dies (the prepared-batch
    hand-off must survive the restart)."""
    embs, mask, q = _dataset(C=32)
    plan = FaultPlan([InjectedFault(point="admit", at=3, action="kill")])
    eng = AsyncRetrievalEngine(embs, mask, EngineConfig(
        batch_size=2, token_buckets=(8,), cand_buckets=(16,), max_k=5,
        flavor="dense", pipeline_depth=2, supervise=True), fault_plan=plan)
    eng.warmup()
    rng = np.random.default_rng(4)
    with eng:
        for _ in range(8):
            cand = rng.choice(32, 16, replace=False).astype(np.int32)
            eng.submit(Request(query=q, k=5, cand_ids=cand))
        done = eng.drain()
    assert sorted(c.rid for c in done) == list(range(8))
    assert all(c.error is None for c in done)
    assert eng.metrics.summary()["thread_restarts"] == {"repro-admit": 1}


def test_unsupervised_kill_still_fails_loudly():
    """supervise=False preserves the legacy contract: a dead serving
    thread surfaces as RuntimeError("serving thread died"). The raise
    consumes the exception, so the follow-up stop() runs the shutdown
    flush — every stranded request is resolved, none dangle."""
    embs, mask, q = _dataset(C=32)
    plan = FaultPlan([InjectedFault(point="dispatch", at=1, action="kill")])
    eng = AsyncRetrievalEngine(embs, mask, EngineConfig(
        batch_size=2, token_buckets=(8,), cand_buckets=(16,), max_k=5,
        flavor="dense", supervise=False), fault_plan=plan)
    eng.warmup()
    rng = np.random.default_rng(5)
    # submit BEFORE start: the tick-1 kill fires almost instantly, and a
    # post-kill submit would itself raise via _raise_if_failed.
    rids = [eng.submit(Request(
        query=q, k=5,
        cand_ids=rng.choice(32, 16, replace=False).astype(np.int32)))
        for _ in range(4)]
    eng.start()
    with pytest.raises(RuntimeError, match="serving thread died"):
        eng.drain()
    eng.stop()                   # exception consumed above: stop() flushes
    for rid in rids:                         # resolve-or-fail: no dangles
        fut = eng.future(rid)
        assert fut is not None and fut.done()
    assert sorted(c.rid for c in eng.poll()) == sorted(rids)


def test_supervision_budget_exhaustion_escalates():
    """More kills than max_thread_restarts: the watchdog gives up and the
    engine fails loudly; every future is still resolved."""
    embs, mask, q = _dataset(C=32)
    plan = FaultPlan([InjectedFault(point="dispatch", at=t, action="kill")
                      for t in (1, 2, 3)])
    eng = AsyncRetrievalEngine(embs, mask, EngineConfig(
        batch_size=2, token_buckets=(8,), cand_buckets=(16,), max_k=5,
        flavor="dense", supervise=True, max_thread_restarts=1,
        supervise_interval_s=0.005), fault_plan=plan)
    eng.warmup()
    rng = np.random.default_rng(6)
    rids = [eng.submit(Request(
        query=q, k=5,
        cand_ids=rng.choice(32, 16, replace=False).astype(np.int32)))
        for _ in range(4)]
    eng.start()
    with pytest.raises(RuntimeError, match="serving thread died"):
        eng.drain()
    eng.stop()                   # exception consumed above: stop() flushes
    assert eng.metrics.summary()["thread_restarts"]["repro-dispatch"] == 1
    assert all(eng.future(r) is not None and eng.future(r).done()
               for r in rids)


# -- stop() flush-and-complete -------------------------------------------


def test_stop_flushes_queued_work_no_futures_dangle():
    """stop() without drain(): everything admitted is still SERVED (the
    flush completes queued and in-flight batches) and every future
    resolves — the old silently-abandoned-queue behavior is gone."""
    embs, mask, q = _dataset(C=32)
    eng = AsyncRetrievalEngine(embs, mask, EngineConfig(
        batch_size=4, deadline_s=30.0, token_buckets=(8,),
        cand_buckets=(16,), max_k=5, flavor="dense", pipeline_depth=2))
    eng.warmup()
    rng = np.random.default_rng(7)
    eng.start()
    rids = [eng.submit(Request(
        query=q, k=5,
        cand_ids=rng.choice(32, 16, replace=False).astype(np.int32)))
        for _ in range(10)]                      # 2.5 batches, none due
    eng.stop()                                   # no drain on purpose
    done = eng.poll()
    assert sorted(c.rid for c in done) == sorted(rids)
    assert all(c.error is None for c in done)
    for rid in rids:
        fut = eng.future(rid)
        assert fut.done() and fut.result().rid == rid
    assert eng.metrics.summary()["errors"] == 0


def test_stop_flushes_continuous_stream():
    """Continuous mode: stop() serves the queued stream before exiting."""
    embs, mask, q = _dataset(C=32)
    eng = AsyncRetrievalEngine(embs, mask, EngineConfig(
        batch_size=2, token_buckets=(8,), cand_buckets=(16,), max_k=5,
        flavor="bandit", alpha_ef=0.3, block_docs=4, block_tokens=2,
        continuous=True, stream_trip_limit=2))
    eng.warmup()
    rng = np.random.default_rng(8)
    eng.start()
    rids = [eng.submit(Request(
        query=q, k=5,
        cand_ids=rng.choice(32, 16, replace=False).astype(np.int32)))
        for _ in range(6)]
    eng.stop()
    done = eng.poll()
    assert sorted(c.rid for c in done) == sorted(rids)
    assert all(c.error is None and c.coverage == 1.0 for c in done)


# -- shard failover (mesh subprocess) ------------------------------------

_MESH_SETUP = """
import numpy as np
from repro.serve import AsyncRetrievalEngine, EngineConfig, Request

rng = np.random.default_rng(0)
C, L, M, T = 47, 6, 8, 8
embs = rng.standard_normal((C, L, M)).astype(np.float32)
embs /= np.linalg.norm(embs, axis=-1, keepdims=True)
mask = np.arange(L)[None] < rng.integers(3, L + 1, C)[:, None]
qs = rng.standard_normal((16, T, M)).astype(np.float32)
qs /= np.linalg.norm(qs, axis=-1, keepdims=True)
cfg = EngineConfig(batch_size=4, token_buckets=(8,), cand_buckets=(16,),
                   max_k=5, flavor="bandit", alpha_ef=0.3, block_docs=4,
                   block_tokens=2,
                   mesh_axes=(("data", 2), ("model", 2)))
eng = AsyncRetrievalEngine(embs, mask, cfg)
eng.warmup()

def serve(n0):
    for i in range(8):
        cand = rng.choice(C, 16, replace=False).astype(np.int32)
        if 30 not in cand:
            cand[0] = 30        # guarantee a shard-2 doc in every request
        eng.submit(Request(query=qs[(n0 + i) % 16], k=5, cand_ids=cand))
    return eng.drain()
"""


def test_shard_failover_partial_coverage_and_recovery():
    """fail_shard: completions report coverage < 1, the dead shard's docs
    vanish from top-K, metrics expose health + failover count; restore:
    coverage returns to 1.0 — all with ZERO recompiles (the health mask is
    a traced operand)."""
    out = run_in_subprocess(_MESH_SETUP + """
healthy = serve(0)
assert all(c.coverage == 1.0 for c in healthy)
dps = eng.corpus.docs_per_shard
eng.fail_shard(2)
down = serve(8)
assert all(0.0 <= c.coverage < 1.0 for c in down), \
    [c.coverage for c in down]
for c in down:
    ids = c.topk_ids[c.topk_ids >= 0]
    assert not np.any(ids // dps == 2), (ids, dps)   # dead shard masked
s = eng.metrics.summary()
assert s["failovers"] == 1
assert s["shard_healthy"] == [True, True, False, True]
eng.restore_shard(2)
back = serve(16)
assert all(c.coverage == 1.0 for c in back)
assert eng.metrics.summary()["shard_healthy"] == [True] * 4
assert eng.metrics.compiles_after_warmup == 0
print("FAILOVER_OK")
    """, n_devices=4)
    assert "FAILOVER_OK" in out


def test_routed_failover_reroutes_quota_mass():
    """Routed (shard-local stage-1) engine: failing a shard re-routes its
    quota mass to the healthy shards (dead shard share -> 0, shares still
    sum to 1) and completions carry the corpus-mass coverage."""
    out = run_in_subprocess("""
import numpy as np
from repro.serve import EngineConfig, Request, RetrievalEngine

rng = np.random.default_rng(1)
C, L, M, T = 47, 6, 8, 8
embs = rng.standard_normal((C, L, M)).astype(np.float32)
embs /= np.linalg.norm(embs, axis=-1, keepdims=True)
mask = np.arange(L)[None] < rng.integers(3, L + 1, C)[:, None]
qs = rng.standard_normal((8, T, M)).astype(np.float32)
qs /= np.linalg.norm(qs, axis=-1, keepdims=True)
eng = RetrievalEngine(embs, mask, EngineConfig(
    batch_size=4, token_buckets=(8,), cand_buckets=(16,), max_k=5,
    flavor="bandit", alpha_ef=0.3, block_docs=4, block_tokens=2,
    stage1="local", stage1_kprime=100000, stage1_candidates=16,
    stage1_total=8, mesh_axes=(("data", 2), ("model", 2))))
eng.warmup()
eng.fail_shard(1)
for i in range(8):
    eng.submit(Request(query=qs[i], k=5))
done = eng.drain()
vd = np.asarray(eng.corpus.valid_docs, float)
want_cov = float(vd[[0, 2, 3]].sum() / vd.sum())
assert all(abs(c.coverage - want_cov) < 1e-6 for c in done), \
    [c.coverage for c in done]
dps = eng.corpus.docs_per_shard
for c in done:
    ids = c.topk_ids[c.topk_ids >= 0]
    assert len(ids) and not np.any(ids // dps == 1)
qs_share = eng.metrics.summary()["routed_quota_share_mean"]
assert qs_share[1] == 0.0, qs_share                 # no quota to the dead
assert abs(sum(qs_share) - 1.0) < 1e-4
assert eng.metrics.compiles_after_warmup == 0
print("ROUTED_FAILOVER_OK")
    """, n_devices=4)
    assert "ROUTED_FAILOVER_OK" in out


def test_fail_shard_needs_mesh():
    embs, mask, _ = _dataset()
    eng = RetrievalEngine(embs, mask, EngineConfig(
        batch_size=2, token_buckets=(8,), cand_buckets=(16,), max_k=5))
    assert eng.shard_health() is None
    with pytest.raises(ValueError, match="mesh"):
        eng.fail_shard(0)
