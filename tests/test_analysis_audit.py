"""ISSUE 9 compile-contract auditor (repro.analysis.hlo_audit).

Unit rules run over synthetic HLO text; the engine cases warm a REAL
serving engine under ``EngineConfig(audit=True)`` and pin the paper-level
contract: a mesh-resident serving step's only cross-shard traffic is the
scorecard merge — per-shard top-K (scores, gids) all-gathers plus two
scalar psums, exactly :func:`scorecard_budget_bytes` — no compiled step
ever syncs with the host, and a bf16 corpus never enters an executable
as a full-size f32 parameter. Mesh engines run in device subprocesses
(tests/_subproc.py)."""
import numpy as np
import pytest

from _subproc import run_in_subprocess
from repro.analysis.hlo_audit import (AuditError, AuditSpec, _shape_bytes,
                                      audit_hlo_text, collective_bytes,
                                      scorecard_budget_bytes)


# ---------------------------------------------------------------------------
# Shape / byte accounting
# ---------------------------------------------------------------------------

def test_shape_bytes_scalar_vector_and_zero_width():
    assert _shape_bytes("f32", "") == 4            # scalar f32[]
    assert _shape_bytes("pred", "") == 1
    assert _shape_bytes("bf16", "8,128") == 8 * 128 * 2
    assert _shape_bytes("s32", "3") == 12
    assert _shape_bytes("token", "") == 0          # token[] is legal HLO


def test_shape_bytes_unknown_dtype_raises():
    """A dtype missing from the table must fail LOUDLY: a silent 0 would
    undercount collective traffic and pass the budget audit vacuously."""
    with pytest.raises(ValueError, match="unknown HLO dtype 'f320'"):
        _shape_bytes("f320", "8")
    with pytest.raises(ValueError, match="unknown HLO dtype"):
        _shape_bytes("quaternion", "")


_COLLECTIVE_HLO = """\
HloModule m

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %tup = (f32[2,4]{1,0}, s32[2,4]{1,0}) all-reduce(%p0, %p0), to_apply=%add
  %sc = f32[] all-reduce(%p0), to_apply=%add
  %st = f32[4,8]{1,0} all-gather-start(%p0), dimensions={0}
  %dn = f32[4,8]{1,0} all-gather-done(%st)
}
"""


def test_collective_bytes_tuple_scalar_and_async_pairs():
    got = collective_bytes(_COLLECTIVE_HLO)
    # tuple result: BOTH element shapes count; scalar f32[] adds 4.
    assert got["all-reduce"] == (2 * 4 * 4) * 2 + 4
    # -start counted once, the matching -done skipped (no double count).
    assert got["all-gather"] == 4 * 8 * 4
    assert got["total"] == got["all-reduce"] + got["all-gather"]


def test_scorecard_budget_formula():
    # (B, K) f32 scores + (B, K) s32 gids per shard, + two f32[B] psums.
    assert scorecard_budget_bytes(2, 4, 4) == 2 * 2 * 4 * 4 * 4 + 2 * 2 * 4
    assert scorecard_budget_bytes(1, 1, 1) == 8 + 8


# ---------------------------------------------------------------------------
# Text-level audit rules
# ---------------------------------------------------------------------------

_CLEAN = """\
HloModule m

%fused_computation (param_0: f32[64,128]) -> f32[64,128] {
  %param_0 = f32[64,128]{1,0} parameter(0)
  ROOT %t = f32[64,128]{1,0} tanh(%param_0)
}

ENTRY %main (Arg_0.1: bf16[64,128]) -> f32[8] {
  %Arg_0.1 = bf16[64,128]{1,0} parameter(0)
  %c = f32[64,128]{1,0} convert(%Arg_0.1)
  ROOT %r = f32[8]{0} slice(%c), slice={[0:8], [0:1]}
}
"""


def test_audit_passes_clean_hlo():
    spec = AuditSpec(collective_budget=0, corpus_dtype="bf16",
                     corpus_elems=64 * 128)
    rep = audit_hlo_text(_CLEAN, spec)
    assert rep.collective_total == 0


def test_host_sync_rule_fires_on_side_effecting_custom_call():
    bad = _CLEAN.replace(
        "%c = f32[64,128]{1,0} convert(%Arg_0.1)",
        '%c = f32[64,128]{1,0} custom-call(%Arg_0.1), '
        'custom_call_target="xla_python_cpu_callback", '
        "custom_call_has_side_effect=true")
    with pytest.raises(AuditError) as ei:
        audit_hlo_text(bad, AuditSpec())
    assert ei.value.rule == "hlo-host-sync"
    assert "custom-call" in str(ei.value)      # provenance line attached


def test_host_sync_rule_fires_on_infeed():
    bad = _CLEAN.replace("%c = f32[64,128]{1,0} convert(%Arg_0.1)",
                         "%c = (f32[64,128]{1,0}, token[]) infeed(%tok)")
    with pytest.raises(AuditError) as ei:
        audit_hlo_text(bad, AuditSpec())
    assert ei.value.rule == "hlo-host-sync"


def test_host_sync_rule_passes_benign_topk_custom_call():
    """CPU lowers lax.top_k to a side-effect-FREE custom-call — the rule
    is side-effect/target based, not any-custom-call based."""
    ok = _CLEAN.replace(
        "%c = f32[64,128]{1,0} convert(%Arg_0.1)",
        '%c = (f32[64,8]{1,0}, s32[64,8]{1,0}) custom-call(%Arg_0.1), '
        'custom_call_target="TopK"')
    audit_hlo_text(ok, AuditSpec())


def test_f64_rule():
    bad = _CLEAN.replace("%c = f32[64,128]{1,0} convert(%Arg_0.1)",
                         "%c = f64[64,128]{1,0} convert(%Arg_0.1)")
    with pytest.raises(AuditError) as ei:
        audit_hlo_text(bad, AuditSpec())
    assert ei.value.rule == "hlo-f64"


def test_corpus_promotion_rule_checks_entry_params_only():
    """The fusion computation in _CLEAN already holds a corpus-sized f32
    ``parameter(0)`` (XLA legally hoists bf16->f32 converts into fusions);
    only an ENTRY parameter means the RESIDENT corpus was promoted."""
    spec = AuditSpec(corpus_dtype="bf16", corpus_elems=64 * 128)
    audit_hlo_text(_CLEAN, spec)               # fusion param: no violation
    bad = _CLEAN.replace("%Arg_0.1 = bf16[64,128]{1,0} parameter(0)",
                         "%Arg_0.1 = f32[64,128]{1,0} parameter(0)")
    with pytest.raises(AuditError) as ei:
        audit_hlo_text(bad, spec)
    assert ei.value.rule == "hlo-corpus-promotion"


def test_corpus_promotion_rule_inactive_for_f32_corpus():
    bad_param = _CLEAN.replace("bf16[64,128]{1,0} parameter",
                               "f32[64,128]{1,0} parameter")
    audit_hlo_text(bad_param, AuditSpec(corpus_dtype="f32",
                                        corpus_elems=64 * 128))


def test_collective_budget_rule():
    bad = _CLEAN.replace(
        "%c = f32[64,128]{1,0} convert(%Arg_0.1)",
        "%c = f32[64,128]{1,0} all-gather(%Arg_0.1), dimensions={0}")
    with pytest.raises(AuditError) as ei:
        audit_hlo_text(bad, AuditSpec(collective_budget=64))
    assert ei.value.rule == "hlo-collective-budget"
    audit_hlo_text(bad, AuditSpec(collective_budget=64 * 128 * 4))  # within
    audit_hlo_text(bad, AuditSpec(collective_budget=None))          # unaudited


# ---------------------------------------------------------------------------
# The real engine under EngineConfig(audit=True)
# ---------------------------------------------------------------------------

def _toy(dtype=np.float32, C=64, L=8, M=16, seed=0):
    rng = np.random.default_rng(seed)
    embs = rng.standard_normal((C, L, M)).astype(dtype)
    mask = np.ones((C, L), bool)
    return embs, mask


_CFG = dict(batch_size=2, token_buckets=(8,), cand_buckets=(16,), max_k=4,
            block_docs=4, block_tokens=4)


def test_engine_warmup_audit_single_device_passes():
    from repro.serve.engine import EngineConfig, RetrievalEngine
    embs, mask = _toy()
    eng = RetrievalEngine(embs, mask,
                          EngineConfig(flavor="dense", audit=True, **_CFG))
    eng.warmup()
    rep = eng.audit()
    assert set(rep) == set(eng.compiled_buckets)
    # Off-mesh there is no legitimate collective traffic at all.
    assert all(r.collective_total == 0 for r in rep.values())


def test_engine_audit_flags_injected_host_callback():
    """Inject a host-callback executable into the warmed cache: audit()
    must fail it with the host-sync rule and name the bucket."""
    import jax
    import jax.numpy as jnp
    from repro.serve.engine import EngineConfig, RetrievalEngine
    embs, mask = _toy()
    eng = RetrievalEngine(embs, mask,
                          EngineConfig(flavor="dense", audit=True, **_CFG))
    eng.warmup()

    def chatty(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2.0

    bad = jax.jit(chatty).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    eng._exec[("step", "dense", 8, 16)] = bad
    with pytest.raises(AuditError) as ei:
        eng.audit()
    assert ei.value.rule == "hlo-host-sync"
    assert "('step', 'dense', 8, 16)" in str(ei.value)


def test_engine_audit_require_bf16_flags_f32_corpus():
    from repro.serve.engine import EngineConfig, RetrievalEngine
    embs, mask = _toy(np.float32)
    eng = RetrievalEngine(embs, mask, EngineConfig(
        flavor="dense", audit=True, audit_require_bf16=True, **_CFG))
    with pytest.raises(AuditError) as ei:
        eng.warmup()
    assert ei.value.rule == "hlo-corpus-promotion"


def test_engine_audit_peak_buffer_bound():
    from repro.serve.engine import EngineConfig, RetrievalEngine
    embs, mask = _toy()
    eng = RetrievalEngine(embs, mask, EngineConfig(
        flavor="dense", audit=True, audit_peak_bytes=1, **_CFG))
    with pytest.raises(AuditError) as ei:
        eng.warmup()
    assert ei.value.rule == "hlo-peak-buffer"


_ROUTED_AUDIT = """
import numpy as np
import jax.numpy as jnp
from repro.analysis.hlo_audit import scorecard_budget_bytes
from repro.serve.engine import EngineConfig, RetrievalEngine

rng = np.random.default_rng(0)
C, L, M = 64, 8, 16
embs = rng.standard_normal((C, L, M)).astype(np.float32)
mask = np.ones((C, L), bool)
cfg = EngineConfig(batch_size=2, token_buckets=(8,), cand_buckets=(16,),
                   max_k=4, flavor="%(flavor)s", mesh_axes=(("data", 4),),
                   stage1="local", stage1_centroids=4, stage1_total=16,
                   block_docs=4, block_tokens=4, audit=True,
                   audit_require_bf16=True)
eng = RetrievalEngine(jnp.asarray(embs, jnp.bfloat16), mask, cfg)
eng.warmup()                                   # audit=True runs here
budget = scorecard_budget_bytes(2, 4, 4)
reports = eng.audit()
stepish = {k: r for k, r in reports.items() if k[0] in ("step", "routed")}
assert stepish, sorted(reports)
for key, rep in stepish.items():
    assert 0 < rep.collective_total <= budget, (key, rep.collective_total)
print("AUDIT_OK", budget)
"""


@pytest.mark.parametrize("flavor", ["dense", "bandit"])
def test_routed_mesh_warmup_audit_within_scorecard_budget(flavor):
    """The acceptance pin: a 4-shard routed engine warms under audit=True
    and every sharded/routed step's collective traffic fits the scorecard
    budget — made structural by _merge_scorecards's per-shard pre-top-K."""
    out = run_in_subprocess(_ROUTED_AUDIT % {"flavor": flavor}, n_devices=4)
    assert "AUDIT_OK 272" in out
