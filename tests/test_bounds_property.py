"""Property-test hardening for the bandit decision bounds (ISSUE 2).

Three paper-level invariants of `repro.core.bounds`, each driven by
hypothesis (real package when installed, `repro.testing.hypothesis_fallback`
otherwise), plus direct tests that exercise the fallback implementation
itself — the fallback must keep finding real counterexamples even in
hermetic containers where hypothesis cannot be installed.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bounds as B
from repro.testing import hypothesis_fallback as hf

# Scheduled CI sets this > 1 to run the same properties with a larger
# example budget (see .github/workflows/ci.yml, job `property-scheduled`).
_MULT = max(1, int(os.environ.get("REPRO_HYP_EXAMPLES_MULT", "1")))


def _row_stats(H, revealed):
    """Incremental statistics (n, total, total_sq) for a reveal mask."""
    rev = revealed.astype(np.float32)
    return (revealed.sum(-1).astype(np.int32), (H * rev).sum(-1),
            ((H ** 2) * rev).sum(-1))


def _intervals(H, revealed, *, alpha_ef, a=None, b=None, delta=0.01):
    N, T = H.shape
    n, total, total_sq = _row_stats(H, revealed)
    a = np.zeros((N, T), np.float32) if a is None else a
    b = np.ones((N, T), np.float32) if b is None else b
    return B.intervals(jnp.asarray(n), jnp.asarray(total),
                       jnp.asarray(total_sq), jnp.asarray(revealed),
                       jnp.asarray(a), jnp.asarray(b),
                       T=T, N=N, delta=delta, alpha_ef=alpha_ef)


# ---------------------------------------------------------------------------
# Invariant 1: interval widths shrink monotonically as cells are revealed.
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.floats(0.1, 2.0))
@settings(max_examples=25 * _MULT, deadline=None)
def test_width_shrinks_monotonically_under_reveal(seed, alpha_ef):
    """Revealing one more cell never widens the MEAN hybrid interval: hard
    bounds tighten cell-by-cell and the stochastic radius shrinks in n (the
    per-row hybrid width is the min of the two, evaluated on a random
    reveal order)."""
    rng = np.random.default_rng(seed)
    N, T = 6, 16
    H = rng.uniform(0, 1, (N, T)).astype(np.float32)
    revealed = np.zeros((N, T), bool)
    order = [(i, t) for i in range(N) for t in range(T)]
    rng.shuffle(order)

    prev_hard = None
    for step, (i, t) in enumerate(order):
        revealed[i, t] = True
        if step % 13 != 0 and step != len(order) - 1:
            continue                      # evaluate at a sample of prefixes
        iv = _intervals(H, revealed, alpha_ef=alpha_ef)
        hard = float(jnp.mean(iv.ub_hard - iv.lb_hard))
        assert np.all(np.asarray(iv.lcb) <= np.asarray(iv.ucb) + 1e-5)
        if prev_hard is not None:
            assert hard <= prev_hard + 1e-4, (step, hard, prev_hard)
        prev_hard = hard
    # fully revealed: width collapses to zero
    iv = _intervals(H, revealed, alpha_ef=alpha_ef)
    np.testing.assert_allclose(np.asarray(iv.ucb - iv.lcb), 0.0, atol=1e-4)


@given(st.integers(0, 2**31 - 1), st.floats(0.05, 2.0))
@settings(max_examples=25 * _MULT, deadline=None)
def test_superset_reveal_tightens_hard_bounds_per_row(seed, alpha_ef):
    """For any reveal masks R1 subset R2: the R2 hard interval is nested in
    the R1 hard interval, per row — and the hybrid interval is always
    clipped inside its own hard interval (no stochastic escape). The
    stochastic radius alone is NOT monotone (a surprising new value can
    inflate sigma), which is exactly why Eq. 13/14 hard-clips."""
    rng = np.random.default_rng(seed)
    N, T = 6, 20
    H = rng.uniform(0, 1, (N, T)).astype(np.float32)
    r1 = rng.random((N, T)) < 0.3
    r2 = r1 | (rng.random((N, T)) < 0.3)
    iv1 = _intervals(H, r1, alpha_ef=alpha_ef)
    iv2 = _intervals(H, r2, alpha_ef=alpha_ef)
    assert np.all(np.asarray(iv2.lb_hard) >= np.asarray(iv1.lb_hard) - 1e-5)
    assert np.all(np.asarray(iv2.ub_hard) <= np.asarray(iv1.ub_hard) + 1e-5)
    for iv in (iv1, iv2):
        assert np.all(np.asarray(iv.lcb) >= np.asarray(iv.lb_hard) - 1e-5)
        assert np.all(np.asarray(iv.ucb) <= np.asarray(iv.ub_hard) + 1e-5)


# ---------------------------------------------------------------------------
# Invariant 2: fully-revealed rows pin the true row-sum exactly.
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 30))
@settings(max_examples=25 * _MULT, deadline=None)
def test_bounds_contain_truth_on_fully_revealed_rows(seed, t_dim):
    rng = np.random.default_rng(seed)
    N = 5
    H = rng.uniform(0, 1, (N, t_dim)).astype(np.float32)
    revealed = np.ones((N, t_dim), bool)
    iv = _intervals(H, revealed, alpha_ef=0.3)
    S = H.sum(-1)
    assert np.all(np.asarray(iv.lcb) <= S + 1e-4)
    assert np.all(np.asarray(iv.ucb) >= S - 1e-4)
    np.testing.assert_allclose(np.asarray(iv.s_hat), S, atol=1e-4)
    np.testing.assert_allclose(np.asarray(iv.ucb - iv.lcb), 0.0, atol=1e-4)


# ---------------------------------------------------------------------------
# Invariant 3: alpha_ef = 1 intervals contain alpha_ef < 1 intervals.
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.99),
       st.integers(2, 30))
@settings(max_examples=30 * _MULT, deadline=None)
def test_alpha1_interval_contains_smaller_alpha(seed, alpha, n_obs):
    """alpha_ef scales the stochastic radius, and hard-bound clipping is
    monotone in the radius — so the relaxed interval is always nested
    inside the alpha_ef=1 interval."""
    rng = np.random.default_rng(seed)
    N, T = 6, 30
    H = rng.uniform(0, 1, (N, T)).astype(np.float32)
    revealed = np.zeros((N, T), bool)
    for i in range(N):
        revealed[i, rng.choice(T, min(n_obs, T), replace=False)] = True
    iv1 = _intervals(H, revealed, alpha_ef=1.0)
    iva = _intervals(H, revealed, alpha_ef=alpha)
    assert np.all(np.asarray(iv1.lcb) <= np.asarray(iva.lcb) + 1e-5)
    assert np.all(np.asarray(iv1.ucb) >= np.asarray(iva.ucb) - 1e-5)


# ---------------------------------------------------------------------------
# The hermetic fallback path itself (runs even when real hypothesis is
# installed: the fallback module is imported and driven directly).
# ---------------------------------------------------------------------------

def test_fallback_given_runs_boundary_then_random_examples():
    seen = []

    @hf.given(hf.integers(3, 9), hf.floats(0.0, 1.0))
    @hf.settings(max_examples=8, deadline=None)
    def prop(n, x):
        seen.append((n, x))
        assert 3 <= n <= 9 and 0.0 <= x <= 1.0

    prop()
    assert len(seen) == 8
    assert seen[0] == (3, 0.0)          # lower boundary combo first
    assert seen[1] == (9, 1.0)          # then the upper boundary combo


def test_fallback_drives_a_real_bounds_property():
    """The fully-revealed-rows invariant, via the fallback engine."""
    runs = []

    @hf.given(hf.integers(0, 10_000))
    @hf.settings(max_examples=6, deadline=None)
    def prop(seed):
        runs.append(seed)
        rng = np.random.default_rng(seed)
        H = rng.uniform(0, 1, (4, 12)).astype(np.float32)
        iv = _intervals(H, np.ones((4, 12), bool), alpha_ef=0.5)
        np.testing.assert_allclose(np.asarray(iv.s_hat), H.sum(-1),
                                   atol=1e-4)

    prop()
    assert len(runs) == 6


def test_fallback_reports_falsifying_example():
    @hf.given(hf.integers(0, 100))
    @hf.settings(max_examples=5, deadline=None)
    def always_fails(n):
        assert n < 0

    with pytest.raises(AssertionError, match="falsifying example"):
        always_fails()
