"""LM flavors: train/prefill/decode consistency across the assigned
attention variants (GQA, SWA ring cache, local/global + softcaps, QKV bias,
MoE) on reduced configs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig
from repro.models.transformer import (forward_decode, forward_prefill,
                                      forward_train, init_cache, init_lm)
from repro.serve.engine import generate

FLAVORS = {
    "dense-gqa": LMConfig(name="d", n_layers=3, d_model=64, n_heads=4,
                          n_kv_heads=2, d_head=16, d_ff=128, vocab=256),
    "swa-ring": LMConfig(name="s", n_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=4, d_head=16, d_ff=128, vocab=256,
                         sliding_window=8),
    "moe": LMConfig(name="m", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=4, d_head=16, d_ff=0, moe=True, n_experts=4,
                    experts_top_k=2, moe_d_ff=96, vocab=256,
                    moe_capacity_factor=8.0),
    "gemma-style": LMConfig(name="g", n_layers=4, d_model=64, n_heads=4,
                            n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                            sliding_window=8, local_global_alternating=True,
                            attn_softcap=50.0, logit_softcap=30.0,
                            act="gelu"),
    "qkv-bias": LMConfig(name="q", n_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                         qkv_bias=True),
}


@pytest.fixture(scope="module")
def rng_key():
    return jax.random.key(0)


@pytest.mark.parametrize("flavor", list(FLAVORS))
def test_decode_matches_train_forward(flavor, rng_key):
    cfg = FLAVORS[flavor]
    params = init_lm(rng_key, cfg)
    tokens = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab)
    logits = forward_train(params, cfg, tokens, remat=False)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    last, cache = forward_prefill(params, cfg, tokens, max_seq=32,
                                  cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, -1]),
                               atol=1e-4)
    # 4 decode steps (crosses the w=8 ring boundary for SWA flavors)
    seq = tokens
    cur = jnp.argmax(last, -1)
    for step in range(4):
        dec, cache = forward_decode(params, cfg, cur, jnp.int32(16 + step),
                                    cache)
        seq = jnp.concatenate([seq, cur[:, None]], axis=1)
        ref = forward_train(params, cfg, seq, remat=False)[:, -1]
        np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                                   atol=1e-4)
        cur = jnp.argmax(dec, -1)


@pytest.mark.parametrize("flavor", ["dense-gqa", "gemma-style"])
def test_q_chunked_attention_equivalent(flavor, rng_key):
    cfg = FLAVORS[flavor]
    params = init_lm(rng_key, cfg)
    tokens = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab)
    full = forward_train(params, cfg, tokens, remat=False)
    chunked = forward_train(params, dataclasses.replace(cfg, attn_q_chunk=4),
                            tokens, remat=False)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-4)


def test_remat_does_not_change_values(rng_key):
    cfg = FLAVORS["dense-gqa"]
    params = init_lm(rng_key, cfg)
    tokens = jax.random.randint(rng_key, (2, 8), 0, cfg.vocab)
    a = forward_train(params, cfg, tokens, remat=False)
    b = forward_train(params, cfg, tokens, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_generate_shapes(rng_key):
    cfg = FLAVORS["dense-gqa"]
    params = init_lm(rng_key, cfg)
    out = generate(params, cfg, jnp.ones((2, 6), jnp.int32),
                   max_new_tokens=5)
    assert out.shape == (2, 11)
    assert not bool(jnp.any(out < 0))


def test_logit_softcap_bounds_logits(rng_key):
    cfg = FLAVORS["gemma-style"]
    params = init_lm(rng_key, cfg)
    tokens = jax.random.randint(rng_key, (1, 8), 0, cfg.vocab)
    logits = forward_train(params, cfg, tokens, remat=False)
    assert float(jnp.max(jnp.abs(logits))) <= 30.0 + 1e-3
