"""ISSUE 3: pooled cross-query reveal engine (repro.core.frontier).

Three contracts:
  * full-budget parity — with hard bounds (alpha_ef -> inf) and an
    unconstrained budget, the pooled engine returns the IDENTICAL top-K set
    per query as ``run_batched_bandit`` vmapped per query (both exact);
  * frontier retirement — each query's reveal trajectory in the pooled
    engine (fixed blocks) is bit-identical to its SOLO run under the same
    key: easy queries pay exactly their solo reveal/round counts no matter
    how hard their batchmates are, and the retirement accounting
    (total_rounds vs Q*max) reflects it;
  * serving integration — rerank_bandit_step's pooled and vmapped engines
    agree, and the pooled gather path (stacked query-offset indices through
    gather_maxsim_op) matches the oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (exact_topk, run_batched_oracle, run_pooled_oracle)
from repro.data.synthetic import make_mixed_difficulty_h


def _mixed_h(seed, Q=6, N=40, T=16, k=5, n_hard=1):
    """Easy queries: clear margin at rank k. Hard queries: 2k near-ties.
    Same generator the reveal benchmark runs, so the workload the tests
    pin is the workload BENCH_reveal.json reports."""
    return jnp.asarray(make_mixed_difficulty_h(
        Q, N, T, k=k, hard_frac=n_hard / Q if n_hard else 0.0, seed=seed))


def _bounds(H):
    return jnp.zeros(H.shape, jnp.float32), jnp.ones(H.shape, jnp.float32)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_full_budget_topk_parity_with_vmapped(seed):
    """Hard-bound mode, full budget: pooled == vmapped == exact, per query."""
    H = _mixed_h(seed)
    a, b = _bounds(H)
    Q, k = H.shape[0], 5
    keys = jax.random.split(jax.random.key(seed), Q)
    kw = dict(k=k, alpha_ef=1e9, block_docs=8, block_tokens=4)
    pooled = run_pooled_oracle(H, a, b, keys, **kw)
    solo = [run_batched_oracle(H[q], a[q], b[q], keys[q], **kw)
            for q in range(Q)]
    for q in range(Q):
        want = set(map(int, np.asarray(exact_topk(H[q], k=k)[0])))
        assert set(map(int, np.asarray(pooled.topk[q]))) == want
        assert set(map(int, np.asarray(solo[q].topk))) == want
    assert bool(np.asarray(pooled.separated).all())


@pytest.mark.parametrize("seed", [3, 4])
def test_frontier_retirement_matches_solo_trajectories(seed):
    """One hard + many easy queries: every query's reveal count AND round
    count in the pooled engine equal its solo run exactly — retirement
    means easy queries never pay extra for the straggler."""
    H = _mixed_h(seed, Q=6, n_hard=1)
    a, b = _bounds(H)
    Q = H.shape[0]
    keys = jax.random.split(jax.random.key(seed), Q)
    kw = dict(k=5, alpha_ef=0.3, block_docs=8, block_tokens=4)
    pooled = run_pooled_oracle(H, a, b, keys, **kw)
    solo_rounds, solo_reveals = [], []
    for q in range(Q):
        r = run_batched_oracle(H[q], a[q], b[q], keys[q], **kw)
        solo_rounds.append(int(r.rounds))
        solo_reveals.append(int(r.reveals))
    np.testing.assert_array_equal(np.asarray(pooled.rounds), solo_rounds)
    np.testing.assert_array_equal(np.asarray(pooled.reveals), solo_reveals)
    # the straggler dominates the trip count; easy queries retired early
    assert int(pooled.trips) == max(solo_rounds)
    assert int(pooled.total_rounds) == sum(solo_rounds)
    assert int(pooled.total_rounds) < Q * max(solo_rounds)
    assert int(pooled.lockstep_waste) == Q * max(solo_rounds) - sum(solo_rounds)
    assert 0.0 < float(pooled.occupancy) <= 1.0


def test_retirement_unaffected_by_batchmates():
    """An easy query's trajectory must not change when the rest of the
    batch swaps between easy and hard batchmates (same per-query key)."""
    H_easy = _mixed_h(7, Q=4, n_hard=0)
    H_mixed = jnp.concatenate([H_easy[:2], _mixed_h(8, Q=2, n_hard=2)])
    a, b = _bounds(H_easy)
    keys = jax.random.split(jax.random.key(9), 4)
    kw = dict(k=5, alpha_ef=0.3, block_docs=8, block_tokens=4)
    r_easy = run_pooled_oracle(H_easy, a, b, keys, **kw)
    r_mixed = run_pooled_oracle(H_mixed, a, b, keys, **kw)
    np.testing.assert_array_equal(np.asarray(r_easy.reveals[:2]),
                                  np.asarray(r_mixed.reveals[:2]))
    np.testing.assert_array_equal(np.asarray(r_easy.revealed[:2]),
                                  np.asarray(r_mixed.revealed[:2]))


def test_slot_growth_reduces_trips_and_keeps_exactness():
    """max_block_docs > block_docs: freed slots go to the stragglers, the
    global trip count shrinks (never grows), and full-budget top-K stays
    exact."""
    H = _mixed_h(10, Q=8, N=40, T=16, n_hard=2)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.key(11), 8)
    kw = dict(k=5, alpha_ef=1e9, block_docs=8, block_tokens=4)
    fixed = run_pooled_oracle(H, a, b, keys, **kw)
    grown = run_pooled_oracle(H, a, b, keys, max_block_docs=24, **kw)
    assert int(grown.trips) <= int(fixed.trips)
    for q in range(8):
        want = set(map(int, np.asarray(exact_topk(H[q], k=5)[0])))
        assert set(map(int, np.asarray(grown.topk[q]))) == want


def test_oversized_max_block_docs_clamped_to_candidates():
    """max_block_docs beyond 2N must clamp to the candidate count, not
    surface as an opaque top_k shape error (reachable from EngineConfig
    alone on a small candidate bucket)."""
    H = _mixed_h(20, Q=4, N=16, T=8)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.key(21), 4)
    res = run_pooled_oracle(H, a, b, keys, k=5, alpha_ef=1e9, block_docs=8,
                            block_tokens=4, max_block_docs=40)
    for q in range(4):
        want = set(map(int, np.asarray(exact_topk(H[q], k=5)[0])))
        assert set(map(int, np.asarray(res.topk[q]))) == want


@pytest.mark.parametrize("seed", [0, 5])
def test_fused_round_bit_identical_to_chain(seed):
    """ISSUE 5 tentpole: the fused round body (one reveal launch + a
    two-scatter state update over the sentinel cell table) must reveal the
    EXACT cells the chain oracle reveals — identical trajectories, rounds,
    occupancy, and bit-identical score estimates."""
    H = _mixed_h(seed, Q=6, N=40, T=16, n_hard=2)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.fold_in(jax.random.key(seed), 50), 6)
    kw = dict(k=5, alpha_ef=0.3, block_docs=8, block_tokens=4)
    chain = run_pooled_oracle(H, a, b, keys, fused=False, **kw)
    fused = run_pooled_oracle(H, a, b, keys, fused=True, **kw)
    for field in ("topk", "reveals", "rounds", "revealed", "trips",
                  "total_rounds", "lockstep_waste", "separated"):
        np.testing.assert_array_equal(np.asarray(getattr(chain, field)),
                                      np.asarray(getattr(fused, field)),
                                      err_msg=field)
    # bit-identical, not allclose: the fused statistics perform the same
    # arithmetic in the same order, only plumbed differently
    np.testing.assert_array_equal(np.asarray(chain.s_hat),
                                  np.asarray(fused.s_hat))
    np.testing.assert_allclose(float(chain.occupancy),
                               float(fused.occupancy), rtol=1e-6)


def test_fused_round_under_growth_matches_chain():
    """Growth re-enables frontier compaction inside the fused body; the
    chain/fused equivalence must survive it (both growth axes on)."""
    H = _mixed_h(30, Q=8, N=40, T=16, n_hard=2)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.key(31), 8)
    kw = dict(k=5, alpha_ef=0.3, block_docs=8, block_tokens=4,
              max_block_docs=24, max_block_tokens=8)
    chain = run_pooled_oracle(H, a, b, keys, fused=False, **kw)
    fused = run_pooled_oracle(H, a, b, keys, fused=True, **kw)
    np.testing.assert_array_equal(np.asarray(chain.revealed),
                                  np.asarray(fused.revealed))
    np.testing.assert_array_equal(np.asarray(chain.rounds),
                                  np.asarray(fused.rounds))


def test_token_growth_never_increases_trips_and_keeps_exactness():
    """ISSUE 5 satellite (2-D slot growth): growing block_tokens alongside
    block_docs must not increase the global trip count vs doc-only growth
    (freed capacity only ever ADDS reveal cells per round), and full-budget
    top-K stays exact."""
    from repro.core import exact_topk
    H = _mixed_h(10, Q=8, N=40, T=16, n_hard=2)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.key(11), 8)
    kw = dict(k=5, alpha_ef=1e9, block_docs=8, block_tokens=4)
    doc_only = run_pooled_oracle(H, a, b, keys, max_block_docs=24, **kw)
    two_d = run_pooled_oracle(H, a, b, keys, max_block_docs=24,
                              max_block_tokens=12, **kw)
    assert int(two_d.trips) <= int(doc_only.trips)
    # more cells per straggler round => total reveal work can only help
    for q in range(8):
        want = set(map(int, np.asarray(exact_topk(H[q], k=5)[0])))
        assert set(map(int, np.asarray(two_d.topk[q]))) == want


def test_token_growth_disabled_is_solo_parity():
    """max_block_tokens == block_tokens (or 0) must leave trajectories at
    exact solo parity — the all-enabled token mask is the old fixed-G
    behavior."""
    H = _mixed_h(33, Q=4, N=32, T=12)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.key(34), 4)
    kw = dict(k=5, alpha_ef=0.3, block_docs=8, block_tokens=4)
    base = run_pooled_oracle(H, a, b, keys, **kw)
    explicit = run_pooled_oracle(H, a, b, keys, max_block_tokens=4, **kw)
    np.testing.assert_array_equal(np.asarray(base.revealed),
                                  np.asarray(explicit.revealed))


def test_unknown_engine_name_raises_value_error():
    from repro.retrieval.service import make_serving_step, rerank_bandit_step
    with pytest.raises(ValueError, match="unknown reveal engine"):
        make_serving_step("bandit", engine="pool")
    with pytest.raises(ValueError, match="unknown reveal engine"):
        rerank_bandit_step(None, None, None, None, None, None, None,
                           engine="pool")


def test_doc_mask_padding_never_revealed():
    """-1-padded candidates (doc_mask False) get no reveals and never enter
    the top-K, exactly as in the solo engine."""
    H = _mixed_h(12, Q=3, N=32, T=12)
    a, b = _bounds(H)
    doc_mask = jnp.asarray(np.arange(32) < 24)[None, :].repeat(3, axis=0)
    keys = jax.random.split(jax.random.key(13), 3)
    res = run_pooled_oracle(H, a, b, keys, k=5, alpha_ef=1e9, block_docs=8,
                            block_tokens=4, doc_mask=doc_mask)
    rev = np.asarray(res.revealed)
    assert not rev[:, 24:, :].any()
    assert (np.asarray(res.topk) < 24).all()
    for q in range(3):
        want = set(map(int, np.asarray(
            exact_topk(jnp.where(doc_mask[q][:, None], H[q], -1.0), k=5)[0])))
        assert set(map(int, np.asarray(res.topk[q]))) == want


# ---------------------------------------------------------------------------
# serving integration: rerank_bandit_step over both engines + stacked gather
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_setup():
    from repro.data.synthetic import make_retrieval_dataset
    ds = make_retrieval_dataset(n_docs=48, n_queries=4, doc_len=16,
                                min_doc_len=6, query_len=8, dim=16, seed=3)
    rng = np.random.default_rng(0)
    B, N, T = 4, 16, 8
    cand = jnp.asarray(np.stack([rng.choice(48, N, replace=False)
                                 for _ in range(B)]), jnp.int32)
    q = jnp.asarray(ds.queries[:B, :T])
    a = jnp.zeros((B, N, T), jnp.float32)
    b = jnp.ones((B, N, T), jnp.float32)
    return ds, q, cand, a, b


def test_rerank_bandit_step_engines_agree(serving_setup):
    """Hard-bound full budget: pooled and vmapped serving engines return
    the identical per-query top-K set, and the stats vector is coherent."""
    from repro.retrieval.service import rerank_bandit_step
    ds, q, cand, a, b = serving_setup
    key = jax.random.key(0)
    out = {}
    for eng in ("pooled", "pooled_fused", "pooled_chain", "vmapped"):
        s, g, f, st = rerank_bandit_step(
            ds.doc_embs, ds.doc_mask, q, cand, a, b, key, topk=5,
            alpha_ef=1e9, block_docs=4, block_tokens=4, engine=eng)
        assert st.shape == (4,)
        assert 0.0 < float(st[0]) <= 1.0
        assert float(st[3]) == 0.0          # clean corpus: none quarantined
        assert ((np.asarray(f) > 0) & (np.asarray(f) <= 1)).all()
        out[eng] = np.asarray(g)
    for eng in ("pooled", "pooled_fused", "pooled_chain"):
        for i in range(q.shape[0]):
            assert set(out[eng][i]) == set(out["vmapped"][i]), eng


def test_pooled_serving_matches_oracle_cells(serving_setup):
    """The stacked gather path (gather_maxsim_op on query-offset indices)
    must reveal the same values the precomputed-H oracle reveals: identical
    top-K and identical per-query coverage under the same keys."""
    from repro.kernels import ref as kref
    from repro.retrieval.service import gather_candidates, rerank_bandit_step
    ds, q, cand, a, b = serving_setup
    key = jax.random.key(1)
    _, gids, frac, _ = rerank_bandit_step(
        ds.doc_embs, ds.doc_mask, q, cand, a, b, key, topk=5,
        alpha_ef=1e9, block_docs=4, block_tokens=4, engine="pooled")
    docs, dmask = gather_candidates(ds.doc_embs, ds.doc_mask, cand)
    H = jnp.stack([kref.maxsim_ref(docs[i], dmask[i], q[i])
                   for i in range(q.shape[0])])
    # all-masked padding rows score 0 in the serving contract
    H = jnp.where(jnp.any(dmask, -1)[:, :, None], H, 0.0)
    keys = jax.random.split(key, q.shape[0])
    res = run_pooled_oracle(H, a, b, keys, k=5, alpha_ef=1e9, block_docs=4,
                            block_tokens=4, doc_mask=cand >= 0)
    want = np.take_along_axis(np.asarray(cand), np.asarray(res.topk), axis=1)
    for i in range(q.shape[0]):
        assert set(np.asarray(gids)[i]) == set(want[i])
    np.testing.assert_allclose(np.asarray(frac), np.asarray(res.coverage),
                               atol=1e-6)


def test_dense_step_has_no_bnlt_intermediate(monkeypatch):
    """ISSUE 3 acceptance: the compiled dense serving step must not
    materialize a (B, N, L, T) similarity tensor — its peak temp buffer
    stays strictly below that threshold (the einsum path it replaced always
    crossed it)."""
    from repro.launch.hlo_analysis import peak_buffer_bytes
    from repro.retrieval.service import rerank_dense_step

    monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
    B, C, N, L, M, T = 4, 32, 16, 512, 16, 64
    SDS = jax.ShapeDtypeStruct
    args = (SDS((C, L, M), jnp.float32), SDS((C, L), jnp.bool_),
            SDS((B, T, M), jnp.float32), SDS((B, N), jnp.int32),
            SDS((B, N, T), jnp.float32), SDS((B, N, T), jnp.float32),
            SDS((), jnp.int32))

    def step(ce, cm, q, cand, a, b, seed):
        return rerank_dense_step(ce, cm, q, cand, a, b,
                                 jax.random.key(seed), topk=10)

    peak = peak_buffer_bytes(jax.jit(step).lower(*args).compile())
    assert peak < B * N * L * T * 4, (peak, B * N * L * T * 4)


# ---------------------------------------------------------------------------
# ISSUE 7: resumable slices (continuous batching) — carry/fresh/trip_limit
# ---------------------------------------------------------------------------

from repro.core import (BatchedConfig, init_frontier_state,  # noqa: E402
                        run_pooled_bandit, run_pooled_slice)


def _cells_for(H):
    """The oracle cell closure over a precomputed (Q, N, T) tensor — the
    same flat-token mapping run_pooled_oracle builds internally."""
    Q, N, T = H.shape
    h_flat = H.reshape(Q * N, T)

    def cells(flat_doc, flat_tok):
        t_local = flat_tok - (flat_doc // N * T)[:, None]
        return h_flat[flat_doc[:, None], jnp.clip(t_local, 0, T - 1)]

    return cells


_SLICE_CFG = BatchedConfig(k=5, alpha_ef=0.3, block_docs=8, block_tokens=4)


@pytest.mark.parametrize("fused", [False, True])
def test_slice_resume_matches_one_shot(fused):
    """Pausing the pooled loop every trip_limit trips and resuming from the
    returned FrontierState must replay the one-shot run bit for bit —
    same reveals, rounds, scores and top-K for every query, under either
    round body (the PRNG keys live in the carried state)."""
    H = _mixed_h(30, Q=4, n_hard=1)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.key(30), 4)
    cells = _cells_for(H)
    want = run_pooled_bandit(cells, a, b, keys, _SLICE_CFG, fused=fused)

    Q, N, T = H.shape
    state = init_frontier_state(Q, N, T)
    fresh = jnp.ones((Q,), jnp.bool_)
    for _ in range(64):
        res, state = run_pooled_slice(cells, a, b, keys, _SLICE_CFG, state,
                                      fresh, trip_limit=2, fused=fused)
        fresh = jnp.zeros((Q,), jnp.bool_)
        if bool(np.asarray(state.done).all()):
            break
    else:
        pytest.fail("stream never quiesced")

    np.testing.assert_array_equal(np.asarray(res.topk), np.asarray(want.topk))
    np.testing.assert_array_equal(np.asarray(res.s_hat),
                                  np.asarray(want.s_hat))
    np.testing.assert_array_equal(np.asarray(res.reveals),
                                  np.asarray(want.reveals))
    np.testing.assert_array_equal(np.asarray(res.rounds),
                                  np.asarray(want.rounds))
    np.testing.assert_array_equal(np.asarray(res.revealed),
                                  np.asarray(want.revealed))


def test_slice_resume_across_round_bodies():
    """The packed FrontierState is the shared slice-boundary format: a
    stream may pause under the fused body and resume under the chain body
    (or vice versa) without changing a single revealed cell."""
    H = _mixed_h(31, Q=4, n_hard=1)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.key(31), 4)
    cells = _cells_for(H)
    want = run_pooled_bandit(cells, a, b, keys, _SLICE_CFG, fused=True)

    Q, N, T = H.shape
    state = init_frontier_state(Q, N, T)
    fresh = jnp.ones((Q,), jnp.bool_)
    for i in range(64):
        res, state = run_pooled_slice(cells, a, b, keys, _SLICE_CFG, state,
                                      fresh, trip_limit=2,
                                      fused=bool(i % 2))   # alternate bodies
        fresh = jnp.zeros((Q,), jnp.bool_)
        if bool(np.asarray(state.done).all()):
            break
    else:
        pytest.fail("stream never quiesced")

    np.testing.assert_array_equal(np.asarray(res.topk), np.asarray(want.topk))
    np.testing.assert_array_equal(np.asarray(res.revealed),
                                  np.asarray(want.revealed))
    np.testing.assert_array_equal(np.asarray(res.reveals),
                                  np.asarray(want.reveals))


def test_slice_refill_parity_with_one_shot():
    """Slot-level continuous batching: a 2-slot stream serving 4 queries
    (retired slots refilled mid-stream via ``fresh``) must give every
    query the same reveals/rounds/top-K as the 4-query one-shot run —
    with fixed blocks a slot's trajectory depends only on its own
    (query, key), never on when it was admitted or who its slotmates
    are."""
    H = _mixed_h(32, Q=4, n_hard=1)
    a, b = _bounds(H)
    keys = jax.random.split(jax.random.key(32), 4)
    want = run_pooled_bandit(_cells_for(H), a, b, keys, _SLICE_CFG)

    Q, N, T = H.shape
    S = 2                                     # stream slots
    state = init_frontier_state(S, N, T)
    slot_q = [0, 1]                           # query occupying each slot
    next_q = 2
    a_s = jnp.stack([a[0], a[1]])
    b_s = jnp.stack([b[0], b[1]])
    keys_s = jnp.stack([keys[0], keys[1]])
    fresh = np.array([True, True])
    got = {}
    for _ in range(128):
        h_slot = jnp.stack([H[slot_q[0]], H[slot_q[1]]])
        res, state = run_pooled_slice(_cells_for(h_slot), a_s, b_s, keys_s,
                                      _SLICE_CFG, state,
                                      jnp.asarray(fresh), trip_limit=2)
        fresh[:] = False
        done = np.asarray(state.done)
        for s in range(S):
            q = slot_q[s]
            if not done[s] or q in got:
                continue
            got[q] = dict(topk=np.asarray(res.topk[s]),
                          reveals=int(res.reveals[s]),
                          rounds=int(res.rounds[s]))
            if next_q < Q:                    # refill the retired slot
                slot_q[s] = next_q
                a_s = a_s.at[s].set(a[next_q])
                b_s = b_s.at[s].set(b[next_q])
                keys_s = keys_s.at[s].set(keys[next_q])
                fresh[s] = True
                next_q += 1
        if len(got) == Q:
            break
    else:
        pytest.fail("stream never served all queries")

    for q in range(Q):
        assert set(map(int, got[q]["topk"])) == \
            set(map(int, np.asarray(want.topk[q]))), q
        assert got[q]["reveals"] == int(want.reveals[q]), q
        assert got[q]["rounds"] == int(want.rounds[q]), q
