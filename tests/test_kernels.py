"""Pallas kernel sweeps vs. the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import (gather_maxsim_op, masked_maxsim_op,
                               maxsim_batch_op, maxsim_op, maxsim_scores_op)


def _inputs(N, L, M, T, dtype, seed=0):
    rng = np.random.default_rng(seed)
    E = rng.standard_normal((N, L, M)).astype(np.float32)
    E /= np.maximum(np.linalg.norm(E, axis=-1, keepdims=True), 1e-9)
    lens = rng.integers(1, L + 1, N)
    mask = np.arange(L)[None] < lens[:, None]
    E = np.where(mask[..., None], E, 0.0)
    Q = rng.standard_normal((T, M)).astype(np.float32)
    Q /= np.maximum(np.linalg.norm(Q, axis=-1, keepdims=True), 1e-9)
    return (jnp.asarray(E, dtype), jnp.asarray(mask), jnp.asarray(Q, dtype))


SHAPES = [
    (8, 64, 128, 32),     # aligned
    (20, 300, 128, 32),   # unaligned N, L
    (7, 96, 128, 13),     # unaligned everything
    (64, 729, 128, 64),   # multimodal-ish (Granite: 729 doc tokens)
    (1, 8, 128, 1),       # degenerate
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_maxsim_matches_ref(shape, dtype):
    N, L, M, T = shape
    E, mask, Q = _inputs(N, L, M, T, dtype)
    h = maxsim_op(E, mask, Q, block_n=8, block_l=128)
    h_ref = ref.maxsim_ref(E, mask, Q)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=tol)


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_masked_maxsim_matches_ref(shape):
    N, L, M, T = shape
    E, mask, Q = _inputs(N, L, M, T, jnp.float32, seed=1)
    bn, bt = 8, 8
    gi, gj = -(-N // bn), -(-T // bt)
    rng = np.random.default_rng(2)
    tm = jnp.asarray(rng.random((gi, gj)) > 0.4)
    h = masked_maxsim_op(E, mask, Q, tm, block_n=bn, block_t=bt, block_l=128)
    full = np.repeat(np.repeat(np.asarray(tm), bn, 0), bt, 1)[:N, :T]
    h_ref = np.where(full, np.asarray(ref.maxsim_ref(E, mask, Q)), 0.0)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=1e-5)


def test_masked_maxsim_inactive_tiles_exact_zero():
    E, mask, Q = _inputs(16, 64, 128, 16, jnp.float32, seed=3)
    tm = jnp.zeros((2, 2), bool)
    h = masked_maxsim_op(E, mask, Q, tm, block_n=8, block_t=8, block_l=64)
    assert (np.asarray(h) == 0.0).all()


@pytest.mark.parametrize("B,G", [(6, 4), (8, 8), (3, 1)])
def test_gather_maxsim_matches_ref(B, G):
    N, L, M, T = 24, 160, 128, 32
    E, mask, Q = _inputs(N, L, M, T, jnp.float32, seed=4)
    rng = np.random.default_rng(5)
    di = jnp.asarray(rng.integers(0, N, B), jnp.int32)
    ti = jnp.asarray(rng.integers(0, T, (B, G)), jnp.int32)
    out = gather_maxsim_op(E, mask, Q, di, ti, block_b=4, block_l=64)
    out_ref = ref.gather_maxsim_ref(E, mask, Q, di, ti)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-5)


def test_scores_equals_row_sum():
    E, mask, Q = _inputs(16, 128, 128, 32, jnp.float32, seed=6)
    s = maxsim_scores_op(E, mask, Q)
    np.testing.assert_allclose(
        np.asarray(s), np.asarray(ref.maxsim_ref(E, mask, Q).sum(-1)),
        rtol=1e-5)


@given(st.integers(1, 24), st.integers(1, 80), st.integers(1, 40),
       st.integers(0, 1000))
@settings(max_examples=12, deadline=None)
def test_maxsim_property_sweep(N, L, T, seed):
    """Hypothesis sweep over irregular shapes (M fixed at the hardware lane
    width)."""
    E, mask, Q = _inputs(N, L, 128, T, jnp.float32, seed)
    h = maxsim_op(E, mask, Q, block_n=8, block_l=64)
    h_ref = ref.maxsim_ref(E, mask, Q)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-5)


# ---------------------------------------------------------------------------
# ISSUE 2 satellite: gather/masked kernel parity on non-multiple-of-block
# shapes (the padding path inside kernels/ops.py) and all-masked documents.
# ---------------------------------------------------------------------------

ODD_SHAPES = [
    (13, 37, 128, 11),    # odd everything
    (7, 129, 128, 5),     # L just past one block
    (9, 63, 128, 17),     # L one short of a block
]


@pytest.mark.parametrize("shape", ODD_SHAPES)
def test_gather_maxsim_odd_shapes_matches_ref(shape):
    N, L, M, T = shape
    E, mask, Q = _inputs(N, L, M, T, jnp.float32, seed=7)
    rng = np.random.default_rng(8)
    B, G = 5, 3                                    # odd batch too
    di = jnp.asarray(rng.integers(0, N, B), jnp.int32)
    ti = jnp.asarray(rng.integers(0, T, (B, G)), jnp.int32)
    out = gather_maxsim_op(E, mask, Q, di, ti, block_b=4, block_l=32)
    out_ref = ref.gather_maxsim_ref(E, mask, Q, di, ti)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-5)


@pytest.mark.parametrize("shape", ODD_SHAPES)
def test_masked_maxsim_odd_shapes_matches_ref(shape):
    N, L, M, T = shape
    E, mask, Q = _inputs(N, L, M, T, jnp.float32, seed=9)
    bn, bt = 8, 8
    gi, gj = -(-N // bn), -(-T // bt)
    rng = np.random.default_rng(10)
    tm = jnp.asarray(rng.random((gi, gj)) > 0.4)
    h = masked_maxsim_op(E, mask, Q, tm, block_n=bn, block_t=bt, block_l=32)
    full = np.repeat(np.repeat(np.asarray(tm), bn, 0), bt, 1)[:N, :T]
    h_ref = np.where(full, np.asarray(ref.maxsim_ref(E, mask, Q)), 0.0)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=1e-5)


def test_gather_maxsim_all_masked_documents():
    """A document with every token masked must yield the ref sentinel (the
    running max never observes a valid token), not garbage from padding."""
    N, L, M, T = 10, 48, 128, 9
    E, mask, Q = _inputs(N, L, M, T, jnp.float32, seed=11)
    mask = jnp.asarray(np.asarray(mask).copy())
    dead = jnp.asarray([2, 7])
    mask = mask.at[dead].set(False)
    rng = np.random.default_rng(12)
    di = jnp.asarray([2, 7, 0, 5], jnp.int32)      # dead docs included
    ti = jnp.asarray(rng.integers(0, T, (4, 2)), jnp.int32)
    out = gather_maxsim_op(E, mask, Q, di, ti, block_b=2, block_l=16)
    out_ref = ref.gather_maxsim_ref(E, mask, Q, di, ti)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-5)
    assert (np.asarray(out)[:2] < -1e37).all()     # dead rows hit _NEG


def test_masked_maxsim_all_masked_documents():
    N, L, M, T = 11, 40, 128, 10
    E, mask, Q = _inputs(N, L, M, T, jnp.float32, seed=13)
    mask = jnp.asarray(np.asarray(mask).copy())
    mask = mask.at[jnp.asarray([0, 4, 10])].set(False)
    bn, bt = 4, 4
    gi, gj = -(-N // bn), -(-T // bt)
    tm = jnp.ones((gi, gj), bool)                  # all tiles active
    h = masked_maxsim_op(E, mask, Q, tm, block_n=bn, block_t=bt, block_l=16)
    h_ref = np.asarray(ref.maxsim_ref(E, mask, Q))
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=1e-5)
    assert (np.asarray(h)[[0, 4, 10]] < -1e37).all()


# ---------------------------------------------------------------------------
# ISSUE 3 satellites: stacked-offset gather indexing (the pooled frontier's
# cell contract), the gather padding contract, the batched dense scorer, and
# the lifted block-divisibility error.
# ---------------------------------------------------------------------------

def _stacked(Bq, N, L, M, T, seed):
    """Per-query inputs stacked the way the pooled frontier stacks them:
    docs (Bq*N, L, M), queries (Bq*T, M)."""
    rng = np.random.default_rng(seed)
    parts = [_inputs(N, L, M, T, jnp.float32, seed=seed + i)
             for i in range(Bq)]
    E = jnp.concatenate([p[0] for p in parts])
    mask = jnp.concatenate([p[1] for p in parts])
    Q = jnp.concatenate([p[2] for p in parts])
    # query-offset selections: doc q*N+i pairs only with tokens q*T+t
    S, G = 7, 3                                    # odd S: pad path active
    qid = rng.integers(0, Bq, S)
    di = jnp.asarray(qid * N + rng.integers(0, N, S), jnp.int32)
    ti = jnp.asarray(qid[:, None] * T + rng.integers(0, T, (S, G)), jnp.int32)
    return E, mask, Q, di, ti


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_gather_maxsim_stacked_offset_parity(impl, monkeypatch):
    """ref/interpret parity on query-offset indices into stacked tensors —
    the exact indexing the pooled reveal engine emits every round."""
    E, mask, Q, di, ti = _stacked(3, 8, 48, 128, 6, seed=21)
    want = np.asarray(ref.gather_maxsim_ref(E, mask, Q, di, ti))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    out = gather_maxsim_op(E, mask, Q, di, ti, block_b=4, block_l=16)
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)


def test_gather_maxsim_pad_rows_replicate_last_index(monkeypatch):
    """B not a multiple of block_b: pad rows replicate the last selection
    (not doc 0) and are sliced off — results must match ref even when doc 0
    is all-masked (the old zero-padding's gather target)."""
    N, L, M, T = 9, 32, 128, 8
    E, mask, Q = _inputs(N, L, M, T, jnp.float32, seed=22)
    mask = jnp.asarray(np.asarray(mask).copy()).at[0].set(False)
    rng = np.random.default_rng(23)
    di = jnp.asarray(rng.integers(1, N, 5), jnp.int32)   # B=5, block_b=4
    ti = jnp.asarray(rng.integers(0, T, (5, 2)), jnp.int32)
    want = np.asarray(ref.gather_maxsim_ref(E, mask, Q, di, ti))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    out = gather_maxsim_op(E, mask, Q, di, ti, block_b=4, block_l=16)
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)


def test_gather_maxsim_unpadded_shapes_raise_clearly():
    from repro.kernels.gather_maxsim import gather_maxsim
    E, mask, Q = _inputs(8, 32, 128, 8, jnp.float32, seed=24)
    di = jnp.zeros((5,), jnp.int32)                # 5 % 4 != 0
    ti = jnp.zeros((5, 2), jnp.int32)
    with pytest.raises(ValueError, match="gather_maxsim_op"):
        gather_maxsim(E, mask, Q, di, ti, block_b=4, block_l=16,
                      interpret=True)


# ---------------------------------------------------------------------------
# ISSUE 5 satellite: bf16 embeddings through every kernel op with f32
# accumulation — parity vs the f32 ref on tile-boundary and odd shapes,
# under both dispatch modes. Both paths cast to f32 BEFORE the contraction,
# so tolerances stay at f32 noise (the bf16 quantization already happened
# to the inputs identically).
# ---------------------------------------------------------------------------

BF16_SHAPES = [
    (8, 64, 128, 32),     # tile-aligned
    (13, 37, 128, 11),    # odd everything
    (9, 63, 128, 17),     # L one short of a block
]


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("shape", BF16_SHAPES)
def test_bf16_maxsim_matches_f32_ref(impl, shape, monkeypatch):
    N, L, M, T = shape
    E, mask, Q = _inputs(N, L, M, T, jnp.bfloat16, seed=30)
    want = ref.maxsim_ref(E.astype(jnp.float32), mask,
                          Q.astype(jnp.float32))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    h = maxsim_op(E, mask, Q, block_n=4, block_l=32)
    assert h.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(h), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("shape", BF16_SHAPES)
def test_bf16_gather_maxsim_matches_f32_ref(impl, shape, monkeypatch):
    N, L, M, T = shape
    E, mask, Q = _inputs(N, L, M, T, jnp.bfloat16, seed=31)
    rng = np.random.default_rng(32)
    B, G = 5, 3
    di = jnp.asarray(rng.integers(0, N, B), jnp.int32)
    ti = jnp.asarray(rng.integers(0, T, (B, G)), jnp.int32)
    want = ref.gather_maxsim_ref(E.astype(jnp.float32), mask,
                                 Q.astype(jnp.float32), di, ti)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    out = gather_maxsim_op(E, mask, Q, di, ti, block_b=4, block_l=32)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_bf16_maxsim_batch_matches_f32_ref(impl, monkeypatch):
    Bq, N, L, M, T = 3, 7, 37, 128, 11
    rng = np.random.default_rng(33)
    E = jnp.asarray(rng.standard_normal((Bq, N, L, M)), jnp.bfloat16)
    mask = jnp.asarray(rng.random((Bq, N, L)) > 0.3)
    Q = jnp.asarray(rng.standard_normal((Bq, T, M)), jnp.bfloat16)
    want = jax.vmap(ref.maxsim_ref)(E.astype(jnp.float32), mask,
                                    Q.astype(jnp.float32))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    got = maxsim_batch_op(E, mask, Q, block_l=16)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_bf16_masked_maxsim_matches_f32_ref(impl, monkeypatch):
    N, L, M, T = 13, 37, 128, 11
    E, mask, Q = _inputs(N, L, M, T, jnp.bfloat16, seed=34)
    bn, bt = 4, 4
    gi, gj = -(-N // bn), -(-T // bt)
    tm = jnp.asarray(np.random.default_rng(35).random((gi, gj)) > 0.4)
    want = ref.masked_maxsim_ref(E.astype(jnp.float32), mask,
                                 Q.astype(jnp.float32), tm, bn, bt)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    h = masked_maxsim_op(E, mask, Q, tm, block_n=bn, block_t=bt, block_l=16)
    assert h.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(h), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("shape", [(2, 8, 64, 128, 16), (3, 7, 37, 128, 11)])
def test_maxsim_batch_matches_per_query_ref(impl, shape, monkeypatch):
    """The batched dense scorer equals per-query maxsim_ref in every
    dispatch mode, including all-masked docs (sentinel rows)."""
    Bq, N, L, M, T = shape
    rng = np.random.default_rng(25)
    E = jnp.asarray(rng.standard_normal((Bq, N, L, M)), jnp.float32)
    mask = jnp.asarray(rng.random((Bq, N, L)) > 0.3)
    mask = mask.at[0, 1].set(False)
    Q = jnp.asarray(rng.standard_normal((Bq, T, M)), jnp.float32)
    want = np.asarray(jax.vmap(ref.maxsim_ref)(E, mask, Q))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    got = np.asarray(maxsim_batch_op(E, mask, Q, block_l=16))
    np.testing.assert_allclose(got, want, atol=1e-5)
    assert (got[0, 1] < -1e37).all()
