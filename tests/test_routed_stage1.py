"""ISSUE 6: shard-local stage-1 ANN with skew-aware candidate routing.

The one-shard_map serving step (``make_routed_serving_step``) must return
the IDENTICAL top-K scorecards as the host-routed path it replaces
(``stage1="host"``: full-corpus stage-1 + numpy ``route_batch`` + the
gathered shard_map step) whenever both see the same candidates. The parity
configuration makes coverage total on both sides: ``kprime`` far above
C*L (clamped inside ``generate_candidates``, so every doc is a stage-1
hit with exact Eq. 15 b-bounds), host ``max_candidates >= C`` and local
``n_local >= c_loc``, and ``n_total=0`` (no quota capping). Per-shard
candidate lists then agree slot-for-slot — both stage-1s emit ascending
doc ids — so even the BANDIT trajectories match bit-for-bit (the PRNG
contract ``fold_in(fold_in(key(base_seed), seed), shard_index)`` is shared).

Multi-device programs run in subprocesses (tests/_subproc.py);
REPRO_KERNEL_IMPL is forwarded so CI's ref/interpret lanes cover the
routed shard_map too. Satellite coverage: ragged corpus (C=41) at both 4
and 1 virtual devices, the quota-capped path, and the engine's routed
dispatch (zero recompiles + skew metrics).
"""
import numpy as np
import pytest

from _subproc import run_in_subprocess

# Ragged corpus: C=41 over 4 shards -> c_loc=11, valid=[11, 11, 11, 8].
# KP >> C*L forces full stage-1 coverage (see module docstring).
_SETUP = """
import jax, jax.numpy as jnp, numpy as np
from repro.retrieval.ann import generate_candidates
from repro.retrieval.service import (make_rerank_dense_step,
                                     make_routed_serving_step,
                                     make_sharded_serving_step)
from repro.retrieval.sharded import route_batch, shard_corpus

rng = np.random.default_rng(0)
C, L, M, B, T = 41, 12, 16, 4, 8
KP = 100_000
emb = rng.standard_normal((C, L, M)).astype(np.float32)
emb /= np.linalg.norm(emb, axis=-1, keepdims=True)
msk = np.arange(L)[None] < rng.integers(4, L + 1, C)[:, None]
q_np = rng.standard_normal((B, T, M)).astype(np.float32)
q_np /= np.linalg.norm(q_np, axis=-1, keepdims=True)
q = jnp.asarray(q_np)

# host-side stage-1 over the FULL corpus (the gathered path's front end)
cand = jax.vmap(lambda qq: generate_candidates(
    jnp.asarray(emb), jnp.asarray(msk), qq, kprime=KP,
    max_candidates=48))(q)


def check_topk(got_s, got_i, want_s, want_i, label):
    got_s, got_i = np.asarray(got_s), np.asarray(got_i)
    want_s, want_i = np.asarray(want_s), np.asarray(want_i)
    for b in range(got_i.shape[0]):
        assert set(got_i[b]) == set(want_i[b]), (label, b, got_i[b], want_i[b])
        np.testing.assert_allclose(np.sort(got_s[b]), np.sort(want_s[b]),
                                   atol=1e-4, err_msg=f"{label} q{b}")


def run_parity(mesh, sc, n_local, n_devices_label):
    cand_l, (a_l, b_l) = route_batch(
        np.asarray(cand.doc_ids), [np.asarray(cand.a), np.asarray(cand.b)],
        sc.docs_per_shard, sc.n_shards, n_local=n_local)
    kw = dict(topk=5, alpha_ef=1e9, block_docs=4, block_tokens=4)
    cents, mass = sc.router.centroids, sc.router.shard_mass
    for flavor in ("dense", "bandit"):
        host = make_sharded_serving_step(mesh, flavor, **kw)
        sh, ih, fh, sth = host(sc.embs, sc.mask, q, jnp.asarray(cand_l),
                               jnp.asarray(a_l), jnp.asarray(b_l),
                               sc.valid_docs_device(), jnp.int32(0))
        routed = make_routed_serving_step(mesh, flavor, n_local=n_local,
                                          n_total=0, kprime=KP, **kw)
        sr, ir, fr, st = routed(sc.embs, sc.mask, cents, mass, q,
                                sc.valid_docs_device(), jnp.int32(0))
        label = flavor + n_devices_label
        check_topk(sr, ir, sh, ih, label)
        assert np.asarray(st).shape == (sc.n_shards, 6), label
        assert (np.asarray(st)[:, 5] == 0).all(), label   # no quarantine
        if flavor == "bandit":
            # full coverage + shared PRNG => identical reveal trajectories
            np.testing.assert_allclose(np.asarray(fr), np.asarray(fh),
                                       atol=1e-5, err_msg=label)
        else:
            # dense absolute reference: 1-shard exact rerank of the same list
            mesh1 = jax.make_mesh((1,), ("ref",))
            d1 = make_rerank_dense_step(mesh1, topk=5)
            sd, idd = d1(jnp.asarray(emb), jnp.asarray(msk), q,
                         jnp.asarray(np.asarray(cand.doc_ids)[:, None, :]))
            check_topk(sr, ir, sd, idd, label + "_vs_exact")
"""


def test_routed_stage1_parity_4_shards():
    """Local vs host stage-1 on the ragged 4-shard mesh: identical top-K
    scorecards for dense AND bandit, identical bandit reveal fractions."""
    out = run_in_subprocess(_SETUP + """
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
sc = shard_corpus(emb, msk, mesh4, n_centroids=4)
assert list(sc.valid_docs) == [11, 11, 11, 8]
assert sc.router is not None
run_parity(mesh4, sc, n_local=16, n_devices_label="@4dev")
print("PARITY4_OK")
    """, n_devices=4)
    assert "PARITY4_OK" in out


def test_routed_stage1_parity_1_device():
    """Same parity on a single device (n_shards=1, n_local >= C): the
    routed step must degrade to the plain pipeline, not assume S > 1."""
    out = run_in_subprocess(_SETUP + """
mesh1 = jax.make_mesh((1,), ("data",))
sc = shard_corpus(emb, msk, mesh1, n_centroids=4)
assert (sc.n_shards, sc.docs_per_shard) == (1, 41)
run_parity(mesh1, sc, n_local=48, n_devices_label="@1dev")
print("PARITY1_OK")
    """, n_devices=1)
    assert "PARITY1_OK" in out


def test_routed_quota_capped_smoke():
    """Skew-aware path (n_total > 0): per-shard stage-1 capped at the
    routed quota still emits only real, duplicate-free global ids, sane
    reveal fractions, and a quota-share column that sums to 1."""
    out = run_in_subprocess(_SETUP + """
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
sc = shard_corpus(emb, msk, mesh4, n_centroids=4)
step = make_routed_serving_step(mesh4, "bandit", topk=5, n_local=16,
                                n_total=24, kprime=6, alpha_ef=0.3,
                                block_docs=4, block_tokens=4)
s, i, f, st = step(sc.embs, sc.mask, sc.router.centroids,
                   sc.router.shard_mass, q, sc.valid_docs_device(),
                   jnp.int32(0))
i, f, st = np.asarray(i), np.asarray(f), np.asarray(st)
assert ((i >= -1) & (i < C)).all(), i
for b in range(B):
    real = i[b][i[b] >= 0]
    assert len(set(real.tolist())) == len(real), (b, i[b])
    assert len(real) >= 5, (b, i[b])           # 24 candidates >> top-5
assert ((f > 0.0) & (f <= 1.0 + 1e-6)).all(), f
assert st.shape == (4, 6)
qs = st[:, 3]                                   # mean quota share per shard
assert np.isclose(qs.sum(), 1.0, atol=1e-4), qs
assert (st[:, 4] >= qs - 1e-6).all()            # max share >= mean share
print("QUOTA_OK")
    """, n_devices=4)
    assert "QUOTA_OK" in out


def test_engine_routed_stage1_zero_recompile_and_parity():
    """RetrievalEngine with stage1="local": warmup pre-compiles the routed
    executable, candidate-less traffic serves with ZERO recompiles, every
    completion matches the stage1="host" engine, and the routed skew
    metrics surface in the summary (and ONLY there)."""
    out = run_in_subprocess("""
import numpy as np
from repro.data.synthetic import make_retrieval_dataset
from repro.serve import EngineConfig, Request, RetrievalEngine

ds = make_retrieval_dataset(n_docs=47, n_queries=8, doc_len=16,
                            min_doc_len=6, query_len=8, dim=16, seed=3)
kw = dict(batch_size=4, deadline_s=0.5, token_buckets=(8,),
          cand_buckets=(48,), max_k=5, flavor="dense",
          stage1_candidates=48, stage1_kprime=100_000,
          mesh_axes=(("data", 2), ("model", 2)))
loc = RetrievalEngine(ds.doc_embs, ds.doc_mask,
                      EngineConfig(stage1="local", **kw))
host = RetrievalEngine(ds.doc_embs, ds.doc_mask,
                       EngineConfig(stage1="host", **kw))
loc.warmup()
host.warmup()
for i in range(8):
    for e in (loc, host):
        e.submit(Request(query=ds.queries[i][:8], k=5))
got = {c.rid: c for c in loc.drain()}
want = {c.rid: c for c in host.drain()}
assert len(got) == 8
for rid, c in got.items():
    assert set(c.topk_ids) == set(want[rid].topk_ids), rid
    np.testing.assert_allclose(np.sort(c.topk_scores),
                               np.sort(want[rid].topk_scores), atol=1e-4)
assert loc.metrics.compiles_after_warmup == 0
assert host.metrics.compiles_after_warmup == 0
s = loc.metrics.summary()
assert len(s["routed_quota_share_mean"]) == 4
assert abs(s["routed_skew"] - 1.0) < 1e-4      # stage1_total=0: uniform
assert "routed_quota_share_mean" not in host.metrics.summary()
print("ENGINE_ROUTED_OK")
    """, n_devices=4)
    assert "ENGINE_ROUTED_OK" in out


def test_engine_stage1_local_requires_mesh():
    """stage1="local" runs inside the corpus shard_map — constructing the
    engine without a mesh must fail loudly, not fall back to host routing."""
    from repro.serve import EngineConfig, RetrievalEngine

    embs = np.zeros((8, 4, 8), np.float32)
    mask = np.ones((8, 4), bool)
    with pytest.raises(ValueError, match="mesh_axes"):
        RetrievalEngine(embs, mask, EngineConfig(stage1="local"))
    with pytest.raises(ValueError, match="stage1"):
        RetrievalEngine(embs, mask, EngineConfig(stage1="bogus"))
