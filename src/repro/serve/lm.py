"""LM serving engine: prefill + greedy decode loop over the KV cache."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models.transformer import (forward_decode, forward_prefill,
                                      init_cache)

Params = Any


def generate(params: Params, cfg: LMConfig, prompt: jax.Array, *,
             max_new_tokens: int = 16, max_seq: int = 0,
             cache_dtype=jnp.float32) -> jax.Array:
    """Greedy generation. prompt (B, S) -> (B, S + max_new_tokens)."""
    B, S = prompt.shape
    max_seq = max_seq or (S + max_new_tokens)
    last_logits, cache = forward_prefill(params, cfg, prompt, max_seq,
                                         cache_dtype=cache_dtype)
    tok0 = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)

    def body(carry, step):
        tok, cache = carry
        logits, cache = forward_decode(params, cfg, tok,
                                       (S + step).astype(jnp.int32), cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, cache), tok

    (_, _), toks = jax.lax.scan(body, (tok0, cache),
                                jnp.arange(max_new_tokens))
    return jnp.concatenate([prompt, toks.T.astype(prompt.dtype)], axis=1)


def serve_step(params: Params, cfg: LMConfig, token: jax.Array,
               position: jax.Array, cache) -> Tuple[jax.Array, Any]:
    """One decode step — THE unit the decode_32k / long_500k cells lower."""
    return forward_decode(params, cfg, token, position, cache)
