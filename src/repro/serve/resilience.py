"""Self-healing serving: thread supervision + graceful fidelity degradation.

The async engine's failure-handling policy lives here, separated from the
pipeline mechanics in :mod:`repro.serve.engine`:

* :class:`Supervisor` — a watchdog thread that notices dead pipeline
  threads (killed by a fault, a chaos plan, or a real bug), restarts them
  up to a configured budget, and escalates to a loud engine-wide failure
  when the budget is exhausted. Restarting is safe because the engine
  keeps every piece of in-flight state (the prepared-batch queue, the
  dispatched-batch deque, the admission batcher) on the ENGINE object,
  not on thread stacks — a restarted thread picks up exactly where its
  predecessor died, and completion delivery is idempotent (rid-deduped),
  so a re-harvested batch can never double-complete a request.

* :class:`DegradeLadder` — the deadline-aware fidelity policy behind
  ``EngineConfig(backpressure="degrade")``. When a batch's tightest
  deadline headroom shrinks below the configured thresholds, the engine
  trades fidelity for availability in rungs, from cheapest to bluntest:

    level 0  full fidelity (no-op knobs)
    level 1  raise the effective ``alpha_ef`` (wider Serfling radii =>
             earlier separation, fewer reveal rounds)
    level 2  raise ``alpha_ef`` further AND cap the reveal rounds
    level 3  maximal alpha + the tightest round cap

  The knobs are TRACED scalars (`alpha_scale`, `round_cap`) threaded into
  the already-compiled executables — changing rungs never recompiles, and
  level 0 is bit-identical to a knob-less trace. Submit-time candidate
  truncation (the pre-ladder "degrade" behavior) remains the first rung
  of defense and is recorded in ``Request.coverage_scale``.

The fault-injection primitives themselves (:class:`FaultPlan`,
:class:`ChaosClock`, :func:`poison_corpus`, ...) live in
:mod:`repro.dist.fault` and are re-exported here for convenience.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional, Tuple

from repro.dist.fault import (ChaosClock, ChaosKill, FaultPlan,  # noqa: F401
                              InjectedFault, apply_delay, poison_corpus)

__all__ = [
    "ChaosClock", "ChaosKill", "DegradeLadder", "FaultPlan",
    "InjectedFault", "Supervisor", "apply_delay", "poison_corpus",
]


@dataclasses.dataclass(frozen=True)
class DegradeLadder:
    """Headroom-ratio -> (alpha_scale, round_cap) fidelity policy.

    ``headrooms`` are strictly-decreasing thresholds on the batch's
    tightest deadline-headroom ratio r = (deadline - now) / expected
    service time. ``r >= headrooms[0]`` is level 0 (full fidelity);
    crossing below ``headrooms[i]`` selects level i+1 with knobs
    ``alpha_scales[i]`` / ``round_caps[i]`` (a cap of 0 leaves the round
    budget alone). Values are per-batch and traced — no recompiles."""

    headrooms: Tuple[float, ...] = (1.0, 0.5, 0.25)
    alpha_scales: Tuple[float, ...] = (2.0, 4.0, 8.0)
    round_caps: Tuple[int, ...] = (0, 8, 4)

    def __post_init__(self):
        if not (len(self.headrooms) == len(self.alpha_scales)
                == len(self.round_caps)):
            raise ValueError("ladder fields must have equal length")
        if any(h2 >= h1 for h1, h2 in zip(self.headrooms,
                                          self.headrooms[1:])):
            raise ValueError("headroom thresholds must strictly decrease")
        if any(s < 1.0 for s in self.alpha_scales):
            raise ValueError("alpha_scales must be >= 1 (degrade, never "
                             "silently upgrade fidelity)")

    @property
    def n_levels(self) -> int:
        return len(self.headrooms) + 1

    def level_for(self, headroom_ratio: float) -> int:
        """0 = comfortable, len(headrooms) = maximally squeezed."""
        level = 0
        for h in self.headrooms:
            if headroom_ratio >= h:
                break
            level += 1
        return level

    def knobs(self, level: int) -> Tuple[float, int]:
        """(alpha_scale, round_cap) for a level; level 0 => (1.0, 0),
        which traces bit-identical to no knobs at all."""
        if level <= 0:
            return 1.0, 0
        i = min(level, len(self.headrooms)) - 1
        return float(self.alpha_scales[i]), int(self.round_caps[i])


class Supervisor:
    """Restart-with-budget watchdog over named pipeline threads.

    The engine registers each serving thread with a factory that builds a
    STARTED replacement; the watchdog polls thread liveness every
    ``interval_s`` and, when a thread is dead while the engine is not
    stopping, either restarts it (budget remaining) or calls
    ``on_exhausted(name, last_exc)`` exactly once and stops watching.

    ``note_failure`` records the exception a dying thread saw so the
    escalation path can chain it. All mutation happens under one lock;
    the watchdog itself is a daemon thread and is joined on ``stop()``.
    """

    def __init__(self, *, max_restarts: int = 2, interval_s: float = 0.02,
                 stopping: Callable[[], bool] = lambda: False,
                 on_exhausted: Optional[
                     Callable[[str, Optional[BaseException]], None]] = None):
        if max_restarts < 0:
            raise ValueError("max_restarts must be >= 0")
        self._max_restarts = int(max_restarts)
        self._interval = float(interval_s)
        self._stopping = stopping
        self._on_exhausted = on_exhausted
        self._lock = threading.Lock()
        self._threads: Dict[str, threading.Thread] = {}
        self._factories: Dict[str, Callable[[], threading.Thread]] = {}
        self._on_restart: Dict[str, Optional[Callable[[], None]]] = {}
        self._last_exc: Dict[str, BaseException] = {}
        self._gave_up: set = set()
        self.restarts: Dict[str, int] = {}
        self._stop_evt = threading.Event()
        self._watchdog: Optional[threading.Thread] = None

    def watch(self, name: str, thread: threading.Thread,
              factory: Callable[[], threading.Thread],
              on_restart: Optional[Callable[[], None]] = None) -> None:
        with self._lock:
            self._threads[name] = thread
            self._factories[name] = factory
            self._on_restart[name] = on_restart
            self.restarts.setdefault(name, 0)

    def note_failure(self, name: str, exc: BaseException) -> None:
        """Called by a dying thread's guard so escalation can chain the
        original exception instead of reporting a bare dead thread."""
        with self._lock:
            self._last_exc[name] = exc

    def start(self) -> "Supervisor":
        if self._watchdog is not None:
            return self
        self._stop_evt.clear()
        self._watchdog = threading.Thread(
            target=self._loop, name="repro-supervisor", daemon=True)
        self._watchdog.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        w, self._watchdog = self._watchdog, None
        if w is not None:
            w.join(timeout=10.0)

    def _loop(self) -> None:
        while not self._stop_evt.wait(self._interval):
            if self._stopping():
                continue            # normal shutdown: dead threads are fine
            with self._lock:
                dead = [(n, t) for n, t in self._threads.items()
                        if not t.is_alive() and n not in self._gave_up]
            for name, _ in dead:
                if self._stopping() or self._stop_evt.is_set():
                    return
                with self._lock:
                    exhausted = self.restarts[name] >= self._max_restarts
                    if not exhausted:
                        self.restarts[name] += 1
                    exc = self._last_exc.get(name)
                if exhausted:
                    with self._lock:
                        self._gave_up.add(name)
                    if self._on_exhausted is not None:
                        self._on_exhausted(name, exc)
                    continue
                cb = self._on_restart.get(name)
                if cb is not None:
                    cb()
                fresh = self._factories[name]()
                with self._lock:
                    self._threads[name] = fresh
