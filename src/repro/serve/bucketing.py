"""Static shape buckets for the streaming retrieval engine.

XLA compiles one program per input shape, and a recompile mid-request is a
multi-second latency cliff — fatal for serving. The engine therefore pads
every admitted batch into a small set of static shapes: query-token counts
round up to one of ``token_buckets`` and candidate counts to one of
``cand_buckets``, so at most ``len(token_buckets) * len(cand_buckets)``
programs exist per step flavor and ``RetrievalEngine.warmup()`` can
pre-compile them all before traffic arrives.

All padding here is host-side numpy (zeros for embeddings, -1 for candidate
ids, zero-width [0, 0] support for padded cells) — padded cells carry no
score mass and padded docs are masked out of every selection.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShapeBuckets:
    """Ascending, deduplicated shape buckets for tokens and candidates."""

    token_buckets: Tuple[int, ...]
    cand_buckets: Tuple[int, ...]

    def __post_init__(self):
        for name in ("token_buckets", "cand_buckets"):
            vals = tuple(sorted(set(int(v) for v in getattr(self, name))))
            if not vals or vals[0] < 1:
                raise ValueError(f"{name} must be non-empty and positive")
            object.__setattr__(self, name, vals)

    @staticmethod
    def _fit(buckets: Tuple[int, ...], x: int, what: str) -> int:
        for b in buckets:
            if x <= b:
                return b
        raise ValueError(f"{what}={x} exceeds the largest bucket "
                         f"{buckets[-1]}; raise the bucket config")

    def token_bucket(self, n_tokens: int) -> int:
        """Smallest token bucket that fits ``n_tokens``."""
        return self._fit(self.token_buckets, n_tokens, "query tokens")

    def cand_bucket(self, n_cands: int) -> int:
        """Smallest candidate bucket that fits ``n_cands``."""
        return self._fit(self.cand_buckets, n_cands, "candidates")

    def all_buckets(self) -> List[Tuple[int, int]]:
        """Every (token_bucket, cand_bucket) combination, for warmup."""
        return [(t, c) for t in self.token_buckets
                for c in self.cand_buckets]


def pad_queries(queries: Sequence[np.ndarray], t_bucket: int) -> np.ndarray:
    """Stack variable-length (T_i, M) queries into (B, t_bucket, M), zero
    padded. Zero query tokens dot to exactly 0 against every doc token, so
    they add nothing to any MaxSim score."""
    m = queries[0].shape[-1]
    out = np.zeros((len(queries), t_bucket, m), np.float32)
    for i, q in enumerate(queries):
        t = q.shape[0]
        if t > t_bucket:
            raise ValueError(f"query has {t} tokens > bucket {t_bucket}")
        out[i, :t] = q
    return out


def pad_candidates(cand_ids: Sequence[Optional[np.ndarray]],
                   n_bucket: int) -> np.ndarray:
    """Stack candidate id lists into (B, n_bucket) int32, -1 padded.
    ``None`` entries become all -1 rows (filled by stage-1 downstream)."""
    out = np.full((len(cand_ids), n_bucket), -1, np.int32)
    for i, c in enumerate(cand_ids):
        if c is None:
            continue
        c = np.asarray(c, np.int32)
        if c.shape[0] > n_bucket:
            raise ValueError(f"{c.shape[0]} candidates > bucket {n_bucket}")
        out[i, :c.shape[0]] = c
    return out


def support_bounds(cand: np.ndarray, n_tokens: Sequence[int], t_bucket: int,
                   support: Tuple[float, float]) -> Tuple[np.ndarray,
                                                          np.ndarray]:
    """Generic per-cell support [a, b] for a padded candidate batch.

    Real (doc, token) cells get the global similarity support; padded docs
    and padded query-token columns get the zero-width [0, 0] interval, so
    the bandit never spends reveals on them and hard bounds stay exact.
    """
    b_sz, n_bucket = cand.shape
    a = np.zeros((b_sz, n_bucket, t_bucket), np.float32)
    b = np.zeros((b_sz, n_bucket, t_bucket), np.float32)
    for i, t in enumerate(n_tokens):
        real = (cand[i] >= 0)[:, None] & (np.arange(t_bucket) < t)[None, :]
        a[i] = np.where(real, np.float32(support[0]), 0.0)
        b[i] = np.where(real, np.float32(support[1]), 0.0)
    return a, b
