"""Serving: streaming retrieval engine + LM decode engine.

``RetrievalEngine`` is the synchronous query-stream serving loop
(deadline-aware batching, static shape buckets, warm jit caches,
dense/bandit dispatch) and the parity oracle for
``AsyncRetrievalEngine`` — the threaded runtime that overlaps host
batch assembly with device execution and, in continuous mode, refills
retired frontier slots from the admission queue mid-flight.
``repro.serve.resilience`` holds the self-healing layer (thread
supervision, shard failover, the fidelity-degradation ladder) and
re-exports the fault-injection harness from ``repro.dist.fault``.
``repro.serve.lm`` holds the LM prefill/decode engine.
"""
from repro.serve.bucketing import (ShapeBuckets, pad_candidates, pad_queries,
                                   support_bounds)
from repro.serve.engine import (AdmissionRejected, AsyncRetrievalEngine,
                                BatchRecord, Completion, EngineConfig,
                                EngineMetrics, Request, RetrievalEngine)
from repro.serve.lm import generate, serve_step
from repro.serve.resilience import (ChaosClock, ChaosKill, DegradeLadder,
                                    FaultPlan, InjectedFault, Supervisor,
                                    poison_corpus)

__all__ = [
    "ShapeBuckets", "pad_candidates", "pad_queries", "support_bounds",
    "AdmissionRejected", "AsyncRetrievalEngine", "BatchRecord", "Completion",
    "EngineConfig", "EngineMetrics", "Request", "RetrievalEngine",
    "ChaosClock", "ChaosKill", "DegradeLadder", "FaultPlan", "InjectedFault",
    "Supervisor", "poison_corpus",
    "generate", "serve_step",
]
