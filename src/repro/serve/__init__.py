"""Serving: streaming retrieval engine + LM decode engine.

``RetrievalEngine`` is the query-stream serving loop (deadline-aware
batching, static shape buckets, warm jit caches, dense/bandit dispatch);
``repro.serve.lm`` holds the LM prefill/decode engine.
"""
from repro.serve.bucketing import (ShapeBuckets, pad_candidates, pad_queries,
                                   support_bounds)
from repro.serve.engine import (BatchRecord, Completion, EngineConfig,
                                EngineMetrics, Request, RetrievalEngine)
from repro.serve.lm import generate, serve_step

__all__ = [
    "ShapeBuckets", "pad_candidates", "pad_queries", "support_bounds",
    "BatchRecord", "Completion", "EngineConfig", "EngineMetrics", "Request",
    "RetrievalEngine", "generate", "serve_step",
]
