"""Serving: LM decode engine + bandit reranking service."""
