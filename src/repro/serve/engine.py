"""Streaming retrieval serving engine with deadline-aware batching.

The request-serving loop the north-star asks for: a stream of
(query, deadline, k) requests is admitted through
:class:`repro.dist.fault.DeadlineBatcher` (release on full batch OR tightest
pending deadline), padded into a small set of static shape buckets
(:mod:`repro.serve.bucketing`) and dispatched through one of the
engine-facing rerank steps from :mod:`repro.retrieval.service`:

* ``dense``  — exact MaxSim over the candidate list,
* ``bandit`` — adaptive Col-Bandit reranking (reveal fraction << 1).

Every (flavor, token-bucket, candidate-bucket) pair is AOT-lowered and
compiled exactly once — ``warmup()`` pre-compiles every bucket so steady
state serves with ZERO recompiles; the executable cache and compile counts
are first-class (``engine.compiled_buckets``, ``metrics.compiles``) so tests
can assert the no-recompile property instead of trusting it.

Requests either carry a stage-1 candidate list (``cand_ids``) or the engine
runs its own stage-1 ANN (``repro.retrieval.ann.generate_candidates``,
vmapped per batch, also bucket-compiled) — the ANN path additionally yields
Eq. 15 per-cell bounds, which is what makes the bandit flavor effective.

The LM decode engine that used to live here moved to ``repro.serve.lm``.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import os
import queue as queue_mod
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_audit import (AuditSpec, audit_executable,
                                      scorecard_budget_bytes)
from repro.core.frontier import FrontierState
from repro.dist.fault import (ChaosKill, DeadlineBatcher, FaultPlan,
                              apply_delay)
from repro.kernels import tuning
from repro.kernels.ops import autotune_op
from repro.kernels.quant import (CORPUS_FORMATS, corpus_nbytes,
                                 format_ordinal)
from repro.retrieval.ann import generate_candidates
from repro.retrieval.corpus import Corpus, build_corpus
from repro.retrieval.service import (init_stream_state,
                                     make_routed_serving_step,
                                     make_serving_step,
                                     make_sharded_serving_step,
                                     make_streaming_step)
from repro.retrieval.sharded import route_batch
from repro.serve.bucketing import (ShapeBuckets, pad_candidates, pad_queries,
                                   support_bounds)
from repro.serve.resilience import DegradeLadder, Supervisor
from repro.serve.lm import generate, serve_step  # noqa: F401  (back-compat)

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static serving configuration (fixes the compiled shape set)."""

    batch_size: int = 8
    deadline_s: float = 0.02          # global admission deadline
    token_buckets: Tuple[int, ...] = (8, 16, 32)
    cand_buckets: Tuple[int, ...] = (32, 64)
    max_k: int = 10                   # compiled top-K width (per-request k <=)
    flavor: str = "auto"              # "dense" | "bandit" | "auto"
    bandit_min_candidates: int = 64   # auto: bandit when bucket >= this
    # Col-Bandit knobs (bandit flavor)
    alpha_ef: float = 0.3
    delta: float = 0.01
    block_docs: int = 8
    block_tokens: int = 8
    max_rounds: int = -1
    support: Tuple[float, float] = (0.0, 1.0)
    # Reveal engine for the bandit flavor: "pooled" (one cross-query
    # frontier loop, one fused reveal launch per round, converged queries
    # retired; falls back to the unfused chain under REPRO_KERNEL_IMPL=ref),
    # "pooled_fused"/"pooled_chain" (force one round body for A/B), or
    # "vmapped" (legacy per-query lockstep loop, kept for A/B).
    bandit_engine: str = "pooled"
    # Pooled engine only: let active queries grow their per-round doc block
    # up to this many docs out of slots freed by retired queries (0 = fixed
    # blocks, exact per-query parity with the solo bandit).
    max_block_docs: int = 0
    # Second growth axis: widen surviving slots' token blocks up to this
    # many tokens per selected doc out of freed frontier CELL capacity
    # (0 = fixed token blocks).
    max_block_tokens: int = 0
    # Kernel block-size autotuning (repro.kernels.tuning): when True,
    # warmup() times the candidate block configurations for every kernel
    # shape bucket the compiled executables will launch, BEFORE the AOT
    # compiles, so steady state serves with tuned tiles and still zero
    # recompiles. ``tuning_table`` names a JSON file: loaded (if present)
    # before any timing — covering entries are reused instead of re-timed
    # — and rewritten with the merged table after an autotune pass, so CI
    # and serving replicas share one tuned table.
    autotune: bool = False
    tuning_table: Optional[str] = None
    # Corpus mesh: () serves from one device (the seed path); a non-empty
    # axis spec like (("data", 2), ("model", 2)) builds that mesh, places
    # the corpus over EVERY axis as a ShardedCorpus (ragged tail padded +
    # tracked), and routes every bucket through the corpus-resident
    # shard_map steps — per-shard scorecards are the only cross-shard
    # traffic, and warmup()'s zero-recompile contract is unchanged.
    mesh_axes: Tuple[Tuple[str, int], ...] = ()
    # Resident corpus format (kernels.quant.CORPUS_FORMATS): "bf16" keeps
    # the corpus dense at its source dtype (the seed path, bit-identical
    # parity oracle); "int8" re-encodes it as per-(doc,token)-row symmetric
    # int8 + bf16 scales (~4x HBM reduction); "residual" stores a centroid
    # id + int8 residual against the spherical-k-means router codebook.
    # Dequantization happens INSIDE the scoring kernels — the compressed
    # payload is what crosses every program boundary, and the audit's
    # hlo-int8-residency rule asserts exactly that. Quantized engines
    # require candidate-carrying requests (stage-1 ANN scans raw token
    # rows) and are incompatible with stage1="local".
    corpus_format: str = "bf16"
    # stage-1 ANN (requests without a candidate list)
    stage1_kprime: int = 8
    stage1_candidates: int = 0        # 0 => smallest candidate bucket
    # Stage-1 placement on a sharded corpus: "host" is the legacy path
    # (host-side ANN over the full index + route_batch routing tables);
    # "local" runs the whole pipeline — centroid route -> shard-local kNN
    # -> Eq. 15 bounds -> rerank -> scorecard merge — inside ONE shard_map
    # (service.make_routed_serving_step): no host round-trip, candidate
    # embeddings never cross shards. Candidate-carrying requests always
    # use the host path (their ids are already global).
    stage1: str = "host"
    # "local" only: k-means centroid count for the skew-aware router built
    # at shard_corpus time, and the global per-query candidate budget the
    # router splits into per-shard quotas (0 = no quota: every shard emits
    # up to its full n_local — still shard-local, just not skew-aware).
    stage1_centroids: int = 8
    stage1_total: int = 0
    # "local" bandit only: seed the bandit with the stage-1 hit cells'
    # exact values (Eq. 15's exact-h branch) at zero reveal cost.
    prereveal_ann: bool = False
    # Admission headroom: a request's completion deadline minus the expected
    # batch service time (EMA of observed batches, floored by this) is what
    # the batcher gets — releasing AT the completion deadline would make
    # every deadline-triggered release a guaranteed miss under a real clock.
    deadline_headroom_s: float = 0.0
    # Async runtime (AsyncRetrievalEngine) knobs — inert on the sync engine.
    # ``pipeline_depth`` bounds the batches in flight on the device plus
    # prepared-but-undispatched batches queued behind them: depth 2 means
    # batch i+1 dispatches while i executes (the JetStream-style overlap);
    # 1 degenerates to synchronous dispatch.
    pipeline_depth: int = 2
    # Backpressure policy when a deadline-carrying request's projected
    # completion (now + (backlog + 1) * expected service) already overruns
    # its deadline at submit: "none" admits anyway (it will simply miss),
    # "reject" raises AdmissionRejected, "degrade" truncates the request's
    # candidate list to the smallest candidate bucket (a cheaper, already
    # compiled shape) and admits — dense requests and stage-1 requests
    # cannot be degraded and fall back to plain admission.
    backpressure: str = "none"
    # Continuous (slot-refill) batching: serve through ONE resumable
    # streaming executable instead of batch-at-a-time dispatch. A retired
    # query's frontier slots are refilled from the admission queue
    # mid-flight (``retrieval.service.make_streaming_step``); the stream
    # advances ``stream_trip_limit`` reveal rounds per device dispatch.
    continuous: bool = False
    stream_trip_limit: int = 4
    # Self-healing runtime (AsyncRetrievalEngine): when ``supervise`` is
    # set, a watchdog (serve.resilience.Supervisor) restarts dead pipeline
    # threads up to ``max_thread_restarts`` each; in-flight work survives
    # restarts because dispatch/admission state lives on the engine, and
    # completion delivery is rid-deduplicated (zero lost, zero duplicated).
    # Budget exhaustion escalates to the loud thread-death failure the
    # unsupervised engine raises immediately.
    supervise: bool = False
    max_thread_restarts: int = 2
    supervise_interval_s: float = 0.02
    # Deadline-aware fidelity ladder (``backpressure="degrade"``, bandit
    # flavor): when a batch's tightest deadline headroom — (deadline - now)
    # / expected service time — drops below headrooms[i], the batch runs
    # with alpha_ef scaled by degrade_alpha_scales[i] and (rung >= 2) the
    # reveal rounds capped at degrade_round_caps[i]. The knobs are traced
    # scalars on the always-lowered executables: changing rungs never
    # recompiles, and rung 0 is bit-identical to the undegrade trace.
    degrade_headrooms: Tuple[float, ...] = (1.0, 0.5, 0.25)
    degrade_alpha_scales: Tuple[float, ...] = (2.0, 4.0, 8.0)
    degrade_round_caps: Tuple[int, ...] = (0, 8, 4)
    seed: int = 0
    # Compile-contract auditing (repro.analysis.hlo_audit): when set,
    # warmup() walks every AOT executable's optimized HLO and raises
    # AuditError (with op provenance) on any host sync, f64 math,
    # f32-resident corpus promotion, over-budget collective traffic
    # (scorecard merge + two scalar psums is the sharded contract) or
    # peak temp buffers past ``audit_peak_bytes`` (0 = a generous
    # corpus-derived bound). ``audit_require_bf16`` additionally treats a
    # non-bf16 corpus itself as a promotion-contract violation.
    audit: bool = False
    audit_peak_bytes: int = 0
    audit_require_bf16: bool = False


class AdmissionRejected(RuntimeError):
    """Raised by ``submit`` under ``backpressure="reject"``: the queue is
    deep enough that the request's completion deadline is already
    unmeetable at admission time."""


@dataclasses.dataclass
class Request:
    """One retrieval request: (query, deadline, k)."""

    query: np.ndarray                       # (T, M) float32 token embeddings
    k: int = 10
    deadline_s: Optional[float] = None      # completion deadline (arrival-rel)
    cand_ids: Optional[np.ndarray] = None   # (n,) global doc ids; None=stage-1
    # filled in by the engine
    rid: int = -1
    arrival: float = 0.0
    # Absolute completion deadline (clock frame), stamped once at admission.
    # Equivalent to arrival + deadline_s, but carried explicitly so the
    # serve-time miss decision (t_done > deadline_abs) has exactly one
    # source of truth — the contract the stale-next_expiry admission test
    # pins down.
    deadline_abs: Optional[float] = None
    # Fraction of the request's ORIGINAL candidate list that survived
    # admission (backpressure="degrade" truncation); multiplies into the
    # completion's coverage so a degraded answer is visibly partial.
    coverage_scale: float = 1.0


@dataclasses.dataclass
class Completion:
    rid: int
    topk_ids: np.ndarray          # (k,) global doc ids, -1 padded
    topk_scores: np.ndarray       # (k,) f32
    queue_wait_s: float           # admission latency
    latency_s: float              # arrival -> results materialized
    deadline_miss: bool
    flavor: str
    bucket: Tuple[int, int]       # (token_bucket, cand_bucket)
    reveal_fraction: float        # fraction of MaxSim cells computed
    # Fraction of the request's candidate universe actually searched:
    # 1.0 on a fully healthy serve; < 1 when a failed shard's documents
    # were masked out of the merge (candidate-mass fraction on healthy
    # shards) or admission truncated the candidate list (coverage_scale).
    # 0.0 on an ``error`` completion — nothing was searched.
    coverage: float = 1.0
    # Fidelity-ladder rung this request's batch ran at (0 = full fidelity).
    degrade_level: int = 0
    # Loud-failure surface: None on a served completion; the failure
    # reason when the engine could not serve the request (stopped with
    # work queued and flushing impossible, supervision budget exhausted,
    # continuous-mode slot lost to a thread restart). topk_ids are all -1.
    error: Optional[str] = None


@dataclasses.dataclass
class BatchRecord:
    bucket: Tuple[int, int]
    flavor: str
    n_real: int
    occupancy: float              # n_real / batch_size
    service_s: float              # release -> results materialized
    reveal_fraction: float
    # Reveal-engine diagnostics (service.py stats vector): live-slot
    # fraction of the pooled frontier (or lockstep duty cycle for the
    # vmapped engine), per-query reveal rounds actually attributable to
    # queries, and the rounds a lockstep loop would have wasted on
    # already-converged queries. Dense batches report (1, 0, 0).
    # On a sharded corpus these aggregate over shards (mean occupancy of
    # the shards that did bandit work, summed rounds/waste) and the raw
    # per-shard vectors land in shard_occupancy / shard_rounds.
    frontier_occupancy: float = 1.0
    total_rounds: float = 0.0
    lockstep_waste: float = 0.0
    shard_occupancy: Optional[Tuple[float, ...]] = None
    shard_rounds: Optional[Tuple[float, ...]] = None
    # Routed (shard-local stage-1) batches only: each shard's mean routed
    # quota share over the batch's queries (columns sum to ~1 across
    # shards; uniform = 1/n_shards). The skew signal metrics.summary()
    # aggregates into routed_quota_share_mean / routed_skew.
    shard_quota_share: Optional[Tuple[float, ...]] = None
    # (doc, query) cells quarantined by the finite-score guard (poisoned
    # corpus rows surfacing NaN/Inf MaxSim values), summed over shards.
    quarantined: float = 0.0
    # Fidelity-ladder rung the batch ran at (0 = full fidelity).
    degrade_level: int = 0


class EngineMetrics:
    """Serving metrics: per-request, per-batch, and compile accounting.

    Mutations go through the ``record_*`` methods, which take an internal
    lock — the async engine's admit, dispatch and caller threads all write
    here concurrently. ``summary()`` snapshots under the same lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self.completions: List[Completion] = []
        self.batches: List[BatchRecord] = []
        self.compiles: Dict[tuple, int] = {}
        self.compiles_after_warmup: int = 0
        # Backpressure accounting (async engine): requests refused outright
        # and requests admitted with a truncated candidate list.
        self.rejected: int = 0
        self.degraded: int = 0
        # Warmup-time kernel autotuning accounting: wall seconds spent
        # timing candidates, buckets measured this warmup, and entries
        # reused from a persisted tuning table instead of re-timed.
        self.autotune_s: float = 0.0
        self.autotune_buckets: int = 0
        self.tuning_entries_loaded: int = 0
        # Resilience accounting: shard-health transitions to unhealthy,
        # the live per-shard health vector (None off-mesh), and serving
        # threads restarted by the supervision watchdog.
        self.failovers: int = 0
        self.shard_health: Optional[List[bool]] = None
        self.thread_restarts: Dict[str, int] = {}

    def record_compile(self, key: tuple, after_warmup: bool) -> None:
        with self._lock:
            self.compiles[key] = self.compiles.get(key, 0) + 1
            if after_warmup:
                self.compiles_after_warmup += 1

    def record_batch(self, record: BatchRecord,
                     completions: Sequence[Completion]) -> None:
        with self._lock:
            self.batches.append(record)
            self.completions.extend(completions)

    def record_rejected(self) -> None:
        with self._lock:
            self.rejected += 1

    def record_degraded(self) -> None:
        with self._lock:
            self.degraded += 1

    def record_failover(self) -> None:
        with self._lock:
            self.failovers += 1

    def record_shard_health(self, healthy: Sequence[bool]) -> None:
        with self._lock:
            self.shard_health = [bool(h) for h in healthy]

    def record_restart(self, name: str) -> None:
        with self._lock:
            self.thread_restarts[name] = self.thread_restarts.get(name, 0) + 1

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            reqs, bats = list(self.completions), list(self.batches)
            n_compiles = int(sum(self.compiles.values()))
            n_after = int(self.compiles_after_warmup)
            n_rej, n_deg = self.rejected, self.degraded
            n_fail = self.failovers
            health = (None if self.shard_health is None
                      else list(self.shard_health))
            restarts = dict(self.thread_restarts)
        bandit_bats = [b for b in bats if b.flavor == "bandit"]
        waits = np.array([c.queue_wait_s for c in reqs]) if reqs else np.zeros(1)
        lats = np.array([c.latency_s for c in reqs]) if reqs else np.zeros(1)
        return {
            "n_requests": len(reqs),
            "n_batches": len(bats),
            "queue_wait_p50_ms": float(np.percentile(waits, 50) * 1e3),
            "queue_wait_p99_ms": float(np.percentile(waits, 99) * 1e3),
            "latency_p50_ms": float(np.percentile(lats, 50) * 1e3),
            "latency_p99_ms": float(np.percentile(lats, 99) * 1e3),
            "deadline_miss_rate": (float(np.mean([c.deadline_miss
                                                  for c in reqs]))
                                   if reqs else 0.0),
            "mean_occupancy": (float(np.mean([b.occupancy for b in bats]))
                               if bats else 0.0),
            "mean_reveal_fraction": (float(np.mean([b.reveal_fraction
                                                    for b in bats]))
                                     if bats else 0.0),
            # Bandit batches only: dense batches report a placeholder 1.0
            # that would dilute the frontier diagnostic under mixed traffic.
            "mean_frontier_occupancy": (float(np.mean(
                [b.frontier_occupancy for b in bandit_bats]))
                if bandit_bats else 0.0),
            "total_reveal_rounds": float(sum(b.total_rounds for b in bats)),
            "total_lockstep_waste": float(sum(b.lockstep_waste
                                              for b in bats)),
            "compiles": n_compiles,
            "compiles_after_warmup": n_after,
            "rejected": int(n_rej),
            "degraded": int(n_deg),
            "autotune_s": float(self.autotune_s),
            "autotune_buckets": int(self.autotune_buckets),
            "tuning_entries_loaded": int(self.tuning_entries_loaded),
            # Resilience surface: quarantined poisoned cells, mean answer
            # coverage (served completions only — error completions carry
            # coverage 0 but no search), ladder activity, failovers, the
            # live shard-health vector, and watchdog restarts.
            "quarantined_total": float(sum(b.quarantined for b in bats)),
            "mean_coverage": (float(np.mean([c.coverage for c in reqs
                                             if c.error is None] or [1.0]))),
            "errors": int(sum(1 for c in reqs if c.error is not None)),
            "ladder_degraded_batches": int(sum(1 for b in bats
                                               if b.degrade_level > 0)),
            "failovers": int(n_fail),
            **({"shard_healthy": health} if health is not None else {}),
            "thread_restarts": restarts,
            **self._shard_summary(bats),
        }

    def _shard_summary(self, bats: List[BatchRecord]) -> Dict[str, Any]:
        """Per-shard aggregates over the sharded-corpus batches: summed
        bandit rounds and mean frontier occupancy per shard — the routing
        skew / straggler signal the mesh operator watches."""
        sharded = [b for b in bats if b.shard_rounds is not None]
        if not sharded:
            return {}
        rounds = np.sum([b.shard_rounds for b in sharded], axis=0)
        occ = np.mean([b.shard_occupancy for b in sharded], axis=0)
        out = {
            "n_shards": len(rounds),
            "shard_rounds_total": [float(r) for r in rounds],
            "shard_occupancy_mean": [float(o) for o in occ],
        }
        routed = [b for b in sharded if b.shard_quota_share is not None]
        if routed:
            qs = np.mean([b.shard_quota_share for b in routed], axis=0)
            # skew = hottest shard's share relative to a uniform split
            # (1.0 = perfectly balanced routing, n_shards = worst case).
            out["routed_quota_share_mean"] = [float(q) for q in qs]
            out["routed_skew"] = float(np.max(qs) * len(qs))
        return out


class _Prepared(NamedTuple):
    """A released batch after host-side preparation (bucketing, padding,
    stage-1, routing): everything the dispatch thread needs to launch the
    device program and the harvest step needs to attribute results."""

    real: List[Request]
    n_real: int
    bucket: Tuple[int, int]
    flavor: str
    exe: Any
    args: tuple
    t_release: float
    # Batch ordinal: the idempotency key the supervised dispatch path uses
    # to guarantee a batch is harvested exactly once across thread restarts.
    bid: int = -1
    # Per-real-request fraction of candidate mass on HEALTHY shards at
    # prepare time (None = fully healthy, i.e. all 1.0).
    coverage: Optional[np.ndarray] = None
    degrade_level: int = 0


class RetrievalEngine:
    """Deadline-batched, shape-bucketed late-interaction serving loop.

    Typical use::

        engine = RetrievalEngine(doc_embs, doc_mask, EngineConfig(...))
        engine.warmup()                        # compile every bucket
        rid = engine.submit(Request(query=q, k=5, deadline_s=0.05))
        done = engine.poll()                   # [] until a batch releases
        done += engine.drain()                 # end of stream: flush queue

    ``clock`` is injectable so tests and simulations drive virtual time.

    Batch execution is staged as prepare (host: bucket, pad, stage-1,
    route) -> dispatch (launch the AOT executable; returns device arrays
    without blocking) -> finish (block_until_ready + attribution). This
    engine runs the three stages back to back per batch — the synchronous
    parity oracle; :class:`AsyncRetrievalEngine` runs them on a pipeline.
    """

    def __init__(self, corpus_embs, corpus_mask,
                 config: Optional[EngineConfig] = None, *,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = config or EngineConfig()
        self.clock = clock
        if self.cfg.stage1 not in ("host", "local"):
            raise ValueError(f"unknown stage1 placement {self.cfg.stage1!r} "
                             "(expected 'host' or 'local')")
        if self.cfg.corpus_format not in CORPUS_FORMATS:
            raise ValueError(
                f"unknown corpus_format {self.cfg.corpus_format!r} "
                f"(expected one of {sorted(CORPUS_FORMATS)})")
        self._quantized = self.cfg.corpus_format != "bf16"
        if self._quantized and self.cfg.stage1 == "local":
            raise ValueError(
                "stage1='local' routes candidates by scanning raw corpus "
                "token rows inside the shard_map and cannot serve a "
                f"{self.cfg.corpus_format!r} corpus; use stage1='host' "
                "with candidate-carrying requests")
        mesh = None
        if self.cfg.mesh_axes:
            names = tuple(a for a, _ in self.cfg.mesh_axes)
            shape = tuple(int(n) for _, n in self.cfg.mesh_axes)
            mesh = jax.make_mesh(shape, names)
        elif self.cfg.stage1 == "local":
            raise ValueError("stage1='local' runs inside the corpus "
                             "shard_map and needs mesh_axes")
        self._routed = mesh is not None and self.cfg.stage1 == "local"
        # The unified facade (repro.retrieval.corpus): one attribute
        # surface for the single-device and mesh-resident placements; the
        # centroid router is built at shard time when shard-local stage-1
        # will consume it.
        self.corpus: Corpus = build_corpus(
            corpus_embs, corpus_mask, mesh=mesh,
            n_centroids=self.cfg.stage1_centroids if self._routed else 0,
            router_seed=self.cfg.seed,
            corpus_format=self.cfg.corpus_format)
        self.corpus_embs = self.corpus.embs
        self.corpus_mask = self.corpus.mask
        self._router_args = self.corpus.router_arrays()
        if mesh is not None:
            self._valid_docs = self.corpus.valid_docs_device()
        self.buckets = ShapeBuckets(self.cfg.token_buckets,
                                    self.cfg.cand_buckets)
        self._stage1_n = (self.cfg.stage1_candidates
                          or self.buckets.cand_buckets[0])
        self._stage1_n = self.buckets.cand_bucket(self._stage1_n)
        self._service_ema = 0.0           # observed batch service time (s)
        # Admission headroom is a LIVE callable: the batcher derives each
        # deadline-carrying request's admission deadline as
        # ``deadline_abs - headroom()`` at poll time, so a service-time EMA
        # that rises while requests queue tightens their release point
        # instead of leaving them frozen at submit-time headroom.
        self._batcher = DeadlineBatcher(self.cfg.batch_size,
                                        self.cfg.deadline_s, clock=clock,
                                        headroom=self._admission_headroom)
        self._exec: Dict[tuple, Any] = {}
        # Compile-once across threads (admit thread compiles stage-1 on a
        # cold miss while the dispatch thread compiles a step, etc.).
        self._exec_lock = threading.RLock()
        self._state_lock = threading.Lock()      # guards _service_ema
        self._rid = itertools.count()
        # Batch ORDINAL, not a raw seed: the executable folds it into the
        # key(cfg.seed) stream, so every batch (whatever its shape bucket)
        # reveals a distinct cell trajectory and the whole stream replays
        # bit-identically from the same config.
        self._batch_seed = itertools.count()
        self._bid = itertools.count()            # _Prepared idempotency key
        self._warmed = False
        self.metrics = EngineMetrics()
        # Fidelity ladder (validated eagerly even when backpressure!="degrade"
        # so a bad config fails at construction, not mid-serve).
        self._ladder = DegradeLadder(
            headrooms=tuple(self.cfg.degrade_headrooms),
            alpha_scales=tuple(self.cfg.degrade_alpha_scales),
            round_caps=tuple(self.cfg.degrade_round_caps))
        # Per-shard health (mesh engines only): the failover mask every
        # prepared batch snapshots. Mutable at runtime via fail_shard /
        # restore_shard — the compiled executables take it as a traced
        # operand, so flipping health never recompiles.
        self._health_lock = threading.Lock()
        self._healthy: Optional[np.ndarray] = None
        if mesh is not None:
            self._healthy = np.ones((self.corpus.n_shards,), bool)
            self.metrics.record_shard_health(self._healthy)

    def _admission_headroom(self) -> float:
        """Expected batch service time the batcher must leave between
        admission and the completion deadline — the LIVE estimate, floored
        by the configured static headroom."""
        with self._state_lock:
            return max(self.cfg.deadline_headroom_s, self._service_ema)

    @property
    def sharded(self) -> Optional[Corpus]:
        """The mesh-resident corpus view, None on a single-device engine
        (back-compat name; ``self.corpus`` is the unified facade)."""
        return self.corpus if self.corpus.mesh is not None else None

    # -- shard health / failover ------------------------------------------

    def shard_health(self) -> Optional[np.ndarray]:
        """Copy of the per-shard health mask (None off-mesh)."""
        if self._healthy is None:
            return None
        with self._health_lock:
            return self._healthy.copy()

    def set_shard_health(self, shard: int, healthy: bool) -> None:
        """Flip one shard's health. An unhealthy shard stops receiving
        routed quota mass (its share re-routes to the healthy shards) and
        its documents are masked out of the scorecard merge; completions
        report the resulting partial ``coverage``. Traced, not compiled:
        the health vector is an executable operand."""
        if self._healthy is None:
            raise ValueError("shard health needs a mesh-resident corpus "
                             "(set mesh_axes)")
        S = len(self._healthy)
        if not 0 <= shard < S:
            raise ValueError(f"shard {shard} out of range [0, {S})")
        with self._health_lock:
            went_down = bool(self._healthy[shard]) and not healthy
            self._healthy[shard] = bool(healthy)
            snap = self._healthy.copy()
        if went_down:
            self.metrics.record_failover()
        self.metrics.record_shard_health(snap)

    def fail_shard(self, shard: int) -> None:
        self.set_shard_health(shard, False)

    def restore_shard(self, shard: int) -> None:
        self.set_shard_health(shard, True)

    # -- flavor policy ----------------------------------------------------

    def flavor_for(self, cand_bucket: int) -> str:
        """Dense-vs-bandit dispatch: fixed flavor, or (auto) adaptive
        reranking once the candidate bucket is large enough for the bandit's
        sublinear reveal count to beat dense scoring's fixed N*T cost."""
        if self.cfg.flavor in ("dense", "bandit"):
            return self.cfg.flavor
        if self.cfg.flavor != "auto":
            raise ValueError(f"unknown flavor {self.cfg.flavor!r}")
        return ("bandit" if cand_bucket >= self.cfg.bandit_min_candidates
                else "dense")

    # -- compilation cache ------------------------------------------------

    @property
    def compiled_buckets(self) -> List[tuple]:
        return sorted(self._exec)

    def _executable(self, key: tuple):
        """One AOT executable per bucket key; compiles (and counts) on miss.
        Thread-safe: a cold miss compiles under the executable lock, so two
        threads racing the same key produce one compile."""
        exe = self._exec.get(key)
        if exe is not None:
            return exe
        with self._exec_lock:
            return self._compile(key)

    def _compile(self, key: tuple):
        with self._exec_lock:
            exe = self._exec.get(key)
            if exe is not None:
                return exe
            exe = self._build(key)
            self._exec[key] = exe
        self.metrics.record_compile(key, after_warmup=self._warmed)
        return exe

    def _build(self, key: tuple):
        """Lower + AOT-compile the executable for one bucket key (no cache
        interaction — ``_compile`` owns the cache and its lock)."""
        cfg = self.cfg
        B = cfg.batch_size
        M = self.corpus_embs.shape[2]
        if key[0] == "step":
            _, flavor, tb, nb = key
            if self.sharded is not None:
                S = self.sharded.n_shards
                step = make_sharded_serving_step(
                    self.sharded.mesh, flavor, topk=cfg.max_k,
                    corpus_format=cfg.corpus_format,
                    alpha_ef=cfg.alpha_ef, delta=cfg.delta,
                    block_docs=cfg.block_docs,
                    block_tokens=cfg.block_tokens,
                    max_rounds=cfg.max_rounds,
                    max_block_docs=cfg.max_block_docs,
                    max_block_tokens=cfg.max_block_tokens,
                    engine=cfg.bandit_engine, base_seed=cfg.seed)
                # Health mask + fidelity knobs are traced operands on the
                # ONE lowered program: failover and ladder rungs at runtime
                # never recompile, and the all-healthy/level-0 values are
                # bit-identical to the knob-less trace.
                args = (self.corpus_embs, self.corpus_mask,
                        SDS((B, tb, M), jnp.float32),
                        SDS((B, S, nb), jnp.int32),
                        SDS((B, S, nb, tb), jnp.float32),
                        SDS((B, S, nb, tb), jnp.float32),
                        SDS((S,), jnp.int32),
                        SDS((), jnp.int32),
                        SDS((S,), jnp.bool_),
                        SDS((), jnp.float32),
                        SDS((), jnp.int32))
                exe = jax.jit(step).lower(*args).compile()
            else:
                step = make_serving_step(
                    flavor, topk=cfg.max_k, alpha_ef=cfg.alpha_ef,
                    delta=cfg.delta, block_docs=cfg.block_docs,
                    block_tokens=cfg.block_tokens, max_rounds=cfg.max_rounds,
                    max_block_docs=cfg.max_block_docs,
                    max_block_tokens=cfg.max_block_tokens,
                    engine=cfg.bandit_engine)
                base = cfg.seed

                def run(ce, cm, q, cand, a, b, seed, a_s, r_c):
                    # Per-batch PRNG: fold the batch ordinal into the
                    # engine-seed stream (never key(seed + ordinal), which
                    # aliases across engines with nearby seeds).
                    k = jax.random.fold_in(jax.random.key(base), seed)
                    return step(ce, cm, q, cand, a, b, k,
                                alpha_scale=a_s, round_cap=r_c)

                args = (self.corpus_embs, self.corpus_mask,
                        SDS((B, tb, M), jnp.float32),
                        SDS((B, nb), jnp.int32),
                        SDS((B, nb, tb), jnp.float32),
                        SDS((B, nb, tb), jnp.float32),
                        SDS((), jnp.int32),
                        SDS((), jnp.float32),
                        SDS((), jnp.int32))
                exe = jax.jit(run).lower(*args).compile()
        elif key[0] == "routed":
            # One-shard_map pipeline: centroid route + shard-local stage-1
            # + rerank + merge, one executable per (flavor, token bucket)
            # — the candidate bucket is pinned to the stage-1 width.
            _, flavor, tb = key
            corpus = self.corpus
            step = make_routed_serving_step(
                corpus.mesh, flavor, topk=cfg.max_k,
                n_local=self._stage1_n, n_total=cfg.stage1_total,
                kprime=cfg.stage1_kprime, support=cfg.support,
                prereveal_ann=cfg.prereveal_ann, alpha_ef=cfg.alpha_ef,
                delta=cfg.delta, block_docs=cfg.block_docs,
                block_tokens=cfg.block_tokens, max_rounds=cfg.max_rounds,
                max_block_docs=cfg.max_block_docs,
                max_block_tokens=cfg.max_block_tokens,
                engine=cfg.bandit_engine, base_seed=cfg.seed)
            cents, mass = self._router_args
            args = (self.corpus_embs, self.corpus_mask, cents, mass,
                    SDS((B, tb, M), jnp.float32),
                    SDS((corpus.n_shards,), jnp.int32),
                    SDS((), jnp.int32),
                    SDS((corpus.n_shards,), jnp.bool_),
                    SDS((), jnp.float32),
                    SDS((), jnp.int32))
            exe = jax.jit(step).lower(*args).compile()
        elif key[0] == "stream":
            # Continuous-batching slice executable: one static shape for
            # the whole stream, per-slot PRNG keys, frontier state donated
            # (the old slice's buffers back the new slice's).
            _, tb, nb = key
            if self.sharded is not None:
                raise ValueError("continuous (slot-refill) serving is "
                                 "single-device; unset mesh_axes")
            step = make_streaming_step(
                topk=cfg.max_k, alpha_ef=cfg.alpha_ef, delta=cfg.delta,
                block_docs=cfg.block_docs, block_tokens=cfg.block_tokens,
                max_rounds=cfg.max_rounds,
                max_block_docs=cfg.max_block_docs,
                max_block_tokens=cfg.max_block_tokens,
                trip_limit=cfg.stream_trip_limit)
            kd = jax.random.key(0).dtype
            state_sds = FrontierState(
                cellvals=SDS((B * nb, tb), jnp.float32),
                stats=SDS((B * nb, 3), jnp.float32),
                key=SDS((B,), kd),
                rounds=SDS((B,), jnp.int32),
                done=SDS((B,), jnp.bool_))
            args = (self.corpus_embs, self.corpus_mask,
                    SDS((B, tb, M), jnp.float32),
                    SDS((B, nb), jnp.int32),
                    SDS((B, nb, tb), jnp.float32),
                    SDS((B, nb, tb), jnp.float32),
                    state_sds,
                    SDS((B,), jnp.bool_),
                    SDS((B,), kd))
            exe = jax.jit(step, donate_argnums=(6,)).lower(*args).compile()
        elif key[0] == "stage1":
            _, tb = key
            if self._quantized:
                raise ValueError(
                    "stage-1 ANN needs a dense corpus; quantized engines "
                    "serve candidate-carrying requests only")
            nb, kp, support = self._stage1_n, cfg.stage1_kprime, cfg.support

            def stage1(ce, cm, q):
                def one(qq):
                    cs = generate_candidates(ce, cm, qq, kprime=kp,
                                             max_candidates=nb,
                                             support=support)
                    return cs.doc_ids, cs.a, cs.b
                return jax.vmap(one)(q)

            args = (self.corpus_embs, self.corpus_mask,
                    SDS((B, tb, M), jnp.float32))
            exe = jax.jit(stage1).lower(*args).compile()
        else:
            raise KeyError(key)
        return exe

    def _autotune_dims(self) -> List[Tuple[str, Dict[str, int]]]:
        """The (op, dims) kernel shape buckets the compiled executables
        will launch — dense buckets hit ``maxsim_batch``, bandit buckets
        hit the fused reveal round (and its ``gather_maxsim`` chain-oracle
        twin, so A/B runs stay tuned too)."""
        cfg = self.cfg
        B = cfg.batch_size
        L, M = self.corpus_embs.shape[1], self.corpus_embs.shape[2]
        half = max(cfg.block_docs // 2, 1)
        G = max(cfg.block_tokens, 1)
        # Mirror ops._fmt_dims: a quantized launch keys its tuning bucket
        # with the format ordinal, so the tuned bucket IS the launched
        # bucket; bf16 adds nothing (persisted tables stay valid).
        fmt = ({} if not self._quantized
               else {"FMT": format_ordinal(cfg.corpus_format)})
        out: List[Tuple[str, Dict[str, int]]] = []
        for tb in self.buckets.token_buckets:
            for nb in self.buckets.cand_buckets:
                # Sharded or not, the per-device candidate list is nb wide
                # (route_batch packs n_local=nb slots per shard).
                if self.flavor_for(nb) == "dense":
                    out.append(("maxsim_batch",
                                dict(B=B, N=nb, T=tb, L=L, M=M, **fmt)))
                else:
                    # Frontier reveal launch geometry — MUST mirror
                    # core.frontier's width math or the tuned bucket is
                    # never the launched bucket: selection widths grow
                    # with the growth knobs (half_w docs, G_cap tokens),
                    # and the launch batch is the flat Q*W rows without
                    # doc growth or the compacted F = Q*2*half with it.
                    half_w = min(max(cfg.max_block_docs // 2, half),
                                 max(nb, 1))
                    rows = B * 2 * (half if half_w > half else half_w)
                    g = min(max(cfg.max_block_tokens, G), max(tb, 1))
                    dims = dict(B=rows, G=g, L=L, M=M, D=B * nb, TQ=B * tb,
                                **fmt)
                    out.append(("fused_reveal", dims))
                    out.append(("gather_maxsim", dims))
        return out

    def autotune(self) -> int:
        """Time candidate kernel block configurations for every shape
        bucket the serving executables will launch and record the winners
        in the tuning table (``repro.kernels.tuning``). Buckets already
        covered by a loaded table entry are skipped. Returns the number of
        buckets measured; wall time lands in ``metrics.autotune_s``."""
        t0 = time.perf_counter()
        measured = 0
        for op, dims in self._autotune_dims():
            if tuning.bucket_key(op, dims) in tuning.table():
                continue
            # Time at the corpus dtype: a bf16 corpus moves half the bytes
            # per tile, and the winning block_l can differ from f32's. A
            # quantized bucket carries its FMT dim — autotune_op encodes
            # the synthetic corpus into that format itself, so the dense
            # dtype here covers the queries (and the pre-encode source).
            dtype = (jnp.float32 if self._quantized
                     else self.corpus_embs.dtype)
            autotune_op(op, dims, dtype=dtype)
            measured += 1
        self.metrics.autotune_s += time.perf_counter() - t0
        self.metrics.autotune_buckets += measured
        return measured

    def warmup(self) -> List[tuple]:
        """Pre-compile every bucket the policy can reach; after this returns
        the engine serves any admissible stream with zero recompiles.

        When ``cfg.autotune`` is set, kernel block sizes are tuned FIRST
        (per shape bucket, reusing/persisting ``cfg.tuning_table``), so the
        AOT executables bake in the tuned tiles and the zero-recompile
        contract is untouched."""
        cfg = self.cfg
        if cfg.tuning_table and os.path.exists(cfg.tuning_table):
            self.metrics.tuning_entries_loaded += tuning.load_table(
                cfg.tuning_table)
        if cfg.autotune:
            self.autotune()
            if cfg.tuning_table:
                # Persist only THIS engine's buckets: the in-process table
                # is a shared cache across engines, and dumping it whole
                # would leak another engine's buckets into this file.
                tuning.save_table(cfg.tuning_table, keys={
                    tuning.bucket_key(op, dims)
                    for op, dims in self._autotune_dims()})
        for tb in self.buckets.token_buckets:
            if not self._quantized:
                # Stage-1 ANN traces over raw token rows; quantized engines
                # reject candidate-less requests at submit, so the bucket
                # is unreachable and compiling it would fail.
                self._executable(("stage1", tb))
            if self._routed:
                # Candidate-less batches dispatch to the one-shard_map
                # routed pipeline; the host stage-1/step executables stay
                # compiled too (mixed candidate-carrying traffic).
                self._executable(("routed", self.flavor_for(self._stage1_n),
                                  tb))
            for nb in self.buckets.cand_buckets:
                # flavor_for is a pure function of the bucket, so exactly one
                # flavor is reachable per (tb, nb) — compile just that one.
                self._executable(("step", self.flavor_for(nb), tb, nb))
        if cfg.continuous:
            self._executable(("stream", *self._stream_bucket))
        self._warmed = True
        if cfg.audit:
            self.audit()
        return self.compiled_buckets

    # -- compile-contract audit -------------------------------------------

    _HLO_DTYPES = {"bfloat16": "bf16", "float16": "f16", "float32": "f32",
                   "float64": "f64", "int8": "s8"}

    def _bucket_peak_bound(self, key: tuple) -> int:
        """Expected peak temp-buffer bound for ONE bucket, derived from its
        launch geometry and the corpus residency format (instead of the old
        engine-wide 8x-corpus blanket): the gathered candidate working set
        in resident-format bytes, the f32 reconstruction/similarity copies
        the scorers materialize, and (stage-1 only) the full-index
        similarity scan. Factors are deliberately generous — interpret-mode
        kernels materialize more than a TPU launch — but the bound now
        scales with the bucket, so a bucket that materializes another
        bucket's (or the whole corpus's) working set still trips
        ``hlo-peak-buffer``. ``cfg.audit_peak_bytes`` overrides."""
        cfg = self.cfg
        B = cfg.batch_size
        rows, L, M = self.corpus_embs.shape
        corpus_bytes = corpus_nbytes(self.corpus_embs)
        if self.corpus.mesh is not None:
            # Per-device SPMD program: shard-local corpus, shard-local temps.
            shards = max(self.corpus.n_shards, 1)
            rows //= shards
            corpus_bytes //= shards
        if key[0] == "step":
            tb, nb = key[2], key[3]
        elif key[0] == "stream":
            tb, nb = key[1], key[2]
        elif key[0] == "routed":
            tb, nb = key[2], self._stage1_n
        else:                                     # ("stage1", tb)
            tb, nb = key[1], self._stage1_n
        fmt = cfg.corpus_format
        if fmt == "bf16":
            row_bytes = L * M * self.corpus_embs.dtype.itemsize
        else:
            # int8 payload + bf16 scale plane (+ i32 centroid ids).
            row_bytes = L * M + L * (2 + (4 if fmt == "residual" else 0))
        gathered = B * nb * row_bytes             # resident-format gather
        work = B * nb * L * max(M, tb) * 4        # f32 dequant/sim copies
        if key[0] in ("stage1", "routed"):
            work += B * tb * rows * L * 4         # full-index token kNN
        return 8 * (gathered + work) + corpus_bytes + (256 << 20)

    def _audit_spec(self, key: tuple) -> AuditSpec:
        """The per-bucket compile contract ``audit()`` asserts.

        Collective budget: a mesh-resident step/routed executable may move
        exactly the scorecard merge (per-shard (scores, gids) top-K lists)
        plus two scalar-per-query psums across shards —
        ``scorecard_budget_bytes(B, S, max_k)``; candidate embeddings and
        reveal traffic must stay shard-local. Host stage-1 over a sharded
        corpus legitimately all-gathers the index (the documented
        exemption: candidate-less traffic belongs on the routed path), so
        that one key is unbudgeted. Everything off-mesh gets budget 0.

        Boundary residency: a bf16 corpus arms the promotion rule; a
        quantized corpus (``corpus_embs`` is a QuantTokens whose payload
        dtype is int8) arms ``hlo-int8-residency`` — the compressed payload
        must enter every executable as an s8 parameter, never widened.
        """
        cfg = self.cfg
        corpus_dtype = self._HLO_DTYPES.get(str(self.corpus_embs.dtype))
        if cfg.audit_require_bf16 and corpus_dtype != "s8":
            # Declare the contract dtype rather than the observed one: a
            # corpus already resident in f32 then trips the promotion rule
            # on its own (corpus-sized f32) entry parameters. A quantized
            # corpus is already under the stricter int8 rule.
            corpus_dtype = "bf16"
        corpus_elems = int(np.prod(self.corpus_embs.shape))
        meshed = self.corpus.mesh is not None
        if meshed:
            # Optimized HLO is per-device SPMD: entry parameters carry
            # shard-local shapes, so the promotion threshold must too.
            corpus_elems //= max(self.corpus.n_shards, 1)
        if key[0] in ("step", "routed") and meshed:
            budget = scorecard_budget_bytes(cfg.batch_size,
                                            self.corpus.n_shards, cfg.max_k)
        elif key[0] == "stage1" and meshed:
            budget = None
        else:
            budget = 0
        peak = cfg.audit_peak_bytes or self._bucket_peak_bound(key)
        return AuditSpec(collective_budget=budget, peak_bytes=peak,
                         corpus_dtype=corpus_dtype,
                         corpus_elems=corpus_elems)

    def audit(self) -> Dict[tuple, Any]:
        """Run the compile-contract auditor over every compiled bucket:
        no host callbacks / infeed / outfeed, no f64, no f32-resident
        corpus promotion (bf16 corpora), collective bytes within the
        scorecard budget, peak temp buffers bounded. Raises
        :class:`repro.analysis.hlo_audit.AuditError` with op provenance on
        the first violated contract; returns ``{bucket key: AuditReport}``
        when every executable passes."""
        with self._exec_lock:
            items = sorted(self._exec.items())
        reports: Dict[tuple, Any] = {}
        for key, exe in items:
            reports[key] = audit_executable(exe, self._audit_spec(key),
                                            label=repr(key))
        return reports

    @property
    def _stream_bucket(self) -> Tuple[int, int]:
        """Continuous mode serves every request through ONE compiled shape:
        the largest token bucket x the largest candidate bucket (any
        admissible request pads into it, so refill never recompiles)."""
        return (self.buckets.token_buckets[-1],
                max(self.buckets.cand_buckets[-1], self._stage1_n))

    # -- request lifecycle ------------------------------------------------

    def submit(self, request: Request) -> int:
        """Admit one request; returns its rid. Completions surface from
        ``poll``/``drain`` (requests are served strictly in batches).
        The caller's Request is not mutated — the engine queues its own
        copy, so one Request object may be submitted repeatedly."""
        q = np.asarray(request.query, np.float32)
        if q.ndim != 2 or q.shape[1] != self.corpus_embs.shape[2]:
            raise ValueError(f"query must be (T, {self.corpus_embs.shape[2]})")
        self.buckets.token_bucket(q.shape[0])          # validate fit
        if request.cand_ids is None and self._quantized:
            # Stage-1 ANN (retrieval.ann.generate_candidates) scans raw
            # token rows; a compressed corpus only serves the rerank path.
            raise ValueError(
                "candidate-less requests need the engine's stage-1 ANN, "
                f"which a {self.cfg.corpus_format!r} corpus cannot run — "
                "provide cand_ids or serve with corpus_format='bf16'")
        if request.cand_ids is not None:
            self.buckets.cand_bucket(len(request.cand_ids))
            cand = np.asarray(request.cand_ids)
            n_docs = (self.sharded.n_docs if self.sharded is not None
                      else self.corpus_embs.shape[0])
            if cand.size and (cand.min() < 0 or cand.max() >= n_docs):
                # Reject the one bad request HERE: a stale id surfacing
                # later (e.g. from the sharded routing table) would fail
                # mid-batch and take every batchmate down with it.
                raise ValueError(
                    f"cand_ids must lie in [0, {n_docs}); got range "
                    f"[{int(cand.min())}, {int(cand.max())}]")
        if request.k > self.cfg.max_k:
            raise ValueError(f"k={request.k} > compiled max_k={self.cfg.max_k}")
        arrival = self.clock()
        admitted = dataclasses.replace(
            request, query=q, rid=next(self._rid), arrival=arrival,
            deadline_abs=(None if request.deadline_s is None
                          else arrival + request.deadline_s))
        # Admission deadline = completion deadline - expected service time,
        # so the batch still has time to EXECUTE before the request is due.
        # The batcher derives it from ``deadline_abs`` and the engine's
        # live ``_admission_headroom()`` at every poll — never frozen here,
        # where a later EMA rise could not reach it.
        self._enqueue(admitted)
        return admitted.rid

    def _enqueue(self, admitted: Request) -> None:
        """Queue placement for a validated request (the async engine's
        continuous mode overrides this to feed the slot-refill stream)."""
        self._batcher.add(admitted, deadline_abs=admitted.deadline_abs)

    def next_expiry(self) -> Optional[float]:
        """Absolute clock time at which the pending (partial) batch will be
        released; None when the queue is empty. Drive your poll loop off
        this instead of busy-waiting."""
        return self._batcher.next_expiry()

    def poll(self) -> List[Completion]:
        """Serve at most one released batch; [] while the admission queue is
        neither full nor past its tightest deadline."""
        out = self._batcher.poll()
        if out is None:
            return []
        return self._serve_batch(*out)

    def drain(self) -> List[Completion]:
        """End of stream: serve every full batch, then flush the remainder
        (flush releases at most one padded batch per call)."""
        done: List[Completion] = []
        while True:
            out = self._batcher.poll()
            if out is None:
                break
            done.extend(self._serve_batch(*out))
        while True:
            out = self._batcher.flush()
            if out is None:
                break
            done.extend(self._serve_batch(*out))
        return done

    # -- batch execution --------------------------------------------------

    def _serve_batch(self, reqs: Sequence[Request],
                     n_real: int) -> List[Completion]:
        """Synchronous path: prepare, dispatch, and harvest back to back."""
        prep = self._prepare_batch(reqs, n_real, self.clock())
        return self._finish_batch(prep, self._dispatch_batch(prep))

    def _dispatch_batch(self, prep: _Prepared):
        """Launch the batch's executable. JAX dispatch is asynchronous:
        this returns device arrays immediately; only ``_finish_batch``
        blocks on them — the property the async pipeline overlaps on."""
        return prep.exe(*prep.args)

    def _degrade_level(self, real: Sequence[Request], flavor: str) -> int:
        """Fidelity-ladder rung for this batch: 0 unless the degrade
        policy is on, the batch has fidelity to trade (bandit flavor on a
        knob-aware reveal engine), and the tightest deadline's headroom
        ratio has fallen below the ladder thresholds."""
        cfg = self.cfg
        if (cfg.backpressure != "degrade" or flavor != "bandit"
                or cfg.bandit_engine == "vmapped"):
            return 0
        deadlines = [r.deadline_abs for r in real
                     if r.deadline_abs is not None]
        expected = self._admission_headroom()
        if not deadlines or expected <= 0:
            return 0
        ratio = (min(deadlines) - self.clock()) / expected
        return self._ladder.level_for(ratio)

    def _prepare_batch(self, reqs: Sequence[Request], n_real: int,
                       t_release: float) -> _Prepared:
        """Host-side batch assembly: bucket, pad, stage-1, route — no
        waiting on the main step executable."""
        cfg = self.cfg
        real = list(reqs[:n_real])
        tb = self.buckets.token_bucket(max(r.query.shape[0] for r in real))
        provided = [r.cand_ids for r in reqs]
        missing = [c is None for c in provided]
        if self._routed and all(missing):
            return self._prepare_batch_routed(reqs, real, n_real, tb,
                                              t_release)
        n_need = max([len(c) for c in provided if c is not None], default=0)
        if any(missing):
            n_need = max(n_need, self._stage1_n)
        nb = self.buckets.cand_bucket(max(n_need, 1))

        queries = pad_queries([r.query for r in reqs], tb)
        cand = pad_candidates(provided, nb)
        n_toks = [r.query.shape[0] for r in reqs]
        a, b = support_bounds(cand, n_toks, tb, cfg.support)

        if any(missing):
            ids1, a1, b1 = self._executable(("stage1", tb))(
                self.corpus_embs, self.corpus_mask, jnp.asarray(queries))
            ids1, a1, b1 = (np.asarray(ids1), np.asarray(a1), np.asarray(b1))
            for i, miss in enumerate(missing):
                if miss:
                    cand[i, :self._stage1_n] = ids1[i]
                    cand[i, self._stage1_n:] = -1
                    a[i, :self._stage1_n] = a1[i]
                    a[i, self._stage1_n:] = 0.0
                    b[i, :self._stage1_n] = b1[i]
                    b[i, self._stage1_n:] = 0.0

        flavor = self.flavor_for(nb)
        exe = self._executable(("step", flavor, tb, nb))
        seed = jnp.int32(next(self._batch_seed))
        level = self._degrade_level(real, flavor)
        a_s, r_c = self._ladder.knobs(level)
        knob_args = (jnp.float32(a_s), jnp.int32(r_c))
        if self.sharded is not None:
            sc = self.sharded
            hl = self.shard_health()
            cov = self._candidate_coverage(cand, real, hl, sc.docs_per_shard)
            # One placement computation for ids + payloads; the dense
            # flavor never reads the support bounds, so skip routing them
            # and ship zeros of the compiled shape.
            payloads = () if flavor == "dense" else (a, b)
            cand_l, routed = route_batch(cand, payloads, sc.docs_per_shard,
                                         sc.n_shards, n_local=nb)
            if flavor == "dense":
                zero = np.zeros((cand.shape[0], sc.n_shards, nb, tb),
                                np.float32)
                a_l, b_l = zero, zero
            else:
                a_l, b_l = routed
            args = (self.corpus_embs, self.corpus_mask, jnp.asarray(queries),
                    jnp.asarray(cand_l), jnp.asarray(a_l), jnp.asarray(b_l),
                    self._valid_docs, seed, jnp.asarray(hl)) + knob_args
        else:
            cov = None
            args = (self.corpus_embs, self.corpus_mask, jnp.asarray(queries),
                    jnp.asarray(cand), jnp.asarray(a), jnp.asarray(b),
                    seed) + knob_args
        return _Prepared(real, n_real, (tb, nb), flavor, exe, args,
                         t_release, next(self._bid), cov, level)

    @staticmethod
    def _candidate_coverage(cand: np.ndarray, real: Sequence[Request],
                            healthy: np.ndarray,
                            docs_per_shard: int) -> Optional[np.ndarray]:
        """Per-request fraction of its real candidates living on healthy
        shards — what the merge will actually search after the failover
        mask drops the dead shards. None (all 1.0) on a healthy mesh."""
        if healthy.all():
            return None
        cov = np.ones((len(real),), np.float32)
        for i in range(len(real)):
            ids = cand[i][cand[i] >= 0]
            if ids.size:
                cov[i] = float(np.mean(healthy[ids // docs_per_shard]))
        return cov

    def _prepare_batch_routed(self, reqs: Sequence[Request],
                              real: List[Request], n_real: int, tb: int,
                              t_release: float) -> _Prepared:
        """One-shard_map dispatch for candidate-less batches on a routed
        engine: no host stage-1, no routing tables — queries in,
        scorecards out."""
        nb = self._stage1_n
        flavor = self.flavor_for(nb)
        exe = self._executable(("routed", flavor, tb))
        queries = pad_queries([r.query for r in reqs], tb)
        seed = jnp.int32(next(self._batch_seed))
        cents, mass = self._router_args
        level = self._degrade_level(real, flavor)
        a_s, r_c = self._ladder.knobs(level)
        hl = self.shard_health()
        cov = None
        if not hl.all():
            # Candidates are chosen inside the shard_map — the searchable
            # universe is the healthy shards' document mass.
            vd = np.asarray(self.corpus.valid_docs, np.float64)
            cov = np.full((len(real),),
                          float(vd[hl].sum() / max(vd.sum(), 1.0)),
                          np.float32)
        args = (self.corpus_embs, self.corpus_mask, cents, mass,
                jnp.asarray(queries), self._valid_docs, seed,
                jnp.asarray(hl), jnp.float32(a_s), jnp.int32(r_c))
        return _Prepared(real, n_real, (tb, nb), flavor, exe, args,
                         t_release, next(self._bid), cov, level)

    def _finish_batch(self, prep: _Prepared, out) -> List[Completion]:
        """Completion harvest: the ONLY stage that blocks on the device."""
        cfg = self.cfg
        real, n_real = prep.real, prep.n_real
        bucket, flavor, t_release = prep.bucket, prep.flavor, prep.t_release
        scores, gids, frac, stats = jax.block_until_ready(out)
        scores, gids, frac, stats = (np.asarray(scores), np.asarray(gids),
                                     np.asarray(frac), np.asarray(stats))
        t_done = self.clock()

        shard_quota = None
        if stats.ndim == 2:        # sharded: per-shard diagnostic vectors
            shard_occ = tuple(float(x) for x in stats[:, 0])
            shard_rounds = tuple(float(x) for x in stats[:, 1])
            if stats.shape[1] >= 5:   # routed step: quota-share columns
                shard_quota = tuple(float(x) for x in stats[:, 3])
            # aggregate occupancy over the shards that did frontier work
            busy = stats[stats[:, 1] > 0]
            agg = (float(np.mean(busy[:, 0])) if len(busy)
                   else float(np.mean(stats[:, 0])),
                   float(np.sum(stats[:, 1])), float(np.sum(stats[:, 2])))
            quarantined = float(np.sum(stats[:, -1]))
        else:
            shard_occ = shard_rounds = None
            agg = (float(stats[0]), float(stats[1]), float(stats[2]))
            quarantined = float(stats[3])

        service_s = t_done - t_release
        with self._state_lock:
            self._service_ema = (service_s if not self.metrics.batches
                                 else 0.7 * self._service_ema
                                 + 0.3 * service_s)
        record = BatchRecord(
            bucket=bucket, flavor=flavor, n_real=n_real,
            occupancy=n_real / cfg.batch_size,
            service_s=service_s,
            reveal_fraction=float(np.mean(frac[:n_real])),
            frontier_occupancy=agg[0],
            total_rounds=agg[1],
            lockstep_waste=agg[2],
            shard_occupancy=shard_occ,
            shard_rounds=shard_rounds,
            shard_quota_share=shard_quota,
            quarantined=quarantined,
            degrade_level=prep.degrade_level)

        done: List[Completion] = []
        for i, r in enumerate(real):
            latency = t_done - r.arrival
            comp = Completion(
                rid=r.rid,
                topk_ids=gids[i, :r.k].copy(),
                topk_scores=scores[i, :r.k].copy(),
                queue_wait_s=t_release - r.arrival,
                latency_s=latency,
                # Serve-time stamping against the ABSOLUTE deadline captured
                # at admission: however the request reached this batch
                # (deadline release, full-batch release, drain, or a poll
                # that raced a fresh admission past a stale next_expiry()),
                # finishing after the deadline is a miss.
                deadline_miss=(r.deadline_abs is not None
                               and t_done > r.deadline_abs + 1e-9),
                flavor=flavor, bucket=bucket,
                reveal_fraction=float(frac[i]),
                coverage=(float(prep.coverage[i])
                          if prep.coverage is not None else 1.0)
                         * r.coverage_scale,
                degrade_level=prep.degrade_level)
            done.append(comp)
        self.metrics.record_batch(record, done)
        return done


# Dispatch-queue sentinel: the admit thread pushes it when it exits so the
# dispatch thread drains its in-flight batches and terminates.
_STOP = object()


# -- static thread-safety contract (repro.analysis.locks) --------------------
# The lockset linter roots one attribute-access set per thread type at these
# methods (closing over ``self.*`` method references) and fails any attribute
# shared by >= 2 thread types that is neither in GUARDED_BY nor consistently
# accessed under one ``with self.<lock>:``.
THREAD_ENTRY_POINTS = {
    "caller": ("submit", "poll", "drain", "stop", "start", "warmup",
               "future", "next_expiry", "autotune", "audit",
               "set_shard_health", "fail_shard", "restore_shard",
               "shard_health"),
    "admit": ("_admit_loop", "_guard"),
    "dispatch": ("_dispatch_loop", "_guard"),
    "stream": ("_stream_loop", "_guard"),
    "supervisor": ("_pre_restart", "_supervision_exhausted", "_spawn"),
}

# Attribute -> its guard. A lock name ("_done_cv", "_exec_lock", ...) is
# VERIFIED: every write outside __init__ must sit under ``with self.<lock>``.
# The mode strings document guards the linter cannot check lexically:
#   internal — the object locks itself (DeadlineBatcher, EngineMetrics);
#   atomic   — single CPython-atomic pointer swap, readers tolerate either
#              value (the supervisor handle);
#   ordered  — writes happen-before the reading thread starts (start()'s
#              thread bookkeeping, supervisor-callback state mutated only
#              while the watched thread is dead) or after it joins;
#   init     — written once before any serving thread exists (warmup flag).
GUARDED_BY = {
    "_futures": "_done_cv",
    "_submitted": "_done_cv",
    "_finished": "_done_cv",
    "_thread_exc": "_done_cv",
    "_completed": "_completed_lock",
    "_delivered_rids": "_completed_lock",
    "_disp_inflight": "_inflight_lock",
    "_inflight": "_inflight_lock",
    "_stream_q": "_work_cv",
    "_service_ema": "_state_lock",
    "_healthy": "_health_lock",
    "_exec": "_exec_lock",
    "_batcher": "internal",
    "_supervisor": "atomic",
    "_admit_holding": "ordered",
    "_harvested": "ordered",
    "_stream_slots": "ordered",
    "_targets": "ordered",
    "_thread_by_name": "ordered",
    "_threads": "ordered",
    "_started": "ordered",
    "_warmed": "init",
}


class AsyncRetrievalEngine(RetrievalEngine):
    """Async continuous-serving runtime over the same compiled buckets.

    Two dedicated threads split the synchronous engine's serve loop the way
    an offline-inference pipeline does:

    * the ADMIT thread drives the deadline batcher (sleeping toward
      ``next_expiry`` — which wakes immediately on a ready full batch) and
      runs host-side batch preparation (bucketing, padding, stage-1,
      routing);
    * the DISPATCH thread launches prepared batches on the device and,
      because JAX dispatch is asynchronous, immediately accepts the next
      one — batch i+1 dispatches while i executes. It calls
      ``jax.block_until_ready`` only at completion-harvest time, once the
      pipeline holds ``cfg.pipeline_depth`` batches (or goes idle).

    Admission backpressure (``cfg.backpressure``) rejects or degrades a
    deadline-carrying request at ``submit`` when the projected completion
    — queue backlog plus pipeline depth, costed at the live service-time
    EMA — already overruns its deadline.

    With ``cfg.continuous`` the batch pipeline is replaced by slot-level
    continuous batching: ONE resumable streaming executable
    (``retrieval.service.make_streaming_step``) holds a ``batch_size``-slot
    frontier; every device dispatch advances all live slots
    ``cfg.stream_trip_limit`` reveal rounds, and slots whose query retired
    are harvested and refilled from the admission queue mid-flight —
    the whole batch never drains to admit new work.

    Completions surface three ways: ``poll()`` (non-blocking pop of
    everything finished since the last poll), ``drain()`` (block until all
    submitted work completes), and per-request ``future(rid)``. The
    synchronous engine remains the parity oracle: an un-``start()``-ed
    async engine serves exactly like :class:`RetrievalEngine`.
    """

    def __init__(self, corpus_embs, corpus_mask,
                 config: Optional[EngineConfig] = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 poll_interval_s: float = 0.002,
                 fault_plan: Optional[FaultPlan] = None):
        super().__init__(corpus_embs, corpus_mask, config, clock=clock)
        if self.cfg.backpressure not in ("none", "reject", "degrade"):
            raise ValueError(f"unknown backpressure policy "
                             f"{self.cfg.backpressure!r}")
        if self.cfg.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        self._poll_interval = float(poll_interval_s)
        self._work_cv = threading.Condition()
        self._done_cv = threading.Condition()
        self._stop_evt = threading.Event()
        self._drain_evt = threading.Event()
        self._prep_q: queue_mod.Queue = queue_mod.Queue(
            maxsize=self.cfg.pipeline_depth)
        self._completed_lock = threading.Lock()
        self._completed: deque = deque()
        self._futures: Dict[int, Future] = {}
        self._submitted = 0
        self._finished = 0
        self._inflight = 0
        self._stream_q: deque = deque()
        self._threads: List[threading.Thread] = []
        self._thread_exc: Optional[BaseException] = None
        self._started = False
        # Fault-injection harness: an inert/None plan adds nothing to the
        # serving loops (the chaos hook returns before ticking).
        self._fault_plan = (fault_plan if fault_plan is not None
                            and not fault_plan.empty else None)
        # Supervised-restart state. Every piece of in-flight pipeline work
        # lives on the ENGINE so a restarted thread resumes it: the batch
        # the admit thread is offering to a full dispatch queue
        # (_admit_holding), the dispatched-batch deque (_disp_inflight),
        # and the continuous stream's occupied slots (_stream_slots).
        # Harvest idempotency comes from _harvested (batch bids finished)
        # plus rid-dedup at delivery (_delivered_rids) — together they
        # give the zero-lost / zero-duplicated completion guarantee.
        self._supervisor: Optional[Supervisor] = None
        self._targets: Dict[str, Callable[[], None]] = {}
        self._thread_by_name: Dict[str, threading.Thread] = {}
        self._inflight_lock = threading.Lock()
        self._disp_inflight: deque = deque()
        self._admit_holding: Optional[_Prepared] = None
        self._harvested: set = set()
        self._delivered_rids: set = set()
        self._stream_slots: List[Optional[Request]] = []

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "AsyncRetrievalEngine":
        """Spawn the serving threads (plus the supervision watchdog under
        ``cfg.supervise``). Idempotent while running."""
        if self._started:
            return self
        self._raise_if_failed()
        self._stop_evt.clear()
        if self.cfg.continuous:
            self._targets = {"repro-stream": self._stream_loop}
        else:
            self._targets = {"repro-admit": self._admit_loop,
                             "repro-dispatch": self._dispatch_loop}
        self._thread_by_name = {}
        self._started = True
        if self.cfg.supervise:
            self._supervisor = Supervisor(
                max_restarts=self.cfg.max_thread_restarts,
                interval_s=self.cfg.supervise_interval_s,
                stopping=self._stop_evt.is_set,
                on_exhausted=self._supervision_exhausted)
        for name in self._targets:
            t = self._spawn(name)
            if self._supervisor is not None:
                self._supervisor.watch(
                    name, t, factory=functools.partial(self._spawn, name),
                    on_restart=functools.partial(self._pre_restart, name))
        self._threads = list(self._thread_by_name.values())
        if self._supervisor is not None:
            self._supervisor.start()
        return self

    def _spawn(self, name: str) -> threading.Thread:
        """Build AND start one named serving thread — the initial spawn
        and the supervisor's restart factory."""
        t = threading.Thread(target=self._guard,
                             args=(self._targets[name], name), name=name,
                             daemon=True)
        self._thread_by_name[name] = t
        t.start()
        return t

    def _pre_restart(self, name: str) -> None:
        """Watchdog callback just before a dead thread is replaced."""
        self.metrics.record_restart(name)
        if name == "repro-stream":
            # The stream loop's frontier state died with its thread: the
            # occupied slots' bandit progress is unrecoverable, so fail
            # those requests LOUDLY (queued requests replay fine — the
            # fresh thread refills from the intact admission queue).
            self._fail_stream_slots(
                "continuous-stream thread restarted; in-flight slot lost")

    def _supervision_exhausted(self, name: str,
                               exc: Optional[BaseException]) -> None:
        """Restart budget spent: escalate to the unsupervised engine's
        loud thread-death failure."""
        with self._done_cv:
            self._thread_exc = exc if exc is not None else RuntimeError(
                f"{name} died with its restart budget exhausted")
            self._stop_evt.set()
            self._done_cv.notify_all()

    def stop(self) -> None:
        """Stop the serving threads, then FLUSH: every admitted request is
        completed (queued and in-flight batches are served synchronously)
        or — when serving is impossible, e.g. a dead thread — failed
        loudly with an ``error`` completion. Nothing is silently dropped
        and no future dangles after stop."""
        if not self._started:
            return
        self._stop_evt.set()
        if self._supervisor is not None:
            self._supervisor.stop()
            self._supervisor = None
        with self._work_cv:
            self._work_cv.notify_all()
        for t in list(self._thread_by_name.values()):
            t.join(timeout=60.0)
        self._started = False
        if self._thread_exc is None:
            self._shutdown_flush()
        self._fail_pending("engine stopped before serving this request")
        self._raise_if_failed()

    def __enter__(self) -> "AsyncRetrievalEngine":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    def _guard(self, fn, name: str = "") -> None:
        try:
            fn()
        except BaseException as e:
            if self._supervisor is not None and not self._stop_evt.is_set():
                # Supervised: die quietly — the watchdog restarts within
                # budget or escalates through _supervision_exhausted.
                self._supervisor.note_failure(name, e)
                return
            # Unsupervised (or stopping): propagate to drain()/stop().
            with self._done_cv:
                self._thread_exc = e
                self._stop_evt.set()
                self._done_cv.notify_all()

    def _raise_if_failed(self) -> None:
        with self._done_cv:
            exc, self._thread_exc = self._thread_exc, None
        if exc is not None:
            raise RuntimeError("serving thread died") from exc

    # -- fault injection ---------------------------------------------------

    def _chaos(self, point: str) -> None:
        """Tick the fault plan's chaos point (once per thread-loop
        iteration). Kills raise AFTER state flips apply, matching
        FaultPlan.tick's ordering."""
        plan = self._fault_plan
        if plan is None:
            return
        for f in plan.tick(point):
            if f.action == "kill":
                raise ChaosKill(f"injected kill at {point!r} "
                                f"tick {f.at}")
            if f.action == "shard_down":
                self.fail_shard(int(f.arg))
            elif f.action == "shard_up":
                self.restore_shard(int(f.arg))
            elif f.action == "delay":
                apply_delay(self.clock, float(f.arg))

    # -- admission --------------------------------------------------------

    def _backlog_batches(self) -> int:
        """Batches queued ahead of a request admitted right now."""
        B = self.cfg.batch_size
        if self.cfg.continuous:
            with self._work_cv:
                return (len(self._stream_q) + B - 1) // B
        queued = (len(self._batcher) + B - 1) // B
        with self._inflight_lock:
            inflight = self._inflight
        return queued + self._prep_q.qsize() + inflight

    def submit(self, request: Request) -> int:
        if self.cfg.continuous and not self._started:
            raise RuntimeError("continuous mode serves from the stream "
                               "thread; call start() before submit()")
        self._raise_if_failed()
        cfg = self.cfg
        if cfg.backpressure != "none" and request.deadline_s is not None:
            # Projected completion: every batch ahead of this request plus
            # its own, costed at the live expected batch service time.
            expected = self._admission_headroom()
            wait = (self._backlog_batches() + 1) * expected
            if wait > request.deadline_s:
                if cfg.backpressure == "reject":
                    self.metrics.record_rejected()
                    raise AdmissionRejected(
                        f"projected wait {wait * 1e3:.1f} ms exceeds "
                        f"deadline {request.deadline_s * 1e3:.1f} ms")
                min_nb = self.buckets.cand_buckets[0]
                if (request.cand_ids is not None
                        and len(request.cand_ids) > min_nb):
                    # First ladder rung: truncate to the cheapest compiled
                    # candidate bucket; the lost tail is a visible coverage
                    # deficit on the completion, not a silent downgrade.
                    request = dataclasses.replace(
                        request,
                        cand_ids=np.asarray(request.cand_ids)[:min_nb],
                        coverage_scale=(request.coverage_scale
                                        * min_nb / len(request.cand_ids)))
                    self.metrics.record_degraded()
        return super().submit(request)

    def _enqueue(self, admitted: Request) -> None:
        with self._done_cv:
            self._futures[admitted.rid] = Future()
            self._submitted += 1
        if self.cfg.continuous:
            with self._work_cv:
                self._stream_q.append(admitted)
                self._work_cv.notify_all()
        else:
            super()._enqueue(admitted)
            with self._work_cv:
                self._work_cv.notify_all()

    def future(self, rid: int) -> Optional[Future]:
        """The request's completion future (None for unknown rids)."""
        with self._done_cv:
            return self._futures.get(rid)

    # -- completion surfaces ----------------------------------------------

    def _resolve(self, comps: Sequence[Completion]) -> None:
        if not comps:
            return
        with self._done_cv:
            for c in comps:
                fut = self._futures.get(c.rid)
                if fut is not None and not fut.done():
                    fut.set_result(c)
                self._finished += 1
            self._done_cv.notify_all()

    def _deliver(self, comps: Sequence[Completion]) -> None:
        """Idempotent completion delivery: a rid is surfaced exactly once,
        however many times a supervised restart re-harvests its batch."""
        if not comps:
            return
        with self._completed_lock:
            fresh = [c for c in comps if c.rid not in self._delivered_rids]
            self._delivered_rids.update(c.rid for c in fresh)
        if not fresh:
            return
        self._resolve(fresh)
        with self._completed_lock:
            self._completed.extend(fresh)

    def poll(self) -> List[Completion]:
        """Un-started: serve synchronously (parity-oracle mode). Started:
        non-blocking pop of everything completed since the last poll.
        After stop() the completed backlog (including the shutdown flush's
        work) is still surfaced before falling back to the sync path."""
        if self._started:
            self._raise_if_failed()
        with self._completed_lock:
            out = list(self._completed)
            self._completed.clear()
        if not self._started:
            comps = super().poll()
            self._resolve(comps)
            out.extend(comps)
        return out

    def drain(self) -> List[Completion]:
        """Block until every submitted request has completed; returns the
        completions not yet surfaced through ``poll``."""
        if not self._started:
            comps = super().drain()
            self._resolve(comps)
            return comps
        self._drain_evt.set()
        with self._work_cv:
            self._work_cv.notify_all()
        try:
            with self._done_cv:
                while self._finished < self._submitted:
                    if self._thread_exc is not None or (
                            self._stop_evt.is_set()):
                        break
                    self._done_cv.wait(timeout=self._poll_interval * 5)
        finally:
            self._drain_evt.clear()
        self._raise_if_failed()
        with self._done_cv:
            if self._finished < self._submitted:
                raise RuntimeError("drain() interrupted by stop()")
        return self.poll()

    # -- batch-pipeline threads -------------------------------------------

    def _admit_loop(self) -> None:
        """Drive the deadline batcher; prepare released batches; feed the
        bounded dispatch queue (whose ``put`` blocking IS the pipeline's
        backpressure on admission work). A prepared batch is parked on
        ``_admit_holding`` until the queue accepts it, so a thread death
        mid-offer hands the batch to the restarted thread (or the stop
        flush) instead of dropping it."""
        while True:
            self._chaos("admit")
            prep = self._admit_holding
            if prep is None:
                out = self._batcher.poll()
                if out is None and self._drain_evt.is_set():
                    out = self._batcher.flush()
                if out is not None:
                    prep = self._prepare_batch(out[0], out[1], self.clock())
            if prep is not None:
                self._admit_holding = prep
                while True:
                    try:
                        self._prep_q.put(prep, timeout=0.1)
                        self._admit_holding = None
                        break
                    except queue_mod.Full:
                        if self._stop_evt.is_set():
                            # still holding: the stop flush serves it
                            self._put_stop()
                            return
                continue
            if self._stop_evt.is_set():
                self._put_stop()
                return
            with self._work_cv:
                exp = self._batcher.next_expiry()
                now = self.clock()
                tmo = (self._poll_interval if exp is None
                       else min(max(exp - now, 0.0), self._poll_interval))
                if tmo > 0:
                    self._work_cv.wait(timeout=tmo)

    def _put_stop(self) -> None:
        """Best-effort dispatch sentinel: never block on a full queue (the
        dispatcher may be dead — the legacy blocking put deadlocked the
        admit thread there). A dropped sentinel is safe: the dispatcher
        also exits on stop_evt once idle, and the stop flush serves
        whatever never got dispatched and discards stray sentinels."""
        try:
            self._prep_q.put_nowait(_STOP)
        except queue_mod.Full:
            pass

    def _harvest_head(self) -> bool:
        """Finish-and-deliver the OLDEST in-flight batch, exactly once.

        Peek-finish-pop (never pop-then-finish): the batch stays on the
        engine-owned deque until its completions are delivered, so a
        thread dying inside ``_finish_batch`` leaves it for the restarted
        thread. The ``bid`` guard skips a head whose predecessor died in
        the window between delivering and popping; rid-dedup in
        ``_deliver`` backstops the symmetric window."""
        with self._inflight_lock:
            if not self._disp_inflight:
                return False
            p, o = self._disp_inflight[0]
        if p.bid not in self._harvested:
            comps = self._finish_batch(p, o)
            self._harvested.add(p.bid)
            self._deliver(comps)
        with self._inflight_lock:
            if self._disp_inflight and self._disp_inflight[0][0].bid == p.bid:
                self._disp_inflight.popleft()
            self._inflight = len(self._disp_inflight)
        return True

    def _dispatch_loop(self) -> None:
        """Launch prepared batches; keep up to ``pipeline_depth`` in
        flight; block on device results only when the pipeline is full or
        idle — the JetStream-style dispatch/harvest split. In-flight
        batches live on ``self._disp_inflight`` (not the thread stack) so
        supervision restarts lose nothing."""
        depth = self.cfg.pipeline_depth
        while True:
            self._chaos("dispatch")
            try:
                prep = self._prep_q.get(timeout=self._poll_interval)
            except queue_mod.Empty:
                prep = None
            if prep is _STOP:
                while self._harvest_head():
                    pass
                return
            if prep is not None:
                with self._inflight_lock:
                    self._disp_inflight.append(
                        (prep, self._dispatch_batch(prep)))
                    self._inflight = len(self._disp_inflight)
                    full = len(self._disp_inflight) >= depth
                if full:
                    self._harvest_head()
            elif not self._harvest_head() and self._stop_evt.is_set():
                # Restarted after the _STOP sentinel was already consumed
                # (or a racing shutdown): nothing in flight, nothing
                # queued — the stop flush owns whatever is left.
                return

    # -- shutdown flush / loud failure ------------------------------------

    def _shutdown_flush(self) -> None:
        """Serve every batch the stopped pipeline left behind, on the
        caller's thread: dispatched-but-unharvested batches, the admit
        thread's parked offer, queued prepared batches, and the admission
        queue's remainder. After this only never-admitted rids can be
        pending (there are none on a healthy stop)."""
        while self._harvest_head():
            pass
        leftovers: List[_Prepared] = []
        if self._admit_holding is not None:
            leftovers.append(self._admit_holding)
            self._admit_holding = None
        while True:
            try:
                prep = self._prep_q.get_nowait()
            except queue_mod.Empty:
                break
            if prep is not _STOP:
                leftovers.append(prep)
        for prep in leftovers:
            if prep.bid in self._harvested:
                continue
            comps = self._finish_batch(prep, self._dispatch_batch(prep))
            self._harvested.add(prep.bid)
            self._deliver(comps)
        while True:
            out = self._batcher.poll() or self._batcher.flush()
            if out is None:
                break
            prep = self._prepare_batch(out[0], out[1], self.clock())
            self._deliver(self._finish_batch(
                prep, self._dispatch_batch(prep)))

    def _error_completion(self, rid: int, reason: str,
                          k: Optional[int] = None) -> Completion:
        k = self.cfg.max_k if k is None else k
        return Completion(
            rid=rid, topk_ids=np.full((k,), -1, np.int32),
            topk_scores=np.full((k,), -np.inf, np.float32),
            queue_wait_s=0.0, latency_s=0.0, deadline_miss=True,
            flavor="error", bucket=(0, 0), reveal_fraction=0.0,
            coverage=0.0, error=reason)

    def _fail_pending(self, reason: str) -> None:
        """Resolve every still-pending future with a LOUD error completion
        — the zero-lost guarantee's last line: after stop() no submitted
        rid is unaccounted for and no future dangles."""
        with self._done_cv:
            pending = sorted(rid for rid, f in self._futures.items()
                             if not f.done())
        if pending:
            self._deliver([self._error_completion(rid, reason)
                           for rid in pending])

    def _fail_stream_slots(self, reason: str) -> None:
        """Fail the continuous stream's occupied slots (their on-device
        frontier state died with the stream thread)."""
        slots = self._stream_slots
        comps = []
        for s, r in enumerate(slots):
            if r is not None:
                comps.append(self._error_completion(r.rid, reason, k=r.k))
                slots[s] = None
        self._deliver(comps)

    # -- continuous (slot-refill) thread ----------------------------------

    def _stream_loop(self) -> None:
        """Slot-level continuous batching: one resumable frontier of
        ``batch_size`` slots; retired slots are harvested and refilled
        from the admission queue between slices while the other slots'
        bandit state carries forward on the device."""
        cfg = self.cfg
        B = cfg.batch_size
        tb, nb = self._stream_bucket
        exe = self._executable(("stream", tb, nb))
        M = self.corpus_embs.shape[2]
        base_key = jax.random.key(cfg.seed)
        state = init_stream_state(B, nb, tb)
        keys = jax.random.split(base_key, B)
        slot: List[Optional[Request]] = [None] * B
        # Engine-visible alias: a supervised restart fails the occupied
        # slots loudly (their frontier state died with this thread).
        self._stream_slots = slot
        slot_fill = [0.0] * B
        queries = np.zeros((B, tb, M), np.float32)
        cand = np.full((B, nb), -1, np.int32)
        a_np = np.zeros((B, nb, tb), np.float32)
        b_np = np.zeros((B, nb, tb), np.float32)

        while True:
            self._chaos("stream")
            # 1. Refill retired slots from the admission queue.
            newly: List[int] = []
            for s in range(B):
                if slot[s] is not None:
                    continue
                with self._work_cv:
                    r = (self._stream_q.popleft() if self._stream_q
                         else None)
                if r is None:
                    break
                slot[s] = r
                slot_fill[s] = self.clock()
                newly.append(s)
            fresh = np.zeros((B,), bool)
            if newly:
                need = [s for s in newly if slot[s].cand_ids is None]
                if need:
                    q_pad = np.zeros((B, tb, M), np.float32)
                    for s in need:
                        q = slot[s].query
                        q_pad[s, :q.shape[0]] = q
                    ids1, a1, b1 = self._executable(("stage1", tb))(
                        self.corpus_embs, self.corpus_mask,
                        jnp.asarray(q_pad))
                    ids1, a1, b1 = (np.asarray(ids1), np.asarray(a1),
                                    np.asarray(b1))
                for s in newly:
                    r = slot[s]
                    queries[s] = 0.0
                    queries[s, :r.query.shape[0]] = r.query
                    if r.cand_ids is None:
                        cand[s] = -1
                        cand[s, :self._stage1_n] = ids1[s]
                        a_np[s] = 0.0
                        b_np[s] = 0.0
                        a_np[s, :self._stage1_n] = a1[s]
                        b_np[s, :self._stage1_n] = b1[s]
                    else:
                        row = pad_candidates([r.cand_ids], nb)
                        cand[s] = row[0]
                        aa, bb = support_bounds(row, [r.query.shape[0]],
                                                tb, cfg.support)
                        a_np[s], b_np[s] = aa[0], bb[0]
                    keys = keys.at[s].set(
                        jax.random.fold_in(base_key, r.rid))
                    fresh[s] = True

            live = [s for s in range(B) if slot[s] is not None]
            if not live:
                if self._stop_evt.is_set():
                    return
                with self._work_cv:
                    if not self._stream_q:
                        self._work_cv.wait(timeout=self._poll_interval)
                continue

            # 2. One slice: every live slot advances trip_limit rounds.
            t0 = self.clock()
            scores, gids, frac, stats, harvest, state = exe(
                self.corpus_embs, self.corpus_mask, jnp.asarray(queries),
                jnp.asarray(cand), jnp.asarray(a_np), jnp.asarray(b_np),
                state, jnp.asarray(fresh), keys)
            scores, gids, frac, stats, harvest = jax.block_until_ready(
                (scores, gids, frac, stats, harvest))
            t_done = self.clock()
            scores, gids, frac, stats, harvest = (
                np.asarray(scores), np.asarray(gids), np.asarray(frac),
                np.asarray(stats), np.asarray(harvest))

            # 3. Harvest retired slots.
            comps: List[Completion] = []
            for s in live:
                if not harvest[s]:
                    continue
                r = slot[s]
                comps.append(Completion(
                    rid=r.rid,
                    topk_ids=gids[s, :r.k].copy(),
                    topk_scores=scores[s, :r.k].copy(),
                    queue_wait_s=slot_fill[s] - r.arrival,
                    latency_s=t_done - r.arrival,
                    deadline_miss=(r.deadline_abs is not None
                                   and t_done > r.deadline_abs + 1e-9),
                    flavor="bandit", bucket=(tb, nb),
                    reveal_fraction=float(frac[s]),
                    coverage=r.coverage_scale))
                slot[s] = None
            service_s = t_done - t0
            with self._state_lock:
                self._service_ema = (
                    service_s if not self.metrics.batches
                    else 0.7 * self._service_ema + 0.3 * service_s)
            self.metrics.record_batch(BatchRecord(
                bucket=(tb, nb), flavor="bandit", n_real=len(live),
                occupancy=len(live) / B, service_s=service_s,
                reveal_fraction=float(np.mean(frac[live])),
                frontier_occupancy=float(stats[0]),
                total_rounds=float(stats[1]),
                lockstep_waste=float(stats[2]),
                quarantined=float(stats[3])), comps)
            self._deliver(comps)
