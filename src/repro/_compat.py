"""Version-compatibility shims, installed from ``repro.__init__``.

The codebase targets the modern ``jax.shard_map(..., check_vma=...)`` entry
point; older jax releases (such as the 0.4.x line pinned in this container)
only expose ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
Rather than sprinkling version checks through every call site (and the
tests, which call ``jax.shard_map`` directly), we install one adapter on the
``jax`` module the first time ``repro`` is imported.
"""
from __future__ import annotations

import jax


def install() -> None:
    """Idempotently install compatibility aliases on the jax module."""
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
            # modern kwarg name -> legacy one (same semantics: replication /
            # varying-mesh-axes checking of out_specs).
            if check_vma is not None and "check_rep" not in kw:
                kw["check_rep"] = bool(check_vma)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            # psum of a unit constant constant-folds to the bound axis size
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size
