"""SASRec [arXiv:1808.09781].

embed_dim=50, 2 self-attention blocks, 1 head, history seq_len=50.
"""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="sasrec",
    interaction="self-attn-seq",
    embed_dim=50,
    n_blocks=2,
    n_heads=1,
    seq_len=50,
    item_vocab=1_000_000,
)
