"""Mixtral 8x22B [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts top-2,
sliding-window attention (w=4096).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="mixtral-8x22b",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=32768,
    moe=True,
    n_experts=8,
    experts_top_k=2,
    moe_d_ff=16384,
    sliding_window=4096,
    rope_theta=1_000_000.0,
)
