"""Factorization Machine [Rendle, ICDM'10].

39 sparse fields, embed_dim=10, pairwise interactions via the O(nk)
sum-square trick.
"""
from repro.configs.base import RecsysConfig, criteo_like_vocab

CONFIG = RecsysConfig(
    name="fm",
    interaction="fm-2way",
    n_sparse=39,
    embed_dim=10,
    vocab_sizes=criteo_like_vocab(39),
)
