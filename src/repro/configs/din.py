"""DIN — Deep Interest Network [arXiv:1706.06978].

embed_dim=18, user-history seq_len=100, attention MLP 80-40, main MLP 200-80.
"""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="din",
    interaction="target-attn",
    embed_dim=18,
    seq_len=100,
    item_vocab=2_000_000,
    attn_mlp=(80, 40),
    mlp=(200, 80),
)
