"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

4 layers, d_hidden=75, aggregators mean/max/min/std, scalers id/amp/atten.
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="pna",
    n_layers=4,
    d_hidden=75,
    aggregators=("mean", "max", "min", "std"),
    scalers=("identity", "amplification", "attenuation"),
)
