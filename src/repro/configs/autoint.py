"""AutoInt [arXiv:1810.11921].

39 sparse fields, embed_dim=16, 3 self-attention interaction layers,
2 heads, d_attn=32.
"""
from repro.configs.base import RecsysConfig, criteo_like_vocab

CONFIG = RecsysConfig(
    name="autoint",
    interaction="self-attn",
    n_sparse=39,
    embed_dim=16,
    vocab_sizes=criteo_like_vocab(39),
    n_attn_layers=3,
    n_heads=2,
    d_attn=32,
)
