"""Architecture registry: ``--arch <id>`` -> config object.

Assigned pool (10 archs) + the paper's own retrieval configs.
"""
from __future__ import annotations

from repro.configs.base import (
    BanditConfig,
    GNN_SHAPES,
    GNNConfig,
    LM_SHAPES,
    LMConfig,
    RECSYS_SHAPES,
    RecsysConfig,
    RETRIEVAL_SHAPES,
    RetrievalConfig,
    ShapeSpec,
    criteo_like_vocab,
)
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from repro.configs.moonshot_v1_16b_a3b import CONFIG as MOONSHOT_V1_16B_A3B
from repro.configs.internlm2_20b import CONFIG as INTERNLM2_20B
from repro.configs.gemma2_27b import CONFIG as GEMMA2_27B
from repro.configs.qwen2_5_3b import CONFIG as QWEN2_5_3B
from repro.configs.pna import CONFIG as PNA
from repro.configs.autoint import CONFIG as AUTOINT
from repro.configs.sasrec import CONFIG as SASREC
from repro.configs.din import CONFIG as DIN
from repro.configs.fm import CONFIG as FM
from repro.configs.colbert_repro import TEXT_CONFIG as COLBERT_TEXT
from repro.configs.colbert_repro import MM_CONFIG as COLBERT_MM

REGISTRY = {
    "mixtral-8x22b": MIXTRAL_8X22B,
    "moonshot-v1-16b-a3b": MOONSHOT_V1_16B_A3B,
    "internlm2-20b": INTERNLM2_20B,
    "gemma2-27b": GEMMA2_27B,
    "qwen2.5-3b": QWEN2_5_3B,
    "pna": PNA,
    "autoint": AUTOINT,
    "sasrec": SASREC,
    "din": DIN,
    "fm": FM,
    # the paper's own workload
    "colbert-text": COLBERT_TEXT,
    "colbert-mm": COLBERT_MM,
}

ASSIGNED_ARCHS = [
    "mixtral-8x22b", "moonshot-v1-16b-a3b", "internlm2-20b", "gemma2-27b",
    "qwen2.5-3b", "pna", "autoint", "sasrec", "din", "fm",
]


def get_config(arch: str):
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def all_cells(archs=None):
    """Enumerate every (arch, shape) cell."""
    archs = archs or ASSIGNED_ARCHS
    for arch in archs:
        cfg = get_config(arch)
        for shape in cfg.shapes:
            yield arch, cfg, shape
