"""Config dataclasses for every architecture family and their input shapes.

Each assigned architecture gets one module in ``repro.configs`` exporting a
``CONFIG`` object; the registry in ``repro.configs.__init__`` maps ``--arch``
ids to them.  Shape sets are family-wide (LM / GNN / RecSys) and are carried
on the config so that ``launch/dryrun.py`` can enumerate every
(arch x shape) cell mechanically.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell.

    kind:
      - "train":    lowers train_step
      - "prefill":  lowers prefill_step (inference prefill)
      - "decode":   lowers serve_step (1 new token against a KV cache)
      - "serve":    lowers a forward scoring step (recsys / retrieval)
    """
    name: str
    kind: str
    # LM shapes
    seq_len: int = 0
    global_batch: int = 0
    # GNN shapes
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    graph_batch: int = 0
    # RecSys shapes
    batch: int = 0
    n_candidates: int = 0


LM_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec(name="train_4k", kind="train", seq_len=4096, global_batch=256),
    ShapeSpec(name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32),
    ShapeSpec(name="decode_32k", kind="decode", seq_len=32768, global_batch=128),
    ShapeSpec(name="long_500k", kind="decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec(name="full_graph_sm", kind="train", n_nodes=2708, n_edges=10556,
              d_feat=1433),
    ShapeSpec(name="minibatch_lg", kind="train", n_nodes=232965,
              n_edges=114615892, batch_nodes=1024, fanout=(15, 10), d_feat=602),
    ShapeSpec(name="ogb_products", kind="train", n_nodes=2449029,
              n_edges=61859140, d_feat=100),
    ShapeSpec(name="molecule", kind="train", n_nodes=30, n_edges=64,
              graph_batch=128, d_feat=16),
)

RECSYS_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec(name="train_batch", kind="train", batch=65536),
    ShapeSpec(name="serve_p99", kind="serve", batch=512),
    ShapeSpec(name="serve_bulk", kind="serve", batch=262144),
    ShapeSpec(name="retrieval_cand", kind="serve", batch=1, n_candidates=1_000_000),
)

# Paper-native retrieval shapes: batched late-interaction reranking.
RETRIEVAL_SHAPES: Tuple[ShapeSpec, ...] = (
    # queries per step x candidate docs per query
    ShapeSpec(name="rerank_online", kind="serve", batch=256, n_candidates=256),
    ShapeSpec(name="rerank_bulk", kind="serve", batch=4096, n_candidates=512),
)


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # MoE
    moe: bool = False
    n_experts: int = 0
    experts_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # attention flavor
    sliding_window: Optional[int] = None           # SWA on every layer
    local_global_alternating: bool = False         # gemma2: even layers local
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"
    attn_q_chunk: int = 0     # >0: memory-efficient chunked attention
    family: str = "lm"
    shapes: Tuple[ShapeSpec, ...] = LM_SHAPES
    # late-interaction head (paper integration): project d_model -> li_dim
    li_dim: int = 128

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, ff = self.d_model, self.d_ff
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        if self.moe:
            e_ff = self.moe_d_ff or ff
            mlp = self.n_experts * 3 * d * e_ff + d * self.n_experts
        else:
            mlp = 3 * d * ff
        norms = 2 * d
        block = attn + mlp + norms
        emb = self.vocab * d
        head = self.vocab * d
        return emb + self.n_layers * block + norms + head

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d, e_ff = self.d_model, (self.moe_d_ff or self.d_ff)
        full = self.param_count()
        all_experts = self.n_experts * 3 * d * e_ff
        active = self.experts_top_k * 3 * d * e_ff
        return full - self.n_layers * (all_experts - active)


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    aggregators: Tuple[str, ...] = ("mean", "max", "min", "std")
    scalers: Tuple[str, ...] = ("identity", "amplification", "attenuation")
    n_classes: int = 47
    towers: int = 1
    family: str = "gnn"
    shapes: Tuple[ShapeSpec, ...] = GNN_SHAPES


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    interaction: str                     # "fm-2way" | "self-attn" | "self-attn-seq" | "target-attn"
    embed_dim: int
    n_sparse: int = 0
    vocab_sizes: Tuple[int, ...] = ()    # per-field table rows
    # AutoInt
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    # SASRec
    n_blocks: int = 0
    seq_len: int = 0
    item_vocab: int = 0
    # DIN
    attn_mlp: Tuple[int, ...] = ()
    mlp: Tuple[int, ...] = ()
    family: str = "recsys"
    shapes: Tuple[ShapeSpec, ...] = RECSYS_SHAPES


@dataclass(frozen=True)
class RetrievalConfig:
    """The paper's own workload: late-interaction reranking."""
    name: str
    query_tokens: int                    # T
    doc_tokens: int                      # L (padded)
    dim: int                             # M
    corpus_docs: int                     # sharded corpus size (serving)
    ann_kprime: int = 10
    family: str = "retrieval"
    shapes: Tuple[ShapeSpec, ...] = RETRIEVAL_SHAPES


@dataclass(frozen=True)
class BanditConfig:
    """Col-Bandit hyper-parameters (paper Sec. 4)."""
    k: int = 5
    delta: float = 0.01
    alpha_ef: float = 0.3
    epsilon: float = 0.1
    radius_c: float = 1.0
    bias_kappa: float = 0.25  # O(1/n) EBS range term; 0 = paper's exact Eq.12
                              # (beyond-paper robustness: guards against
                              # sigma-underestimation at small n)
    support: Tuple[float, float] = (0.0, 1.0)
    warmup_fraction: float = 0.0     # static warm-up variant; 0 => one cell/doc
    max_reveals: int = -1            # -1 => N*T
    # batched (TPU) variant
    block_docs: int = 8              # B docs refined per round
    block_tokens: int = 8            # G tokens revealed per selected doc


def criteo_like_vocab(n_fields: int, seed: int = 0) -> Tuple[int, ...]:
    """Deterministic, criteo-shaped table sizes: a few huge, many small."""
    sizes = []
    for i in range(n_fields):
        if i % 13 == 0:
            sizes.append(10_000_000)
        elif i % 5 == 0:
            sizes.append(1_000_000)
        elif i % 3 == 0:
            sizes.append(100_000)
        else:
            sizes.append(10_000 + 997 * i)
    return tuple(sizes)
