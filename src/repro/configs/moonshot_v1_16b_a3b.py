"""Moonshot/Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=163840, MoE 64e top-6.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=163840,
    moe=True,
    n_experts=64,
    experts_top_k=6,
    moe_d_ff=1408,
    rope_theta=50_000.0,
)
