"""The paper's own workloads (Sec. 5 / App. A.2).

Text: ColBERTv2 / Jina-ColBERT-v2 — d=128, fixed T=32 query tokens.
Multimodal: Granite Vision Embedding — d=128, 729 doc tokens per image.
"""
from repro.configs.base import RetrievalConfig

TEXT_CONFIG = RetrievalConfig(
    name="colbert-text",
    query_tokens=32,
    doc_tokens=128,
    dim=128,
    corpus_docs=5_230_000,   # HotPotQA-scale
    ann_kprime=10,
)

MM_CONFIG = RetrievalConfig(
    name="colbert-mm",
    query_tokens=64,
    doc_tokens=729,
    dim=128,
    corpus_docs=2_600_000,
    ann_kprime=10,
)
