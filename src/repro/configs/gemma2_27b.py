"""Gemma2-27B [arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, local+global
alternating attention (w=4096 on local layers), logit softcaps.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="gemma2-27b",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=36864,
    vocab=256000,
    sliding_window=4096,
    local_global_alternating=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
)
