"""Compressed resident-corpus formats: int8 rows and centroid residuals.

The corpus-per-device ceiling is set by resident bytes, and ColBERTv2-style
compression (PAPERS.md) shows late-interaction embeddings survive centroid
id + low-bit residual with negligible quality loss.  This module defines the
quantized corpus container and the host-side encoders; the kernels under
``repro.kernels`` dequantize blocks *in VMEM* right before the f32 MaxSim
accumulation, so the reconstructed rows never touch HBM (the FLASH-MAXSIM
IO argument, extended one step down the memory hierarchy).

Formats (``CORPUS_FORMATS``):

  * ``bf16``     — uncompressed passthrough: the corpus stays a plain array
                   at its source residency (bf16 in, bf16 resident; f32 in,
                   f32 resident — the pre-compression behavior, bit-exact).
  * ``int8``     — per-(doc, token)-row symmetric quantization: for each
                   length-M row, scale = absmax/127 (stored bf16), payload
                   int8.  ~M + 2 bytes/row vs 4M uncompressed.
  * ``residual`` — centroid id + int8 residual: each row is assigned its
                   nearest codebook centroid (the stage-1 router's spherical
                   k-means centroids double as the codebook) and only the
                   residual is int8-quantized.  Decoded row =
                   codebook[code] + data * scale.

``QuantTokens`` is a NamedTuple, hence automatically a jax pytree: it flows
through ``jit`` / ``vmap`` / ``shard_map`` wherever a plain corpus array
did, and ``.shape`` / ``.dtype`` / ``.ndim`` delegate to the int8 payload so
shape-derived call sites (``corpus.shape[2]`` etc.) keep working unchanged.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

CORPUS_FORMATS = ("bf16", "int8", "residual")

# int8 symmetric range. 127 (not 128) keeps the code range symmetric so
# dequantization has no bias term.
_QMAX = 127.0


class QuantTokens(NamedTuple):
    """A quantized token-embedding tensor with payload shape (..., L, M).

    data:     int8 (..., L, M) quantized rows (or residuals)
    scales:   (..., L) per-row dequant scale, bf16-resident
    codes:    (..., L) i32 centroid id per row — residual format only
    codebook: (Kc, M) f32 shared codebook — residual format only, replicated
              (never sharded or reshaped with the doc axis)
    """
    data: Any
    scales: Any
    codes: Any = None
    codebook: Any = None

    @property
    def shape(self):
        return self.data.shape

    @property
    def ndim(self):
        return self.data.ndim

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def fmt(self) -> str:
        return "residual" if self.codes is not None else "int8"


def corpus_format(x) -> str:
    """Format tag of a corpus operand (plain array -> 'bf16')."""
    return x.fmt if isinstance(x, QuantTokens) else "bf16"


def format_ordinal(fmt: str) -> int:
    """Power-of-two ordinal used to key tuning buckets per format."""
    if fmt not in CORPUS_FORMATS:
        raise ValueError(f"unknown corpus format {fmt!r}; "
                         f"expected one of {CORPUS_FORMATS}")
    return 1 << CORPUS_FORMATS.index(fmt)


def corpus_nbytes(x) -> int:
    """Resident bytes of a corpus operand, counting every quantization
    sidecar (scales, codes, codebook) — the honest bytes/doc numerator."""
    if isinstance(x, QuantTokens):
        leaves = [x.data, x.scales, x.codes, x.codebook]
        return sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
                   for a in leaves if a is not None)
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize


# ---------------------------------------------------------------------------
# host-side encoders (numpy: corpus build happens before device placement)
# ---------------------------------------------------------------------------

def _encode_rows(x: np.ndarray, scale_dtype) -> "tuple[np.ndarray, np.ndarray]":
    """Symmetric per-row int8 encode of (..., M) rows -> (int8, scales)."""
    absmax = np.max(np.abs(x), axis=-1)
    scale = (absmax / _QMAX).astype(np.float32)
    # bf16 scale residency: round the scale FIRST, then quantize against the
    # rounded value — the pair (data, scale) is self-consistent, so the
    # round-trip error stays bounded by scale/2 per element.
    scale = np.asarray(jnp.asarray(scale).astype(scale_dtype))
    s32 = scale.astype(np.float32)
    safe = np.where(s32 > 0, s32, 1.0)
    data = np.clip(np.rint(x / safe[..., None]), -_QMAX, _QMAX).astype(np.int8)
    return data, scale


def quantize_int8(embs, scale_dtype=jnp.bfloat16) -> QuantTokens:
    """Per-(doc, token)-row symmetric int8 quantization (host-side).

    All-zero rows get scale 0 and decode to exact zeros; rows with absmax
    anywhere up to f32 max are safe (scale = absmax/127 never overflows).
    """
    x = np.asarray(embs, dtype=np.float32)
    data, scale = _encode_rows(x, scale_dtype)
    return QuantTokens(data=data, scales=scale)


def quantize_residual(embs, codebook, scale_dtype=jnp.bfloat16) -> QuantTokens:
    """Centroid id + int8 residual against a shared (Kc, M) codebook.

    The codebook is the stage-1 router's spherical-k-means centroids
    (unit rows); assignment is by max inner product, matching the router's
    affinity metric.
    """
    x = np.asarray(embs, dtype=np.float32)
    cb = np.asarray(codebook, dtype=np.float32)
    if cb.ndim != 2 or cb.shape[0] < 1 or cb.shape[1] != x.shape[-1]:
        raise ValueError(f"codebook must be (Kc, M={x.shape[-1]}); "
                         f"got {cb.shape}")
    codes = np.argmax(x @ cb.T, axis=-1).astype(np.int32)
    resid = x - cb[codes]
    data, scale = _encode_rows(resid, scale_dtype)
    return QuantTokens(data=data, scales=scale, codes=codes, codebook=cb)


def quantize(embs, fmt: str, codebook=None,
             scale_dtype=jnp.bfloat16):
    """Encode ``embs`` into ``fmt`` ('bf16' passes through unchanged)."""
    if fmt == "bf16":
        return embs
    if fmt == "int8":
        return quantize_int8(embs, scale_dtype=scale_dtype)
    if fmt == "residual":
        if codebook is None:
            raise ValueError("residual format needs a (Kc, M) codebook "
                             "(the stage-1 router centroids)")
        return quantize_residual(embs, codebook, scale_dtype=scale_dtype)
    raise ValueError(f"unknown corpus format {fmt!r}; "
                     f"expected one of {CORPUS_FORMATS}")


# ---------------------------------------------------------------------------
# dequantization — the same math the kernels run per VMEM block
# ---------------------------------------------------------------------------

def dequant_block(data, scales, codes=None, codebook=None):
    """Reconstruct f32 rows from quantized operands; jnp-only so it runs
    unchanged inside a Pallas kernel body (on a VMEM block) and in the
    reference oracles (on whole arrays).

    The codebook gather is expressed as a one-hot matmul, which lowers to
    an MXU dot on TPU instead of a serialized VMEM gather (Kc is small —
    the codebook tile is resident anyway).
    """
    out = data.astype(jnp.float32) * scales.astype(jnp.float32)[..., None]
    if codes is not None:
        kc = codebook.shape[0]
        one_hot = (codes[..., None] == jnp.arange(kc, dtype=codes.dtype)
                   ).astype(jnp.float32)
        cents = jax.lax.dot_general(
            one_hot, codebook.astype(jnp.float32),
            (((one_hot.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        out = out + cents
    return out


def dequantize(qt: QuantTokens):
    """Full-array f32 reconstruction (oracle / ref-impl path)."""
    return dequant_block(qt.data, qt.scales, qt.codes, qt.codebook)


# ---------------------------------------------------------------------------
# structural helpers: treat (array | QuantTokens) uniformly at call sites
# ---------------------------------------------------------------------------

def corpus_take(x, idx, axis: int = 0):
    """``jnp.take`` over the doc axis of a corpus operand. The codebook is
    shared state, never gathered."""
    if isinstance(x, QuantTokens):
        return QuantTokens(
            data=jnp.take(x.data, idx, axis=axis),
            scales=jnp.take(x.scales, idx, axis=axis),
            codes=None if x.codes is None else jnp.take(x.codes, idx,
                                                        axis=axis),
            codebook=x.codebook)
    return jnp.take(x, idx, axis=axis)


def corpus_reshape(x, *lead: int):
    """Reshape the leading (doc/batch) axes to ``lead``, keeping each
    leaf's trailing dims: data (..., L, M), scales/codes (..., L)."""
    if isinstance(x, QuantTokens):
        l_dim, m_dim = x.data.shape[-2:]
        return QuantTokens(
            data=x.data.reshape(*lead, l_dim, m_dim),
            scales=x.scales.reshape(*lead, l_dim),
            codes=None if x.codes is None else x.codes.reshape(*lead, l_dim),
            codebook=x.codebook)
    return x.reshape(*lead, *x.shape[-2:])


def corpus_index(x, idx):
    """``x[idx]`` over the leading axis (codebook untouched)."""
    if isinstance(x, QuantTokens):
        return QuantTokens(
            data=x.data[idx], scales=x.scales[idx],
            codes=None if x.codes is None else x.codes[idx],
            codebook=x.codebook)
    return x[idx]


def _pad_axis(a, axis: int, mult: int, value=0):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths, constant_values=value)


def corpus_pad_to(x, axis: int, mult: int, value=0):
    """Pad one axis of a corpus operand to a multiple of ``mult``.  Axis
    indices refer to the payload layout (..., L, M); the M axis only exists
    on the payload, every other axis is shared with scales/codes.  Pad rows
    get scale 0 / code 0, decoding to exact zeros (int8) or centroid 0
    (residual) — both are neutralized by the all-False pad token mask the
    callers maintain, same as zero pad rows on the dense path."""
    if not isinstance(x, QuantTokens):
        return _pad_axis(x, axis, mult, value)
    nd = x.data.ndim
    axis = axis % nd
    data = _pad_axis(x.data, axis, mult, value)
    if axis == nd - 1:                      # M axis: payload-only
        return x._replace(data=data)
    return QuantTokens(
        data=data,
        scales=_pad_axis(x.scales, axis, mult, 0),
        codes=None if x.codes is None else _pad_axis(x.codes, axis, mult, 0),
        codebook=x.codebook)


def corpus_asarray(x, as_numpy: bool = False):
    """np/jnp-ify every leaf (codebook included), preserving structure."""
    conv = np.asarray if as_numpy else jnp.asarray
    if isinstance(x, QuantTokens):
        return QuantTokens(
            data=conv(x.data), scales=conv(x.scales),
            codes=None if x.codes is None else conv(x.codes),
            codebook=None if x.codebook is None else conv(x.codebook))
    return conv(x)


def corpus_leaves(x) -> Sequence[Any]:
    """Non-None leaves of a corpus operand (plain array -> [array])."""
    if isinstance(x, QuantTokens):
        return [a for a in (x.data, x.scales, x.codes, x.codebook)
                if a is not None]
    return [x]
