"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (per-kernel
shape/dtype sweeps in tests/test_kernels_*.py) and the fallback path used on
platforms without Pallas support.

Every oracle that consumes document embeddings also accepts a quantized
corpus (``quant.QuantTokens``): rows are reconstructed with the same
``dequant_block`` math the Pallas kernels run per VMEM block, then the
existing f32 oracle math applies unchanged.  ``maxsim_batch_ref`` — the
REPRO_KERNEL_IMPL=ref *serving* path — dequantizes per L-chunk inside its
streaming loop so the peak temporary stays (B, N, block_l, T)-sized and the
full f32 corpus is never materialized, mirroring the kernels' VMEM
discipline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant import QuantTokens, corpus_index, corpus_pad_to, \
    dequant_block, dequantize

_NEG = jnp.float32(-3e38)


def _dense_rows(doc_embs) -> jax.Array:
    """Oracle-side reconstruction: f32 rows from either format."""
    if isinstance(doc_embs, QuantTokens):
        return dequantize(doc_embs)
    return doc_embs.astype(jnp.float32)


def maxsim_ref(doc_embs: jax.Array, doc_tok_mask: jax.Array,
               queries: jax.Array) -> jax.Array:
    """Dense MaxSim matrix (Eq. 4).

    doc_embs:     (N, L, M)  document token embeddings (padded)
    doc_tok_mask: (N, L)     True for real tokens
    queries:      (T, M)     query token embeddings
    returns H:    (N, T) f32 — H[i, t] = max_j <e_ij, q_t> over valid j
    """
    sims = jnp.einsum("nlm,tm->nlt", _dense_rows(doc_embs),
                      queries.astype(jnp.float32))
    sims = jnp.where(doc_tok_mask[:, :, None], sims, _NEG)
    return jnp.max(sims, axis=1)


def maxsim_scores_ref(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                      queries: jax.Array) -> jax.Array:
    """Full late-interaction scores (Eq. 2): S_i = sum_t H[i, t]."""
    return jnp.sum(maxsim_ref(doc_embs, doc_tok_mask, queries), axis=-1)


def masked_maxsim_ref(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                      queries: jax.Array, tile_mask: jax.Array,
                      block_n: int, block_t: int) -> jax.Array:
    """Tile-masked MaxSim: H computed only where the (doc-block, tok-block)
    tile is active; inactive tiles are exactly 0.

    tile_mask: (N // block_n, T // block_t) bool.
    """
    h = maxsim_ref(doc_embs, doc_tok_mask, queries)
    full = jnp.repeat(jnp.repeat(tile_mask, block_n, axis=0), block_t, axis=1)
    # tile_mask covers the padded grid; truncate to the real (N, T) so
    # unaligned shapes broadcast (latent bug caught by the ref CI lane).
    return jnp.where(full[:h.shape[0], :h.shape[1]], h, 0.0)


def maxsim_batch_ref(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, *, block_l: int = 64) -> jax.Array:
    """Per-query-batched MaxSim, streamed over document tokens.

    doc_embs (B, N, L, M), doc_tok_mask (B, N, L), queries (B, T, M)
    -> H (B, N, T) with H[b, i, t] = max_j <e_bij, q_bt> over valid j.

    Deliberately NOT ``vmap(maxsim_ref)``: that would materialize the full
    (B, N, L, T) similarity tensor, the exact intermediate the serving path
    exists to avoid. Instead the L axis is walked in ``block_l`` chunks with
    a running max, so the peak temporary is (B, N, block_l, T) — the jnp
    mirror of the Pallas kernel's VMEM tiling, and the escape-hatch path the
    REPRO_KERNEL_IMPL=ref serving step compiles to.
    """
    Bq, N, L, M = doc_embs.shape
    T = queries.shape[1]
    quantized = isinstance(doc_embs, QuantTokens)
    e = doc_embs if quantized else doc_embs.astype(jnp.float32)
    q = queries.astype(jnp.float32)
    bl = min(block_l, max(L, 1))
    pad = (-L) % bl
    if pad:
        e = corpus_pad_to(e, 2, bl)
        m = jnp.pad(doc_tok_mask, ((0, 0), (0, 0), (0, pad)))
    else:
        m = doc_tok_mask
    n_blocks = e.shape[2] // bl

    def step(l, h):
        if quantized:
            # dequantize ONE chunk: the peak f32 temporary stays
            # (B, N, block_l, ·) even on a quantized corpus
            d_c = jax.lax.dynamic_slice_in_dim(e.data, l * bl, bl, axis=2)
            s_c = jax.lax.dynamic_slice_in_dim(e.scales, l * bl, bl, axis=2)
            c_c = (None if e.codes is None else
                   jax.lax.dynamic_slice_in_dim(e.codes, l * bl, bl, axis=2))
            e_c = dequant_block(d_c, s_c, c_c, e.codebook)
        else:
            e_c = jax.lax.dynamic_slice_in_dim(e, l * bl, bl, axis=2)
        m_c = jax.lax.dynamic_slice_in_dim(m, l * bl, bl, axis=2)
        sims = jnp.einsum("bnlm,btm->bnlt", e_c, q)
        sims = jnp.where(m_c[:, :, :, None], sims, _NEG)
        return jnp.maximum(h, jnp.max(sims, axis=2))

    h0 = jnp.full((Bq, N, T), _NEG, jnp.float32)
    return jax.lax.fori_loop(0, n_blocks, step, h0)


def gather_maxsim_ref(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                      queries: jax.Array, doc_idx: jax.Array,
                      tok_idx: jax.Array) -> jax.Array:
    """Gathered MaxSim for the block-synchronous bandit: compute
    H[doc_idx[b], tok_idx[b, g]] for the selected (doc, token) cells only.

    doc_idx: (B,) int32; tok_idx: (B, G) int32 -> out (B, G) f32.
    """
    e = _dense_rows(corpus_index(doc_embs, doc_idx))     # (B, L, M)
    m = doc_tok_mask[doc_idx]                            # (B, L)
    q = queries[tok_idx].astype(jnp.float32)             # (B, G, M)
    sims = jnp.einsum("blm,bgm->blg", e, q)
    sims = jnp.where(m[:, :, None], sims, _NEG)
    return jnp.max(sims, axis=1)


def fused_reveal_ref(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, doc_idx: jax.Array,
                     tok_idx: jax.Array, new_mask: jax.Array):
    """Fused reveal-round oracle: gathered MaxSim values for the selected
    cells PLUS the per-row sufficient-statistic deltas over the fresh cells.

    doc_idx (F,), tok_idx (F, G), new_mask (F, G) ->
      vals (F, G) f32, stats (F, 3) f32 = [d_count, d_total, d_total_sq].

    ``stats`` sums only cells where ``new_mask`` is True — the statistics
    contract of ``core.batched._apply_block_reveal`` (already-revealed and
    padded cells contribute exactly 0).
    """
    vals = gather_maxsim_ref(doc_embs, doc_tok_mask, queries, doc_idx,
                             tok_idx)
    nf = new_mask.astype(jnp.float32)
    vm = jnp.where(new_mask, vals, 0.0)
    stats = jnp.stack([jnp.sum(nf, axis=-1), jnp.sum(vm, axis=-1),
                       jnp.sum(vm * vals, axis=-1)], axis=-1)
    return vals, stats


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_mask: jax.Array, scale: float,
                         softcap: float | None = None) -> jax.Array:
    """Single-step decode attention oracle.

    q: (B, H, D); k, v: (B, S, H, D); kv_mask: (B, S) -> out (B, H, D).
    """
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(kv_mask[:, None, :], logits, _NEG)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32))
