"""Fused reveal-round Pallas kernel — one launch per pooled bandit round.

The pooled frontier engine (``repro.core.frontier``) used to lower each
round through a CHAIN of XLA ops: gather the selected doc embeddings into
an (F, L, M) HBM buffer, launch ``gather_maxsim`` over it, then scatter the
(F, G) values back into the stacked statistics (values / revealed /
n / total / total_sq — five separate scatters). Every link in that chain is
an HBM round-trip, which is exactly what FLASH-MAXSIM-style IO analysis
says the late-interaction hot loop cannot afford.

This kernel fuses the gather -> score -> accumulate middle of the round:

  * the frontier's compacted doc selections (``doc_idx``) are SCALAR
    PREFETCHED, so each grid step DMAs the selected document's embedding
    tile straight from the corpus-resident (D, L, M) tensor into VMEM —
    the (F, L, M) gathered intermediate is never materialized in HBM;
  * MaxSim over the document axis runs with a VMEM-resident running max
    (L tiled through the innermost grid dimension);
  * the per-candidate sufficient statistics that ``core.bounds`` consumes
    are accumulated IN the kernel: for every frontier row the output
    carries [reveal-count delta, revealed-sum delta, revealed-sum-of-
    squares delta] over the freshly revealed cells (``new_mask``), so the
    caller's state update shrinks to one scatter-min (cell values) plus
    one 3-column scatter-add.

Grid: (F // block_b, L // block_l), L innermost. ``gather=True`` requires
``block_b == 1`` (one frontier row per step — the index map can only
redirect a whole block); ``gather=False`` takes pre-gathered (F, L, M)
rows and allows wider row blocks, which is the cheaper layout for the
interpret-mode CI lane (trace time scales with grid size, and CPU has no
HBM/VMEM distinction to exploit).

Stats live in the first ``STATS_USED`` lanes of a ``STATS_W``-wide output
row (lane-padded so the store stays tile-aligned on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant import QuantTokens, corpus_take, dequant_block

_NEG = -3e38  # python float: jnp constants would be captured as kernel consts

STATS_W = 8        # lane-padded stats row width
STATS_USED = 3     # [d_count, d_total, d_total_sq]


def _fused_reveal_kernel(doc_idx_ref, e_ref, m_ref, q_ref, new_ref,
                         vals_ref, stats_ref, acc_ref, *, n_l_blocks):
    del doc_idx_ref  # consumed by the index maps, not the body
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, _NEG)

    e = e_ref[...].astype(jnp.float32)     # (BB, BL, M)
    q = q_ref[...].astype(jnp.float32)     # (BB, G, M)
    mask = m_ref[...]                      # (BB, BL)
    # batched (BB): (BL, M) . (G, M) -> (BL, G)
    sims = jax.lax.dot_general(
        e, q, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    sims = jnp.where(mask[:, :, None], sims, _NEG)
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(sims, axis=1))

    @pl.when(l == n_l_blocks - 1)
    def _done():
        v = acc_ref[...]                   # (BB, G)
        vals_ref[...] = v
        new = new_ref[...]                 # (BB, G) bool — fresh cells only
        nf = new.astype(jnp.float32)
        vm = jnp.where(new, v, 0.0)
        d_n = jnp.sum(nf, axis=-1)         # (BB,)
        d_tot = jnp.sum(vm, axis=-1)
        # vm * v (not nf * v * v): a 0 * inf from an all-masked document's
        # _NEG sentinel squaring out of f32 range would poison the row
        # with NaN; where-masking first keeps dead lanes exactly 0.
        d_sq = jnp.sum(vm * v, axis=-1)
        lane = jax.lax.broadcasted_iota(jnp.int32, (v.shape[0], STATS_W), 1)
        stats_ref[...] = jnp.where(
            lane == 0, d_n[:, None],
            jnp.where(lane == 1, d_tot[:, None],
                      jnp.where(lane == 2, d_sq[:, None], 0.0)))


def _fused_reveal_q_kernel(doc_idx_ref, *refs, n_l_blocks, residual):
    """Quantized-corpus fused reveal: the scalar-prefetched index maps DMA
    the selected doc's int8 payload block (plus scale / centroid-id rows)
    straight from the compressed resident corpus — HBM only ever moves
    compressed bytes, and the f32 row exists solely in VMEM between the
    dequant and the dot."""
    del doc_idx_ref  # consumed by the index maps, not the body
    if residual:
        (e_ref, s_ref, c_ref, cb_ref, m_ref, q_ref, new_ref, vals_ref,
         stats_ref, acc_ref) = refs
    else:
        e_ref, s_ref, m_ref, q_ref, new_ref, vals_ref, stats_ref, \
            acc_ref = refs
        c_ref = cb_ref = None
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, _NEG)

    e = dequant_block(e_ref[...], s_ref[...],
                      None if c_ref is None else c_ref[...],
                      None if cb_ref is None else cb_ref[...])
    q = q_ref[...].astype(jnp.float32)     # (BB, G, M)
    mask = m_ref[...]                      # (BB, BL)
    sims = jax.lax.dot_general(
        e, q, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    sims = jnp.where(mask[:, :, None], sims, _NEG)
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(sims, axis=1))

    @pl.when(l == n_l_blocks - 1)
    def _done():
        v = acc_ref[...]                   # (BB, G)
        vals_ref[...] = v
        new = new_ref[...]                 # (BB, G) bool — fresh cells only
        nf = new.astype(jnp.float32)
        vm = jnp.where(new, v, 0.0)
        d_n = jnp.sum(nf, axis=-1)         # (BB,)
        d_tot = jnp.sum(vm, axis=-1)
        # vm * v, not nf * v * v — see _fused_reveal_kernel
        d_sq = jnp.sum(vm * v, axis=-1)
        lane = jax.lax.broadcasted_iota(jnp.int32, (v.shape[0], STATS_W), 1)
        stats_ref[...] = jnp.where(
            lane == 0, d_n[:, None],
            jnp.where(lane == 1, d_tot[:, None],
                      jnp.where(lane == 2, d_sq[:, None], 0.0)))


@functools.partial(jax.jit, static_argnames=("block_b", "block_l", "gather",
                                             "interpret"))
def fused_reveal(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                 q_sel: jax.Array, new_mask: jax.Array, doc_idx: jax.Array,
                 *, block_b: int = 1, block_l: int = 256,
                 gather: bool = True, interpret: bool = False):
    """One fused reveal round.

    doc_embs:     (D, L, M) corpus/stacked docs (``gather=True``) or the
                  pre-gathered (F, L, M) frontier rows (``gather=False``);
                  may be a quantized corpus (``quant.QuantTokens``), in
                  which case each grid step DMAs the compressed payload
                  block and dequantizes it in VMEM
    doc_tok_mask: matching (D, L) / (F, L) token validity
    q_sel:        (F, G, M) pre-gathered query tokens per frontier row
    new_mask:     (F, G) bool — cells that are fresh this round
    doc_idx:      (F,) i32 — selected doc per frontier row (scalar-prefetch
                  gather target when ``gather=True``; still threaded when
                  ``gather=False`` so both modes share one call signature)
    returns:      vals (F, G) f32 MaxSim values,
                  stats (F, STATS_W) f32 with lanes [dn, dtotal, dtotal_sq]
    """
    F, G, M = q_sel.shape
    L = doc_embs.shape[1]
    bb = 1 if gather else min(block_b, max(F, 1))
    bl = min(block_l, max(L, 1))
    if F % bb != 0 or L % bl != 0:
        raise ValueError(
            f"fused_reveal needs pre-padded shapes: F={F} must be a "
            f"multiple of block_b={bb} and L={L} of block_l={bl} — call it "
            "through repro.kernels.ops.fused_reveal_op, which pads both "
            "axes (and documents the padding contract).")
    n_l_blocks = L // bl

    if gather:
        e_spec = pl.BlockSpec((bb, bl, M), lambda i, l, di: (di[i], l, 0))
        m_spec = pl.BlockSpec((bb, bl), lambda i, l, di: (di[i], l))
        row_spec = pl.BlockSpec((bb, bl), lambda i, l, di: (di[i], l))
    else:
        e_spec = pl.BlockSpec((bb, bl, M), lambda i, l, di: (i, l, 0))
        m_spec = pl.BlockSpec((bb, bl), lambda i, l, di: (i, l))
        row_spec = pl.BlockSpec((bb, bl), lambda i, l, di: (i, l))

    if isinstance(doc_embs, QuantTokens):
        residual = doc_embs.codes is not None
        in_specs = [e_spec, row_spec]
        operands = [doc_embs.data, doc_embs.scales]
        if residual:
            kc = doc_embs.codebook.shape[0]
            in_specs += [row_spec,
                         pl.BlockSpec((kc, M), lambda i, l, di: (0, 0))]
            operands += [doc_embs.codes, doc_embs.codebook]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(F // bb, n_l_blocks),
            in_specs=in_specs + [
                m_spec,
                pl.BlockSpec((bb, G, M), lambda i, l, di: (i, 0, 0)),
                pl.BlockSpec((bb, G), lambda i, l, di: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bb, G), lambda i, l, di: (i, 0)),
                pl.BlockSpec((bb, STATS_W), lambda i, l, di: (i, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((bb, G), jnp.float32)],
        )
        return pl.pallas_call(
            functools.partial(_fused_reveal_q_kernel, n_l_blocks=n_l_blocks,
                              residual=residual),
            grid_spec=grid_spec,
            out_shape=[jax.ShapeDtypeStruct((F, G), jnp.float32),
                       jax.ShapeDtypeStruct((F, STATS_W), jnp.float32)],
            interpret=interpret,
        )(doc_idx, *operands, doc_tok_mask, q_sel, new_mask)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(F // bb, n_l_blocks),
        in_specs=[
            e_spec,
            m_spec,
            pl.BlockSpec((bb, G, M), lambda i, l, di: (i, 0, 0)),
            pl.BlockSpec((bb, G), lambda i, l, di: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, G), lambda i, l, di: (i, 0)),
            pl.BlockSpec((bb, STATS_W), lambda i, l, di: (i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bb, G), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fused_reveal_kernel, n_l_blocks=n_l_blocks),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((F, G), jnp.float32),
                   jax.ShapeDtypeStruct((F, STATS_W), jnp.float32)],
        interpret=interpret,
    )(doc_idx, doc_embs, doc_tok_mask, q_sel, new_mask)
