"""Jitted public wrappers around the Pallas kernels.

Handles (a) padding to tile multiples, (b) platform dispatch: real Pallas on
TPU, ``interpret=True`` on CPU (executes the kernel body in Python — used to
validate kernels in this container), and pure-jnp reference as the escape
hatch (``REPRO_KERNEL_IMPL=ref``).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.maxsim import maxsim
from repro.kernels.masked_maxsim import masked_maxsim
from repro.kernels.gather_maxsim import gather_maxsim


def _impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if env != "auto":
        return env
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "interpret"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
              queries: jax.Array, *, block_n: int = 8, block_t: int = 0,
              block_l: int = 256) -> jax.Array:
    """Dense MaxSim matrix H (N, T) — pads, dispatches, slices back."""
    impl = _impl()
    if impl == "ref":
        return ref.maxsim_ref(doc_embs, doc_tok_mask, queries)
    N, L, M = doc_embs.shape
    T = queries.shape[0]
    bn = min(block_n, max(N, 1))
    bl = min(block_l, max(L, 1))
    e = _pad_to(_pad_to(doc_embs, 0, bn), 1, bl)
    m = _pad_to(_pad_to(doc_tok_mask, 0, bn), 1, bl)  # pads False => masked
    bt = block_t if block_t > 0 else queries.shape[0]
    q = _pad_to(queries, 0, bt)
    h = maxsim(e, m, q, block_n=bn, block_t=bt, block_l=bl,
               interpret=(impl == "interpret"))
    return h[:N, :T]


def masked_maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, tile_mask: jax.Array, *,
                     block_n: int = 8, block_t: int = 8,
                     block_l: int = 256) -> jax.Array:
    impl = _impl()
    if impl == "ref":
        return ref.masked_maxsim_ref(doc_embs, doc_tok_mask, queries,
                                     tile_mask, block_n, block_t)
    N, L, M = doc_embs.shape
    T = queries.shape[0]
    bn, bt, bl = block_n, block_t, min(block_l, max(L, 1))
    e = _pad_to(_pad_to(doc_embs, 0, bn), 1, bl)
    m = _pad_to(_pad_to(doc_tok_mask, 0, bn), 1, bl)
    q = _pad_to(queries, 0, bt)
    # Grow tile_mask to the padded grid (padded tiles stay inactive).
    gi, gj = e.shape[0] // bn, q.shape[0] // bt
    tm = jnp.zeros((gi, gj), jnp.bool_).at[
        :tile_mask.shape[0], :tile_mask.shape[1]].set(tile_mask)
    h = masked_maxsim(e, m, q, tm, block_n=bn, block_t=bt, block_l=bl,
                      interpret=(impl == "interpret"))
    return h[:N, :T]


def gather_maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, doc_idx: jax.Array,
                     tok_idx: jax.Array, *, block_b: int = 8,
                     block_l: int = 256) -> jax.Array:
    impl = _impl()
    if impl == "ref":
        return ref.gather_maxsim_ref(doc_embs, doc_tok_mask, queries,
                                     doc_idx, tok_idx)
    B, G = tok_idx.shape
    L = doc_embs.shape[1]
    bb = min(block_b, max(B, 1))
    bl = min(block_l, max(L, 1))
    e = _pad_to(doc_embs, 1, bl)
    m = _pad_to(doc_tok_mask, 1, bl)
    pad_b = (-B) % bb
    di = jnp.pad(doc_idx, (0, pad_b))
    ti = jnp.pad(tok_idx, ((0, pad_b), (0, 0)))
    out = gather_maxsim(e, m, queries, di, ti, block_b=bb, block_l=bl,
                        interpret=(impl == "interpret"))
    return out[:B]


def maxsim_scores_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, **kw) -> jax.Array:
    """Full late-interaction scores S (N,) = sum_t H[:, t]."""
    return jnp.sum(maxsim_op(doc_embs, doc_tok_mask, queries, **kw), axis=-1)
