"""Jitted public wrappers around the Pallas kernels.

Handles (a) padding to tile multiples, (b) platform dispatch: real Pallas on
TPU, ``interpret=True`` on CPU (executes the kernel body in Python — used to
validate kernels in this container), and pure-jnp reference as the escape
hatch (``REPRO_KERNEL_IMPL=ref``).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.maxsim import maxsim
from repro.kernels.masked_maxsim import masked_maxsim
from repro.kernels.gather_maxsim import gather_maxsim


def _impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if env != "auto":
        return env
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "interpret"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
              queries: jax.Array, *, block_n: int = 8, block_t: int = 0,
              block_l: int = 256) -> jax.Array:
    """Dense MaxSim matrix H (N, T) — pads, dispatches, slices back."""
    impl = _impl()
    if impl == "ref":
        return ref.maxsim_ref(doc_embs, doc_tok_mask, queries)
    N, L, M = doc_embs.shape
    T = queries.shape[0]
    bn = min(block_n, max(N, 1))
    bl = min(block_l, max(L, 1))
    e = _pad_to(_pad_to(doc_embs, 0, bn), 1, bl)
    m = _pad_to(_pad_to(doc_tok_mask, 0, bn), 1, bl)  # pads False => masked
    bt = block_t if block_t > 0 else queries.shape[0]
    q = _pad_to(queries, 0, bt)
    h = maxsim(e, m, q, block_n=bn, block_t=bt, block_l=bl,
               interpret=(impl == "interpret"))
    return h[:N, :T]


def masked_maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, tile_mask: jax.Array, *,
                     block_n: int = 8, block_t: int = 8,
                     block_l: int = 256) -> jax.Array:
    impl = _impl()
    if impl == "ref":
        return ref.masked_maxsim_ref(doc_embs, doc_tok_mask, queries,
                                     tile_mask, block_n, block_t)
    N, L, M = doc_embs.shape
    T = queries.shape[0]
    bn, bt, bl = block_n, block_t, min(block_l, max(L, 1))
    e = _pad_to(_pad_to(doc_embs, 0, bn), 1, bl)
    m = _pad_to(_pad_to(doc_tok_mask, 0, bn), 1, bl)
    q = _pad_to(queries, 0, bt)
    # Grow tile_mask to the padded grid (padded tiles stay inactive).
    gi, gj = e.shape[0] // bn, q.shape[0] // bt
    tm = jnp.zeros((gi, gj), jnp.bool_).at[
        :tile_mask.shape[0], :tile_mask.shape[1]].set(tile_mask)
    h = masked_maxsim(e, m, q, tm, block_n=bn, block_t=bt, block_l=bl,
                      interpret=(impl == "interpret"))
    return h[:N, :T]


def gather_maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, doc_idx: jax.Array,
                     tok_idx: jax.Array, *, block_b: int = 8,
                     block_l: int = 256) -> jax.Array:
    """Gathered MaxSim for the bandit reveal: out[s, g] = max_j
    <E[doc_idx[s], j], Q[tok_idx[s, g]]> over valid j.

    Padding contract: when the selection batch B is not a multiple of
    ``block_b``, the pad rows REPLICATE the last (doc_idx, tok_idx) row —
    a valid index whose doc block the kernel is touching anyway — instead
    of defaulting to doc 0, which would gather (and score) an unrelated
    document's embeddings per padded row. Pad rows are sliced off before
    returning; callers never observe them. ``doc_idx``/``tok_idx`` must be
    in-range for ``doc_embs``/``queries`` — the pooled frontier engine
    passes query-offset ids into stacked (Q*N, L, M) / (Q*T, M) tensors and
    this op is oblivious to the stacking — the budgeted rerank flavor
    (``retrieval.service._budgeted_scores``) feeds it the same stacked
    contract with (batch*candidate)-major rows.
    """
    if doc_idx.shape[0] != tok_idx.shape[0]:
        raise ValueError(
            f"gather_maxsim_op: doc_idx has {doc_idx.shape[0]} rows but "
            f"tok_idx has {tok_idx.shape[0]} — every selection row needs "
            "one doc id and one token block")
    impl = _impl()
    if impl == "ref":
        return ref.gather_maxsim_ref(doc_embs, doc_tok_mask, queries,
                                     doc_idx, tok_idx)
    B, G = tok_idx.shape
    L = doc_embs.shape[1]
    bb = min(block_b, max(B, 1))
    bl = min(block_l, max(L, 1))
    e = _pad_to(doc_embs, 1, bl)
    m = _pad_to(doc_tok_mask, 1, bl)
    pad_b = (-B) % bb
    di = jnp.concatenate([doc_idx,
                          jnp.broadcast_to(doc_idx[-1:], (pad_b,))])
    ti = jnp.concatenate([tok_idx,
                          jnp.broadcast_to(tok_idx[-1:], (pad_b, G))])
    out = gather_maxsim(e, m, queries, di, ti, block_b=bb, block_l=bl,
                        interpret=(impl == "interpret"))
    return out[:B]


def maxsim_batch_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                    queries: jax.Array, *, block_n: int = 8,
                    block_t: int = 8, block_l: int = 128) -> jax.Array:
    """Per-query-batched MaxSim H (B, N, T) — the dense serving scorer.

    Every dispatch target streams document tokens instead of materializing
    the (B, N, L, T) similarity tensor: ``pallas``/``interpret`` vmap the
    tiled ``maxsim`` kernel over the query batch (vmap adds a batch grid
    dimension; L is tiled through VMEM with a running max), and ``ref``
    uses the L-chunked running-max oracle. All-masked docs yield the _NEG
    sentinel in every mode; callers zero them as needed.
    """
    impl = _impl()
    if impl == "ref":
        return ref.maxsim_batch_ref(doc_embs, doc_tok_mask, queries,
                                    block_l=block_l)
    Bq, N, L, M = doc_embs.shape
    T = queries.shape[1]
    bn = min(block_n, max(N, 1))
    bt = min(block_t, max(T, 1))
    bl = min(block_l, max(L, 1))
    e = _pad_to(_pad_to(doc_embs, 1, bn), 2, bl)
    m = _pad_to(_pad_to(doc_tok_mask, 1, bn), 2, bl)  # pads False => masked
    q = _pad_to(queries, 1, bt)
    h = jax.vmap(lambda eb, mb, qb: maxsim(
        eb, mb, qb, block_n=bn, block_t=bt, block_l=bl,
        interpret=(impl == "interpret")))(e, m, q)
    return h[:, :N, :T]


def maxsim_scores_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, **kw) -> jax.Array:
    """Full late-interaction scores S (N,) = sum_t H[:, t]."""
    return jnp.sum(maxsim_op(doc_embs, doc_tok_mask, queries, **kw), axis=-1)
