"""Jitted public wrappers around the Pallas kernels.

Handles (a) padding to tile multiples, (b) platform dispatch: real Pallas on
TPU, ``interpret=True`` on CPU (executes the kernel body in Python — used to
validate kernels in this container), and pure-jnp reference as the escape
hatch (``REPRO_KERNEL_IMPL=ref``), and (c) block-size resolution: an
explicit ``block_*`` argument wins, then a tuned per-shape-bucket entry
(:mod:`repro.kernels.tuning`), then the op's default. Embedding/query
inputs may be ``bfloat16`` — every dispatch target accumulates in f32 and
returns f32.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref, tuning
from repro.kernels.maxsim import maxsim
from repro.kernels.masked_maxsim import masked_maxsim
from repro.kernels.gather_maxsim import gather_maxsim
from repro.kernels.quant import (QuantTokens, corpus_asarray, corpus_format,
                                 corpus_pad_to, corpus_take, format_ordinal,
                                 quantize_int8, quantize_residual)
from repro.kernels.reveal import STATS_USED, fused_reveal


def _impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if env != "auto":
        return env
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "interpret"


def _fmt_dims(dims: Dict[str, int], doc_embs) -> Dict[str, int]:
    """Key tuning buckets per corpus format: a quantized launch adds an FMT
    dim (power-of-two ordinal) so int8/residual learn their own block sizes.
    bf16/dense launches add nothing — their bucket keys (and any persisted
    tuned tables) are unchanged from before compression existed."""
    fmt = corpus_format(doc_embs)
    if fmt != "bf16":
        dims["FMT"] = format_ordinal(fmt)
    return dims


def _resolve(op: str, dims: Dict[str, int], **overrides) -> Dict[str, int]:
    """Block-size resolution: explicit argument > tuned bucket > default.

    ``None`` and 0 both defer (0 kept for back-compat with the old
    ``block_t=0`` "use full axis" convention, which is retired — the
    resolved default caps the tile instead of growing it to the axis)."""
    cfg = tuning.lookup(op, dims)
    for k, v in overrides.items():
        if v:
            cfg[k] = v
    return cfg


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
              queries: jax.Array, *, block_n: Optional[int] = None,
              block_t: Optional[int] = None,
              block_l: Optional[int] = None) -> jax.Array:
    """Dense MaxSim matrix H (N, T) — pads, dispatches, slices back.

    The query-token tile defaults to ``min(128, T)`` and T is padded up to
    it: the old ``block_t=0 -> bt = T`` default made an unbucketed large-T
    call blow the VMEM tile budget documented in ``kernels/maxsim.py``
    ((BN, BL, BT) similarity tile grows linearly in T).
    """
    impl = _impl()
    if impl == "ref":
        return ref.maxsim_ref(doc_embs, doc_tok_mask, queries)
    N, L, M = doc_embs.shape
    T = queries.shape[0]
    cfg = _resolve("maxsim", _fmt_dims(dict(N=N, T=T, L=L, M=M), doc_embs),
                   block_n=block_n, block_t=block_t, block_l=block_l)
    bn = min(cfg["block_n"], max(N, 1))
    bt = min(cfg["block_t"], max(T, 1))
    bl = min(cfg["block_l"], max(L, 1))
    e = corpus_pad_to(corpus_pad_to(doc_embs, 0, bn), 1, bl)
    m = _pad_to(_pad_to(doc_tok_mask, 0, bn), 1, bl)  # pads False => masked
    q = _pad_to(queries, 0, bt)
    h = maxsim(e, m, q, block_n=bn, block_t=bt, block_l=bl,
               interpret=(impl == "interpret"))
    return h[:N, :T]


def masked_maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, tile_mask: jax.Array, *,
                     block_n: int = 8, block_t: int = 8,
                     block_l: Optional[int] = None) -> jax.Array:
    """Tile-masked MaxSim. ``block_n``/``block_t`` are SEMANTIC here — they
    define the (doc, token) tile grid ``tile_mask`` is expressed in — so
    only the L tile is tunable."""
    impl = _impl()
    if impl == "ref":
        return ref.masked_maxsim_ref(doc_embs, doc_tok_mask, queries,
                                     tile_mask, block_n, block_t)
    N, L, M = doc_embs.shape
    T = queries.shape[0]
    cfg = _resolve("masked_maxsim",
                   _fmt_dims(dict(N=N, T=T, L=L, M=M), doc_embs),
                   block_l=block_l)
    bn, bt, bl = block_n, block_t, min(cfg["block_l"], max(L, 1))
    e = corpus_pad_to(corpus_pad_to(doc_embs, 0, bn), 1, bl)
    m = _pad_to(_pad_to(doc_tok_mask, 0, bn), 1, bl)
    q = _pad_to(queries, 0, bt)
    # Grow tile_mask to the padded grid (padded tiles stay inactive).
    gi, gj = e.shape[0] // bn, q.shape[0] // bt
    tm = jnp.zeros((gi, gj), jnp.bool_).at[
        :tile_mask.shape[0], :tile_mask.shape[1]].set(tile_mask)
    h = masked_maxsim(e, m, q, tm, block_n=bn, block_t=bt, block_l=bl,
                      interpret=(impl == "interpret"))
    return h[:N, :T]


def gather_maxsim_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, doc_idx: jax.Array,
                     tok_idx: jax.Array, *, block_b: Optional[int] = None,
                     block_l: Optional[int] = None) -> jax.Array:
    """Gathered MaxSim for the bandit reveal: out[s, g] = max_j
    <E[doc_idx[s], j], Q[tok_idx[s, g]]> over valid j.

    Padding contract: when the selection batch B is not a multiple of
    ``block_b``, the pad rows REPLICATE the last (doc_idx, tok_idx) row —
    a valid index whose doc block the kernel is touching anyway — instead
    of defaulting to doc 0, which would gather (and score) an unrelated
    document's embeddings per padded row. Pad rows are sliced off before
    returning; callers never observe them. ``doc_idx``/``tok_idx`` must be
    in-range for ``doc_embs``/``queries`` — the pooled frontier engine
    passes query-offset ids into stacked (Q*N, L, M) / (Q*T, M) tensors and
    this op is oblivious to the stacking — the budgeted rerank flavor
    (``retrieval.service._budgeted_scores``) feeds it the same stacked
    contract with (batch*candidate)-major rows.
    """
    if doc_idx.shape[0] != tok_idx.shape[0]:
        raise ValueError(
            f"gather_maxsim_op: doc_idx has {doc_idx.shape[0]} rows but "
            f"tok_idx has {tok_idx.shape[0]} — every selection row needs "
            "one doc id and one token block")
    impl = _impl()
    if impl == "ref":
        return ref.gather_maxsim_ref(doc_embs, doc_tok_mask, queries,
                                     doc_idx, tok_idx)
    B, G = tok_idx.shape
    D, L, M = doc_embs.shape
    cfg = _resolve("gather_maxsim",
                   _fmt_dims(dict(B=B, G=G, L=L, M=M, D=D,
                                  TQ=queries.shape[0]), doc_embs),
                   block_b=block_b, block_l=block_l)
    bb = min(cfg["block_b"], max(B, 1))
    bl = min(cfg["block_l"], max(L, 1))
    e = corpus_pad_to(doc_embs, 1, bl)
    m = _pad_to(doc_tok_mask, 1, bl)
    pad_b = (-B) % bb
    di = jnp.concatenate([doc_idx,
                          jnp.broadcast_to(doc_idx[-1:], (pad_b,))])
    ti = jnp.concatenate([tok_idx,
                          jnp.broadcast_to(tok_idx[-1:], (pad_b, G))])
    out = gather_maxsim(e, m, queries, di, ti, block_b=bb, block_l=bl,
                        interpret=(impl == "interpret"))
    return out[:B]


def fused_reveal_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                    queries: jax.Array, doc_idx: jax.Array,
                    tok_idx: jax.Array, new_mask: jax.Array, *,
                    block_b: Optional[int] = None,
                    block_l: Optional[int] = None):
    """Fused reveal round (``kernels.reveal``): gathered MaxSim values for
    the frontier's selected cells PLUS the per-row sufficient-statistic
    deltas ``core.bounds`` consumes, in one launch.

    doc_idx (F,), tok_idx (F, G), new_mask (F, G) ->
      (vals (F, G) f32, stats (F, 3) f32 = [d_count, d_total, d_total_sq]).

    Same index contract as :func:`gather_maxsim_op` (the pooled frontier's
    query-offset ids into stacked tensors); same pad contract on F —
    replicated last row, but with ``new_mask`` padded False so pad rows
    contribute zero statistics even before they are sliced off. On TPU the
    doc gather happens INSIDE the kernel (scalar-prefetched row indices),
    so the (F, L, M) gathered intermediate never exists in HBM; interpret
    mode pre-gathers at the XLA level and runs the same kernel body with
    wider row blocks (trace time scales with grid size on CPU).
    """
    if doc_idx.shape[0] != tok_idx.shape[0] \
            or tok_idx.shape != new_mask.shape:
        raise ValueError(
            f"fused_reveal_op: doc_idx/tok_idx/new_mask rows disagree "
            f"({doc_idx.shape[0]}, {tok_idx.shape}, {new_mask.shape}) — "
            "every selection row needs one doc id and matching (G,) token "
            "and freshness columns")
    impl = _impl()
    if impl == "ref":
        return ref.fused_reveal_ref(doc_embs, doc_tok_mask, queries,
                                    doc_idx, tok_idx, new_mask)
    B, G = tok_idx.shape
    D, L, M = doc_embs.shape
    gather = impl == "pallas"
    cfg = _resolve("fused_reveal",
                   _fmt_dims(dict(B=B, G=G, L=L, M=M, D=D,
                                  TQ=queries.shape[0]), doc_embs),
                   block_b=block_b, block_l=block_l)
    bb = 1 if gather else min(cfg["block_b"], max(B, 1))
    bl = min(cfg["block_l"], max(L, 1))
    e = corpus_pad_to(doc_embs, 1, bl)
    m = _pad_to(doc_tok_mask, 1, bl)
    pad_b = (-B) % bb
    di = jnp.concatenate([doc_idx,
                          jnp.broadcast_to(doc_idx[-1:], (pad_b,))])
    ti = jnp.concatenate([tok_idx,
                          jnp.broadcast_to(tok_idx[-1:], (pad_b, G))])
    nm = jnp.concatenate([new_mask,
                          jnp.zeros((pad_b, G), jnp.bool_)])
    q_sel = jnp.take(queries, ti, axis=0)              # (B+pad, G, M)
    if not gather:
        e = corpus_take(e, di, axis=0)                 # (B+pad, L, M)
        m = jnp.take(m, di, axis=0)
    vals, stats = fused_reveal(e, m, q_sel, nm, di, block_b=bb, block_l=bl,
                               gather=gather, interpret=(impl == "interpret"))
    return vals[:B], stats[:B, :STATS_USED]


def maxsim_batch_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                    queries: jax.Array, *, block_n: Optional[int] = None,
                    block_t: Optional[int] = None,
                    block_l: Optional[int] = None) -> jax.Array:
    """Per-query-batched MaxSim H (B, N, T) — the dense serving scorer.

    Every dispatch target streams document tokens instead of materializing
    the (B, N, L, T) similarity tensor: ``pallas``/``interpret`` vmap the
    tiled ``maxsim`` kernel over the query batch (vmap adds a batch grid
    dimension; L is tiled through VMEM with a running max), and ``ref``
    uses the L-chunked running-max oracle. All-masked docs yield the _NEG
    sentinel in every mode; callers zero them as needed.
    """
    impl = _impl()
    Bq, N, L, M = doc_embs.shape
    T = queries.shape[1]
    cfg = _resolve("maxsim_batch",
                   _fmt_dims(dict(B=Bq, N=N, T=T, L=L, M=M), doc_embs),
                   block_n=block_n, block_t=block_t, block_l=block_l)
    if impl == "ref":
        return ref.maxsim_batch_ref(doc_embs, doc_tok_mask, queries,
                                    block_l=cfg["block_l"])
    bn = min(cfg["block_n"], max(N, 1))
    bt = min(cfg["block_t"], max(T, 1))
    bl = min(cfg["block_l"], max(L, 1))
    e = corpus_pad_to(corpus_pad_to(doc_embs, 1, bn), 2, bl)
    m = _pad_to(_pad_to(doc_tok_mask, 1, bn), 2, bl)  # pads False => masked
    q = _pad_to(queries, 1, bt)
    if isinstance(e, QuantTokens):
        # vmap over the query-batch axis of every per-doc leaf; the
        # codebook is shared across the batch, not mapped
        e_axes = QuantTokens(0, 0, None if e.codes is None else 0, None)
    else:
        e_axes = 0
    h = jax.vmap(lambda eb, mb, qb: maxsim(
        eb, mb, qb, block_n=bn, block_t=bt, block_l=bl,
        interpret=(impl == "interpret")), in_axes=(e_axes, 0, 0))(e, m, q)
    return h[:, :N, :T]


def maxsim_scores_op(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                     queries: jax.Array, **kw) -> jax.Array:
    """Full late-interaction scores S (N,) = sum_t H[:, t]."""
    return jnp.sum(maxsim_op(doc_embs, doc_tok_mask, queries, **kw), axis=-1)


# ---------------------------------------------------------------------------
# Autotuning entry point: synthetic-array runners per op.
# ---------------------------------------------------------------------------

def autotune_op(op: str, dims: Dict[str, int], *, repeats: int = 2,
                seed: int = 0, dtype=jnp.float32):
    """Time the op's candidate block configurations at one shape bucket on
    synthetic arrays and record the winner in the tuning table.

    ``dims`` uses the same keys the op's own ``_resolve`` call derives from
    its launch shapes, so a recorded entry is exactly what later launches
    of that bucket look up:

    * ``maxsim``:        N, T, L, M
    * ``maxsim_batch``:  B, N, T, L, M
    * ``gather_maxsim``: B, G, L, M, D (doc rows), TQ (query-token rows)
    * ``fused_reveal``:  B, G, L, M, D, TQ

    A quantized bucket (``FMT`` dim present — see ``_fmt_dims``) is timed
    against a synthetic corpus encoded into that format, so the recorded
    block sizes reflect the dequant kernels' actual cost profile.

    Returns (best_config, {candidate-json: seconds}). Under
    ``REPRO_KERNEL_IMPL=ref`` the ops ignore block sizes entirely, so this
    records nothing and returns the defaults unmeasured.
    """
    if _impl() == "ref":
        return dict(tuning.DEFAULTS.get(op, {})), {}
    key = jax.random.key(seed)
    d = dict(dims)
    fmt = {1: "bf16", 2: "int8", 4: "residual"}.get(int(d.get("FMT", 1)))
    if fmt is None:
        raise ValueError(f"autotune_op: unknown FMT ordinal {d['FMT']!r}")

    def _norm(k, shape):
        return jax.random.normal(k, shape, jnp.float32).astype(dtype)

    def _corpus(arr):
        """Encode the synthetic corpus into the bucket's resident format."""
        if fmt == "bf16":
            return arr
        a = np.asarray(jax.device_get(arr), np.float32)
        if fmt == "int8":
            return corpus_asarray(quantize_int8(a))
        rng = np.random.default_rng(seed)
        cb = rng.standard_normal((8, a.shape[-1])).astype(np.float32)
        cb /= np.linalg.norm(cb, axis=-1, keepdims=True)
        return corpus_asarray(quantize_residual(a, cb))

    if op == "maxsim":
        ks = jax.random.split(key, 2)
        E = _corpus(_norm(ks[0], (d["N"], d["L"], d["M"])))
        mask = jnp.ones((d["N"], d["L"]), jnp.bool_)
        Q = _norm(ks[1], (d["T"], d["M"]))

        def runner(**cfg):
            return lambda: jax.block_until_ready(
                maxsim_op(E, mask, Q, **cfg))
    elif op == "maxsim_batch":
        ks = jax.random.split(key, 2)
        E = _corpus(_norm(ks[0], (d["B"], d["N"], d["L"], d["M"])))
        mask = jnp.ones((d["B"], d["N"], d["L"]), jnp.bool_)
        Q = _norm(ks[1], (d["B"], d["T"], d["M"]))

        def runner(**cfg):
            return lambda: jax.block_until_ready(
                maxsim_batch_op(E, mask, Q, **cfg))
    elif op in ("gather_maxsim", "fused_reveal"):
        ks = jax.random.split(key, 4)
        D, TQ = d.get("D", max(d["B"], 8)), d.get("TQ", 64)
        E = _corpus(_norm(ks[0], (D, d["L"], d["M"])))
        mask = jnp.ones((D, d["L"]), jnp.bool_)
        Q = _norm(ks[1], (TQ, d["M"]))
        di = jax.random.randint(ks[2], (d["B"],), 0, D, jnp.int32)
        ti = jax.random.randint(ks[3], (d["B"], d["G"]), 0, TQ, jnp.int32)
        if op == "gather_maxsim":
            def runner(**cfg):
                return lambda: jax.block_until_ready(
                    gather_maxsim_op(E, mask, Q, di, ti, **cfg))
        else:
            nm = jnp.ones((d["B"], d["G"]), jnp.bool_)

            def runner(**cfg):
                return lambda: jax.block_until_ready(
                    fused_reveal_op(E, mask, Q, di, ti, nm, **cfg))
    else:
        raise ValueError(f"autotune_op: unknown op {op!r}")
    cands = None
    if op == "fused_reveal" and _impl() == "pallas":
        # Gather mode forces block_b == 1 (the scalar-prefetch index map
        # redirects whole blocks), so candidates differing only in block_b
        # are the identical launch — dedup instead of timing duplicates.
        cands = []
        for c in tuning.candidates(op, dims):
            c = {k: v for k, v in c.items() if k != "block_b"}
            if c not in cands:
                cands.append(c)
    return tuning.autotune(op, dims, runner, repeats=repeats, cands=cands)
