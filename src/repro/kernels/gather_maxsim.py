"""Gathered MaxSim Pallas kernel — the block-synchronous bandit's reveal op.

Each round the bandit selects B ambiguous documents and G tokens per
document; the reveal computes exactly those B*G cells:

    out[b, g] = max_j <E[doc_idx[b], j], Q[tok_idx[b, g]]>

The doc/query gathers happen at the XLA level (cheap dynamic-slice / take on
small N); the kernel then runs a dense batched (B, L, M) x (B, G, M)
matmul-max with L tiled through VMEM. FLOPs = B * G * L * M * 2 exactly —
the bandit's savings are realized 1:1, with zero tile waste from irregular
reveal patterns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant import QuantTokens, corpus_take, dequant_block

_NEG = -3e38  # python float: jnp constants would be captured as kernel consts


def _gather_maxsim_kernel(e_ref, m_ref, q_ref, out_ref, acc_ref, *,
                          n_l_blocks):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, _NEG)

    e = e_ref[...].astype(jnp.float32)     # (BB, BL, M)
    q = q_ref[...].astype(jnp.float32)     # (BB, G, M)
    mask = m_ref[...]                      # (BB, BL)
    # batched (BB): (BL, M) . (G, M) -> (BL, G)
    sims = jax.lax.dot_general(
        e, q, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    sims = jnp.where(mask[:, :, None], sims, _NEG)
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(sims, axis=1))

    @pl.when(l == n_l_blocks - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def _gather_maxsim_q_kernel(*refs, n_l_blocks, residual):
    """Quantized-corpus variant: the XLA-level doc gather moved int8 bytes
    (plus tiny sidecars); rows are reconstructed per VMEM block here."""
    if residual:
        e_ref, s_ref, c_ref, cb_ref, m_ref, q_ref, out_ref, acc_ref = refs
    else:
        e_ref, s_ref, m_ref, q_ref, out_ref, acc_ref = refs
        c_ref = cb_ref = None
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, _NEG)

    e = dequant_block(e_ref[...], s_ref[...],
                      None if c_ref is None else c_ref[...],
                      None if cb_ref is None else cb_ref[...])
    q = q_ref[...].astype(jnp.float32)     # (BB, G, M)
    mask = m_ref[...]                      # (BB, BL)
    sims = jax.lax.dot_general(
        e, q, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    sims = jnp.where(mask[:, :, None], sims, _NEG)
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(sims, axis=1))

    @pl.when(l == n_l_blocks - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "block_l",
                                             "interpret"))
def gather_maxsim(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                  queries: jax.Array, doc_idx: jax.Array, tok_idx: jax.Array,
                  *, block_b: int = 8, block_l: int = 256,
                  interpret: bool = False) -> jax.Array:
    """out (B, G) — MaxSim values for the selected cells.

    With a quantized corpus the gather moves int8 payload + sidecars only;
    dequantization happens per VMEM block inside the kernel.
    """
    B, G = tok_idx.shape
    L, M = doc_embs.shape[1], doc_embs.shape[2]
    e = corpus_take(doc_embs, doc_idx, axis=0)         # (B, L, M)
    m = jnp.take(doc_tok_mask, doc_idx, axis=0)        # (B, L)
    q = jnp.take(queries, tok_idx, axis=0)             # (B, G, M)

    bb = min(block_b, B)
    bl = min(block_l, L)
    if B % bb != 0 or L % bl != 0:
        raise ValueError(
            f"gather_maxsim needs pre-padded shapes: B={B} must be a "
            f"multiple of block_b={bb} and L={L} of block_l={bl} — call it "
            "through repro.kernels.ops.gather_maxsim_op, which pads both "
            "axes (and documents the padding contract).")
    n_l_blocks = L // bl

    grid = (B // bb, n_l_blocks)
    if isinstance(e, QuantTokens):
        residual = e.codes is not None
        in_specs = [
            pl.BlockSpec((bb, bl, M), lambda i, l: (i, l, 0)),
            pl.BlockSpec((bb, bl), lambda i, l: (i, l)),
        ]
        operands = [e.data, e.scales]
        if residual:
            kc = e.codebook.shape[0]
            in_specs += [
                pl.BlockSpec((bb, bl), lambda i, l: (i, l)),
                pl.BlockSpec((kc, M), lambda i, l: (0, 0)),
            ]
            operands += [e.codes, e.codebook]
        in_specs += [
            pl.BlockSpec((bb, bl), lambda i, l: (i, l)),
            pl.BlockSpec((bb, G, M), lambda i, l: (i, 0, 0)),
        ]
        operands += [m, q]
        return pl.pallas_call(
            functools.partial(_gather_maxsim_q_kernel, n_l_blocks=n_l_blocks,
                              residual=residual),
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bb, G), lambda i, l: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((B, G), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bb, G), jnp.float32)],
            interpret=interpret,
        )(*operands)
    return pl.pallas_call(
        functools.partial(_gather_maxsim_kernel, n_l_blocks=n_l_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bl, M), lambda i, l: (i, l, 0)),
            pl.BlockSpec((bb, bl), lambda i, l: (i, l)),
            pl.BlockSpec((bb, G, M), lambda i, l: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, G), lambda i, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, G), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, G), jnp.float32)],
        interpret=interpret,
    )(e, m, q)
