"""Pallas TPU kernels for the MaxSim hot spot (Eq. 4) + oracles.

maxsim        — dense exact-reranking kernel (full H matrix)
masked_maxsim — tile-granular pruning (pl.when skips MXU work per tile)
gather_maxsim — irregular reveal sets for the block-synchronous bandit
ref           — pure-jnp oracles; ops — padded/jitted public wrappers
"""
from repro.kernels.ops import (gather_maxsim_op, masked_maxsim_op, maxsim_op,
                               maxsim_scores_op)

__all__ = ["gather_maxsim_op", "masked_maxsim_op", "maxsim_op",
           "maxsim_scores_op"]
