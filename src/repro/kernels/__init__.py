"""Pallas TPU kernels for the MaxSim hot spot (Eq. 4) + oracles.

maxsim        — dense exact-reranking kernel (full H matrix)
masked_maxsim — tile-granular pruning (pl.when skips MXU work per tile)
gather_maxsim — irregular reveal sets for the block-synchronous bandit
reveal        — fused reveal round: in-kernel doc gather + MaxSim +
                sufficient-statistic accumulation (one launch per round)
tuning        — per-shape-bucket block-size autotuning (JSON-persistable)
ref           — pure-jnp oracles; ops — padded/jitted public wrappers
"""
from repro.kernels.ops import (autotune_op, fused_reveal_op,
                               gather_maxsim_op, masked_maxsim_op, maxsim_op,
                               maxsim_scores_op)

__all__ = ["autotune_op", "fused_reveal_op", "gather_maxsim_op",
           "masked_maxsim_op", "maxsim_op", "maxsim_scores_op"]
