"""Shape-bucket block-size autotuning for the Pallas kernel ops.

The kernel wrappers in :mod:`repro.kernels.ops` used to hard-code one block
configuration (``block_n=8, block_l=256``, ...) for every shape they were
launched at. The right tiling depends on the launch shape — how much of L
fits a VMEM tile, how many frontier rows amortize a grid step — so this
module keeps a small table:

    (op, shape bucket) -> {block_*: int, ...}

* **Buckets**, not exact shapes: every dimension is rounded up to its next
  power of two, so one timed entry covers the whole family of shapes the
  serving engine's static buckets generate.
* **Resolution order** (``repro.kernels.ops._resolve``): an explicit block
  argument wins, then a tuned table entry, then the per-op default below.
  Resolution happens at Python trace time — block sizes are static to the
  compiled executable, so retuning never invalidates a warm cache (the
  engine autotunes BEFORE it AOT-compiles its buckets).
* **Persistence**: :func:`save_table` / :func:`load_table` round-trip the
  table through JSON so CI lanes and serving replicas reuse one tuned
  table instead of re-timing at every warmup
  (``EngineConfig.tuning_table``).

:func:`autotune` itself is measurement-only plumbing — it times a caller
supplied runner over :func:`candidates` and records the winner. The
runners that build synthetic arrays for each op live in
``repro.kernels.ops.autotune_op`` (ops imports this module, not the other
way around).
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

# Per-op fallback block configuration — the values the ops shipped with
# before tuning existed, except maxsim's block_t: the old ``block_t=0``
# resolved to the FULL query-token axis, which blows the documented VMEM
# tile budget for unbucketed large-T calls; 128 is the one-lane-tile cap
# the kernel's tile math is written for (kernels/maxsim.py header).
DEFAULTS: Dict[str, Dict[str, int]] = {
    "maxsim": {"block_n": 8, "block_t": 128, "block_l": 256},
    "maxsim_batch": {"block_n": 8, "block_t": 8, "block_l": 128},
    "masked_maxsim": {"block_l": 256},
    "gather_maxsim": {"block_b": 8, "block_l": 256},
    "fused_reveal": {"block_b": 8, "block_l": 256},
}

# Candidate grids per op — small but non-degenerate: autotuning compiles
# one executable per candidate, and warmup budgets are real. Candidates
# whose block exceeds the (padded) dimension collapse to the clamped
# config, so duplicates are pruned against the launch dims before timing.
# The maxsim/maxsim_batch grids were widened after BENCH_kernels.json
# showed speedups pinned at 1.0: at bucketed serving shapes (T<=64,
# N<=32) the old 3-4 point grids clamped every candidate onto the
# default, so there was nothing to win. The same grids serve the
# quantized (int8/residual) launches — those buckets carry an FMT dim
# (see ops._fmt_dims), so each format records its own winner per shape.
CANDIDATES: Dict[str, List[Dict[str, int]]] = {
    "maxsim": [
        {"block_n": 8, "block_t": 128, "block_l": 256},
        {"block_n": 8, "block_t": 128, "block_l": 128},
        {"block_n": 16, "block_t": 128, "block_l": 128},
        {"block_n": 16, "block_t": 128, "block_l": 256},
        {"block_n": 32, "block_t": 128, "block_l": 128},
        {"block_n": 8, "block_t": 64, "block_l": 256},
        {"block_n": 8, "block_t": 32, "block_l": 256},
        {"block_n": 16, "block_t": 64, "block_l": 128},
        {"block_n": 32, "block_t": 32, "block_l": 128},
    ],
    "maxsim_batch": [
        {"block_n": 8, "block_t": 8, "block_l": 128},
        {"block_n": 8, "block_t": 16, "block_l": 128},
        {"block_n": 16, "block_t": 16, "block_l": 128},
        {"block_n": 16, "block_t": 8, "block_l": 64},
        {"block_n": 8, "block_t": 16, "block_l": 64},
        {"block_n": 4, "block_t": 16, "block_l": 128},
        {"block_n": 16, "block_t": 16, "block_l": 64},
    ],
    "gather_maxsim": [
        {"block_b": 8, "block_l": 256},
        {"block_b": 16, "block_l": 128},
        {"block_b": 32, "block_l": 128},
        {"block_b": 8, "block_l": 128},
    ],
    "fused_reveal": [
        {"block_b": 8, "block_l": 256},
        {"block_b": 16, "block_l": 128},
        {"block_b": 8, "block_l": 128},
    ],
}

_TABLE: Dict[Tuple, Dict[str, int]] = {}


def _pow2_bucket(x: int) -> int:
    x = max(int(x), 1)
    return 1 << (x - 1).bit_length()


def bucket_key(op: str, dims: Dict[str, int]) -> Tuple:
    """(op, ((dim, pow2-rounded size), ...)) — the table's lookup key."""
    return (op, tuple(sorted((k, _pow2_bucket(v)) for k, v in dims.items())))


def lookup(op: str, dims: Dict[str, int]) -> Dict[str, int]:
    """Tuned entry for the op at these dims, merged over its defaults."""
    cfg = dict(DEFAULTS.get(op, {}))
    cfg.update(_TABLE.get(bucket_key(op, dims), {}))
    return cfg


def record(op: str, dims: Dict[str, int], config: Dict[str, int]) -> None:
    _TABLE[bucket_key(op, dims)] = dict(config)


def table() -> Dict[Tuple, Dict[str, int]]:
    return dict(_TABLE)


def clear() -> None:
    _TABLE.clear()


def table_json(keys: Optional[set] = None) -> List[Dict[str, Any]]:
    """The table as JSON-ready rows (also what ``save_table`` writes).
    ``keys`` restricts to those bucket keys (the serving engine persists
    only its own buckets out of the process-shared cache)."""
    return [{"op": op, "bucket": dict(bucket), "config": dict(cfg)}
            for (op, bucket), cfg in sorted(_TABLE.items())
            if keys is None or (op, bucket) in keys]


def save_table(path: str, *, keys: Optional[set] = None) -> None:
    with open(path, "w") as f:
        json.dump(table_json(keys), f, indent=1)


def load_table(path: str) -> int:
    """Merge a persisted table into the live one; returns entries loaded."""
    with open(path) as f:
        rows = json.load(f)
    for row in rows:
        key = (row["op"], tuple(sorted(
            (k, int(v)) for k, v in row["bucket"].items())))
        _TABLE[key] = {k: int(v) for k, v in row["config"].items()}
    return len(rows)


def candidates(op: str, dims: Dict[str, int]) -> List[Dict[str, int]]:
    """The op's candidate grid, clamped to the launch dims and deduped.

    Clamping mirrors the ops' own ``min(block, dim)`` guard so two
    candidates that collapse to the same effective config are timed once.
    """
    clamp = {"block_n": dims.get("N"), "block_t": dims.get("T"),
             "block_l": dims.get("L"), "block_b": dims.get("B")}
    out: List[Dict[str, int]] = []
    for cand in CANDIDATES.get(op, [DEFAULTS.get(op, {})]):
        eff = {k: (min(v, clamp[k]) if clamp.get(k) else v)
               for k, v in cand.items()}
        if eff not in out:
            out.append(eff)
    return out


def time_call(fn: Callable[[], Any], *, repeats: int = 3) -> float:
    """Best-of-N wall time of ``fn`` (compile/warm excluded by a first
    untimed call). ``fn`` must block until its result is materialized."""
    fn()
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(op: str, dims: Dict[str, int],
             runner: Callable[..., Callable[[], Any]], *,
             repeats: int = 3,
             cands: Optional[Iterable[Dict[str, int]]] = None,
             ) -> Tuple[Dict[str, int], Dict[str, float]]:
    """Time ``runner(**candidate)`` over the candidate grid, record the
    winner for (op, dims), and return (best_config, per-candidate timings).

    ``runner`` is called once per candidate and must return a 0-arg
    callable executing the op at that block configuration (the runner owns
    argument construction so autotuning works against real serving arrays
    or synthetic ones alike).
    """
    timings: Dict[str, float] = {}
    best_cfg: Optional[Dict[str, int]] = None
    best_t = float("inf")
    for cand in (cands if cands is not None else candidates(op, dims)):
        t = time_call(runner(**cand), repeats=repeats)
        timings[json.dumps(cand, sort_keys=True)] = t
        if t < best_t:
            best_t, best_cfg = t, dict(cand)
    if best_cfg is None:
        raise ValueError(f"autotune({op!r}): empty candidate set")
    record(op, dims, best_cfg)
    return best_cfg, timings
