"""Tile-masked MaxSim Pallas kernel — Col-Bandit's pruning made physical.

The bandit decides which (doc, query-token) tiles are worth computing; this
kernel SKIPS the MXU work for every inactive tile via ``pl.when`` — compute
is saved at tile granularity, not just masked out. Inactive output tiles are
written as exact 0 on the first L step so the output is fully defined.

tile_mask has shape (N/BN, T/BT): one bool per output tile. The static-budget
baselines (Doc-TopMargin with tile-aligned reveals) and the bulk reranking
path use this; the round-based bandit uses the gather kernel instead
(``gather_maxsim``) because its reveal sets are irregular.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant import QuantTokens, dequant_block

_NEG = -3e38  # python float: jnp constants would be captured as kernel consts


def _masked_maxsim_kernel(mask_ref, e_ref, m_ref, q_ref, out_ref, acc_ref, *,
                          n_l_blocks):
    l = pl.program_id(2)
    active = mask_ref[0, 0]

    @pl.when(jnp.logical_not(active) & (l == 0))
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(active)
    def _compute():
        @pl.when(l == 0)
        def _init():
            acc_ref[...] = jnp.full_like(acc_ref, _NEG)

        e = e_ref[...].astype(jnp.float32)
        q = q_ref[...].astype(jnp.float32)
        tok_mask = m_ref[...]
        sims = jax.lax.dot_general(
            e, q, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sims = jnp.where(tok_mask[:, :, None], sims, _NEG)
        acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(sims, axis=1))

        @pl.when(l == n_l_blocks - 1)
        def _done():
            out_ref[...] = acc_ref[...]


def _masked_maxsim_q_kernel(*refs, n_l_blocks, residual):
    """Quantized-corpus variant: identical tile skipping, but the embedding
    block is reconstructed from int8 (+ sidecars) in VMEM inside the active
    branch — inactive tiles skip the dequant work too."""
    if residual:
        (mask_ref, e_ref, s_ref, c_ref, cb_ref, m_ref, q_ref, out_ref,
         acc_ref) = refs
    else:
        mask_ref, e_ref, s_ref, m_ref, q_ref, out_ref, acc_ref = refs
        c_ref = cb_ref = None
    l = pl.program_id(2)
    active = mask_ref[0, 0]

    @pl.when(jnp.logical_not(active) & (l == 0))
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(active)
    def _compute():
        @pl.when(l == 0)
        def _init():
            acc_ref[...] = jnp.full_like(acc_ref, _NEG)

        e = dequant_block(e_ref[...], s_ref[...],
                          None if c_ref is None else c_ref[...],
                          None if cb_ref is None else cb_ref[...])
        q = q_ref[...].astype(jnp.float32)
        tok_mask = m_ref[...]
        sims = jax.lax.dot_general(
            e, q, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sims = jnp.where(tok_mask[:, :, None], sims, _NEG)
        acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(sims, axis=1))

        @pl.when(l == n_l_blocks - 1)
        def _done():
            out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_t", "block_l",
                                             "interpret"))
def masked_maxsim(doc_embs: jax.Array, doc_tok_mask: jax.Array,
                  queries: jax.Array, tile_mask: jax.Array, *,
                  block_n: int = 8, block_t: int = 0, block_l: int = 256,
                  interpret: bool = False) -> jax.Array:
    N, L, M = doc_embs.shape
    T = queries.shape[0]
    bn = min(block_n, N)
    bt = block_t if block_t > 0 else T
    bt = min(bt, T)
    bl = min(block_l, L)
    if N % bn or T % bt or L % bl:
        raise ValueError(f"masked_maxsim blocks must tile the operands: "
                         f"(N,T,L)=({N},{T},{L}) vs (bn,bt,bl)="
                         f"({bn},{bt},{bl})")
    if tile_mask.shape != (N // bn, T // bt):
        raise ValueError(f"tile_mask must be (N//bn, T//bt)="
                         f"({N // bn},{T // bt}); got {tile_mask.shape}")
    n_l_blocks = L // bl

    grid = (N // bn, T // bt, n_l_blocks)
    if isinstance(doc_embs, QuantTokens):
        residual = doc_embs.codes is not None
        in_specs = [
            pl.BlockSpec((1, 1), lambda i, j, l: (i, j)),
            pl.BlockSpec((bn, bl, M), lambda i, j, l: (i, l, 0)),
            pl.BlockSpec((bn, bl), lambda i, j, l: (i, l)),
        ]
        operands = [tile_mask, doc_embs.data, doc_embs.scales]
        if residual:
            kc = doc_embs.codebook.shape[0]
            in_specs += [
                pl.BlockSpec((bn, bl), lambda i, j, l: (i, l)),
                pl.BlockSpec((kc, M), lambda i, j, l: (0, 0)),
            ]
            operands += [doc_embs.codes, doc_embs.codebook]
        in_specs += [
            pl.BlockSpec((bn, bl), lambda i, j, l: (i, l)),
            pl.BlockSpec((bt, M), lambda i, j, l: (j, 0)),
        ]
        operands += [doc_tok_mask, queries]
        return pl.pallas_call(
            functools.partial(_masked_maxsim_q_kernel, n_l_blocks=n_l_blocks,
                              residual=residual),
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bn, bt), lambda i, j, l: (i, j)),
            out_shape=jax.ShapeDtypeStruct((N, T), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bn, bt), jnp.float32)],
            interpret=interpret,
        )(*operands)
    return pl.pallas_call(
        functools.partial(_masked_maxsim_kernel, n_l_blocks=n_l_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, l: (i, j)),
            pl.BlockSpec((bn, bl, M), lambda i, j, l: (i, l, 0)),
            pl.BlockSpec((bn, bl), lambda i, j, l: (i, l)),
            pl.BlockSpec((bt, M), lambda i, j, l: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bt), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, T), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bt), jnp.float32)],
        interpret=interpret,
    )(tile_mask, doc_embs, doc_tok_mask, queries)
