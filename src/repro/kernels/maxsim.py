"""Dense MaxSim Pallas TPU kernel — the exact-reranking hot spot (Eq. 4).

For every (doc i, query token t): H[i, t] = max_j <e_ij, q_t>.

Tiling (VMEM-resident, MXU-aligned):
  grid = (N/BN, T/BT, L/BL); the L axis is the innermost (sequential) grid
  dimension so a running max over document tokens lives in a VMEM scratch
  tile of shape (BN, BT) and the output block is written once, on the last
  L step. Embedding dim M is kept whole (128 in every assigned config — one
  MXU lane tile).

  per-step compute: (BN, BL, M) x (BT, M) -> dot_general batched over BN
  -> (BN, BL, BT) similarities -> masked max over BL -> running max.

VMEM at defaults (BN=8, BT=128, BL=256, M=128, f32):
  E tile 1.0 MiB + Q tile 64 KiB + sims 1.0 MiB + scratch 4 KiB  << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant import QuantTokens, dequant_block

_NEG = -3e38  # python float: jnp constants would be captured as kernel consts


def _maxsim_kernel(e_ref, m_ref, q_ref, out_ref, acc_ref, *, n_l_blocks):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, _NEG)

    e = e_ref[...].astype(jnp.float32)          # (BN, BL, M)
    q = q_ref[...].astype(jnp.float32)          # (BT, M)
    mask = m_ref[...]                           # (BN, BL)
    # (BN, BL, M) . (BT, M) -> (BN, BL, BT)
    sims = jax.lax.dot_general(
        e, q, (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    sims = jnp.where(mask[:, :, None], sims, _NEG)
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(sims, axis=1))

    @pl.when(l == n_l_blocks - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def _maxsim_q_kernel(*refs, n_l_blocks, residual):
    """Quantized-corpus variant: the int8 payload (plus scale / centroid
    sidecars) arrives per block; rows are reconstructed in VMEM right before
    the f32 dot — the dequantized tile never exists outside this step."""
    if residual:
        e_ref, s_ref, c_ref, cb_ref, m_ref, q_ref, out_ref, acc_ref = refs
    else:
        e_ref, s_ref, m_ref, q_ref, out_ref, acc_ref = refs
        c_ref = cb_ref = None
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, _NEG)

    e = dequant_block(e_ref[...], s_ref[...],
                      None if c_ref is None else c_ref[...],
                      None if cb_ref is None else cb_ref[...])
    q = q_ref[...].astype(jnp.float32)          # (BT, M)
    mask = m_ref[...]                           # (BN, BL)
    sims = jax.lax.dot_general(
        e, q, (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    sims = jnp.where(mask[:, :, None], sims, _NEG)
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(sims, axis=1))

    @pl.when(l == n_l_blocks - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_t", "block_l",
                                             "interpret"))
def maxsim(doc_embs: jax.Array, doc_tok_mask: jax.Array, queries: jax.Array,
           *, block_n: int = 8, block_t: int = 0, block_l: int = 256,
           interpret: bool = False) -> jax.Array:
    """Dense MaxSim matrix H (N, T). Shapes must be pre-padded so that
    BN | N, BT | T, BL | L (``repro.kernels.ops.maxsim_op`` handles padding).

    ``doc_embs`` may be a quantized corpus (``quant.QuantTokens``): the
    int8 payload and its sidecars are tiled through VMEM and dequantized
    in-kernel, so HBM only ever moves compressed bytes.
    """
    N, L, M = doc_embs.shape
    T = queries.shape[0]
    bn = min(block_n, N)
    bt = block_t if block_t > 0 else T
    bt = min(bt, T)
    bl = min(block_l, L)
    if N % bn or T % bt or L % bl:
        raise ValueError(f"maxsim blocks must tile the operands: "
                         f"(N,T,L)=({N},{T},{L}) vs (bn,bt,bl)="
                         f"({bn},{bt},{bl})")
    n_l_blocks = L // bl

    grid = (N // bn, T // bt, n_l_blocks)
    if isinstance(doc_embs, QuantTokens):
        residual = doc_embs.codes is not None
        in_specs = [
            pl.BlockSpec((bn, bl, M), lambda i, j, l: (i, l, 0)),
            pl.BlockSpec((bn, bl), lambda i, j, l: (i, l)),
        ]
        operands = [doc_embs.data, doc_embs.scales]
        if residual:
            kc = doc_embs.codebook.shape[0]
            in_specs += [
                pl.BlockSpec((bn, bl), lambda i, j, l: (i, l)),
                pl.BlockSpec((kc, M), lambda i, j, l: (0, 0)),
            ]
            operands += [doc_embs.codes, doc_embs.codebook]
        in_specs += [
            pl.BlockSpec((bn, bl), lambda i, j, l: (i, l)),
            pl.BlockSpec((bt, M), lambda i, j, l: (j, 0)),
        ]
        operands += [doc_tok_mask, queries]
        return pl.pallas_call(
            functools.partial(_maxsim_q_kernel, n_l_blocks=n_l_blocks,
                              residual=residual),
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bn, bt), lambda i, j, l: (i, j)),
            out_shape=jax.ShapeDtypeStruct((N, T), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bn, bt), jnp.float32)],
            interpret=interpret,
        )(*operands)
    return pl.pallas_call(
        functools.partial(_maxsim_kernel, n_l_blocks=n_l_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl, M), lambda i, j, l: (i, l, 0)),
            pl.BlockSpec((bn, bl), lambda i, j, l: (i, l)),
            pl.BlockSpec((bt, M), lambda i, j, l: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bt), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, T), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bt), jnp.float32)],
        interpret=interpret,
    )(doc_embs, doc_tok_mask, queries)
