"""repro: Col-Bandit late-interaction retrieval framework (JAX/Pallas)."""
from repro import _compat

_compat.install()

__version__ = "0.1.0"
