"""repro: Col-Bandit late-interaction retrieval framework (JAX/Pallas)."""
__version__ = "0.1.0"
