"""HLO-text analysis: collective byte accounting for the roofline.

``compiled.cost_analysis()`` exposes FLOPs and bytes-accessed but NOT
collective traffic — we parse the optimized HLO and sum the operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Sizes are per-replica operand bytes, i.e. the payload a
single device injects into the interconnect for that op (the standard
roofline convention: collective_time ~= bytes / link_bw, treating ring
algorithms' 2(n-1)/n factor as ~1).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %x = bf16[2,16,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind (+ 'total').

    ``-done`` ops are skipped so async pairs aren't double counted; tuple
    outputs count every element shape on the line before the op name."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped or "-done." in stripped:
            continue
        hit = None
        for coll in _COLLECTIVES:
            if f" {coll}(" in stripped or f" {coll}-start(" in stripped:
                hit = coll
                break
        if hit is None:
            continue
        lhs = stripped.split(f" {hit}")[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
        out[hit] += nbytes
        out["total"] += nbytes
    return dict(out)


def flops_and_bytes(compiled) -> Dict[str, float]:
    """Pull FLOPs / bytes-accessed out of compiled.cost_analysis() across
    jax versions (dict vs list-of-dict)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    return {"hlo_flops": flops, "hlo_bytes": nbytes}


def peak_buffer_bytes(compiled) -> float:
    """Peak temporary-buffer footprint of a compiled executable.

    ``temp_size_in_bytes`` is XLA's allocation for every intermediate the
    program materializes — the number that blows up when a formulation
    keeps a (B, N, L, T) similarity tensor live instead of streaming it.
    Used by the reveal benchmark / tests to assert the dense serving step
    stays under the materialized-intermediate threshold."""
    return float(compiled.memory_analysis().temp_size_in_bytes)


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        try:
            out[k] = float(getattr(ma, k))
        except AttributeError:
            pass
    return out
