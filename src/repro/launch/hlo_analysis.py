"""Back-compat shim: the HLO accounting moved to
:mod:`repro.analysis.hlo_audit` (where the compile-contract auditor lives);
this module re-exports the original surface for the roofline/dryrun
harnesses and older imports."""
from __future__ import annotations

from repro.analysis.hlo_audit import (_COLLECTIVES, _DTYPE_BYTES,  # noqa: F401
                                      _SHAPE_RE, _shape_bytes,
                                      collective_bytes, flops_and_bytes,
                                      memory_stats, peak_buffer_bytes)

__all__ = ["collective_bytes", "flops_and_bytes", "memory_stats",
           "peak_buffer_bytes"]
