"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
before any jax import; tests/benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 = 256 chips per pod; the multi-pod
    variant stacks 2 pods on a leading 'pod' axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int = 0, *, axes=("data", "model")):
    """Small debug mesh over whatever devices exist (tests/examples)."""
    n = n_devices or len(jax.devices())
    if len(axes) == 2:
        # favor model axis when n allows
        model = 1
        for m in (8, 4, 2, 1):
            if n % m == 0:
                model = m
                break
        return jax.make_mesh((n // model, model), axes)
    return jax.make_mesh((n,), axes)
