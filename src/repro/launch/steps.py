"""Per-(arch x shape) cell programs for the multi-pod dry-run.

``build_cell(arch, shape_name, mesh)`` returns a CellProgram holding:
  * the step callable (train_step / prefill_step / serve_step / scoring),
  * abstract inputs (ShapeDtypeStruct — never allocated),
  * in/out shardings for the mesh,
  * analytic MODEL_FLOPS (6*N*D train / 2*N*D forward; MoE uses N_active),
so launch/dryrun.py can mechanically ``jit(...).lower(...).compile()`` every
cell and benchmarks/roofline.py can derive the three roofline terms.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import (GNNConfig, LMConfig, RecsysConfig,
                                RetrievalConfig, ShapeSpec)
from repro.dist import sharding as SH
from repro.models import recsys as R
from repro.models.gnn import GraphBatch, init_pna
from repro.models.transformer import init_cache, init_lm, forward_prefill
from repro.serve.engine import serve_step
from repro.train.optimizer import adamw, cosine_schedule
from repro.train.train_step import (TrainState, make_gnn_train_step,
                                    make_lm_train_step,
                                    make_recsys_train_step,
                                    recsys_score_candidates, recsys_serve)

SDS = jax.ShapeDtypeStruct


class CellProgram(NamedTuple):
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: Tuple[Any, ...]            # abstract ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any
    model_flops: float
    note: str = ""
    donate_argnums: Tuple[int, ...] = ()


def _sds_like(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def _pad_mult(n: int, m: int) -> int:
    return -(-n // m) * m


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _n_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def _dp_total(mesh: Mesh) -> int:
    n = 1
    for a in SH.fsdp_axes(mesh):
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def lm_model_flops(cfg: LMConfig, shape: ShapeSpec) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        # + attention quadratic term (per layer 2*2*S^2*q_dim, window-capped)
        attn = 0.0
        for _, (n_l, s_att) in _stack_windows(cfg, shape.seq_len).items():
            attn += (shape.global_batch * n_l
                     * 2 * 2 * shape.seq_len * min(s_att, shape.seq_len)
                     * cfg.q_dim * 0.5)
        return 2.0 * n_active * tokens + attn
    # decode: one token per sequence + attention over the cache
    attn = 0.0
    for _, (n_l, s_att) in _stack_windows(cfg, shape.seq_len).items():
        attn += (shape.global_batch * n_l * 2 * 2
                 * min(s_att, shape.seq_len) * cfg.q_dim)
    return 2.0 * n_active * shape.global_batch + attn


def _stack_windows(cfg: LMConfig, max_seq: int) -> Dict[str, Tuple[int, int]]:
    w = cfg.sliding_window or 0
    if cfg.local_global_alternating:
        n_pairs = cfg.n_layers // 2
        return {"local": (n_pairs, w or max_seq), "global": (n_pairs, max_seq)}
    return {"all": (cfg.n_layers, w if w else max_seq)}


def gnn_model_flops(cfg: GNNConfig, n_nodes: int, n_edges: int,
                    d_feat: int, train: bool = True) -> float:
    d = cfg.d_hidden
    n_agg = len(cfg.aggregators) * len(cfg.scalers)
    per_layer = (n_edges * 2 * d * d * 2               # two msg matmuls
                 + n_nodes * 2 * (1 + n_agg) * d * d)  # update matmul
    fwd = (n_nodes * 2 * d_feat * d                    # encode
           + cfg.n_layers * per_layer
           + n_nodes * 2 * d * cfg.n_classes)
    return (3.0 if train else 1.0) * fwd


def recsys_model_flops(cfg: RecsysConfig, shape: ShapeSpec) -> float:
    B = shape.batch if shape.n_candidates == 0 else shape.n_candidates
    D = cfg.embed_dim
    if cfg.interaction == "fm-2way":
        # retrieval_cand uses the FM algebraic shortcut: O(N*D), F-free
        fwd = (B * D * 4 if shape.n_candidates > 0
               else B * cfg.n_sparse * D * 4)
    elif cfg.interaction == "self-attn":
        F, H, A = cfg.n_sparse, cfg.n_heads, cfg.d_attn
        per = 2 * F * (D * H * A * 4 + F * H * A * 2)
        fwd = B * cfg.n_attn_layers * per + B * 2 * F * H * A
    elif cfg.interaction == "target-attn":
        S = cfg.seq_len
        attn = S * (4 * D * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1]
                    + cfg.attn_mlp[1]) * 2
        mlp = (3 * D * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1] + cfg.mlp[1]) * 2
        fwd = B * (attn + mlp)
    else:  # sasrec
        S = cfg.seq_len
        per_block = 2 * S * (4 * D * D) + 2 * S * S * D * 2
        n_seq = shape.batch if shape.n_candidates == 0 else 1
        fwd = n_seq * cfg.n_blocks * per_block + B * 2 * D
    return (3.0 if shape.kind == "train" else 1.0) * fwd


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(cfg: LMConfig, shape: ShapeSpec, mesh: Mesh,
             micro: int = 0, param_mode: str = "zero3",
             flash_decode: bool = False) -> CellProgram:
    dtype = jnp.bfloat16
    dp_total = _dp_total(mesh)
    rules = SH.lm_param_rules(mesh, mode=param_mode)
    key = jax.random.key(0)

    params_abs = jax.eval_shape(lambda: init_lm(key, cfg, dtype=dtype))
    p_specs = SH.specs_from_rules(params_abs, rules)
    p_shard = _named(mesh, p_specs)

    if shape.kind == "train":
        opt = adamw(cosine_schedule(3e-4, 100, 10_000))
        m_abs = jax.tree.map(lambda p: SDS(p.shape, jnp.float32), params_abs)
        from repro.train.optimizer import AdamWState
        state_abs = TrainState(
            params=params_abs,
            opt=AdamWState(step=SDS((), jnp.int32), m=m_abs, v=m_abs))
        opt_specs = SH.specs_from_rules(params_abs, SH.lm_opt_rules(mesh))
        state_specs = TrainState(
            params=p_specs,
            opt=AdamWState(step=P(), m=opt_specs, v=opt_specs))
        state_shard = _named(mesh, state_specs)

        B, S = shape.global_batch, shape.seq_len
        if param_mode == "dp_all":
            dp_total = _n_devices(mesh)
        n_micro = micro if micro else max(1, B // dp_total)
        # chunked attention keeps per-layer logits ~(q_chunk x S) in remat;
        # MoE archs get tighter chunks (dispatch buffers add pressure)
        qc = (1024 if cfg.moe else 2048) if S > 2048 else 0
        cfg_t = dataclasses.replace(cfg, attn_q_chunk=qc)
        step = make_lm_train_step(cfg_t, opt, num_microbatches=n_micro,
                                  chunk_tokens=4096 if cfg.moe else 8192)
        batch_abs = {"tokens": SDS((B, S), jnp.int32),
                     "targets": SDS((B, S), jnp.int32)}
        bs = (P(tuple(mesh.axis_names), None) if param_mode == "dp_all"
              else SH.lm_batch_spec(mesh))
        b_spec = {"tokens": bs, "targets": bs}
        out_shard = (state_shard,
                     {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P())})
        return CellProgram(
            arch=cfg.name, shape=shape.name, kind="train", fn=step,
            args=(state_abs, batch_abs),
            in_shardings=(state_shard, _named(mesh, b_spec)),
            out_shardings=out_shard,
            model_flops=lm_model_flops(cfg, shape),
            note=f"microbatches={n_micro}",
            donate_argnums=(0,))

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        cfg_p = dataclasses.replace(cfg, attn_q_chunk=2048 if S >= 16384 else 0)

        def prefill_step(params, tokens):
            return forward_prefill(params, cfg_p, tokens, max_seq=S,
                                   cache_dtype=jnp.bfloat16)

        cache_abs = jax.eval_shape(
            lambda: init_cache(cfg, B, S, jnp.bfloat16))
        c_specs = {name: type(stack)(**SH.lm_cache_specs(mesh, B))
                   for name, stack in cache_abs.items()}
        out_shard = (NamedSharding(mesh, P(SH.fsdp_axes(mesh), None)),
                     _named(mesh, c_specs))
        return CellProgram(
            arch=cfg.name, shape=shape.name, kind="prefill", fn=prefill_step,
            args=(params_abs, SDS((B, S), jnp.int32)),
            in_shardings=(p_shard,
                          NamedSharding(mesh, SH.lm_batch_spec(mesh))),
            out_shardings=out_shard,
            model_flops=lm_model_flops(cfg, shape),
            note=f"q_chunk={cfg_p.attn_q_chunk}")

    # decode
    B, S = shape.global_batch, shape.seq_len
    cache_abs = jax.eval_shape(lambda: init_cache(cfg, B, S, jnp.bfloat16))
    c_specs = {name: type(stack)(**SH.lm_cache_specs(mesh, B))
               for name, stack in cache_abs.items()}
    # pin the per-layer cache slices inside the scan to the cache layout
    # (without this GSPMD rematerializes them un-sharded; see DESIGN.md)
    from repro.dist import act_sharding
    slice_specs = SH.lm_cache_specs(mesh, B)
    act_sharding.set_extra("cache_kv", P(*tuple(slice_specs["k"])[1:]))
    act_sharding.set_extra("cache_pos", slice_specs["pos"])
    from repro.dist import flash_decode as FD
    if flash_decode:
        # §Perf H2: explicit shard_map split-K decode attention
        seq_part = tuple(slice_specs["k"])[2]
        batch_part = tuple(slice_specs["k"])[1]
        FD.configure(mesh, batch_part, seq_part)
    else:
        FD.configure(None, None, None)
    c_shard = _named(mesh, c_specs)
    tok_spec = (NamedSharding(mesh, P(SH.fsdp_axes(mesh)))
                if B > 1 else NamedSharding(mesh, P()))

    def decode_step(params, token, position, cache):
        return serve_step(params, cfg, token, position, cache)

    logits_shard = (NamedSharding(mesh, P(SH.fsdp_axes(mesh), "model"))
                    if B > 1 else NamedSharding(mesh, P(None, "model")))
    return CellProgram(
        arch=cfg.name, shape=shape.name, kind="decode", fn=decode_step,
        args=(params_abs, SDS((B,), jnp.int32), SDS((), jnp.int32),
              cache_abs),
        in_shardings=(p_shard, tok_spec, NamedSharding(mesh, P()), c_shard),
        out_shardings=(logits_shard, c_shard),
        model_flops=lm_model_flops(cfg, shape),
        donate_argnums=(3,),
        note=f"kv_cache={ {k: v.k.shape for k, v in cache_abs.items()} }")


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cell(cfg: GNNConfig, shape: ShapeSpec, mesh: Mesh) -> CellProgram:
    n_dev = _n_devices(mesh)
    key = jax.random.key(0)

    if shape.name == "minibatch_lg":
        f1, f2 = shape.fanout
        n_nodes = shape.batch_nodes * (1 + f1 + f1 * f2)
        n_edges = shape.batch_nodes * (f1 + f1 * f2)
        d_feat = shape.d_feat
        note = f"sampled subgraph {n_nodes} nodes / {n_edges} edges"
    elif shape.name == "molecule":
        n_nodes = shape.graph_batch * shape.n_nodes
        n_edges = shape.graph_batch * shape.n_edges
        d_feat = shape.d_feat
        note = f"block-diag batch of {shape.graph_batch} molecules"
    else:
        n_nodes, n_edges, d_feat = shape.n_nodes, shape.n_edges, shape.d_feat
        note = "full graph"
    # dst-partition contract (models/gnn.py): +25% slack for range skew
    n_edges_p = _pad_mult(int(n_edges * 1.25), n_dev)
    n_nodes_p = _pad_mult(n_nodes, n_dev)

    params_abs = jax.eval_shape(lambda: init_pna(key, cfg, d_feat))
    p_specs = SH.specs_from_rules(params_abs, SH.gnn_param_rules(mesh))

    opt = adamw(cosine_schedule(1e-3, 100, 10_000))
    from repro.train.optimizer import AdamWState
    m_abs = jax.tree.map(lambda p: SDS(p.shape, jnp.float32), params_abs)
    state_abs = TrainState(params=params_abs,
                           opt=AdamWState(step=SDS((), jnp.int32),
                                          m=m_abs, v=m_abs))
    state_specs = TrainState(params=p_specs,
                             opt=AdamWState(step=P(), m=p_specs, v=p_specs))

    every = tuple(mesh.axis_names)
    batch_abs = GraphBatch(
        feats=SDS((n_nodes_p, d_feat), jnp.float32),
        senders=SDS((n_edges_p,), jnp.int32),
        receivers=SDS((n_edges_p,), jnp.int32),
        edge_mask=SDS((n_edges_p,), jnp.bool_),
        node_mask=SDS((n_nodes_p,), jnp.bool_),
        labels=SDS((n_nodes_p,), jnp.int32))
    b_specs = GraphBatch(feats=P(), senders=P(every), receivers=P(every),
                         edge_mask=P(every), node_mask=P(), labels=P())

    from repro.models.gnn import pna_loss_sharded

    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda prm: pna_loss_sharded(prm, cfg, batch, mesh))(state.params)
        new_p, new_opt, gnorm = opt.update(grads, state.opt, state.params)
        return TrainState(new_p, new_opt), {"loss": loss, "grad_norm": gnorm}
    return CellProgram(
        arch=cfg.name, shape=shape.name, kind="train", fn=step,
        args=(state_abs, batch_abs),
        in_shardings=(_named(mesh, state_specs), _named(mesh, b_specs)),
        out_shardings=(_named(mesh, state_specs),
                       {"loss": NamedSharding(mesh, P()),
                        "grad_norm": NamedSharding(mesh, P())}),
        model_flops=gnn_model_flops(cfg, n_nodes, n_edges, d_feat),
        note=note, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_init(cfg: RecsysConfig, key):
    if cfg.interaction == "fm-2way":
        return R.init_fm(key, cfg)
    if cfg.interaction == "self-attn":
        return R.init_autoint(key, cfg)
    if cfg.interaction == "target-attn":
        return R.init_din(key, cfg)
    return R.init_sasrec(key, cfg)


def _recsys_batch_abs(cfg: RecsysConfig, shape: ShapeSpec, mesh: Mesh):
    """(abstract batch, batch specs) for forward/train shapes."""
    B = shape.batch
    dp = SH.fsdp_axes(mesh)
    if cfg.interaction in ("fm-2way", "self-attn"):
        batch = {"ids": SDS((B, cfg.n_sparse), jnp.int32)}
        specs = {"ids": P(dp, None)}
    else:
        batch = {"hist_ids": SDS((B, cfg.seq_len), jnp.int32),
                 "hist_mask": SDS((B, cfg.seq_len), jnp.bool_),
                 "target_ids": SDS((B,), jnp.int32)}
        specs = {"hist_ids": P(dp, None), "hist_mask": P(dp, None),
                 "target_ids": P(dp)}
    if shape.kind == "train":
        batch["labels"] = SDS((B,), jnp.float32)
        specs["labels"] = P(dp)
    return batch, specs


def _recsys_cell(cfg: RecsysConfig, shape: ShapeSpec, mesh: Mesh) -> CellProgram:
    key = jax.random.key(0)
    params_abs = jax.eval_shape(lambda: _recsys_init(cfg, key))
    p_specs = SH.specs_from_rules(params_abs, SH.recsys_param_rules(mesh))
    p_shard = _named(mesh, p_specs)
    every = tuple(mesh.axis_names)
    n_dev = _n_devices(mesh)

    if shape.kind == "train":
        opt = adamw(cosine_schedule(1e-3, 100, 10_000))
        from repro.train.optimizer import AdamWState
        m_abs = jax.tree.map(lambda p: SDS(p.shape, jnp.float32), params_abs)
        state_abs = TrainState(params=params_abs,
                               opt=AdamWState(step=SDS((), jnp.int32),
                                              m=m_abs, v=m_abs))
        state_specs = TrainState(params=p_specs,
                                 opt=AdamWState(step=P(), m=p_specs,
                                                v=p_specs))
        batch_abs, b_specs = _recsys_batch_abs(cfg, shape, mesh)
        step = make_recsys_train_step(cfg, opt)
        return CellProgram(
            arch=cfg.name, shape=shape.name, kind="train", fn=step,
            args=(state_abs, batch_abs),
            in_shardings=(_named(mesh, state_specs), _named(mesh, b_specs)),
            out_shardings=(_named(mesh, state_specs),
                           {"loss": NamedSharding(mesh, P()),
                            "grad_norm": NamedSharding(mesh, P())}),
            model_flops=recsys_model_flops(cfg, shape),
            donate_argnums=(0,))

    if shape.n_candidates > 0:
        # retrieval_cand: 1 query vs ~1M candidates
        N = _pad_mult(shape.n_candidates, n_dev)
        if cfg.interaction in ("fm-2way", "self-attn"):
            batch_abs = {"context_ids": SDS((cfg.n_sparse - 1,), jnp.int32),
                         "cand_ids": SDS((N,), jnp.int32)}
            b_specs = {"context_ids": P(), "cand_ids": P(every)}
        else:
            batch_abs = {"hist_ids": SDS((cfg.seq_len,), jnp.int32),
                         "hist_mask": SDS((cfg.seq_len,), jnp.bool_),
                         "cand_ids": SDS((N,), jnp.int32)}
            b_specs = {"hist_ids": P(), "hist_mask": P(),
                       "cand_ids": P(every)}

        def score_step(params, batch):
            if cfg.interaction == "self-attn":
                return R.autoint_score_candidates(
                    params, cfg, batch["context_ids"], batch["cand_ids"],
                    chunk=N)
            if cfg.interaction == "target-attn":
                return R.din_score_candidates(
                    params, cfg, batch["hist_ids"], batch["hist_mask"],
                    batch["cand_ids"], chunk=N)
            return recsys_score_candidates(params, cfg, batch)

        return CellProgram(
            arch=cfg.name, shape=shape.name, kind="serve", fn=score_step,
            args=(params_abs, batch_abs),
            in_shardings=(p_shard, _named(mesh, b_specs)),
            out_shardings=NamedSharding(mesh, P(every)),
            model_flops=recsys_model_flops(cfg, shape),
            note=f"candidates padded {shape.n_candidates} -> {N}")

    # plain serving (serve_p99 / serve_bulk)
    batch_abs, b_specs = _recsys_batch_abs(cfg, shape, mesh)

    def serve(params, batch):
        return recsys_serve(params, cfg, batch)

    return CellProgram(
        arch=cfg.name, shape=shape.name, kind="serve", fn=serve,
        args=(params_abs, batch_abs),
        in_shardings=(p_shard, _named(mesh, b_specs)),
        out_shardings=NamedSharding(mesh, P(SH.fsdp_axes(mesh))),
        model_flops=recsys_model_flops(cfg, shape))


# ---------------------------------------------------------------------------
# Retrieval (paper) cells
# ---------------------------------------------------------------------------

def _retrieval_cell(cfg: RetrievalConfig, shape: ShapeSpec,
                    mesh: Mesh) -> CellProgram:
    from repro.retrieval.service import (make_rerank_bandit_step,
                                         make_rerank_dense_step)
    n_dev = _n_devices(mesh)
    every = tuple(mesh.axis_names)
    B, N = shape.batch, shape.n_candidates
    L, M, T = cfg.doc_tokens, cfg.dim, cfg.query_tokens
    C = _pad_mult(cfg.corpus_docs, n_dev)

    if shape.name.startswith("rerank_bandit"):
        step, in_specs, out_specs = make_rerank_bandit_step(
            mesh, topk=10, max_rounds=max(4, (N * T) // (16 * 8) // 2))
        args = (SDS((B, N, L, M), jnp.bfloat16),   # gathered candidate docs
                SDS((B, N, L), jnp.bool_),
                SDS((B, T, M), jnp.bfloat16),
                SDS((B, N), jnp.int32),
                SDS((B, N, T), jnp.float32),
                SDS((B, N, T), jnp.float32))
        return CellProgram(
            arch=cfg.name, shape=shape.name, kind="serve", fn=step,
            args=args,
            in_shardings=_named(mesh, in_specs),
            out_shardings=_named(mesh, out_specs),
            model_flops=B * N * T * L * M * 2 * 0.3,  # at ~30% coverage
            note="block-synchronous Col-Bandit, adaptive rounds")

    step = make_rerank_dense_step(mesh, topk=10)
    n_loc = max(1, -(-N * 4 // n_dev))   # 4x headroom for routing skew
    args = (SDS((C, L, M), jnp.bfloat16),
            SDS((C, L), jnp.bool_),
            SDS((B, T, M), jnp.bfloat16),
            SDS((B, n_dev, n_loc), jnp.int32))
    in_specs = (P(every, None, None), P(every, None), P(None, None, None),
                P(None, every, None))
    return CellProgram(
        arch=cfg.name, shape=shape.name, kind="serve", fn=step,
        args=args,
        in_shardings=_named(mesh, in_specs),
        out_shardings=(NamedSharding(mesh, P(None, None)),
                       NamedSharding(mesh, P(None, None))),
        model_flops=B * N * T * L * M * 2,
        note=f"corpus {C} docs sharded {n_dev}-way, {n_loc} cand slots/shard")


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               depth: int = 0, batch: int = 0, micro: int = 0,
               param_mode: str = "zero3",
               flash_decode: bool = False) -> CellProgram:
    """depth/batch/micro overrides serve the roofline pass: reduced-depth
    UNROLLED lowerings whose cost numbers extrapolate linearly to full
    depth (launch/scan_util.py explains why rolled scans can't be used)."""
    from repro.dist import act_sharding
    act_sharding.set_mesh(mesh)
    if param_mode == "dp_all":
        act_sharding.set_axes(tuple(mesh.axis_names), None)
    cfg = get_config(arch)
    shape = next(s for s in cfg.shapes if s.name == shape_name)
    if depth and cfg.family == "lm":
        cfg = dataclasses.replace(cfg, n_layers=depth)
    if batch and cfg.family == "lm":
        shape = dataclasses.replace(shape, global_batch=batch)
    if batch and cfg.family == "retrieval":
        shape = dataclasses.replace(shape, batch=batch)
    if cfg.family == "lm":
        return _lm_cell(cfg, shape, mesh, micro=micro, param_mode=param_mode,
                        flash_decode=flash_decode)
    if cfg.family == "gnn":
        return _gnn_cell(cfg, shape, mesh)
    if cfg.family == "recsys":
        return _recsys_cell(cfg, shape, mesh)
    if cfg.family == "retrieval":
        return _retrieval_cell(cfg, shape, mesh)
    raise ValueError(cfg.family)
