import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile EVERY (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.json

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init): 512 host placeholder devices back the
(2, 16, 16) production mesh on this CPU-only container. Lowering uses
ShapeDtypeStructs only — nothing is allocated at full size.
"""

import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

PAPER_ARCHS = ["colbert-text", "colbert-mm"]


def run_cell(arch: str, shape_name: str, mesh, *, verbose: bool = True):
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh)
    n_chips = int(np.prod(list(mesh.shape.values())))
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    mem = H.memory_stats(compiled)
    cost = H.flops_and_bytes(compiled)
    coll = H.collective_bytes(compiled.as_text())
    t1 = time.time()

    # roofline terms (per-chip seconds): cost_analysis is per-device in
    # SPMD mode (the HLO module is the per-device program)
    compute_s = cost["hlo_flops"] / PEAK_FLOPS
    memory_s = cost["hlo_bytes"] / HBM_BW
    collective_s = coll.get("total", 0) / ICI_BW
    model_flops_chip = cell.model_flops / n_chips
    rec = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "mesh": dict(mesh.shape), "n_chips": n_chips,
        "note": cell.note,
        "model_flops_per_chip": model_flops_chip,
        **cost,
        "collective_bytes": coll,
        "memory": mem,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(
            (("compute", compute_s), ("memory", memory_s),
             ("collective", collective_s)), key=lambda kv: kv[1])[0],
        "useful_flops_frac": (model_flops_chip / cost["hlo_flops"]
                              if cost["hlo_flops"] else 0.0),
        "compile_s": t1 - t0,
    }
    if verbose:
        mm = mem.get("temp_size_in_bytes", 0) / 2**30
        aa = mem.get("argument_size_in_bytes", 0) / 2**30
        print(f"  [OK] {arch:22s} {shape_name:15s} "
              f"args={aa:7.2f}GiB temp={mm:7.2f}GiB "
              f"T_c={compute_s*1e3:9.3f}ms T_m={memory_s*1e3:9.3f}ms "
              f"T_coll={collective_s*1e3:9.3f}ms -> {rec['bottleneck']:10s} "
              f"useful={rec['useful_flops_frac']*100:5.1f}% "
              f"({t1-t0:.0f}s compile)")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="one arch id (default: all assigned + paper)")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the (2,16,16) 512-chip mesh")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--skip-paper", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    if not args.arch and not args.skip_paper:
        archs += PAPER_ARCHS

    meshes = []
    if args.both:
        meshes = [("single-pod", make_production_mesh(multi_pod=False)),
                  ("multi-pod", make_production_mesh(multi_pod=True))]
    else:
        name = "multi-pod" if args.multi_pod else "single-pod"
        meshes = [(name, make_production_mesh(multi_pod=args.multi_pod))]

    records, failures = [], []
    for mesh_name, mesh in meshes:
        print(f"=== {mesh_name}: mesh {dict(mesh.shape)} "
              f"({int(np.prod(list(mesh.shape.values())))} chips) ===")
        for arch in archs:
            cfg = get_config(arch)
            shapes = ([args.shape] if args.shape
                      else [s.name for s in cfg.shapes])
            for shape_name in shapes:
                try:
                    rec = run_cell(arch, shape_name, mesh)
                    rec["mesh_name"] = mesh_name
                    records.append(rec)
                except Exception as e:
                    failures.append((mesh_name, arch, shape_name, str(e)))
                    print(f"  [FAIL] {arch} {shape_name}: {e}")
                    traceback.print_exc(limit=3)

    print(f"\n{len(records)} cells compiled, {len(failures)} failures")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"records": records,
                       "failures": failures,
                       "constants": {"peak_flops": PEAK_FLOPS,
                                     "hbm_bw": HBM_BW, "ici_bw": ICI_BW}},
                      f, indent=1)
        print(f"wrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
