"""Training substrate: optimizer, per-family steps, loop, compression."""
