"""Gradient compression for data-parallel all-reduce.

int8 quantized psum with ERROR FEEDBACK (the residual of this step's
quantization is added to next step's gradient, guaranteeing the compression
error doesn't accumulate — Seide et al. / 1-bit SGD lineage):

    g_eff   = g + err_prev
    scale   = pmax(|g_eff|) / 127          (shared scale -> exact int psum)
    q       = round(g_eff / scale)  : int8
    err     = g_eff - q * scale            (carried to next step)
    g_out   = psum(q) * scale / n_devices

Wire cost: 1 byte/grad element + one scalar pmax per leaf (vs 4 bytes fp32 or
2 bytes bf16) => 4x (resp. 2x) all-reduce byte reduction on the DP axis.
``topk_sparsify`` additionally zeroes all but the top-k fraction per leaf
(magnitude), also with error feedback.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


def init_error_buffer(grads: Params) -> Params:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def int8_psum(grads: Params, error: Params, axis_name: str
              ) -> Tuple[Params, Params]:
    """Quantized mean-all-reduce over `axis_name` with error feedback.
    Must run inside shard_map/vmap with that axis bound."""
    n = jax.lax.axis_size(axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        err = g - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale / n, err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def topk_sparsify(grads: Params, error: Params, frac: float = 0.1
                  ) -> Tuple[Params, Params]:
    """Keep the top-`frac` fraction of entries per leaf (by magnitude);
    the rest goes to the error buffer."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        k = max(1, int(frac * flat.shape[0]))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(g) >= thresh, g, 0.0)
        return kept, g - kept

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def int8_rs_ag(grads: Params, error: Params, axis_name: str
               ) -> Tuple[Params, Params]:
    """Wire-efficient int8 mean-all-reduce: reduce-scatter the int8 payload
    (all_to_all), sum locally in int32, REquantize the reduced shard to int8,
    all-gather it back. Wire bytes = 2 x 1 B/element vs 4 B for an fp32
    all-reduce — the pattern production 1-bit/int8 collectives use (a plain
    psum of int8 would widen to int32 ON THE WIRE and save nothing).
    Error feedback carries the local quantization residual."""
    n = jax.lax.axis_size(axis_name)

    def one(g, e):
        shape = g.shape
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % n
        flat = jnp.pad(flat, (0, pad))
        scale = jax.lax.pmax(jnp.max(jnp.abs(flat)), axis_name) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        err = (flat - q.astype(jnp.float32) * scale)[
            :flat.shape[0] - pad].reshape(shape)
        # reduce-scatter: all_to_all the n equal chunks (int8 on the wire)
        chunks = q.reshape(n, -1)
        recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0,
                                  concat_axis=0, tiled=False)
        local_sum = jnp.sum(recv.astype(jnp.int32), axis=0)     # my shard
        # requantize the reduced shard (values now in [-127n, 127n])
        scale2 = jax.lax.pmax(jnp.max(jnp.abs(local_sum)), axis_name
                              ).astype(jnp.float32) / 127.0
        scale2 = jnp.maximum(scale2, 1e-12)
        q2 = jnp.clip(jnp.round(local_sum.astype(jnp.float32) / scale2),
                      -127, 127).astype(jnp.int8)
        full = jax.lax.all_gather(q2, axis_name, axis=0,
                                  tiled=True)                    # int8 wire
        out = (full.astype(jnp.float32) * scale * scale2 / n)
        return out[:out.shape[0] - pad].reshape(shape), err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
