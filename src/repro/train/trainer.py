"""Training loop with checkpoint/restart and deterministic resume.

The data pipeline is STEP-KEYED: batch(step) = f(seed, step), so resuming
from a checkpoint at step k replays exactly the batches a non-interrupted
run would have seen — the restart test asserts bitwise-identical params.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import (AsyncCheckpointer, latest_checkpoint,
                                   restore_checkpoint)
from repro.train.train_step import TrainState

Params = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    async_ckpt: bool = True


class Trainer:
    def __init__(self, step_fn: Callable, batch_fn: Callable[[int], Any],
                 init_state: TrainState, cfg: TrainerConfig):
        """step_fn(state, batch) -> (state, metrics); batch_fn(step) -> batch."""
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.state = init_state
        self.cfg = cfg
        self.start_step = 0
        self.metrics_log: list = []
        self.ckpt = (AsyncCheckpointer(cfg.ckpt_dir)
                     if cfg.ckpt_dir and cfg.async_ckpt else None)

    def maybe_restore(self):
        if not self.cfg.ckpt_dir:
            return
        found = latest_checkpoint(self.cfg.ckpt_dir)
        if found:
            step, path = found
            self.state, meta = restore_checkpoint(path, self.state)
            self.state = jax.tree.map(jax.numpy.asarray, self.state)
            self.start_step = step
            print(f"[trainer] resumed from {path} (step {step})")

    def _save(self, step: int):
        if not self.cfg.ckpt_dir:
            return
        if self.ckpt:
            self.ckpt.save(step, self.state)
        else:
            from repro.ckpt.checkpoint import save_checkpoint
            save_checkpoint(self.cfg.ckpt_dir, step,
                            jax.tree.map(np.asarray, self.state))

    def run(self, guard: Optional[Callable[[int], None]] = None) -> TrainState:
        t0 = time.time()
        for step in range(self.start_step, self.cfg.total_steps):
            if guard is not None:
                guard(step)
            batch = self.batch_fn(step)
            self.state, metrics = self.step_fn(self.state, batch)
            if (step + 1) % self.cfg.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step + 1
                m["sec"] = time.time() - t0
                self.metrics_log.append(m)
                print(f"[trainer] step {step+1}: " +
                      " ".join(f"{k}={v:.4g}" for k, v in m.items()
                               if k != "step"))
            if (step + 1) % self.cfg.ckpt_every == 0:
                self._save(step + 1)
        if self.ckpt:
            self.ckpt.wait()
        return self.state
