"""Per-family losses and jit-ready train/serve step functions.

The LM cross-entropy is CHUNKED over the sequence (scan + remat): at
vocab=256k / 1M-token batches, materializing full (tokens, vocab) logits in
fp32 would be ~1 TB — chunking keeps the live logits slice bounded while
leaving total FLOPs unchanged (forward recomputed per chunk on backward).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from repro.models.scan_util import scan as _scan

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig
from repro.models import gnn as G
from repro.models import recsys as R
from repro.models.layers import rms_norm, softcap
from repro.models.transformer import forward_train
from repro.train.optimizer import AdamW, AdamWState

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt: AdamWState


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def _hidden_states(params: Params, cfg: LMConfig, tokens: jax.Array,
                   remat: bool) -> jax.Array:
    """Forward trunk only (no LM head); per-layer remat inside the scan."""
    from repro.models.transformer import forward_hidden
    return forward_hidden(params, cfg, tokens, remat=remat)


def lm_loss(params: Params, cfg: LMConfig, tokens: jax.Array,
            targets: jax.Array, *, chunk_tokens: int = 8192,
            remat: bool = True) -> jax.Array:
    """Next-token CE, chunked over the SEQUENCE axis (the batch axis stays
    sharded over the FSDP group throughout, so chunking never reshards)."""
    from repro.dist.act_sharding import constrain as _cst
    B, S = tokens.shape
    hidden = _hidden_states(params, cfg, tokens, remat)      # (B, S, D)
    hidden = _cst(hidden, "dp", None, None)

    head = params["head"]
    chunk_s = max(1, min(S, chunk_tokens // max(B, 1)))
    while S % chunk_s != 0:
        chunk_s -= 1
    n_chunks = S // chunk_s

    def chunk_loss(carry, xs):
        hc, yc = xs                                          # (B, cs, D)
        logits = softcap(hc @ head, cfg.logit_softcap).astype(jnp.float32)
        logits = _cst(logits, "dp", None, "tp")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(yc, 0)[..., None],
                                   axis=-1)[..., 0]
        nll = jnp.where(yc >= 0, logz - gold, 0.0)
        cnt = jnp.sum((yc >= 0).astype(jnp.float32))
        return (carry[0] + jnp.sum(nll), carry[1] + cnt), None

    h_cs = hidden.reshape(B, n_chunks, chunk_s, -1).transpose(1, 0, 2, 3)
    y_cs = targets.reshape(B, n_chunks, chunk_s).transpose(1, 0, 2)
    body = jax.checkpoint(chunk_loss) if remat else chunk_loss
    (tot, cnt), _ = _scan(body, (jnp.float32(0), jnp.float32(0)),
                          (h_cs, y_cs))
    return tot / jnp.maximum(cnt, 1.0)


def make_lm_train_step(cfg: LMConfig, opt: AdamW, chunk_tokens: int = 8192,
                       num_microbatches: int = 1) -> Callable:
    """num_microbatches > 1 = gradient accumulation via lax.scan.

    The (B, S) batch is viewed as (B/m, m, S) and transposed so the scan's
    leading (microbatch) axis is UNsharded while the per-micro batch rows
    stay sharded over the FSDP group — every device contributes B/(m*dp)
    rows to each micro step and activation peaks shrink by m."""
    def step(state: TrainState, batch: Dict[str, jax.Array]):
        tokens, targets = batch["tokens"], batch["targets"]

        def loss_fn(p, t, y):
            return lm_loss(p, cfg, t, y, chunk_tokens=chunk_tokens)

        if num_microbatches > 1:
            B, S = tokens.shape
            assert B % num_microbatches == 0
            mb = B // num_microbatches
            tk = tokens.reshape(mb, num_microbatches, S).transpose(1, 0, 2)
            tg = targets.reshape(mb, num_microbatches, S).transpose(1, 0, 2)

            def micro(carry, xs):
                g_acc, l_acc = carry
                t, y = xs
                loss, g = jax.value_and_grad(loss_fn)(state.params, t, y)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (g_sum, l_sum), _ = _scan(micro, (g0, jnp.float32(0)),
                                             (tk, tg))
            grads = jax.tree.map(lambda g: g / num_microbatches, g_sum)
            loss = l_sum / num_microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(
                state.params, tokens, targets)
        new_p, new_opt, gnorm = opt.update(grads, state.opt, state.params)
        return TrainState(new_p, new_opt), {"loss": loss, "grad_norm": gnorm}
    return step


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def make_gnn_train_step(cfg: GNNConfig, opt: AdamW) -> Callable:
    def step(state: TrainState, batch: G.GraphBatch):
        loss, grads = jax.value_and_grad(
            lambda p: G.pna_loss(p, cfg, batch))(state.params)
        new_p, new_opt, gnorm = opt.update(grads, state.opt, state.params)
        return TrainState(new_p, new_opt), {"loss": loss, "grad_norm": gnorm}
    return step


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def recsys_forward(params: Params, cfg: RecsysConfig,
                   batch: Dict[str, jax.Array]) -> jax.Array:
    if cfg.interaction == "fm-2way":
        return R.fm_forward(params, cfg, batch["ids"])
    if cfg.interaction == "self-attn":
        return R.autoint_forward(params, cfg, batch["ids"])
    if cfg.interaction == "target-attn":
        return R.din_forward(params, cfg, batch["hist_ids"],
                             batch["hist_mask"], batch["target_ids"])
    if cfg.interaction == "self-attn-seq":
        return R.sasrec_forward(params, cfg, batch["hist_ids"],
                                batch["hist_mask"], batch["target_ids"])
    raise ValueError(cfg.interaction)


def make_recsys_train_step(cfg: RecsysConfig, opt: AdamW) -> Callable:
    def step(state: TrainState, batch: Dict[str, jax.Array]):
        def loss_fn(p):
            logits = recsys_forward(p, cfg, batch)
            return bce_loss(logits, batch["labels"])
        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        new_p, new_opt, gnorm = opt.update(grads, state.opt, state.params)
        return TrainState(new_p, new_opt), {"loss": loss, "grad_norm": gnorm}
    return step


def recsys_serve(params: Params, cfg: RecsysConfig,
                 batch: Dict[str, jax.Array]) -> jax.Array:
    """Forward scoring (serve_p99 / serve_bulk shapes)."""
    return recsys_forward(params, cfg, batch)


def recsys_score_candidates(params: Params, cfg: RecsysConfig,
                            batch: Dict[str, jax.Array]) -> jax.Array:
    """retrieval_cand shape: 1 query vs n_candidates items."""
    if cfg.interaction == "fm-2way":
        return R.fm_score_candidates(params, cfg, batch["context_ids"],
                                     batch["cand_ids"])
    if cfg.interaction == "self-attn":
        return R.autoint_score_candidates(params, cfg, batch["context_ids"],
                                          batch["cand_ids"])
    if cfg.interaction == "target-attn":
        return R.din_score_candidates(params, cfg, batch["hist_ids"],
                                      batch["hist_mask"], batch["cand_ids"])
    if cfg.interaction == "self-attn-seq":
        return R.sasrec_score_candidates(params, cfg, batch["hist_ids"],
                                         batch["hist_mask"], batch["cand_ids"])
    raise ValueError(cfg.interaction)
