"""Data-parallel LM train step with int8-compressed gradient all-reduce
(§Perf H1, iteration 4 — completes the lead recorded in EXPERIMENTS.md).

Layout: pure data parallelism over a chosen axis group (params REPLICATED
across it, batch sharded). The whole step runs under shard_map so the
gradient reduction is OURS, not GSPMD's: grads are quantized to int8 with a
shared scale (one scalar pmax per leaf) and summed with an int32 psum —
4x fewer bytes on the wire than fp32, 2x fewer than bf16, with ERROR
FEEDBACK carried in the training state so quantization error cannot
accumulate (train/compression.py).

This is the production pattern for small/medium models where H1 showed the
collective term is gradient/activation traffic, not weight gathers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import LMConfig
from repro.train.compression import init_error_buffer, int8_rs_ag
from repro.train.optimizer import AdamW, AdamWState
from repro.train.train_step import lm_loss

Params = Any


class CompressedTrainState(NamedTuple):
    params: Params
    opt: AdamWState
    error: Params          # error-feedback buffers (fp32, param-shaped)


def init_compressed_state(params: Params, opt: AdamW) -> CompressedTrainState:
    return CompressedTrainState(params=params, opt=opt.init(params),
                                error=init_error_buffer(params))


def make_compressed_lm_train_step(cfg: LMConfig, opt: AdamW, mesh: Mesh,
                                  *, chunk_tokens: int = 8192,
                                  compress: bool = True) -> Callable:
    """Returns step(state, batch) -> (state, metrics); batch sharded over
    every mesh axis, params/opt/error replicated (ZeRO-0 + wire compression;
    compose with zero1 opt sharding outside if desired)."""
    every = tuple(mesh.axis_names)

    def shard_fn(state: CompressedTrainState, tokens, targets):
        def loss_fn(p):
            return lm_loss(p, cfg, tokens, targets,
                           chunk_tokens=chunk_tokens, remat=True)

        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        loss = jax.lax.pmean(loss, every)
        if compress:
            grads, new_error = int8_rs_ag(grads, state.error, every)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, every), grads)
            new_error = state.error
        new_p, new_opt, gnorm = opt.update(grads, state.opt, state.params)
        new_state = CompressedTrainState(params=new_p, opt=new_opt,
                                         error=new_error)
        return new_state, {"loss": loss, "grad_norm": gnorm}

    rep = jax.tree.map(lambda _: P(), jax.tree.leaves({"x": 0}))  # helper

    def step(state: CompressedTrainState, batch: Dict[str, jax.Array]):
        state_specs = jax.tree.map(lambda _: P(), state)
        out_specs = (jax.tree.map(lambda _: P(), state),
                     {"loss": P(), "grad_norm": P()})
        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(state_specs, P(every, None), P(every, None)),
            out_specs=out_specs,
        )(state, batch["tokens"], batch["targets"])

    return step
