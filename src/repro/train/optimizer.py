"""AdamW + LR schedules in pure JAX (optax is not available offline).

Optimizer state is a pytree shaped like the params (m, v per leaf), so it
shards exactly like the params (ZeRO-3-equivalent under the 2D param
sharding in dist/sharding.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


class AdamW(NamedTuple):
    init: Callable[[Params], AdamWState]
    update: Callable[[Params, AdamWState, Params, jax.Array], tuple]


def adamw(lr: Callable[[jax.Array], jax.Array] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1,
          grad_clip_norm: float = 1.0) -> AdamW:
    lr_fn = lr if callable(lr) else (lambda step: jnp.float32(lr))

    def init(params: Params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(grads: Params, state: AdamWState, params: Params,
               extra_scale: jax.Array | None = None):
        step = state.step + 1
        # global-norm clip
        gnorm = global_norm(grads)
        clip = jnp.minimum(1.0, grad_clip_norm / jnp.maximum(gnorm, 1e-9))
        if extra_scale is not None:
            clip = clip * extra_scale

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * clip
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
            vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr_fn(step) * delta
            return new_p.astype(p.dtype), m2, v2

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        results = [upd(g, m, v, p)
                   for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_params = treedef.unflatten([r[0] for r in results])
        new_m = treedef.unflatten([r[1] for r in results])
        new_v = treedef.unflatten([r[2] for r in results])
        return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm

    return AdamW(init=init, update=update)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(peak_lr) * jnp.where(s < warmup_steps, warm, cos)
    return fn


def linear_schedule(peak_lr: float, warmup_steps: int,
                    total_steps: int) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        decay = jnp.clip(1.0 - (s - warmup_steps) /
                         jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        return jnp.float32(peak_lr) * jnp.where(s < warmup_steps, warm, decay)
    return fn
