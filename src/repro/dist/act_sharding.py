"""Global activation-sharding constraint context.

Model code annotates activations with LOGICAL axes (``"dp"``, ``"tp"``)
via :func:`constrain`; the launch layer binds those names to concrete mesh
axes once per cell with :func:`set_mesh` / :func:`set_axes`.  When no mesh
is configured (unit tests, single-device examples) every constraint is a
no-op, so the same model code runs unmodified everywhere.

:func:`set_extra` registers NAMED full PartitionSpecs (e.g. ``"cache_kv"``)
that :func:`constrain_named` applies — the decode cell uses this to pin the
per-layer KV-cache slices inside the scan to the cache layout without the
model having to know the mesh.

All constraints are divisibility-guarded like dist/sharding.py: a dim that
doesn't divide its axis group is left unsharded rather than failing.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import fsdp_axes, tp_axis

_mesh = None
_dp: Optional[Tuple[str, ...]] = None
_tp: Optional[str] = None
_extra: Dict[str, P] = {}


def set_mesh(mesh) -> None:
    """Bind the constraint context to ``mesh`` (None to disable).  Resets
    the logical axes to the defaults (dp = FSDP group, tp = 'model') and
    clears named extras — one fresh context per lowered cell."""
    global _mesh, _dp, _tp
    _mesh = mesh
    _dp = fsdp_axes(mesh) if mesh is not None else None
    _tp = tp_axis(mesh) if mesh is not None else None
    _extra.clear()


def set_axes(dp, tp) -> None:
    """Override what the logical "dp" / "tp" names resolve to (e.g. the
    dp_all layout binds dp to EVERY mesh axis and tp to None)."""
    global _dp, _tp
    _dp = dp
    _tp = tp


def set_extra(name: str, spec: P) -> None:
    """Register a named full-rank PartitionSpec for constrain_named."""
    _extra[name] = spec


def clear() -> None:
    """Drop the mesh, axes and extras — constraints become no-ops (needed
    before running manual-collective shard_map code in the same process)."""
    global _mesh, _dp, _tp
    _mesh = None
    _dp = None
    _tp = None
    _extra.clear()


def get_mesh():
    return _mesh


def _resolve(part):
    if part == "dp":
        return _dp
    if part == "tp":
        return _tp
    return part


def _apply(x: jax.Array, parts) -> jax.Array:
    mesh_shape = dict(_mesh.shape)
    fitted = []
    for dim, part in zip(x.shape, parts):
        part = _resolve(part)
        if part is None:
            fitted.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        ok = True
        for a in axes:
            if a not in mesh_shape:
                ok = False
                break
            size *= int(mesh_shape[a])
        fitted.append(part if ok and dim % size == 0 else None)
    if all(p is None for p in fitted):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_mesh, P(*fitted)))


def constrain(x: jax.Array, *parts) -> jax.Array:
    """Constrain ``x`` (rank == len(parts)) to the resolved logical spec.
    No-op without a configured mesh or on rank mismatch."""
    if _mesh is None or x.ndim != len(parts):
        return x
    return _apply(x, parts)


def constrain_named(x: jax.Array, name: str) -> jax.Array:
    """Apply the registered named spec, or pass through when unregistered
    (model code can annotate optimistically — see "cache_logits" in
    models/layers.py)."""
    if _mesh is None or name not in _extra:
        return x
    parts = tuple(_extra[name])
    if len(parts) != x.ndim:
        return x
    return _apply(x, parts)
