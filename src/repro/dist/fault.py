"""Fault-tolerance and elastic-serving primitives.

* :func:`simulate_failure` — deterministic in-process "kill" for testing the
  checkpoint/restart contract (crash at step k, restart, land bitwise-equal
  with an uninterrupted run — tests/test_train_ckpt_fault.py).
* :func:`reshard` — place a restored (host) state tree onto a fresh mesh
  layout: elastic restart onto a different device topology.
* :class:`DeadlineBatcher` — the serving-side admission queue: release a
  batch when it is FULL or when the oldest request has waited past the
  deadline (padded to the compiled batch shape so one program serves both).
* :class:`FaultPlan` / :class:`InjectedFault` — the deterministic chaos
  harness: a replayable script of thread kills, shard health flips and
  dispatch delays, fired by counter (not wall clock) at named chaos points
  so two runs of the same plan inject the identical fault sequence.
* :class:`ChaosClock` — a thread-safe virtual clock so injected delays and
  deadline accounting stay deterministic in tests.
* :func:`poison_corpus` — seeded NaN/Inf corruption of a fraction of corpus
  rows, for exercising the finite-score quarantine guard end to end.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding


class SimulatedFailure(RuntimeError):
    """Raised by the failure guard to emulate a worker being killed."""


def simulate_failure(run: Callable[[Callable[[int], None]], Any],
                     fail_at_step: int) -> bool:
    """Run ``run(guard)`` where ``guard(step)`` kills the run the first time
    ``step == fail_at_step``.  Returns True when the failure fired (the run
    died mid-flight), False when the run finished before reaching the step.
    """
    fired = [False]

    def guard(step: int) -> None:
        if step == fail_at_step and not fired[0]:
            fired[0] = True
            raise SimulatedFailure(f"simulated failure at step {step}")

    try:
        run(guard)
    except SimulatedFailure:
        return True
    return fired[0]


class ChaosKill(RuntimeError):
    """Raised inside a serving thread by a FaultPlan ``kill`` action — the
    supervised analogue of the thread being SIGKILLed mid-loop. The engine
    watchdog recognizes it (and any other exception) as a dead thread and
    restarts within the restart budget."""


@dataclasses.dataclass(frozen=True)
class InjectedFault:
    """One scripted fault.

    ``point`` names the chaos point ("admit" | "dispatch" | "stream" —
    the engine ticks its point once per thread-loop iteration), ``at`` is
    the tick count at which the fault fires (the Nth time that point is
    reached), ``action`` is what happens:

    * ``"kill"``       — raise :class:`ChaosKill` in the ticking thread,
    * ``"shard_down"`` — mark mesh shard ``int(arg)`` unhealthy,
    * ``"shard_up"``   — restore mesh shard ``int(arg)``,
    * ``"delay"``      — stall the ticking thread ``arg`` seconds (advanced
      on a :class:`ChaosClock` when the engine clock is one, else slept).
    """

    point: str
    at: int
    action: str
    arg: float = 0.0

    def __post_init__(self):
        if self.action not in ("kill", "shard_down", "shard_up", "delay"):
            raise ValueError(f"unknown fault action {self.action!r}")
        if self.at < 1:
            raise ValueError("fault fires at tick >= 1")


class FaultPlan:
    """A deterministic, replayable fault schedule.

    Counter-based, not clock-based: every serving-thread loop iteration
    ticks its named chaos point, and a fault fires when its point's counter
    reaches ``at``. Two runs of the same plan over the same request stream
    therefore inject the identical fault sequence at the identical loop
    boundaries — the property the chaos soak's replay assertions need.
    An EMPTY plan is inert by construction (``tick`` returns nothing and
    the engine skips the chaos hook entirely), so a no-fault run is
    bit-identical to a run without a plan.

    Thread-safe: chaos points tick from the serving threads while tests
    read ``fired`` from the caller thread.
    """

    def __init__(self, faults: Sequence[InjectedFault] = ()):
        self.faults = tuple(faults)
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}
        self._fired: List[InjectedFault] = []
        self._by_point: Dict[str, Dict[int, List[InjectedFault]]] = {}
        for f in self.faults:
            self._by_point.setdefault(f.point, {}).setdefault(
                f.at, []).append(f)

    @classmethod
    def seeded(cls, seed: int, *, points: Sequence[str] = ("dispatch",),
               n_faults: int = 1, max_tick: int = 50,
               actions: Sequence[str] = ("kill",),
               shards: Sequence[int] = (0,),
               delay_s: float = 0.0) -> "FaultPlan":
        """A randomized-but-replayable plan: ``n_faults`` faults drawn with
        ``numpy.random.default_rng(seed)`` over the given points, tick
        range and actions. The same seed always yields the same plan."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            action = actions[int(rng.integers(len(actions)))]
            arg = 0.0
            if action in ("shard_down", "shard_up"):
                arg = float(shards[int(rng.integers(len(shards)))])
            elif action == "delay":
                arg = delay_s
            faults.append(InjectedFault(
                point=points[int(rng.integers(len(points)))],
                at=int(rng.integers(1, max_tick + 1)),
                action=action, arg=arg))
        return cls(faults)

    @property
    def empty(self) -> bool:
        return not self.faults

    def tick(self, point: str) -> List[InjectedFault]:
        """Advance ``point``'s counter; return the faults firing at this
        tick (kills last, so a kill+state-flip tick applies the flip)."""
        if not self.faults:
            return []
        with self._lock:
            c = self._counts.get(point, 0) + 1
            self._counts[point] = c
            due = list(self._by_point.get(point, {}).get(c, []))
            self._fired.extend(due)
        return sorted(due, key=lambda f: f.action == "kill")

    @property
    def fired(self) -> List[InjectedFault]:
        """Snapshot of the faults that have fired so far (test surface)."""
        with self._lock:
            return list(self._fired)

    def counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counts)


class ChaosClock:
    """A thread-safe virtual clock: ``()`` reads the time, ``advance``
    moves it, ``sleep`` is an advance (injected delays cost virtual time
    only). Inject as the engine's ``clock=`` so deadline accounting and
    FaultPlan delays are deterministic and wall-time-free in tests."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> None:
        with self._lock:
            self._t += float(dt)

    def sleep(self, dt: float) -> None:
        self.advance(dt)


def apply_delay(clock: Callable[[], float], seconds: float) -> None:
    """Stall the calling thread ``seconds``: virtually when ``clock`` is a
    :class:`ChaosClock`, else a real ``time.sleep`` — the one place the
    chaos harness decides between simulated and wall time."""
    if seconds <= 0:
        return
    if isinstance(clock, ChaosClock):
        clock.sleep(seconds)
    else:
        time.sleep(seconds)


def poison_corpus(embs, fraction: float, seed: int = 0, *,
                  mode: str = "nan") -> Tuple[np.ndarray, np.ndarray]:
    """Seeded corruption of a fraction of corpus doc rows.

    Returns ``(poisoned_embs, poisoned_mask)`` where ``poisoned_mask`` is
    the (C,) bool row selection — at least one row whenever ``fraction >
    0`` and the corpus is non-empty. ``mode`` is ``"nan"`` | ``"inf"`` |
    ``"neginf"``; the corruption hits every token of the selected docs so
    any reveal of the row trips the finite-score guard. The input is
    copied, never mutated."""
    embs = np.array(embs, dtype=np.float32, copy=True)
    C = embs.shape[0]
    mask = np.zeros((C,), bool)
    n_bad = int(round(C * float(fraction)))
    if fraction > 0 and C:
        n_bad = max(n_bad, 1)
    if n_bad:
        rng = np.random.default_rng(seed)
        rows = rng.choice(C, size=min(n_bad, C), replace=False)
        val = {"nan": np.nan, "inf": np.inf, "neginf": -np.inf}
        try:
            embs[rows] = val[mode]
        except KeyError:
            raise ValueError(f"unknown poison mode {mode!r} "
                             "(expected 'nan', 'inf' or 'neginf')") from None
        mask[rows] = True
    return embs, mask


def reshard(tree: Any, specs: Any, mesh) -> Any:
    """Place every leaf of ``tree`` on ``mesh`` with its PartitionSpec from
    ``specs`` (a matching tree of specs).  Used after restore_checkpoint to
    land host arrays in a NEW device layout — the checkpoint format is
    layout-free, so a job can come back on a different mesh shape."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


class DeadlineBatcher:
    """Admission batching with a latency deadline.

    ``add`` enqueues a request; ``poll`` returns ``None`` while the batch is
    neither full nor expired, otherwise ``(requests, n_real)`` where
    ``requests`` always has exactly ``batch_size`` entries (short batches
    are padded by repeating the last real request, so the jitted serving
    step sees one static shape) and ``n_real`` counts the genuine ones.
    The deadline clock starts at the OLDEST pending request, so a trickle
    of traffic is released within ``deadline_s`` of its first arrival.

    A request may carry its own (tighter) admission deadline, two ways:

    * ``add(req, deadline_s=...)`` — a relative admission deadline, frozen
      at add time: release once the request has waited that long.
    * ``add(req, deadline_abs=...)`` — an absolute COMPLETION deadline
      (clock frame). The admission deadline is derived lazily, at every
      ``next_expiry``/``poll``, as ``deadline_abs - headroom()`` where
      ``headroom`` is the constructor-supplied callable (e.g. the serving
      engine's live batch-service-time EMA). Deriving at poll time — not
      at add time — is what keeps queued requests honest when the service
      estimate RISES while they wait: a frozen admission deadline would
      release them too late to execute before completion is due.

    The batch releases as soon as ANY pending request is past its
    (tightest) admission deadline, so a latency-critical request is never
    held behind the global window. ``next_expiry`` returns the CURRENT
    clock when a full batch is already pending: a caller sleeping until
    ``next_expiry()`` must wake immediately, since ``poll`` would release
    right now (sleeping through a ready full batch was a real bug).

    All queue operations take an internal lock, so producers (``add``) and
    a consumer loop (``next_expiry``/``poll``/``flush``) may live on
    different threads — the async serving engine's contract.
    """

    def __init__(self, batch_size: int, deadline_s: float,
                 clock: Callable[[], float] = time.monotonic,
                 headroom: Optional[Callable[[], float]] = None):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.batch_size = int(batch_size)
        self.deadline_s = float(deadline_s)
        self.clock = clock
        self.headroom = headroom or (lambda: 0.0)
        self._lock = threading.Lock()
        # (arrival_ts, admission_deadline_s|None, deadline_abs|None, req)
        self._pending: deque = deque()

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    def add(self, request: Any, deadline_s: Optional[float] = None,
            deadline_abs: Optional[float] = None) -> None:
        entry = (self.clock(), deadline_s, deadline_abs, request)
        with self._lock:
            self._pending.append(entry)

    def _entry_expiry(self, ts: float, d: Optional[float],
                      d_abs: Optional[float], headroom: float) -> float:
        """Absolute admission deadline of one entry: the global window,
        tightened by a frozen relative deadline and/or a live-derived
        absolute one. Clamped to the arrival stamp so a request already
        past ``deadline_abs - headroom`` releases immediately instead of
        producing an expiry in the past."""
        expiry = ts + self.deadline_s
        if d is not None:
            expiry = min(expiry, ts + d)
        if d_abs is not None:
            expiry = min(expiry, max(ts, d_abs - headroom))
        return expiry

    def next_expiry(self) -> Optional[float]:
        """Earliest absolute time at which ``poll`` will release a batch
        (None when the queue is empty). A ready FULL batch expires NOW —
        the caller's poll loop must not sleep through it."""
        with self._lock:
            return self._next_expiry_locked()

    def _next_expiry_locked(self) -> Optional[float]:
        if not self._pending:
            return None
        if len(self._pending) >= self.batch_size:
            return self.clock()
        headroom = self.headroom()
        return min(self._entry_expiry(ts, d, d_abs, headroom)
                   for ts, d, d_abs, _ in self._pending)

    def poll(self) -> Optional[Tuple[List[Any], int]]:
        with self._lock:
            if not self._pending:
                return None
            if len(self._pending) >= self.batch_size:
                reqs = [self._pending.popleft()[3]
                        for _ in range(self.batch_size)]
                return reqs, self.batch_size
            if self.clock() < self._next_expiry_locked():
                return None
            return self._flush_locked()

    def flush(self) -> Optional[Tuple[List[Any], int]]:
        """Release the oldest pending batch immediately (padded), deadline
        or not. At most ``batch_size`` real requests per call — the padded
        static-shape contract holds even when more are pending; call in a
        loop (or ``poll`` first) to drain completely."""
        with self._lock:
            return self._flush_locked()

    def _flush_locked(self) -> Optional[Tuple[List[Any], int]]:
        if not self._pending:
            return None
        take = min(len(self._pending), self.batch_size)
        reqs = [self._pending.popleft()[3] for _ in range(take)]
        n_real = len(reqs)
        reqs = reqs + [reqs[-1]] * (self.batch_size - n_real)
        return reqs, n_real
