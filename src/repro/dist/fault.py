"""Fault-tolerance and elastic-serving primitives.

* :func:`simulate_failure` — deterministic in-process "kill" for testing the
  checkpoint/restart contract (crash at step k, restart, land bitwise-equal
  with an uninterrupted run — tests/test_train_ckpt_fault.py).
* :func:`reshard` — place a restored (host) state tree onto a fresh mesh
  layout: elastic restart onto a different device topology.
* :class:`DeadlineBatcher` — the serving-side admission queue: release a
  batch when it is FULL or when the oldest request has waited past the
  deadline (padded to the compiled batch shape so one program serves both).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, List, Optional, Tuple

import jax
from jax.sharding import NamedSharding


class SimulatedFailure(RuntimeError):
    """Raised by the failure guard to emulate a worker being killed."""


def simulate_failure(run: Callable[[Callable[[int], None]], Any],
                     fail_at_step: int) -> bool:
    """Run ``run(guard)`` where ``guard(step)`` kills the run the first time
    ``step == fail_at_step``.  Returns True when the failure fired (the run
    died mid-flight), False when the run finished before reaching the step.
    """
    fired = [False]

    def guard(step: int) -> None:
        if step == fail_at_step and not fired[0]:
            fired[0] = True
            raise SimulatedFailure(f"simulated failure at step {step}")

    try:
        run(guard)
    except SimulatedFailure:
        return True
    return fired[0]


def reshard(tree: Any, specs: Any, mesh) -> Any:
    """Place every leaf of ``tree`` on ``mesh`` with its PartitionSpec from
    ``specs`` (a matching tree of specs).  Used after restore_checkpoint to
    land host arrays in a NEW device layout — the checkpoint format is
    layout-free, so a job can come back on a different mesh shape."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


class DeadlineBatcher:
    """Admission batching with a latency deadline.

    ``add`` enqueues a request; ``poll`` returns ``None`` while the batch is
    neither full nor expired, otherwise ``(requests, n_real)`` where
    ``requests`` always has exactly ``batch_size`` entries (short batches
    are padded by repeating the last real request, so the jitted serving
    step sees one static shape) and ``n_real`` counts the genuine ones.
    The deadline clock starts at the OLDEST pending request, so a trickle
    of traffic is released within ``deadline_s`` of its first arrival.

    A request may carry its own (tighter) admission deadline, two ways:

    * ``add(req, deadline_s=...)`` — a relative admission deadline, frozen
      at add time: release once the request has waited that long.
    * ``add(req, deadline_abs=...)`` — an absolute COMPLETION deadline
      (clock frame). The admission deadline is derived lazily, at every
      ``next_expiry``/``poll``, as ``deadline_abs - headroom()`` where
      ``headroom`` is the constructor-supplied callable (e.g. the serving
      engine's live batch-service-time EMA). Deriving at poll time — not
      at add time — is what keeps queued requests honest when the service
      estimate RISES while they wait: a frozen admission deadline would
      release them too late to execute before completion is due.

    The batch releases as soon as ANY pending request is past its
    (tightest) admission deadline, so a latency-critical request is never
    held behind the global window. ``next_expiry`` returns the CURRENT
    clock when a full batch is already pending: a caller sleeping until
    ``next_expiry()`` must wake immediately, since ``poll`` would release
    right now (sleeping through a ready full batch was a real bug).

    All queue operations take an internal lock, so producers (``add``) and
    a consumer loop (``next_expiry``/``poll``/``flush``) may live on
    different threads — the async serving engine's contract.
    """

    def __init__(self, batch_size: int, deadline_s: float,
                 clock: Callable[[], float] = time.monotonic,
                 headroom: Optional[Callable[[], float]] = None):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.batch_size = int(batch_size)
        self.deadline_s = float(deadline_s)
        self.clock = clock
        self.headroom = headroom or (lambda: 0.0)
        self._lock = threading.Lock()
        # (arrival_ts, admission_deadline_s|None, deadline_abs|None, req)
        self._pending: deque = deque()

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    def add(self, request: Any, deadline_s: Optional[float] = None,
            deadline_abs: Optional[float] = None) -> None:
        entry = (self.clock(), deadline_s, deadline_abs, request)
        with self._lock:
            self._pending.append(entry)

    def _entry_expiry(self, ts: float, d: Optional[float],
                      d_abs: Optional[float], headroom: float) -> float:
        """Absolute admission deadline of one entry: the global window,
        tightened by a frozen relative deadline and/or a live-derived
        absolute one. Clamped to the arrival stamp so a request already
        past ``deadline_abs - headroom`` releases immediately instead of
        producing an expiry in the past."""
        expiry = ts + self.deadline_s
        if d is not None:
            expiry = min(expiry, ts + d)
        if d_abs is not None:
            expiry = min(expiry, max(ts, d_abs - headroom))
        return expiry

    def next_expiry(self) -> Optional[float]:
        """Earliest absolute time at which ``poll`` will release a batch
        (None when the queue is empty). A ready FULL batch expires NOW —
        the caller's poll loop must not sleep through it."""
        with self._lock:
            return self._next_expiry_locked()

    def _next_expiry_locked(self) -> Optional[float]:
        if not self._pending:
            return None
        if len(self._pending) >= self.batch_size:
            return self.clock()
        headroom = self.headroom()
        return min(self._entry_expiry(ts, d, d_abs, headroom)
                   for ts, d, d_abs, _ in self._pending)

    def poll(self) -> Optional[Tuple[List[Any], int]]:
        with self._lock:
            if not self._pending:
                return None
            if len(self._pending) >= self.batch_size:
                reqs = [self._pending.popleft()[3]
                        for _ in range(self.batch_size)]
                return reqs, self.batch_size
            if self.clock() < self._next_expiry_locked():
                return None
            return self._flush_locked()

    def flush(self) -> Optional[Tuple[List[Any], int]]:
        """Release the oldest pending batch immediately (padded), deadline
        or not. At most ``batch_size`` real requests per call — the padded
        static-shape contract holds even when more are pending; call in a
        loop (or ``poll`` first) to drain completely."""
        with self._lock:
            return self._flush_locked()

    def _flush_locked(self) -> Optional[Tuple[List[Any], int]]:
        if not self._pending:
            return None
        take = min(len(self._pending), self.batch_size)
        reqs = [self._pending.popleft()[3] for _ in range(take)]
        n_real = len(reqs)
        reqs = reqs + [reqs[-1]] * (self.batch_size - n_real)
        return reqs, n_real
