"""Fault-tolerance and elastic-serving primitives.

* :func:`simulate_failure` — deterministic in-process "kill" for testing the
  checkpoint/restart contract (crash at step k, restart, land bitwise-equal
  with an uninterrupted run — tests/test_train_ckpt_fault.py).
* :func:`reshard` — place a restored (host) state tree onto a fresh mesh
  layout: elastic restart onto a different device topology.
* :class:`DeadlineBatcher` — the serving-side admission queue: release a
  batch when it is FULL or when the oldest request has waited past the
  deadline (padded to the compiled batch shape so one program serves both).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, List, Optional, Tuple

import jax
from jax.sharding import NamedSharding


class SimulatedFailure(RuntimeError):
    """Raised by the failure guard to emulate a worker being killed."""


def simulate_failure(run: Callable[[Callable[[int], None]], Any],
                     fail_at_step: int) -> bool:
    """Run ``run(guard)`` where ``guard(step)`` kills the run the first time
    ``step == fail_at_step``.  Returns True when the failure fired (the run
    died mid-flight), False when the run finished before reaching the step.
    """
    fired = [False]

    def guard(step: int) -> None:
        if step == fail_at_step and not fired[0]:
            fired[0] = True
            raise SimulatedFailure(f"simulated failure at step {step}")

    try:
        run(guard)
    except SimulatedFailure:
        return True
    return fired[0]


def reshard(tree: Any, specs: Any, mesh) -> Any:
    """Place every leaf of ``tree`` on ``mesh`` with its PartitionSpec from
    ``specs`` (a matching tree of specs).  Used after restore_checkpoint to
    land host arrays in a NEW device layout — the checkpoint format is
    layout-free, so a job can come back on a different mesh shape."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


class DeadlineBatcher:
    """Admission batching with a latency deadline.

    ``add`` enqueues a request; ``poll`` returns ``None`` while the batch is
    neither full nor expired, otherwise ``(requests, n_real)`` where
    ``requests`` always has exactly ``batch_size`` entries (short batches
    are padded by repeating the last real request, so the jitted serving
    step sees one static shape) and ``n_real`` counts the genuine ones.
    The deadline clock starts at the OLDEST pending request, so a trickle
    of traffic is released within ``deadline_s`` of its first arrival.

    A request may carry its own (tighter) admission deadline via
    ``add(req, deadline_s=...)``: the pending batch is released as soon as
    ANY pending request has waited past ``min(deadline_s, its own)`` — the
    serving engine uses this so a latency-critical request is never held
    behind the global admission window.
    """

    def __init__(self, batch_size: int, deadline_s: float,
                 clock: Callable[[], float] = time.monotonic):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.batch_size = int(batch_size)
        self.deadline_s = float(deadline_s)
        self.clock = clock
        self._pending: deque = deque()   # (arrival_ts, deadline_s|None, req)

    def __len__(self) -> int:
        return len(self._pending)

    def add(self, request: Any, deadline_s: Optional[float] = None) -> None:
        self._pending.append((self.clock(), deadline_s, request))

    def next_expiry(self) -> Optional[float]:
        """Earliest absolute time at which ``poll`` will release a partial
        batch (None when the queue is empty)."""
        if not self._pending:
            return None
        return min(ts + (self.deadline_s if d is None
                         else min(self.deadline_s, d))
                   for ts, d, _ in self._pending)

    def poll(self) -> Optional[Tuple[List[Any], int]]:
        if not self._pending:
            return None
        if len(self._pending) >= self.batch_size:
            reqs = [self._pending.popleft()[2]
                    for _ in range(self.batch_size)]
            return reqs, self.batch_size
        if self.clock() < self.next_expiry():
            return None
        return self.flush()

    def flush(self) -> Optional[Tuple[List[Any], int]]:
        """Release the oldest pending batch immediately (padded), deadline
        or not. At most ``batch_size`` real requests per call — the padded
        static-shape contract holds even when more are pending; call in a
        loop (or ``poll`` first) to drain completely."""
        if not self._pending:
            return None
        take = min(len(self._pending), self.batch_size)
        reqs = [self._pending.popleft()[2] for _ in range(take)]
        n_real = len(reqs)
        reqs = reqs + [reqs[-1]] * (self.batch_size - n_real)
        return reqs, n_real
