"""Split-K ("flash-decoding") attention over a sequence-sharded KV cache.

§Perf H2: on long-context decode (long_500k: B=1, S=512k) the KV cache
shards its SEQUENCE dim over the ``model`` axis (dist/sharding.py
``lm_cache_specs``).  GSPMD's automatic strategy for the decode attention
einsum then all-gathers K/V per layer — hundreds of MiB per step.  The
flash-decode path keeps K/V resident: every shard computes attention over
its LOCAL keys, and the shards exchange only the (B, H) running max and
denominator plus the (B, H, D) weighted-value partials — a distributed
log-sum-exp combine, i.e. exactly flash-decoding's split-K reduction with
the splits living on different chips.

The launch layer activates it per-cell with :func:`configure`; model code
gates on :func:`enabled` (models/transformer.py decode path).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_NEG = jnp.float32(-1e30)

_mesh = None
_batch_part = None       # PartitionSpec entry for the cache batch dim
_seq_part = None         # PartitionSpec entry for the cache sequence dim


def configure(mesh, batch_part, seq_part) -> None:
    """Bind (or, with ``configure(None, None, None)``, unbind) the split-K
    decode path.  ``batch_part`` / ``seq_part`` are the PartitionSpec
    entries of the cache's batch and sequence dims (lm_cache_specs)."""
    global _mesh, _batch_part, _seq_part
    _mesh = mesh
    _batch_part = batch_part
    _seq_part = seq_part


def enabled() -> bool:
    return _mesh is not None


def _axes_tuple(part) -> Tuple[str, ...]:
    if part is None:
        return ()
    return part if isinstance(part, tuple) else (part,)


def _local_attention(qg, k, v, kv_pos, kv_valid, q_pos, window,
                     *, scale: float, softcap: Optional[float],
                     seq_axes: Tuple[str, ...]):
    """One shard's split-K contribution + cross-shard LSE combine.

    qg:      (B, 1, Hkv, G, Dh)   queries, grouped per KV head
    k, v:    (B, S_loc, Hkv, Dh)  local KV shard
    kv_pos:  (B, S_loc) absolute position per slot (-1 = empty)
    kv_valid:(B, S_loc) slot validity
    q_pos:   (B, 1) query position; window: scalar i32 (<=0 = full causal)
    """
    f32 = jnp.float32
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(f32),
                        k.astype(f32)) * scale            # (B,K,G,1,S)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    causal = kv_pos[:, None, :] <= q_pos[:, :, None]      # (B,1,S)
    dist = q_pos[:, :, None] - kv_pos[:, None, :]
    in_window = jnp.where(window > 0, dist < window, True)
    mask = (causal & in_window & kv_valid[:, None, :])[:, None, None, :, :]
    logits = jnp.where(mask, logits, _NEG)

    m_loc = jnp.max(logits, axis=-1)                      # (B,K,G,1)
    for ax in seq_axes:
        m_loc = jax.lax.pmax(m_loc, ax)
    p = jnp.exp(logits - m_loc[..., None])
    p = jnp.where(mask, p, 0.0)   # guard: all-masked shard would exp(0)=1
    denom = jnp.sum(p, axis=-1)                           # (B,K,G,1)
    num = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(f32))
    if seq_axes:
        denom = jax.lax.psum(denom, seq_axes)
        num = jax.lax.psum(num, seq_axes)
    denom = jnp.maximum(denom, 1e-30)
    # denom: (B,K,G,1) -> broadcast over (B,1,K,G,D)
    out = num / denom.transpose(0, 3, 1, 2)[..., None]
    return out.astype(qg.dtype)


def flash_decode_attention(qg, k, v, kv_pos, kv_valid, q_pos, window,
                           scale: float,
                           attn_softcap: Optional[float] = None):
    """Decode attention with the configured split-K sharding.

    Shapes as in :func:`_local_attention` but GLOBAL; returns
    (B, 1, Hkv, G, Dh).  Runs the kernel under shard_map on the configured
    mesh so each shard only ever touches its local slice of the cache.
    """
    seq_axes = _axes_tuple(_seq_part)
    kernel = functools.partial(_local_attention, scale=float(scale),
                               softcap=attn_softcap, seq_axes=seq_axes)
    if _mesh is None:
        return kernel(qg, k, v, kv_pos, kv_valid, q_pos, window)
    bp, sp = _batch_part, _seq_part
    return jax.shard_map(
        kernel, mesh=_mesh,
        in_specs=(P(bp, None, None, None, None),   # qg
                  P(bp, sp, None, None),           # k
                  P(bp, sp, None, None),           # v
                  P(bp, sp),                       # kv_pos
                  P(bp, sp),                       # kv_valid
                  P(bp, None),                     # q_pos
                  P()),                            # window
        out_specs=P(bp, None, None, None, None),
        check_vma=False,
    )(qg, k, v, kv_pos, kv_valid, q_pos, window)
