"""Manual collectives for shard_map code paths.

``ring_matmul`` is the building block the launch layer uses where GSPMD's
automatic resharding would insert one bulk all-gather: the row-sharded
operand's partial products circulate around the ring one hop per step
(``ppermute``), so every link carries 1/n of the payload per step and
compute can overlap communication on hardware with async collectives.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _neighbor_perm(n: int) -> list:
    return [(j, (j + 1) % n) for j in range(n)]


def ring_all_gather(x_local: jax.Array, axis_name: str) -> jax.Array:
    """All-gather ``x_local`` (r, ...) -> (n*r, ...) in ring order.

    Must run under shard_map with ``axis_name`` bound.  Equivalent to
    ``jax.lax.all_gather(..., tiled=True)`` but lowered as n-1 ppermute
    hops; chunk j of the result is device j's shard, so concatenating along
    axis 0 reconstructs the axis-sharded global array.
    """
    n = jax.lax.axis_size(axis_name)
    if n == 1:
        return x_local

    def hop(buf, _):
        nxt = jax.lax.ppermute(buf, axis_name, _neighbor_perm(n))
        return nxt, nxt

    # after k hops device i holds device (i-k) mod n's chunk
    _, received = jax.lax.scan(hop, x_local, None, length=n - 1)
    chunks = jnp.concatenate([x_local[None], received], axis=0)  # (n, r, ...)
    # chunks[j] = shard of device (i-j) mod n; reorder to source order 0..n-1
    idx = jax.lax.axis_index(axis_name)
    order = jnp.mod(idx - jnp.arange(n), n)
    ordered = jnp.take(chunks, order, axis=0)
    return ordered.reshape((n * x_local.shape[0],) + x_local.shape[1:])


def ring_matmul(x_local: jax.Array, w: jax.Array,
                axis_name: str) -> jax.Array:
    """Row-sharded matmul with ring reconstruction of the full product.

    x_local: (rows/n, K) — the local shard of a row-sharded X;
    w:       (K, N)     — replicated.
    Returns the FULL (rows, N) product on every device: each shard computes
    its local block, then the blocks ride the ring (n-1 ppermute hops, 1/n
    of the output per hop) instead of a monolithic all-gather.
    """
    return ring_all_gather(x_local @ w, axis_name)
