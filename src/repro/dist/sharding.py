"""Parameter / batch / KV-cache PartitionSpec rules for the production meshes.

The two production meshes (launch/mesh.py) are::

    single-pod  {"data": 16, "model": 16}            256 chips
    multi-pod   {"pod": 2, "data": 16, "model": 16}  512 chips

Conventions used throughout:

  * the FSDP ("dp") group is every mesh axis EXCEPT ``model`` — ZeRO-style
    parameter/optimizer sharding and batch sharding both ride on it, so a
    second pod automatically widens the group ("pod","data");
  * the ``model`` axis is tensor parallelism ("tp"): attention heads and
    FFN hidden dims shard over it.

Rules are written for the TRAILING dims of a leaf and matched against its
pytree key path, so one rule covers both a plain leaf (``embed`` -> (V, D))
and its scan-stacked counterpart (``wq`` -> (L, D, Q): the leading layer
axis is padded with ``None``) and even the rank-4 MoE expert weights
((L, E, D, F): E also padded).  Every produced spec is divisibility-checked
against the mesh: a dim that doesn't divide evenly over its assigned axes is
silently left unsharded (replicated) instead of failing to lower — the
contract ``tests/test_dist.py::test_param_spec_rules_cover_lm_tree`` pins.
"""
from __future__ import annotations

import re
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"


# ---------------------------------------------------------------------------
# mesh helpers (duck-typed: anything with .shape mapping + .axis_names works)
# ---------------------------------------------------------------------------

def fsdp_axes(mesh) -> Tuple[str, ...]:
    """The ZeRO/data-parallel axis group: every axis except ``model``.

    On a mesh with only a model axis (or a single custom axis) the full set
    is returned so batch specs always have at least one axis to shard over.
    """
    names = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
    return names or tuple(mesh.axis_names)


def tp_axis(mesh) -> Optional[str]:
    """The tensor-parallel axis, or None when the mesh has no ``model``."""
    return MODEL_AXIS if MODEL_AXIS in tuple(mesh.axis_names) else None


def _group_size(mesh_shape: Dict[str, int], axes) -> int:
    n = 1
    for a in axes:
        n *= int(mesh_shape[a])
    return n


# ---------------------------------------------------------------------------
# rule engine
# ---------------------------------------------------------------------------

class ShardingRules(NamedTuple):
    """An ordered (pattern -> trailing-dims spec) table bound to a mesh shape
    (only the shape dict is captured so abstract/fake meshes work too)."""
    mesh_shape: Dict[str, int]
    rules: Tuple[Tuple[Any, P], ...]


def _compile(mesh, rules) -> ShardingRules:
    return ShardingRules(
        mesh_shape=dict(mesh.shape),
        rules=tuple((re.compile(pat), spec) for pat, spec in rules))


def _fit_spec(spec: P, shape: Tuple[int, ...],
              mesh_shape: Dict[str, int]) -> P:
    """Adapt a trailing-dims spec to a concrete leaf shape: left-pad with
    None for extra leading dims (layer / expert stacking) and drop any
    partition whose axis-group size does not divide the dim."""
    parts = list(tuple(spec))
    if len(parts) > len(shape):
        parts = parts[len(parts) - len(shape):]
    parts = [None] * (len(shape) - len(parts)) + parts
    fitted = []
    for dim, part in zip(shape, parts):
        if part is None:
            fitted.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        if any(a not in mesh_shape for a in axes):
            fitted.append(None)
            continue
        fitted.append(part if dim % _group_size(mesh_shape, axes) == 0
                      else None)
    return P(*fitted)


def specs_from_rules(tree, rules: ShardingRules):
    """Tree of abstract leaves -> tree of PartitionSpecs (same structure).

    Each leaf's key path (``jax.tree_util.keystr``) is matched against the
    rule table; the FIRST matching rule wins, unmatched leaves replicate.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        spec = P()
        for pat, s in rules.rules:
            if pat.search(key):
                spec = s
                break
        out.append(_fit_spec(spec, tuple(leaf.shape), rules.mesh_shape))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# LM rules
# ---------------------------------------------------------------------------

def lm_param_rules(mesh, mode: str = "zero3") -> ShardingRules:
    """Parameter layout for the transformer LM family.

    mode
      * ``zero3``  — fully sharded parameters: contraction dim over the FSDP
        group, heads/hidden over ``model`` (gathered just-in-time per layer).
      * ``zero1``  — parameters replicated over the FSDP group (weight
        gathers disappear from the step); TP sharding kept.  Pair with
        ``lm_opt_rules`` so the optimizer state stays sharded.
      * ``dp_all`` — no TP at all: everything shards its leading dim over
        EVERY mesh axis (pure data parallelism, §Perf H1 iteration 3).
    """
    dp = fsdp_axes(mesh)
    tp = tp_axis(mesh)
    every = tuple(mesh.axis_names)
    if mode == "zero3":
        row, col = dp, tp
    elif mode == "zero1":
        row, col = None, tp
    elif mode == "dp_all":
        row, col = every, None
    else:
        raise ValueError(f"unknown param mode {mode!r}")
    vec = row
    rules = [
        (r"\['embed'\]$", P(row, col)),
        (r"\['head'\]$", P(row, col)),
        (r"\['final_norm'\]$", P(vec)),
        (r"\['ln1'\]$|\['ln2'\]$", P(vec)),
        (r"\['wq'\]$|\['wk'\]$|\['wv'\]$", P(row, col)),
        (r"\['wo'\]$", P(col, row)),
        (r"\['bq'\]$|\['bk'\]$|\['bv'\]$", P(col)),
        (r"\['router'\]$", P(row, None)),
        # one rule serves dense MLP (L, D, F) AND MoE experts (L, E, D, F):
        # trailing-2 dims are (contraction, hidden) in both layouts
        (r"\['w_gate'\]$|\['w_up'\]$", P(row, col)),
        (r"\['w_down'\]$", P(col, row)),
    ]
    return _compile(mesh, rules)


def lm_opt_rules(mesh) -> ShardingRules:
    """AdamW m/v layout: ALWAYS fully sharded (ZeRO-1 semantics) — optimizer
    state is 2x fp32 per param and never needs to be resident unsharded."""
    return lm_param_rules(mesh, mode="zero3")


def lm_batch_spec(mesh) -> P:
    """(B, S) token batches shard rows over the FSDP group."""
    return P(fsdp_axes(mesh), None)


def lm_cache_specs(mesh, batch: int) -> Dict[str, P]:
    """KV-cache stack layout, keyed by ``models.kv_cache.CacheStack`` field.

    k/v are (n_layers, B, S_cache, H_kv, D_head): batch shards over the FSDP
    group when it divides (decode_32k), the cache SEQUENCE dim shards over
    ``model`` (long_500k's B=1 cache is ~16 GiB/layer-stack otherwise — the
    split-K flash-decode path in dist/flash_decode.py consumes exactly this
    layout).  ``pos`` is (B, S_cache) and follows the same two axes.
    """
    dp = fsdp_axes(mesh)
    mesh_shape = dict(mesh.shape)
    bp = dp if (batch > 1 and batch % _group_size(mesh_shape, dp) == 0) \
        else None
    sp = tp_axis(mesh)
    return {"k": P(None, bp, sp, None, None),
            "v": P(None, bp, sp, None, None),
            "pos": P(bp, sp)}


# ---------------------------------------------------------------------------
# Retrieval corpus rules
# ---------------------------------------------------------------------------

def corpus_axes(mesh) -> Tuple[str, ...]:
    """The axis group the corpus token index shards its doc dim over: EVERY
    mesh axis. The (C, L, M) index is the big object in late-interaction
    serving (C ~ 10^7 docs x L x M fp32 dwarfs queries and scorecards), so
    it takes the whole machine; queries replicate across it and the only
    cross-shard traffic is K-sized scorecards (retrieval/service.py)."""
    return tuple(mesh.axis_names)


def corpus_specs(mesh) -> Dict[str, P]:
    """PartitionSpecs for the corpus-resident arrays, keyed by field name of
    ``repro.retrieval.sharded.ShardedCorpus``: doc dim over every axis,
    token/embedding dims replicated."""
    every = corpus_axes(mesh)
    return {"embs": P(every, None, None),       # (C, L, M)
            "mask": P(every, None),             # (C, L)
            "pooled": P(every, None),           # (C, M) two-phase summaries
            # quantized-corpus sidecars (kernels.quant.QuantTokens): the
            # int8 payload shards like "embs", the per-row scale / centroid
            # id planes like "mask" — same doc dim, same contiguous blocks
            "scales": P(every, None),           # (C, L) bf16
            "codes": P(every, None),            # (C, L) i32
            # the residual codebook is Kc x M and read by every shard:
            "codebook": P(None, None),          # (Kc, M)
            # centroid-router state is tiny (Kc x M / Kc x S) and every
            # shard routes every query, so it replicates:
            "centroids": P(None, None),         # (Kc, M)
            "shard_mass": P(None, None)}        # (Kc, n_shards)


# ---------------------------------------------------------------------------
# GNN / RecSys rules
# ---------------------------------------------------------------------------

def gnn_param_rules(mesh) -> ShardingRules:
    """PNA weights: (d_in, d_out) matrices over (fsdp, model) where they
    divide (d_hidden=75 doesn't on the production meshes -> replicated,
    which is also the pna_loss_sharded shard_map contract: params in)."""
    dp = fsdp_axes(mesh)
    tp = tp_axis(mesh)
    rules = [
        (r"\['encode'\]$|\['decode'\]$", P(dp, tp)),
        (r"\['w_msg_src'\]$|\['w_msg_dst'\]$|\['w_update'\]$", P(dp, tp)),
    ]
    return _compile(mesh, rules)


def recsys_param_rules(mesh) -> ShardingRules:
    """RecSys layout: the fused embedding tables are the whole model — their
    rows shard over ('model' [+ 'pod']) (models/recsys.py contract; rows are
    padded to 4096 so they always divide); the small dense interaction
    weights replicate."""
    names = tuple(mesh.axis_names)
    rows = tuple(a for a in ("pod", MODEL_AXIS) if a in names) or None
    rules = [
        (r"\['table'\]$|\['linear'\]$", P(rows, None)),
        (r"\['item_table'\]$|\['pos_table'\]$", P(rows, None)),
    ]
    return _compile(mesh, rules)
