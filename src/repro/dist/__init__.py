"""repro.dist — the distributed-execution layer.

Module map (each module's docstring carries the details):

  sharding      PartitionSpec rule engine: parameter/optimizer/batch/cache
                layouts for every model family on the production meshes
                ({"data":16,"model":16} and {"pod":2,"data":16,"model":16}).
  act_sharding  global activation-constraint context: models annotate
                logical axes ("dp"/"tp"), the launch layer binds them per
                cell (set_mesh/set_axes/set_extra); no-op when unbound.
  collectives   manual shard_map collectives (ring_matmul / ring all-gather).
  fault         failure injection, elastic reshard-on-restore, and the
                deadline admission batcher for serving.
  flash_decode  split-K decode attention over the sequence-sharded KV cache
                (§Perf H2), toggled per-cell via configure().
"""
from repro.dist import (act_sharding, collectives, fault,  # noqa: F401
                        flash_decode, sharding)
