"""Configurable decoder LM covering the 5 assigned transformer archs.

Layers are STACKED and executed with ``jax.lax.scan`` (MaxText-style): one
layer gets lowered/compiled regardless of depth — essential for 56-layer
dry-runs. Architectural axes, all driven by ``LMConfig``:

  * GQA with arbitrary (n_heads, n_kv_heads)        — all archs
  * sliding-window attention on every layer          — mixtral (w=4096)
  * local/global alternating layers + softcaps       — gemma2
  * QKV bias                                         — qwen2.5
  * routed MoE FFN (capacity dispatch)               — mixtral, moonshot

Layer grouping: archs with uniform layers use one stack ("all"); gemma2 uses
one stack of (local, global) layer PAIRS so the scan body stays homogeneous
while local layers keep ring caches of size=window and global layers keep
full-length caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from repro.models.scan_util import scan as _scan
from repro.dist.act_sharding import constrain as _cst

from repro.configs.base import LMConfig
from repro.models import kv_cache as KV
from repro.models.layers import (attention, init_attention, init_mlp, mlp,
                                 rms_norm, softcap, dense_init, embed_init)
from repro.models.moe import init_moe, moe_ffn

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key: jax.Array, cfg: LMConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.d_head, cfg.qkv_bias,
                               dtype),
    }
    if cfg.moe:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe_d_ff or cfg.d_ff,
                            cfg.n_experts, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def _stack_layers(key: jax.Array, cfg: LMConfig, n: int, dtype) -> Params:
    """Init n layers and stack each leaf along axis 0 (scan-ready)."""
    keys = jax.random.split(key, n)
    layers = [_init_layer(k, cfg, dtype) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)


def init_lm(key: jax.Array, cfg: LMConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    params: Params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "head": dense_init(ks[1], cfg.d_model, cfg.vocab, dtype),
    }
    if cfg.local_global_alternating:
        assert cfg.n_layers % 2 == 0
        n_pairs = cfg.n_layers // 2
        params["local"] = _stack_layers(ks[2], cfg, n_pairs, dtype)
        params["global"] = _stack_layers(ks[3], cfg, n_pairs, dtype)
    else:
        params["all"] = _stack_layers(ks[2], cfg, cfg.n_layers, dtype)
    return params


def cache_spec(cfg: LMConfig, max_seq: int) -> Dict[str, Tuple[int, int]]:
    """stack name -> (n_layers_in_stack, s_cache)."""
    w = cfg.sliding_window or 0
    if cfg.local_global_alternating:
        n_pairs = cfg.n_layers // 2
        return {"local": (n_pairs, min(w, max_seq) if w else max_seq),
                "global": (n_pairs, max_seq)}
    s = min(w, max_seq) if w else max_seq
    return {"all": (cfg.n_layers, s)}


def init_cache(cfg: LMConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> KV.Cache:
    return {name: KV.init_stack(n, batch, s, cfg.n_kv_heads, cfg.d_head,
                                dtype)
            for name, (n, s) in cache_spec(cfg, max_seq).items()}


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _layer(p: Params, x: jax.Array, positions: jax.Array, cfg: LMConfig,
           window: jax.Array,
           kv_override=None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pre-norm block. Returns (x_out, k_seq, v_seq) — K/V exposed so prefill
    can populate caches without recomputation."""
    x = _cst(x, "dp", None, None)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    # compute K/V explicitly (shared with cache population)
    B, S, _ = h.shape
    k_seq = h @ p["attn"]["wk"]
    v_seq = h @ p["attn"]["wv"]
    if "bk" in p["attn"]:
        k_seq = k_seq + p["attn"]["bk"]
        v_seq = v_seq + p["attn"]["bv"]
    k_seq = k_seq.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v_seq = v_seq.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    from repro.models.layers import apply_rope
    k_rope = apply_rope(k_seq, positions, cfg.rope_theta)

    if kv_override is None:
        kv = (k_rope, v_seq, positions, jnp.ones(positions.shape, jnp.bool_))
    else:
        kv = kv_override
    attn_out = attention(
        p["attn"], h, positions, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
        rope_theta=cfg.rope_theta, window=window,
        attn_softcap=cfg.attn_softcap, kv_override=kv,
        q_chunk=cfg.attn_q_chunk)
    x = x + attn_out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe:
        ff = moe_ffn(p["moe"], h2, top_k=cfg.experts_top_k, act=cfg.act,
                     capacity_factor=cfg.moe_capacity_factor)
    else:
        ff = mlp(p["mlp"], h2, act=cfg.act)
    return x + ff, k_rope, v_seq


def _window_scalar(cfg: LMConfig, local: bool) -> jax.Array:
    if local and cfg.sliding_window:
        return jnp.int32(cfg.sliding_window)
    if (not cfg.local_global_alternating) and cfg.sliding_window:
        return jnp.int32(cfg.sliding_window)
    return jnp.int32(0)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def forward_train(params: Params, cfg: LMConfig, tokens: jax.Array,
                  *, remat: bool = True) -> jax.Array:
    """tokens (B, S) -> logits (B, S, V). Full causal (+window) attention."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def make_body(window_local, window_global=None, paired=False):
        def body(x, layer_p):
            if paired:
                lp, gp = layer_p
                x, _, _ = _layer(lp, x, positions, cfg, window_local)
                x, _, _ = _layer(gp, x, positions, cfg,
                                 jnp.int32(0) if window_global is None
                                 else window_global)
            else:
                x, _, _ = _layer(layer_p, x, positions, cfg, window_local)
            return x, None
        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        return body

    if cfg.local_global_alternating:
        body = make_body(_window_scalar(cfg, True), jnp.int32(0), paired=True)
        x, _ = _scan(body, x, (params["local"], params["global"]))
    else:
        body = make_body(_window_scalar(cfg, True))
        x, _ = _scan(body, x, params["all"])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"]
    return softcap(logits, cfg.logit_softcap)


def forward_hidden(params: Params, cfg: LMConfig, tokens: jax.Array,
                   *, remat: bool = False) -> jax.Array:
    """tokens (B, S) -> final hidden states (B, S, D) (no LM head) — the
    trunk for both LM training (head applied chunked in train_step) and the
    ColBERT late-interaction encoder. remat=True checkpoints each layer
    (nothing saveable): backward recomputes one layer at a time, so peak
    activation memory stays one layer deep regardless of depth."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, layer_p):
        if cfg.local_global_alternating:
            lp, gp = layer_p
            x, _, _ = _layer(lp, x, positions, cfg, _window_scalar(cfg, True))
            x, _, _ = _layer(gp, x, positions, cfg, jnp.int32(0))
        else:
            x, _, _ = _layer(layer_p, x, positions, cfg,
                             _window_scalar(cfg, True))
        return x, None

    xs = ((params["local"], params["global"])
          if cfg.local_global_alternating else params["all"])
    if remat:
        # Nested (sqrt-L) remat: a flat checkpointed scan still stacks one
        # x-carry residual PER LAYER (56 x ~100 MB/chip on mixtral train);
        # a two-level scan-of-scans saves only f outer + L/f inner carries
        # (~15 instead of 56) for one extra forward recompute.
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
        n_stack = jax.tree.leaves(xs)[0].shape[0]
        f = max((d for d in range(1, n_stack + 1)
                 if n_stack % d == 0 and d * d <= n_stack), default=1)
        if f > 1:
            outer_xs = jax.tree.map(
                lambda a: a.reshape(f, n_stack // f, *a.shape[1:]), xs)

            def outer_body(x, block_params):
                x, _ = _scan(body, x, block_params)
                return x, None

            outer = jax.checkpoint(
                outer_body, policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = _scan(outer, x, outer_xs)
        else:
            x, _ = _scan(body, x, xs)
    else:
        x, _ = _scan(body, x, xs)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def forward_prefill(params: Params, cfg: LMConfig, tokens: jax.Array,
                    max_seq: int, cache_dtype=jnp.bfloat16,
                    ) -> Tuple[jax.Array, KV.Cache]:
    """Prefill: returns (last-token logits (B, V), populated cache)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    spec = cache_spec(cfg, max_seq)

    def scan_stack(x, stack_params, window, s_cache):
        def body(x, layer_p):
            x, k_seq, v_seq = _layer(layer_p, x, positions, cfg, window)
            k, v, pos = KV.prefill_write(k_seq.astype(cache_dtype),
                                         v_seq.astype(cache_dtype),
                                         positions, s_cache)
            return x, (k, v, pos)
        return _scan(body, x, stack_params)

    cache: KV.Cache = {}
    if cfg.local_global_alternating:
        def body(x, layer_p):
            lp, gp = layer_p
            x, kl, vl = _layer(lp, x, positions, cfg, _window_scalar(cfg, True))
            x, kg, vg = _layer(gp, x, positions, cfg, jnp.int32(0))
            wl = KV.prefill_write(kl.astype(cache_dtype),
                                  vl.astype(cache_dtype), positions,
                                  spec["local"][1])
            wg = KV.prefill_write(kg.astype(cache_dtype),
                                  vg.astype(cache_dtype), positions,
                                  spec["global"][1])
            return x, (wl, wg)
        x, (wl, wg) = _scan(body, x, (params["local"], params["global"]))
        cache["local"] = KV.CacheStack(k=wl[0], v=wl[1], pos=wl[2][0])
        cache["global"] = KV.CacheStack(k=wg[0], v=wg[1], pos=wg[2][0])
    else:
        x, (k, v, pos) = scan_stack(x, params["all"],
                                    _window_scalar(cfg, True),
                                    spec["all"][1])
        cache["all"] = KV.CacheStack(k=k, v=v, pos=pos[0])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["head"]
    return softcap(logits, cfg.logit_softcap), cache


def forward_decode(params: Params, cfg: LMConfig, token: jax.Array,
                   position: jax.Array, cache: KV.Cache,
                   ) -> Tuple[jax.Array, KV.Cache]:
    """One decode step. token (B,) i32 at scalar `position`; returns
    (logits (B, V), updated cache)."""
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0)[:, None, :]   # (B, 1, D)
    positions = jnp.broadcast_to(position.astype(jnp.int32), (B, 1))

    def step_layer(x, layer_p, stack: KV.CacheStack, window, layer_slot):
        """One layer against one cache stack layer (functional update)."""
        k_l, v_l = stack.k[layer_slot], stack.v[layer_slot]
        h = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
        k_new = h @ layer_p["attn"]["wk"]
        v_new = h @ layer_p["attn"]["wv"]
        if "bk" in layer_p["attn"]:
            k_new = k_new + layer_p["attn"]["bk"]
            v_new = v_new + layer_p["attn"]["bv"]
        k_new = k_new.reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
        v_new = v_new.reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
        from repro.models.layers import apply_rope
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
        k_upd, v_upd, pos_upd = KV.write_token(
            k_l, v_l, stack.pos, k_new.astype(k_l.dtype),
            v_new.astype(v_l.dtype), position)
        from repro.dist.act_sharding import constrain_named
        k_upd = constrain_named(k_upd, "cache_kv")
        v_upd = constrain_named(v_upd, "cache_kv")
        pos_upd = constrain_named(pos_upd, "cache_pos")
        kv_valid = pos_upd >= 0
        from repro.dist import flash_decode as FD
        if FD.enabled():
            # §Perf H2: explicit split-K attention over the seq-sharded
            # cache (GSPMD would all-gather K/V per layer otherwise).
            q = h @ layer_p["attn"]["wq"]
            if "bq" in layer_p["attn"]:
                q = q + layer_p["attn"]["bq"]
            q = q.reshape(B, 1, cfg.n_heads, cfg.d_head)
            q = apply_rope(q, positions, cfg.rope_theta)
            groups = cfg.n_heads // cfg.n_kv_heads
            qg = q.reshape(B, 1, cfg.n_kv_heads, groups, cfg.d_head)
            o = FD.flash_decode_attention(
                qg, k_upd, v_upd, pos_upd, kv_valid, positions, window,
                1.0 / float(cfg.d_head) ** 0.5, cfg.attn_softcap)
            attn_out = (o.reshape(B, 1, cfg.n_heads * cfg.d_head)
                        .astype(x.dtype) @ layer_p["attn"]["wo"])
        else:
            attn_out = attention(
                layer_p["attn"], h, positions, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                rope_theta=cfg.rope_theta, window=window,
                attn_softcap=cfg.attn_softcap,
                kv_override=(k_upd, v_upd, pos_upd, kv_valid))
        x = x + attn_out
        h2 = rms_norm(x, layer_p["ln2"], cfg.norm_eps)
        if cfg.moe:
            # decode: never drop tokens (worst-case capacity is cheap at S=1)
            ff = moe_ffn(layer_p["moe"], h2, top_k=cfg.experts_top_k,
                         act=cfg.act, no_drop=True)
        else:
            ff = mlp(layer_p["mlp"], h2, act=cfg.act)
        return x + ff, (k_upd, v_upd, pos_upd)

    # The full cache stacks ride in the scan CARRY and are updated in place
    # with dynamic_update_slice on the (unsharded) layer axis: one buffer per
    # stack lives for the whole step and XLA aliases it with the donated
    # input — passing slices through scan xs/ys doubled peak memory.
    new_cache: KV.Cache = {}
    if cfg.local_global_alternating:
        def body(carry, xs):
            x, kl_buf, vl_buf, pl, kg_buf, vg_buf, pg, idx = carry
            lp, gp = xs
            stack_l = KV.CacheStack(
                k=jax.lax.dynamic_index_in_dim(kl_buf, idx, 0, keepdims=True),
                v=jax.lax.dynamic_index_in_dim(vl_buf, idx, 0, keepdims=True),
                pos=pl)
            x, (k1, v1, p1) = step_layer(x, lp, stack_l,
                                         _window_scalar(cfg, True), 0)
            kl_buf = jax.lax.dynamic_update_index_in_dim(kl_buf, k1, idx, 0)
            vl_buf = jax.lax.dynamic_update_index_in_dim(vl_buf, v1, idx, 0)
            stack_g = KV.CacheStack(
                k=jax.lax.dynamic_index_in_dim(kg_buf, idx, 0, keepdims=True),
                v=jax.lax.dynamic_index_in_dim(vg_buf, idx, 0, keepdims=True),
                pos=pg)
            x, (k2, v2, p2) = step_layer(x, gp, stack_g, jnp.int32(0), 0)
            kg_buf = jax.lax.dynamic_update_index_in_dim(kg_buf, k2, idx, 0)
            vg_buf = jax.lax.dynamic_update_index_in_dim(vg_buf, v2, idx, 0)
            return (x, kl_buf, vl_buf, p1, kg_buf, vg_buf, p2, idx + 1), None

        carry0 = (x, cache["local"].k, cache["local"].v, cache["local"].pos,
                  cache["global"].k, cache["global"].v, cache["global"].pos,
                  jnp.int32(0))
        (x, kl, vl, pl, kg, vg, pg, _), _ = _scan(
            body, carry0, (params["local"], params["global"]))
        new_cache["local"] = KV.CacheStack(k=kl, v=vl, pos=pl)
        new_cache["global"] = KV.CacheStack(k=kg, v=vg, pos=pg)
    else:
        def body(carry, lp):
            x, k_buf, v_buf, pos, idx = carry
            stack = KV.CacheStack(
                k=jax.lax.dynamic_index_in_dim(k_buf, idx, 0, keepdims=True),
                v=jax.lax.dynamic_index_in_dim(v_buf, idx, 0, keepdims=True),
                pos=pos)
            x, (k, v, p) = step_layer(x, lp, stack,
                                      _window_scalar(cfg, True), 0)
            k_buf = jax.lax.dynamic_update_index_in_dim(k_buf, k, idx, 0)
            v_buf = jax.lax.dynamic_update_index_in_dim(v_buf, v, idx, 0)
            return (x, k_buf, v_buf, p, idx + 1), None

        carry0 = (x, cache["all"].k, cache["all"].v, cache["all"].pos,
                  jnp.int32(0))
        (x, k, v, p, _), _ = _scan(body, carry0, params["all"])
        new_cache["all"] = KV.CacheStack(k=k, v=v, pos=p)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["head"]
    return softcap(logits, cfg.logit_softcap), new_cache
