"""Late-interaction (ColBERT-style) encoder head over an LM backbone.

This is the paper-integration point for the assigned LM archs: any of the 5
transformer backbones + a linear projection to li_dim (=128, matching
ColBERTv2 / Jina-ColBERT-v2 / Granite Vision) + L2 normalization produces
the token embeddings that the Col-Bandit reranker consumes.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models.layers import dense_init
from repro.models.transformer import forward_hidden

Params = Dict[str, Any]


def init_li_head(key: jax.Array, cfg: LMConfig, dtype=jnp.float32) -> Params:
    return {"proj": dense_init(key, cfg.d_model, cfg.li_dim, dtype)}


def encode_tokens(lm_params: Params, head: Params, cfg: LMConfig,
                  tokens: jax.Array, mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) + validity mask -> (B, S, li_dim) L2-normalized token
    embeddings (masked positions are zeroed)."""
    hidden = forward_hidden(lm_params, cfg, tokens)      # (B, S, D)
    emb = hidden @ head["proj"]                          # (B, S, li_dim)
    emb = emb / jnp.maximum(
        jnp.linalg.norm(emb.astype(jnp.float32), axis=-1, keepdims=True),
        1e-9).astype(emb.dtype)
    return jnp.where(mask[:, :, None], emb, 0.0), mask
