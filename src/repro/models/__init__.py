"""Model zoo: transformer LMs, ColBERT head, PNA GNN, recsys models."""
