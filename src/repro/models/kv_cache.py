"""KV caches for decode: full-length and ring-buffer (sliding-window).

A cache stack holds (k, v, pos) for a group of layers with identical shape:
  k, v: (n_layers_in_stack, B, S_cache, H_kv, D_head)
  pos:  (B, S_cache) int32 — absolute position held in each slot (-1 empty)

Sliding-window layers use S_cache = window with ring addressing
slot = position % window; full-attention layers use S_cache = max_seq.
Positions are stored explicitly so prefill layouts, ring wrap-around and
validity all fall out of one mask: valid = pos >= 0 (and the window/causal
mask handles recency).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CacheStack(NamedTuple):
    k: jax.Array     # (n, B, S_cache, Hkv, Dh)
    v: jax.Array
    pos: jax.Array   # (B, S_cache) i32, shared across the stack's layers


Cache = Dict[str, CacheStack]


def init_stack(n_layers: int, batch: int, s_cache: int, n_kv_heads: int,
               d_head: int, dtype=jnp.bfloat16) -> CacheStack:
    return CacheStack(
        k=jnp.zeros((n_layers, batch, s_cache, n_kv_heads, d_head), dtype),
        v=jnp.zeros((n_layers, batch, s_cache, n_kv_heads, d_head), dtype),
        pos=jnp.full((batch, s_cache), -1, jnp.int32),
    )


def decode_slot(position: jax.Array, s_cache: int) -> jax.Array:
    """Ring slot for an absolute position (identity when cache is full-seq)."""
    return jnp.mod(position, s_cache)


def write_token(stack_k: jax.Array, stack_v: jax.Array, pos_arr: jax.Array,
                k_new: jax.Array, v_new: jax.Array,
                position: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Write one decode token into a single layer's (B, S, H, D) cache slices.
    k_new/v_new: (B, 1, H, D); position: scalar i32 (same for the batch).

    Implemented as a masked SELECT over the slot axis rather than
    dynamic_update_slice: a dynamic index into a sharded dimension forces
    GSPMD into involuntary full rematerialization (it replicates the whole
    cache — observed 100+ GiB/chip on long_500k), while the elementwise
    select keeps every shard local. XLA aliases the output with the donated
    input buffer, so no extra copy materializes."""
    s_cache = stack_k.shape[1]
    slot = decode_slot(position, s_cache)
    slot_mask = jnp.arange(s_cache) == slot                  # (S,)
    k = jnp.where(slot_mask[None, :, None, None], k_new.astype(stack_k.dtype),
                  stack_k)
    v = jnp.where(slot_mask[None, :, None, None], v_new.astype(stack_v.dtype),
                  stack_v)
    pos = jnp.where(slot_mask[None, :], position.astype(jnp.int32), pos_arr)
    return k, v, pos


def prefill_write(k_seq: jax.Array, v_seq: jax.Array, positions: jax.Array,
                  s_cache: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Turn per-layer prefill K/V (B, S, H, D) into a cache of size s_cache.

    Full cache (s_cache >= S): pad to the right.
    Ring cache  (s_cache <  S): keep the last s_cache tokens at their ring
    slots (older tokens are outside the window by construction).
    """
    B, S, H, D = k_seq.shape
    if s_cache >= S:
        pad = s_cache - S
        k = jnp.pad(k_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.pad(positions.astype(jnp.int32), ((0, 0), (0, pad)),
                      constant_values=-1)
        return k, v, pos
    k_tail = k_seq[:, S - s_cache:]
    v_tail = v_seq[:, S - s_cache:]
    p_tail = positions[:, S - s_cache:].astype(jnp.int32)
    slots = jnp.mod(p_tail[0], s_cache)                      # (s_cache,)
    k = jnp.zeros((B, s_cache, H, D), k_seq.dtype).at[:, slots].set(k_tail)
    v = jnp.zeros((B, s_cache, H, D), v_seq.dtype).at[:, slots].set(v_tail)
    pos = jnp.full((B, s_cache), -1, jnp.int32).at[:, slots].set(p_tail)
    return k, v, pos
