"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

Message passing is built on ``jax.ops.segment_sum`` / ``segment_max`` over an
edge-index (src -> dst) scatter — JAX has no sparse SpMM beyond BCOO, so this
IS the system's message-passing engine (per assignment note). Four
aggregators (mean/max/min/std) x three degree scalers (identity,
amplification, attenuation) per the assigned config.

Also provides:
  * block-diagonal batching for small molecule graphs,
  * a real fanout neighbor sampler (GraphSAGE-style) for minibatch_lg,
    with static output shapes (sampling WITH replacement, standard for
    TPU-shaped pipelines).

Col-Bandit applicability: none (DESIGN.md §Arch-applicability) — PNA has no
sum-decomposable per-candidate score to progressively reveal; it runs at
full fidelity.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from repro.models.scan_util import scan as _scan
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]
_BIG = 1e30


class GraphBatch(NamedTuple):
    feats: jax.Array      # (N, d_feat)
    senders: jax.Array    # (E,) i32
    receivers: jax.Array  # (E,) i32
    edge_mask: jax.Array  # (E,) bool
    node_mask: jax.Array  # (N,) bool
    labels: jax.Array     # (N,) i32


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def init_pna(key: jax.Array, cfg: GNNConfig, d_feat: int,
             dtype=jnp.float32) -> Params:
    n_agg = len(cfg.aggregators) * len(cfg.scalers)
    ks = jax.random.split(key, 2 + 3 * cfg.n_layers)
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "w_msg_src": dense_init(ks[2 + 3 * i], cfg.d_hidden, cfg.d_hidden, dtype),
            "w_msg_dst": dense_init(ks[3 + 3 * i], cfg.d_hidden, cfg.d_hidden, dtype),
            "w_update": dense_init(ks[4 + 3 * i],
                                   cfg.d_hidden * (1 + n_agg), cfg.d_hidden,
                                   dtype),
        })
    return {
        "encode": dense_init(ks[0], d_feat, cfg.d_hidden, dtype),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
        "decode": dense_init(ks[1], cfg.d_hidden, cfg.n_classes, dtype),
    }


def _aggregate(msgs: jax.Array, receivers: jax.Array, edge_mask: jax.Array,
               n_nodes: int, aggregators) -> Tuple[jax.Array, jax.Array]:
    """Segment-reduce messages per destination node.
    Returns (concat aggregates (N, n_agg*d), degree (N,))."""
    w = edge_mask.astype(msgs.dtype)[:, None]
    msgs_m = msgs * w
    deg = jax.ops.segment_sum(edge_mask.astype(jnp.float32), receivers,
                              num_segments=n_nodes)
    safe_deg = jnp.maximum(deg, 1.0)[:, None]

    outs = []
    ssum = jax.ops.segment_sum(msgs_m, receivers, num_segments=n_nodes)
    mean = ssum / safe_deg
    for agg in aggregators:
        if agg == "mean":
            outs.append(mean)
        elif agg == "max":
            mx = jax.ops.segment_max(
                jnp.where(edge_mask[:, None], msgs, -_BIG), receivers,
                num_segments=n_nodes)
            outs.append(jnp.where(deg[:, None] > 0, mx, 0.0))
        elif agg == "min":
            mn = -jax.ops.segment_max(
                jnp.where(edge_mask[:, None], -msgs, -_BIG), receivers,
                num_segments=n_nodes)
            outs.append(jnp.where(deg[:, None] > 0, mn, 0.0))
        elif agg == "std":
            sq = jax.ops.segment_sum(msgs_m * msgs_m, receivers,
                                     num_segments=n_nodes)
            var = jnp.maximum(sq / safe_deg - mean * mean, 0.0)
            outs.append(jnp.sqrt(var + 1e-8))
        else:
            raise ValueError(agg)
    return jnp.concatenate(outs, axis=-1), deg


def _scale(agg: jax.Array, deg: jax.Array, scalers, mean_log_deg: float) -> jax.Array:
    """PNA degree scalers applied to the concatenated aggregates."""
    logd = jnp.log(deg + 1.0)[:, None]
    d_inv = mean_log_deg
    outs = []
    for s in scalers:
        if s == "identity":
            outs.append(agg)
        elif s == "amplification":
            outs.append(agg * (logd / d_inv))
        elif s == "attenuation":
            outs.append(agg * (d_inv / jnp.maximum(logd, 1e-3)))
        else:
            raise ValueError(s)
    return jnp.concatenate(outs, axis=-1)


def pna_forward(params: Params, cfg: GNNConfig, batch: GraphBatch,
                *, mean_log_deg: float = 2.0) -> jax.Array:
    """Full PNA forward -> per-node class logits (N, n_classes)."""
    n_nodes = batch.feats.shape[0]
    h = batch.feats @ params["encode"]

    def body(h, layer_p):
        msg = (jnp.take(h, batch.senders, axis=0) @ layer_p["w_msg_src"]
               + jnp.take(h, batch.receivers, axis=0) @ layer_p["w_msg_dst"])
        msg = jax.nn.relu(msg)
        agg, deg = _aggregate(msg, batch.receivers, batch.edge_mask, n_nodes,
                              cfg.aggregators)
        scaled = _scale(agg, deg, cfg.scalers, mean_log_deg)
        upd = jnp.concatenate([h, scaled], axis=-1) @ layer_p["w_update"]
        return h + jax.nn.relu(upd), None

    h, _ = _scan(body, h, params["layers"])
    logits = h @ params["decode"]
    return jnp.where(batch.node_mask[:, None], logits, 0.0)


def pna_loss(params: Params, cfg: GNNConfig, batch: GraphBatch,
             **kw) -> jax.Array:
    logits = pna_forward(params, cfg, batch, **kw)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch.labels[:, None], axis=-1)[:, 0]
    nll = jnp.where(batch.node_mask, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(batch.node_mask), 1)


# ---------------------------------------------------------------------------
# data utilities
# ---------------------------------------------------------------------------

def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                 seed: int = 0) -> GraphBatch:
    rng = np.random.default_rng(seed)
    send = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    recv = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    feats = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return GraphBatch(feats=jnp.asarray(feats), senders=jnp.asarray(send),
                      receivers=jnp.asarray(recv),
                      edge_mask=jnp.ones(n_edges, bool),
                      node_mask=jnp.ones(n_nodes, bool),
                      labels=jnp.asarray(labels))


def batch_molecules(n_graphs: int, nodes_per: int, edges_per: int,
                    d_feat: int, n_classes: int, seed: int = 0) -> GraphBatch:
    """Block-diagonal batching: one big disconnected graph, offsets per mol."""
    gs = [random_graph(nodes_per, edges_per, d_feat, n_classes, seed + i)
          for i in range(n_graphs)]
    feats = jnp.concatenate([g.feats for g in gs])
    send = jnp.concatenate([g.senders + i * nodes_per for i, g in enumerate(gs)])
    recv = jnp.concatenate([g.receivers + i * nodes_per for i, g in enumerate(gs)])
    return GraphBatch(
        feats=feats, senders=send, receivers=recv,
        edge_mask=jnp.ones(send.shape[0], bool),
        node_mask=jnp.ones(feats.shape[0], bool),
        labels=jnp.concatenate([g.labels for g in gs]))


class CSRGraph(NamedTuple):
    indptr: np.ndarray    # (N+1,)
    indices: np.ndarray   # (E,)


def build_csr(n_nodes: int, senders: np.ndarray,
              receivers: np.ndarray) -> CSRGraph:
    order = np.argsort(receivers, kind="stable")
    sorted_recv = receivers[order]
    sorted_send = senders[order]
    counts = np.bincount(sorted_recv, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=sorted_send.astype(np.int32))


def sample_subgraph(csr: CSRGraph, feats: np.ndarray, labels: np.ndarray,
                    seeds: np.ndarray, fanout: Tuple[int, ...],
                    seed: int = 0) -> GraphBatch:
    """GraphSAGE-style fanout sampling with static shapes (with replacement;
    zero-degree nodes get self-loops). Layer l expands frontier by fanout[l].
    Output node order: [seeds, layer1 samples, layer2 samples, ...]."""
    rng = np.random.default_rng(seed)
    frontier = seeds.astype(np.int64)
    all_nodes = [frontier]
    send_list, recv_list = [], []
    offset = 0
    for f in fanout:
        deg = csr.indptr[frontier + 1] - csr.indptr[frontier]
        # sample f neighbors per frontier node (with replacement)
        r = rng.integers(0, np.maximum(deg, 1)[:, None], (frontier.size, f))
        nbr = np.where(deg[:, None] > 0,
                       csr.indices[np.minimum(csr.indptr[frontier][:, None] + r,
                                              len(csr.indices) - 1)],
                       frontier[:, None])   # self-loop for isolated nodes
        new_offset = offset + frontier.size
        dst_local = np.repeat(np.arange(offset, new_offset), f)
        src_local = np.arange(new_offset, new_offset + nbr.size)
        send_list.append(src_local)
        recv_list.append(dst_local)
        frontier = nbr.reshape(-1)
        all_nodes.append(frontier)
        offset = new_offset

    nodes = np.concatenate(all_nodes)
    send = np.concatenate(send_list).astype(np.int32)
    recv = np.concatenate(recv_list).astype(np.int32)
    return GraphBatch(
        feats=jnp.asarray(feats[nodes]),
        senders=jnp.asarray(send), receivers=jnp.asarray(recv),
        edge_mask=jnp.ones(send.shape[0], bool),
        node_mask=jnp.ones(nodes.shape[0], bool),
        labels=jnp.asarray(labels[nodes].astype(np.int32)))


# ---------------------------------------------------------------------------
# distributed full-graph step (edge partition by destination)
# ---------------------------------------------------------------------------

def partition_edges_by_dst(senders: np.ndarray, receivers: np.ndarray,
                           n_nodes: int, n_parts: int
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side partitioner for the sharded full-graph step: device d owns
    the contiguous node range [d*N/n_parts, (d+1)*N/n_parts) and receives
    EXACTLY the edges whose destination falls in its range, padded to the
    max per-part count so shapes stay uniform. Returns padded
    (senders, receivers, edge_mask) of shape (n_parts * per_part,)."""
    assert n_nodes % n_parts == 0, (n_nodes, n_parts)
    rng_size = n_nodes // n_parts
    part = receivers // rng_size
    order = np.argsort(part, kind="stable")
    s_sorted, r_sorted, p_sorted = senders[order], receivers[order], part[order]
    counts = np.bincount(p_sorted, minlength=n_parts)
    per_part = int(counts.max())
    S = np.zeros((n_parts, per_part), np.int32)
    R = np.zeros((n_parts, per_part), np.int32)
    M = np.zeros((n_parts, per_part), bool)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for d in range(n_parts):
        c = counts[d]
        S[d, :c] = s_sorted[starts[d]:starts[d] + c]
        R[d, :c] = r_sorted[starts[d]:starts[d] + c]
        M[d, :c] = True
        R[d, c:] = d * rng_size          # padding points in-range (masked)
    return S.reshape(-1), R.reshape(-1), M.reshape(-1)


def pna_loss_sharded(params: Params, cfg: GNNConfig, batch: GraphBatch,
                     mesh, *, mean_log_deg: float = 2.0) -> jax.Array:
    """Distributed PNA loss via shard_map: node features replicated, edges
    partitioned by destination range (``partition_edges_by_dst`` contract),
    aggregates computed shard-locally into each device's node range, node
    update on the local range, then one all-gather per layer to rebuild the
    replicated h for the next layer's sender gathers. Collective traffic per
    layer = the (N, d_hidden) feature matrix — no scatter crosses shards."""
    from jax.sharding import PartitionSpec as P
    every = tuple(mesh.axis_names)
    n_dev = 1
    for a in every:
        n_dev *= mesh.shape[a]
    n_nodes = batch.feats.shape[0]
    assert n_nodes % n_dev == 0, (n_nodes, n_dev)
    n_loc = n_nodes // n_dev

    def shard_fn(prm, feats, senders, receivers, edge_mask, node_mask,
                 labels):
        # local shard: edges (E_loc,), everything else replicated
        h = feats @ prm["encode"]

        shard_ix = jnp.int32(0)
        mul = 1
        for ax in reversed(every):
            shard_ix = shard_ix + mul * jax.lax.axis_index(ax)
            mul = mul * jax.lax.axis_size(ax)
        base = shard_ix * n_loc
        local_recv = receivers - base

        def body(h, layer_p):
            msg = (jnp.take(h, senders, axis=0) @ layer_p["w_msg_src"]
                   + jnp.take(h, receivers, axis=0) @ layer_p["w_msg_dst"])
            msg = jax.nn.relu(msg)
            agg, deg = _aggregate(msg, local_recv, edge_mask, n_loc,
                                  cfg.aggregators)
            scaled = _scale(agg, deg, cfg.scalers, mean_log_deg)
            h_loc = jax.lax.dynamic_slice_in_dim(h, base, n_loc, axis=0)
            upd = jnp.concatenate([h_loc, scaled], axis=-1) @ layer_p["w_update"]
            h_new_loc = h_loc + jax.nn.relu(upd)
            h_new = jax.lax.all_gather(h_new_loc, every, axis=0, tiled=True)
            return h_new, None

        h, _ = _scan(body, h, prm["layers"])
        # loss over this shard's node range
        logits = (jax.lax.dynamic_slice_in_dim(h, base, n_loc, 0)
                  @ prm["decode"]).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        lab = jax.lax.dynamic_slice_in_dim(labels, base, n_loc, 0)
        nm = jax.lax.dynamic_slice_in_dim(node_mask, base, n_loc, 0)
        nll = -jnp.take_along_axis(logp, lab[:, None], axis=-1)[:, 0]
        tot = jax.lax.psum(jnp.sum(jnp.where(nm, nll, 0.0)), every)
        cnt = jax.lax.psum(jnp.sum(nm.astype(jnp.float32)), every)
        return tot / jnp.maximum(cnt, 1.0)

    p_specs = jax.tree.map(lambda _: P(), params)
    return jax.shard_map(
        shard_fn, mesh=mesh, check_vma=False,
        in_specs=(p_specs, P(), P(every), P(every), P(every), P(), P()),
        out_specs=P(),
    )(params, batch.feats, batch.senders, batch.receivers, batch.edge_mask,
      batch.node_mask, batch.labels)
