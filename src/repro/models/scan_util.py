"""Scan indirection for roofline analysis.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so FLOP/byte/collective numbers pulled from a scanned (stacked-layer)
lowering undercount by ~n_layers x n_microbatches. The roofline pass
(benchmarks/roofline.py) therefore lowers REDUCED-depth models with every
scan UNROLLED (cost numbers then scale linearly and are extrapolated to full
depth), while the dry-run proper keeps rolled scans (fast compiles, correct
memory analysis).

``set_unroll(True)`` flips every model/train scan routed through here.
"""
from __future__ import annotations

import jax

_UNROLL = False


def set_unroll(value: bool) -> None:
    global _UNROLL
    _UNROLL = bool(value)


def get_unroll() -> bool:
    return _UNROLL


def scan(f, init, xs, **kw):
    if _UNROLL:
        kw = dict(kw)
        kw["unroll"] = True
    return jax.lax.scan(f, init, xs, **kw)
