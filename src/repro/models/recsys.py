"""RecSys model zoo: FM, AutoInt, DIN, SASRec.

The hot path is the huge sparse embedding lookup. JAX has no native
EmbeddingBag — it is implemented here as ``jnp.take`` + ``segment_sum``
(single-hot fields collapse to a plain gather). All field tables live in ONE
concatenated (total_rows, dim) tensor with static per-field offsets so the
lookup is a single gather and the table row-shards cleanly over the mesh
('model' [+'pod'] axes; see repro/dist/sharding.py for the shard_map lookup
that avoids GSPMD all-gathering the table).

``*_score_candidates`` implement the retrieval_cand shape (1 query vs 10^6
items) as batched dot/forward — and expose sum-decomposable component
matrices for the generalized Col-Bandit (core/generalized.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.layers import dense, dense_init, init_dense, layer_norm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

def field_offsets(vocab_sizes: Tuple[int, ...]) -> np.ndarray:
    """Static row offset of each field's sub-table in the fused table."""
    return np.concatenate([[0], np.cumsum(np.asarray(vocab_sizes))[:-1]])


def init_fused_table(key: jax.Array, vocab_sizes: Tuple[int, ...], dim: int,
                     dtype=jnp.float32, pad_rows_to: int = 4096) -> jax.Array:
    """Rows padded to a multiple of `pad_rows_to` so the table row-shards
    over any mesh axis combination (512 devices max)."""
    total = int(np.sum(np.asarray(vocab_sizes)))
    total = -(-total // pad_rows_to) * pad_rows_to
    return (jax.random.normal(key, (total, dim), jnp.float32) * 0.05
            ).astype(dtype)


def embedding_lookup(table: jax.Array, ids: jax.Array,
                     offsets: np.ndarray) -> jax.Array:
    """Single-hot per-field lookup. ids: (B, F) local per-field indices ->
    (B, F, dim). The fused-table gather is the EmbeddingBag fast path."""
    global_ids = ids + jnp.asarray(offsets, ids.dtype)[None, :]
    return jnp.take(table, global_ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array, bag_ids: jax.Array,
                  n_bags: int, weights: Optional[jax.Array] = None,
                  mode: str = "sum") -> jax.Array:
    """Multi-hot EmbeddingBag: ids (nnz,) global rows, bag_ids (nnz,) ->
    (n_bags, dim) via gather + segment reduce (the torch-parity op JAX
    lacks natively)."""
    rows = jnp.take(table, ids, axis=0)                    # (nnz, dim)
    if weights is not None:
        rows = rows * weights[:, None]
    summed = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if mode == "sum":
        return summed
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(bag_ids, rows.dtype),
                                  bag_ids, num_segments=n_bags)
        return summed / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, bag_ids, num_segments=n_bags)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# FM  [Rendle ICDM'10]
# ---------------------------------------------------------------------------

def init_fm(key: jax.Array, cfg: RecsysConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "table": init_fused_table(ks[0], cfg.vocab_sizes, cfg.embed_dim, dtype),
        "linear": init_fused_table(ks[1], cfg.vocab_sizes, 1, dtype),
        "bias": jnp.zeros((), dtype),
    }


def fm_forward(params: Params, cfg: RecsysConfig, ids: jax.Array) -> jax.Array:
    """ids (B, F) -> logit (B,). Pairwise term via the O(nk) sum-square
    trick: sum_{i<j} <v_i, v_j> = 0.5 * ((sum v)^2 - sum v^2)."""
    offs = field_offsets(cfg.vocab_sizes)
    v = embedding_lookup(params["table"], ids, offs)        # (B, F, D)
    lin = embedding_lookup(params["linear"], ids, offs)[..., 0]  # (B, F)
    s = jnp.sum(v, axis=1)                                  # (B, D)
    s2 = jnp.sum(v * v, axis=1)                             # (B, D)
    pair = 0.5 * jnp.sum(s * s - s2, axis=-1)               # (B,)
    return params["bias"] + jnp.sum(lin, axis=-1) + pair


def fm_score_candidates(params: Params, cfg: RecsysConfig,
                        context_ids: jax.Array,
                        cand_ids: jax.Array) -> jax.Array:
    """retrieval_cand: fixed context fields (F-1 ids), candidate fills the
    last field. score(i) = const + lin_i + <v_i, sum_f v_f> (FM algebra) —
    O(N*D) instead of O(N*F*D)."""
    offs = field_offsets(cfg.vocab_sizes)
    ctx = embedding_lookup(params["table"], context_ids[None, :],
                           offs[:-1])[0]                    # (F-1, D)
    ctx_sum = jnp.sum(ctx, axis=0)                          # (D,)
    cand_rows = cand_ids + int(offs[-1])
    v_c = jnp.take(params["table"], cand_rows, axis=0)      # (N, D)
    lin_c = jnp.take(params["linear"], cand_rows, axis=0)[:, 0]
    inter = v_c @ ctx_sum
    return lin_c + inter                                    # + const (rank-free)


def fm_candidate_components(params: Params, cfg: RecsysConfig,
                            context_ids: jax.Array,
                            cand_ids: jax.Array) -> jax.Array:
    """(N, F) component matrix for the generalized bandit: column f is the
    candidate x context-field-f interaction (+ linear term in col 0)."""
    offs = field_offsets(cfg.vocab_sizes)
    ctx = embedding_lookup(params["table"], context_ids[None, :],
                           offs[:-1])[0]                    # (F-1, D)
    cand_rows = cand_ids + int(offs[-1])
    v_c = jnp.take(params["table"], cand_rows, axis=0)      # (N, D)
    lin_c = jnp.take(params["linear"], cand_rows, axis=0)   # (N, 1)
    inter = v_c @ ctx.T                                     # (N, F-1)
    return jnp.concatenate([lin_c, inter], axis=-1)


# ---------------------------------------------------------------------------
# AutoInt  [arXiv:1810.11921]
# ---------------------------------------------------------------------------

def init_autoint(key: jax.Array, cfg: RecsysConfig,
                 dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2 + 4 * cfg.n_attn_layers)
    layers = []
    d_in = cfg.embed_dim
    for i in range(cfg.n_attn_layers):
        layers.append({
            "wq": dense_init(ks[2 + 4 * i], d_in, cfg.d_attn * cfg.n_heads, dtype),
            "wk": dense_init(ks[3 + 4 * i], d_in, cfg.d_attn * cfg.n_heads, dtype),
            "wv": dense_init(ks[4 + 4 * i], d_in, cfg.d_attn * cfg.n_heads, dtype),
            "w_res": dense_init(ks[5 + 4 * i], d_in, cfg.d_attn * cfg.n_heads, dtype),
        })
        d_in = cfg.d_attn * cfg.n_heads
    return {
        "table": init_fused_table(ks[0], cfg.vocab_sizes, cfg.embed_dim, dtype),
        "layers": layers,
        "out": init_dense(ks[1], d_in * cfg.n_sparse, 1, dtype=dtype),
    }


def _interacting_layer(p: Params, x: jax.Array, n_heads: int,
                       d_attn: int) -> jax.Array:
    """Multi-head self-attention over the FIELD axis (B, F, d)."""
    B, F, _ = x.shape
    q = (x @ p["wq"]).reshape(B, F, n_heads, d_attn)
    k = (x @ p["wk"]).reshape(B, F, n_heads, d_attn)
    v = (x @ p["wv"]).reshape(B, F, n_heads, d_attn)
    logits = jnp.einsum("bfhd,bghd->bhfg", q, k) / jnp.sqrt(jnp.float32(d_attn))
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhfg,bghd->bfhd", w, v).reshape(B, F, n_heads * d_attn)
    return jax.nn.relu(out + x @ p["w_res"])


def autoint_forward(params: Params, cfg: RecsysConfig,
                    ids: jax.Array) -> jax.Array:
    offs = field_offsets(cfg.vocab_sizes)
    x = embedding_lookup(params["table"], ids, offs)        # (B, F, D)
    for lp in params["layers"]:
        x = _interacting_layer(lp, x, cfg.n_heads, cfg.d_attn)
    flat = x.reshape(x.shape[0], -1)
    return dense(params["out"], flat)[:, 0]


def autoint_score_candidates(params: Params, cfg: RecsysConfig,
                             context_ids: jax.Array,
                             cand_ids: jax.Array,
                             chunk: int = 8192) -> jax.Array:
    """Score N candidates sharing fixed context fields: full forward with the
    candidate substituted into the last field, chunked over candidates."""
    n = cand_ids.shape[0]

    def score_chunk(c_ids):
        ids = jnp.concatenate(
            [jnp.broadcast_to(context_ids[None, :], (c_ids.shape[0],
                                                     context_ids.shape[0])),
             c_ids[:, None]], axis=-1)
        return autoint_forward(params, cfg, ids)

    if n <= chunk:
        return score_chunk(cand_ids)
    n_chunks = -(-n // chunk)
    padded = jnp.pad(cand_ids, (0, n_chunks * chunk - n))
    out = jax.lax.map(score_chunk, padded.reshape(n_chunks, chunk))
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# DIN  [arXiv:1706.06978]
# ---------------------------------------------------------------------------

def init_din(key: jax.Array, cfg: RecsysConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.embed_dim
    attn_in = 4 * d
    p: Params = {
        "item_table": (jax.random.normal(ks[0], (cfg.item_vocab, d),
                                         jnp.float32) * 0.05).astype(dtype),
        "attn": [init_dense(ks[1], attn_in, cfg.attn_mlp[0], dtype=dtype),
                 init_dense(ks[2], cfg.attn_mlp[0], cfg.attn_mlp[1], dtype=dtype),
                 init_dense(ks[3], cfg.attn_mlp[1], 1, dtype=dtype)],
        "mlp": [init_dense(ks[4], 3 * d, cfg.mlp[0], dtype=dtype),
                init_dense(ks[5], cfg.mlp[0], cfg.mlp[1], dtype=dtype),
                init_dense(ks[6], cfg.mlp[1], 1, dtype=dtype)],
    }
    return p


def _din_attention(p: Params, hist: jax.Array, hist_mask: jax.Array,
                   target: jax.Array) -> jax.Array:
    """Target attention: weight each history item by MLP(h, t, h-t, h*t).
    hist (B, S, D), target (B, D) -> user interest vector (B, D)."""
    B, S, D = hist.shape
    t = jnp.broadcast_to(target[:, None, :], (B, S, D))
    z = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    for i, lp in enumerate(p["attn"]):
        z = dense(lp, z)
        if i < len(p["attn"]) - 1:
            z = jax.nn.sigmoid(z)                           # Dice-ish
    w = z[..., 0]                                           # (B, S) raw weights
    w = jnp.where(hist_mask, w, 0.0)
    return jnp.einsum("bs,bsd->bd", w, hist)


def din_forward(params: Params, cfg: RecsysConfig, hist_ids: jax.Array,
                hist_mask: jax.Array, target_ids: jax.Array) -> jax.Array:
    hist = jnp.take(params["item_table"], hist_ids, axis=0)   # (B, S, D)
    target = jnp.take(params["item_table"], target_ids, axis=0)
    user = _din_attention(params, hist, hist_mask, target)
    z = jnp.concatenate([user, target, user * target], axis=-1)
    for i, lp in enumerate(params["mlp"]):
        z = dense(lp, z)
        if i < len(params["mlp"]) - 1:
            z = jax.nn.sigmoid(z)
    return z[:, 0]


def din_score_candidates(params: Params, cfg: RecsysConfig,
                         hist_ids: jax.Array, hist_mask: jax.Array,
                         cand_ids: jax.Array, chunk: int = 8192) -> jax.Array:
    """One user (hist (S,)) vs N candidate items."""
    n = cand_ids.shape[0]

    def score_chunk(c_ids):
        B = c_ids.shape[0]
        h = jnp.broadcast_to(hist_ids[None], (B, hist_ids.shape[0]))
        m = jnp.broadcast_to(hist_mask[None], (B, hist_mask.shape[0]))
        return din_forward(params, cfg, h, m, c_ids)

    if n <= chunk:
        return score_chunk(cand_ids)
    n_chunks = -(-n // chunk)
    padded = jnp.pad(cand_ids, (0, n_chunks * chunk - n))
    out = jax.lax.map(score_chunk, padded.reshape(n_chunks, chunk))
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# SASRec  [arXiv:1808.09781]
# ---------------------------------------------------------------------------

def init_sasrec(key: jax.Array, cfg: RecsysConfig,
                dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2 + 5 * cfg.n_blocks)
    d = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        blocks.append({
            "wq": dense_init(ks[2 + 5 * i], d, d, dtype),
            "wk": dense_init(ks[3 + 5 * i], d, d, dtype),
            "wv": dense_init(ks[4 + 5 * i], d, d, dtype),
            "ff1": init_dense(ks[5 + 5 * i], d, d, dtype=dtype),
            "ff2": init_dense(ks[6 + 5 * i], d, d, dtype=dtype),
            "ln1_s": jnp.ones((d,), dtype), "ln1_b": jnp.zeros((d,), dtype),
            "ln2_s": jnp.ones((d,), dtype), "ln2_b": jnp.zeros((d,), dtype),
        })
    return {
        "item_table": (jax.random.normal(ks[0], (cfg.item_vocab, d),
                                         jnp.float32) * 0.05).astype(dtype),
        "pos_table": (jax.random.normal(ks[1], (cfg.seq_len, d),
                                        jnp.float32) * 0.05).astype(dtype),
        "blocks": blocks,
    }


def sasrec_user_state(params: Params, cfg: RecsysConfig, hist_ids: jax.Array,
                      hist_mask: jax.Array) -> jax.Array:
    """hist (B, S) -> user representation (B, D): last valid position state
    after causal self-attention blocks."""
    B, S = hist_ids.shape
    d = cfg.embed_dim
    x = jnp.take(params["item_table"], hist_ids, axis=0)
    x = x + params["pos_table"][None, :S]
    causal = jnp.tril(jnp.ones((S, S), bool))
    key_ok = hist_mask[:, None, :]
    for bp in params["blocks"]:
        h = layer_norm(x, bp["ln1_s"], bp["ln1_b"])
        q, k, v = h @ bp["wq"], h @ bp["wk"], h @ bp["wv"]
        logits = jnp.einsum("bsd,btd->bst", q, k) / jnp.sqrt(jnp.float32(d))
        logits = jnp.where(causal[None] & key_ok, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        x = x + jnp.einsum("bst,btd->bsd", w, v)
        h2 = layer_norm(x, bp["ln2_s"], bp["ln2_b"])
        x = x + dense(bp["ff2"], jax.nn.relu(dense(bp["ff1"], h2)))
    # state at the last valid position
    last = jnp.maximum(jnp.sum(hist_mask.astype(jnp.int32), axis=-1) - 1, 0)
    return jnp.take_along_axis(x, last[:, None, None].repeat(d, -1), 1)[:, 0]


def sasrec_forward(params: Params, cfg: RecsysConfig, hist_ids: jax.Array,
                   hist_mask: jax.Array, target_ids: jax.Array) -> jax.Array:
    """Next-item logit: <user_state, item_emb[target]>."""
    u = sasrec_user_state(params, cfg, hist_ids, hist_mask)
    t = jnp.take(params["item_table"], target_ids, axis=0)
    return jnp.sum(u * t, axis=-1)


def sasrec_score_candidates(params: Params, cfg: RecsysConfig,
                            hist_ids: jax.Array, hist_mask: jax.Array,
                            cand_ids: jax.Array) -> jax.Array:
    """1 user vs N candidates: one user-state pass + (N, D) @ (D,) matvec."""
    u = sasrec_user_state(params, cfg, hist_ids[None], hist_mask[None])[0]
    items = jnp.take(params["item_table"], cand_ids, axis=0)
    return items @ u
