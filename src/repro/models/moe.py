"""Top-k routed Mixture-of-Experts (Mixtral 8x22B: 8e top-2; Moonlight:
64e top-6) with capacity-based dispatch so compiled FLOPs reflect ACTIVE
experts only (the 6*N_active*D roofline accounting depends on this — a
dense all-experts formulation would inflate HLO FLOPs by E/top_k).

Dispatch is BATCH-ROW-LOCAL (GShard-style capacity per sequence): the
position-in-expert cumsum runs over each row's tokens only, so under batch
sharding no cross-device scan is ever generated — each data shard dispatches
its own rows. Per-row capacity C = ceil(S * k / E * capacity_factor); tokens
beyond capacity are dropped (residual passes through), as in production MoE
systems. ``no_drop=True`` (decode) sizes C to the worst case instead.

Expert weights are stored (E, D, F) and shard D over the FSDP group and F
over TP (dist/sharding.py) — ZeRO-3 semantics: XLA all-gathers each layer's
expert shards just-in-time inside the scan.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense_init
from repro.dist.act_sharding import constrain as _cst

Params = Dict[str, Any]


def init_moe(key: jax.Array, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)

    def einit(k, di, do):
        scale = 1.0 / jnp.sqrt(jnp.float32(di))
        return (jax.random.normal(k, (n_experts, di, do), jnp.float32)
                * scale).astype(dtype)

    return {
        "router": dense_init(ks[0], d_model, n_experts, dtype),
        "w_gate": einit(ks[1], d_model, d_ff),
        "w_up": einit(ks[2], d_model, d_ff),
        "w_down": einit(ks[3], d_ff, d_model),
    }


def moe_ffn(p: Params, x: jax.Array, *, top_k: int, act: str = "silu",
            capacity_factor: float = 1.25, no_drop: bool = False) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E = p["router"].shape[-1]

    gate_logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    top_vals, top_idx = jax.lax.top_k(gate_logits, top_k)     # (B, S, k)
    top_w = jax.nn.softmax(top_vals, axis=-1)

    if no_drop:
        capacity = S * top_k                                   # worst case
    else:
        capacity = int(max(1, round(S * top_k / E * capacity_factor)))
    capacity = min(capacity, S * top_k)

    # (B, S*k) flattened slot views, row-local positions
    e_idx = top_idx.reshape(B, S * top_k)
    onehot = jax.nn.one_hot(e_idx, E, dtype=jnp.float32)       # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1.0
    pos_in_expert = jnp.sum(pos * onehot, axis=-1)             # (B, S*k)
    keep = pos_in_expert < capacity
    w = top_w.reshape(B, S * top_k) * keep.astype(top_w.dtype)
    c_idx = jnp.clip(pos_in_expert.astype(jnp.int32), 0, capacity - 1)
    src = jnp.broadcast_to(jnp.arange(S)[:, None],
                           (S, top_k)).reshape(S * top_k)      # token of slot

    def dispatch_row(tok_row, e_row, c_row, keep_row):
        contrib = jnp.where(keep_row[:, None], tok_row[src], 0.0)
        return jnp.zeros((E, capacity, D), x.dtype).at[e_row, c_row].add(contrib)

    buf = jax.vmap(dispatch_row)(x, e_idx, c_idx, keep)        # (B, E, C, D)
    buf = _cst(buf, "dp", None, None, None)

    h = act_fn(act)(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) * \
        jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = _cst(h, "dp", None, None, "tp")
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])     # (B, E, C, D)
    out_buf = _cst(out_buf, "dp", None, None, None)

    def combine_row(out_row, e_row, c_row, w_row):
        gathered = out_row[e_row, c_row]                       # (S*k, D)
        weighted = gathered * w_row[:, None].astype(gathered.dtype)
        return jnp.zeros((S, D), x.dtype).at[src].add(weighted.astype(x.dtype))

    return jax.vmap(combine_row)(out_buf, e_idx, c_idx, w)


def moe_aux_loss(p: Params, x: jax.Array, *, top_k: int) -> jax.Array:
    """Switch-style load-balancing loss (fraction-dispatched x router prob)."""
    B, S, D = x.shape
    E = p["router"].shape[-1]
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_idx = jax.lax.top_k(logits, top_k)
    counts = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    return jnp.float32(E) * jnp.sum(frac * mean_prob)
