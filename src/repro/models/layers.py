"""Shared neural building blocks (pure-functional, params = nested dicts).

Covers every attention flavor in the assigned LM pool: GQA, sliding-window
(Mixtral), local/global alternating + softcaps (Gemma-2), QKV bias (Qwen2.5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from repro.models.scan_util import scan as _scan
from repro.dist.act_sharding import constrain as _cst

Params = Dict[str, Any]
_NEG = jnp.float32(-1e30)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int,
               dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / jnp.sqrt(jnp.float32(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int,
               dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "dice": jax.nn.sigmoid}[name]


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, d_model: int, n_heads: int,
                   n_kv_heads: int, d_head: int, qkv_bias: bool,
                   dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * d_head, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * d_head, dtype),
        "wo": dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * d_head,), dtype)
    return p


def _attn_mask(q_pos: jax.Array, kv_pos: jax.Array,
               window: jax.Array) -> jax.Array:
    """Causal + optional sliding window, built from positions (no O(S^2)
    materialized constants; XLA fuses the iota comparisons into the softmax).
    q_pos: (B, Sq); kv_pos: (B, Skv); window: scalar (<=0 => full causal)."""
    causal = kv_pos[:, None, :] <= q_pos[:, :, None]          # (B, Sq, Skv)
    dist = q_pos[:, :, None] - kv_pos[:, None, :]
    in_window = jnp.where(window > 0, dist < window, True)
    return causal & in_window


def attention(
    p: Params,
    x: jax.Array,                   # (B, S, D)
    positions: jax.Array,           # (B, S)
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    rope_theta: float,
    window: jax.Array,              # scalar i32; <=0 => full
    attn_softcap: Optional[float] = None,
    kv_override: Optional[Tuple[jax.Array, jax.Array, jax.Array, jax.Array]] = None,
    q_chunk: int = 0,
) -> jax.Array:
    """Causal (optionally windowed) GQA self-attention.

    kv_override = (k, v, kv_pos, kv_valid) lets the decode path attend over a
    cache instead of the in-sequence K/V; shapes (B, Skv, Hkv, Dh), (B, Skv).

    q_chunk > 0 processes queries in sequential chunks (lax.scan) so the
    (S, Skv) logits never materialize whole — the memory-efficient path for
    32k prefill (keys stay resident; peak logits = q_chunk x Skv).
    """
    B, S, D = x.shape
    q = _cst(x @ p["wq"], "dp", None, "tp")   # heads -> TP
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, n_heads, d_head)
    q = apply_rope(q, positions, rope_theta)

    if kv_override is None:
        k = x @ p["wk"]
        v = x @ p["wv"]
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = apply_rope(k.reshape(B, S, n_kv_heads, d_head), positions,
                       rope_theta)
        v = v.reshape(B, S, n_kv_heads, d_head)
        kv_pos, kv_valid = positions, jnp.ones((B, S), jnp.bool_)
    else:
        k, v, kv_pos, kv_valid = kv_override

    groups = n_heads // n_kv_heads
    scale = 1.0 / jnp.sqrt(jnp.float32(d_head))

    def attend(q_blk: jax.Array, pos_blk: jax.Array) -> jax.Array:
        """q_blk (B, Sq, H, Dh), pos_blk (B, Sq) -> (B, Sq, H*Dh)."""
        Sq = q_blk.shape[1]
        qg = q_blk.reshape(B, Sq, n_kv_heads, groups, d_head)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        # Optional §Perf constraint ("cache_logits"): pins decode logits to
        # the KV-seq sharding so the softmax runs DISTRIBUTED (flash-decoding
        # split-K: tiny max/sum all-reduces) instead of GSPMD all-gathering
        # K/V per layer. No-op unless registered by the launch layer.
        from repro.dist.act_sharding import constrain_named as _cn
        logits = _cn(logits, "cache_logits")
        logits = softcap(logits, attn_softcap)
        mask = _attn_mask(pos_blk, kv_pos, window) & kv_valid[:, None, :]
        logits = jnp.where(mask[:, None, None, :, :], logits, _NEG)
        w = jax.nn.softmax(logits, axis=-1)
        w = _cn(w, "cache_logits")
        out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
        return out.reshape(B, Sq, n_heads * d_head).astype(x.dtype)

    k = _cst(k, "dp", None, None, None)   # KV heads replicated across TP
    v = _cst(v, "dp", None, None, None)
    if q_chunk and S > q_chunk and S % q_chunk == 0:
        n_chunks = S // q_chunk
        q_cs = q.reshape(B, n_chunks, q_chunk, n_heads, d_head
                         ).transpose(1, 0, 2, 3, 4)
        pos_cs = positions.reshape(B, n_chunks, q_chunk).transpose(1, 0, 2)
        _, outs = _scan(
            lambda _, xs: (None, attend(xs[0], xs[1])), None, (q_cs, pos_cs))
        out = outs.transpose(1, 0, 2, 3).reshape(B, S, n_heads * d_head)
    else:
        out = attend(q, positions)
    return _cst(out @ p["wo"], "dp", None, None)


# ---------------------------------------------------------------------------
# MLP (GLU family)
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, d_model: int, d_ff: int,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    h = _cst(act_fn(act)(x @ p["w_gate"]) * (x @ p["w_up"]),
             "dp", None, "tp")
    return _cst(h @ p["w_down"], "dp", None, None)


def init_dense(key: jax.Array, d_in: int, d_out: int, bias: bool = True,
               dtype=jnp.float32) -> Params:
    p = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    return y + p["b"] if "b" in p else y
