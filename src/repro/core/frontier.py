"""Pooled cross-query reveal engine — continuous batching for Col-Bandit.

``jax.vmap(one_query)`` over a ``while_loop`` runs every query of a serving
batch in lockstep to the SLOWEST query's round count: converged queries keep
burning reveal-kernel slots until the last straggler separates. This module
replaces that with one global ``while_loop`` driving all Q queries at once:

  1. every round, each still-active query runs the shared LUCB block
     selection (``repro.core.batched._round_select`` — bit-identical policy
     and PRNG stream to the solo bandit),
  2. the selected (doc, token) blocks of ALL active queries are pooled into
     a single fixed-capacity frontier: doc ids are query-offset into the
     stacked (Q*N, L, M) candidate tensor, token ids into the stacked
     (Q*T, M) query-token table, and valid slots are compacted to the front,
  3. the whole frontier lowers through ONE ``compute_cells`` call — in
     serving, one ``kernels.ops.gather_maxsim_op`` kernel launch per round
     instead of Q per-query einsums,
  4. per-query done-masks retire finished queries: their slots drop out of
     the frontier (occupancy is measured), their round counters freeze, and
     — with ``cfg.max_block_docs > block_docs`` — their freed slots are
     reallocated to still-active queries, which then reveal bigger blocks
     per round and converge in fewer global loop trips.

Statistics live STACKED as one (Q*N, T) ``BanditState`` so the frontier's
query-offset scatter is the ordinary ``_apply_block_reveal``; per-query
views (Q, N, T) feed the vmapped interval/selection math.

With ``max_block_docs == 0`` (the default) each query's reveal trajectory is
exactly the solo ``run_batched_bandit`` trajectory under the same key —
pooling changes WHERE cells are computed (one kernel launch), never WHICH
cells a query reveals. That invariant is what the frontier-retirement tests
pin down, and why full-budget top-K parity with the vmapped path is exact.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bounds as B
from repro.core.bandit import _select_arms, _topk_mask
from repro.core.batched import (BatchedConfig, _apply_block_reveal,
                                _round_select)
from repro.core.state import BanditState

_NEG = jnp.float32(-3e38)

# Cell contract (pooled): compute_cells(flat_doc (S,), flat_tok (S, G))
# -> (S, G), where flat_doc indexes the stacked (Q*N, ...) doc axis and
# flat_tok the stacked (Q*T, ...) query-token axis (doc q*N+i pairs only
# with tokens q*T+t of the SAME query q). This is exactly the contract
# ``kernels.ops.gather_maxsim_op`` lowers on the stacked tensors.


class PooledResult(NamedTuple):
    topk: jax.Array            # (Q, K) i32 — per-query top-K doc slots
    s_hat: jax.Array           # (Q, N) f32 — final score estimates
    coverage: jax.Array        # (Q,) f32 — Eq. 6 per query
    reveals: jax.Array         # (Q,) i32 — |Omega_q|
    rounds: jax.Array          # (Q,) i32 — per-query LUCB rounds (frozen at
                               #   retirement; == solo rounds when blocks
                               #   are fixed)
    separated: jax.Array       # (Q,) bool — stopped via LCB >= UCB
    revealed: jax.Array        # (Q, N, T) bool — final observation sets
    trips: jax.Array           # () i32 — global while_loop iterations
                               #   (== max(rounds) by construction)
    total_rounds: jax.Array    # () i32 — sum(rounds): reveal rounds actually
                               #   attributable to queries
    lockstep_waste: jax.Array  # () i32 — Q*trips - total_rounds: rounds a
                               #   vmapped lockstep loop would have burned on
                               #   already-converged queries
    occupancy: jax.Array       # () f32 — mean fraction of frontier slots
                               #   holding live reveal work across trips


def run_pooled_bandit(
    compute_cells,
    a: jax.Array,                # (Q, N, T) lower support per cell
    b: jax.Array,                # (Q, N, T) upper support per cell
    keys: jax.Array,             # (Q,) per-query PRNG keys
    cfg: BatchedConfig,
    *,
    doc_mask: Optional[jax.Array] = None,   # (Q, N) bool valid candidates
) -> PooledResult:
    Q, N, T = a.shape
    k = cfg.k
    G = cfg.block_tokens
    half = max(cfg.block_docs // 2, 1)
    # Selection width per query: fixed (== solo) unless growth is enabled.
    # Clamped to N: a query can never hold more than its N candidate rows,
    # and an unclamped width would surface as an opaque top_k shape error
    # (reachable from EngineConfig.max_block_docs alone on small buckets).
    half_w = min(max(cfg.max_block_docs // 2, half), max(N, 1))
    W = 2 * half_w                           # per-query selection rows
    F = Q * 2 * half                         # frontier capacity (slots)
    max_rounds = cfg.max_rounds
    if max_rounds <= 0:
        max_rounds = (N * T) // max(cfg.block_docs * G, 1) + T + 8
    if doc_mask is None:
        doc_mask = jnp.ones((Q, N), jnp.bool_)
    a = jnp.where(doc_mask[:, :, None], a, 0.0).astype(jnp.float32)
    b = jnp.where(doc_mask[:, :, None], b, 0.0).astype(jnp.float32)

    q_doc_off = (jnp.arange(Q, dtype=jnp.int32) * N)[:, None]       # (Q, 1)

    # Per-query init split — same stream as run_batched_bandit's
    # ``key, k_init = split(key)`` so trajectories line up query by query.
    split2 = jax.vmap(lambda kk: tuple(jax.random.split(kk)))
    state_keys, k_init = split2(keys)

    state = BanditState(
        values=jnp.zeros((Q * N, T), jnp.float32),
        revealed=(~doc_mask[:, :, None]).reshape(Q * N, 1)
        & jnp.ones((Q * N, T), jnp.bool_),
        n=jnp.zeros((Q * N,), jnp.int32),
        total=jnp.zeros((Q * N,), jnp.float32),
        total_sq=jnp.zeros((Q * N,), jnp.float32),
        key=state_keys,                     # (Q,) keys — per-query streams
        rounds=jnp.zeros((Q,), jnp.int32),  # per-query round counters
        # Queries with NO valid candidate start retired (rounds stay 0):
        # routine on a sharded corpus, where a query's candidates may all be
        # resident elsewhere — an empty query must not hold frontier slots
        # or inflate the per-shard round/occupancy accounting.
        done=~jnp.any(doc_mask, axis=1),    # per-query retirement flags
    )

    # Init reveal (paper footnote 2): one random cell per doc, all queries
    # pooled into a single (Q*N, 1) compute_cells call.
    t0 = jax.vmap(lambda kk: jax.random.randint(kk, (N,), 0, T))(k_init)
    all_docs = jnp.arange(Q * N, dtype=jnp.int32)
    flat_t0 = t0.reshape(Q * N, 1)
    init_vals = compute_cells(all_docs,
                              flat_t0 + (all_docs // N * T)[:, None])
    state = _apply_block_reveal(state, all_docs, flat_t0, init_vals,
                                doc_mask.reshape(Q * N, 1))

    iv_kwargs = dict(T=T, N=N, delta=cfg.delta, alpha_ef=cfg.alpha_ef,
                     c=cfg.radius_c, bias_kappa=cfg.bias_kappa)

    def get_intervals_q(n_q, total_q, total_sq_q, revealed_q, a_q, b_q,
                        mask_q) -> B.Intervals:
        iv = B.intervals(n_q, total_q, total_sq_q, revealed_q, a_q, b_q,
                         **iv_kwargs)
        return iv._replace(
            s_hat=jnp.where(mask_q, iv.s_hat, _NEG),
            lcb=jnp.where(mask_q, iv.lcb, _NEG),
            ucb=jnp.where(mask_q, iv.ucb, _NEG),
        )

    def per_query_intervals(st: BanditState) -> B.Intervals:
        return jax.vmap(get_intervals_q)(
            st.n.reshape(Q, N), st.total.reshape(Q, N),
            st.total_sq.reshape(Q, N), st.revealed.reshape(Q, N, T),
            a, b, doc_mask)

    select_q = functools.partial(_round_select, k=k, epsilon=cfg.epsilon,
                                 half=half_w, G=G)

    def cond(carry):
        st, _, _ = carry
        return jnp.any((~st.done) & (st.rounds < max_rounds))

    def body(carry):
        st, trips, occ_sum = carry
        active = (~st.done) & (st.rounds < max_rounds)          # (Q,)

        iv = per_query_intervals(st)
        sel = jax.vmap(select_q)(st.key, iv, st.revealed.reshape(Q, N, T),
                                 st.n.reshape(Q, N), a, b, doc_mask)

        # Slot allotment: with growth enabled, freed capacity is split
        # evenly among active queries (never below the solo width, never
        # above the selection width) — continuous batching for rounds.
        n_active = jnp.maximum(jnp.sum(active.astype(jnp.int32)), 1)
        per_group = jnp.clip(F // (2 * n_active), half, half_w)
        grp_en = jnp.arange(half_w, dtype=jnp.int32) < per_group
        enabled = jnp.concatenate([grp_en, grp_en])             # (W,)

        live = active & ~sel.stop                               # (Q,)
        cell_en = (sel.cell_ok & enabled[None, :, None]
                   & live[:, None, None])                       # (Q, W, G)

        # Pool + compact: scatter live slots to the frontier front; the
        # overflow index F is dropped, so retired queries simply vanish.
        flat_doc = (sel.doc_idx + q_doc_off).reshape(Q * W)
        flat_tok = sel.tok_idx.reshape(Q * W, G)
        flat_cell = cell_en.reshape(Q * W, G)
        slot_live = jnp.any(flat_cell, axis=-1)                 # (Q*W,)
        pos = jnp.cumsum(slot_live.astype(jnp.int32)) - 1
        dump = jnp.where(slot_live, pos, F)
        f_doc = jnp.zeros((F,), jnp.int32).at[dump].set(flat_doc,
                                                        mode="drop")
        f_tok = jnp.zeros((F, G), jnp.int32).at[dump].set(flat_tok,
                                                          mode="drop")
        f_cell = jnp.zeros((F, G), jnp.bool_).at[dump].set(flat_cell,
                                                           mode="drop")

        # ONE pooled reveal for the whole batch round.
        vals = compute_cells(f_doc, f_tok + (f_doc // N * T)[:, None])
        nxt = _apply_block_reveal(st, f_doc, f_tok, vals, f_cell)

        # Per-query bookkeeping — mirrors the solo loop's cond/stop exactly:
        # a query that separates this round reveals nothing (its slots were
        # masked out of the frontier) and retires with rounds+1.
        no_progress = ~jnp.any(sel.cell_ok & enabled[None, :, None],
                               axis=(1, 2))
        nxt = nxt._replace(
            key=sel.key,
            rounds=st.rounds + active.astype(jnp.int32),
            done=st.done | (active & (sel.stop | no_progress)),
        )
        occ = jnp.sum(slot_live.astype(jnp.float32)) / jnp.float32(F)
        return nxt, trips + 1, occ_sum + occ

    state, trips, occ_sum = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32),
                     jnp.zeros((), jnp.float32)))

    iv = per_query_intervals(state)
    tk = jax.vmap(functools.partial(_topk_mask, k=k))(iv.s_hat)
    topk_idx = tk[1]
    sep = jax.vmap(lambda iv_q, m_q: _select_arms(iv_q, _topk_mask(
        iv_q.s_hat, k)[0], m_q))(iv, doc_mask)
    separated = jax.vmap(lambda iv_q, ip, im: iv_q.lcb[ip] >= iv_q.ucb[im])(
        iv, sep[0], sep[1])

    rev_q = state.revealed.reshape(Q, N, T) & doc_mask[:, :, None]
    n_rev = jnp.sum(rev_q, axis=(1, 2))
    n_cells = jnp.maximum(jnp.sum(doc_mask, axis=1) * T, 1)
    total_rounds = jnp.sum(state.rounds)
    return PooledResult(
        topk=topk_idx,
        s_hat=iv.s_hat,
        coverage=n_rev.astype(jnp.float32) / n_cells.astype(jnp.float32),
        reveals=n_rev.astype(jnp.int32),
        rounds=state.rounds,
        separated=separated,
        revealed=rev_q,
        trips=trips,
        total_rounds=total_rounds,
        lockstep_waste=Q * trips - total_rounds,
        occupancy=occ_sum / jnp.maximum(trips.astype(jnp.float32), 1.0),
    )


@functools.partial(
    jax.jit,
    static_argnames=("k", "delta", "alpha_ef", "epsilon", "radius_c",
                     "block_docs", "block_tokens", "max_rounds",
                     "bias_kappa", "max_block_docs"),
)
def run_pooled_oracle(
    h_full: jax.Array, a: jax.Array, b: jax.Array, keys: jax.Array, *,
    k: int, delta: float = 0.01, alpha_ef: float = 0.3, epsilon: float = 0.1,
    radius_c: float = 1.0, bias_kappa: float = 0.0, block_docs: int = 8,
    block_tokens: int = 8, max_rounds: int = -1, max_block_docs: int = 0,
    doc_mask: Optional[jax.Array] = None,
) -> PooledResult:
    """Oracle-mode pooled engine: cells come from a precomputed (Q, N, T)
    H tensor. The flat token ids are mapped back to each slot's own query
    (doc q*N+i only ever pairs with tokens q*T+t), mirroring the stacked
    gather_maxsim contract."""
    Q, N, T = h_full.shape
    cfg = BatchedConfig(k=k, delta=delta, alpha_ef=alpha_ef, epsilon=epsilon,
                        radius_c=radius_c, bias_kappa=bias_kappa,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds, max_block_docs=max_block_docs)
    h_flat = h_full.reshape(Q * N, T)

    def cells(flat_doc: jax.Array, flat_tok: jax.Array) -> jax.Array:
        t_local = flat_tok - (flat_doc // N * T)[:, None]
        return h_flat[flat_doc[:, None], jnp.clip(t_local, 0, T - 1)]

    return run_pooled_bandit(cells, a, b, keys, cfg, doc_mask=doc_mask)
