"""Pooled cross-query reveal engine — continuous batching for Col-Bandit.

``jax.vmap(one_query)`` over a ``while_loop`` runs every query of a serving
batch in lockstep to the SLOWEST query's round count: converged queries keep
burning reveal-kernel slots until the last straggler separates. This module
replaces that with one global ``while_loop`` driving all Q queries at once:

  1. every round, each still-active query runs the shared LUCB block
     selection (``repro.core.batched._round_select`` — bit-identical policy
     and PRNG stream to the solo bandit),
  2. the selected (doc, token) blocks of ALL active queries are pooled into
     a single fixed-capacity frontier: doc ids are query-offset into the
     stacked (Q*N, L, M) candidate tensor, token ids into the stacked
     (Q*T, M) query-token table,
  3. the whole frontier lowers through ONE reveal launch per round,
  4. per-query done-masks retire finished queries: their slots drop out of
     the frontier (occupancy is measured), their round counters freeze, and
     — with ``cfg.max_block_docs > block_docs`` (and/or
     ``cfg.max_block_tokens > block_tokens``) — their freed capacity is
     reallocated to still-active queries, which then reveal bigger doc
     and/or token blocks per round and converge in fewer global loop trips.

Two ROUND BODIES lower step 3, selected by ``fused=`` (default: fused
unless ``REPRO_KERNEL_IMPL=ref``):

* **chain** (the ``ref``-lane oracle): cells come from the abstract
  ``compute_cells`` gather, and the statistics update is the classic
  ``_apply_block_reveal`` scatter chain over a stacked (Q*N, T)
  ``BanditState`` — five separate scatters per round, each an HBM
  round-trip at serving scale.
* **fused**: one reveal launch returns the cell values AND the per-row
  sufficient-statistic deltas (``kernels.ops.fused_reveal_op`` — in-kernel
  doc gather, VMEM-resident running max, in-kernel stat accumulation), and
  the whole state update collapses to ONE scatter-min into a sentinel-
  encoded (Q*N, T) cell-value table (``_UNREV`` marks unrevealed; the
  revealed mask is derived by comparison, fusing into the interval math)
  plus ONE 3-column scatter-add of the (n, total, total_sq) statistics.
  When no slot growth is configured the frontier also skips compaction —
  capacity equals the selection width, so the flat (Q*W) selections feed
  the launch directly (dead slots ride along as masked no-ops).

Both bodies make bit-identical per-query reveal decisions from identical
statistics: the fused body is a re-plumbing of WHERE values and statistics
are computed, never WHICH cells a query reveals. That invariant is what the
chain-vs-fused parity tests pin down, on top of the existing guarantee that
with ``max_block_docs == 0`` each query's trajectory is exactly the solo
``run_batched_bandit`` trajectory under the same key.
"""
from __future__ import annotations

import functools
import os
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bounds as B
from repro.core.bandit import _select_arms, _topk_mask
from repro.core.batched import (BatchedConfig, _apply_block_reveal,
                                _round_select)
from repro.core.state import BanditState

_NEG = jnp.float32(-3e38)
# Fused-round cell table sentinel: unrevealed cells hold _UNREV; anything
# below _REV_THRESH is a revealed value. Real MaxSim values are bounded far
# below 1.5e38 (the all-masked-document sentinel is -3e38, also below).
_UNREV = jnp.float32(3e38)
_REV_THRESH = jnp.float32(1.5e38)
# Finite-score guard: a revealed cell that comes back NaN/Inf (poisoned
# corpus row, kernel bug) is recorded as _QUAR instead — finite, so the
# sufficient statistics stay well-defined (no NaN mean, no inf total_sq),
# yet far below any genuine MaxSim value, so the doc can never win the
# top-K. _QUAR_THRESH separates quarantined cells from real ones at
# finalize time (real |MaxSim| is O(|q||d|) << 1e4).
_QUAR = jnp.float32(-3e4)
_QUAR_THRESH = jnp.float32(-1e4)

# Cell contract (pooled): compute_cells(flat_doc (S,), flat_tok (S, G))
# -> (S, G), where flat_doc indexes the stacked (Q*N, ...) doc axis and
# flat_tok the stacked (Q*T, ...) query-token axis (doc q*N+i pairs only
# with tokens q*T+t of the SAME query q). This is exactly the contract
# ``kernels.ops.gather_maxsim_op`` lowers on the stacked tensors. The
# fused round extends it: compute_cells_fused(flat_doc, flat_tok,
# new_mask) -> (vals (S, G), stats (S, 3)) with stats rows
# [d_count, d_total, d_total_sq] summed over new_mask cells — the
# ``kernels.ops.fused_reveal_op`` contract.


def _auto_fused() -> bool:
    """Round-body default: the fused Pallas round everywhere except the
    ``REPRO_KERNEL_IMPL=ref`` lane, which keeps the unfused scatter chain
    as the oracle (the env var is ``kernels.ops._impl``'s dispatch knob;
    core reads it directly rather than importing the kernels layer)."""
    return os.environ.get("REPRO_KERNEL_IMPL", "auto") != "ref"


def _with_stats(compute_cells: Callable) -> Callable:
    """Adapt a plain gather-style cell source to the fused-round contract
    by deriving the statistic deltas in XLA (the reductions fuse with the
    gather; kernel-backed sources compute them in-kernel instead)."""

    def cells_fused(flat_doc, flat_tok, new_mask):
        v = compute_cells(flat_doc, flat_tok)
        nf = new_mask.astype(jnp.float32)
        vm = jnp.where(new_mask, v, 0.0)
        return v, jnp.stack([jnp.sum(nf, axis=-1), jnp.sum(vm, axis=-1),
                             jnp.sum(vm * v, axis=-1)], axis=-1)

    return cells_fused


class FrontierState(NamedTuple):
    """Resumable pooled-frontier carry — the slot-level continuous-batching
    state. The five BanditState statistics collapse to one sentinel-encoded
    cell table + one packed (n, total, total_sq) block; ``key``/``rounds``/
    ``done`` are per-SLOT. A serving loop holds one of these across
    ``run_pooled_slice`` calls: when slot q retires (``done[q]``), the host
    harvests its results and refills the slot with a new query — passing
    ``fresh[q]=True`` on the next call resets exactly that slot's rows
    (fresh init reveal included) while every other slot's statistics carry
    forward untouched. Both round bodies (fused and chain) read and write
    this same packed encoding at the call boundary, so a stream may even
    alternate bodies between slices.
    """

    cellvals: jax.Array    # (Q*N, T) f32 — _UNREV where unrevealed
    stats: jax.Array       # (Q*N, 3) f32 — [n, total, total_sq]
    key: jax.Array         # (Q,) per-query PRNG keys
    rounds: jax.Array      # (Q,) i32 — frozen at retirement
    done: jax.Array        # (Q,) bool


# Backwards-compatible internal alias (pre-resume name).
_FusedState = FrontierState


def init_frontier_state(Q: int, N: int, T: int) -> FrontierState:
    """An all-slots-empty carry: every slot retired (``done``), zero
    statistics, cell tables reading as revealed-empty (value 0.0 < the
    sentinel threshold, matching how both bodies encode invalid docs).
    Feed it as the first ``carry`` of a streaming loop — slots come alive
    only when refilled via ``fresh``."""
    return FrontierState(
        cellvals=jnp.zeros((Q * N, T), jnp.float32),
        stats=jnp.zeros((Q * N, 3), jnp.float32),
        key=jax.random.split(jax.random.key(0), Q),
        rounds=jnp.zeros((Q,), jnp.int32),
        done=jnp.ones((Q,), jnp.bool_))


class PooledResult(NamedTuple):
    topk: jax.Array            # (Q, K) i32 — per-query top-K doc slots
    s_hat: jax.Array           # (Q, N) f32 — final score estimates
    coverage: jax.Array        # (Q,) f32 — Eq. 6 per query
    reveals: jax.Array         # (Q,) i32 — |Omega_q|
    rounds: jax.Array          # (Q,) i32 — per-query LUCB rounds (frozen at
                               #   retirement; == solo rounds when blocks
                               #   are fixed)
    separated: jax.Array       # (Q,) bool — stopped via LCB >= UCB
    revealed: jax.Array        # (Q, N, T) bool — final observation sets
    trips: jax.Array           # () i32 — global while_loop iterations
                               #   (== max(rounds) by construction)
    total_rounds: jax.Array    # () i32 — sum(rounds): reveal rounds actually
                               #   attributable to queries
    lockstep_waste: jax.Array  # () i32 — Q*trips - total_rounds: rounds a
                               #   vmapped lockstep loop would have burned on
                               #   already-converged queries
    occupancy: jax.Array       # () f32 — mean fraction of frontier slots
                               #   holding live reveal work across trips
    quarantined: jax.Array     # (Q,) i32 — candidate docs whose revealed
                               #   cells included a non-finite value (the
                               #   finite-score guard excluded them from
                               #   the top-K; 0 everywhere on clean data)


def run_pooled_bandit(
    compute_cells,
    a: jax.Array,                # (Q, N, T) lower support per cell
    b: jax.Array,                # (Q, N, T) upper support per cell
    keys: jax.Array,             # (Q,) per-query PRNG keys
    cfg: BatchedConfig,
    *,
    doc_mask: Optional[jax.Array] = None,   # (Q, N) bool valid candidates
    compute_cells_fused=None,    # fused contract; derived when omitted
    fused: Optional[bool] = None,           # None => _auto_fused()
    prereveal: Optional[jax.Array] = None,      # (Q, N, T) bool — cells whose
    prereveal_vals: Optional[jax.Array] = None,  # exact values are known
    carry: Optional[FrontierState] = None,  # resume from a prior slice
    fresh: Optional[jax.Array] = None,      # (Q,) bool — slots to (re)init
    trip_limit: int = 0,                    # >0: pause after this many trips
    return_state: bool = False,             # also return the FrontierState
    alpha_scale=None,            # traced () f32 >= 1: per-call fidelity knob
    round_cap=None,              # traced () i32: per-call round cap (<=0 off)
):
    """``prereveal``/``prereveal_vals`` seed the bandit with cells whose
    exact values an earlier stage already computed (e.g. the stage-1 ANN
    hit cells, Eq. 15's exact-``h`` branch) at zero reveal cost: they enter
    the sufficient statistics before round 0, count as revealed for the
    selection policy (never re-revealed) and for ``reveals``/``coverage``.
    Both round bodies apply them identically.

    Streaming (continuous batching) extensions — all default-off, and the
    default path is trace-identical to the one-shot engine:

    * ``carry`` resumes from a prior call's :class:`FrontierState` instead
      of a cold start. ``fresh`` (default all-False when carrying, forced
      all-True otherwise) marks the slots being REFILLED this call: a fresh
      slot is fully re-initialized from this call's ``a``/``b``/``keys``/
      ``prereveal`` (init reveal included, prereveal masked to fresh slots)
      while carried slots' statistics, keys, round counters and retirement
      flags pass through untouched. Carried slots' ``a``/``b``/``doc_mask``
      must be re-presented unchanged — the packed state holds statistics,
      not supports.
    * ``trip_limit > 0`` pauses the global while_loop after that many trips
      even with queries still active, so the host can harvest retired slots
      mid-flight. Per-query results in the returned :class:`PooledResult`
      are only FINAL for slots with ``done`` set (or every slot once the
      loop ran to quiescence).
    * ``return_state=True`` returns ``(PooledResult, FrontierState)``.

    Degraded-fidelity knobs (serve-layer ladder; both TRACED scalars, so
    one compiled executable serves every fidelity level with zero
    recompiles — ``serfling_radius`` is linear in ``alpha_ef``, making the
    scale exact, not an approximation):

    * ``alpha_scale`` multiplies the effective ``alpha_ef`` for this call
      (wider radii => earlier separation => fewer reveals). ``None`` keeps
      the static config value with a trace identical to pre-knob code;
      passing ``1.0`` is numerically bit-identical to ``None``.
    * ``round_cap`` caps this call's per-query reveal rounds below the
      static ``cfg.max_rounds`` (values ``<= 0`` disable the cap).

    Finite-score guard (always on): any revealed cell that comes back
    non-finite is recorded as the ``_QUAR`` sentinel; its doc is excluded
    from the final top-K and counted in ``PooledResult.quarantined``. On
    all-finite data every guard op is an identity, so clean runs stay
    bit-identical to pre-guard code.
    """
    if fused is None:
        fused = _auto_fused()
    Q, N, T = a.shape
    if carry is None:
        fresh = jnp.ones((Q,), jnp.bool_)
    elif fresh is None:
        fresh = jnp.zeros((Q,), jnp.bool_)
    fresh = fresh.astype(jnp.bool_)
    fresh_rows = jnp.broadcast_to(fresh[:, None], (Q, N)).reshape(Q * N)
    k = cfg.k
    G = cfg.block_tokens
    half = max(cfg.block_docs // 2, 1)
    # Selection widths per query: fixed (== solo) unless growth is enabled.
    # Clamped to N / T: a query can never hold more than its N candidate
    # rows or T tokens, and an unclamped width would surface as an opaque
    # top_k shape error (reachable from EngineConfig alone on small
    # buckets).
    half_w = min(max(cfg.max_block_docs // 2, half), max(N, 1))
    W = 2 * half_w                           # per-query selection rows
    G_cap = min(max(cfg.max_block_tokens, G), max(T, 1))  # token sel width
    F = Q * 2 * half                         # frontier capacity (slots)
    max_rounds = cfg.max_rounds
    if max_rounds <= 0:
        max_rounds = (N * T) // max(cfg.block_docs * G, 1) + T + 8
    if round_cap is not None:
        # Traced per-call cap: <= 0 disables (the compiled program is one
        # executable for every ladder level). Python-int path untouched.
        rc = jnp.asarray(round_cap, jnp.int32)
        max_rounds = jnp.minimum(
            jnp.int32(max_rounds), jnp.where(rc > 0, rc, jnp.int32(max_rounds)))
    if doc_mask is None:
        doc_mask = jnp.ones((Q, N), jnp.bool_)
    a = jnp.where(doc_mask[:, :, None], a, 0.0).astype(jnp.float32)
    b = jnp.where(doc_mask[:, :, None], b, 0.0).astype(jnp.float32)

    if prereveal is not None:
        pr_flat = (prereveal & doc_mask[:, :, None]).reshape(Q * N, T)
        if carry is not None:
            # Prereveal seeds belong to the query ENTERING a slot; a
            # carried slot already absorbed its own at its fresh call.
            pr_flat = pr_flat & fresh_rows[:, None]
        pv_flat = jnp.where(
            pr_flat, prereveal_vals.reshape(Q * N, T).astype(jnp.float32),
            0.0)
        # Stage-1 seeds computed over a poisoned corpus row are non-finite
        # too — same quarantine treatment as a live reveal.
        pv_flat = jnp.where(jnp.isfinite(pv_flat), pv_flat, _QUAR)
    else:
        pr_flat = pv_flat = None

    q_doc_off = (jnp.arange(Q, dtype=jnp.int32) * N)[:, None]       # (Q, 1)

    # Per-query init split — same stream as run_batched_bandit's
    # ``key, k_init = split(key)`` so trajectories line up query by query.
    split2 = jax.vmap(lambda kk: tuple(jax.random.split(kk)))
    state_keys, k_init = split2(keys)
    if carry is not None:
        state_keys = jnp.where(fresh, state_keys, carry.key)

    # Init reveal (paper footnote 2): one random cell per doc, all queries
    # pooled into a single (Q*N, 1) reveal.
    t0 = jax.vmap(lambda kk: jax.random.randint(kk, (N,), 0, T))(k_init)
    all_docs = jnp.arange(Q * N, dtype=jnp.int32)
    flat_t0 = t0.reshape(Q * N, 1)

    iv_kwargs = dict(T=T, N=N, delta=cfg.delta, alpha_ef=cfg.alpha_ef,
                     c=cfg.radius_c, bias_kappa=cfg.bias_kappa)
    if alpha_scale is not None:
        # serfling_radius is LINEAR in alpha_ef (checked by the fidelity
        # tests), so a traced effective alpha is exact — and x * 1.0 is an
        # IEEE identity, so scale 1.0 stays bit-identical to the static
        # config value.
        iv_kwargs["alpha_ef"] = (jnp.float32(cfg.alpha_ef)
                                 * jnp.asarray(alpha_scale, jnp.float32))

    def sanitize(vals):
        """Finite-score guard on a block of freshly revealed cell values:
        identity on finite data, _QUAR where poisoned."""
        return jnp.where(jnp.isfinite(vals), vals, _QUAR)

    def get_intervals_q(n_q, total_q, total_sq_q, revealed_q, a_q, b_q,
                        mask_q) -> B.Intervals:
        iv = B.intervals(n_q, total_q, total_sq_q, revealed_q, a_q, b_q,
                         **iv_kwargs)
        return iv._replace(
            s_hat=jnp.where(mask_q, iv.s_hat, _NEG),
            lcb=jnp.where(mask_q, iv.lcb, _NEG),
            ucb=jnp.where(mask_q, iv.ucb, _NEG),
        )

    select_q = functools.partial(_round_select, k=k, epsilon=cfg.epsilon,
                                 half=half_w, G=G_cap)

    def select_round(st_key, iv, revealed_q, n_q, active, *, compact):
        """Shared round front-end: per-query LUCB selection, capacity
        allotment over both growth axes, and frontier pooling. Returns the
        raw selection (for key/stop bookkeeping), the pooled (doc, tok,
        cell) arrays, the per-query no-progress flags, and this round's
        frontier occupancy."""
        sel = jax.vmap(select_q)(st_key, iv, revealed_q, n_q, a, b, doc_mask)

        # Capacity allotment: freed DOC slots are split evenly among active
        # queries (never below the solo width, never above the selection
        # width), and remaining CELL capacity (F*G cells per round) widens
        # each surviving slot's token block — 2-D continuous batching.
        n_active = jnp.maximum(jnp.sum(active.astype(jnp.int32)), 1)
        per_group = jnp.clip(F // (2 * n_active), half, half_w)
        per_tok = jnp.clip((F * G) // (n_active * 2 * per_group), G, G_cap)
        grp_en = jnp.arange(half_w, dtype=jnp.int32) < per_group
        doc_en = jnp.concatenate([grp_en, grp_en])              # (W,)
        tok_en = jnp.arange(G_cap, dtype=jnp.int32) < per_tok   # (G_cap,)

        live = active & ~sel.stop                               # (Q,)
        sel_en = (sel.cell_ok & doc_en[None, :, None]
                  & tok_en[None, None, :])                      # (Q, W, G_cap)
        cell_en = sel_en & live[:, None, None]
        no_progress = ~jnp.any(sel_en, axis=(1, 2))

        flat_doc = (sel.doc_idx + q_doc_off).reshape(Q * W)
        flat_tok = sel.tok_idx.reshape(Q * W, G_cap)
        flat_cell = cell_en.reshape(Q * W, G_cap)
        slot_live = jnp.any(flat_cell, axis=-1)                 # (Q*W,)
        if compact:
            # Pool + compact: scatter live slots to the frontier front; the
            # overflow index F is dropped, so retired queries' slots vanish
            # and the launch batch stays at the fixed capacity F < Q*W.
            pos = jnp.cumsum(slot_live.astype(jnp.int32)) - 1
            dump = jnp.where(slot_live, pos, F)
            f_doc = jnp.zeros((F,), jnp.int32).at[dump].set(flat_doc,
                                                            mode="drop")
            f_tok = jnp.zeros((F, G_cap), jnp.int32).at[dump].set(
                flat_tok, mode="drop")
            f_cell = jnp.zeros((F, G_cap), jnp.bool_).at[dump].set(
                flat_cell, mode="drop")
        else:
            # No growth => capacity == selection width: feed the flat
            # selections straight to the launch (dead slots are masked
            # no-ops) and skip the cumsum + three compaction scatters.
            f_doc, f_tok, f_cell = flat_doc, flat_tok, flat_cell
        occ = jnp.sum(slot_live.astype(jnp.float32)) / jnp.float32(F)
        return sel, f_doc, f_tok, f_cell, no_progress, occ

    def finalize(n, total, total_sq, revealed, rounds, trips, occ_sum,
                 quar_doc):
        iv = jax.vmap(get_intervals_q)(
            n.reshape(Q, N), total.reshape(Q, N), total_sq.reshape(Q, N),
            revealed.reshape(Q, N, T), a, b, doc_mask)
        # Quarantined docs (any revealed cell tripped the finite-score
        # guard) are forced out of the top-K; identity when none did.
        quar_q = quar_doc.reshape(Q, N) & doc_mask
        iv = iv._replace(s_hat=jnp.where(quar_q, _NEG, iv.s_hat))
        tk = jax.vmap(functools.partial(_topk_mask, k=k))(iv.s_hat)
        topk_idx = tk[1]
        sep = jax.vmap(lambda iv_q, m_q: _select_arms(iv_q, _topk_mask(
            iv_q.s_hat, k)[0], m_q))(iv, doc_mask)
        separated = jax.vmap(
            lambda iv_q, ip, im: iv_q.lcb[ip] >= iv_q.ucb[im])(
            iv, sep[0], sep[1])

        rev_q = revealed.reshape(Q, N, T) & doc_mask[:, :, None]
        n_rev = jnp.sum(rev_q, axis=(1, 2))
        n_cells = jnp.maximum(jnp.sum(doc_mask, axis=1) * T, 1)
        total_rounds = jnp.sum(rounds)
        return PooledResult(
            topk=topk_idx,
            s_hat=iv.s_hat,
            coverage=n_rev.astype(jnp.float32) / n_cells.astype(jnp.float32),
            reveals=n_rev.astype(jnp.int32),
            rounds=rounds,
            separated=separated,
            revealed=rev_q,
            trips=trips,
            total_rounds=total_rounds,
            # Clamped: on a resumed slice, carried-in rounds can exceed
            # this slice's Q*trips budget.
            lockstep_waste=jnp.maximum(Q * trips - total_rounds, 0),
            occupancy=occ_sum / jnp.maximum(trips.astype(jnp.float32), 1.0),
            quarantined=jnp.sum(quar_q, axis=1).astype(jnp.int32),
        )

    def cond(loop_carry):
        st, trips, _ = loop_carry
        go = jnp.any((~st.done) & (st.rounds < max_rounds))
        if trip_limit > 0:
            go = jnp.logical_and(go, trips < trip_limit)
        return go

    # Queries with NO valid candidate start retired (rounds stay 0):
    # routine on a sharded corpus, where a query's candidates may all be
    # resident elsewhere — an empty query must not hold frontier slots
    # or inflate the per-shard round/occupancy accounting.
    done0 = ~jnp.any(doc_mask, axis=1)
    rounds0 = jnp.zeros((Q,), jnp.int32)
    if carry is not None:
        done0 = jnp.where(fresh, done0, carry.done)
        rounds0 = jnp.where(fresh, rounds0, carry.rounds)
    zero_trip = (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))

    if fused:
        cells_fused = (compute_cells_fused if compute_cells_fused is not None
                       else _with_stats(compute_cells))
        flat_mask = doc_mask.reshape(Q * N)

        new0 = flat_mask[:, None]                               # (Q*N, 1)
        if carry is not None:
            new0 = new0 & fresh_rows[:, None]
        if pr_flat is not None:
            # An init cell that stage 1 already revealed is not new: it must
            # enter the stats exactly once (mirrors _apply_block_reveal's
            # ``already`` skip in the chain body).
            already0 = jnp.take_along_axis(pr_flat, flat_t0, axis=1)
            new0 = new0 & ~already0
        vals0, stats0 = cells_fused(all_docs,
                                    flat_t0 + (all_docs // N * T)[:, None],
                                    new0)
        # Finite-score guard: sanitize the revealed values and, for rows
        # where a non-finite value slipped into the in-kernel statistic
        # accumulation, rebuild that row's deltas from the sanitized
        # values. Rows with only finite cells keep the kernel's own stats
        # bit for bit (no re-summation => chain/fused parity untouched).
        bad0 = new0 & ~jnp.isfinite(vals0)
        vals0 = sanitize(vals0)
        vm0 = jnp.where(new0, vals0, 0.0)
        fix0 = jnp.stack([jnp.sum(new0.astype(jnp.float32), -1),
                          jnp.sum(vm0, -1), jnp.sum(vm0 * vm0, -1)], axis=-1)
        stats0 = jnp.where(jnp.any(bad0, -1)[:, None], fix0, stats0)
        cellvals0 = jnp.where(flat_mask[:, None],
                              jnp.full((Q * N, T), _UNREV), 0.0)
        if pr_flat is not None:
            cellvals0 = jnp.where(pr_flat, pv_flat, cellvals0)
            stats0 = stats0 + jnp.stack(
                [jnp.sum(pr_flat, -1).astype(jnp.float32),
                 jnp.sum(pv_flat, -1), jnp.sum(pv_flat * pv_flat, -1)],
                axis=-1)
        cellvals0 = cellvals0.at[all_docs[:, None], flat_t0].min(
            jnp.where(new0, vals0, _UNREV))
        if carry is not None:
            cellvals0 = jnp.where(fresh_rows[:, None], cellvals0,
                                  carry.cellvals)
            stats0 = jnp.where(fresh_rows[:, None], stats0, carry.stats)
        state = _FusedState(cellvals=cellvals0, stats=stats0,
                            key=state_keys, rounds=rounds0, done=done0)

        def body(carry):
            st, trips, occ_sum = carry
            active = (~st.done) & (st.rounds < max_rounds)       # (Q,)
            revealed = st.cellvals < _REV_THRESH                 # (Q*N, T)
            n_q = st.stats[:, 0].reshape(Q, N)
            iv = jax.vmap(get_intervals_q)(
                n_q, st.stats[:, 1].reshape(Q, N),
                st.stats[:, 2].reshape(Q, N), revealed.reshape(Q, N, T),
                a, b, doc_mask)
            sel, f_doc, f_tok, f_cell, no_progress, occ = select_round(
                st.key, iv, revealed.reshape(Q, N, T), n_q, active,
                compact=half_w > half)

            # ONE fused reveal launch + a two-scatter state update. No
            # already-revealed re-check here: the selection policy only
            # ever emits unrevealed cells (``_round_select`` masks width
            # and gumbel draws to _NEG on revealed cells and ``cell_ok``
            # thresholds them out), so ``f_cell`` IS the fresh-cell mask.
            # The chain oracle keeps the defensive re-check; the parity
            # tests (identical reveal counts and trajectories) pin that
            # the invariant holds.
            new = f_cell
            vals, dstats = cells_fused(
                f_doc, f_tok + (f_doc // N * T)[:, None], new)
            # Finite-score guard (same contract as the init reveal): only
            # rows that actually saw a non-finite value get their stat
            # deltas rebuilt from the sanitized values.
            bad = new & ~jnp.isfinite(vals)
            vals = sanitize(vals)
            vm = jnp.where(new, vals, 0.0)
            fix = jnp.stack([jnp.sum(new.astype(jnp.float32), -1),
                             jnp.sum(vm, -1), jnp.sum(vm * vm, -1)],
                            axis=-1)
            dstats = jnp.where(jnp.any(bad, -1)[:, None], fix, dstats)
            cellvals = st.cellvals.at[f_doc[:, None], f_tok].min(
                jnp.where(new, vals, _UNREV))
            stats = st.stats.at[f_doc].add(dstats)

            nxt = _FusedState(
                cellvals=cellvals, stats=stats, key=sel.key,
                rounds=st.rounds + active.astype(jnp.int32),
                done=st.done | (active & (sel.stop | no_progress)))
            return nxt, trips + 1, occ_sum + occ

        state, trips, occ_sum = jax.lax.while_loop(
            cond, body, (state, *zero_trip))
        res = finalize(state.stats[:, 0], state.stats[:, 1],
                       state.stats[:, 2], state.cellvals < _REV_THRESH,
                       state.rounds, trips, occ_sum,
                       jnp.any(state.cellvals <= _QUAR_THRESH, axis=-1))
        return (res, state) if return_state else res

    # ------------------------------------------------------------------
    # Chain round body — the REPRO_KERNEL_IMPL=ref oracle: abstract cell
    # gather + the classic five-scatter _apply_block_reveal update over a
    # stacked BanditState. Kept bit-identical to the pre-fusion engine.
    # ------------------------------------------------------------------
    state = BanditState(
        values=jnp.zeros((Q * N, T), jnp.float32),
        revealed=(~doc_mask[:, :, None]).reshape(Q * N, 1)
        & jnp.ones((Q * N, T), jnp.bool_),
        n=jnp.zeros((Q * N,), jnp.int32),
        total=jnp.zeros((Q * N,), jnp.float32),
        total_sq=jnp.zeros((Q * N,), jnp.float32),
        key=state_keys,                     # (Q,) keys — per-query streams
        rounds=rounds0,                     # per-query round counters
        done=done0,                         # per-query retirement flags
    )

    if carry is not None:
        # Unpack the sentinel encoding into the five-field BanditState for
        # carried rows (fresh rows keep the cold-start init above). The
        # encoding is lossless: revealed <=> cellvals below the sentinel
        # threshold, and unrevealed values are definitionally 0 here.
        c_rev = carry.cellvals < _REV_THRESH
        fr = fresh_rows[:, None]
        state = state._replace(
            values=jnp.where(fr, state.values,
                             jnp.where(c_rev, carry.cellvals, 0.0)),
            revealed=jnp.where(fr, state.revealed, c_rev),
            n=jnp.where(fresh_rows, state.n,
                        carry.stats[:, 0].astype(jnp.int32)),
            total=jnp.where(fresh_rows, state.total, carry.stats[:, 1]),
            total_sq=jnp.where(fresh_rows, state.total_sq,
                               carry.stats[:, 2]),
        )

    if pr_flat is not None:
        # Seed the statistics with the prerevealed cells; the init reveal
        # below then skips them via _apply_block_reveal's ``already`` check.
        state = state._replace(
            values=state.values + pv_flat,
            revealed=state.revealed | pr_flat,
            n=state.n + jnp.sum(pr_flat, -1).astype(jnp.int32),
            total=state.total + jnp.sum(pv_flat, -1),
            total_sq=state.total_sq + jnp.sum(pv_flat * pv_flat, -1))

    init_vals = sanitize(compute_cells(all_docs,
                                       flat_t0 + (all_docs // N * T)[:, None]))
    init_valid = doc_mask.reshape(Q * N, 1)
    if carry is not None:
        init_valid = init_valid & fresh_rows[:, None]
    state = _apply_block_reveal(state, all_docs, flat_t0, init_vals,
                                init_valid)

    def per_query_intervals(st: BanditState) -> B.Intervals:
        return jax.vmap(get_intervals_q)(
            st.n.reshape(Q, N), st.total.reshape(Q, N),
            st.total_sq.reshape(Q, N), st.revealed.reshape(Q, N, T),
            a, b, doc_mask)

    def body(carry):
        st, trips, occ_sum = carry
        active = (~st.done) & (st.rounds < max_rounds)          # (Q,)

        iv = per_query_intervals(st)
        sel, f_doc, f_tok, f_cell, no_progress, occ = select_round(
            st.key, iv, st.revealed.reshape(Q, N, T), st.n.reshape(Q, N),
            active, compact=True)

        # ONE pooled reveal for the whole batch round, then the scatter
        # chain into the stacked statistics.
        vals = sanitize(compute_cells(f_doc, f_tok + (f_doc // N * T)[:, None]))
        nxt = _apply_block_reveal(st, f_doc, f_tok, vals, f_cell)

        # Per-query bookkeeping — mirrors the solo loop's cond/stop exactly:
        # a query that separates this round reveals nothing (its slots were
        # masked out of the frontier) and retires with rounds+1.
        nxt = nxt._replace(
            key=sel.key,
            rounds=st.rounds + active.astype(jnp.int32),
            done=st.done | (active & (sel.stop | no_progress)),
        )
        return nxt, trips + 1, occ_sum + occ

    state, trips, occ_sum = jax.lax.while_loop(
        cond, body, (state, *zero_trip))
    res = finalize(state.n, state.total, state.total_sq, state.revealed,
                   state.rounds, trips, occ_sum,
                   jnp.any(state.revealed & (state.values <= _QUAR_THRESH),
                           axis=-1))
    if return_state:
        # Pack back to the sentinel encoding — the shared slice boundary
        # format, so a stream may resume under either round body.
        packed = FrontierState(
            cellvals=jnp.where(state.revealed, state.values, _UNREV),
            stats=jnp.stack([state.n.astype(jnp.float32), state.total,
                             state.total_sq], axis=-1),
            key=state.key, rounds=state.rounds, done=state.done)
        return res, packed
    return res


def run_pooled_slice(
    compute_cells,
    a: jax.Array, b: jax.Array, keys: jax.Array, cfg: BatchedConfig,
    carry: FrontierState,
    fresh: jax.Array,
    *, trip_limit: int, **kw,
) -> tuple:
    """One bounded segment of the pooled bandit — the continuous-batching
    step. Resume from ``carry``, re-initialize the ``fresh`` slots from
    this call's ``a``/``b``/``keys`` (and ``prereveal``/``doc_mask`` via
    ``**kw``), run at most ``trip_limit`` global while_loop trips, and
    return ``(PooledResult, FrontierState)``. The host loop harvests slots
    whose returned ``state.done`` is set (their PooledResult rows are
    final), marks them fresh, and calls again — the other slots' bandit
    state rides through unchanged. Start a stream from
    :func:`init_frontier_state` with ``fresh`` all-True."""
    return run_pooled_bandit(compute_cells, a, b, keys, cfg, carry=carry,
                             fresh=fresh, trip_limit=trip_limit,
                             return_state=True, **kw)


def run_pooled_oracle(
    h_full: jax.Array, a: jax.Array, b: jax.Array, keys: jax.Array, *,
    fused: Optional[bool] = None, **kw,
) -> PooledResult:
    """Oracle-mode pooled engine: cells come from a precomputed (Q, N, T)
    H tensor. The flat token ids are mapped back to each slot's own query
    (doc q*N+i only ever pairs with tokens q*T+t), mirroring the stacked
    gather_maxsim contract. ``fused`` picks the round body (None = auto:
    fused unless REPRO_KERNEL_IMPL=ref); both bodies reveal identical
    cells.

    ``fused=None`` is resolved HERE, outside the jit boundary: were it a
    static arg resolved inside the trace, the compiled cache entry for
    ``None`` would pin whichever REPRO_KERNEL_IMPL was set at first call
    and silently serve the wrong round body after a same-process env
    change (the monkeypatch pattern the kernel tests rely on)."""
    return _pooled_oracle_jit(h_full, a, b, keys,
                              fused=_auto_fused() if fused is None
                              else fused, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("k", "delta", "alpha_ef", "epsilon", "radius_c",
                     "block_docs", "block_tokens", "max_rounds",
                     "bias_kappa", "max_block_docs", "max_block_tokens",
                     "fused"),
)
def _pooled_oracle_jit(
    h_full: jax.Array, a: jax.Array, b: jax.Array, keys: jax.Array, *,
    k: int, fused: bool, delta: float = 0.01, alpha_ef: float = 0.3,
    epsilon: float = 0.1, radius_c: float = 1.0, bias_kappa: float = 0.0,
    block_docs: int = 8, block_tokens: int = 8, max_rounds: int = -1,
    max_block_docs: int = 0, max_block_tokens: int = 0,
    doc_mask: Optional[jax.Array] = None,
) -> PooledResult:
    Q, N, T = h_full.shape
    cfg = BatchedConfig(k=k, delta=delta, alpha_ef=alpha_ef, epsilon=epsilon,
                        radius_c=radius_c, bias_kappa=bias_kappa,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds, max_block_docs=max_block_docs,
                        max_block_tokens=max_block_tokens)
    h_flat = h_full.reshape(Q * N, T)

    def cells(flat_doc: jax.Array, flat_tok: jax.Array) -> jax.Array:
        t_local = flat_tok - (flat_doc // N * T)[:, None]
        return h_flat[flat_doc[:, None], jnp.clip(t_local, 0, T - 1)]

    return run_pooled_bandit(cells, a, b, keys, cfg, doc_mask=doc_mask,
                             fused=fused)
