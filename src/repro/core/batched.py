"""Block-synchronous Col-Bandit — the TPU-native adaptation (DESIGN.md §2).

The paper's Algorithm 1 reveals ONE cell per iteration; on TPU that serializes
the MXU. Here every round:

  1. computes all hybrid intervals (vectorized, Eq. 13/14),
  2. checks the LUCB stopping rule (unchanged),
  3. selects the B/2 weakest winners and B/2 strongest losers (the natural
     batch generalization of {i+, i-}),
  4. reveals G tokens per selected doc (epsilon-greedy max-width, unchanged
     policy, applied top-G instead of top-1),
  5. updates statistics with one vectorized masked update.

Statistics over revealed cells are exact, so every bound stays valid; the only
behavioural difference vs. the paper is coverage granularity (B*G cells per
round instead of 1). The paper's own Future Work section calls for exactly
this ("reveals blocks of high-uncertainty cells simultaneously").

The reveal is abstracted as ``compute_cells(doc_idx, tok_idx) -> values`` so
the same control loop drives (a) the precomputed-H oracle used in benchmarks
and (b) the gathered MaxSim Pallas kernel used in serving
(``repro.retrieval.service``).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bounds as B
from repro.core.bandit import BanditResult, _select_arms, _topk_mask
from repro.core.state import BanditState, init_state

_NEG = jnp.float32(-3e38)

CellFn = Callable[[jax.Array, jax.Array], jax.Array]  # (B,), (B,G) -> (B,G)


class BatchedConfig(NamedTuple):
    k: int
    delta: float = 0.01
    alpha_ef: float = 0.3
    epsilon: float = 0.1
    radius_c: float = 1.0
    bias_kappa: float = 0.0
    block_docs: int = 8       # B
    block_tokens: int = 8     # G
    max_rounds: int = -1      # -1 => ceil(N*T / (B*G)) + margin
    # Pooled cross-query engine only (repro.core.frontier): when > block_docs,
    # queries that retire from the shared frontier free their reveal slots and
    # still-active queries may grow their per-round doc block up to this many
    # docs. 0 (default) keeps blocks fixed at ``block_docs``, which preserves
    # exact per-query trajectory parity with ``run_batched_bandit``.
    max_block_docs: int = 0
    # Second growth axis (pooled engine only): when > block_tokens, freed
    # frontier CELL capacity also widens each surviving slot's token block
    # up to this many tokens per selected doc. 0 keeps token blocks fixed
    # at ``block_tokens`` (solo-trajectory parity, as above).
    max_block_tokens: int = 0


def _apply_block_reveal(state: BanditState, doc_idx: jax.Array,
                        tok_idx: jax.Array, vals: jax.Array,
                        valid: jax.Array) -> BanditState:
    """Vectorized reveal of cells {(doc_idx[b], tok_idx[b,g])}: scatter the
    values + update running (n, total, total_sq). Skips already-revealed and
    invalid entries.

    Only touches the statistics fields (values/revealed/n/total/total_sq);
    key/rounds/done pass through untouched, so the pooled cross-query engine
    can hold its stacked (Q*N, T) statistics in the same ``BanditState``
    container and reuse this scatter unchanged with query-offset doc ids."""
    already = state.revealed[doc_idx[:, None], tok_idx]        # (B, G)
    new = valid & ~already
    newf = new.astype(jnp.float32)
    vals = vals.astype(jnp.float32)
    # Unrevealed slots hold 0.0 and `new` excludes re-reveals, so scatter-add
    # writes each value exactly once (works for negative similarities too).
    values = state.values.at[doc_idx[:, None], tok_idx].add(
        jnp.where(new, vals, 0.0))
    # Scatter-OR (max), not set: the pooled frontier points its empty slots
    # at (doc 0, tok 0) with valid=False, so that cell can receive BOTH a
    # live True and an empty slot's pass-through — duplicate scatter-set
    # writes would race and could clobber the reveal.
    revealed = state.revealed.at[doc_idx[:, None], tok_idx].max(
        new | already)
    n = state.n.at[doc_idx].add(jnp.sum(new, axis=-1).astype(jnp.int32))
    total = state.total.at[doc_idx].add(jnp.sum(newf * vals, axis=-1))
    total_sq = state.total_sq.at[doc_idx].add(jnp.sum(newf * vals * vals, axis=-1))
    return state._replace(values=values, revealed=revealed, n=n, total=total,
                          total_sq=total_sq)


class RoundSelection(NamedTuple):
    """One round's block selection — the policy output shared by the solo
    loop below and the pooled cross-query engine (repro.core.frontier)."""

    key: jax.Array        # advanced PRNG key (next round's state key)
    doc_idx: jax.Array    # (2*half,) i32 selected docs (winners ++ losers)
    tok_idx: jax.Array    # (2*half, G) i32 selected tokens per doc
    cell_ok: jax.Array    # (2*half, G) bool — cell is fresh and selectable
    stop: jax.Array       # () bool — LUCB separation reached this round


def _round_select(key: jax.Array, iv: B.Intervals, revealed: jax.Array,
                  n: jax.Array, a: jax.Array, b: jax.Array,
                  doc_mask: jax.Array, *, k: int, epsilon: float, half: int,
                  G: int) -> RoundSelection:
    """LUCB block selection (Sec. 4.3, batched): ``half`` weakest winners +
    ``half`` strongest losers, G epsilon-greedy max-width tokens per doc.

    Pure function of (key, statistics) so the solo ``run_batched_bandit``
    and the pooled frontier engine (which vmaps it over queries) make
    bit-identical choices from identical per-query state — the property the
    frontier-retirement tests pin down."""
    T = a.shape[1]
    tk_mask, _ = _topk_mask(iv.s_hat, k)
    i_plus, i_minus = _select_arms(iv, tk_mask, doc_mask)
    stop = iv.lcb[i_plus] >= iv.ucb[i_minus]

    has_unrev = n < T
    # half weakest winners: smallest LCB within the current top-K.
    win_score = jnp.where(tk_mask & doc_mask & has_unrev, -iv.lcb, _NEG)
    _, win_idx = jax.lax.top_k(win_score, half)
    win_ok = jnp.take(win_score, win_idx) > _NEG / 2
    # half strongest losers: largest UCB outside the top-K.
    lose_score = jnp.where(~tk_mask & doc_mask & has_unrev, iv.ucb, _NEG)
    _, lose_idx = jax.lax.top_k(lose_score, half)
    lose_ok = jnp.take(lose_score, lose_idx) > _NEG / 2

    doc_idx = jnp.concatenate([win_idx, lose_idx]).astype(jnp.int32)
    doc_ok = jnp.concatenate([win_ok, lose_ok])            # (2*half,)

    # Token choice per selected doc: epsilon-greedy max-width, top-G.
    key, k_eps, k_tok = jax.random.split(key, 3)
    unrev = ~revealed[doc_idx]                             # (2*half, T)
    width = jnp.where(unrev, b[doc_idx] - a[doc_idx], _NEG)
    gumbel = jnp.where(unrev, jax.random.gumbel(k_tok, width.shape), _NEG)
    explore = jax.random.uniform(k_eps, (doc_idx.shape[0], 1)) < epsilon
    sel_score = jnp.where(explore, gumbel, width)
    top_w, tok_idx = jax.lax.top_k(sel_score, G)           # (2*half, G)
    cell_ok = (top_w > _NEG / 2) & doc_ok[:, None]
    return RoundSelection(key=key, doc_idx=doc_idx,
                          tok_idx=tok_idx.astype(jnp.int32),
                          cell_ok=cell_ok, stop=stop)


def run_batched_bandit(
    compute_cells: CellFn,
    a: jax.Array,                # (N, T)
    b: jax.Array,                # (N, T)
    key: jax.Array,
    cfg: BatchedConfig,
    *,
    doc_mask: Optional[jax.Array] = None,
) -> BanditResult:
    N, T = a.shape
    k = cfg.k
    Bd, G = cfg.block_docs, cfg.block_tokens
    half = max(Bd // 2, 1)
    max_rounds = cfg.max_rounds
    if max_rounds <= 0:
        max_rounds = (N * T) // max(Bd * G, 1) + T + 8
    if doc_mask is None:
        doc_mask = jnp.ones((N,), jnp.bool_)
    a = jnp.where(doc_mask[:, None], a, 0.0).astype(jnp.float32)
    b = jnp.where(doc_mask[:, None], b, 0.0).astype(jnp.float32)

    key, k_init = jax.random.split(key)
    state = init_state(N, T, key)
    state = state._replace(revealed=state.revealed | ~doc_mask[:, None])

    # Init: one random cell per doc (paper footnote 2) — here as one G-column
    # block per doc would overshoot, so reveal exactly one cell per doc via a
    # strided pass of the same block primitive.
    t0 = jax.random.randint(k_init, (N,), 0, T)
    all_docs = jnp.arange(N, dtype=jnp.int32)
    init_vals = compute_cells(all_docs, t0[:, None])          # (N, 1)
    state = _apply_block_reveal(state, all_docs, t0[:, None], init_vals,
                                doc_mask[:, None])

    iv_kwargs = dict(T=T, N=N, delta=cfg.delta, alpha_ef=cfg.alpha_ef,
                     c=cfg.radius_c, bias_kappa=cfg.bias_kappa)

    def get_intervals(st: BanditState) -> B.Intervals:
        iv = B.intervals(st.n, st.total, st.total_sq, st.revealed, a, b,
                         **iv_kwargs)
        return iv._replace(
            s_hat=jnp.where(doc_mask, iv.s_hat, _NEG),
            lcb=jnp.where(doc_mask, iv.lcb, _NEG),
            ucb=jnp.where(doc_mask, iv.ucb, _NEG),
        )

    def cond(st: BanditState) -> jax.Array:
        return (~st.done) & (st.rounds < max_rounds)

    def body(st: BanditState) -> BanditState:
        iv = get_intervals(st)
        sel = _round_select(st.key, iv, st.revealed, st.n, a, b, doc_mask,
                            k=k, epsilon=cfg.epsilon, half=half, G=G)
        vals = compute_cells(sel.doc_idx, sel.tok_idx)
        nxt = _apply_block_reveal(st, sel.doc_idx, sel.tok_idx, vals,
                                  sel.cell_ok)
        no_progress = ~jnp.any(sel.cell_ok)
        nxt = nxt._replace(key=sel.key, rounds=st.rounds + 1,
                           done=sel.stop | no_progress)
        # On stop, keep the pre-reveal observation set (don't pay for it).
        return jax.lax.cond(
            sel.stop,
            lambda s: s._replace(key=sel.key, rounds=s.rounds + 1, done=True),
            lambda s: nxt,
            st)

    state = jax.lax.while_loop(cond, body, state)

    iv = get_intervals(state)
    tk_mask, topk_idx = _topk_mask(iv.s_hat, k)
    i_plus, i_minus = _select_arms(iv, tk_mask, doc_mask)
    n_rev = jnp.sum(state.revealed & doc_mask[:, None])
    n_cells = jnp.maximum(jnp.sum(doc_mask) * T, 1)
    return BanditResult(
        topk=topk_idx,
        coverage=n_rev.astype(jnp.float32) / n_cells.astype(jnp.float32),
        reveals=n_rev.astype(jnp.int32),
        rounds=state.rounds,
        separated=iv.lcb[i_plus] >= iv.ucb[i_minus],
        s_hat=iv.s_hat,
        revealed=state.revealed & doc_mask[:, None],
    )


@functools.partial(
    jax.jit,
    static_argnames=("k", "delta", "alpha_ef", "epsilon", "radius_c",
                     "block_docs", "block_tokens", "max_rounds",
                     "bias_kappa"),
)
def run_batched_oracle(
    h_full: jax.Array, a: jax.Array, b: jax.Array, key: jax.Array, *,
    k: int, delta: float = 0.01, alpha_ef: float = 0.3, epsilon: float = 0.1,
    radius_c: float = 1.0, bias_kappa: float = 0.0, block_docs: int = 8,
    block_tokens: int = 8, max_rounds: int = -1,
    doc_mask: Optional[jax.Array] = None,
) -> BanditResult:
    """Oracle-mode batched bandit: cells come from a precomputed H matrix."""
    cfg = BatchedConfig(k=k, delta=delta, alpha_ef=alpha_ef, epsilon=epsilon,
                        radius_c=radius_c, bias_kappa=bias_kappa,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds)

    def cells(doc_idx: jax.Array, tok_idx: jax.Array) -> jax.Array:
        return h_full[doc_idx[:, None], tok_idx]

    return run_batched_bandit(cells, a, b, key, cfg, doc_mask=doc_mask)
