"""Beyond-paper extension: finite-population Top-K identification over ANY
sum-decomposable score (DESIGN.md §Arch-applicability).

The paper's machinery only needs (i) per-candidate scores of the form
S_i = sum_t C_{i,t} with a finite component set, and (ii) known support
[a, b] per component. MaxSim matrices are one instance; we reuse the exact
same bounds/LUCB loop for:

  * FM retrieval      — C_{i,f} = contribution of field-pair block f to the
                         FM score of candidate i (sum-square trick per block),
  * AutoInt retrieval — C_{i,f} = per-field interaction logit contribution,
  * SASRec/DIN        — C_{i,g} = per-dimension-group partial dot product of
                         user state with candidate item embedding.

This turns "score 10^6 candidates" into "reveal only the component blocks
needed to separate the top-K", the direct analogue of the paper's regime.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.bandit import BanditResult, run_bandit
from repro.core.batched import run_batched_oracle


def component_support(components: jax.Array,
                      slack: float = 0.0) -> Tuple[jax.Array, jax.Array]:
    """Per-column support [a_t, b_t] for a component matrix (N, T): the
    tightest bounds available without revealing which row is which.
    ``slack`` widens the interval (robustness against estimation error when
    supports come from a sample)."""
    a = jnp.min(components, axis=0) - slack     # (T,)
    b = jnp.max(components, axis=0) + slack
    N = components.shape[0]
    return (jnp.broadcast_to(a, (N, a.shape[0])),
            jnp.broadcast_to(b, (N, b.shape[0])))


def dot_components(user: jax.Array, items: jax.Array,
                   n_groups: int) -> jax.Array:
    """Decompose score_i = <user, item_i> into ``n_groups`` contiguous
    dimension-group partial dots -> component matrix (N, n_groups)."""
    d = user.shape[-1]
    assert d % n_groups == 0, (d, n_groups)
    g = d // n_groups
    u = user.reshape(n_groups, g)
    it = items.reshape(items.shape[0], n_groups, g)
    return jnp.einsum("ngd,gd->ng", it, u)


def fm_pair_components(query_emb: jax.Array, cand_embs: jax.Array) -> jax.Array:
    """FM cross-term decomposition for retrieval: candidate item i interacting
    with F fixed user/context fields. Component f = <v_item_i, v_field_f>.
    query_emb: (F, D) context field embeddings; cand_embs: (N, D)."""
    return jnp.einsum("nd,fd->nf", cand_embs, query_emb)


def topk_bandit_generalized(
    components: jax.Array,      # (N, T) candidate x component contributions
    key: jax.Array,
    *,
    k: int,
    alpha_ef: float = 0.3,
    delta: float = 0.01,
    epsilon: float = 0.1,
    support_slack: float = 0.0,
    batched: bool = True,
    block_docs: int = 32,
    block_tokens: int = 4,
) -> BanditResult:
    """Run Top-K identification over a generic component matrix."""
    a, b = component_support(components, slack=support_slack)
    if batched:
        return run_batched_oracle(
            components, a, b, key, k=k, delta=delta, alpha_ef=alpha_ef,
            epsilon=epsilon, block_docs=block_docs, block_tokens=block_tokens)
    return run_bandit(components, a, b, key, k=k, delta=delta,
                      alpha_ef=alpha_ef, epsilon=epsilon)
