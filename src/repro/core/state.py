"""Bandit state pytree + reveal/update primitives shared by the sequential
(faithful) and block-synchronous (TPU) Col-Bandit variants."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BanditState(NamedTuple):
    values: jax.Array      # (N, T) f32 — revealed MaxSim values (0 if unrevealed)
    revealed: jax.Array    # (N, T) bool — the observation set Omega
    n: jax.Array           # (N,) i32 — |O_i|
    total: jax.Array       # (N,) f32 — sum of revealed values per row
    total_sq: jax.Array    # (N,) f32 — sum of squares
    key: jax.Array         # PRNG key
    rounds: jax.Array      # i32 — loop iterations executed
    done: jax.Array        # bool — stop flag


def init_state(n_docs: int, n_tokens: int, key: jax.Array) -> BanditState:
    return BanditState(
        values=jnp.zeros((n_docs, n_tokens), jnp.float32),
        revealed=jnp.zeros((n_docs, n_tokens), jnp.bool_),
        n=jnp.zeros((n_docs,), jnp.int32),
        total=jnp.zeros((n_docs,), jnp.float32),
        total_sq=jnp.zeros((n_docs,), jnp.float32),
        key=key,
        rounds=jnp.zeros((), jnp.int32),
        done=jnp.zeros((), jnp.bool_),
    )


def reveal_cell(state: BanditState, h_full: jax.Array, i: jax.Array,
                t: jax.Array) -> BanditState:
    """Reveal one cell (i, t) from the oracle matrix. No-op if already seen."""
    was = state.revealed[i, t]
    val = h_full[i, t].astype(jnp.float32)
    new = jnp.logical_not(was)
    newf = new.astype(jnp.float32)
    return state._replace(
        values=state.values.at[i, t].set(jnp.where(new, val, state.values[i, t])),
        revealed=state.revealed.at[i, t].set(True),
        n=state.n.at[i].add(new.astype(jnp.int32)),
        total=state.total.at[i].add(newf * val),
        total_sq=state.total_sq.at[i].add(newf * val * val),
    )


def reveal_mask(state: BanditState, h_full: jax.Array,
                mask: jax.Array) -> BanditState:
    """Reveal every cell where ``mask`` is True (vectorized, idempotent)."""
    new = mask & ~state.revealed
    newf = new.astype(jnp.float32)
    vals = h_full.astype(jnp.float32)
    return state._replace(
        values=jnp.where(new, vals, state.values),
        revealed=state.revealed | new,
        n=state.n + jnp.sum(new, axis=-1).astype(jnp.int32),
        total=state.total + jnp.sum(newf * vals, axis=-1),
        total_sq=state.total_sq + jnp.sum(newf * vals * vals, axis=-1),
    )


def coverage(state: BanditState, doc_mask: jax.Array | None = None) -> jax.Array:
    """Eq. 6 — fraction of the (valid) matrix revealed."""
    if doc_mask is None:
        return jnp.mean(state.revealed.astype(jnp.float32))
    rev = jnp.sum(jnp.where(doc_mask[:, None], state.revealed, False))
    tot = jnp.sum(doc_mask) * state.revealed.shape[1]
    return rev.astype(jnp.float32) / jnp.maximum(tot.astype(jnp.float32), 1.0)
