"""Non-adaptive reveal baselines (paper App. A.3) + exact scoring.

Doc-Uniform   (Algorithm 2): per row, reveal ceil(gamma*T) cells uniformly
              at random without replacement; rank by the partial sums.
Doc-TopMargin (Algorithm 3): per row, reveal the ceil(gamma*T) cells with the
              largest support width (b - a); rank by the partial sums.
Exact         : full scoring — the non-pruned reference (100% coverage).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

_NEG = jnp.float32(-3e38)


class BaselineResult(NamedTuple):
    topk: jax.Array       # (K,)
    coverage: jax.Array   # scalar f32
    scores: jax.Array     # (N,) partial-sum scores
    revealed: jax.Array   # (N, T) bool


def _finish(scores: jax.Array, revealed: jax.Array, k: int,
            doc_mask: jax.Array) -> BaselineResult:
    scores = jnp.where(doc_mask, scores, _NEG)
    _, topk = jax.lax.top_k(scores, k)
    n_rev = jnp.sum(revealed & doc_mask[:, None])
    n_cells = jnp.maximum(jnp.sum(doc_mask) * revealed.shape[1], 1)
    cov = n_rev.astype(jnp.float32) / n_cells.astype(jnp.float32)
    return BaselineResult(topk=topk, coverage=cov, scores=scores,
                          revealed=revealed & doc_mask[:, None])


@functools.partial(jax.jit, static_argnames=("k", "budget"))
def doc_uniform(h_full: jax.Array, key: jax.Array, *, k: int, budget: int,
                doc_mask: Optional[jax.Array] = None) -> BaselineResult:
    """Algorithm 2 with per-row budget B = ``budget`` cells."""
    N, T = h_full.shape
    if doc_mask is None:
        doc_mask = jnp.ones((N,), jnp.bool_)
    budget = max(1, min(budget, T))
    # Rank a per-row random permutation; take the first `budget` positions.
    noise = jax.random.uniform(key, (N, T))
    order = jnp.argsort(noise, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    revealed = ranks < budget
    scores = jnp.sum(jnp.where(revealed, h_full, 0.0), axis=-1)
    return _finish(scores, revealed, k, doc_mask)


@functools.partial(jax.jit, static_argnames=("k", "budget"))
def doc_top_margin(h_full: jax.Array, a: jax.Array, b: jax.Array, *, k: int,
                   budget: int,
                   doc_mask: Optional[jax.Array] = None) -> BaselineResult:
    """Algorithm 3: reveal the top-B cells per row by support width b-a."""
    N, T = h_full.shape
    if doc_mask is None:
        doc_mask = jnp.ones((N,), jnp.bool_)
    budget = max(1, min(budget, T))
    width = (b - a).astype(jnp.float32)
    ranks = jnp.argsort(jnp.argsort(-width, axis=-1), axis=-1)
    revealed = ranks < budget
    scores = jnp.sum(jnp.where(revealed, h_full, 0.0), axis=-1)
    return _finish(scores, revealed, k, doc_mask)


@functools.partial(jax.jit, static_argnames=("k",))
def exact_topk(h_full: jax.Array, *, k: int,
               doc_mask: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Full ColBERT scoring (Eq. 2/3): S_i = sum_t H_it, then top-K."""
    N, T = h_full.shape
    if doc_mask is None:
        doc_mask = jnp.ones((N,), jnp.bool_)
    scores = jnp.where(doc_mask, jnp.sum(h_full, axis=-1), _NEG)
    _, topk = jax.lax.top_k(scores, k)
    return topk, scores
