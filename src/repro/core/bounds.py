"""Decision bounds for Col-Bandit (paper Sec. 4.2, App. A).

Implements:
  Eq.  8   empirical mean mu_hat_i over observed cells
  Eq.  9   score proxy S_hat_i = T * mu_hat_i
  Eq. 10/11 deterministic hard bounds from per-cell support [a_it, b_it]
  Eq. 12   variance-adaptive empirical Bernstein-Serfling radius
  Eq. 13/14 hybrid decision interval (hard-clipped)
  Eq. 17   empirical std over observed cells
  Eq. 18   finite-population correction rho_n

All statistics are maintained incrementally as (n_i, total_i, total_sq_i)
so one reveal is an O(1) state update; interval evaluation is vectorized
over documents. Everything is fp32.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Intervals(NamedTuple):
    s_hat: jax.Array      # (N,) estimated total score  (Eq. 9)
    lcb: jax.Array        # (N,) hybrid lower bound     (Eq. 13)
    ucb: jax.Array        # (N,) hybrid upper bound     (Eq. 14)
    lb_hard: jax.Array    # (N,)                        (Eq. 10)
    ub_hard: jax.Array    # (N,)                        (Eq. 11)
    radius: jax.Array     # (N,) r_i^eff                (Eq. 12)
    sigma: jax.Array      # (N,)                        (Eq. 17)


def rho_n(n: jax.Array, T: int) -> jax.Array:
    """Finite-population correction, Eq. 18. Piecewise in n; collapses to 0
    at n == T so a fully-observed row has zero stochastic radius."""
    n = n.astype(jnp.float32)
    Tf = jnp.float32(T)
    small = 1.0 - (n - 1.0) / Tf
    large = (1.0 - n / Tf) * (1.0 + 1.0 / jnp.maximum(n, 1.0))
    return jnp.where(n <= Tf / 2.0, small, large)


def empirical_sigma(n: jax.Array, total: jax.Array, total_sq: jax.Array) -> jax.Array:
    """Unbiased empirical std (Eq. 17); 0 where n <= 1 (radius handles it)."""
    nf = n.astype(jnp.float32)
    var = (total_sq - total * total / jnp.maximum(nf, 1.0)) / jnp.maximum(nf - 1.0, 1.0)
    return jnp.sqrt(jnp.maximum(var, 0.0))


def serfling_radius(
    sigma: jax.Array,
    n: jax.Array,
    *,
    T: int,
    N: int,
    delta: float,
    alpha_ef: float,
    c: float = 1.0,
    bias_kappa: float = 0.0,
    value_range: float = 1.0,
) -> jax.Array:
    """Variance-adaptive decision radius, Eq. 12.

    r_i = alpha_ef * T * sigma_i * sqrt(2 log(cN/delta) / n_i) * sqrt(rho_n).
    +inf where n_i <= 1 (App. A: variance undefined -> rely on hard bounds).

    ``bias_kappa > 0`` adds the O(1/n) range term of the full empirical
    Bernstein-Serfling inequality (Bardenet & Maillard Thm 4.3):
    + alpha_ef * kappa * T * (b-a) * log(cN/delta) / n. The paper OMITS this
    term ("alpha_ef practically compensates", App. A); it matters when rows
    have tiny empirical variance at small n (sigma_hat underestimates), so
    we expose it as an opt-in robustness knob — default 0 = paper-faithful.
    """
    nf = jnp.maximum(n.astype(jnp.float32), 1.0)
    log_term = jnp.log(jnp.float32(c) * jnp.float32(N) / jnp.float32(delta))
    r = (jnp.float32(alpha_ef) * jnp.float32(T) * sigma
         * jnp.sqrt(2.0 * log_term / nf)
         * jnp.sqrt(jnp.maximum(rho_n(n, T), 0.0)))
    if bias_kappa > 0.0:
        r = r + (jnp.float32(alpha_ef) * jnp.float32(bias_kappa)
                 * jnp.float32(T) * jnp.float32(value_range) * log_term / nf)
    return jnp.where(n <= 1, jnp.inf, r)


def hard_bounds(
    total: jax.Array,          # (N,) sum of revealed values
    revealed: jax.Array,       # (N, T) bool
    a: jax.Array,              # (N, T) per-cell lower support
    b: jax.Array,              # (N, T) per-cell upper support
) -> Tuple[jax.Array, jax.Array]:
    """Deterministic bounds, Eq. 10/11: observed sum + support of the rest."""
    unrevealed = ~revealed
    lb = total + jnp.sum(jnp.where(unrevealed, a, 0.0), axis=-1)
    ub = total + jnp.sum(jnp.where(unrevealed, b, 0.0), axis=-1)
    return lb, ub


def intervals(
    n: jax.Array,
    total: jax.Array,
    total_sq: jax.Array,
    revealed: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    T: int,
    N: int,
    delta: float,
    alpha_ef: float,
    c: float = 1.0,
    bias_kappa: float = 0.0,
) -> Intervals:
    """Hybrid decision interval (Eq. 13/14), vectorized over documents."""
    lb_hard, ub_hard = hard_bounds(total, revealed, a, b)
    nf = n.astype(jnp.float32)
    mu = total / jnp.maximum(nf, 1.0)
    s_hat = jnp.float32(T) * mu
    # n == 0: no empirical info; proxy = midpoint of the hard interval.
    s_hat = jnp.where(n == 0, 0.5 * (lb_hard + ub_hard), s_hat)
    sigma = empirical_sigma(n, total, total_sq)
    r = serfling_radius(sigma, n, T=T, N=N, delta=delta, alpha_ef=alpha_ef,
                        c=c, bias_kappa=bias_kappa)
    # inf-radius arithmetic picks the hard bound in the min/max below.
    lcb = jnp.maximum(lb_hard, s_hat - r)
    ucb = jnp.minimum(ub_hard, s_hat + r)
    # Numerical guard: hybrid interval must stay non-empty & consistent.
    lcb = jnp.minimum(lcb, ucb)
    return Intervals(s_hat=s_hat, lcb=lcb, ucb=ucb, lb_hard=lb_hard,
                     ub_hard=ub_hard, radius=r, sigma=sigma)
