"""Evaluation metrics (paper Sec. 5.1).

Overlap@K (Eq. 16) measures ranking fidelity vs. full scoring; Recall@K,
MRR@K, nDCG@K measure end-task retrieval effectiveness against relevance
labels. All are pure-jnp and vmap-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def overlap_at_k(topk_hat: jax.Array, topk_star: jax.Array) -> jax.Array:
    """Eq. 16: |T_K_star ∩ T_K_hat| / K (index sets, order-insensitive)."""
    eq = topk_hat[:, None] == topk_star[None, :]
    return jnp.sum(eq.any(axis=-1).astype(jnp.float32)) / topk_hat.shape[0]


def recall_at_k(topk: jax.Array, relevant: jax.Array) -> jax.Array:
    """relevant: (N,) bool per candidate. Recall = hits@K / total relevant."""
    hits = jnp.sum(relevant[topk].astype(jnp.float32))
    total = jnp.maximum(jnp.sum(relevant.astype(jnp.float32)), 1.0)
    return hits / total


def mrr_at_k(topk: jax.Array, relevant: jax.Array) -> jax.Array:
    """Reciprocal rank of the first relevant hit within the top-K list."""
    rel = relevant[topk].astype(jnp.float32)              # (K,) in rank order
    ranks = jnp.arange(1, topk.shape[0] + 1, dtype=jnp.float32)
    rr = rel / ranks
    first = jnp.argmax(rel)                               # first hit position
    any_hit = jnp.any(rel > 0)
    return jnp.where(any_hit, rr[first], 0.0)


def ndcg_at_k(topk: jax.Array, relevant: jax.Array) -> jax.Array:
    """Binary-gain nDCG@K against an ideal ranking of the relevant set."""
    k = topk.shape[0]
    rel = relevant[topk].astype(jnp.float32)
    discounts = 1.0 / jnp.log2(jnp.arange(2, k + 2, dtype=jnp.float32))
    dcg = jnp.sum(rel * discounts)
    n_rel = jnp.sum(relevant.astype(jnp.int32))
    ideal_hits = (jnp.arange(k) < n_rel).astype(jnp.float32)
    idcg = jnp.maximum(jnp.sum(ideal_hits * discounts), 1e-9)
    return dcg / idcg


def all_metrics(topk_hat: jax.Array, topk_star: jax.Array,
                relevant: jax.Array) -> dict:
    return {
        "overlap": overlap_at_k(topk_hat, topk_star),
        "recall": recall_at_k(topk_hat, relevant),
        "mrr": mrr_at_k(topk_hat, relevant),
        "ndcg": ndcg_at_k(topk_hat, relevant),
    }
