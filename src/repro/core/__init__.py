"""Col-Bandit core: bounds, LUCB policies, baselines, metrics."""
from repro.core.bandit import BanditResult, run_bandit
from repro.core.batched import BatchedConfig, run_batched_bandit, run_batched_oracle
from repro.core.baselines import doc_top_margin, doc_uniform, exact_topk
from repro.core.frontier import (FrontierState, PooledResult,
                                 init_frontier_state, run_pooled_bandit,
                                 run_pooled_oracle, run_pooled_slice)
from repro.core.bounds import Intervals, intervals, rho_n, serfling_radius
from repro.core.metrics import (all_metrics, mrr_at_k, ndcg_at_k,
                                overlap_at_k, recall_at_k)
from repro.core.state import BanditState, coverage, init_state

__all__ = [
    "BanditResult", "run_bandit", "BatchedConfig", "run_batched_bandit",
    "run_batched_oracle", "PooledResult", "run_pooled_bandit",
    "run_pooled_oracle", "FrontierState", "init_frontier_state",
    "run_pooled_slice", "doc_top_margin", "doc_uniform", "exact_topk",
    "Intervals", "intervals", "rho_n", "serfling_radius", "all_metrics",
    "mrr_at_k", "ndcg_at_k", "overlap_at_k", "recall_at_k", "BanditState",
    "coverage", "init_state",
]
