"""Col-Bandit, faithful sequential LUCB (paper Algorithm 1).

One (document, token) MaxSim cell is revealed per iteration, exactly as
written in the paper; this is the correctness oracle and the paper-faithful
baseline recorded in EXPERIMENTS.md. The TPU-adapted block-synchronous
variant lives in ``repro.core.batched``.

The "environment" is a precomputed MaxSim matrix ``h_full`` (N, T): revealing
cell (i, t) returns ``h_full[i, t]`` and costs one atomic unit (Sec. 2.1,
"Atomic Cost"). FLOP accounting against real document lengths is layered on
top by the caller (``repro.retrieval.pipeline``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BanditConfig
from repro.core import bounds as B
from repro.core.state import BanditState, init_state, reveal_cell, reveal_mask

_NEG = jnp.float32(-3e38)
_POS = jnp.float32(3e38)


class BanditResult(NamedTuple):
    topk: jax.Array        # (K,) i32 — returned document indices
    coverage: jax.Array    # scalar f32 — Eq. 6 over valid docs
    reveals: jax.Array     # scalar i32 — |Omega|
    rounds: jax.Array      # scalar i32 — LUCB iterations
    separated: jax.Array   # scalar bool — stopped via LCB >= UCB (vs budget)
    s_hat: jax.Array       # (N,) f32 — final score estimates
    revealed: jax.Array    # (N, T) bool — final observation set


def _topk_mask(scores: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Boolean membership mask of the current Top-K by score (stable ties)."""
    _, idx = jax.lax.top_k(scores, k)
    mask = jnp.zeros(scores.shape, jnp.bool_).at[idx].set(True)
    return mask, idx


def _select_arms(iv: B.Intervals, topk_mask: jax.Array,
                 valid: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Weakest winner i+ and strongest loser i- (Sec. 4.3)."""
    i_plus = jnp.argmin(jnp.where(topk_mask & valid, iv.lcb, _POS))
    i_minus = jnp.argmax(jnp.where(~topk_mask & valid, iv.ucb, _NEG))
    return i_plus, i_minus


@functools.partial(
    jax.jit,
    static_argnames=("k", "delta", "alpha_ef", "epsilon", "radius_c",
                     "warmup_fraction", "max_reveals", "init_one_per_doc",
                     "bias_kappa"),
)
def run_bandit(
    h_full: jax.Array,            # (N, T) oracle MaxSim matrix
    a: jax.Array,                 # (N, T) lower support per cell
    b: jax.Array,                 # (N, T) upper support per cell
    key: jax.Array,
    *,
    k: int,
    delta: float = 0.01,
    alpha_ef: float = 0.3,
    epsilon: float = 0.1,
    radius_c: float = 1.0,
    bias_kappa: float = 0.0,
    warmup_fraction: float = 0.0,
    max_reveals: int = -1,
    init_one_per_doc: bool = True,
    doc_mask: Optional[jax.Array] = None,   # (N,) bool — valid candidates
    prereveal: Optional[jax.Array] = None,  # (N, T) bool — free initial cells
) -> BanditResult:
    """Algorithm 1. Returns the estimated Top-K set and the cost paid."""
    N, T = h_full.shape
    if doc_mask is None:
        doc_mask = jnp.ones((N,), jnp.bool_)
    budget = max_reveals if max_reveals > 0 else N * T
    # Invalid (padding) docs: pin support to zero & mark fully revealed so
    # they are never selected and contribute nothing.
    a = jnp.where(doc_mask[:, None], a, 0.0).astype(jnp.float32)
    b = jnp.where(doc_mask[:, None], b, 0.0).astype(jnp.float32)
    h_full = jnp.where(doc_mask[:, None], h_full, 0.0)

    key, k_init, k_warm = jax.random.split(key, 3)
    state = init_state(N, T, key)
    state = state._replace(revealed=state.revealed | ~doc_mask[:, None])

    # -- Exploration init (Sec. 4.1) --------------------------------------
    if prereveal is not None:
        # e.g. cells whose exact value stage-1 ANN already computed
        # (beyond-paper `prereveal_ann`): revealed at zero marginal cost.
        state = reveal_mask(state, h_full, prereveal & doc_mask[:, None])
    if init_one_per_doc:
        # footnote 2: one uniformly random cell per document.
        t0 = jax.random.randint(k_init, (N,), 0, T)
        mask0 = (jnp.arange(T)[None, :] == t0[:, None]) & doc_mask[:, None]
        state = reveal_mask(state, h_full, mask0)
    if warmup_fraction > 0.0:
        # static warm-up: gamma_init * N * T cells uniformly w/o replacement.
        m = int(-(-warmup_fraction * N * T // 1))  # ceil
        flat = jax.random.permutation(k_warm, N * T)[:m]
        warm = jnp.zeros((N * T,), jnp.bool_).at[flat].set(True)
        warm = warm.reshape(N, T) & doc_mask[:, None]
        state = reveal_mask(state, h_full, warm)

    iv_kwargs = dict(T=T, N=N, delta=delta, alpha_ef=alpha_ef, c=radius_c,
                     bias_kappa=bias_kappa)

    def get_intervals(st: BanditState) -> B.Intervals:
        iv = B.intervals(st.n, st.total, st.total_sq, st.revealed, a, b,
                         **iv_kwargs)
        # Padding docs: push out of every selection.
        s_hat = jnp.where(doc_mask, iv.s_hat, _NEG)
        lcb = jnp.where(doc_mask, iv.lcb, _NEG)
        ucb = jnp.where(doc_mask, iv.ucb, _NEG)
        return iv._replace(s_hat=s_hat, lcb=lcb, ucb=ucb)

    def separated(iv: B.Intervals) -> jax.Array:
        tk, _ = _topk_mask(iv.s_hat, k)
        i_p, i_m = _select_arms(iv, tk, doc_mask)
        return iv.lcb[i_p] >= iv.ucb[i_m]

    def cond(st: BanditState) -> jax.Array:
        n_rev = jnp.sum(st.revealed & doc_mask[:, None])
        return (~st.done) & (n_rev < budget)

    def body(st: BanditState) -> BanditState:
        iv = get_intervals(st)
        tk_mask, _ = _topk_mask(iv.s_hat, k)                 # line 4
        i_plus, i_minus = _select_arms(iv, tk_mask, doc_mask)  # lines 5-6
        stop = iv.lcb[i_plus] >= iv.ucb[i_minus]             # line 7

        # line 10: the more ambiguous of the two (fall back to the one that
        # still has unrevealed cells — a fully-observed row has width 0).
        w_plus = iv.ucb[i_plus] - iv.lcb[i_plus]
        w_minus = iv.ucb[i_minus] - iv.lcb[i_minus]
        full_p = st.n[i_plus] >= T
        full_m = st.n[i_minus] >= T
        w_plus = jnp.where(full_p, _NEG, w_plus)
        w_minus = jnp.where(full_m, _NEG, w_minus)
        i_star = jnp.where(w_plus >= w_minus, i_plus, i_minus)
        both_full = full_p & full_m

        # lines 11-16: epsilon-greedy token choice within the row.
        key, k_eps, k_tok = jax.random.split(st.key, 3)
        unrev = ~st.revealed[i_star]
        width = jnp.where(unrev, b[i_star] - a[i_star], _NEG)
        t_exploit = jnp.argmax(width)                        # Max-Width
        gumbel = jax.random.gumbel(k_tok, (T,))
        t_explore = jnp.argmax(jnp.where(unrev, gumbel, _NEG))
        explore = jax.random.uniform(k_eps) < epsilon
        t_star = jnp.where(explore, t_explore, t_exploit)

        def do_stop(s: BanditState) -> BanditState:
            return s._replace(key=key, rounds=s.rounds + 1, done=True)

        def do_reveal(s: BanditState) -> BanditState:
            nxt = reveal_cell(s, h_full, i_star, t_star)     # lines 17-20
            return nxt._replace(key=key, rounds=s.rounds + 1, done=both_full)

        return jax.lax.cond(stop, do_stop, do_reveal, st)

    state = jax.lax.while_loop(cond, body, state)

    iv = get_intervals(state)
    _, topk_idx = jax.lax.top_k(iv.s_hat, k)
    n_rev = jnp.sum(state.revealed & doc_mask[:, None])
    n_cells = jnp.maximum(jnp.sum(doc_mask) * T, 1)
    return BanditResult(
        topk=topk_idx,
        coverage=n_rev.astype(jnp.float32) / n_cells.astype(jnp.float32),
        reveals=n_rev.astype(jnp.int32),
        rounds=state.rounds,
        separated=separated(iv),
        s_hat=iv.s_hat,
        revealed=state.revealed & doc_mask[:, None],
    )
