"""Checkpointing: atomic sharded save/restore + async writer."""
