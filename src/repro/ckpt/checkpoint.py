"""Checkpointing: sharded-layout-agnostic save/restore + async writer.

Format: one ``.npz`` per step (leaf path -> array) + ``meta.json``. Restore
targets an EXAMPLE pytree (shapes/structure), so checkpoints reshard freely:
a state saved under mesh A is loaded and re-placed under mesh B by the
caller's jit/device_put — this is the elastic-rescale path exercised in
tests/test_fault.py. On multi-host deployments each process saves its
addressable shards under ``shard{proc}`` (same format); this container is
single-process so there is exactly one shard file.

The async writer snapshots to host memory synchronously (cheap) and writes
to disk on a background thread — training never blocks on the filesystem.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np

Params = Any
_CKPT_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Params):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(root: str, step: int, state: Params,
                    extra: Optional[dict] = None) -> str:
    """Synchronous save. Returns the checkpoint directory."""
    d = os.path.join(root, f"step_{step:08d}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = _flatten(state)
    np.savez(os.path.join(tmp, "shard0.npz"), **arrays)
    meta = {"step": step, "time": time.time(), "extra": extra or {},
            "n_leaves": len(arrays)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    os.replace(tmp, d)            # atomic publish
    return d


def latest_checkpoint(root: str) -> Optional[Tuple[int, str]]:
    if not os.path.isdir(root):
        return None
    best = None
    for name in os.listdir(root):
        m = _CKPT_RE.match(name)
        if m:
            step = int(m.group(1))
            if best is None or step > best[0]:
                best = (step, os.path.join(root, name))
    return best


def restore_checkpoint(path: str, example: Params) -> Tuple[Params, dict]:
    """Restore into the structure of ``example`` (shapes must match)."""
    with np.load(os.path.join(path, "shard0.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(example)
    leaves = []
    for p, leaf in flat:
        key = jax.tree_util.keystr(p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(example), leaves), meta


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread checkpointer."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: Params, extra: Optional[dict] = None):
        self.wait()
        # device->host snapshot happens here, synchronously (consistent view)
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                save_checkpoint(self.root, step, host_state, extra)
                self._gc()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        if not os.path.isdir(self.root):
            return
        steps = sorted(int(m.group(1)) for n in os.listdir(self.root)
                       if (m := _CKPT_RE.match(n)))
        for s in steps[:-self.keep]:
            d = os.path.join(self.root, f"step_{s:08d}")
            for f in os.listdir(d):
                os.remove(os.path.join(d, f))
            os.rmdir(d)
