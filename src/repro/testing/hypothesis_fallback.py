"""Minimal stand-in for the ``hypothesis`` property-testing API.

The real hypothesis is declared in ``[project.optional-dependencies] test``
and is always preferred; this fallback exists so the suite still COLLECTS
AND RUNS in hermetic containers where installing it isn't possible.  It
implements exactly the surface the tests use — ``given``, ``settings`` and
``strategies.integers`` — with deterministic pseudo-random example
generation (seeded per test name), boundary examples first, and no
shrinking.  ``tests/conftest.py`` installs it into ``sys.modules`` only
when ``import hypothesis`` fails.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib
from typing import Any, Callable, List


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any],
                 boundary: List[Any]):
        self._draw = draw
        self.boundary = boundary          # tried before random examples

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    if min_value > max_value:
        raise ValueError("min_value must be <= max_value")
    bounds = [min_value, max_value] if min_value != max_value else [min_value]
    return _Strategy(lambda rng: rng.randint(min_value, max_value), bounds)


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)), [False, True])


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng: rng.choice(options), options[:1])


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     [min_value, max_value])


class settings:
    """Decorator recording run options (only max_examples is honored)."""

    def __init__(self, max_examples: int = None, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_max_examples = self.max_examples
        return fn


def given(*strategies: _Strategy):
    """Run the test once per generated example (boundary combos first on
    the first draws, then seeded-random tuples)."""

    def deco(fn):
        params = list(inspect.signature(fn).parameters.values())
        drawn = [p.name for p in params[len(params) - len(strategies):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", None) or 20
            rng = random.Random(zlib.crc32(fn.__qualname__.encode("utf-8")))
            for i in range(n):
                if i == 0:
                    example = tuple(s.boundary[0] for s in strategies)
                elif i == 1 and all(len(s.boundary) > 1 for s in strategies):
                    example = tuple(s.boundary[-1] for s in strategies)
                else:
                    example = tuple(s.draw(rng) for s in strategies)
                try:
                    # Bind drawn values by NAME: pytest passes fixtures as
                    # keywords, so positional splicing would collide.
                    fn(*args, **kwargs, **dict(zip(drawn, example)))
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__name__}): "
                        f"{example!r}") from e
        # pytest must NOT see the generated params as fixture requests.
        # Mirror real hypothesis: strategies bind the RIGHTMOST parameters;
        # any leading ones stay visible so pytest injects them as fixtures.
        # (functools.wraps exposes the full signature via __wrapped__ —
        # drop it and advertise only the fixture params.)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(
            parameters=params[:len(params) - len(strategies)])
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


def install() -> None:
    """Register this module as ``hypothesis`` in sys.modules (fallback only
    — callers must first verify the real package is absent)."""
    mod = sys.modules[__name__]
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "sampled_from", "floats"):
        setattr(strategies, name, getattr(mod, name))
    mod.strategies = strategies
    sys.modules.setdefault("hypothesis", mod)
    sys.modules.setdefault("hypothesis.strategies", strategies)
