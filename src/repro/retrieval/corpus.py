"""Unified corpus facade: one object for the host and mesh-resident views.

Historically the stage-1 kNN (``retrieval/ann.py`` over a single-host
``TokenIndex``) and the sharded serving path (``retrieval/sharded.py`` +
``service.py``) were two architectures glued by host-side routing tables.
This module is the seam that unifies them:

* :func:`gather_tokens` — THE candidate-embedding gather. Rank-general
  (works for a (N,) id vector or a (B, N) batch), -1 ids come back fully
  masked. ``TokenIndex.gather_docs`` and ``service.gather_candidates``
  both delegate here, so every flavor agrees on pad semantics.
* :class:`CentroidRouter` / :func:`build_router` — the IVF-style centroid
  router (ColBERTv2/PLAID direction): k-means over doc-pooled embeddings
  at corpus-build time, plus the per-(centroid, shard) doc-mass table.
  At query time :func:`route_mass` turns query-token/centroid affinities
  into per-shard candidate mass and :func:`route_quotas` converts the mass
  into integer per-shard candidate quotas that ALWAYS sum to the global
  budget (largest-remainder rounding, deterministic tie-break) — the
  skew-aware replacement for worst-case-uniform ``N_loc`` provisioning.
* :class:`Corpus` / :func:`build_corpus` — the facade object the serving
  engine holds: a single-device corpus (``mesh=None``) and a mesh-resident
  ``ShardedCorpus`` expose the same attribute surface (``embs``, ``mask``,
  ``n_shards``, ``docs_per_shard``, ``valid_docs``, ``router``, ...).

Loud-failure contract: quotas are never silently clamped. The host-side
:meth:`CentroidRouter.route` raises ``ValueError`` when a routed quota
exceeds a shard's ``valid_docs`` (or the compiled ``n_local`` capacity).
The in-shard_map path needs no clamp at all — shard-local stage-1 only
ever emits docs the shard genuinely hit, so an over-quota shard simply
yields fewer candidates (``doc_mask`` False), never a wrong id.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.kernels.quant import (CORPUS_FORMATS, corpus_asarray, corpus_take,
                                 quantize)
from repro.retrieval.sharded import ShardedCorpus, shard_corpus


def gather_tokens(embs: jax.Array, mask: jax.Array,
                  doc_ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Gather candidate token embeddings by doc id (the one shared gather).

    embs (C, L, M), mask (C, L), doc_ids (..., N) with -1 padding ->
    (..., N, L, M) embeddings + (..., N, L) mask, all-False for -1 ids.
    A quantized corpus (``QuantTokens``) gathers leaf-wise — the moved
    bytes stay compressed — and comes back as ``QuantTokens`` with the
    same (..., N, L, M) payload layout.
    """
    safe = jnp.maximum(doc_ids, 0)
    docs = corpus_take(embs, safe, axis=0)
    dmask = jnp.take(mask, safe, axis=0) & (doc_ids >= 0)[..., None]
    return docs, dmask


# ---------------------------------------------------------------------------
# Centroid router
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CentroidRouter:
    """IVF-style router state: unit centroids over doc-pooled embeddings
    plus the (centroid, shard) doc-mass table. Both arrays are replicated
    on the mesh (they are tiny next to the token index) so every shard can
    compute the identical (B, n_shards) quota table inside the shard_map
    and read its own column — routing costs zero cross-shard traffic."""

    centroids: jax.Array     # (Kc, M) f32 unit rows
    shard_mass: jax.Array    # (Kc, n_shards) f32 — docs per (centroid, shard)
    valid_docs: np.ndarray   # (n_shards,) i32 — genuine docs per shard

    @property
    def n_centroids(self) -> int:
        return self.centroids.shape[0]

    @property
    def n_shards(self) -> int:
        return self.shard_mass.shape[1]

    def route(self, queries, n_total: int, *,
              n_local: Optional[int] = None,
              healthy: Optional[np.ndarray] = None) -> np.ndarray:
        """Host-side routing API: (B, T, M) queries -> (B, n_shards) integer
        quotas summing exactly to ``n_total`` per query. Raises ``ValueError``
        (never clamps) when a quota exceeds a shard's ``valid_docs`` or the
        compiled per-shard capacity ``n_local``. ``healthy`` (n_shards,)
        bool re-routes a failed shard's quota mass onto healthy shards
        (see :func:`route_quotas`)."""
        mass = route_mass(jnp.asarray(queries, jnp.float32), self.centroids,
                          self.shard_mass)
        h = None if healthy is None else jnp.asarray(healthy, jnp.bool_)
        quotas = np.asarray(route_quotas(mass, n_total, healthy=h))
        validate_quotas(quotas, self.valid_docs, n_local=n_local)
        return quotas


def validate_quotas(quotas: np.ndarray, valid_docs: np.ndarray, *,
                    n_local: Optional[int] = None) -> None:
    """Loud-failure quota check: a routed quota larger than a shard's
    genuine doc count (or the compiled slot capacity) is a configuration
    error — raise instead of silently clamping and serving a short list."""
    quotas = np.asarray(quotas)
    valid_docs = np.asarray(valid_docs)
    peak = quotas.max(axis=0) if quotas.ndim == 2 else quotas
    for s, (q, v) in enumerate(zip(peak, valid_docs)):
        if q > v:
            raise ValueError(
                f"routed quota {int(q)} for shard {s} exceeds its "
                f"valid_docs={int(v)}; lower n_total or rebalance the "
                "corpus (quotas are never silently clamped)")
    if n_local is not None and peak.size and int(peak.max()) > n_local:
        s = int(np.argmax(peak))
        raise ValueError(
            f"routed quota {int(peak.max())} for shard {s} exceeds the "
            f"compiled per-shard capacity n_local={int(n_local)}; raise "
            "n_local or lower n_total")


def build_router(embs, mask, *, n_shards: int, docs_per_shard: int,
                 n_centroids: int = 8, n_iters: int = 10, seed: int = 0,
                 valid_docs: Optional[np.ndarray] = None) -> CentroidRouter:
    """Build the centroid router at corpus-shard time (host numpy; this is
    index construction, not the query hot path).

    Spherical k-means (Lloyd, ``n_iters`` fixed iterations, deterministic
    under ``seed``) over the doc-pooled unit embeddings of every doc with
    at least one valid token; ``shard_mass[c, s]`` counts the docs of
    cluster ``c`` resident on shard ``s`` (shard of doc = row //
    docs_per_shard — the contiguous-block placement ``shard_corpus``
    uses). Empty clusters keep their centroid and zero mass. Docs with no
    valid token carry no mass (they can never be stage-1 candidates)."""
    embs = np.asarray(embs).astype(np.float32)
    mask = np.asarray(mask, bool)
    C, _, M = embs.shape
    if valid_docs is None:
        valid_docs = np.clip(C - docs_per_shard * np.arange(n_shards),
                             0, docs_per_shard).astype(np.int32)
    denom = np.maximum(mask.sum(1, keepdims=True), 1).astype(np.float32)
    pooled = (embs * mask[:, :, None]).sum(1) / denom
    pooled /= np.maximum(np.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)
    ids = np.nonzero(mask.any(1))[0]
    k = int(max(min(n_centroids, len(ids)), 1))
    if len(ids) == 0:
        cents = np.zeros((k, M), np.float32)
        assign = np.zeros((0,), np.int64)
    else:
        rng = np.random.default_rng(seed)
        cents = pooled[ids[rng.choice(len(ids), size=k, replace=False)]].copy()
        pts = pooled[ids]
        for _ in range(max(n_iters, 1)):
            assign = np.argmax(pts @ cents.T, axis=1)
            for c in range(k):
                sel = pts[assign == c]
                if len(sel):
                    v = sel.mean(0)
                    nrm = np.linalg.norm(v)
                    if nrm > 1e-9:
                        cents[c] = v / nrm
        assign = np.argmax(pts @ cents.T, axis=1)
    shard_mass = np.zeros((k, n_shards), np.float32)
    if len(ids):
        np.add.at(shard_mass, (assign, ids // docs_per_shard), 1.0)
    return CentroidRouter(centroids=jnp.asarray(cents),
                          shard_mass=jnp.asarray(shard_mass),
                          valid_docs=np.asarray(valid_docs, np.int32))


def route_mass(queries: jax.Array, centroids: jax.Array,
               shard_mass: jax.Array, *, n_probe: int = 0) -> jax.Array:
    """Routed per-shard candidate mass (jit/shard_map-safe).

    queries (B, T, M), centroids (Kc, M), shard_mass (Kc, S) -> (B, S):
    per-token centroid affinity relu(<q_t, c_k>) summed over tokens
    (zero-padded query tokens contribute exactly 0), optionally truncated
    to the top ``n_probe`` centroids per query, then pushed through the
    mass table. A zero-centroid router yields all-zero mass, which
    :func:`route_quotas` resolves to uniform quotas."""
    B = queries.shape[0]
    S = shard_mass.shape[1]
    if centroids.shape[0] == 0:
        return jnp.zeros((B, S), jnp.float32)
    aff = jnp.einsum("btm,km->btk", queries.astype(jnp.float32),
                     centroids.astype(jnp.float32))
    aff = jnp.sum(jax.nn.relu(aff), axis=1)                       # (B, Kc)
    if n_probe and n_probe < centroids.shape[0]:
        kth = jax.lax.top_k(aff, n_probe)[0][:, -1:]
        aff = jnp.where(aff >= kth, aff, 0.0)
    return aff @ shard_mass.astype(jnp.float32)                   # (B, S)


def route_quotas(mass: jax.Array, n_total: int,
                 healthy: Optional[jax.Array] = None) -> jax.Array:
    """Integer per-shard quotas from routed mass (jit/shard_map-safe).

    mass (B, S) >= 0 -> quotas (B, S) i32 with ``sum(quotas[b]) ==
    n_total`` EXACTLY for every query: largest-remainder rounding of the
    proportional ideal, deterministic tie-break (larger fractional part
    wins, lower shard index on exact ties). All-zero mass rows (router
    missed every centroid, or no router) fall back to uniform shares.

    ``healthy`` is an optional (S,) bool mask: unhealthy shards have
    their mass zeroed BEFORE normalisation, so their quota share is
    re-routed proportionally onto the surviving shards (failover). When
    no healthy shard has mass the fallback is uniform over the healthy
    set. ``healthy=None`` is bit-identical to the pre-failover path.
    With every shard unhealthy the quotas degenerate to the unmasked
    uniform fallback — callers are expected to fail the request before
    that point."""
    mass = jnp.maximum(mass.astype(jnp.float32), 0.0)
    B, S = mass.shape
    if healthy is None:
        tot = jnp.sum(mass, axis=-1, keepdims=True)
        frac = jnp.where(tot > 0, mass / jnp.maximum(tot, 1e-30),
                         jnp.float32(1.0 / S))
    else:
        h = jnp.asarray(healthy, jnp.bool_).reshape(S).astype(jnp.float32)
        h = jnp.where(jnp.sum(h) > 0, h, jnp.ones((S,), jnp.float32))
        mass = mass * h[None, :]
        tot = jnp.sum(mass, axis=-1, keepdims=True)
        nh = jnp.sum(h)
        # All-healthy keeps the legacy 1/S constant (bit-identical to the
        # healthy=None trace — x * 1.0 is an IEEE identity upstream too).
        fallback = jnp.where(nh >= S, jnp.full((S,), jnp.float32(1.0 / S)),
                             h / jnp.maximum(nh, 1.0))
        frac = jnp.where(tot > 0, mass / jnp.maximum(tot, 1e-30),
                         fallback[None, :])
    ideal = frac * jnp.float32(n_total)
    base = jnp.floor(ideal).astype(jnp.int32)
    rem = jnp.clip(n_total - jnp.sum(base, axis=-1), 0, S)        # (B,)
    # Priority order for the leftover units: fractional part, lower index
    # breaking exact ties (the epsilon is far below any meaningful
    # fractional difference at serving scales).
    prio = (ideal - jnp.floor(ideal)) - jnp.arange(S) * jnp.float32(1e-6)
    order = jnp.argsort(-prio, axis=-1)                           # (B, S)
    bonus = (jnp.arange(S)[None, :] < rem[:, None]).astype(jnp.int32)
    out = jnp.zeros((B, S), jnp.int32)
    return out.at[jnp.arange(B)[:, None], order].add(bonus) + base


# ---------------------------------------------------------------------------
# Corpus facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Corpus:
    """One attribute surface for both corpus placements.

    ``mesh=None`` is the single-device view (one shard owning everything);
    otherwise the arrays are the mesh-resident ``ShardedCorpus`` placement
    (doc dim over every axis, ragged tail padded + tracked) and ``router``
    holds the replicated centroid-router state for shard-local stage-1."""

    embs: jax.Array                      # (C_pad, L, M) f32 | bf16 |
                                         #   QuantTokens (compressed)
    mask: jax.Array                      # (C_pad, L) bool
    mesh: Optional[Mesh]
    n_docs: int
    n_shards: int
    docs_per_shard: int
    valid_docs: np.ndarray               # (n_shards,) i32
    router: Optional[CentroidRouter] = None
    pooled: Optional[jax.Array] = None
    fmt: str = "bf16"                    # resident format (CORPUS_FORMATS)

    @property
    def padded_docs(self) -> int:
        return self.n_shards * self.docs_per_shard

    def valid_docs_device(self) -> jax.Array:
        return jnp.asarray(self.valid_docs, jnp.int32)

    def gather_docs(self, doc_ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Candidate sub-index by global doc id (shared gather)."""
        return gather_tokens(self.embs, self.mask, doc_ids)

    def router_arrays(self) -> Tuple[jax.Array, jax.Array]:
        """(centroids, shard_mass) for the routed serving step — zero-row
        placeholders when no router was built (route_mass then yields zero
        mass and quotas fall back to uniform)."""
        if self.router is not None:
            return self.router.centroids, self.router.shard_mass
        return (jnp.zeros((0, self.embs.shape[2]), jnp.float32),
                jnp.zeros((0, self.n_shards), jnp.float32))


def build_corpus(embs, mask, *, mesh: Optional[Mesh] = None,
                 n_centroids: int = 0, router_iters: int = 10,
                 router_seed: int = 0, pooled=None,
                 corpus_format: str = "bf16") -> Corpus:
    """Build the unified corpus facade.

    With a mesh, this is ``shard_corpus`` plus (``n_centroids > 0``) the
    centroid router, built at shard time over the same contiguous-block
    placement. Without one, the single-device view: one shard owning all
    ``C`` docs (bf16 corpora stay bf16, as in ``shard_corpus``).

    ``corpus_format`` ('bf16' | 'int8' | 'residual') selects the resident
    encoding — see ``shard_corpus``. 'residual' needs centroids, so it
    bumps ``n_centroids`` to 8 when none were requested; the router built
    for stage-1 routing doubles as the codebook."""
    if corpus_format not in CORPUS_FORMATS:
        raise ValueError(f"unknown corpus format {corpus_format!r}; "
                         f"expected one of {CORPUS_FORMATS}")
    if mesh is not None:
        sc: ShardedCorpus = shard_corpus(
            embs, mask, mesh, pooled=pooled, n_centroids=n_centroids,
            router_iters=router_iters, router_seed=router_seed,
            corpus_format=corpus_format)
        return Corpus(embs=sc.embs, mask=sc.mask, mesh=mesh,
                      n_docs=sc.n_docs, n_shards=sc.n_shards,
                      docs_per_shard=sc.docs_per_shard,
                      valid_docs=sc.valid_docs, router=sc.router,
                      pooled=sc.pooled, fmt=sc.fmt)
    host = np.asarray(embs)
    dmask_h = np.asarray(mask, bool)
    if host.ndim != 3 or dmask_h.ndim != 2 or host.shape[:2] != dmask_h.shape:
        raise ValueError("corpus must be (C, L, M) embs + (C, L) mask")
    C = host.shape[0]
    if corpus_format == "residual" and not n_centroids:
        n_centroids = 8  # the residual codebook IS the router's centroids
    router = None
    if n_centroids:
        router = build_router(embs, mask, n_shards=1, docs_per_shard=C,
                              n_centroids=n_centroids, n_iters=router_iters,
                              seed=router_seed)
    if corpus_format == "bf16":
        dev = jnp.asarray(embs)
        if dev.dtype != jnp.bfloat16:
            dev = dev.astype(jnp.float32)
    else:
        codebook = (None if corpus_format != "residual"
                    else np.asarray(router.centroids, np.float32))
        dev = corpus_asarray(quantize(host.astype(np.float32), corpus_format,
                                      codebook=codebook))
    return Corpus(embs=dev, mask=jnp.asarray(dmask_h, jnp.bool_), mesh=None,
                  n_docs=C, n_shards=1, docs_per_shard=C,
                  valid_docs=np.asarray([C], np.int32), router=router,
                  pooled=None if pooled is None
                  else jnp.asarray(pooled, jnp.float32),
                  fmt=corpus_format)
