"""Stage-1 candidate generation: per-query-token kNN + ANN-derived bounds.

Follows paper App. A.1: for each query token q_t, retrieve the top-k' most
similar document tokens (instantiated as exact kNN for reproducibility, as
in the paper); the candidate set is the union of owning documents. Eq. 15
turns the stage-1 similarities into per-(doc, token) upper bounds:

    a_it = 0
    b_it = h(d_i, t)      if d_i was retrieved for token t  (exact value!)
         = s_k'^(t)       otherwise (the k'-th neighbor similarity)

Note: when any token of d_i is in the top-k' for q_t, the *best* token of
d_i necessarily is too (it has a higher sim), so the scatter-max below
recovers the exact h(d_i, t) for hit cells. ``known_mask/known_vals`` expose
those exact cells so the (beyond-paper) ``prereveal_ann`` option can start
the bandit with them at zero additional cost.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

_NEG = jnp.float32(-3e38)


class CandidateSet(NamedTuple):
    doc_ids: jax.Array      # (N,) i32, -1 padding
    doc_mask: jax.Array     # (N,) bool
    a: jax.Array            # (N, T) lower support
    b: jax.Array            # (N, T) upper support (Eq. 15)
    known_mask: jax.Array   # (N, T) bool — cells whose exact value stage 1 saw
    known_vals: jax.Array   # (N, T) f32
    s_kprime: jax.Array     # (T,) k'-th neighbor similarity per query token

    @property
    def n_candidates(self) -> jax.Array:
        return jnp.sum(self.doc_mask)


@functools.partial(jax.jit, static_argnames=("kprime", "max_candidates",
                                             "support"))
def generate_candidates(
    index_embs: jax.Array,      # (C, L, M)
    index_mask: jax.Array,      # (C, L)
    query: jax.Array,           # (T, M)
    quota=None,                 # () i32 traced cap on |candidates|, or None
    *,
    kprime: int = 10,
    max_candidates: int = 256,
    support: Tuple[float, float] = (0.0, 1.0),
) -> CandidateSet:
    C, L, M = index_embs.shape
    T = query.shape[0]
    kprime = min(kprime, C * L)   # a tiny shard can't yield k' neighbors
    toks = index_embs.reshape(C * L, M)
    owner = jnp.repeat(jnp.arange(C, dtype=jnp.int32), L)
    valid = index_mask.reshape(-1)

    sims = query.astype(jnp.float32) @ toks.astype(jnp.float32).T  # (T, C*L)
    sims = jnp.where(valid[None, :], sims, _NEG)
    top_vals, top_idx = jax.lax.top_k(sims, kprime)                # (T, k')
    hit_docs = jnp.take(owner, top_idx)                            # (T, k')
    s_kprime = top_vals[:, kprime - 1]

    # Candidate set = union of hit docs. If the union exceeds
    # max_candidates, keep the docs with the HIGHEST best-hit similarity
    # (arbitrary-id truncation would silently drop strong candidates).
    doc_best = jnp.full((C,), _NEG).at[hit_docs.reshape(-1)].max(
        top_vals.reshape(-1))
    best_vals, best_ids = jax.lax.top_k(doc_best, min(max_candidates, C))
    if C < max_candidates:               # pad to the static candidate count
        pad = max_candidates - C
        best_vals = jnp.pad(best_vals, (0, pad), constant_values=_NEG)
        best_ids = jnp.pad(best_ids, (0, pad), constant_values=0)
    sel = best_vals > _NEG / 2
    if quota is not None:
        # Skew-aware routing cap: best_vals is descending, so rank ==
        # position; keep only the strongest ``quota`` candidates.
        sel = sel & (jnp.arange(max_candidates) < quota)
    sentinel = jnp.iinfo(jnp.int32).max
    sorted_slots = jnp.sort(jnp.where(sel, best_ids, sentinel))
    # Keep the sentinel-padded array around: it stays ascending, which the
    # searchsorted hit-lookup below requires (-1 padding would break the
    # sort order and silently drop exact b-values for high doc ids).
    cands = jnp.where(sorted_slots == sentinel, -1,
                      sorted_slots).astype(jnp.int32)
    doc_mask = cands >= 0

    a_lo, b_hi = support
    a = jnp.full((max_candidates, T), jnp.float32(a_lo))
    # Default upper bound: the k'-th neighbor similarity per token (Eq. 15).
    b = jnp.broadcast_to(jnp.maximum(s_kprime, a_lo)[None, :],
                         (max_candidates, T)).astype(jnp.float32)

    # Hit cells: exact h value via scatter-max into candidate rows.
    pos = jnp.searchsorted(sorted_slots, hit_docs)                 # (T, k')
    pos = jnp.clip(pos, 0, max_candidates - 1)
    is_cand = jnp.take(sorted_slots, pos) == hit_docs
    t_grid = jnp.broadcast_to(jnp.arange(T)[:, None], hit_docs.shape)
    safe_pos = jnp.where(is_cand, pos, max_candidates - 1)

    known_vals = jnp.full((max_candidates, T), _NEG)
    known_vals = known_vals.at[safe_pos, t_grid].max(
        jnp.where(is_cand, top_vals, _NEG))
    known_mask = known_vals > _NEG / 2
    known_vals = jnp.where(known_mask, known_vals, 0.0)

    b = jnp.where(known_mask, known_vals, b)
    b = jnp.clip(b, a_lo, b_hi)
    a = jnp.where(doc_mask[:, None], a, 0.0)
    b = jnp.where(doc_mask[:, None], b, 0.0)

    return CandidateSet(doc_ids=cands, doc_mask=doc_mask, a=a, b=b,
                        known_mask=known_mask & doc_mask[:, None],
                        known_vals=known_vals, s_kprime=s_kprime)


def generic_bounds(n: int, t: int,
                   support: Tuple[float, float] = (0.0, 1.0)
                   ) -> Tuple[jax.Array, jax.Array]:
    """No-ANN fallback: global similarity-range bounds (paper Sec. 5.3)."""
    a = jnp.full((n, t), jnp.float32(support[0]))
    b = jnp.full((n, t), jnp.float32(support[1]))
    return a, b
