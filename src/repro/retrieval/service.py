"""Cluster-scale late-interaction serving (the paper's workload, distributed).

Two step flavors, both lowered by the multi-pod dry-run:

rerank_dense_step (corpus-resident scoring)
    The corpus token index (C, L, M) is sharded over ('model' [, 'pod']);
    queries are sharded over the FSDP group and replicated across corpus
    shards. The ANN stage routes each candidate to the shard that owns it
    (host-side routing table, standard in distributed retrieval): input
    ``cand_local`` (B, n_corpus_shards, N_loc) holds local doc slots. Each
    shard gathers its resident candidates, runs the dense MaxSim scorer, and
    the global top-K emerges from an all-gather of (scores, ids) — the only
    cross-shard traffic is K-sized scorecards, never token embeddings.

rerank_bandit_step (query-resident adaptive scoring)
    Queries are sharded over EVERY axis; each device gathers its queries'
    candidate embeddings once (collective gather from the sharded corpus)
    and then runs the block-synchronous Col-Bandit locally through the
    pooled cross-query reveal engine (``repro.core.frontier``): one global
    round loop for the device's whole query shard, every round's frontier
    lowered through a single ``gather_maxsim`` kernel launch, converged
    queries retired instead of riding lockstep to the slowest query.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.batched import BatchedConfig, run_batched_bandit
from repro.core.frontier import (FrontierState, init_frontier_state,
                                 run_pooled_bandit)
from repro.kernels.ops import (fused_reveal_op, gather_maxsim_op,
                               maxsim_batch_op)
from repro.kernels.quant import QuantTokens, corpus_reshape
from repro.retrieval.ann import generate_candidates
from repro.retrieval.corpus import gather_tokens, route_mass, route_quotas
from repro.retrieval.sharded import corpus_embs_spec

_NEG = jnp.float32(-3e38)


def _local_maxsim_scores(doc_embs, doc_mask, queries):
    """(B, N, L, M) x (B, T, M) -> scores (B, N) = sum_t max_l sims.

    Lowered through the tiled ``maxsim_batch_op`` kernel path (Pallas on
    TPU, interpret on CPU, L-chunked jnp under REPRO_KERNEL_IMPL=ref) —
    no dispatch target materializes the (B, N, L, T) similarity tensor.
    ``doc_embs`` may be a quantized gather (``QuantTokens`` with a
    (B, N, L, M) payload): the kernels dequantize per VMEM block."""
    h = maxsim_batch_op(doc_embs, doc_mask, queries)          # (B, N, T)
    h = jnp.where(jnp.any(doc_mask, axis=2)[:, :, None], h, 0.0)
    return jnp.sum(h, axis=-1)


# ---------------------------------------------------------------------------
# Shared candidate-routing / gather / merge path.
#
# Every rerank flavor does the same three things around its scorer:
#   1. gather candidate token embeddings by (possibly -1-padded) doc id,
#   2. translate shard-local slots to global doc ids (shard_map flavors),
#   3. merge per-shard scorecards into a global top-K.
# These helpers are that one path; the step builders below only differ in
# the scorer they plug into the middle.
# ---------------------------------------------------------------------------

def gather_candidates(corpus_embs, corpus_mask, cand_ids):
    """Gather candidate token embeddings by global doc id.

    corpus_embs (C, L, M), corpus_mask (C, L), cand_ids (B, N) with -1
    padding -> docs (B, N, L, M), dmask (B, N, L) (all-False for padding).
    Thin alias of the facade's :func:`repro.retrieval.corpus.gather_tokens`
    (one shared gather => every flavor agrees on pad semantics).
    """
    return gather_tokens(corpus_embs, corpus_mask, cand_ids)


def _gathered_docs_spec(every, corpus_format: str):
    """shard_map PartitionSpec for a pre-gathered (B, N, L, M) candidate
    operand, batch-sharded over ``every``. Quantized formats need a
    ``QuantTokens`` OF specs mirroring the operand's pytree structure."""
    dense = P(every, None, None, None)
    if corpus_format == "bf16":
        return dense
    side = P(every, None, None)
    residual = corpus_format == "residual"
    return QuantTokens(data=dense, scales=side,
                       codes=side if residual else None,
                       codebook=P(None, None) if residual else None)


def _require_dense(corpus_embs, where: str):
    """Loud failure for the flavors whose math needs raw embedding rows
    (stage-1 kNN, pooled summaries, the legacy per-query einsum)."""
    if isinstance(corpus_embs, QuantTokens):
        raise ValueError(
            f"{where} requires a dense (bf16/f32) corpus; got a "
            f"{corpus_embs.fmt!r}-quantized one. Rebuild the corpus with "
            "corpus_format='bf16' or pick a quantization-aware flavor "
            "(dense/bandit/streaming).")


def _shard_index(every):
    """Linearized position of this shard in the (row-major) mesh axis group
    — the doc-dim shard number ``jax.sharding`` assigns this device."""
    shard_ix = jnp.int32(0)
    mul = 1
    for ax in reversed(every):
        shard_ix = shard_ix + mul * jax.lax.axis_index(ax)
        mul = mul * jax.lax.axis_size(ax)
    return shard_ix


def _shard_global_ids(cand, c_loc, every, valid_docs=None):
    """Shard-local candidate slot -> global doc id (inside shard_map).

    ``valid_docs`` is the (n_shards,) replicated ragged-tail table from
    :class:`repro.retrieval.sharded.ShardedCorpus`: shard ``s`` genuinely
    owns only ``valid_docs[s]`` of its ``c_loc`` padded rows, so a slot
    pointing past that count maps to -1 instead of a padded-tail global id
    (which, unclamped, would be a perfectly in-range id that scores the
    zero embedding — or, with an unpadded ``c_loc``, alias a real doc on
    another shard). ``None`` keeps the legacy every-shard-full contract.
    """
    shard_ix = _shard_index(every)
    owned = jnp.int32(c_loc) if valid_docs is None else valid_docs[shard_ix]
    ok = (cand >= 0) & (cand < owned)
    return jnp.where(ok, cand + shard_ix * c_loc, -1)


def _merge_scorecards(scores, gids, every, topk):
    """All-gather per-shard scorecards and take the global top-K.
    The only cross-shard traffic in the corpus-resident flavors.

    Each shard first reduces its (B, N_loc) scorecard to its local top-K —
    a slot that does not make a shard's own top-K cannot make the global
    one — so the gather moves exactly (B, K) scores + ids per shard
    whatever the candidate width. That makes the serving engine's audited
    collective budget (``analysis.hlo_audit.scorecard_budget_bytes``) a
    structural property of this merge, not an optimizer accident.

    Pad entries (gid < 0: -1-padded slots, ragged-tail clamps, short
    per-shard top-K lists) are masked to the -inf sentinel HERE, not left
    to each scorer: a shard with fewer than ``topk`` valid candidates used
    to ship its pads' raw scores into the gather, where a 0.0 pad could
    outrank a genuinely negative real score. Result sets with fewer than
    ``topk`` valid candidates overall return -1 ids for the shortfall."""
    scores = jnp.where(gids >= 0, scores, _NEG)
    if scores.shape[1] > topk:
        scores, pos = jax.lax.top_k(scores, topk)
        gids = jnp.take_along_axis(gids, pos, axis=1)
    all_scores = jax.lax.all_gather(scores, every, axis=1, tiled=True)
    all_gids = jax.lax.all_gather(gids, every, axis=1, tiled=True)
    all_scores = jnp.where(all_gids >= 0, all_scores, _NEG)
    best, pos = jax.lax.top_k(all_scores, topk)
    ids = jnp.take_along_axis(all_gids, pos, axis=1)
    return best, jnp.where(best > _NEG / 2, ids, -1)


def _chunked_over_queries(score_chunk, args, chunk=512):
    """Map ``score_chunk`` over the query batch in bounded-size chunks so the
    gathered-docs working set stays small; falls back to one call when the
    batch does not divide evenly.

    ``score_chunk`` MUST return exactly one 2-D (chunk_size, n_scores)
    array per chunk: the chunked path re-assembles with a flat
    ``reshape(B, -1)``, which would silently flatten any extra trailing
    axes (e.g. a frontier-backed scorer returning per-round diagnostics)
    into the score axis. Checked at trace time so new scorers fail loudly
    instead of corrupting the scorecard merge."""
    B = args[0].shape[0]
    chunk = min(B, chunk)
    if B % chunk == 0 and B > chunk:
        nch = B // chunk
        out = jax.lax.map(
            score_chunk,
            tuple(x.reshape(nch, chunk, *x.shape[1:]) for x in args))
        if out.ndim != 3:
            raise ValueError(
                "_chunked_over_queries: score_chunk must return a single "
                f"2-D (chunk, n_scores) array per chunk; got mapped shape "
                f"{out.shape}. Return diagnostics through a separate "
                "un-chunked path instead.")
        return out.reshape(B, -1)
    out = score_chunk(args)
    if out.ndim != 2:
        raise ValueError(
            "_chunked_over_queries: score_chunk must return a 2-D "
            f"(batch, n_scores) array; got shape {out.shape}.")
    return out


def make_rerank_dense_step(mesh: Mesh, *, topk: int = 10,
                           valid_docs=None, corpus_format: str = "bf16"):
    """Returns a jit-able step:
    (corpus_embs (C,L,M), corpus_mask (C,L), queries (B,T,M),
     cand_local (B, n_shards, N_loc) local slot ids, -1 pad)
     -> (topk_scores (B, K), topk_ids (B, K) global doc ids).

    Corpus docs shard over EVERY mesh axis (the index is the big object);
    queries are replicated (33 MB at B=4096 — cheap) so each corpus shard
    scores its resident candidates for all queries; the only cross-shard
    traffic is the (B, n_shards*N_loc) scorecard all-gather.

    ``valid_docs`` is ShardedCorpus's (n_shards,) ragged-tail table (see
    ``_shard_global_ids``); omit it for an exactly-divisible corpus.
    ``corpus_format`` must match the resident corpus (``ShardedCorpus
    .fmt``) — shard_map in_specs are built before the operands arrive, so
    the quantized pytree structure has to be declared up front."""
    every = tuple(mesh.axis_names)
    vd = None if valid_docs is None else jnp.asarray(valid_docs, jnp.int32)
    embs_spec = corpus_embs_spec(mesh, corpus_format)

    def step(corpus_embs, corpus_mask, queries, cand_local):
        def shard_fn(c_embs, c_mask, q, cand):
            # c_embs: (C_loc, L, M); q: (B, T, M) full; cand: (B, 1, N_loc)
            cand = cand[:, 0, :]                              # (B, N_loc)
            gids = _shard_global_ids(cand, c_embs.shape[0], every, vd)

            def score_chunk(args):
                q_c, cand_c = args
                docs, dmask = gather_candidates(c_embs, c_mask, cand_c)
                return _local_maxsim_scores(docs, dmask, q_c)

            scores = _chunked_over_queries(score_chunk, (q, cand))
            scores = jnp.where(gids >= 0, scores, _NEG)
            return _merge_scorecards(scores, gids, every, topk)

        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(embs_spec,
                      P(every, None),
                      P(None, None, None),
                      P(None, every, None)),
            out_specs=(P(None, None), P(None, None)),
        )(corpus_embs, corpus_mask, queries, cand_local)

    return step


def _bandit_one_query(cfg: BatchedConfig):
    """Per-query Col-Bandit over pre-gathered candidate embeddings — the
    legacy lockstep engine (kept for A/B benchmarking against the pooled
    frontier; select with ``engine="vmapped"``).

    Returns a closure (docs_q (N,L,M), dmask_q (N,L), q (T,M), cand_q (N,),
    a_q/b_q (N,T), key) -> (topk_scores (K,), topk_global_ids (K,),
    coverage ()). The reveal op is the gathered MaxSim einsum; under vmap
    every query pays the slowest query's round count."""

    def one_query(docs_q, dmask_q, q, cand_q, a_q, b_q, key):
        def cells(doc_idx, tok_idx):
            e = jnp.take(docs_q, doc_idx, axis=0)           # (Bd, L, M)
            m = jnp.take(dmask_q, doc_idx, axis=0)
            qq = jnp.take(q, tok_idx, axis=0)               # (Bd, G, M)
            sims = jnp.einsum("blm,bgm->blg", e.astype(jnp.float32),
                              qq.astype(jnp.float32))
            sims = jnp.where(m[:, :, None], sims, _NEG)
            return jnp.max(sims, axis=1)
        res = run_batched_bandit(cells, a_q, b_q, key, cfg,
                                 doc_mask=cand_q >= 0)
        gids = jnp.where(jnp.take(cand_q, res.topk) >= 0,
                         jnp.take(cand_q, res.topk), -1)
        return jnp.take(res.s_hat, res.topk), gids, res.coverage, res.rounds

    return one_query


def _vmapped_rerank(docs, dmask, queries, cand_ids, a, b, keys,
                    cfg: BatchedConfig, *, alpha_scale=None, round_cap=None):
    """Lockstep engine: vmap the solo bandit over the query batch.

    The legacy path has no traced fidelity knobs (``alpha_scale`` /
    ``round_cap`` are accepted for signature parity and ignored) and no
    in-loop quarantine; a final finite-score guard drops any non-finite
    top-K entry to the -inf sentinel so poisoned cells can never surface
    in a result list."""
    del alpha_scale, round_cap
    _require_dense(docs, "the vmapped lockstep engine")
    scores, gids, cov, rounds = jax.vmap(_bandit_one_query(cfg))(
        docs, dmask, queries, cand_ids, a, b, keys)
    bad = ~jnp.isfinite(scores)
    quar = jnp.sum(bad).astype(jnp.float32)
    scores = jnp.where(bad, _NEG, scores)
    gids = jnp.where(bad, -1, gids)
    return scores, gids, cov, _lockstep_stats(rounds, quar)


def _lockstep_stats(rounds, quarantined):
    """(occupancy, total_rounds, lockstep_waste, quarantined) for a vmapped
    run: the while_loop executes every query to max(rounds), so waste is
    what the batch PAID for already-converged queries."""
    Bq = rounds.shape[0]
    total = jnp.sum(rounds)
    trips = jnp.max(rounds)
    paid = jnp.maximum(Bq * trips, 1)
    return jnp.stack([total.astype(jnp.float32) / paid.astype(jnp.float32),
                      total.astype(jnp.float32),
                      (paid - total).astype(jnp.float32),
                      jnp.asarray(quarantined, jnp.float32)])


def _pooled_rerank(docs, dmask, queries, cand_ids, a, b, keys,
                   cfg: BatchedConfig, *, fused=None, prereveal=None,
                   prereveal_vals=None, alpha_scale=None, round_cap=None):
    """Pooled frontier engine over pre-gathered candidates.

    Stacks the (B, N, L, M) candidates to (B*N, L, M) and the query tokens
    to (B*T, M); every bandit round then reveals ALL queries' selected
    blocks with one kernel launch on query-offset indices — the
    dense-as-the-hardware-allows reveal the paper's FLOP savings need.
    ``fused=None`` (the default) lowers the round through the fused reveal
    kernel (``fused_reveal_op``: in-kernel doc gather + MaxSim +
    sufficient-statistic accumulation) everywhere except the
    ``REPRO_KERNEL_IMPL=ref`` oracle lane, which keeps the unfused
    ``gather_maxsim_op`` -> scatter chain; ``fused=False`` forces the
    chain for A/B. ``prereveal``/``prereveal_vals`` (B, N, T) seed the
    bandit with exactly-known cells (the stage-1 ANN hit values) at zero
    reveal cost. ``alpha_scale``/``round_cap`` are the traced per-call
    fidelity knobs (graceful degradation ladder — see
    :func:`repro.core.frontier.run_pooled_bandit`); ``None`` is
    bit-identical to the pre-knob path. Returns (topk_scores (B, K),
    topk_global_ids (B, K), coverage (B,), stats (4,) = [frontier
    occupancy, total rounds, lockstep waste, quarantined docs])."""
    Bq, N, L, M = docs.shape
    T = queries.shape[1]
    stacked = corpus_reshape(docs, Bq * N)     # quantized: leaf-wise reshape
    stacked_mask = dmask.reshape(Bq * N, L)
    flat_q = queries.reshape(Bq * T, M)

    def cells(flat_doc, flat_tok):
        return gather_maxsim_op(stacked, stacked_mask, flat_q,
                                flat_doc, flat_tok)

    def cells_fused(flat_doc, flat_tok, new_mask):
        return fused_reveal_op(stacked, stacked_mask, flat_q,
                               flat_doc, flat_tok, new_mask)

    res = run_pooled_bandit(cells, a, b, keys, cfg, doc_mask=cand_ids >= 0,
                            compute_cells_fused=cells_fused, fused=fused,
                            prereveal=prereveal,
                            prereveal_vals=prereveal_vals,
                            alpha_scale=alpha_scale, round_cap=round_cap)
    scores = jnp.take_along_axis(res.s_hat, res.topk, axis=1)
    picked = jnp.take_along_axis(cand_ids, res.topk, axis=1)
    gids = jnp.where(picked >= 0, picked, -1)
    stats = jnp.stack([res.occupancy,
                       res.total_rounds.astype(jnp.float32),
                       res.lockstep_waste.astype(jnp.float32),
                       jnp.sum(res.quarantined).astype(jnp.float32)])
    return scores, gids, res.coverage, stats


_RERANK_ENGINES = {
    "pooled": _pooled_rerank,                       # fused round (auto)
    "pooled_fused": functools.partial(_pooled_rerank, fused=True),
    "pooled_chain": functools.partial(_pooled_rerank, fused=False),
    "vmapped": _vmapped_rerank,
}


def _rerank_engine(engine: str):
    try:
        return _RERANK_ENGINES[engine]
    except KeyError:
        raise ValueError(f"unknown reveal engine: {engine!r} "
                         f"(expected one of {sorted(_RERANK_ENGINES)})"
                         ) from None


def make_rerank_bandit_step(mesh: Mesh, *, topk: int = 10,
                            alpha_ef: float = 0.3, delta: float = 0.01,
                            block_docs: int = 16, block_tokens: int = 8,
                            max_rounds: int = 64, max_block_docs: int = 0,
                            max_block_tokens: int = 0,
                            engine: str = "pooled",
                            placement: str = "query", base_seed: int = 0,
                            corpus_format: str = "bf16"):
    """Adaptive reranking step: the Col-Bandit over a sharded machine.

    ``placement`` picks which side of the gather stays resident:

    * ``"query"`` (default) — queries shard over every axis; each device
      gathers its queries' candidate embeddings once and runs ONE pooled
      frontier loop over its whole query shard (``engine="pooled"``;
      ``engine="vmapped"`` keeps the legacy lockstep path for A/B).
      Returns ``(step, in_specs, out_specs)`` for the caller to lower.
    * ``"corpus"`` — the corpus-resident shard_map flavor: the (C, L, M)
      index shards over every axis, queries replicate, and every shard
      runs the pooled frontier engine over its OWN resident candidates;
      the per-shard K-sized scorecards are the only cross-shard traffic
      (``_merge_scorecards``). Returns the shard_map-applied step with the
      ``make_sharded_serving_step`` signature (it IS that factory's
      ``flavor="bandit"``), including the ragged-tail ``valid_docs`` clamp.
    """
    if placement == "corpus":
        return make_sharded_serving_step(
            mesh, "bandit", topk=topk, alpha_ef=alpha_ef, delta=delta,
            block_docs=block_docs, block_tokens=block_tokens,
            max_rounds=max_rounds, max_block_docs=max_block_docs,
            max_block_tokens=max_block_tokens, engine=engine,
            base_seed=base_seed, corpus_format=corpus_format)
    if placement != "query":
        raise ValueError(f"unknown placement: {placement!r} "
                         "(expected 'query' or 'corpus')")
    names = tuple(mesh.axis_names)
    every = tuple(names)

    cfg = BatchedConfig(k=topk, delta=delta, alpha_ef=alpha_ef,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds, max_block_docs=max_block_docs,
                        max_block_tokens=max_block_tokens)
    rerank = _rerank_engine(engine)

    def step(docs, dmask, queries, cand_ids, a, b):
        """docs (B, N, L, M) pre-gathered candidate embeddings (the routing
        layer gathers them from the sharded corpus as part of stage 1);
        queries (B, T, M), cand_ids (B, N), a/b (B, N, T) support bounds —
        all sharded over every axis on B.
        Returns (topk_global_ids (B, K), coverage (B,))."""
        B = queries.shape[0]
        keys = jax.random.split(jax.random.key(0), B)
        _, gids, cov, _ = rerank(docs, dmask, queries, cand_ids, a, b,
                                 keys, cfg)
        return gids, cov

    in_specs = (_gathered_docs_spec(every, corpus_format),  # docs (B,N,L,M)
                P(every, None, None),          # dmask (B, N, L)
                P(every, None, None),          # queries (B, T, M)
                P(every, None),                # cand_ids (B, N)
                P(every, None, None),          # a (B, N, T)
                P(every, None, None))          # b
    out_specs = (P(every, None), P(every))

    return step, in_specs, out_specs


def _budgeted_scores(docs, dmask, queries, toks):
    """Budgeted MaxSim over the selected query tokens, lowered through the
    ``gather_maxsim_op`` kernel path (the bandit's reveal kernel — a
    FLASH-MAXSIM-style fused gather+score instead of materializing the
    (b, N, L, G') similarity tensor the einsum formulation paid for).

    docs (b, N, L, M), dmask (b, N, L), queries (b, T, M),
    toks (b, N, G') -> scores (b, N) = sum over the G' selected cells.
    """
    b, N, L, M = docs.shape
    T = queries.shape[1]
    G = toks.shape[-1]
    doc_idx = jnp.arange(b * N, dtype=jnp.int32)
    # Query-offset token ids into the stacked (b*T, M) table — the same
    # stacking contract the pooled frontier feeds this kernel. Clamp
    # BEFORE offsetting: a -1 pad would otherwise land on q*T - 1, the
    # previous query's last token (the einsum path this replaced clamped
    # via take_along_axis, so keep that contract).
    tok_flat = (jnp.clip(toks.reshape(b * N, G).astype(jnp.int32), 0, T - 1)
                + (doc_idx // N * T)[:, None])
    h = gather_maxsim_op(docs.reshape(b * N, L, M), dmask.reshape(b * N, L),
                         queries.reshape(b * T, M), doc_idx, tok_flat)
    h = h.reshape(b, N, G)                                # _NEG where no
    h = jnp.where(jnp.any(dmask, 2)[:, :, None], h, 0.0)  # valid doc token
    return jnp.sum(h, axis=-1)


def make_rerank_budgeted_step(mesh: Mesh, *, topk: int = 10,
                              tokens_per_doc: int = 10, valid_docs=None):
    """§Perf: the paper's pruning INSIDE the sharded serving step.

    Identical layout to make_rerank_dense_step, but each (query, candidate)
    pair scores only ``tokens_per_doc`` of the T query tokens — the ones the
    bounds machinery selected (Doc-TopMargin order offline, or the bandit's
    reveal set online), supplied as ``tok_idx``. The scorer gathers exactly
    the selected (candidate, token) cells through ``gather_maxsim_op``
    (Pallas on TPU) instead of contracting a gathered query einsum, so
    compiled FLOPs/bytes drop by ~G'/T — Col-Bandit's coverage savings
    made visible to the roofline."""
    every = tuple(mesh.axis_names)
    vd = None if valid_docs is None else jnp.asarray(valid_docs, jnp.int32)

    def step(corpus_embs, corpus_mask, queries, cand_local, tok_idx):
        _require_dense(corpus_embs, "the budgeted serving step")

        def shard_fn(c_embs, c_mask, q, cand, toks):
            cand = cand[:, 0, :]                              # (B, N_loc)
            toks = toks[:, 0, :, :]                           # (B, N_loc, G')
            gids = _shard_global_ids(cand, c_embs.shape[0], every, vd)

            def score_chunk(args):
                q_c, cand_c, tok_c = args
                docs, dmask = gather_candidates(c_embs, c_mask, cand_c)
                return _budgeted_scores(docs, dmask, q_c, tok_c)

            scores = _chunked_over_queries(score_chunk, (q, cand, toks))
            scores = jnp.where(gids >= 0, scores, _NEG)
            return _merge_scorecards(scores, gids, every, topk)

        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(P(every, None, None), P(every, None),
                      P(None, None, None), P(None, every, None),
                      P(None, every, None, None)),
            out_specs=(P(None, None), P(None, None)),
        )(corpus_embs, corpus_mask, queries, cand_local, tok_idx)

    return step


def make_rerank_two_phase_step(mesh: Mesh, *, topk: int = 10,
                               survivors: int = 2, valid_docs=None):
    """§Perf H3 iteration 2: PLAID-style two-phase scoring.

    H3 iteration 1 (token pruning) taught us the dominant memory term is
    READING candidate token embeddings (L x M per doc), which query-token
    pruning cannot cut. Phase 1 therefore screens candidates on a POOLED
    doc summary (1 x M per doc — 128x fewer bytes): approx score =
    sum_t <q_t, pooled_d>. Only the top ``survivors`` of N_loc candidates
    per (query, shard) proceed to exact MaxSim scoring — the full
    (L x M)-byte reads shrink by survivors/N_loc.

    Non-survivors keep their phase-1 score in the global merge (standard
    multi-stage retrieval semantics: monotone-ish, not exact). Phase 2
    (exact MaxSim on the survivors) lowers through ``maxsim_batch_op`` via
    ``_local_maxsim_scores``; phase 1 is a plain (b, N, M) matmul with no
    token axis to tile, so it stays jnp."""
    every = tuple(mesh.axis_names)
    vd = None if valid_docs is None else jnp.asarray(valid_docs, jnp.int32)

    def step(corpus_embs, corpus_mask, corpus_pooled, queries, cand_local):
        _require_dense(corpus_embs, "the two-phase serving step")

        def shard_fn(c_embs, c_mask, c_pool, q, cand):
            cand = cand[:, 0, :]                              # (B, N_loc)
            gids = _shard_global_ids(cand, c_embs.shape[0], every, vd)

            def score_chunk(args):
                q_c, cand_c = args                            # (b,T,M),(b,N)
                safe = jnp.maximum(cand_c, 0)
                # --- phase 1: pooled screening (M bytes per doc) ---
                pooled = jnp.take(c_pool, safe, axis=0)       # (b, N, M)
                q_sum = jnp.sum(q_c.astype(jnp.float32), axis=1)   # (b, M)
                s1 = jnp.einsum("bnm,bm->bn", pooled.astype(jnp.float32),
                                q_sum)
                s1 = jnp.where(cand_c >= 0, s1, _NEG)
                # --- phase 2: exact MaxSim for the survivors only ---
                _, surv_pos = jax.lax.top_k(s1, survivors)    # (b, k2)
                surv_ids = jnp.take_along_axis(cand_c, surv_pos, axis=1)
                docs, dmask = gather_candidates(c_embs, c_mask, surv_ids)
                s2 = _local_maxsim_scores(docs, dmask, q_c)   # (b, k2)
                s2 = jnp.where(surv_ids >= 0, s2, _NEG)
                # exact scores override the phase-1 proxies
                out = s1 * 1e-3                               # keep ordering,
                out = out.at[jnp.arange(out.shape[0])[:, None],  # under exact
                             surv_pos].set(s2)
                return out

            scores = _chunked_over_queries(score_chunk, (q, cand))
            return _merge_scorecards(scores, gids, every, topk)

        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(P(every, None, None), P(every, None), P(every, None),
                      P(None, None, None), P(None, every, None)),
            out_specs=(P(None, None), P(None, None)),
        )(corpus_embs, corpus_mask, corpus_pooled, queries, cand_local)

    return step


# ---------------------------------------------------------------------------
# Engine-facing serving steps (repro.serve.RetrievalEngine).
#
# Same scorers as the shard_map flavors above, but expressed as plain
# jit-able programs over a replicated (or host-local) corpus: the engine
# pads every batch into a small set of static (B, T_bucket, N_bucket)
# shapes and AOT-compiles one executable per bucket, so these must be pure
# functions of statically-shaped arrays. Both flavors share the
# ``gather_candidates`` routing path and one uniform signature:
#
#   step(corpus_embs, corpus_mask, queries, cand_ids, a, b, key,
#        [alpha_scale (), round_cap ()])
#     -> (topk_scores (B, K), topk_global_ids (B, K), reveal_frac (B,),
#         stats (4,))
#
# ``reveal_frac`` is the fraction of (candidate, token) MaxSim cells the
# flavor actually computed: 1.0 for dense, the bandit's coverage (Eq. 6)
# for the adaptive flavor. ``stats`` is the reveal-engine diagnostic
# vector [frontier_occupancy, total_rounds, lockstep_waste, quarantined]:
# for the pooled engine, occupancy is the measured live-slot fraction of
# the shared frontier; for the vmapped engine it is the lockstep duty
# cycle sum(rounds) / (B * max(rounds)); dense reports [1, 0, 0, q].
# ``quarantined`` counts docs (cells for vmapped/dense) whose MaxSim hit
# a non-finite value and were excluded from the top-K — a poisoned-corpus
# signal, 0 on clean data. ``alpha_scale`` (f32) and ``round_cap`` (i32,
# <= 0 disables) are OPTIONAL traced fidelity knobs for the degradation
# ladder; omitted, the step traces bit-identical to the pre-knob engine.
# ---------------------------------------------------------------------------

def rerank_dense_step(corpus_embs, corpus_mask, queries, cand_ids, a, b,
                      key, *, topk: int = 10, alpha_scale=None,
                      round_cap=None):
    """Exact MaxSim over the candidate list; a/b/key (and the fidelity
    knobs — dense has no fidelity to trade) accepted and ignored so dense
    and bandit executables are interchangeable to the engine. Non-finite
    scores (poisoned corpus rows) are quarantined to the -inf sentinel and
    counted in ``stats[3]``."""
    del a, b, key, alpha_scale, round_cap
    docs, dmask = gather_candidates(corpus_embs, corpus_mask, cand_ids)
    scores = _local_maxsim_scores(docs, dmask, queries)
    finite = jnp.isfinite(scores)
    quar = jnp.sum((cand_ids >= 0) & ~finite).astype(jnp.float32)
    scores = jnp.where((cand_ids >= 0) & finite, scores, _NEG)
    best, pos = jax.lax.top_k(scores, topk)
    gids = jnp.take_along_axis(cand_ids, pos, axis=1)
    gids = jnp.where(best > _NEG / 2, gids, -1)
    frac = jnp.ones((queries.shape[0],), jnp.float32)
    stats = jnp.stack([jnp.float32(1.0), jnp.float32(0.0),
                       jnp.float32(0.0), quar])
    return best, gids, frac, stats


def rerank_bandit_step(corpus_embs, corpus_mask, queries, cand_ids, a, b,
                       key, *, topk: int = 10, alpha_ef: float = 0.3,
                       delta: float = 0.01, block_docs: int = 8,
                       block_tokens: int = 8, max_rounds: int = -1,
                       max_block_docs: int = 0, max_block_tokens: int = 0,
                       engine: str = "pooled", alpha_scale=None,
                       round_cap=None):
    """Adaptive Col-Bandit rerank over the candidate list.

    ``engine="pooled"`` (default) drives the whole batch through one
    pooled frontier loop — one gather_maxsim kernel launch per round,
    converged queries retired (and, with ``max_block_docs`` >
    ``block_docs``, their reveal slots redistributed to the stragglers).
    ``engine="vmapped"`` is the legacy per-query lockstep loop (it
    ignores the traced ``alpha_scale``/``round_cap`` fidelity knobs)."""
    rerank = _rerank_engine(engine)
    cfg = BatchedConfig(k=topk, delta=delta, alpha_ef=alpha_ef,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds, max_block_docs=max_block_docs,
                        max_block_tokens=max_block_tokens)
    docs, dmask = gather_candidates(corpus_embs, corpus_mask, cand_ids)
    keys = jax.random.split(key, queries.shape[0])
    return rerank(docs, dmask, queries, cand_ids, a, b, keys, cfg,
                  alpha_scale=alpha_scale, round_cap=round_cap)


def make_serving_step(flavor: str, *, topk: int = 10, alpha_ef: float = 0.3,
                      delta: float = 0.01, block_docs: int = 8,
                      block_tokens: int = 8, max_rounds: int = -1,
                      max_block_docs: int = 0, max_block_tokens: int = 0,
                      engine: str = "pooled"):
    """Shape-bucket-aware step factory the serving engine consumes.

    Returns an un-jitted step with the uniform engine signature; the caller
    owns compilation (``RetrievalEngine`` AOT-lowers one executable per
    (flavor, token-bucket, candidate-bucket) and keeps the cache warm).
    ``engine`` picks the bandit reveal engine (pooled frontier vs legacy
    vmapped lockstep); dense ignores it."""
    _rerank_engine(engine)
    if flavor == "dense":
        return functools.partial(rerank_dense_step, topk=topk)
    if flavor == "bandit":
        return functools.partial(
            rerank_bandit_step, topk=topk, alpha_ef=alpha_ef, delta=delta,
            block_docs=block_docs, block_tokens=block_tokens,
            max_rounds=max_rounds, max_block_docs=max_block_docs,
            max_block_tokens=max_block_tokens, engine=engine)
    raise ValueError(f"unknown serving flavor: {flavor!r}")


# ---------------------------------------------------------------------------
# Continuous-batching (slot-refill) engine-facing step.
#
# The batch steps above run each admitted batch to quiescence: every query
# in the batch rides the global while_loop until the LAST one separates,
# and a new batch cannot start until the whole previous one drains. The
# streaming step instead runs the pooled bandit a bounded number of trips
# per call and hands the packed per-slot frontier state back to the host:
#
#   step(corpus_embs, corpus_mask, queries (B, T, M), cand_ids (B, N),
#        a (B, N, T), b (B, N, T), state (FrontierState), fresh (B,) bool,
#        keys (B,) per-slot PRNG keys)
#     -> (topk_scores (B, K), topk_global_ids (B, K), reveal_frac (B,),
#         stats (4,), done (B,) bool, new_state (FrontierState))
#
# The host loop (``serve.AsyncRetrievalEngine`` continuous mode) harvests
# slots with ``done`` set — their score/gid/coverage rows are final —
# refills them from the admission queue (new query tokens + candidates in
# those rows, ``fresh`` marking them) and re-enters the SAME compiled
# executable: one static (B, T, N) shape, zero recompiles, retirement
# granularity of ``trip_limit`` reveal rounds instead of a whole batch.
# Carried slots' query/candidate/bound rows must be re-presented unchanged.
# ---------------------------------------------------------------------------

def init_stream_state(B: int, N: int, T: int) -> FrontierState:
    """All-slots-retired frontier carry for a (B, N-candidate, T-token)
    streaming step — the state a continuous-batching loop starts from."""
    return init_frontier_state(B, N, T)


def make_streaming_step(*, topk: int = 10, alpha_ef: float = 0.3,
                        delta: float = 0.01, block_docs: int = 8,
                        block_tokens: int = 8, max_rounds: int = -1,
                        max_block_docs: int = 0, max_block_tokens: int = 0,
                        trip_limit: int = 4, fused=None):
    """Slot-refill serving step factory (bandit flavor only — dense has no
    rounds to slice). ``trip_limit`` is the slice length: how many global
    reveal rounds one device dispatch advances every live slot before
    control returns to the host for harvest/refill. Small values shrink
    refill latency (a retired slot idles at most ``trip_limit`` rounds);
    large values amortize dispatch overhead. ``fused`` as in
    :func:`_pooled_rerank` (None = auto by REPRO_KERNEL_IMPL)."""
    if trip_limit < 1:
        raise ValueError("trip_limit must be >= 1")
    cfg = BatchedConfig(k=topk, delta=delta, alpha_ef=alpha_ef,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds, max_block_docs=max_block_docs,
                        max_block_tokens=max_block_tokens)

    def step(corpus_embs, corpus_mask, queries, cand_ids, a, b, state,
             fresh, keys):
        docs, dmask = gather_candidates(corpus_embs, corpus_mask, cand_ids)
        Bq, N, L, M = docs.shape
        T = queries.shape[1]
        stacked = corpus_reshape(docs, Bq * N)
        stacked_mask = dmask.reshape(Bq * N, L)
        flat_q = queries.reshape(Bq * T, M)

        def cells(flat_doc, flat_tok):
            return gather_maxsim_op(stacked, stacked_mask, flat_q,
                                    flat_doc, flat_tok)

        def cells_fused(flat_doc, flat_tok, new_mask):
            return fused_reveal_op(stacked, stacked_mask, flat_q,
                                   flat_doc, flat_tok, new_mask)

        res, new_state = run_pooled_bandit(
            cells, a, b, keys, cfg, doc_mask=cand_ids >= 0,
            compute_cells_fused=cells_fused, fused=fused,
            carry=state, fresh=fresh, trip_limit=trip_limit,
            return_state=True)
        scores = jnp.take_along_axis(res.s_hat, res.topk, axis=1)
        picked = jnp.take_along_axis(cand_ids, res.topk, axis=1)
        gids = jnp.where(picked >= 0, picked, -1)
        stats = jnp.stack([res.occupancy,
                           res.total_rounds.astype(jnp.float32),
                           res.lockstep_waste.astype(jnp.float32),
                           jnp.sum(res.quarantined).astype(jnp.float32)])
        # Harvestable = separated/no-progress OR round-capped: a slot that
        # exhausts max_rounds without separating must still leave the
        # stream, else the host would re-enter it forever. Mirrors
        # run_pooled_bandit's default when ``cfg.max_rounds <= 0``.
        mr = cfg.max_rounds
        if mr <= 0:
            mr = (N * T) // max(cfg.block_docs * cfg.block_tokens, 1) + T + 8
        harvest = new_state.done | (new_state.rounds >= mr)
        return scores, gids, res.coverage, stats, harvest, new_state

    return step


# ---------------------------------------------------------------------------
# Mesh-sharded engine-facing serving steps.
#
# Same contract as the un-sharded engine steps above, but the corpus lives
# sharded over EVERY mesh axis (repro.retrieval.sharded.ShardedCorpus) and
# candidates arrive pre-routed to their resident shard:
#
#   step(corpus_embs (C_pad, L, M), corpus_mask (C_pad, L),
#        queries (B, T, M), cand_local (B, n_shards, N_loc),
#        a_local/b_local (B, n_shards, N_loc, T),
#        valid_docs (n_shards,), seed (),
#        [healthy (n_shards,) bool, alpha_scale (), round_cap ()])
#     -> (topk_scores (B, K), topk_global_ids (B, K), reveal_frac (B,),
#         stats (n_shards, 4))
#
# Every shard scores (dense) or pooled-frontier-reranks (bandit) its OWN
# resident candidates; the only cross-shard traffic is the per-shard
# K-sized scorecard all-gather plus two scalar psums for the reveal
# fraction. ``stats`` keeps the [frontier_occupancy, total_rounds,
# lockstep_waste, quarantined] vector but PER SHARD, so the engine can
# surface shard skew (a shard whose frontier idles is a routing-imbalance
# signal) and per-shard poisoning. ``healthy`` masks failed shards out of
# the scorecard merge (their candidates score -inf everywhere, so healthy
# shards' results pass through untouched — graceful partial coverage);
# the fidelity knobs are traced scalars as in the flat steps. All three
# trailing operands are optional and default to the no-fault trace.
# ---------------------------------------------------------------------------

def make_sharded_serving_step(mesh: Mesh, flavor: str, *, topk: int = 10,
                              alpha_ef: float = 0.3, delta: float = 0.01,
                              block_docs: int = 8, block_tokens: int = 8,
                              max_rounds: int = -1, max_block_docs: int = 0,
                              max_block_tokens: int = 0,
                              engine: str = "pooled", base_seed: int = 0,
                              corpus_format: str = "bf16"):
    """Corpus-resident shard_map serving step (dense | bandit).

    The per-batch PRNG key is ``fold_in(key(base_seed), seed)`` with the
    shard index folded on top, so every (batch, shard) pair reveals an
    independent cell trajectory while the whole step stays a deterministic
    function of (base_seed, seed, inputs). ``corpus_format`` must match
    the resident ``ShardedCorpus.fmt``: a quantized corpus arrives as a
    ``QuantTokens`` pytree, and the shard_map in_specs (declared here,
    before tracing) must mirror its structure leaf-for-leaf."""
    every = tuple(mesh.axis_names)
    n_shards = 1
    for ax in every:
        n_shards *= int(mesh.shape[ax])
    if flavor not in ("dense", "bandit"):
        raise ValueError(f"unknown sharded serving flavor: {flavor!r}")
    rerank = _rerank_engine(engine)
    embs_spec = corpus_embs_spec(mesh, corpus_format)

    def step(corpus_embs, corpus_mask, queries, cand_local, a_local,
             b_local, valid_docs, seed, healthy=None, alpha_scale=None,
             round_cap=None):
        B, S, NL = cand_local.shape
        T = queries.shape[1]
        k_shard = min(topk, NL)
        if S != n_shards:
            raise ValueError(f"cand_local routed for {S} shards on a "
                             f"{n_shards}-shard mesh")
        if n_shards * k_shard < topk:
            raise ValueError(
                f"cannot assemble a global top-{topk} from {n_shards} "
                f"shards x {k_shard} candidate slots; raise N_loc")

        cfg = BatchedConfig(k=k_shard, delta=delta, alpha_ef=alpha_ef,
                            block_docs=block_docs, block_tokens=block_tokens,
                            max_rounds=max_rounds,
                            max_block_docs=max_block_docs,
                            max_block_tokens=max_block_tokens)

        # Materialize the optional fault/fidelity operands so the shard_map
        # signature stays static: defaults trace to the no-fault program.
        healthy = (jnp.ones((n_shards,), jnp.bool_) if healthy is None
                   else jnp.asarray(healthy, jnp.bool_))
        knobs = alpha_scale is not None or round_cap is not None
        asc = (jnp.float32(1.0) if alpha_scale is None
               else jnp.asarray(alpha_scale, jnp.float32))
        rcp = (jnp.int32(0) if round_cap is None
               else jnp.asarray(round_cap, jnp.int32))

        def shard_fn(c_embs, c_mask, q, cand, a_l, b_l, vd, sd, hl, a_s,
                     r_c):
            cand = cand[:, 0, :]                            # (B, N_loc)
            a_l, b_l = a_l[:, 0], b_l[:, 0]                 # (B, N_loc, T)
            gids = _shard_global_ids(cand, c_embs.shape[0], every, vd)
            # A failed shard contributes nothing: its candidates become
            # pads, so the scorecard merge masks them to -inf and the
            # psum'd reveal fraction reflects only the healthy corpus.
            valid = (gids >= 0) & hl[_shard_index(every)]
            gids = jnp.where(valid, gids, -1)
            docs, dmask = gather_candidates(c_embs, c_mask, cand)
            dmask = dmask & valid[:, :, None]
            n_cells = (jnp.sum(valid, axis=1) * T).astype(jnp.float32)

            if flavor == "dense":
                s = _local_maxsim_scores(docs, dmask, q)
                finite = jnp.isfinite(s)
                quar = jnp.sum(valid & ~finite).astype(jnp.float32)
                s = jnp.where(valid & finite, s, _NEG)
                best, pos = jax.lax.top_k(s, k_shard)
                bg = jnp.take_along_axis(gids, pos, axis=1)
                n_rev = n_cells
                stats_loc = jnp.stack([jnp.float32(1.0), jnp.float32(0.0),
                                       jnp.float32(0.0), quar])
            else:
                key = jax.random.fold_in(jax.random.key(base_seed), sd)
                key = jax.random.fold_in(key, _shard_index(every))
                keys = jax.random.split(key, cand.shape[0])
                kw = ({"alpha_scale": a_s, "round_cap": r_c} if knobs
                      else {})
                best, bg, cov, stats_loc = rerank(
                    docs, dmask, q, gids, a_l, b_l, keys, cfg, **kw)
                n_rev = cov * n_cells

            tot_rev = jax.lax.psum(n_rev, every)
            tot_cells = jax.lax.psum(n_cells, every)
            frac = tot_rev / jnp.maximum(tot_cells, 1.0)
            g_best, g_ids = _merge_scorecards(best, bg, every, topk)
            return g_best, g_ids, frac, stats_loc[None, :]

        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(embs_spec, P(every, None),
                      P(None, None, None), P(None, every, None),
                      P(None, every, None, None), P(None, every, None, None),
                      P(None), P(), P(None), P(), P()),
            out_specs=(P(None, None), P(None, None), P(None),
                       P(every, None)),
        )(corpus_embs, corpus_mask, queries, cand_local, a_local, b_local,
          valid_docs, seed, healthy, asc, rcp)

    return step


# ---------------------------------------------------------------------------
# One-shard_map routed pipeline: shard-local stage-1 + pooled rerank.
#
# The gather flavors above still split the pipeline across two
# architectures: stage-1 kNN and candidate routing run on the HOST
# (``ann.generate_candidates`` + ``sharded.route_batch``), then the
# shard_map step consumes the pre-routed (B, n_shards, N_loc) tables. The
# routed step below retires that round-trip: centroid routing, stage-1
# kNN over the shard's own (C_loc * L, M) tokens, Eq. 15 bounds, and the
# pooled bandit rerank ALL run inside one shard_map. Candidate ids,
# embeddings and bounds never leave their shard — the only cross-shard
# traffic is the K-sized scorecard all-gather plus two scalar psums.
#
#   step(corpus_embs (C_pad, L, M), corpus_mask (C_pad, L),
#        centroids (Kc, M), shard_mass (Kc, n_shards),   # replicated router
#        queries (B, T, M), valid_docs (n_shards,), seed (),
#        [healthy (n_shards,) bool, alpha_scale (), round_cap ()])
#     -> (topk_scores (B, K), topk_global_ids (B, K), reveal_frac (B,),
#         stats (n_shards, 6))
#
# ``stats`` extends the per-shard reveal diagnostics with two routing
# columns and the quarantine count: [occupancy, total_rounds,
# lockstep_waste, mean quota share, max quota share, quarantined] — the
# skew + poisoning signals ``metrics.summary()`` surfaces. ``healthy``
# additionally re-routes a failed shard's quota mass onto the healthy
# shards (``route_quotas(..., healthy=...)``) — shard-local failover with
# zero extra communication, since the quota table is replicated anyway.
# ---------------------------------------------------------------------------

def make_routed_serving_step(mesh: Mesh, flavor: str = "bandit", *,
                             topk: int = 10, n_local: int = 16,
                             n_total: int = 0, kprime: int = 8,
                             support: Tuple[float, float] = (0.0, 1.0),
                             prereveal_ann: bool = False,
                             alpha_ef: float = 0.3, delta: float = 0.01,
                             block_docs: int = 8, block_tokens: int = 8,
                             max_rounds: int = -1, max_block_docs: int = 0,
                             max_block_tokens: int = 0,
                             engine: str = "pooled", base_seed: int = 0,
                             corpus_format: str = "bf16"):
    """Shard-local stage-1 serving step (dense | bandit), centroid-routed.

    Dense corpora only: shard-local stage-1 runs kNN over the raw
    (C_loc * L, M) token rows, which a compressed-resident corpus does not
    expose (``corpus_format != 'bf16'`` raises). Use the gather flavors
    (``make_sharded_serving_step``) for quantized corpora.

    Every shard runs the replicated centroid router over the full query
    batch (identical (B, n_shards) quota table everywhere — routing costs
    zero communication), caps its own stage-1 kNN at its quota column when
    ``n_total > 0`` (skew-aware: a shard the router sends little mass to
    emits few candidates instead of a worst-case-uniform ``n_local``), and
    feeds its local ``CandidateSet`` — Eq. 15 a/b bounds included —
    straight into the scorer. ``prereveal_ann=True`` additionally seeds
    the bandit with the stage-1 hit cells' exact values (zero reveal
    cost). Quotas are deliberately NOT validated here: shard-local stage-1
    only ever emits docs the shard genuinely hit, so an over-quota shard
    yields fewer candidates, never a wrong id — the loud ``ValueError``
    lives on the host path (``CentroidRouter.route``).

    PRNG: ``fold_in(fold_in(key(base_seed), seed), shard_index)`` — same
    determinism contract as ``make_sharded_serving_step``."""
    every = tuple(mesh.axis_names)
    n_shards = 1
    for ax in every:
        n_shards *= int(mesh.shape[ax])
    if flavor not in ("dense", "bandit"):
        raise ValueError(f"unknown routed serving flavor: {flavor!r}")
    if corpus_format != "bf16":
        raise ValueError(
            "the routed serving step requires a dense (bf16/f32) corpus: "
            "shard-local stage-1 kNN scans raw token rows, which a "
            f"{corpus_format!r}-compressed corpus does not expose. Use "
            "make_sharded_serving_step (host-routed gather flavors) for "
            "quantized corpora.")
    rerank = _rerank_engine(engine)
    if prereveal_ann and engine == "vmapped":
        raise ValueError("prereveal_ann requires a pooled reveal engine "
                         "(the vmapped lockstep path has no prereveal)")
    k_shard = min(topk, n_local)
    if n_shards * k_shard < topk:
        raise ValueError(
            f"cannot assemble a global top-{topk} from {n_shards} shards "
            f"x {k_shard} candidate slots; raise n_local")

    cfg = BatchedConfig(k=k_shard, delta=delta, alpha_ef=alpha_ef,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds, max_block_docs=max_block_docs,
                        max_block_tokens=max_block_tokens)
    gen = functools.partial(generate_candidates, kprime=kprime,
                            max_candidates=n_local, support=support)

    def step(corpus_embs, corpus_mask, centroids, shard_mass, queries,
             valid_docs, seed, healthy=None, alpha_scale=None,
             round_cap=None):
        use_healthy = healthy is not None
        knobs = alpha_scale is not None or round_cap is not None
        healthy = (jnp.ones((n_shards,), jnp.bool_) if healthy is None
                   else jnp.asarray(healthy, jnp.bool_))
        asc = (jnp.float32(1.0) if alpha_scale is None
               else jnp.asarray(alpha_scale, jnp.float32))
        rcp = (jnp.int32(0) if round_cap is None
               else jnp.asarray(round_cap, jnp.int32))

        def shard_fn(c_embs, c_mask, cents, mass, q, vd, sd, hl, a_s, r_c):
            shard_ix = _shard_index(every)
            B, T = q.shape[0], q.shape[1]
            c_loc = c_embs.shape[0]

            # Centroid routing (replicated state => identical table on
            # every shard; each reads its own column). A failed shard's
            # quota mass is re-routed onto healthy shards HERE, so
            # failover costs zero extra candidates system-wide.
            m = route_mass(q, cents, mass)                    # (B, S)
            if n_total:
                quota = route_quotas(m, n_total,
                                     healthy=hl if use_healthy else None)
                my_quota = quota[:, shard_ix]                 # (B,)
                share = quota.astype(jnp.float32) / jnp.float32(n_total)
            else:
                my_quota = None
                share = jnp.full((B, n_shards), 1.0 / n_shards, jnp.float32)
            my_share = share[:, shard_ix]                     # (B,)

            # Shard-local stage-1: per-query-token kNN over this shard's
            # own (C_loc * L, M) tokens. Pad rows carry all-False masks so
            # they can never become candidates.
            if my_quota is None:
                cand = jax.vmap(lambda qq: gen(c_embs, c_mask, qq))(q)
            else:
                cand = jax.vmap(
                    lambda qq, nq: gen(c_embs, c_mask, qq, nq))(q, my_quota)

            gids = _shard_global_ids(cand.doc_ids, c_loc, every, vd)
            valid = (gids >= 0) & hl[shard_ix]
            gids = jnp.where(valid, gids, -1)
            docs, dmask = gather_candidates(c_embs, c_mask, cand.doc_ids)
            dmask = dmask & valid[:, :, None]
            n_cells = (jnp.sum(valid, axis=1) * T).astype(jnp.float32)

            if flavor == "dense":
                s = _local_maxsim_scores(docs, dmask, q)
                finite = jnp.isfinite(s)
                quar = jnp.sum(valid & ~finite).astype(jnp.float32)
                s = jnp.where(valid & finite, s, _NEG)
                best, pos = jax.lax.top_k(s, k_shard)
                bg = jnp.take_along_axis(gids, pos, axis=1)
                n_rev = n_cells
                stats4 = jnp.stack([jnp.float32(1.0), jnp.float32(0.0),
                                    jnp.float32(0.0), quar])
            else:
                key = jax.random.fold_in(jax.random.key(base_seed), sd)
                key = jax.random.fold_in(key, shard_ix)
                keys = jax.random.split(key, B)
                a_l = jnp.where(valid[:, :, None], cand.a, 0.0)
                b_l = jnp.where(valid[:, :, None], cand.b, 0.0)
                kw = {}
                n_known = jnp.zeros((B,), jnp.float32)
                if prereveal_ann:
                    pr = cand.known_mask & valid[:, :, None]
                    kw = dict(prereveal=pr, prereveal_vals=cand.known_vals)
                    n_known = jnp.sum(pr, axis=(1, 2)).astype(jnp.float32)
                if knobs:
                    kw.update(alpha_scale=a_s, round_cap=r_c)
                best, bg, cov, stats4 = rerank(
                    docs, dmask, q, gids, a_l, b_l, keys, cfg, **kw)
                # Reveal accounting: prereveal cells were free (stage 1
                # already computed them), so they don't count as work.
                n_rev = jnp.maximum(cov * n_cells - n_known, 0.0)

            tot_rev = jax.lax.psum(n_rev, every)
            tot_cells = jax.lax.psum(n_cells, every)
            frac = tot_rev / jnp.maximum(tot_cells, 1.0)
            g_best, g_ids = _merge_scorecards(best, bg, every, topk)
            # Column order keeps quarantine LAST so the routing-skew
            # columns stay at the indices metrics consumers already read.
            stats_loc = jnp.concatenate(
                [stats4[:3], jnp.stack([jnp.mean(my_share),
                                        jnp.max(my_share)]),
                 stats4[3:]])[None, :]
            return g_best, g_ids, frac, stats_loc

        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(P(every, None, None), P(every, None),
                      P(None, None), P(None, None),
                      P(None, None, None), P(None), P(), P(None), P(),
                      P()),
            out_specs=(P(None, None), P(None, None), P(None),
                       P(every, None)),
        )(corpus_embs, corpus_mask, centroids, shard_mass, queries,
          valid_docs, seed, healthy, asc, rcp)

    return step
