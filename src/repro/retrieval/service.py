"""Cluster-scale late-interaction serving (the paper's workload, distributed).

Two step flavors, both lowered by the multi-pod dry-run:

rerank_dense_step (corpus-resident scoring)
    The corpus token index (C, L, M) is sharded over ('model' [, 'pod']);
    queries are sharded over the FSDP group and replicated across corpus
    shards. The ANN stage routes each candidate to the shard that owns it
    (host-side routing table, standard in distributed retrieval): input
    ``cand_local`` (B, n_corpus_shards, N_loc) holds local doc slots. Each
    shard gathers its resident candidates, runs the dense MaxSim scorer, and
    the global top-K emerges from an all-gather of (scores, ids) — the only
    cross-shard traffic is K-sized scorecards, never token embeddings.

rerank_bandit_step (query-resident adaptive scoring)
    Queries are sharded over EVERY axis; each device gathers its queries'
    candidate embeddings once (collective gather from the sharded corpus)
    and then runs the block-synchronous Col-Bandit locally through the
    pooled cross-query reveal engine (``repro.core.frontier``): one global
    round loop for the device's whole query shard, every round's frontier
    lowered through a single ``gather_maxsim`` kernel launch, converged
    queries retired instead of riding lockstep to the slowest query.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.batched import BatchedConfig, run_batched_bandit
from repro.core.frontier import run_pooled_bandit
from repro.kernels.ops import gather_maxsim_op, maxsim_batch_op

_NEG = jnp.float32(-3e38)


def _local_maxsim_scores(doc_embs, doc_mask, queries):
    """(B, N, L, M) x (B, T, M) -> scores (B, N) = sum_t max_l sims.

    Lowered through the tiled ``maxsim_batch_op`` kernel path (Pallas on
    TPU, interpret on CPU, L-chunked jnp under REPRO_KERNEL_IMPL=ref) —
    no dispatch target materializes the (B, N, L, T) similarity tensor."""
    h = maxsim_batch_op(doc_embs, doc_mask, queries)          # (B, N, T)
    h = jnp.where(jnp.any(doc_mask, axis=2)[:, :, None], h, 0.0)
    return jnp.sum(h, axis=-1)


# ---------------------------------------------------------------------------
# Shared candidate-routing / gather / merge path.
#
# Every rerank flavor does the same three things around its scorer:
#   1. gather candidate token embeddings by (possibly -1-padded) doc id,
#   2. translate shard-local slots to global doc ids (shard_map flavors),
#   3. merge per-shard scorecards into a global top-K.
# These helpers are that one path; the step builders below only differ in
# the scorer they plug into the middle.
# ---------------------------------------------------------------------------

def gather_candidates(corpus_embs, corpus_mask, cand_ids):
    """Gather candidate token embeddings by global doc id.

    corpus_embs (C, L, M), corpus_mask (C, L), cand_ids (B, N) with -1
    padding -> docs (B, N, L, M), dmask (B, N, L) (all-False for padding).
    """
    safe = jnp.maximum(cand_ids, 0)
    docs = jnp.take(corpus_embs, safe, axis=0)
    dmask = jnp.take(corpus_mask, safe, axis=0) & (cand_ids >= 0)[:, :, None]
    return docs, dmask


def _shard_global_ids(cand, c_loc, every):
    """Shard-local candidate slot -> global doc id (inside shard_map)."""
    shard_ix = jnp.int32(0)
    mul = 1
    for ax in reversed(every):
        shard_ix = shard_ix + mul * jax.lax.axis_index(ax)
        mul = mul * jax.lax.axis_size(ax)
    return jnp.where(cand >= 0, cand + shard_ix * c_loc, -1)


def _merge_scorecards(scores, gids, every, topk):
    """All-gather (B, N_loc) per-shard scorecards and take the global top-K.
    The only cross-shard traffic in the corpus-resident flavors."""
    all_scores = jax.lax.all_gather(scores, every, axis=1, tiled=True)
    all_gids = jax.lax.all_gather(gids, every, axis=1, tiled=True)
    best, pos = jax.lax.top_k(all_scores, topk)
    return best, jnp.take_along_axis(all_gids, pos, axis=1)


def _chunked_over_queries(score_chunk, args, chunk=512):
    """Map ``score_chunk`` over the query batch in bounded-size chunks so the
    gathered-docs working set stays small; falls back to one call when the
    batch does not divide evenly.

    ``score_chunk`` MUST return exactly one 2-D (chunk_size, n_scores)
    array per chunk: the chunked path re-assembles with a flat
    ``reshape(B, -1)``, which would silently flatten any extra trailing
    axes (e.g. a frontier-backed scorer returning per-round diagnostics)
    into the score axis. Checked at trace time so new scorers fail loudly
    instead of corrupting the scorecard merge."""
    B = args[0].shape[0]
    chunk = min(B, chunk)
    if B % chunk == 0 and B > chunk:
        nch = B // chunk
        out = jax.lax.map(
            score_chunk,
            tuple(x.reshape(nch, chunk, *x.shape[1:]) for x in args))
        if out.ndim != 3:
            raise ValueError(
                "_chunked_over_queries: score_chunk must return a single "
                f"2-D (chunk, n_scores) array per chunk; got mapped shape "
                f"{out.shape}. Return diagnostics through a separate "
                "un-chunked path instead.")
        return out.reshape(B, -1)
    out = score_chunk(args)
    if out.ndim != 2:
        raise ValueError(
            "_chunked_over_queries: score_chunk must return a 2-D "
            f"(batch, n_scores) array; got shape {out.shape}.")
    return out


def make_rerank_dense_step(mesh: Mesh, *, topk: int = 10):
    """Returns a jit-able step:
    (corpus_embs (C,L,M), corpus_mask (C,L), queries (B,T,M),
     cand_local (B, n_shards, N_loc) local slot ids, -1 pad)
     -> (topk_scores (B, K), topk_ids (B, K) global doc ids).

    Corpus docs shard over EVERY mesh axis (the index is the big object);
    queries are replicated (33 MB at B=4096 — cheap) so each corpus shard
    scores its resident candidates for all queries; the only cross-shard
    traffic is the (B, n_shards*N_loc) scorecard all-gather."""
    every = tuple(mesh.axis_names)

    def step(corpus_embs, corpus_mask, queries, cand_local):
        def shard_fn(c_embs, c_mask, q, cand):
            # c_embs: (C_loc, L, M); q: (B, T, M) full; cand: (B, 1, N_loc)
            cand = cand[:, 0, :]                              # (B, N_loc)

            def score_chunk(args):
                q_c, cand_c = args
                docs, dmask = gather_candidates(c_embs, c_mask, cand_c)
                return _local_maxsim_scores(docs, dmask, q_c)

            scores = _chunked_over_queries(score_chunk, (q, cand))
            scores = jnp.where(cand >= 0, scores, _NEG)
            gids = _shard_global_ids(cand, c_embs.shape[0], every)
            return _merge_scorecards(scores, gids, every, topk)

        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(P(every, None, None),
                      P(every, None),
                      P(None, None, None),
                      P(None, every, None)),
            out_specs=(P(None, None), P(None, None)),
        )(corpus_embs, corpus_mask, queries, cand_local)

    return step


def _bandit_one_query(cfg: BatchedConfig):
    """Per-query Col-Bandit over pre-gathered candidate embeddings — the
    legacy lockstep engine (kept for A/B benchmarking against the pooled
    frontier; select with ``engine="vmapped"``).

    Returns a closure (docs_q (N,L,M), dmask_q (N,L), q (T,M), cand_q (N,),
    a_q/b_q (N,T), key) -> (topk_scores (K,), topk_global_ids (K,),
    coverage ()). The reveal op is the gathered MaxSim einsum; under vmap
    every query pays the slowest query's round count."""

    def one_query(docs_q, dmask_q, q, cand_q, a_q, b_q, key):
        def cells(doc_idx, tok_idx):
            e = jnp.take(docs_q, doc_idx, axis=0)           # (Bd, L, M)
            m = jnp.take(dmask_q, doc_idx, axis=0)
            qq = jnp.take(q, tok_idx, axis=0)               # (Bd, G, M)
            sims = jnp.einsum("blm,bgm->blg", e.astype(jnp.float32),
                              qq.astype(jnp.float32))
            sims = jnp.where(m[:, :, None], sims, _NEG)
            return jnp.max(sims, axis=1)
        res = run_batched_bandit(cells, a_q, b_q, key, cfg,
                                 doc_mask=cand_q >= 0)
        gids = jnp.where(jnp.take(cand_q, res.topk) >= 0,
                         jnp.take(cand_q, res.topk), -1)
        return jnp.take(res.s_hat, res.topk), gids, res.coverage, res.rounds

    return one_query


def _vmapped_rerank(docs, dmask, queries, cand_ids, a, b, keys,
                    cfg: BatchedConfig):
    """Lockstep engine: vmap the solo bandit over the query batch."""
    scores, gids, cov, rounds = jax.vmap(_bandit_one_query(cfg))(
        docs, dmask, queries, cand_ids, a, b, keys)
    return scores, gids, cov, _lockstep_stats(rounds)


def _lockstep_stats(rounds):
    """(occupancy, total_rounds, lockstep_waste) for a vmapped run: the
    while_loop executes every query to max(rounds), so waste is what the
    batch PAID for already-converged queries."""
    Bq = rounds.shape[0]
    total = jnp.sum(rounds)
    trips = jnp.max(rounds)
    paid = jnp.maximum(Bq * trips, 1)
    return jnp.stack([total.astype(jnp.float32) / paid.astype(jnp.float32),
                      total.astype(jnp.float32),
                      (paid - total).astype(jnp.float32)])


def _pooled_rerank(docs, dmask, queries, cand_ids, a, b, keys,
                   cfg: BatchedConfig):
    """Pooled frontier engine over pre-gathered candidates.

    Stacks the (B, N, L, M) candidates to (B*N, L, M) and the query tokens
    to (B*T, M); every bandit round then reveals ALL queries' selected
    blocks with one ``gather_maxsim_op`` launch on query-offset indices —
    the dense-as-the-hardware-allows reveal the paper's FLOP savings need.
    Returns (topk_scores (B, K), topk_global_ids (B, K), coverage (B,),
    stats (3,) = [frontier occupancy, total rounds, lockstep waste])."""
    Bq, N, L, M = docs.shape
    T = queries.shape[1]
    stacked = docs.reshape(Bq * N, L, M)
    stacked_mask = dmask.reshape(Bq * N, L)
    flat_q = queries.reshape(Bq * T, M)

    def cells(flat_doc, flat_tok):
        return gather_maxsim_op(stacked, stacked_mask, flat_q,
                                flat_doc, flat_tok)

    res = run_pooled_bandit(cells, a, b, keys, cfg, doc_mask=cand_ids >= 0)
    scores = jnp.take_along_axis(res.s_hat, res.topk, axis=1)
    picked = jnp.take_along_axis(cand_ids, res.topk, axis=1)
    gids = jnp.where(picked >= 0, picked, -1)
    stats = jnp.stack([res.occupancy,
                       res.total_rounds.astype(jnp.float32),
                       res.lockstep_waste.astype(jnp.float32)])
    return scores, gids, res.coverage, stats


_RERANK_ENGINES = {"pooled": _pooled_rerank, "vmapped": _vmapped_rerank}


def _rerank_engine(engine: str):
    try:
        return _RERANK_ENGINES[engine]
    except KeyError:
        raise ValueError(f"unknown reveal engine: {engine!r} "
                         f"(expected one of {sorted(_RERANK_ENGINES)})"
                         ) from None


def make_rerank_bandit_step(mesh: Mesh, *, topk: int = 10,
                            alpha_ef: float = 0.3, delta: float = 0.01,
                            block_docs: int = 16, block_tokens: int = 8,
                            max_rounds: int = 64, engine: str = "pooled"):
    """Adaptive reranking step: gather-then-pooled-bandit per query shard.

    Each device runs ONE pooled frontier loop over its whole query shard
    (``engine="pooled"``, the default) instead of vmapping a per-query
    loop; ``engine="vmapped"`` keeps the legacy lockstep path for A/B."""
    names = tuple(mesh.axis_names)
    every = tuple(names)

    cfg = BatchedConfig(k=topk, delta=delta, alpha_ef=alpha_ef,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds)
    rerank = _rerank_engine(engine)

    def step(docs, dmask, queries, cand_ids, a, b):
        """docs (B, N, L, M) pre-gathered candidate embeddings (the routing
        layer gathers them from the sharded corpus as part of stage 1);
        queries (B, T, M), cand_ids (B, N), a/b (B, N, T) support bounds —
        all sharded over every axis on B.
        Returns (topk_global_ids (B, K), coverage (B,))."""
        B = queries.shape[0]
        keys = jax.random.split(jax.random.key(0), B)
        _, gids, cov, _ = rerank(docs, dmask, queries, cand_ids, a, b,
                                 keys, cfg)
        return gids, cov

    in_specs = (P(every, None, None, None),   # docs (B, N, L, M)
                P(every, None, None),          # dmask (B, N, L)
                P(every, None, None),          # queries (B, T, M)
                P(every, None),                # cand_ids (B, N)
                P(every, None, None),          # a (B, N, T)
                P(every, None, None))          # b
    out_specs = (P(every, None), P(every))

    return step, in_specs, out_specs


def make_rerank_budgeted_step(mesh: Mesh, *, topk: int = 10,
                              tokens_per_doc: int = 10):
    """§Perf: the paper's pruning INSIDE the sharded serving step.

    Identical layout to make_rerank_dense_step, but each (query, candidate)
    pair scores only ``tokens_per_doc`` of the T query tokens — the ones the
    bounds machinery selected (Doc-TopMargin order offline, or the bandit's
    reveal set online), supplied as ``tok_idx``. The einsum contracts a
    (B, N_loc, G', M) gathered query tensor instead of the full (B, T, M),
    so compiled FLOPs/bytes drop by ~G'/T — Col-Bandit's coverage savings
    made visible to the roofline."""
    every = tuple(mesh.axis_names)

    def step(corpus_embs, corpus_mask, queries, cand_local, tok_idx):
        def shard_fn(c_embs, c_mask, q, cand, toks):
            cand = cand[:, 0, :]                              # (B, N_loc)
            toks = toks[:, 0, :, :]                           # (B, N_loc, G')

            def score_chunk(args):
                q_c, cand_c, tok_c = args
                docs, dmask = gather_candidates(c_embs, c_mask, cand_c)
                # gather the selected query tokens per (query, cand)
                q_sel = jnp.take_along_axis(
                    q_c[:, None, :, :],
                    tok_c[:, :, :, None].astype(jnp.int32), axis=2)
                sims = jnp.einsum("bnlm,bngm->bnlg",
                                  docs.astype(jnp.float32),
                                  q_sel.astype(jnp.float32))
                sims = jnp.where(dmask[:, :, :, None], sims, _NEG)
                h = jnp.max(sims, axis=2)                     # (b, N, G')
                h = jnp.where(jnp.any(dmask, 2)[:, :, None], h, 0.0)
                return jnp.sum(h, axis=-1)

            scores = _chunked_over_queries(score_chunk, (q, cand, toks))
            scores = jnp.where(cand >= 0, scores, _NEG)
            gids = _shard_global_ids(cand, c_embs.shape[0], every)
            return _merge_scorecards(scores, gids, every, topk)

        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(P(every, None, None), P(every, None),
                      P(None, None, None), P(None, every, None),
                      P(None, every, None, None)),
            out_specs=(P(None, None), P(None, None)),
        )(corpus_embs, corpus_mask, queries, cand_local, tok_idx)

    return step


def make_rerank_two_phase_step(mesh: Mesh, *, topk: int = 10,
                               survivors: int = 2):
    """§Perf H3 iteration 2: PLAID-style two-phase scoring.

    H3 iteration 1 (token pruning) taught us the dominant memory term is
    READING candidate token embeddings (L x M per doc), which query-token
    pruning cannot cut. Phase 1 therefore screens candidates on a POOLED
    doc summary (1 x M per doc — 128x fewer bytes): approx score =
    sum_t <q_t, pooled_d>. Only the top ``survivors`` of N_loc candidates
    per (query, shard) proceed to exact MaxSim scoring — the full
    (L x M)-byte reads shrink by survivors/N_loc.

    Non-survivors keep their phase-1 score in the global merge (standard
    multi-stage retrieval semantics: monotone-ish, not exact)."""
    every = tuple(mesh.axis_names)

    def step(corpus_embs, corpus_mask, corpus_pooled, queries, cand_local):
        def shard_fn(c_embs, c_mask, c_pool, q, cand):
            cand = cand[:, 0, :]                              # (B, N_loc)

            def score_chunk(args):
                q_c, cand_c = args                            # (b,T,M),(b,N)
                safe = jnp.maximum(cand_c, 0)
                # --- phase 1: pooled screening (M bytes per doc) ---
                pooled = jnp.take(c_pool, safe, axis=0)       # (b, N, M)
                q_sum = jnp.sum(q_c.astype(jnp.float32), axis=1)   # (b, M)
                s1 = jnp.einsum("bnm,bm->bn", pooled.astype(jnp.float32),
                                q_sum)
                s1 = jnp.where(cand_c >= 0, s1, _NEG)
                # --- phase 2: exact MaxSim for the survivors only ---
                _, surv_pos = jax.lax.top_k(s1, survivors)    # (b, k2)
                surv_ids = jnp.take_along_axis(cand_c, surv_pos, axis=1)
                docs, dmask = gather_candidates(c_embs, c_mask, surv_ids)
                s2 = _local_maxsim_scores(docs, dmask, q_c)   # (b, k2)
                s2 = jnp.where(surv_ids >= 0, s2, _NEG)
                # exact scores override the phase-1 proxies
                out = s1 * 1e-3                               # keep ordering,
                out = out.at[jnp.arange(out.shape[0])[:, None],  # under exact
                             surv_pos].set(s2)
                return out

            scores = _chunked_over_queries(score_chunk, (q, cand))
            gids = _shard_global_ids(cand, c_embs.shape[0], every)
            return _merge_scorecards(scores, gids, every, topk)

        return jax.shard_map(
            shard_fn, mesh=mesh, check_vma=False,
            in_specs=(P(every, None, None), P(every, None), P(every, None),
                      P(None, None, None), P(None, every, None)),
            out_specs=(P(None, None), P(None, None)),
        )(corpus_embs, corpus_mask, corpus_pooled, queries, cand_local)

    return step


# ---------------------------------------------------------------------------
# Engine-facing serving steps (repro.serve.RetrievalEngine).
#
# Same scorers as the shard_map flavors above, but expressed as plain
# jit-able programs over a replicated (or host-local) corpus: the engine
# pads every batch into a small set of static (B, T_bucket, N_bucket)
# shapes and AOT-compiles one executable per bucket, so these must be pure
# functions of statically-shaped arrays. Both flavors share the
# ``gather_candidates`` routing path and one uniform signature:
#
#   step(corpus_embs, corpus_mask, queries, cand_ids, a, b, key)
#     -> (topk_scores (B, K), topk_global_ids (B, K), reveal_frac (B,),
#         stats (3,))
#
# ``reveal_frac`` is the fraction of (candidate, token) MaxSim cells the
# flavor actually computed: 1.0 for dense, the bandit's coverage (Eq. 6)
# for the adaptive flavor. ``stats`` is the reveal-engine diagnostic
# vector [frontier_occupancy, total_rounds, lockstep_waste]: for the
# pooled engine, occupancy is the measured live-slot fraction of the
# shared frontier; for the vmapped engine it is the lockstep duty cycle
# sum(rounds) / (B * max(rounds)); dense reports [1, 0, 0].
# ---------------------------------------------------------------------------

def rerank_dense_step(corpus_embs, corpus_mask, queries, cand_ids, a, b,
                      key, *, topk: int = 10):
    """Exact MaxSim over the candidate list; a/b/key accepted (and ignored)
    so dense and bandit executables are interchangeable to the engine."""
    del a, b, key
    docs, dmask = gather_candidates(corpus_embs, corpus_mask, cand_ids)
    scores = _local_maxsim_scores(docs, dmask, queries)
    scores = jnp.where(cand_ids >= 0, scores, _NEG)
    best, pos = jax.lax.top_k(scores, topk)
    gids = jnp.take_along_axis(cand_ids, pos, axis=1)
    gids = jnp.where(best > _NEG / 2, gids, -1)
    frac = jnp.ones((queries.shape[0],), jnp.float32)
    stats = jnp.array([1.0, 0.0, 0.0], jnp.float32)
    return best, gids, frac, stats


def rerank_bandit_step(corpus_embs, corpus_mask, queries, cand_ids, a, b,
                       key, *, topk: int = 10, alpha_ef: float = 0.3,
                       delta: float = 0.01, block_docs: int = 8,
                       block_tokens: int = 8, max_rounds: int = -1,
                       max_block_docs: int = 0, engine: str = "pooled"):
    """Adaptive Col-Bandit rerank over the candidate list.

    ``engine="pooled"`` (default) drives the whole batch through one
    pooled frontier loop — one gather_maxsim kernel launch per round,
    converged queries retired (and, with ``max_block_docs`` >
    ``block_docs``, their reveal slots redistributed to the stragglers).
    ``engine="vmapped"`` is the legacy per-query lockstep loop."""
    rerank = _rerank_engine(engine)
    cfg = BatchedConfig(k=topk, delta=delta, alpha_ef=alpha_ef,
                        block_docs=block_docs, block_tokens=block_tokens,
                        max_rounds=max_rounds, max_block_docs=max_block_docs)
    docs, dmask = gather_candidates(corpus_embs, corpus_mask, cand_ids)
    keys = jax.random.split(key, queries.shape[0])
    return rerank(docs, dmask, queries, cand_ids, a, b, keys, cfg)


def make_serving_step(flavor: str, *, topk: int = 10, alpha_ef: float = 0.3,
                      delta: float = 0.01, block_docs: int = 8,
                      block_tokens: int = 8, max_rounds: int = -1,
                      max_block_docs: int = 0, engine: str = "pooled"):
    """Shape-bucket-aware step factory the serving engine consumes.

    Returns an un-jitted step with the uniform engine signature; the caller
    owns compilation (``RetrievalEngine`` AOT-lowers one executable per
    (flavor, token-bucket, candidate-bucket) and keeps the cache warm).
    ``engine`` picks the bandit reveal engine (pooled frontier vs legacy
    vmapped lockstep); dense ignores it."""
    _rerank_engine(engine)
    if flavor == "dense":
        return functools.partial(rerank_dense_step, topk=topk)
    if flavor == "bandit":
        return functools.partial(
            rerank_bandit_step, topk=topk, alpha_ef=alpha_ef, delta=delta,
            block_docs=block_docs, block_tokens=block_tokens,
            max_rounds=max_rounds, max_block_docs=max_block_docs,
            engine=engine)
    raise ValueError(f"unknown serving flavor: {flavor!r}")
