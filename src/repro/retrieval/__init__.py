"""Two-stage late-interaction retrieval: index, stage-1 kNN, reranking."""
from repro.retrieval.ann import CandidateSet, generate_candidates, generic_bounds
from repro.retrieval.corpus import (CentroidRouter, Corpus, build_corpus,
                                    build_router, gather_tokens, route_mass,
                                    route_quotas, validate_quotas)
from repro.retrieval.index import TokenIndex, build_index, build_index_from_ragged
from repro.retrieval.pipeline import (RerankResult, ServeResult,
                                      evaluate_dataset, rerank_query,
                                      serve_queries)
from repro.retrieval.sharded import (ShardedCorpus, route_aligned,
                                     route_batch, route_candidates,
                                     shard_corpus)
