"""Two-stage late-interaction retrieval: index, stage-1 kNN, reranking."""
from repro.retrieval.ann import CandidateSet, generate_candidates, generic_bounds
from repro.retrieval.index import TokenIndex, build_index, build_index_from_ragged
from repro.retrieval.pipeline import RerankResult, evaluate_dataset, rerank_query
from repro.retrieval.sharded import (ShardedCorpus, route_aligned,
                                     route_batch, route_candidates,
                                     shard_corpus)
